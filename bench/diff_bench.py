#!/usr/bin/env python3
"""Compare a fresh BENCH_server.json against the committed baseline.

Two surfaces, two rules:

* ``metrics.deterministic`` (and the top-level blocks it mirrors) holds
  simulated quantities only — byte-identical across machines, thread
  counts, and runs. Any difference there is a real behavioural change
  and fails the diff (exit 1).
* ``metrics.host`` holds wall-clock-derived numbers (throughput,
  speedups, overhead ladders). Those drift with the machine, so numeric
  leaves are compared with a relative tolerance and only *reported* by
  default; ``--strict`` turns out-of-tolerance host drift into a
  failure too.

Usage:
    python3 bench/diff_bench.py                  # compare ./BENCH_server.json vs bench/BENCH_server.json
    python3 bench/diff_bench.py --write          # promote the fresh record to the committed baseline
    python3 bench/diff_bench.py --strict --tolerance 0.5

The committed baseline starts life as a ``{"bootstrap": true}`` marker
(no machine has recorded a run yet); the first ``--write`` replaces it
with a real record.
"""

import argparse
import json
import shutil
import sys
from pathlib import Path


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None


def canon(value):
    """Canonical byte form of a JSON subtree (sorted keys, fixed indent)."""
    return json.dumps(value, indent=1, sort_keys=True)


def walk_numeric(value, prefix=""):
    """Yield (path, number) for every numeric leaf of a JSON subtree."""
    if isinstance(value, dict):
        for k in sorted(value):
            yield from walk_numeric(value[k], f"{prefix}.{k}" if prefix else k)
    elif isinstance(value, list):
        for i, v in enumerate(value):
            yield from walk_numeric(v, f"{prefix}[{i}]")
    elif isinstance(value, bool):
        pass
    elif isinstance(value, (int, float)):
        yield prefix, float(value)


def first_diff_line(a, b):
    """First differing line between two canonical dumps (context for CI logs)."""
    for la, lb in zip(a.splitlines(), b.splitlines()):
        if la != lb:
            return f"-{la.strip()}\n  +{lb.strip()}"
    return "(one record is a prefix of the other)"


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", default="BENCH_server.json", help="freshly-written record")
    ap.add_argument(
        "--record",
        default=str(Path(__file__).resolve().parent / "BENCH_server.json"),
        help="committed baseline",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="relative drift allowed on host (wall-clock) numeric leaves",
    )
    ap.add_argument(
        "--strict", action="store_true", help="fail on out-of-tolerance host drift too"
    )
    ap.add_argument(
        "--write", action="store_true", help="promote the fresh record to the baseline"
    )
    args = ap.parse_args()

    fresh = load(args.fresh)
    if fresh is None:
        print(f"no fresh record at {args.fresh} — run a multi_viewer mode first", file=sys.stderr)
        return 2
    record = load(args.record)

    if args.write:
        shutil.copyfile(args.fresh, args.record)
        print(f"promoted {args.fresh} -> {args.record}")
        return 0

    if record is None or record.get("bootstrap"):
        print(
            f"baseline {args.record} is a bootstrap placeholder — nothing to compare.\n"
            f"Promote the fresh record with: python3 bench/diff_bench.py --write"
        )
        return 0

    fresh_metrics = fresh.get("metrics", {})
    record_metrics = record.get("metrics", {})

    # Deterministic surface: byte-for-byte.
    det_fresh = canon(fresh_metrics.get("deterministic", {}))
    det_record = canon(record_metrics.get("deterministic", {}))
    failed = False
    if det_fresh != det_record:
        print("DETERMINISTIC DIFF (simulated surface changed — a real behavioural change):")
        print("  " + first_diff_line(det_record, det_fresh))
        failed = True
    else:
        print("deterministic surface: identical")

    # Host surface: tolerant numeric comparison, leaf by leaf.
    host_fresh = dict(walk_numeric(fresh_metrics.get("host", {})))
    host_record = dict(walk_numeric(record_metrics.get("host", {})))
    drifted = []
    for path in sorted(set(host_fresh) & set(host_record)):
        a, b = host_record[path], host_fresh[path]
        base = max(abs(a), abs(b), 1e-12)
        rel = abs(a - b) / base
        if rel > args.tolerance:
            drifted.append((path, a, b, rel))
    missing = sorted(set(host_record) - set(host_fresh))
    added = sorted(set(host_fresh) - set(host_record))
    if drifted:
        print(f"host drift beyond {args.tolerance:.0%} on {len(drifted)} leaves:")
        for path, a, b, rel in drifted[:20]:
            print(f"  {path}: {a:.4g} -> {b:.4g}  ({rel:+.0%})")
        if args.strict:
            failed = True
    else:
        print(f"host surface: {len(host_fresh)} numeric leaves within {args.tolerance:.0%}")
    if missing:
        print(f"host leaves missing from the fresh record: {missing[:10]}")
    if added:
        print(f"new host leaves (not in the baseline): {added[:10]}")

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
