//! Minimal offline drop-in for the `anyhow` crate, covering the subset this
//! repository uses: [`Error`], [`Result`], the [`Context`] extension trait,
//! and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The error value is a context chain of rendered messages (outermost
//! first). Like real `anyhow`, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion (and thus `?` on any standard
//! error) coherent.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error. Display shows the outermost message; Debug
/// shows the whole chain (`Caused by:` sections), mirroring `anyhow`.
pub struct Error {
    /// Messages, outermost context first; the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or("error"))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("error"))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, msg) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to a fallible value (`Result` of any convertible error,
/// or `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] when `cond` is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: Result<()> = Err(io_err()).with_context(|| "opening scene".to_string());
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "opening scene");
        assert_eq!(e.root_cause(), "gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        let e = anyhow!("plain {}", 5);
        assert_eq!(e.to_string(), "plain 5");
    }

    #[test]
    fn result_context_on_anyhow_error_itself() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.root_cause(), "inner");
    }
}
