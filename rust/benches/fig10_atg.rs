//! Fig. 10 reproduction — Adaptive Tile Grouping.
//!
//! (a) DRAM access count, ATG vs conventional raster scan, sweeping the
//!     user threshold {0.3, 0.5, 0.7} × Tile Blocks {1, 2, 4, 8}.
//!     Paper: optimum 1.6× at threshold 0.5 / TB 1; 0.3 over-groups within
//!     limited buffer capacity, 0.7 misses strong connections.
//! (b) Energy with/without frame-to-frame correlation (FFC) at th=0.5 TB=4.
//!     Paper: 5.2× reduction (average condition), 2.2× (extreme).

use gaucim::bench::{bench_scale, section, Bench};
use gaucim::camera::ViewCondition;
use gaucim::coordinator::App;
use gaucim::pipeline::{FramePipeline, PipelineConfig};
use gaucim::scene::synth::SceneKind;
use gaucim::tiles::atg::AtgConfig;
use gaucim::util::json::Json;

/// Sum of blend-stage DRAM bursts (the Fig. 10(a) metric) and grouping-stage
/// energy (the Fig. 10(b) metric) over a trajectory. Frame 0 is excluded
/// from the energy sum — both variants pay the identical phase-1 cost there.
fn blend_bursts(
    app: &App,
    config: PipelineConfig,
    cond: ViewCondition,
    frames: usize,
    reset_each_frame: bool,
) -> (u64, f64) {
    let traj = app.trajectory(cond, frames);
    let mut pipeline = FramePipeline::new(&app.scene, config);
    let mut bursts = 0u64;
    let mut atg_energy = 0.0;
    for (i, (cam, t)) in traj.iter().enumerate() {
        if reset_each_frame {
            pipeline.reset();
        }
        let r = pipeline.render_frame(cam, *t, false);
        bursts += r.traffic.blend_dram.bursts;
        if i > 0 {
            atg_energy += r.energy.atg_pj;
        }
    }
    (bursts, atg_energy)
}

fn main() {
    let n = 150_000 / bench_scale();
    let frames = 5;
    let mut app = App::new(SceneKind::DynamicLarge, n, 42);
    app.config = app.config.clone().with_resolution(1280, 720);
    // The scaled scene has ~~10x fewer visible splats than the paper-scale
    // workload; shrink the buffer by the same factor so the working-set /
    // capacity ratio (what ATG's reuse depends on) matches the paper's
    // 256 KB configuration (DESIGN.md §7).
    app.config.sram_bytes = 64 * 1024;

    // ---------------------------------------------------------- (a) -----
    section(&format!(
        "Fig. 10(a) — blend DRAM accesses: ATG sweep vs raster scan ({n} gaussians)"
    ));
    let base = PipelineConfig {
        use_atg: false,
        ..app.config.clone()
    };
    let (raster_bursts, _) = blend_bursts(&app, base, ViewCondition::Average, frames, false);
    println!("raster-scan baseline: {raster_bursts} bursts over {frames} frames\n");
    println!(
        "{:<12} {:>4} {:>14} {:>11} {:>22}",
        "threshold", "TB", "bursts", "reduction", "paper note"
    );

    let mut rows = Vec::new();
    let mut best: Option<(f64, f32, usize)> = None;
    for &th in &[0.3f32, 0.5, 0.7] {
        for &tb in &[1usize, 2, 4, 8] {
            let config = PipelineConfig {
                use_atg: true,
                atg: AtgConfig {
                    user_threshold: th,
                    tile_block: tb,
                    ..AtgConfig::default()
                },
                ..app.config.clone()
            };
            let (bursts, _) = blend_bursts(&app, config, ViewCondition::Average, frames, false);
            let reduction = raster_bursts as f64 / bursts.max(1) as f64;
            let note = if (th, tb) == (0.5, 1) {
                "paper optimum: 1.6x"
            } else if (th, tb) == (0.5, 4) {
                "paper operating point"
            } else {
                ""
            };
            println!("{th:<12} {tb:>4} {bursts:>14} {reduction:>10.3}x {note:>22}");
            if best.map(|(b, _, _)| reduction > b).unwrap_or(true) {
                best = Some((reduction, th, tb));
            }
            rows.push(
                Json::obj()
                    .set("threshold", th)
                    .set("tile_block", tb)
                    .set("bursts", bursts)
                    .set("reduction", reduction),
            );
        }
    }
    if let Some((r, th, tb)) = best {
        println!("\nbest: {r:.3}x at threshold {th} / TB {tb} (paper: 1.6x at 0.5 / 1)");
    }

    // ---------------------------------------------------------- (b) -----
    section("Fig. 10(b) — ATG energy with/without frame-to-frame correlation (th=0.5, TB=4)");
    let op = PipelineConfig {
        use_atg: true,
        atg: AtgConfig { user_threshold: 0.5, tile_block: 4, ..AtgConfig::default() },
        ..app.config.clone()
    };
    let (_, e_noffc) = blend_bursts(&app, op.clone(), ViewCondition::Average, frames, true);
    let (_, e_avg) = blend_bursts(&app, op.clone(), ViewCondition::Average, frames, false);
    let (_, e_ext) = blend_bursts(&app, op.clone(), ViewCondition::Extreme, frames, false);
    println!("  without FFC (regroup every frame): {:.3} nJ", e_noffc * 1e-3);
    println!(
        "  with FFC, average condition:       {:.3} nJ  ({:.2}x vs no-FFC; paper 5.2x)",
        e_avg * 1e-3,
        e_noffc / e_avg
    );
    println!(
        "  with FFC, extreme condition:       {:.3} nJ  ({:.2}x vs no-FFC; paper 2.2x)",
        e_ext * 1e-3,
        e_noffc / e_ext
    );
    rows.push(
        Json::obj()
            .set("energy_no_ffc_pj", e_noffc)
            .set("energy_ffc_average_pj", e_avg)
            .set("energy_ffc_extreme_pj", e_ext)
            .set("ffc_average_reduction", e_noffc / e_avg)
            .set("ffc_extreme_reduction", e_noffc / e_ext),
    );

    // Host timing for one ATG-enabled frame.
    section("host timing");
    let traj = app.trajectory(ViewCondition::Average, 1);
    let mut pipeline = FramePipeline::new(&app.scene, op);
    let (cam, t) = &traj[0];
    let r = Bench::quick().run("pipeline_frame(atg, perf-only)", || {
        pipeline.render_frame(cam, *t, false)
    });
    println!("{}", r.row());

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig10_atg.json", Json::Arr(rows).pretty()).ok();
    println!("\nwrote reports/fig10_atg.json");
}
