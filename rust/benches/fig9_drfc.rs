//! Fig. 9 reproduction: normalized DRAM access count, DR-FC vs conventional
//! frustum culling, for grid numbers 4 / 8 / 16 on the dynamic scene.
//!
//! Paper result: DR-FC reduces DRAM accesses 2.94× (grid 4) → 3.66×
//! (grid 16). Expect the same monotone shape; absolute ratios depend on the
//! synthetic scene's visible fraction.

use gaucim::bench::{bench_scale, metric_row, section, Bench};
use gaucim::camera::ViewCondition;
use gaucim::coordinator::App;
use gaucim::culling::conventional::ConventionalCulling;
use gaucim::culling::{DrFc, GridConfig, GridPartition};
use gaucim::memory::dram::DramModel;
use gaucim::scene::synth::SceneKind;
use gaucim::scene::DramLayout;
use gaucim::util::json::Json;

fn main() {
    let n = 150_000 / bench_scale();
    let frames = 6;
    let mut app = App::new(SceneKind::DynamicLarge, n, 42);
    app.config = app.config.clone().with_resolution(640, 360);
    let traj = app.trajectory(ViewCondition::Average, frames);

    section(&format!(
        "Fig. 9 — DR-FC vs conventional culling (dynamic scene, {n} gaussians, {frames} frames)"
    ));
    println!(
        "{:<8} {:>16} {:>16} {:>12} {:>14}",
        "grid", "conv bursts/frm", "drfc bursts/frm", "reduction", "paper"
    );

    let paper = [(4usize, 2.94), (8, 3.3), (16, 3.66)];
    let mut rows = Vec::new();
    let mut timing = None;

    for &(grid_n, paper_red) in &paper {
        let grid = GridPartition::build(&app.scene, GridConfig::new(grid_n));
        let layout = DramLayout::build(&app.scene, &grid);

        let mut conv_bursts = 0u64;
        let mut drfc_bursts = 0u64;
        for (cam, t) in &traj {
            let mut d = DramModel::default_lpddr5();
            ConventionalCulling::new(&app.scene, &layout).cull(cam, *t, &mut d);
            conv_bursts += d.stats().bursts;

            let mut d = DramModel::default_lpddr5();
            DrFc::new(&app.scene, &grid, &layout).cull(cam, *t, &mut d);
            drfc_bursts += d.stats().bursts;
        }
        let reduction = conv_bursts as f64 / drfc_bursts.max(1) as f64;
        println!(
            "{:<8} {:>16} {:>16} {:>11.2}x {:>13.2}x",
            grid_n,
            conv_bursts / frames as u64,
            drfc_bursts / frames as u64,
            reduction,
            paper_red
        );
        rows.push(
            Json::obj()
                .set("grid", grid_n)
                .set("conv_bursts_per_frame", conv_bursts / frames as u64)
                .set("drfc_bursts_per_frame", drfc_bursts / frames as u64)
                .set("reduction", reduction)
                .set("paper_reduction", paper_red),
        );

        // Wall-clock of one DR-FC pass at grid 4 (the operating point).
        if grid_n == 4 {
            let drfc = DrFc::new(&app.scene, &grid, &layout);
            let (cam, t) = &traj[0];
            let r = Bench::quick().run("drfc_cull_frame(grid=4)", || {
                let mut d = DramModel::default_lpddr5();
                drfc.cull(cam, *t, &mut d)
            });
            timing = Some(r);
        }
    }

    // On-chip metadata cost of finer grids (the Fig. 9 trade-off).
    section("grid metadata trade-off");
    for grid_n in [4usize, 8, 16] {
        let grid = GridPartition::build(&app.scene, GridConfig::new(grid_n));
        let layout = DramLayout::build(&app.scene, &grid);
        metric_row(
            &format!("on-chip grid metadata (grid={grid_n})"),
            layout.metadata_bytes() as f64 / 1024.0,
            "KB",
        );
    }

    if let Some(r) = timing {
        section("host timing");
        println!("{}", r.row());
    }

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig9_drfc.json", Json::Arr(rows).pretty()).ok();
    println!("\nwrote reports/fig9_drfc.json");
}
