//! Fig. 2(a) reproduction: latency breakdown of the *unoptimized* dynamic
//! 3DGS pipeline (conventional culling / raster scan / conventional sort)
//! into preprocessing, sorting, and rasterization — plus the optimized
//! pipeline's breakdown for contrast.
//!
//! Paper observation: frustum culling dominates preprocessing time, and the
//! preprocessing bottleneck is exacerbated by the temporal dimension.

use gaucim::bench::{bench_scale, section, Bench};
use gaucim::camera::ViewCondition;
use gaucim::coordinator::App;
use gaucim::pipeline::{profile_breakdown, FramePipeline, PipelineConfig};
use gaucim::scene::synth::SceneKind;
use gaucim::util::json::Json;

fn print_breakdown(label: &str, shares: &[gaucim::pipeline::PhaseShare]) -> Json {
    println!("{label}:");
    let mut obj = Json::obj().set("label", label);
    for s in shares {
        println!(
            "  {:<16} {:>10.3} ms {:>6.1}%",
            s.phase,
            s.ns / 1e6,
            s.share * 100.0
        );
        obj = obj.set(s.phase, s.share);
    }
    obj
}

fn main() {
    let n = 200_000 / bench_scale();
    let frames = 4;
    let mut app = App::new(SceneKind::DynamicLarge, n, 42);
    app.config = app.config.clone().with_resolution(1280, 720);
    let traj = app.trajectory(ViewCondition::Average, frames);

    section(&format!(
        "Fig. 2(a) — phase latency breakdown (dynamic scene, {n} gaussians, 1280x720)"
    ));
    let mut rows = Vec::new();

    let baseline = profile_breakdown(
        &app.scene,
        PipelineConfig::baseline(true).with_resolution(1280, 720),
        &traj,
    );
    rows.push(print_breakdown(
        "baseline (conventional culling + raster + uniform bucket sort)",
        &baseline,
    ));

    println!();
    let optimized = profile_breakdown(&app.scene, app.config.clone(), &traj);
    rows.push(print_breakdown(
        "3DGauCIM (DR-FC + ATG + AII-Sort + DD3D-Flow)",
        &optimized,
    ));

    // The paper's headline observation: preprocessing (dominated by the
    // full-DRAM frustum-culling sweep) shrinks dramatically once DR-FC
    // removes the sweep.
    let pre_base = baseline.iter().find(|s| s.phase == "preprocessing").unwrap();
    let pre_opt = optimized.iter().find(|s| s.phase == "preprocessing").unwrap();
    println!(
        "\npreprocessing latency: baseline {:.3} ms -> optimized {:.3} ms ({:.2}x)",
        pre_base.ns / 1e6,
        pre_opt.ns / 1e6,
        pre_base.ns / pre_opt.ns.max(1e-9)
    );

    section("host timing");
    let mut pipeline = FramePipeline::new(
        &app.scene,
        PipelineConfig::baseline(true).with_resolution(1280, 720),
    );
    let (cam, t) = &traj[0];
    let r = Bench::quick().run("baseline_pipeline_frame(perf-only)", || {
        pipeline.render_frame(cam, *t, false)
    });
    println!("{}", r.row());

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig2_profiling.json", Json::Arr(rows).pretty()).ok();
    println!("\nwrote reports/fig2_profiling.json");
}
