//! Table I reproduction: end-to-end 3DGauCIM vs GSCore-class accelerator vs
//! Jetson AGX Orin, on the static and dynamic large-scale scenes.
//!
//! Paper rows: 3DGauCIM dynamic 211 FPS / 0.63 W / 4.13 mm² / PSNR 31.4;
//! static 214 FPS / 0.28 W / 1.81 mm² / 24.74. GSCore 91.2 FPS / 0.87 W /
//! 3.95 mm² (28 nm, static). Orin 31 FPS / 15 W (dynamic).
//!
//! Absolute FPS depends on workload scale (our synthetic scenes + scaled
//! gaussian counts); the *shape* — 3DGauCIM ≥ 200 FPS class at sub-watt
//! power, GSCore ~2× slower at ~3× power, GPU an order of magnitude slower
//! at ~20× power — is the reproduction target.

use gaucim::baseline::{gscore, jetson, GscoreModel, JetsonModel};
use gaucim::bench::{bench_scale, section, Bench};
use gaucim::camera::ViewCondition;
use gaucim::coordinator::App;
use gaucim::culling::{GridConfig, GridPartition};
use gaucim::energy::StageLatency;
use gaucim::scene::synth::SceneKind;
use gaucim::scene::DramLayout;
use gaucim::util::json::Json;

fn main() {
    let frames = 6;
    let mut rows = Vec::new();

    section("Table I — end-to-end comparison (scaled workload)");
    for kind in [SceneKind::DynamicLarge, SceneKind::StaticLarge] {
        let n = match kind {
            SceneKind::DynamicLarge => 600_000 / bench_scale(),
            SceneKind::StaticLarge => 100_000 / bench_scale(),
        };
        let mut app = App::new(kind, n, 42);
        app.config = app.config.clone().with_resolution(1280, 720);
        let cond = if kind == SceneKind::DynamicLarge {
            ViewCondition::Average
        } else {
            ViewCondition::Static
        };

        // PSNR on one sampled frame, perf on the rest.
        let rep = app.run_sequence(cond, frames, frames);
        let (paper_fps, paper_w, paper_area, paper_psnr) = match kind {
            SceneKind::DynamicLarge => (211.0, 0.63, 4.13, 31.4),
            SceneKind::StaticLarge => (214.0, 0.28, 1.81, 24.74),
        };
        println!("\n--- {} ({n} gaussians, {frames} frames) ---", app.scene.name);
        println!("{}", rep.report.row());
        println!(
            "    PSNR(hw vs reference) {:.2} dB | paper: {} FPS / {} W / {} mm² / PSNR {}",
            rep.psnr_db, paper_fps, paper_w, paper_area, paper_psnr
        );
        println!(
            "    SRAM 256 KB, DCIM {} KB (paper: 256 KB / {} KB)",
            app.config.dcim.storage_kb,
            if kind == SceneKind::DynamicLarge { 144 } else { 48 }
        );

        // GSCore-class model on the identical scene.
        let grid_cfg = if app.scene.dynamic {
            GridConfig::new(4)
        } else {
            GridConfig::static_scene(4)
        };
        let grid = GridPartition::build(&app.scene, grid_cfg);
        let layout = DramLayout::build(&app.scene, &grid);
        let model = GscoreModel::new(&app.scene, &layout, 1280, 720);
        let traj = app.trajectory(cond, 3);
        let mut g_lat = StageLatency::default();
        let mut g_energy = 0.0;
        for (cam, t) in &traj {
            let f = model.render_frame(cam, *t);
            g_lat.add(&f.latency);
            g_energy += f.energy.total_pj();
        }
        let g_lat = g_lat.scale(1.0 / traj.len() as f64);
        let g_fps = 1e9 / g_lat.pipelined_ns();
        let g_power = (g_energy / traj.len() as f64) * 1e-12 * g_fps + 0.12;
        println!(
            "  gscore-class model           {:>7.1} FPS {:>7.3} W   (published {} FPS / {} W / {} mm²)",
            g_fps,
            g_power,
            gscore::published::FPS_STATIC_LARGE,
            gscore::published::POWER_W,
            gscore::published::AREA_MM2
        );

        // Jetson Orin roofline on the same per-frame work.
        let jf = JetsonModel::from_workload(
            (rep.energy.dcim_pj / 0.033) as u64,
            rep.avg_dram_bytes as u64,
        );
        println!(
            "  jetson-orin roofline         {:>7.1} FPS {:>7.3} W   (published {} FPS / {} W)",
            jf.fps,
            jetson::published::POWER_W,
            jetson::published::FPS_DYNAMIC,
            jetson::published::POWER_W
        );

        rows.push(
            Json::obj()
                .set("scene", app.scene.name.as_str())
                .set("gaussians", n)
                .set("gaucim_fps", rep.report.fps)
                .set("gaucim_power_w", rep.report.power_w)
                .set("gaucim_area_mm2", rep.report.area_mm2)
                .set("gaucim_psnr_db", rep.psnr_db)
                .set("gscore_fps", g_fps)
                .set("gscore_power_w", g_power)
                .set("jetson_fps", jf.fps)
                .set("paper_gaucim_fps", paper_fps)
                .set("paper_gaucim_power_w", paper_w)
                .set("paper_gaucim_area_mm2", paper_area),
        );
    }

    section("host timing (full-stack frame, dynamic paper config)");
    let mut app = App::new(SceneKind::DynamicLarge, 100_000 / bench_scale(), 42);
    app.config = app.config.clone().with_resolution(1280, 720);
    let traj = app.trajectory(ViewCondition::Average, 1);
    let mut pipeline = gaucim::pipeline::FramePipeline::new(&app.scene, app.config.clone());
    let (cam, t) = &traj[0];
    let r = Bench::quick().run("table1_frame(perf-only)", || {
        pipeline.render_frame(cam, *t, false)
    });
    println!("{}", r.row());

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/table1_endtoend.json", Json::Arr(rows).pretty()).ok();
    println!("\nwrote reports/table1_endtoend.json");
}
