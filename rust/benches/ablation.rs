//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **exp-LUT fraction bits** (paper §3.4: "12-bit precision maintains
//!    PSNR without degradation") — sweep 4..16 bits, measure max relative
//!    alpha error and scene PSNR;
//! 2. **posteriori knowledge on/off** for ATG + AII jointly (reset the
//!    pipeline's carry state each frame);
//! 3. **buffer depth segments** (the §3.3-III co-design with AII-Sort's N);
//! 4. **DR-FC duplicate-reference skip** on/off (the §3.1 memory-access
//!    strategy).

use gaucim::bench::{bench_scale, metric_row, section};
use gaucim::camera::ViewCondition;
use gaucim::coordinator::App;
use gaucim::dcim::ExpLut;
use gaucim::pipeline::{FramePipeline, PipelineConfig};
use gaucim::render::{psnr, HwRenderer, ReferenceRenderer};
use gaucim::scene::synth::SceneKind;
use gaucim::util::json::Json;

fn main() {
    let n = 60_000 / bench_scale();
    let mut app = App::new(SceneKind::DynamicLarge, n, 42);
    app.config = app.config.clone().with_resolution(640, 360);
    let mut report = Json::obj();

    // ------------------------------------------------------------ 1 -----
    section("ablation 1 — exp-LUT fraction bits (paper value: 12 — the 4x8-entry LUT ceiling)");
    let cam = app.camera_template();
    let reference = ReferenceRenderer::new(640, 360).render(&app.scene, &cam, 0.5);
    let mut lut_rows = Vec::new();
    for bits in [4u32, 8, 12] {
        let lut = ExpLut::with_frac_bits(bits);
        let rel = lut.max_rel_error(-30.0, 0.0, 20_000);
        let mut hw = HwRenderer::with_exp(640, 360, lut);
        hw.fp16_params = false; // isolate the LUT effect
        let img = hw.render(&app.scene, &cam, 0.5);
        let p = psnr(&reference, &img);
        println!("  {bits:>2} bits: max rel err {rel:.2e}, scene PSNR {p:.2} dB");
        lut_rows.push(
            Json::obj()
                .set("frac_bits", bits as u64)
                .set("max_rel_error", rel)
                .set("psnr_db", p),
        );
    }
    report = report.set("exp_lut_bits", Json::Arr(lut_rows));

    // ------------------------------------------------------------ 2 -----
    section("ablation 2 — posteriori knowledge (ATG + AII carry) on/off");
    let frames = 5;
    let traj = app.trajectory(ViewCondition::Average, frames);
    let mut run = |reset: bool| -> (u64, u64, f64) {
        let mut pipeline = FramePipeline::new(&app.scene, app.config.clone());
        let mut atg_ops = 0u64;
        let mut sort_cycles = 0u64;
        let mut energy = 0.0;
        for (i, (cam, t)) in traj.iter().enumerate() {
            if reset {
                pipeline.reset();
            }
            let r = pipeline.render_frame(cam, *t, false);
            if i > 0 {
                atg_ops += r.atg_ops;
                sort_cycles += r.sort.cycles;
                energy += r.energy.atg_pj + r.energy.sort_pj;
            }
        }
        (atg_ops, sort_cycles, energy)
    };
    let (ops_off, cyc_off, e_off) = run(true);
    let (ops_on, cyc_on, e_on) = run(false);
    metric_row("ATG ops/frame (posteriori OFF)", ops_off as f64 / 4.0, "ops");
    metric_row("ATG ops/frame (posteriori ON)", ops_on as f64 / 4.0, "ops");
    metric_row("sort cycles/frame (OFF)", cyc_off as f64 / 4.0, "cycles");
    metric_row("sort cycles/frame (ON)", cyc_on as f64 / 4.0, "cycles");
    metric_row("grouping+sort energy reduction", e_off / e_on.max(1e-9), "x");
    report = report
        .set("posteriori_atg_ops_off", ops_off)
        .set("posteriori_atg_ops_on", ops_on)
        .set("posteriori_sort_cycles_off", cyc_off)
        .set("posteriori_sort_cycles_on", cyc_on);

    // ------------------------------------------------------------ 3 -----
    section("ablation 3 — SRAM buffer depth segments (co-design with AII N)");
    let mut seg_rows = Vec::new();
    for n_buckets in [2usize, 4, 8, 16] {
        let config = PipelineConfig {
            n_buckets,
            ..app.config.clone()
        };
        let mut pipeline = FramePipeline::new(&app.scene, config);
        let mut hits = 0u64;
        let mut lookups = 0u64;
        for (cam, t) in &traj {
            let r = pipeline.render_frame(cam, *t, false);
            hits += r.traffic.blend_sram.hits;
            lookups += r.traffic.blend_sram.lookups;
        }
        let rate = hits as f64 / lookups.max(1) as f64;
        metric_row(&format!("SRAM hit rate (N = {n_buckets})"), rate * 100.0, "%");
        seg_rows.push(
            Json::obj()
                .set("segments", n_buckets)
                .set("hit_rate", rate),
        );
    }
    report = report.set("buffer_segments", Json::Arr(seg_rows));

    // ------------------------------------------------------------ 4 -----
    section("ablation 4 — DR-FC duplicate-reference skip");
    {
        use gaucim::culling::{DrFc, GridConfig, GridPartition};
        use gaucim::memory::dram::DramModel;
        use gaucim::scene::DramLayout;
        let grid = GridPartition::build(&app.scene, GridConfig::new(4));
        let layout = DramLayout::build(&app.scene, &grid);
        let (cam, t) = &traj[0];

        // With skip (the shipped implementation).
        let mut d = DramModel::default_lpddr5();
        let out = DrFc::new(&app.scene, &grid, &layout).cull(cam, *t, &mut d);
        let with_skip = d.stats().bytes;

        // Without skip: charge every reference individually, duplicates and
        // all — what the paper's "redundant DRAM accesses" would cost.
        let mut d2 = DramModel::default_lpddr5();
        for &flat in &out.visible_cells {
            let (s, e) = layout.cell_ranges[flat];
            if e > s {
                d2.read(s, e - s);
            }
            for &gi in &layout.cell_refs[flat] {
                d2.read(layout.addr[gi as usize], layout.bytes_per_gaussian);
            }
        }
        let without_skip = d2.stats().bytes;
        metric_row("DR-FC bytes/frame (with dedup skip)", with_skip as f64 / 1e6, "MB");
        metric_row("DR-FC bytes/frame (no dedup skip)", without_skip as f64 / 1e6, "MB");
        metric_row(
            "dedup-skip traffic reduction",
            without_skip as f64 / with_skip.max(1) as f64,
            "x",
        );
        report = report
            .set("drfc_bytes_with_skip", with_skip)
            .set("drfc_bytes_without_skip", without_skip);
    }

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/ablation.json", report.pretty()).ok();
    println!("\nwrote reports/ablation.json");
}
