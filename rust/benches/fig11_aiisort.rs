//! Fig. 11 reproduction: sorting latency, AII-Sort vs conventional
//! Bucket-Bitonic, for N ∈ {4, 8, 16} buckets under average and extreme
//! viewing conditions (Tile Blocks = 4, the §4.B operating point).
//!
//! Paper: AII reduces latency 2.75×→6.94× (average) and 2.47×→6.57×
//! (extreme) as N grows 4→16 — more buckets only pay off when the
//! intervals are balanced, which is exactly what the posteriori
//! initialization provides.

use gaucim::bench::{bench_scale, section, Bench};
use gaucim::camera::ViewCondition;
use gaucim::coordinator::App;
use gaucim::pipeline::{FramePipeline, PipelineConfig};
use gaucim::scene::synth::SceneKind;
use gaucim::util::json::Json;

/// Total steady-state sort cycles over a trajectory (frame 0 excluded:
/// phase 1 is identical for both sorters).
fn sort_cycles(app: &App, config: PipelineConfig, cond: ViewCondition, frames: usize) -> u64 {
    let traj = app.trajectory(cond, frames);
    let mut pipeline = FramePipeline::new(&app.scene, config);
    let mut cycles = 0u64;
    for (i, (cam, t)) in traj.iter().enumerate() {
        let r = pipeline.render_frame(cam, *t, false);
        if i > 0 {
            cycles += r.sort.cycles;
        }
    }
    cycles
}

fn main() {
    let n = 120_000 / bench_scale();
    let frames = 5;
    let mut app = App::new(SceneKind::DynamicLarge, n, 42);
    app.config = app.config.clone().with_resolution(640, 360);

    section(&format!(
        "Fig. 11 — sorting latency: AII-Sort vs conventional Bucket-Bitonic ({n} gaussians)"
    ));
    println!(
        "{:<10} {:<4} {:>16} {:>14} {:>11} {:>8}",
        "condition", "N", "conv cycles", "aii cycles", "reduction", "paper"
    );

    let paper = [
        (ViewCondition::Average, 4usize, 2.75),
        (ViewCondition::Average, 8, 4.5),
        (ViewCondition::Average, 16, 6.94),
        (ViewCondition::Extreme, 4, 2.47),
        (ViewCondition::Extreme, 8, 4.0),
        (ViewCondition::Extreme, 16, 6.57),
    ];
    let mut rows = Vec::new();
    for &(cond, n_buckets, paper_red) in &paper {
        let base = PipelineConfig {
            n_buckets,
            ..app.config.clone()
        };
        let conv = sort_cycles(
            &app,
            PipelineConfig { use_aii: false, ..base.clone() },
            cond,
            frames,
        );
        let aii = sort_cycles(
            &app,
            PipelineConfig { use_aii: true, ..base.clone() },
            cond,
            frames,
        );
        let reduction = conv as f64 / aii.max(1) as f64;
        println!(
            "{:<10} {:<4} {:>16} {:>14} {:>10.2}x {:>7.2}x",
            cond.label(),
            n_buckets,
            conv,
            aii,
            reduction,
            paper_red
        );
        rows.push(
            Json::obj()
                .set("condition", cond.label())
                .set("n_buckets", n_buckets)
                .set("conventional_cycles", conv)
                .set("aii_cycles", aii)
                .set("reduction", reduction)
                .set("paper_reduction", paper_red),
        );
    }

    section("host timing");
    let traj = app.trajectory(ViewCondition::Average, 2);
    let mut pipeline = FramePipeline::new(&app.scene, app.config.clone());
    // Warm posteriori state, then time a steady-state frame.
    pipeline.render_frame(&traj[0].0, traj[0].1, false);
    let (cam, t) = &traj[1];
    let r = Bench::quick().run("pipeline_frame(aii steady-state)", || {
        pipeline.render_frame(cam, *t, false)
    });
    println!("{}", r.row());

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/fig11_aiisort.json", Json::Arr(rows).pretty()).ok();
    println!("\nwrote reports/fig11_aiisort.json");
}
