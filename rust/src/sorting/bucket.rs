//! Bucket partitioning primitives shared by the conventional sorter and
//! AII-Sort: boundary construction (uniform / quantile) and routing.

use super::SortItem;

/// `n_buckets − 1` interior boundaries splitting `[lo, hi]` uniformly
/// (the conventional initialization the paper's Challenge 3 criticizes).
pub fn uniform_boundaries(lo: f32, hi: f32, n_buckets: usize) -> Vec<f32> {
    let n = n_buckets.max(1);
    if n == 1 || hi <= lo {
        return vec![];
    }
    let step = (hi - lo) / n as f32;
    (1..n).map(|i| lo + step * i as f32).collect()
}

/// Equal-count boundaries from **sorted** items — the "near-perfect interval"
/// a balanced previous frame hands to the next (AII-Sort phase 2).
pub fn quantile_boundaries(sorted: &[SortItem], n_buckets: usize) -> Vec<f32> {
    let n = n_buckets.max(1);
    if n == 1 || sorted.is_empty() {
        return vec![];
    }
    (1..n)
        .map(|i| {
            let idx = (i * sorted.len()) / n;
            sorted[idx.min(sorted.len() - 1)].0
        })
        .collect()
}

/// Route items into `boundaries.len() + 1` buckets. Items below the first
/// boundary go to bucket 0; at/above the last go to the final bucket — so
/// stale boundaries (posteriori reuse) degrade balance, never correctness.
pub fn assign_buckets(items: &[SortItem], boundaries: &[f32]) -> Vec<Vec<SortItem>> {
    let mut buckets: Vec<Vec<SortItem>> = Vec::new();
    assign_buckets_into(items, boundaries, &mut buckets);
    buckets
}

/// Pooled variant of [`assign_buckets`]: routes into caller-owned scratch,
/// reusing both the outer vector and every inner bucket's capacity. This is
/// the per-block hot path of the sort stage (one call per tile block per
/// frame), so the scratch lives in the frame context — per executor worker
/// — and is covered by the zero-allocation capacity-signature test.
pub fn assign_buckets_into(
    items: &[SortItem],
    boundaries: &[f32],
    buckets: &mut Vec<Vec<SortItem>>,
) {
    let n_buckets = boundaries.len() + 1;
    buckets.resize_with(n_buckets, Vec::new);
    for b in buckets.iter_mut() {
        b.clear();
    }
    for &it in items {
        let mut b = 0;
        while b < boundaries.len() && it.0 >= boundaries[b] {
            b += 1;
        }
        buckets[b].push(it);
    }
}

/// Bucket occupancy counts (balance diagnostics; Fig. 6's motivation).
pub fn occupancies(buckets: &[Vec<SortItem>]) -> Vec<usize> {
    buckets.iter().map(|b| b.len()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::stats::occupancy_cv;
    use crate::util::Rng;

    #[test]
    fn uniform_boundaries_are_even() {
        let b = uniform_boundaries(0.0, 100.0, 4);
        assert_eq!(b, vec![25.0, 50.0, 75.0]);
        assert!(uniform_boundaries(0.0, 100.0, 1).is_empty());
        assert!(uniform_boundaries(5.0, 5.0, 4).is_empty());
    }

    #[test]
    fn assignment_respects_boundaries() {
        let items = vec![(1.0, 0), (26.0, 1), (50.0, 2), (99.0, 3), (-5.0, 4), (200.0, 5)];
        let buckets = assign_buckets(&items, &[25.0, 50.0, 75.0]);
        assert_eq!(buckets.len(), 4);
        assert_eq!(buckets[0], vec![(1.0, 0), (-5.0, 4)]);
        assert_eq!(buckets[1], vec![(26.0, 1)]);
        assert_eq!(buckets[2], vec![(50.0, 2)]); // boundary value goes up
        assert_eq!(buckets[3], vec![(99.0, 3), (200.0, 5)]);
    }

    #[test]
    fn quantile_boundaries_balance_skewed_data() {
        let mut rng = Rng::new(7);
        let mut items: Vec<SortItem> =
            (0..4000u32).map(|i| (rng.log_normal(1.0, 0.9), i)).collect();
        items.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        let lo = items.first().unwrap().0;
        let hi = items.last().unwrap().0;
        let uni = assign_buckets(&items, &uniform_boundaries(lo, hi, 8));
        let qtl = assign_buckets(&items, &quantile_boundaries(&items, 8));

        let cv_uni = occupancy_cv(&occupancies(&uni));
        let cv_qtl = occupancy_cv(&occupancies(&qtl));
        assert!(
            cv_qtl < 0.25 && cv_uni > 1.0,
            "quantile cv {cv_qtl} must beat uniform cv {cv_uni} on skewed data"
        );
    }

    #[test]
    fn assign_buckets_into_matches_and_reuses_capacity() {
        let mut rng = Rng::new(3);
        let items: Vec<SortItem> = (0..500u32).map(|i| (rng.normal(), i)).collect();
        let boundaries = [-0.5f32, 0.0, 0.7];
        let mut scratch: Vec<Vec<SortItem>> = Vec::new();
        assign_buckets_into(&items, &boundaries, &mut scratch);
        assert_eq!(scratch, assign_buckets(&items, &boundaries));

        // Steady-state reuse: a second routing of the same items must not
        // grow the outer vector or any bucket (zero allocations).
        let outer = scratch.capacity();
        let inner: Vec<usize> = scratch.iter().map(Vec::capacity).collect();
        assign_buckets_into(&items, &boundaries, &mut scratch);
        assert_eq!(scratch.capacity(), outer);
        assert_eq!(scratch.iter().map(Vec::capacity).collect::<Vec<_>>(), inner);
        assert_eq!(scratch, assign_buckets(&items, &boundaries));

        // Fewer boundaries shrink the bucket count in place.
        assign_buckets_into(&items, &boundaries[..1], &mut scratch);
        assert_eq!(scratch.len(), 2);
        assert_eq!(scratch, assign_buckets(&items, &boundaries[..1]));
    }

    #[test]
    fn all_items_land_somewhere() {
        let mut rng = Rng::new(9);
        let items: Vec<SortItem> = (0..777u32).map(|i| (rng.normal(), i)).collect();
        let buckets = assign_buckets(&items, &[-1.0, 0.0, 1.0]);
        let total: usize = buckets.iter().map(|b| b.len()).sum();
        assert_eq!(total, 777);
    }
}
