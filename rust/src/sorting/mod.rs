//! Depth sorting: the paper's AII-Sort (Adaptive-Interval-Initialization
//! Bucket-Bitonic sort with posteriori knowledge, §3.2) against the
//! conventional uniform-interval Bucket-Bitonic baseline, over a
//! cycle-accurate model of the on-chip sorting hardware.

pub mod aii;
pub mod bitonic;
pub mod bucket;

pub use aii::AiiSort;
pub use bitonic::{bitonic_sort, BitonicHw};
pub use bucket::{assign_buckets, assign_buckets_into, quantile_boundaries, uniform_boundaries};

/// One sortable record: (depth key, splat index).
pub type SortItem = (f32, u32);

/// Hardware work counters for a sorting pass.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SortStats {
    /// Sorting-engine cycles (comparator array + scans + assignment).
    pub cycles: u64,
    /// Comparator operations executed.
    pub comparisons: u64,
    /// Elements scanned for min/max (conventional phase-one only).
    pub minmax_scanned: u64,
    /// Elements routed into buckets.
    pub bucketed: u64,
}

impl SortStats {
    pub fn add(&mut self, o: &SortStats) {
        self.cycles += o.cycles;
        self.comparisons += o.comparisons;
        self.minmax_scanned += o.minmax_scanned;
        self.bucketed += o.bucketed;
    }
}

/// Shared hardware parameters of the sort engine.
///
/// The on-chip sorter is the paper's "middle ground" (§3.2): a **fixed-width
/// bitonic engine** (`engine_width` elements sort in one pipelined pass)
/// fed by a bucket router. A bucket that fits the engine costs the bitonic
/// network cycles; an **overflowing** bucket falls back to the
/// hardware-efficient-but-performance-limited insertion-class sorter the
/// paper contrasts (parallel shift lanes), whose cost is quadratic:
/// `n²/(2·insertion_lanes)`. This is precisely why unbalanced buckets
/// (Challenge 3) are catastrophic and why AII-Sort's near-uniform intervals
/// recover the bucket sort's amortized O(N).
#[derive(Debug, Clone, Copy)]
pub struct SortHwConfig {
    /// Parallel comparators in the bitonic array.
    pub comparators: usize,
    /// Elements the min/max scanner consumes per cycle.
    pub scan_lanes: usize,
    /// Elements the bucket-router consumes per cycle.
    pub route_lanes: usize,
    /// Bitonic engine capacity (elements sortable in one network pass).
    pub engine_width: usize,
    /// Parallel shift lanes of the insertion-class overflow sorter.
    pub insertion_lanes: usize,
}

impl Default for SortHwConfig {
    fn default() -> Self {
        SortHwConfig {
            comparators: 64,
            scan_lanes: 32,
            route_lanes: 32,
            engine_width: 64,
            insertion_lanes: 64,
        }
    }
}

impl SortHwConfig {
    /// Cycle cost of sorting one bucket of `n` elements on this hardware.
    pub fn bucket_cycles(&self, n: usize) -> u64 {
        if n <= self.engine_width {
            bitonic::network_cycles(n, self.comparators)
        } else {
            // Overflow: insertion-class fallback, quadratic in occupancy.
            (n as u64 * n as u64).div_ceil(2 * self.insertion_lanes as u64)
        }
    }
}

/// Sorter selection for the stage-graph sort stage: the paper's AII-Sort
/// (posteriori interval initialization, per-block state) or the
/// conventional uniform-interval Bucket-Bitonic baseline. Owning the choice
/// here keeps the pipeline's sort stage a single dispatch instead of an
/// ablation `if` in the frame loop.
#[derive(Debug)]
pub enum SortEngine {
    /// AII-Sort with per-tile-block posteriori boundaries.
    Aii(AiiSort),
    /// Conventional min/max-scan + uniform intervals every frame.
    Conventional,
}

impl SortEngine {
    /// Build the engine matching a pipeline configuration.
    pub fn new(use_aii: bool, n_buckets: usize, n_blocks: usize, hw: SortHwConfig) -> SortEngine {
        if use_aii {
            SortEngine::Aii(AiiSort::new(n_buckets, n_blocks, hw))
        } else {
            SortEngine::Conventional
        }
    }

    /// Sort one tile block's working set (ascending depth). The conventional
    /// arm reads `n_buckets`/`hw` live from the caller's configuration,
    /// matching the pre-refactor frame loop exactly.
    pub fn sort_block(
        &mut self,
        block: usize,
        items: &mut Vec<SortItem>,
        n_buckets: usize,
        hw: &SortHwConfig,
    ) -> SortStats {
        match self {
            SortEngine::Aii(aii) => aii.sort_tile(block, items),
            SortEngine::Conventional => conventional_bucket_bitonic(items, n_buckets, hw),
        }
    }

    /// Drop posteriori state (scene cut); no-op for the conventional arm.
    pub fn reset(&mut self) {
        if let SortEngine::Aii(aii) = self {
            aii.reset();
        }
    }
}

/// Conventional Bucket-Bitonic sort (the Fig. 11 baseline): every frame
/// scans min/max depth, splits `[min, max]` into `n_buckets` **uniform**
/// intervals, routes, and bitonic-sorts each bucket.
pub fn conventional_bucket_bitonic(
    items: &mut Vec<SortItem>,
    n_buckets: usize,
    hw: &SortHwConfig,
) -> SortStats {
    let mut buckets: Vec<Vec<SortItem>> = Vec::new();
    conventional_bucket_bitonic_into(items, n_buckets, hw, &mut buckets)
}

/// Pooled variant of [`conventional_bucket_bitonic`]: routes through
/// caller-owned bucket scratch (the executor hands each worker its own),
/// so steady-state frames allocate no bucket vectors.
pub fn conventional_bucket_bitonic_into(
    items: &mut Vec<SortItem>,
    n_buckets: usize,
    hw: &SortHwConfig,
    buckets: &mut Vec<Vec<SortItem>>,
) -> SortStats {
    let mut stats = SortStats::default();
    let n = items.len();
    if n <= 1 {
        return stats;
    }

    // Phase one every frame: full min/max scan.
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &(d, _) in items.iter() {
        lo = lo.min(d);
        hi = hi.max(d);
    }
    stats.minmax_scanned += n as u64;
    stats.cycles += (n as u64).div_ceil(hw.scan_lanes as u64);

    let boundaries = uniform_boundaries(lo, hi, n_buckets);
    sort_with_boundaries_into(items, &boundaries, hw, &mut stats, buckets);
    stats
}

/// Route into buckets by `boundaries`, bitonic-sort each bucket, and
/// splice back in ascending depth order — the bucket-route + per-bucket
/// sort core shared by the conventional path and AII-Sort, over
/// caller-owned bucket scratch (see [`assign_buckets_into`]).
pub(crate) fn sort_with_boundaries_into(
    items: &mut Vec<SortItem>,
    boundaries: &[f32],
    hw: &SortHwConfig,
    stats: &mut SortStats,
    buckets: &mut Vec<Vec<SortItem>>,
) {
    let n = items.len();
    assign_buckets_into(items, boundaries, buckets);
    stats.bucketed += n as u64;
    stats.cycles += (n as u64).div_ceil(hw.route_lanes as u64);
    // Routing comparisons: linear interval compare per element.
    stats.comparisons += n as u64 * (boundaries.len() as u64 + 1);

    items.clear();
    for bucket in buckets.iter_mut() {
        // Numeric path: host sort (same ascending result the bitonic
        // network produces — the network itself is validated separately in
        // `bitonic`'s tests; running it per bucket was a host hot spot,
        // see EXPERIMENTS.md §Perf).
        bucket.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        // Performance path: closed-form comparator count + the fixed-width
        // engine / overflow-fallback cycle cost.
        stats.comparisons += bitonic::network_passes(bucket.len())
            * (bucket.len().next_power_of_two() as u64 / 2);
        stats.cycles += hw.bucket_cycles(bucket.len());
        items.extend_from_slice(bucket);
    }
}

/// Verify ascending order by key (test helper, also used by prop tests).
pub fn is_sorted(items: &[SortItem]) -> bool {
    items.windows(2).all(|w| w[0].0 <= w[1].0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_items(seed: u64, n: usize, skew: bool) -> Vec<SortItem> {
        let mut rng = Rng::new(seed);
        (0..n as u32)
            .map(|i| {
                let d = if skew {
                    rng.log_normal(1.0, 0.8)
                } else {
                    rng.range_f32(0.0, 100.0)
                };
                (d, i)
            })
            .collect()
    }

    #[test]
    fn conventional_sorts_correctly() {
        for skew in [false, true] {
            let mut items = random_items(1, 500, skew);
            let orig = items.clone();
            conventional_bucket_bitonic(&mut items, 8, &SortHwConfig::default());
            assert!(is_sorted(&items));
            assert_eq!(items.len(), orig.len());
            // Same multiset of ids.
            let mut a: Vec<u32> = items.iter().map(|x| x.1).collect();
            let mut b: Vec<u32> = orig.iter().map(|x| x.1).collect();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn empty_and_single_are_noops() {
        let hw = SortHwConfig::default();
        let mut empty: Vec<SortItem> = vec![];
        assert_eq!(conventional_bucket_bitonic(&mut empty, 8, &hw), SortStats::default());
        let mut one = vec![(3.0, 0)];
        conventional_bucket_bitonic(&mut one, 8, &hw);
        assert_eq!(one, vec![(3.0, 0)]);
    }

    #[test]
    fn sort_engine_dispatches_to_both_arms() {
        let hw = SortHwConfig::default();
        let items_src = random_items(3, 600, true);

        let mut conv_engine = SortEngine::new(false, 8, 4, hw);
        let mut a = items_src.clone();
        let sa = conv_engine.sort_block(0, &mut a, 8, &hw);
        let mut b = items_src.clone();
        let sb = conventional_bucket_bitonic(&mut b, 8, &hw);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        conv_engine.reset(); // no-op, must not panic

        let mut aii_engine = SortEngine::new(true, 8, 4, hw);
        let mut c = items_src.clone();
        let sc = aii_engine.sort_block(0, &mut c, 8, &hw);
        assert!(is_sorted(&c));
        assert_eq!(sc.minmax_scanned, 600, "phase 1 pays the scan");
        let mut d = items_src.clone();
        let sd = aii_engine.sort_block(0, &mut d, 8, &hw);
        assert_eq!(sd.minmax_scanned, 0, "posteriori boundaries skip it");
        aii_engine.reset();
        let mut e = items_src.clone();
        let se = aii_engine.sort_block(0, &mut e, 8, &hw);
        assert_eq!(se.minmax_scanned, 600, "reset forgets posteriori state");
    }

    #[test]
    fn skewed_data_costs_more_than_uniform() {
        // Uniform intervals on skewed data create a dominant bucket whose
        // superlinear bitonic cost exceeds the balanced case.
        let hw = SortHwConfig::default();
        let mut uni = random_items(2, 2000, false);
        let mut skw = random_items(2, 2000, true);
        let c_uni = conventional_bucket_bitonic(&mut uni, 16, &hw);
        let c_skw = conventional_bucket_bitonic(&mut skw, 16, &hw);
        assert!(
            c_skw.cycles > c_uni.cycles,
            "skewed {} vs uniform {}",
            c_skw.cycles,
            c_uni.cycles
        );
    }
}
