//! Bitonic sorting network (Batcher 1968) with a hardware cycle model.
//!
//! The on-chip sort engine is a fixed array of `comparators` compare-swap
//! units; a network over n (padded to a power of two) elements has
//! k(k+1)/2 stage-passes (k = log₂ n), each pass issuing n/2 compare-swaps
//! that the array executes in ⌈(n/2)/comparators⌉ cycles. The model counts
//! exactly the compare-swaps the real network executes, so the
//! O(n log² n) superlinearity that punishes unbalanced buckets is real.

use super::SortItem;

/// Comparator-array parameters.
#[derive(Debug, Clone, Copy)]
pub struct BitonicHw {
    pub comparators: usize,
}

/// Work performed by one network invocation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BitonicStats {
    pub cycles: u64,
    pub comparisons: u64,
    /// Stage-passes executed.
    pub passes: u64,
}

/// Sort `items` ascending by key with a bitonic network; returns the
/// hardware work. Non-power-of-two inputs are padded with +∞ sentinels
/// (removed before returning), exactly as the hardware pads its buffer.
pub fn bitonic_sort(items: &mut Vec<SortItem>, hw: &BitonicHw) -> BitonicStats {
    let n = items.len();
    let mut stats = BitonicStats::default();
    if n <= 1 {
        return stats;
    }
    let padded = n.next_power_of_two();
    items.resize(padded, (f32::INFINITY, u32::MAX));

    let mut k = 2usize;
    while k <= padded {
        let mut j = k / 2;
        while j > 0 {
            // One stage-pass: padded/2 compare-swap slots.
            let compares = (padded / 2) as u64;
            stats.passes += 1;
            stats.comparisons += compares;
            stats.cycles += compares.div_ceil(hw.comparators as u64);
            for i in 0..padded {
                let l = i ^ j;
                if l > i {
                    let ascending = (i & k) == 0;
                    let (a, b) = (items[i].0, items[l].0);
                    if (ascending && a > b) || (!ascending && a < b) {
                        items.swap(i, l);
                    }
                }
            }
            j /= 2;
        }
        k *= 2;
    }

    items.truncate(n);
    stats
}

/// Closed-form pass count for a bucket of `n` elements (used by analytic
/// latency projections without running the network).
pub fn network_passes(n: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    let k = n.next_power_of_two().trailing_zeros() as u64;
    k * (k + 1) / 2
}

/// Closed-form cycle count for `n` elements on `comparators` units.
pub fn network_cycles(n: usize, comparators: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    let padded = n.next_power_of_two() as u64;
    network_passes(n) * (padded / 2).div_ceil(comparators as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sorting::is_sorted;
    use crate::util::proptest::{check, ensure};
    use crate::util::Rng;

    const HW: BitonicHw = BitonicHw { comparators: 64 };

    #[test]
    fn sorts_random_inputs() {
        let mut rng = Rng::new(1);
        for n in [0, 1, 2, 3, 7, 8, 100, 255, 256, 1000] {
            let mut v: Vec<SortItem> = (0..n as u32).map(|i| (rng.f32() * 100.0, i)).collect();
            bitonic_sort(&mut v, &HW);
            assert!(is_sorted(&v), "n={n}");
            assert_eq!(v.len(), n);
        }
    }

    #[test]
    fn property_sorts_and_preserves_multiset() {
        check(100, 42, |rng| {
            let n = rng.range_usize(0, 300);
            let mut v: Vec<SortItem> =
                (0..n as u32).map(|i| (rng.log_normal(0.0, 1.0), i)).collect();
            let mut ids: Vec<u32> = v.iter().map(|x| x.1).collect();
            bitonic_sort(&mut v, &HW);
            ensure(is_sorted(&v), "sorted")?;
            let mut out: Vec<u32> = v.iter().map(|x| x.1).collect();
            ids.sort_unstable();
            out.sort_unstable();
            ensure(ids == out, "same ids")
        });
    }

    #[test]
    fn stats_match_closed_form() {
        let mut rng = Rng::new(3);
        for n in [2, 5, 64, 100, 512] {
            let mut v: Vec<SortItem> = (0..n as u32).map(|i| (rng.f32(), i)).collect();
            let s = bitonic_sort(&mut v, &HW);
            assert_eq!(s.passes, network_passes(n), "passes n={n}");
            assert_eq!(s.cycles, network_cycles(n, HW.comparators), "cycles n={n}");
        }
    }

    #[test]
    fn superlinear_in_bucket_size() {
        // One big bucket of 4096 costs more than 16 buckets of 256.
        let big = network_cycles(4096, 64);
        let small = 16 * network_cycles(256, 64);
        assert!(big > small, "big {big} vs 16×small {small}");
    }

    #[test]
    fn closed_form_edge_cases() {
        assert_eq!(network_passes(0), 0);
        assert_eq!(network_passes(1), 0);
        assert_eq!(network_passes(2), 1);
        assert_eq!(network_passes(4), 3);
        assert_eq!(network_cycles(1, 64), 0);
    }
}
