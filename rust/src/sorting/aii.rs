//! Adaptive-Interval-Initialization Bucket-Bitonic Sort with posteriori
//! knowledge (AII-Sort, paper §3.2).
//!
//! **Phase 1** (frame 0): min/max scan → uniform intervals (same as the
//! conventional sorter).
//!
//! **Phase 2** (frames 1..N): the bucket boundaries are initialized from the
//! *previous frame's sorted output* (equal-count quantiles), exploiting
//! frame-to-frame depth coherence. This (a) skips the min/max scan entirely
//! and (b) yields near-uniform occupancy, so the bitonic stage runs on many
//! small buckets instead of one dominant one — amortized O(N).
//!
//! Boundaries are tracked **per tile block** (implementation consideration I:
//! "group adjacent tiles into Tile Blocks and store the average bucket
//! interval value for each tile group").

use super::bucket::{quantile_boundaries, uniform_boundaries};
use super::{sort_with_boundaries_into, SortHwConfig, SortItem, SortStats};

/// The AII-Sort engine; owns per-block posteriori boundaries.
#[derive(Debug)]
pub struct AiiSort {
    pub n_buckets: usize,
    pub hw: SortHwConfig,
    /// Per-tile-block boundaries carried from the previous frame.
    boundaries: Vec<Option<Vec<f32>>>,
}

impl AiiSort {
    /// `n_blocks` = number of tile blocks tracked (boundaries are averaged
    /// at block granularity).
    pub fn new(n_buckets: usize, n_blocks: usize, hw: SortHwConfig) -> AiiSort {
        AiiSort {
            n_buckets: n_buckets.max(1),
            hw,
            boundaries: vec![None; n_blocks.max(1)],
        }
    }

    /// Drop all posteriori state (scene cut).
    pub fn reset(&mut self) {
        for b in &mut self.boundaries {
            *b = None;
        }
    }

    /// Tile blocks tracked by this engine.
    pub fn n_blocks(&self) -> usize {
        self.boundaries.len()
    }

    /// Blocks currently holding carried boundaries (warm blocks).
    pub fn warm_blocks(&self) -> usize {
        self.boundaries.iter().filter(|b| b.is_some()).count()
    }

    /// Extract the per-block posteriori intervals, leaving the engine cold
    /// — the retained-state handoff a departing viewer session uses so a
    /// later session can [`AiiSort::warm_start`] from them.
    pub fn take_intervals(&mut self) -> Vec<Option<Vec<f32>>> {
        let n = self.boundaries.len();
        std::mem::replace(&mut self.boundaries, vec![None; n])
    }

    /// Seed the per-block boundaries from previously retained intervals
    /// (`take_intervals` of a compatible engine). Warm blocks skip the
    /// phase-1 min/max scan on their first sort, exactly as if the engine
    /// had sorted the previous frame itself.
    ///
    /// # Panics
    ///
    /// Panics if `intervals` does not cover the same block count.
    pub fn warm_start(&mut self, intervals: Vec<Option<Vec<f32>>>) {
        assert_eq!(
            intervals.len(),
            self.boundaries.len(),
            "warm-start intervals must match the engine's block count"
        );
        self.boundaries = intervals;
    }

    /// Does block `block` have carried boundaries?
    pub fn has_posteriori(&self, block: usize) -> bool {
        self.boundaries
            .get(block)
            .map(|b| b.is_some())
            .unwrap_or(false)
    }

    /// Sort one tile's items (ascending depth), updating the block's
    /// boundaries from the sorted result for the next frame.
    pub fn sort_tile(&mut self, block: usize, items: &mut Vec<SortItem>) -> SortStats {
        let block = block.min(self.boundaries.len() - 1);
        let n_buckets = self.n_buckets;
        let hw = self.hw;
        let mut scratch: Vec<Vec<SortItem>> = Vec::new();
        AiiSort::sort_block_slot(n_buckets, &hw, &mut self.boundaries[block], items, &mut scratch)
    }

    /// The per-block posteriori slots, one per tile block — the parallel
    /// executor hands each worker disjoint slots so blocks sort
    /// concurrently without sharing `&mut self`.
    pub fn boundaries_mut(&mut self) -> &mut [Option<Vec<f32>>] {
        &mut self.boundaries
    }

    /// Sort one block's working set against a single posteriori slot
    /// (phase 1 min/max scan when the slot is empty, phase 2 reuse
    /// otherwise), updating the slot from the sorted result. `scratch` is
    /// the caller-owned bucket-routing scratch (per executor worker).
    pub fn sort_block_slot(
        n_buckets: usize,
        hw: &SortHwConfig,
        slot: &mut Option<Vec<f32>>,
        items: &mut Vec<SortItem>,
        scratch: &mut Vec<Vec<SortItem>>,
    ) -> SortStats {
        let mut stats = SortStats::default();
        let n = items.len();
        if n <= 1 {
            return stats;
        }

        match slot.as_deref() {
            Some(boundaries) => {
                sort_with_boundaries_into(items, boundaries, hw, &mut stats, scratch);
            }
            None => {
                // Phase 1: pay the min/max scan once.
                let mut lo = f32::INFINITY;
                let mut hi = f32::NEG_INFINITY;
                for &(d, _) in items.iter() {
                    lo = lo.min(d);
                    hi = hi.max(d);
                }
                stats.minmax_scanned += n as u64;
                stats.cycles += (n as u64).div_ceil(hw.scan_lanes as u64);
                let boundaries = uniform_boundaries(lo, hi, n_buckets);
                sort_with_boundaries_into(items, &boundaries, hw, &mut stats, scratch);
            }
        }

        // Posteriori update: equal-count quantiles of this frame's sorted
        // result become next frame's intervals.
        *slot = Some(quantile_boundaries(items, n_buckets));
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sorting::{conventional_bucket_bitonic, is_sorted};
    use crate::util::proptest::{check, ensure};
    use crate::util::Rng;

    /// Skewed depth samples with slight frame-to-frame drift (the coherence
    /// AII exploits).
    fn frame_items(rng: &mut Rng, n: usize, drift: f32) -> Vec<SortItem> {
        (0..n as u32)
            .map(|i| (rng.log_normal(1.0, 0.8) + drift, i))
            .collect()
    }

    #[test]
    fn sorts_correctly_all_frames() {
        let mut aii = AiiSort::new(8, 4, SortHwConfig::default());
        let mut rng = Rng::new(1);
        for f in 0..5 {
            let mut items = frame_items(&mut rng, 800, f as f32 * 0.05);
            aii.sort_tile(0, &mut items);
            assert!(is_sorted(&items), "frame {f}");
            assert_eq!(items.len(), 800);
        }
    }

    #[test]
    fn frame0_scans_minmax_later_frames_do_not() {
        let mut aii = AiiSort::new(8, 4, SortHwConfig::default());
        let mut rng = Rng::new(2);
        let mut items = frame_items(&mut rng, 500, 0.0);
        let s0 = aii.sort_tile(0, &mut items);
        assert_eq!(s0.minmax_scanned, 500);
        let mut items = frame_items(&mut rng, 500, 0.02);
        let s1 = aii.sort_tile(0, &mut items);
        assert_eq!(s1.minmax_scanned, 0, "posteriori boundaries skip the scan");
    }

    #[test]
    fn blocks_track_independent_boundaries() {
        let mut aii = AiiSort::new(8, 2, SortHwConfig::default());
        let mut rng = Rng::new(3);
        let mut items = frame_items(&mut rng, 300, 0.0);
        aii.sort_tile(0, &mut items);
        assert!(aii.has_posteriori(0));
        assert!(!aii.has_posteriori(1));
    }

    #[test]
    fn steady_state_beats_conventional_on_skewed_data() {
        let hw = SortHwConfig::default();
        let mut aii = AiiSort::new(16, 1, hw);
        let mut rng = Rng::new(4);

        // Warm up posteriori state.
        let mut items = frame_items(&mut rng, 3000, 0.0);
        aii.sort_tile(0, &mut items);

        // Steady state vs conventional on statistically identical frames.
        let mut aii_cycles = 0u64;
        let mut conv_cycles = 0u64;
        for f in 1..6 {
            let drift = f as f32 * 0.02;
            let mut a = frame_items(&mut rng, 3000, drift);
            let mut c = a.clone();
            aii_cycles += aii.sort_tile(0, &mut a).cycles;
            conv_cycles += conventional_bucket_bitonic(&mut c, 16, &hw).cycles;
            assert_eq!(a, c, "both sorters must agree on the result");
        }
        assert!(
            (conv_cycles as f64) > 1.5 * aii_cycles as f64,
            "conventional {conv_cycles} vs AII {aii_cycles}"
        );
    }

    #[test]
    fn reset_forgets_posteriori() {
        let mut aii = AiiSort::new(8, 1, SortHwConfig::default());
        let mut rng = Rng::new(5);
        let mut items = frame_items(&mut rng, 200, 0.0);
        aii.sort_tile(0, &mut items);
        assert!(aii.has_posteriori(0));
        aii.reset();
        assert!(!aii.has_posteriori(0));
        let mut items = frame_items(&mut rng, 200, 0.0);
        let s = aii.sort_tile(0, &mut items);
        assert_eq!(s.minmax_scanned, 200);
    }

    #[test]
    fn warm_start_from_retained_intervals_skips_minmax_scan() {
        let hw = SortHwConfig::default();
        let mut donor = AiiSort::new(8, 3, hw);
        let mut rng = Rng::new(6);
        let mut items = frame_items(&mut rng, 400, 0.0);
        donor.sort_tile(1, &mut items);
        assert_eq!(donor.warm_blocks(), 1);

        // Handoff: donor's intervals seed a fresh engine; the donor cools.
        let intervals = donor.take_intervals();
        assert_eq!(donor.warm_blocks(), 0);
        assert_eq!(intervals.len(), 3);
        let mut fresh = AiiSort::new(8, 3, hw);
        fresh.warm_start(intervals);
        assert_eq!(fresh.n_blocks(), 3);
        assert_eq!(fresh.warm_blocks(), 1);

        // The warmed block sorts without the phase-1 scan; cold blocks pay.
        let mut items = frame_items(&mut rng, 400, 0.02);
        let warm = fresh.sort_tile(1, &mut items);
        assert_eq!(warm.minmax_scanned, 0, "retained intervals skip the scan");
        assert!(is_sorted(&items));
        let mut items = frame_items(&mut rng, 400, 0.02);
        let cold = fresh.sort_tile(0, &mut items);
        assert_eq!(cold.minmax_scanned, 400);
    }

    #[test]
    fn property_always_sorted_and_permutation() {
        check(60, 11, |rng| {
            let mut aii = AiiSort::new(1 + rng.below(16), 1 + rng.below(4), SortHwConfig::default());
            for _ in 0..3 {
                let n = rng.range_usize(0, 400);
                let mut items: Vec<SortItem> =
                    (0..n as u32).map(|i| (rng.log_normal(0.0, 1.2), i)).collect();
                let block = rng.below(4);
                let mut ids: Vec<u32> = items.iter().map(|x| x.1).collect();
                aii.sort_tile(block, &mut items);
                ensure(is_sorted(&items), "sorted")?;
                let mut out: Vec<u32> = items.iter().map(|x| x.1).collect();
                ids.sort_unstable();
                out.sort_unstable();
                ensure(ids == out, "permutation")?;
            }
            Ok(())
        });
    }
}
