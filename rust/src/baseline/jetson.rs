//! NVIDIA Jetson AGX Orin roofline model (Table I's GPU comparison row).
//!
//! The paper benchmarks the CUDA Gaussian-splatting kernel on an Orin (8 nm,
//! 15 W mode) and reports 31 FPS / 15 W on the dynamic scenes. We model the
//! published spec — peak FP16 throughput and LPDDR5 bandwidth at the 15 W
//! power budget — and evaluate the same workload's arithmetic/byte demands
//! against it (roofline), which is where the ~30 FPS class number comes
//! from.

use crate::energy::StageLatency;

/// Published Orin (15 W mode) characteristics.
pub mod published {
    /// Effective sustained FP16 TFLOPs at 15 W (GPU clocks capped).
    pub const FP16_TFLOPS: f64 = 5.3;
    /// Sustained DRAM bandwidth (GB/s) at the capped EMC clock.
    pub const DRAM_GBPS: f64 = 102.0;
    /// Module power (W).
    pub const POWER_W: f64 = 15.0;
    /// Reference point from the paper's Table I.
    pub const FPS_DYNAMIC: f64 = 31.0;
    pub const PSNR_DYNAMIC: f64 = 31.64;
    /// Host-side per-frame overhead (kernel launches, sorting on GPU via
    /// radix sort, Python/torch dispatch) observed in nerfstudio-class
    /// stacks (ms).
    pub const FRAME_OVERHEAD_MS: f64 = 12.0;
}

/// Roofline evaluation of one frame's demands.
#[derive(Debug, Clone, Copy)]
pub struct JetsonFrame {
    pub compute_ms: f64,
    pub memory_ms: f64,
    pub overhead_ms: f64,
    pub frame_ms: f64,
    pub fps: f64,
}

/// The model.
pub struct JetsonModel;

impl JetsonModel {
    /// Evaluate a frame that needs `flops` FP16 operations and moves
    /// `bytes` through DRAM.
    pub fn evaluate(flops: f64, bytes: f64) -> JetsonFrame {
        let compute_ms = flops / (published::FP16_TFLOPS * 1e12) * 1e3;
        let memory_ms = bytes / (published::DRAM_GBPS * 1e9) * 1e3;
        let overhead_ms = published::FRAME_OVERHEAD_MS;
        // GPU overlaps compute and memory; overhead serializes.
        let frame_ms = compute_ms.max(memory_ms) + overhead_ms;
        JetsonFrame {
            compute_ms,
            memory_ms,
            overhead_ms,
            frame_ms,
            fps: 1000.0 / frame_ms,
        }
    }

    /// Frame demands from pipeline statistics: `macs` (→ 2 flops each) and
    /// DRAM bytes, plus a GPU inefficiency factor for divergent
    /// rasterization (empirically ~3× over the ideal MAC count).
    pub fn from_workload(macs: u64, dram_bytes: u64) -> JetsonFrame {
        Self::evaluate(macs as f64 * 2.0 * 3.0, dram_bytes as f64 * 2.0)
    }

    /// As a [`StageLatency`] for report plumbing.
    pub fn as_latency(frame: &JetsonFrame) -> StageLatency {
        StageLatency {
            preprocess_ns: frame.overhead_ms * 1e6,
            sort_ns: 0.0,
            blend_ns: frame.compute_ms.max(frame.memory_ms) * 1e6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_bounds_fps() {
        // Even a zero-work frame can't beat the dispatch overhead.
        let f = JetsonModel::evaluate(0.0, 0.0);
        assert!(f.fps <= 1000.0 / published::FRAME_OVERHEAD_MS + 1e-9);
    }

    #[test]
    fn dynamic_scene_class_lands_near_published_fps() {
        // A paper-scale dynamic frame: ~0.6 M visible Gaussians × ~1.3 k
        // MACs effective each (incl. divergence) and ~350 MB traffic.
        let f = JetsonModel::from_workload(800_000_000, 350_000_000);
        assert!(
            (15.0..60.0).contains(&f.fps),
            "Orin model should land in the tens of FPS: {}",
            f.fps
        );
    }

    #[test]
    fn compute_and_memory_scale() {
        let light = JetsonModel::evaluate(1e9, 1e6);
        let heavy = JetsonModel::evaluate(1e12, 1e6);
        assert!(heavy.frame_ms > light.frame_ms);
        let membound = JetsonModel::evaluate(1e9, 1e12);
        assert!(membound.memory_ms > membound.compute_ms);
    }
}
