//! Comparison baselines for Table I: the GSCore accelerator (ASPLOS'24 [4])
//! and the NVIDIA Jetson AGX Orin edge GPU [23].

pub mod gscore;
pub mod jetson;

pub use gscore::GscoreModel;
pub use jetson::JetsonModel;
