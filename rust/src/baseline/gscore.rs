//! GSCore-class accelerator model (Lee et al., ASPLOS 2024 [4]).
//!
//! A structural model of GSCore's pipeline run on **our** scenes: per-frame
//! full-parameter DRAM fetch (no coarse culling), shape-aware intersection
//! (modeled as the same intersection count), hierarchical/bitonic sorting
//! with **uniform** bucket initialization every frame (no posteriori reuse),
//! raster-scan tile order (no ATG), and a conventional 28 nm digital MAC
//! datapath instead of DCIM. Published reference points (Table I: 91.2 FPS /
//! 0.87 W / 3.95 mm² @ 28 nm on Tanks & Temples) are reproduced as constants
//! for the comparison row; the structural model supplies the *scaling* on
//! our synthetic scenes.

use crate::camera::Camera;
use crate::culling::conventional::ConventionalCulling;
use crate::energy::{ops, FrameEnergy, StageLatency};
use crate::memory::dram::DramModel;
use crate::pipeline::frame::{DIGITAL_FREQ_GHZ, EARLY_TERMINATION_FACTOR, PREPROCESS_MACS_PER_GAUSSIAN};
use crate::scene::{DramLayout, Scene};
use crate::sorting::{conventional_bucket_bitonic, SortHwConfig, SortStats};
use crate::tiles::intersect::{bin_splats, project_gaussian, Splat2D, TileGrid};

/// Published GSCore Table-I reference numbers (28 nm, Tanks & Temples).
pub mod published {
    pub const AREA_MM2: f64 = 3.95;
    pub const POWER_W: f64 = 0.87;
    pub const FPS_STATIC_LARGE: f64 = 91.2;
    pub const PSNR_STATIC: f64 = 24.26;
    pub const SRAM_KB: usize = 272;
}

/// Energy of a conventional 28 nm digital FP16 MAC (pJ) — vs 0.033 pJ DCIM.
pub const E_MAC_28NM_PJ: f64 = 0.9;

/// Frame statistics from the GSCore structural model.
#[derive(Debug, Clone)]
pub struct GscoreFrame {
    pub energy: FrameEnergy,
    pub latency: StageLatency,
    pub sort: SortStats,
    pub n_visible: usize,
    pub dram_bytes: u64,
}

/// The model.
pub struct GscoreModel<'a> {
    pub scene: &'a Scene,
    pub layout: &'a DramLayout,
    pub width: usize,
    pub height: usize,
    /// GSCore's digital MAC throughput (MACs/cycle) — 256-lane class.
    pub macs_per_cycle: f64,
}

impl<'a> GscoreModel<'a> {
    pub fn new(scene: &'a Scene, layout: &'a DramLayout, width: usize, height: usize) -> Self {
        GscoreModel { scene, layout, width, height, macs_per_cycle: 256.0 }
    }

    /// Run one frame of the GSCore-style pipeline.
    pub fn render_frame(&self, cam: &Camera, t: f32) -> GscoreFrame {
        let mut energy = FrameEnergy::default();
        let mut latency = StageLatency::default();

        // Preprocess: fetch everything (no coarse culling).
        let mut dram = DramModel::default_lpddr5();
        let cull = ConventionalCulling::new(self.scene, self.layout).cull(cam, t, &mut dram);
        energy.cull_pj += cull.fetched as f64 * ops::E_FRUSTUM_PJ;
        energy.dram_pj += dram.stats().energy_pj;
        let pre_dram_ns = dram.stats().busy_ns;

        let splats: Vec<Splat2D> = cull
            .visible
            .iter()
            .filter_map(|&gi| {
                project_gaussian(&self.scene.gaussians[gi as usize], gi, cam, t)
            })
            .collect();
        let proj_macs = cull.visible.len() as u64 * PREPROCESS_MACS_PER_GAUSSIAN;
        energy.intersect_pj += proj_macs as f64 * E_MAC_28NM_PJ;
        let proj_ns = proj_macs as f64 / self.macs_per_cycle / DIGITAL_FREQ_GHZ;
        latency.preprocess_ns = pre_dram_ns.max(proj_ns + cull.fetched as f64 / DIGITAL_FREQ_GHZ);

        // Sort: conventional bucket-bitonic (uniform intervals each frame).
        let grid = TileGrid::new(self.width, self.height);
        let bins = bin_splats(&grid, &splats);
        let mut sort = SortStats::default();
        let hw = SortHwConfig::default();
        for bin in &bins {
            let mut items: Vec<(f32, u32)> = bin
                .iter()
                .map(|&si| (splats[si as usize].depth, si))
                .collect();
            sort.add(&conventional_bucket_bitonic(&mut items, 8, &hw));
        }
        energy.sort_pj += sort.comparisons as f64 * ops::E_CMP_FP16_PJ
            + sort.bucketed as f64 * ops::E_ROUTE_PJ;
        latency.sort_ns = sort.cycles as f64 / DIGITAL_FREQ_GHZ;

        // Blend: raster order, no depth-segmented reuse buffer — model
        // per-tile refetch of its splats (GSCore streams per-tile lists).
        let mut blend_dram = DramModel::default_lpddr5();
        let mut pairs_upper = 0u64;
        for (tile, bin) in bins.iter().enumerate() {
            let (x0, y0, x1, y1) = grid.tile_pixels(tile);
            pairs_upper += ((x1 - x0) * (y1 - y0)) as u64 * bin.len() as u64;
            for &si in bin {
                let gi = splats[si as usize].id as usize;
                blend_dram.read(self.layout.addr[gi], self.layout.bytes_per_gaussian);
            }
        }
        energy.dram_pj += blend_dram.stats().energy_pj;
        let pairs = (pairs_upper as f64 * EARLY_TERMINATION_FACTOR) as u64;
        // Digital blend: ~13 MACs + exp (≈ 8 digital ops) per pair.
        let blend_macs = pairs * 21;
        energy.dcim_pj += blend_macs as f64 * E_MAC_28NM_PJ; // (digital MACs)
        let blend_ns = blend_macs as f64 / self.macs_per_cycle / DIGITAL_FREQ_GHZ;
        latency.blend_ns = blend_ns.max(blend_dram.stats().busy_ns);

        GscoreFrame {
            energy,
            latency,
            sort,
            n_visible: splats.len(),
            dram_bytes: dram.stats().bytes + blend_dram.stats().bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::culling::grid::{GridConfig, GridPartition};
    use crate::math::Vec3;
    use crate::scene::synth::{SceneKind, SynthParams};

    #[test]
    fn gscore_frame_produces_stats() {
        let scene = SynthParams::new(SceneKind::StaticLarge, 3000).generate();
        let grid = GridPartition::build(&scene, GridConfig::static_scene(4));
        let layout = DramLayout::build(&scene, &grid);
        let model = GscoreModel::new(&scene, &layout, 320, 180);
        let cam = Camera::look_at(
            Vec3::new(0.0, 4.0, 22.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            16.0 / 9.0,
            0.1,
            200.0,
        );
        let f = model.render_frame(&cam, 0.0);
        assert!(f.n_visible > 0);
        assert!(f.energy.total_pj() > 0.0);
        assert!(f.latency.pipelined_ns() > 0.0);
        assert!(f.dram_bytes >= scene.dram_bytes() / 2);
    }
}
