//! # gaucim — 3DGauCIM reproduction
//!
//! Algorithm/hardware co-design framework reproducing *3DGauCIM: Accelerating
//! Static/Dynamic 3D Gaussian Splatting via Digital CIM for High Frame Rate
//! Real-Time Edge Rendering* (cs.AR 2025).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas tile-blending / exp2-LUT kernels (build-time Python,
//!   `python/compile/kernels/`), lowered into
//! * **L2** — the JAX preprocessing + blending graphs
//!   (`python/compile/model.py`), AOT-compiled once to HLO text in
//!   `artifacts/`, and executed from Rust via the PJRT CPU client
//!   ([`runtime`], behind the off-by-default `xla` feature).
//! * **L3** — this crate: the paper's four contributions (DR-FC culling,
//!   ATG tile grouping, AII-Sort, DD3D-Flow DCIM mapping) plus every
//!   substrate they need (synthetic 4DGS scenes, LPDDR5 DRAM model, SRAM
//!   buffer model, DCIM macro model, reference renderer, energy/FPS
//!   roll-up).
//!
//! The per-frame engine is an explicit **stage graph**
//! ([`pipeline::FramePipeline`]): `CullStage → ProjectStage →
//! IntersectStage → GroupStage → SortStage → BlendStage`, every stage
//! reading/writing a pooled [`pipeline::FrameCtx`] so steady-state frames
//! allocate no scratch vectors. Every frame runs a **numeric path** (real
//! pixels, bit-faithful DD3D-Flow exp) and a **performance path** (event
//! counts into the hardware models → cycles/energy → FPS/W), mirroring the
//! paper's methodology (functional RTL + measured DCIM-macro statistics +
//! Ramulator).
//!
//! The memory layer has two timing backends behind one statistics
//! contract: the frozen synchronous oracle
//! ([`memory::oracle::SyncDramModel`]) and the event-queue
//! [`memory::MemorySystem`] (per-channel queues, outstanding-transaction
//! windows, shard channel groups, contention) reached through
//! [`memory::MemPort`] handles threaded through the frame context — see
//! `rust/src/memory/README.md`.
//!
//! Host parallelism is handled by the **deterministic intra-frame
//! executor** ([`pipeline::par`]): a persistent scoped worker pool fans the
//! sort stage out per tile block and the blend walk out per depth segment
//! (plus the numeric render per tile), with every simulated stat
//! bit-identical to the serial path at any thread count
//! (`PipelineConfig::threads`, `PALLAS_THREADS`) — see
//! `rust/src/pipeline/README.md`.
//!
//! Above the frame engine, [`coordinator::RenderServer`] shares one
//! immutable scene preparation (grid partition, DRAM layout, FP16-quantized
//! copy, shard map) across N concurrent per-viewer sessions and renders
//! whole viewer batches in parallel (private memory systems) or against one
//! shared, contended memory system whose deterministic lockstep request
//! schedule is preserved by two-phase trace replay while rounds render in
//! parallel — the serving-at-scale entry points.
//!
//! Entry points: [`coordinator::App`] drives single-viewer renders;
//! [`coordinator::RenderServer`] drives multi-viewer batches;
//! [`pipeline::FramePipeline`] is the per-frame engine; `examples/` and
//! `rust/benches/` regenerate every paper table and figure.

pub mod baseline;
pub mod bench;
pub mod camera;
pub mod coordinator;
pub mod culling;
pub mod dcim;
pub mod energy;
pub mod math;
pub mod memory;
pub mod obs;
pub mod pipeline;
pub mod render;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod scene;
pub mod sorting;
pub mod tiles;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
