//! Energy / power / area accounting (the Table I roll-up).
//!
//! Per-op energies for the 16 nm digital blocks come from published 16 nm
//! op-energy surveys (the paper verifies its digital modules with 16 nm
//! SPICE; we encode the same class of constants — DESIGN.md §2). DRAM and
//! SRAM energies are charged inside their models; DCIM inside the macro
//! model; this module owns the *digital* ops (sorting comparators,
//! Union-Find, intersection tests, control) and the final roll-up.

pub mod report;

pub use report::{PowerReport, PreprocessBreakdown, StageLatency};

/// 16 nm digital per-op energies (pJ).
pub mod ops {
    /// FP16 comparator (sorting network compare-swap).
    pub const E_CMP_FP16_PJ: f64 = 0.05;
    /// Union-Find operation (find/union incl. its SRAM pointer traffic).
    pub const E_UNIONFIND_PJ: f64 = 2.0;
    /// Gaussian-tile intersection test (bbox + conic extent, few FP16 ops).
    pub const E_INTERSECT_PJ: f64 = 0.8;
    /// Per-Gaussian frustum test (sphere vs 6 planes).
    pub const E_FRUSTUM_PJ: f64 = 1.2;
    /// Per-cell coarse grid test (AABB vs 6 planes, runs on metadata only).
    pub const E_GRID_TEST_PJ: f64 = 1.5;
    /// Bucket routing decision per element.
    pub const E_ROUTE_PJ: f64 = 0.08;
    /// Generic FP16 MAC in plain digital logic (≈ 12× the DCIM MAC —
    /// the gap that motivates DD3D-Flow).
    pub const E_MAC_FP16_DIGITAL_PJ: f64 = 0.4;
}

/// Static (leakage + clock + controller) power of the accelerator (W).
pub const IDLE_POWER_W: f64 = 0.045;

/// Area constants (mm², 16 nm).
pub mod area {
    /// 256 KB SRAM buffer.
    pub const SRAM_256KB_MM2: f64 = 1.15;
    /// Digital logic (sorter, culling controller, ATG, NoC) — dynamic config.
    pub const LOGIC_DYNAMIC_MM2: f64 = 1.05;
    /// Digital logic — static config (smaller sorter/no temporal path).
    pub const LOGIC_STATIC_MM2: f64 = 0.55;
}

/// Energy accumulated over one frame, by component (pJ).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FrameEnergy {
    pub dram_pj: f64,
    pub sram_pj: f64,
    pub dcim_pj: f64,
    pub nmc_pj: f64,
    pub sort_pj: f64,
    pub atg_pj: f64,
    pub cull_pj: f64,
    pub intersect_pj: f64,
}

impl FrameEnergy {
    pub fn total_pj(&self) -> f64 {
        self.dram_pj
            + self.sram_pj
            + self.dcim_pj
            + self.nmc_pj
            + self.sort_pj
            + self.atg_pj
            + self.cull_pj
            + self.intersect_pj
    }

    pub fn total_mj(&self) -> f64 {
        self.total_pj() * 1e-9
    }

    pub fn add(&mut self, o: &FrameEnergy) {
        self.dram_pj += o.dram_pj;
        self.sram_pj += o.sram_pj;
        self.dcim_pj += o.dcim_pj;
        self.nmc_pj += o.nmc_pj;
        self.sort_pj += o.sort_pj;
        self.atg_pj += o.atg_pj;
        self.cull_pj += o.cull_pj;
        self.intersect_pj += o.intersect_pj;
    }

    pub fn scale(&self, s: f64) -> FrameEnergy {
        FrameEnergy {
            dram_pj: self.dram_pj * s,
            sram_pj: self.sram_pj * s,
            dcim_pj: self.dcim_pj * s,
            nmc_pj: self.nmc_pj * s,
            sort_pj: self.sort_pj * s,
            atg_pj: self.atg_pj * s,
            cull_pj: self.cull_pj * s,
            intersect_pj: self.intersect_pj * s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_sums_components() {
        let e = FrameEnergy {
            dram_pj: 1.0,
            sram_pj: 2.0,
            dcim_pj: 3.0,
            nmc_pj: 4.0,
            sort_pj: 5.0,
            atg_pj: 6.0,
            cull_pj: 7.0,
            intersect_pj: 8.0,
        };
        assert_eq!(e.total_pj(), 36.0);
        assert!((e.total_mj() - 36e-9).abs() < 1e-20);
    }

    #[test]
    fn add_and_scale() {
        let mut a = FrameEnergy { dram_pj: 10.0, ..Default::default() };
        let b = FrameEnergy { dram_pj: 5.0, sort_pj: 3.0, ..Default::default() };
        a.add(&b);
        assert_eq!(a.dram_pj, 15.0);
        assert_eq!(a.sort_pj, 3.0);
        let h = a.scale(0.5);
        assert_eq!(h.dram_pj, 7.5);
    }

    #[test]
    fn dcim_mac_far_cheaper_than_digital() {
        assert!(ops::E_MAC_FP16_DIGITAL_PJ > 10.0 * 0.033);
    }
}
