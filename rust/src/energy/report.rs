//! FPS / power / area roll-up — produces the rows of Table I.

use super::{area, FrameEnergy, IDLE_POWER_W};
use crate::util::json::Json;

/// Per-stage latency of one frame (ns). The accelerator pipelines stages
/// tile-wise, so steady-state throughput is set by the slowest stage; the
/// first frame pays the sum.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageLatency {
    pub preprocess_ns: f64,
    pub sort_ns: f64,
    pub blend_ns: f64,
}

impl StageLatency {
    /// Steady-state frame time under tile-level pipelining.
    pub fn pipelined_ns(&self) -> f64 {
        self.preprocess_ns.max(self.sort_ns).max(self.blend_ns)
    }

    /// Un-pipelined (first-frame / single-buffer) frame time.
    pub fn sequential_ns(&self) -> f64 {
        self.preprocess_ns + self.sort_ns + self.blend_ns
    }

    pub fn add(&mut self, o: &StageLatency) {
        self.preprocess_ns += o.preprocess_ns;
        self.sort_ns += o.sort_ns;
        self.blend_ns += o.blend_ns;
    }

    pub fn scale(&self, s: f64) -> StageLatency {
        StageLatency {
            preprocess_ns: self.preprocess_ns * s,
            sort_ns: self.sort_ns * s,
            blend_ns: self.blend_ns * s,
        }
    }
}

/// Modeled sub-stage attribution of the preprocess superstage (ns), for
/// the six-granular stage spans the frame tracer emits
/// (`obs::trace`). `cull_ns`/`intersect_ns`/`group_ns` are digital-logic
/// op counts over `DIGITAL_FREQ_GHZ`; `project_ns` is the DCIM macro busy
/// time. These are attribution detail *inside*
/// [`StageLatency::preprocess_ns`] (which models DRAM fetch ∥ compute),
/// not an independent latency model — their sum can differ from
/// `preprocess_ns` and the tracer clamps nesting accordingly. All inputs
/// are simulated/modeled quantities, so the breakdown is bit-identical
/// across host thread counts.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PreprocessBreakdown {
    /// DR-FC grid tests + record fetch issue (compute side of culling).
    pub cull_ns: f64,
    /// Projection / covariance / SH compute on the DCIM tier.
    pub project_ns: f64,
    /// Splat–tile intersection tests.
    pub intersect_ns: f64,
    /// ATG regrouping (scan + union-find) ops.
    pub group_ns: f64,
}

/// A Table-I style report for one configuration + scene.
#[derive(Debug, Clone)]
pub struct PowerReport {
    pub label: String,
    pub fps: f64,
    pub power_w: f64,
    pub area_mm2: f64,
    pub energy_per_frame_mj: f64,
    pub latency: StageLatency,
    pub energy: FrameEnergy,
}

impl PowerReport {
    /// Build from averaged per-frame energy + latency.
    /// `dcim_area_mm2` comes from the DCIM config; static/dynamic selects
    /// the digital-logic area class.
    pub fn from_frame(
        label: impl Into<String>,
        energy: FrameEnergy,
        latency: StageLatency,
        dcim_area_mm2: f64,
        dynamic: bool,
    ) -> PowerReport {
        let frame_s = (latency.pipelined_ns() * 1e-9).max(1e-12);
        let fps = 1.0 / frame_s;
        let dynamic_power = energy.total_pj() * 1e-12 / frame_s;
        let logic = if dynamic { area::LOGIC_DYNAMIC_MM2 } else { area::LOGIC_STATIC_MM2 };
        PowerReport {
            label: label.into(),
            fps,
            power_w: dynamic_power + IDLE_POWER_W,
            area_mm2: dcim_area_mm2 + area::SRAM_256KB_MM2 + logic,
            energy_per_frame_mj: energy.total_mj(),
            latency,
            energy,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("label", self.label.as_str())
            .set("fps", self.fps)
            .set("power_w", self.power_w)
            .set("area_mm2", self.area_mm2)
            .set("energy_per_frame_mj", self.energy_per_frame_mj)
            .set("preprocess_ns", self.latency.preprocess_ns)
            .set("sort_ns", self.latency.sort_ns)
            .set("blend_ns", self.latency.blend_ns)
            .set("dram_pj", self.energy.dram_pj)
            .set("dcim_pj", self.energy.dcim_pj)
            .set("sram_pj", self.energy.sram_pj)
    }

    /// Formatted one-line summary (bench output).
    pub fn row(&self) -> String {
        format!(
            "{:<28} {:>7.1} FPS {:>7.3} W {:>6.2} mm² {:>8.4} mJ/frame",
            self.label, self.fps, self.power_w, self.area_mm2, self.energy_per_frame_mj
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipelined_is_bottleneck_stage() {
        let l = StageLatency { preprocess_ns: 1.0e6, sort_ns: 2.0e6, blend_ns: 4.0e6 };
        assert_eq!(l.pipelined_ns(), 4.0e6);
        assert_eq!(l.sequential_ns(), 7.0e6);
    }

    #[test]
    fn report_math() {
        let energy = FrameEnergy { dram_pj: 1.0e9, dcim_pj: 1.0e9, ..Default::default() };
        let latency = StageLatency { preprocess_ns: 1.0e6, sort_ns: 1.0e6, blend_ns: 4.0e6 };
        let r = PowerReport::from_frame("test", energy, latency, 1.9, true);
        // 4 ms frame → 250 FPS.
        assert!((r.fps - 250.0).abs() < 1e-6);
        // 2 mJ / 4 ms = 0.5 W dynamic + idle.
        assert!((r.power_w - (0.5 + IDLE_POWER_W)).abs() < 1e-9);
        assert!(r.area_mm2 > 3.0 && r.area_mm2 < 5.0);
        assert!(r.row().contains("FPS"));
    }

    #[test]
    fn static_logic_smaller_area() {
        let e = FrameEnergy::default();
        let l = StageLatency { preprocess_ns: 1.0, sort_ns: 1.0, blend_ns: 1.0 };
        let d = PowerReport::from_frame("d", e, l, 1.9, true);
        let s = PowerReport::from_frame("s", e, l, 0.65, false);
        assert!(s.area_mm2 < d.area_mm2);
    }
}
