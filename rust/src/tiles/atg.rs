//! Adaptive Tile Grouping with posteriori knowledge (ATG, paper §3.3).
//!
//! **Phase 1** (frame 0): threshold the connection graph (eq. 11) and group
//! connected tile blocks with Union-Find; the tile *processing order* visits
//! groups one after another so Gaussians shared inside a group stay resident
//! in the SRAM buffer.
//!
//! **Phase 2** (frames 1..N): diff the thresholded boundary states against
//! the previous frame; only blocks touched by a **deformation flag** are
//! re-grouped, the rest inherit the previous grouping. The work counter
//! (`regroup_ops`) feeds the energy model — the 5.2×/2.2× savings of
//! Fig. 10(b) come from flagged-region work ≪ full-graph work.

use super::connection::ConnectionGraph;
use super::unionfind::UnionFind;

/// ATG configuration (paper sweeps: threshold 0.3–0.7, Tile Blocks 1–8;
/// chosen operating point threshold 0.5, Tile Blocks 4, K from §3.3-II).
#[derive(Debug, Clone, Copy)]
pub struct AtgConfig {
    pub user_threshold: f32,
    pub tile_block: usize,
    /// K highest/lowest strengths for the eq. 11 bounds.
    pub k: usize,
    /// Cap on tiles per group so one group's working set fits the buffer.
    pub max_group_blocks: usize,
}

impl Default for AtgConfig {
    fn default() -> Self {
        AtgConfig {
            user_threshold: 0.5,
            tile_block: 4,
            k: 16,
            max_group_blocks: 64,
        }
    }
}

/// A grouping of tile blocks.
#[derive(Debug, Clone)]
pub struct TileGroups {
    /// Group label per block.
    pub label: Vec<u32>,
    /// Blocks per group.
    pub groups: Vec<Vec<u32>>,
    /// Boundary on/off states this grouping was derived from.
    pub edge_states: Vec<bool>,
    /// Threshold actually applied.
    pub threshold: f32,
}

/// Result of one ATG update.
#[derive(Debug, Clone)]
pub struct AtgOutcome {
    pub groups: TileGroups,
    /// Cheap boundary scans/diffs (comparator-class work).
    pub scan_ops: u64,
    /// Union-Find / regroup operations (SRAM-pointer-class work).
    pub uf_ops: u64,
    /// Deformation flags raised (0 for phase 1 / full regroup).
    pub flags: u64,
    /// True when phase 2 reused the previous grouping wholesale.
    pub reused_previous: bool,
}

impl AtgOutcome {
    /// Combined op count (back-compat aggregate used by reports).
    pub fn regroup_ops(&self) -> u64 {
        self.scan_ops + self.uf_ops
    }
}

/// The ATG engine; owns the posteriori state between frames.
#[derive(Debug)]
pub struct Atg {
    pub config: AtgConfig,
    previous: Option<TileGroups>,
}

impl Atg {
    pub fn new(config: AtgConfig) -> Atg {
        Atg { config, previous: None }
    }

    /// Drop posteriori state (new sequence / scene cut).
    pub fn reset(&mut self) {
        self.previous = None;
    }

    /// Update for the current frame's connection graph.
    pub fn update(&mut self, graph: &ConnectionGraph) -> AtgOutcome {
        let threshold = graph.threshold(self.config.user_threshold, self.config.k);
        let states = graph.edge_states(threshold);

        let outcome = match &self.previous {
            None => self.full_regroup(graph, threshold, states),
            Some(prev) if prev.edge_states.len() != states.len() => {
                self.full_regroup(graph, threshold, states)
            }
            Some(prev) => self.incremental_regroup(graph, prev, threshold, states),
        };
        self.previous = Some(outcome.groups.clone());
        outcome
    }

    /// Phase 1: full Union-Find over all thresholded boundaries.
    fn full_regroup(
        &self,
        graph: &ConnectionGraph,
        threshold: f32,
        states: Vec<bool>,
    ) -> AtgOutcome {
        let mut scan_ops = 0u64;
        let mut uf_ops = 0u64;
        let mut uf = UnionFind::new(graph.n_blocks());
        for (i, &on) in states.iter().enumerate() {
            scan_ops += 1; // boundary scan
            if on {
                let (a, b) = graph.edge_blocks(i);
                if self.can_merge(&mut uf, a, b) {
                    uf.union(a, b);
                }
                uf_ops += 2; // find + union class work
            }
        }
        let (label, groups) = uf.groups();
        uf_ops += graph.n_blocks() as u64; // label sweep
        AtgOutcome {
            groups: TileGroups { label, groups, edge_states: states, threshold },
            scan_ops,
            uf_ops,
            flags: 0,
            reused_previous: false,
        }
    }

    /// Phase 2: diff boundary states; rebuild only if flags were raised, and
    /// charge work proportional to the flagged neighborhood.
    fn incremental_regroup(
        &self,
        graph: &ConnectionGraph,
        prev: &TileGroups,
        threshold: f32,
        states: Vec<bool>,
    ) -> AtgOutcome {
        // Deformation flags: boundaries whose on/off state changed.
        let mut flagged_blocks = std::collections::BTreeSet::new();
        let mut flags = 0u64;
        let scan_ops = states.len() as u64; // the diff scan itself
        let mut uf_ops = 0u64;
        for (i, (&now, &before)) in states.iter().zip(&prev.edge_states).enumerate() {
            if now != before {
                flags += 1;
                let (a, b) = graph.edge_blocks(i);
                flagged_blocks.insert(a);
                flagged_blocks.insert(b);
            }
        }

        if flags == 0 {
            // Grouping carries over verbatim.
            return AtgOutcome {
                groups: TileGroups {
                    label: prev.label.clone(),
                    groups: prev.groups.clone(),
                    edge_states: states,
                    threshold,
                },
                scan_ops,
                uf_ops: 0,
                flags: 0,
                reused_previous: true,
            };
        }

        // Affected groups: every group containing a flagged block — those
        // are rebuilt; unaffected groups carry over. (Result is identical to
        // a full regroup — asserted by tests — but the charged work is
        // proportional to the flagged region, which is the paper's point.)
        let affected: std::collections::BTreeSet<u32> = flagged_blocks
            .iter()
            .map(|&b| prev.label[b])
            .collect();
        let affected_blocks: u64 = prev
            .groups
            .iter()
            .enumerate()
            .filter(|(gi, _)| affected.contains(&(*gi as u32)))
            .map(|(_, g)| g.len() as u64)
            .sum();
        uf_ops += flags * 2 + affected_blocks * 3;

        let mut uf = UnionFind::new(graph.n_blocks());
        for (i, &on) in states.iter().enumerate() {
            if on {
                let (a, b) = graph.edge_blocks(i);
                if self.can_merge(&mut uf, a, b) {
                    uf.union(a, b);
                }
            }
        }
        let (label, groups) = uf.groups();
        AtgOutcome {
            groups: TileGroups { label, groups, edge_states: states, threshold },
            scan_ops,
            uf_ops,
            flags,
            reused_previous: false,
        }
    }

    /// Buffer-capacity guard: don't grow groups beyond `max_group_blocks`.
    fn can_merge(&self, uf: &mut UnionFind, a: usize, b: usize) -> bool {
        uf.component_size(a) + uf.component_size(b) <= self.config.max_group_blocks
    }
}

impl TileGroups {
    /// Tile visit order: groups in sequence, each group's blocks in raster
    /// order, each block's tiles in raster order. `tiles_x/tiles_y` describe
    /// the tile grid; `block` is the Tile Block edge.
    pub fn tile_order(&self, tiles_x: usize, tiles_y: usize, block: usize) -> Vec<usize> {
        let mut order = Vec::with_capacity(tiles_x * tiles_y);
        let mut scratch = Vec::new();
        self.tile_order_into(tiles_x, tiles_y, block, &mut order, &mut scratch);
        order
    }

    /// Pooled variant of [`TileGroups::tile_order`]: fills `out` in place and
    /// uses `scratch` for the per-group block sort, reusing both capacities
    /// across frames (stage-graph `FrameCtx` scratch contract).
    pub fn tile_order_into(
        &self,
        tiles_x: usize,
        tiles_y: usize,
        block: usize,
        out: &mut Vec<usize>,
        scratch: &mut Vec<u32>,
    ) {
        let block = block.max(1);
        let bx = tiles_x.div_ceil(block).max(1);
        out.clear();
        for group in &self.groups {
            scratch.clear();
            scratch.extend_from_slice(group);
            scratch.sort_unstable();
            for &blk in scratch.iter() {
                let (bx_i, by_i) = ((blk as usize) % bx, (blk as usize) / bx);
                for ty in (by_i * block)..((by_i + 1) * block).min(tiles_y) {
                    for tx in (bx_i * block)..((bx_i + 1) * block).min(tiles_x) {
                        out.push(ty * tiles_x + tx);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn graph_with_footprints(seed: u64, n: usize) -> ConnectionGraph {
        let mut g = ConnectionGraph::new(20, 12, 1);
        let mut rng = Rng::new(seed);
        for _ in 0..n {
            let tx = rng.below(18);
            let ty = rng.below(10);
            let w = 1 + rng.below(3);
            let h = 1 + rng.below(3);
            g.record_footprint(tx, ty, (tx + w).min(19), (ty + h).min(11));
        }
        g
    }

    #[test]
    fn phase1_groups_cover_all_blocks() {
        let g = graph_with_footprints(1, 200);
        let mut atg = Atg::new(AtgConfig { tile_block: 1, ..Default::default() });
        let out = atg.update(&g);
        assert_eq!(out.groups.label.len(), g.n_blocks());
        let total: usize = out.groups.groups.iter().map(|grp| grp.len()).sum();
        assert_eq!(total, g.n_blocks());
        assert!(!out.reused_previous);
    }

    #[test]
    fn identical_frame_reuses_grouping_with_less_work() {
        let g = graph_with_footprints(2, 200);
        let mut atg = Atg::new(AtgConfig { tile_block: 1, ..Default::default() });
        let first = atg.update(&g);
        let second = atg.update(&g);
        assert!(second.reused_previous);
        assert_eq!(second.flags, 0);
        assert!(second.regroup_ops() < first.regroup_ops());
        assert_eq!(second.groups.label, first.groups.label);
    }

    #[test]
    fn small_change_raises_few_flags() {
        let g1 = graph_with_footprints(3, 300);
        let mut g2 = g1.clone();
        // A localized deformation: an actor-sized burst of new footprints.
        for _ in 0..25 {
            g2.record_footprint(5, 5, 9, 6);
        }
        let mut atg = Atg::new(AtgConfig { tile_block: 1, ..Default::default() });
        let first = atg.update(&g1);
        let second = atg.update(&g2);
        assert!(second.flags > 0, "a change must raise flags");
        // Note: eq. 11's threshold is global, so a strong local change can
        // also flip marginal boundaries elsewhere; still well under half.
        assert!(
            (second.flags as usize) < g1.n_edges() / 2,
            "local change should flag a minority of boundaries: {}",
            second.flags
        );
        // Incremental result must equal a from-scratch regroup of g2.
        let mut fresh = Atg::new(AtgConfig { tile_block: 1, ..Default::default() });
        let scratch = fresh.update(&g2);
        assert_eq!(groups_as_sets(&second.groups), groups_as_sets(&scratch.groups));
        let _ = first;
    }

    #[test]
    fn group_size_capped_by_buffer_guard() {
        let mut g = ConnectionGraph::new(30, 30, 1);
        // Strengthen everything: giant footprints.
        for _ in 0..50 {
            g.record_footprint(0, 0, 29, 29);
        }
        let cfg = AtgConfig { tile_block: 1, max_group_blocks: 16, ..Default::default() };
        let mut atg = Atg::new(cfg);
        let out = atg.update(&g);
        for grp in &out.groups.groups {
            assert!(grp.len() <= 16, "group of {} exceeds cap", grp.len());
        }
    }

    #[test]
    fn tile_order_is_permutation() {
        let g = graph_with_footprints(4, 150);
        let mut atg = Atg::new(AtgConfig { tile_block: 1, ..Default::default() });
        let out = atg.update(&g);
        let order = out.groups.tile_order(20, 12, 1);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..240).collect::<Vec<_>>());
    }

    #[test]
    fn tile_order_with_blocks_is_permutation() {
        let mut g = ConnectionGraph::new(19, 11, 4); // non-multiple dims
        g.record_footprint(0, 0, 8, 8);
        let mut atg = Atg::new(AtgConfig { tile_block: 4, ..Default::default() });
        let out = atg.update(&g);
        let order = out.groups.tile_order(19, 11, 4);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..19 * 11).collect::<Vec<_>>());
    }

    fn groups_as_sets(g: &TileGroups) -> std::collections::BTreeSet<Vec<u32>> {
        g.groups
            .iter()
            .map(|grp| {
                let mut v = grp.clone();
                v.sort_unstable();
                v
            })
            .collect()
    }
}
