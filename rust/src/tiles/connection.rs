//! Tile-block connection-strength graph (ATG phase 1, paper §3.3-A).
//!
//! During intersection testing, a Gaussian overlapping several tiles
//! **strengthens** the boundaries interior to its footprint and **weakens**
//! the boundaries it crosses out of — enhancing Gaussian-tile intersection
//! features. Tiles are aggregated into `block × block` **Tile Blocks**
//! (implementation consideration I) and the graph lives on block-level
//! horizontal/vertical boundaries.
//!
//! The grouping threshold follows eq. 11: per graph, take the K highest and
//! K lowest strengths, use their medians as `upper`/`lower`, and set
//! `threshold = (upper − lower) × user_th + lower`.

use crate::math::stats::median;

/// Strength added to interior boundaries per overlapping Gaussian.
const ENHANCE: f32 = 1.0;
/// Strength removed from crossed-out boundaries per overlapping Gaussian.
const SUPPRESS: f32 = 0.25;

/// Connection graph over tile blocks.
#[derive(Debug, Clone)]
pub struct ConnectionGraph {
    /// Blocks per row / column.
    pub bx: usize,
    pub by: usize,
    /// Tiles per block edge.
    pub block: usize,
    /// Horizontal boundaries: between (x,y) and (x+1,y); len (bx−1)·by.
    h: Vec<f32>,
    /// Vertical boundaries: between (x,y) and (x,y+1); len bx·(by−1).
    v: Vec<f32>,
}

impl ConnectionGraph {
    /// Build for a tile grid of `tiles_x × tiles_y` tiles with the given
    /// Tile Block edge (paper sweeps block ∈ {1, 2, 4, 8}).
    pub fn new(tiles_x: usize, tiles_y: usize, block: usize) -> ConnectionGraph {
        let block = block.max(1);
        let bx = tiles_x.div_ceil(block).max(1);
        let by = tiles_y.div_ceil(block).max(1);
        ConnectionGraph {
            bx,
            by,
            block,
            h: vec![0.0; bx.saturating_sub(1) * by],
            v: vec![0.0; bx * by.saturating_sub(1)],
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.bx * self.by
    }

    #[inline]
    pub fn block_of_tile(&self, tx: usize, ty: usize) -> usize {
        (ty / self.block).min(self.by - 1) * self.bx + (tx / self.block).min(self.bx - 1)
    }

    #[inline]
    fn h_idx(&self, x: usize, y: usize) -> usize {
        y * (self.bx - 1) + x
    }

    #[inline]
    fn v_idx(&self, x: usize, y: usize) -> usize {
        y * self.bx + x
    }

    /// Reset strengths (frame 0 of a fresh sequence).
    pub fn clear(&mut self) {
        self.h.iter_mut().for_each(|e| *e = 0.0);
        self.v.iter_mut().for_each(|e| *e = 0.0);
    }

    /// Record one Gaussian's footprint given its inclusive tile rect.
    /// Boundaries interior to the rect are enhanced; boundaries on the rect's
    /// border (crossing out of the footprint) are suppressed.
    pub fn record_footprint(&mut self, tx0: usize, ty0: usize, tx1: usize, ty1: usize) {
        // Convert to block coordinates (inclusive).
        let bx0 = (tx0 / self.block).min(self.bx - 1);
        let bx1 = (tx1 / self.block).min(self.bx - 1);
        let by0 = (ty0 / self.block).min(self.by - 1);
        let by1 = (ty1 / self.block).min(self.by - 1);

        // Interior horizontal boundaries.
        for y in by0..=by1 {
            for x in bx0..bx1 {
                let i = self.h_idx(x, y);
                self.h[i] += ENHANCE;
            }
        }
        // Interior vertical boundaries.
        for y in by0..by1 {
            for x in bx0..=bx1 {
                let i = self.v_idx(x, y);
                self.v[i] += ENHANCE;
            }
        }
        // Suppressed border boundaries: left/right edges of the rect.
        for y in by0..=by1 {
            if bx0 > 0 {
                let i = self.h_idx(bx0 - 1, y);
                self.h[i] -= SUPPRESS;
            }
            if bx1 + 1 < self.bx {
                let i = self.h_idx(bx1, y);
                self.h[i] -= SUPPRESS;
            }
        }
        // Top/bottom edges.
        for x in bx0..=bx1 {
            if by0 > 0 {
                let i = self.v_idx(x, by0 - 1);
                self.v[i] -= SUPPRESS;
            }
            if by1 + 1 < self.by {
                let i = self.v_idx(x, by1);
                self.v[i] -= SUPPRESS;
            }
        }
    }

    /// All boundary strengths (h then v).
    pub fn strengths(&self) -> Vec<f32> {
        let mut s = self.h.clone();
        s.extend_from_slice(&self.v);
        s
    }

    /// Eq. 11 threshold from the K highest / K lowest strengths.
    pub fn threshold(&self, user_th: f32, k: usize) -> f32 {
        let mut s = self.strengths();
        if s.is_empty() {
            return 0.0;
        }
        s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let k = k.max(1).min(s.len());
        let lower = median(&s[..k]);
        let upper = median(&s[s.len() - k..]);
        (upper - lower) * user_th + lower
    }

    /// Visit every boundary at-or-above `threshold` as a block pair `(a, b)`.
    pub fn edges_above(&self, threshold: f32, mut f: impl FnMut(usize, usize)) {
        for y in 0..self.by {
            for x in 0..self.bx.saturating_sub(1) {
                if self.h[self.h_idx(x, y)] >= threshold {
                    f(y * self.bx + x, y * self.bx + x + 1);
                }
            }
        }
        for y in 0..self.by.saturating_sub(1) {
            for x in 0..self.bx {
                if self.v[self.v_idx(x, y)] >= threshold {
                    f(y * self.bx + x, (y + 1) * self.bx + x);
                }
            }
        }
    }

    /// Boolean on/off state of every boundary under `threshold`
    /// (h boundaries then v) — the signal phase 2 diffs for deformation flags.
    pub fn edge_states(&self, threshold: f32) -> Vec<bool> {
        let mut out = Vec::with_capacity(self.h.len() + self.v.len());
        out.extend(self.h.iter().map(|&e| e >= threshold));
        out.extend(self.v.iter().map(|&e| e >= threshold));
        out
    }

    /// Blocks adjacent to boundary `edge_idx` (in `edge_states` numbering).
    pub fn edge_blocks(&self, edge_idx: usize) -> (usize, usize) {
        if edge_idx < self.h.len() {
            let y = edge_idx / (self.bx - 1).max(1);
            let x = edge_idx % (self.bx - 1).max(1);
            (y * self.bx + x, y * self.bx + x + 1)
        } else {
            let i = edge_idx - self.h.len();
            let y = i / self.bx;
            let x = i % self.bx;
            (y * self.bx + x, (y + 1) * self.bx + x)
        }
    }

    pub fn n_edges(&self) -> usize {
        self.h.len() + self.v.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_from_tiles() {
        let g = ConnectionGraph::new(80, 45, 4);
        assert_eq!(g.bx, 20);
        assert_eq!(g.by, 12);
        assert_eq!(g.n_blocks(), 240);
        assert_eq!(g.n_edges(), 19 * 12 + 20 * 11);
    }

    #[test]
    fn block_of_tile_maps_correctly() {
        let g = ConnectionGraph::new(8, 8, 4);
        assert_eq!(g.block_of_tile(0, 0), 0);
        assert_eq!(g.block_of_tile(3, 3), 0);
        assert_eq!(g.block_of_tile(4, 0), 1);
        assert_eq!(g.block_of_tile(0, 4), 2);
        assert_eq!(g.block_of_tile(7, 7), 3);
    }

    #[test]
    fn vertical_footprint_strengthens_vertical_boundary() {
        // Blocks are 1 tile (block=1); a footprint spanning tiles (2,1)-(2,3)
        // strengthens the two vertical boundaries inside it.
        let mut g = ConnectionGraph::new(6, 6, 1);
        g.record_footprint(2, 1, 2, 3);
        let th = 0.5;
        let mut edges = Vec::new();
        g.edges_above(th, |a, b| edges.push((a, b)));
        // Interior vertical boundaries: (2,1)-(2,2) and (2,2)-(2,3).
        assert!(edges.contains(&(1 * 6 + 2, 2 * 6 + 2)));
        assert!(edges.contains(&(2 * 6 + 2, 3 * 6 + 2)));
        assert_eq!(edges.len(), 2);
    }

    #[test]
    fn suppression_lowers_border_boundaries() {
        let mut g = ConnectionGraph::new(6, 6, 1);
        g.record_footprint(2, 2, 3, 3);
        // The boundary left of the rect was suppressed below zero.
        let strengths = g.strengths();
        assert!(strengths.iter().any(|&s| s < 0.0));
        assert!(strengths.iter().any(|&s| s >= 1.0));
    }

    #[test]
    fn threshold_between_extremes() {
        let mut g = ConnectionGraph::new(8, 8, 1);
        for _ in 0..10 {
            g.record_footprint(1, 1, 1, 4);
        }
        g.record_footprint(5, 5, 6, 5);
        let th_lo = g.threshold(0.0, 4);
        let th_mid = g.threshold(0.5, 4);
        let th_hi = g.threshold(1.0, 4);
        assert!(th_lo <= th_mid && th_mid <= th_hi);
        assert!(th_hi > 1.0, "upper median should reflect the strong boundary");
    }

    #[test]
    fn edge_states_and_blocks_roundtrip() {
        let mut g = ConnectionGraph::new(4, 4, 1);
        g.record_footprint(0, 0, 1, 0);
        let states = g.edge_states(0.5);
        assert_eq!(states.len(), g.n_edges());
        let on: Vec<usize> = states
            .iter()
            .enumerate()
            .filter(|(_, &s)| s)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(on.len(), 1);
        let (a, b) = g.edge_blocks(on[0]);
        assert_eq!((a, b), (0, 1));
    }

    #[test]
    fn tile_block_aggregation_merges_footprints() {
        // With block=4, a footprint inside one block touches no boundary.
        let mut g = ConnectionGraph::new(8, 8, 4);
        g.record_footprint(0, 0, 2, 2);
        assert!(g.strengths().iter().all(|&s| s <= 0.0));
        // Spanning two blocks strengthens the block boundary.
        g.record_footprint(2, 0, 5, 0);
        let mut found = false;
        g.edges_above(0.5, |a, b| {
            assert_eq!((a, b), (0, 1));
            found = true;
        });
        assert!(found);
    }
}
