//! Gaussian → screen-space splat projection (paper eqs. 7–8, 10) and
//! splat–tile intersection testing.
//!
//! This is the canonical projection used by both the L3 performance models
//! and the CPU reference renderer; the L2 JAX graph implements the same math
//! (checked against each other in `rust/tests/` and `python/tests/`).

use super::TILE_PX;
use crate::camera::Camera;
use crate::math::{Vec2, Vec3};
use crate::scene::Gaussian4D;

/// Minimum contribution before a splat is discarded (1/255 of opacity).
pub const ALPHA_CUTOFF: f32 = 1.0 / 255.0;

/// EWA low-pass dilation added to the 2-D covariance diagonal (3DGS uses
/// 0.3 px² so splats never fall between pixels).
pub const COV2D_DILATION: f32 = 0.3;

/// A projected 2-D Gaussian ready for sorting/blending.
#[derive(Debug, Clone, Copy)]
pub struct Splat2D {
    /// Original Gaussian index.
    pub id: u32,
    /// Pixel-space mean.
    pub mean: Vec2,
    /// Conic (inverse 2-D covariance): `[a, b, c]` of a·dx² + 2b·dx·dy + c·dy².
    pub conic: [f32; 3],
    /// Conservative pixel radius (3σ of the major axis).
    pub radius: f32,
    /// Axis-aligned 3σ extents of the screen-space ellipse (tight bbox —
    /// what the intersection-testing stage bins with; a thin vertical splat
    /// has rx ≪ ry, the paper's Challenge-2 shape).
    pub rx: f32,
    pub ry: f32,
    /// View depth (camera-space z).
    pub depth: f32,
    /// Base opacity × temporal weight — eq. 10's o·G(t) factor, merged
    /// offline so the blend evaluates one exponential per pixel (DD3D-Flow).
    pub alpha_base: f32,
    /// View-dependent RGB from SH.
    pub color: Vec3,
}

/// Project one 4-D Gaussian at scene time `t`. Returns `None` when culled
/// (temporally dead, behind the camera, degenerate, or sub-cutoff alpha).
pub fn project_gaussian(g: &Gaussian4D, id: u32, cam: &Camera, t: f32) -> Option<Splat2D> {
    let w_t = g.temporal_weight(t);
    let alpha_base = g.opacity * w_t;
    if alpha_base < ALPHA_CUTOFF {
        return None;
    }

    let mean3 = g.mean_at(t);
    let pc = cam.to_camera(mean3);
    let (mean2, depth) = cam.project_cam(pc)?;

    // Σ²ᴰ = (J W Σ³ᴰ|ᵗ Wᵀ Jᵀ)₁:₂,₁:₂  (eq. 8)
    let w = cam.view_rotation();
    let j = cam.projection_jacobian(pc);
    let jw = j.mul_mat(&w);
    let cov2d_full = jw.mul_mat(&g.cov3d()).mul_mat(&jw.transpose());
    let mut a = cov2d_full.m[0][0] + COV2D_DILATION;
    let b = cov2d_full.m[0][1];
    let mut c = cov2d_full.m[1][1] + COV2D_DILATION;
    // Guard degenerate covariances.
    a = a.max(1e-6);
    c = c.max(1e-6);

    let det = a * c - b * b;
    if det <= 0.0 {
        return None;
    }
    let inv_det = 1.0 / det;
    let conic = [c * inv_det, -b * inv_det, a * inv_det];

    // 3σ of the major axis: eigenvalues of [[a,b],[b,c]], plus the exact
    // axis-aligned extents (marginal std-devs √a, √c).
    let mid = 0.5 * (a + c);
    let disc = (mid * mid - det).max(0.0).sqrt();
    let lambda_max = mid + disc;
    let radius = 3.0 * lambda_max.sqrt();
    let rx = 3.0 * a.sqrt();
    let ry = 3.0 * c.sqrt();

    // View-dependent color.
    let dir = (mean3 - cam.position).normalized();
    let color = g.sh_color(dir);

    Some(Splat2D {
        id,
        mean: mean2,
        conic,
        radius,
        rx,
        ry,
        depth,
        alpha_base,
        color,
    })
}

/// Evaluate the splat's Gaussian falloff at pixel `(px, py)` — the spatial
/// part of eq. 10's merged exponent.
#[inline]
pub fn splat_exponent(s: &Splat2D, px: f32, py: f32) -> f32 {
    let dx = px - s.mean.x;
    let dy = py - s.mean.y;
    -0.5 * (s.conic[0] * dx * dx + 2.0 * s.conic[1] * dx * dy + s.conic[2] * dy * dy)
}

/// The image's tile decomposition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileGrid {
    pub width: usize,
    pub height: usize,
    pub tiles_x: usize,
    pub tiles_y: usize,
}

impl TileGrid {
    pub fn new(width: usize, height: usize) -> TileGrid {
        TileGrid {
            width,
            height,
            tiles_x: width.div_ceil(TILE_PX),
            tiles_y: height.div_ceil(TILE_PX),
        }
    }

    pub fn n_tiles(&self) -> usize {
        self.tiles_x * self.tiles_y
    }

    #[inline]
    pub fn tile_index(&self, tx: usize, ty: usize) -> usize {
        ty * self.tiles_x + tx
    }

    #[inline]
    pub fn tile_xy(&self, idx: usize) -> (usize, usize) {
        (idx % self.tiles_x, idx / self.tiles_x)
    }

    /// Pixel rectangle of tile `idx`: (x0, y0, x1, y1), exclusive ends,
    /// clipped to the image.
    pub fn tile_pixels(&self, idx: usize) -> (usize, usize, usize, usize) {
        let (tx, ty) = self.tile_xy(idx);
        let x0 = tx * TILE_PX;
        let y0 = ty * TILE_PX;
        (x0, y0, (x0 + TILE_PX).min(self.width), (y0 + TILE_PX).min(self.height))
    }

    /// Inclusive tile-coordinate range covered by a splat's radius, or
    /// `None` when fully off-screen.
    pub fn tile_range(&self, s: &Splat2D) -> Option<(usize, usize, usize, usize)> {
        let x0 = s.mean.x - s.rx;
        let x1 = s.mean.x + s.rx;
        let y0 = s.mean.y - s.ry;
        let y1 = s.mean.y + s.ry;
        if x1 < 0.0 || y1 < 0.0 || x0 >= self.width as f32 || y0 >= self.height as f32 {
            return None;
        }
        let tx0 = (x0.max(0.0) as usize) / TILE_PX;
        let ty0 = (y0.max(0.0) as usize) / TILE_PX;
        let tx1 = ((x1 as usize).min(self.width - 1)) / TILE_PX;
        let ty1 = ((y1 as usize).min(self.height - 1)) / TILE_PX;
        Some((tx0, ty0, tx1.min(self.tiles_x - 1), ty1.min(self.tiles_y - 1)))
    }

    /// Enumerate tile indices a splat intersects.
    pub fn splat_tiles(&self, s: &Splat2D, mut f: impl FnMut(usize)) {
        if let Some((tx0, ty0, tx1, ty1)) = self.tile_range(s) {
            for ty in ty0..=ty1 {
                for tx in tx0..=tx1 {
                    f(self.tile_index(tx, ty));
                }
            }
        }
    }
}

/// Build per-tile splat lists for a frame (the "intersection testing" stage;
/// counts are the duplication factor the sorting stage must handle).
pub fn bin_splats(grid: &TileGrid, splats: &[Splat2D]) -> Vec<Vec<u32>> {
    let mut bins: Vec<Vec<u32>> = Vec::new();
    bin_splats_into(grid, splats, &mut bins);
    bins
}

/// Pooled variant of [`bin_splats`]: reuses `bins`' outer and inner vector
/// capacities across frames (the stage-graph `FrameCtx` scratch contract —
/// steady-state frames allocate nothing here).
pub fn bin_splats_into(grid: &TileGrid, splats: &[Splat2D], bins: &mut Vec<Vec<u32>>) {
    if bins.len() != grid.n_tiles() {
        bins.resize_with(grid.n_tiles(), Vec::new);
    }
    for b in bins.iter_mut() {
        b.clear();
    }
    for (si, s) in splats.iter().enumerate() {
        grid.splat_tiles(s, |tile| bins[tile].push(si as u32));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec3;

    fn cam() -> Camera {
        Camera::look_at(
            Vec3::new(0.0, 0.0, 10.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            16.0 / 9.0,
            0.1,
            100.0,
        )
    }

    fn centered_gaussian(sigma: f32) -> Gaussian4D {
        Gaussian4D::isotropic(Vec3::ZERO, sigma, 0.9, Vec3::splat(0.3))
    }

    #[test]
    fn center_gaussian_projects_to_image_center() {
        let c = cam();
        let s = project_gaussian(&centered_gaussian(0.5), 0, &c, 0.0).unwrap();
        assert!((s.mean.x - c.intrinsics.cx).abs() < 1e-2);
        assert!((s.mean.y - c.intrinsics.cy).abs() < 1e-2);
        assert!((s.depth - 10.0).abs() < 1e-3);
        assert!((s.alpha_base - 0.9).abs() < 1e-6);
    }

    #[test]
    fn behind_camera_returns_none() {
        let c = cam();
        let g = Gaussian4D::isotropic(Vec3::new(0.0, 0.0, 20.0), 0.5, 0.9, Vec3::ONE);
        assert!(project_gaussian(&g, 0, &c, 0.0).is_none());
    }

    #[test]
    fn temporally_dead_returns_none() {
        let c = cam();
        let mut g = centered_gaussian(0.5);
        g.sigma_t = 0.01;
        g.mu_t = 0.0;
        g.velocity = Vec3::ZERO;
        assert!(project_gaussian(&g, 0, &c, 0.5).is_none(), "50σ away in time");
        assert!(project_gaussian(&g, 0, &c, 0.0).is_some());
    }

    #[test]
    fn radius_scales_with_sigma_and_distance() {
        let c = cam();
        let s_small = project_gaussian(&centered_gaussian(0.2), 0, &c, 0.0).unwrap();
        let s_big = project_gaussian(&centered_gaussian(1.0), 0, &c, 0.0).unwrap();
        assert!(s_big.radius > 2.0 * s_small.radius);
    }

    #[test]
    fn exponent_is_zero_at_mean_negative_away() {
        let c = cam();
        let s = project_gaussian(&centered_gaussian(0.5), 0, &c, 0.0).unwrap();
        assert!(splat_exponent(&s, s.mean.x, s.mean.y).abs() < 1e-9);
        assert!(splat_exponent(&s, s.mean.x + 30.0, s.mean.y) < -0.1);
    }

    #[test]
    fn tile_grid_covers_image() {
        let g = TileGrid::new(1280, 720);
        assert_eq!(g.tiles_x, 80);
        assert_eq!(g.tiles_y, 45);
        assert_eq!(g.n_tiles(), 3600);
        let (x0, y0, x1, y1) = g.tile_pixels(g.n_tiles() - 1);
        assert_eq!((x1, y1), (1280, 720));
        assert_eq!((x0, y0), (1264, 704));
    }

    #[test]
    fn tile_grid_handles_non_multiple_sizes() {
        let g = TileGrid::new(100, 50);
        assert_eq!(g.tiles_x, 7);
        assert_eq!(g.tiles_y, 4);
        let (_, _, x1, y1) = g.tile_pixels(g.n_tiles() - 1);
        assert_eq!((x1, y1), (100, 50));
    }

    #[test]
    fn offscreen_splat_has_no_tiles() {
        let grid = TileGrid::new(640, 360);
        let s = Splat2D {
            id: 0,
            mean: Vec2::new(-100.0, -100.0),
            conic: [1.0, 0.0, 1.0],
            radius: 10.0,
            rx: 10.0,
            ry: 10.0,
            depth: 1.0,
            alpha_base: 0.5,
            color: Vec3::ONE,
        };
        assert!(grid.tile_range(&s).is_none());
    }

    #[test]
    fn bin_splats_puts_center_splat_in_center_tile() {
        let grid = TileGrid::new(640, 360);
        let s = Splat2D {
            id: 7,
            mean: Vec2::new(320.0, 180.0),
            conic: [1.0, 0.0, 1.0],
            radius: 4.0,
            rx: 4.0,
            ry: 4.0,
            depth: 1.0,
            alpha_base: 0.5,
            color: Vec3::ONE,
        };
        let bins = bin_splats(&grid, &[s]);
        let center_tile = grid.tile_index(320 / TILE_PX, 180 / TILE_PX);
        assert!(bins[center_tile].contains(&0));
        let total: usize = bins.iter().map(|b| b.len()).sum();
        assert!(total >= 1 && total <= 9, "small splat touches few tiles: {total}");
    }

    #[test]
    fn bin_splats_into_reuses_capacity_and_matches() {
        let grid = TileGrid::new(320, 180);
        let mk = |x: f32, y: f32| Splat2D {
            id: 0,
            mean: Vec2::new(x, y),
            conic: [1.0, 0.0, 1.0],
            radius: 20.0,
            rx: 20.0,
            ry: 20.0,
            depth: 1.0,
            alpha_base: 0.5,
            color: Vec3::ONE,
        };
        let frame_a = vec![mk(100.0, 90.0), mk(200.0, 40.0)];
        let frame_b = vec![mk(101.0, 91.0), mk(201.0, 41.0)];

        let mut pooled: Vec<Vec<u32>> = Vec::new();
        bin_splats_into(&grid, &frame_a, &mut pooled);
        assert_eq!(pooled, bin_splats(&grid, &frame_a));
        let caps: Vec<usize> = pooled.iter().map(Vec::capacity).collect();

        bin_splats_into(&grid, &frame_b, &mut pooled);
        assert_eq!(pooled, bin_splats(&grid, &frame_b));
        // clear() keeps capacity: the pool never shrinks between frames.
        for (b, &c) in pooled.iter().zip(&caps) {
            assert!(b.capacity() >= c);
        }
    }

    #[test]
    fn big_splat_touches_many_tiles() {
        let grid = TileGrid::new(640, 360);
        let s = Splat2D {
            id: 0,
            mean: Vec2::new(320.0, 180.0),
            conic: [0.001, 0.0, 0.001],
            radius: 100.0,
            rx: 100.0,
            ry: 100.0,
            depth: 1.0,
            alpha_base: 0.5,
            color: Vec3::ONE,
        };
        let mut count = 0;
        grid.splat_tiles(&s, |_| count += 1);
        assert!(count > 100, "200px-diameter splat covers many 16px tiles: {count}");
    }
}
