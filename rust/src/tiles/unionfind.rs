//! Union-Find (disjoint set union) with path halving + union by size —
//! the grouping primitive of ATG phase 1 (paper §3.3-A).

/// Disjoint-set forest over `0..n`.
#[derive(Debug, Clone)]
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
    components: usize,
}

impl UnionFind {
    pub fn new(n: usize) -> UnionFind {
        UnionFind {
            parent: (0..n as u32).collect(),
            size: vec![1; n],
            components: n,
        }
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Representative of `x` (path halving).
    pub fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] as usize != x {
            self.parent[x] = self.parent[self.parent[x] as usize];
            x = self.parent[x] as usize;
        }
        x
    }

    /// Merge the sets of `a` and `b`; returns true when a merge happened.
    pub fn union(&mut self, a: usize, b: usize) -> bool {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        if self.size[ra] < self.size[rb] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb] = ra as u32;
        self.size[ra] += self.size[rb];
        self.components -= 1;
        true
    }

    pub fn connected(&mut self, a: usize, b: usize) -> bool {
        self.find(a) == self.find(b)
    }

    pub fn n_components(&self) -> usize {
        self.components
    }

    /// Size of the component containing `x`.
    pub fn component_size(&mut self, x: usize) -> usize {
        let r = self.find(x);
        self.size[r] as usize
    }

    /// Group members by representative: returns (label per element, groups).
    pub fn groups(&mut self) -> (Vec<u32>, Vec<Vec<u32>>) {
        let n = self.len();
        let mut label = vec![u32::MAX; n];
        let mut groups: Vec<Vec<u32>> = Vec::new();
        for i in 0..n {
            let r = self.find(i);
            if label[r] == u32::MAX {
                label[r] = groups.len() as u32;
                groups.push(Vec::new());
            }
            label[i] = label[r];
            groups[label[r] as usize].push(i as u32);
        }
        (label, groups)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singletons_initially() {
        let mut uf = UnionFind::new(5);
        assert_eq!(uf.n_components(), 5);
        assert!(!uf.connected(0, 1));
    }

    #[test]
    fn union_connects_transitively() {
        let mut uf = UnionFind::new(6);
        uf.union(0, 1);
        uf.union(1, 2);
        assert!(uf.connected(0, 2));
        assert!(!uf.connected(0, 3));
        assert_eq!(uf.n_components(), 4);
        assert_eq!(uf.component_size(2), 3);
    }

    #[test]
    fn redundant_union_returns_false() {
        let mut uf = UnionFind::new(3);
        assert!(uf.union(0, 1));
        assert!(!uf.union(1, 0));
        assert_eq!(uf.n_components(), 2);
    }

    #[test]
    fn groups_partition_everything() {
        let mut uf = UnionFind::new(8);
        uf.union(0, 4);
        uf.union(4, 6);
        uf.union(1, 3);
        let (label, groups) = uf.groups();
        assert_eq!(label.len(), 8);
        let total: usize = groups.iter().map(|g| g.len()).sum();
        assert_eq!(total, 8);
        assert_eq!(label[0], label[4]);
        assert_eq!(label[0], label[6]);
        assert_eq!(label[1], label[3]);
        assert_ne!(label[0], label[1]);
        // Each member is in the group its label names.
        for (i, &l) in label.iter().enumerate() {
            assert!(groups[l as usize].contains(&(i as u32)));
        }
    }

    #[test]
    fn large_chain_has_flat_depth_after_finds() {
        let n = 10_000;
        let mut uf = UnionFind::new(n);
        for i in 0..n - 1 {
            uf.union(i, i + 1);
        }
        assert_eq!(uf.n_components(), 1);
        assert_eq!(uf.component_size(0), n);
    }
}
