//! Tile-space processing: Gaussian→2D projection and tile intersection
//! testing, the connection-strength graph, Union-Find, and Adaptive Tile
//! Grouping with posteriori knowledge (ATG, paper §3.3) plus the raster-scan
//! baseline ordering.

pub mod atg;
pub mod connection;
pub mod intersect;
pub mod raster;
pub mod unionfind;

pub use atg::{Atg, AtgConfig, TileGroups};
pub use connection::ConnectionGraph;
pub use intersect::{project_gaussian, Splat2D, TileGrid};
pub use unionfind::UnionFind;

/// Rendering tile edge in pixels (3DGS convention: 16×16).
pub const TILE_PX: usize = 16;
