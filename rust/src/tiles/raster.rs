//! Conventional raster-scan tile ordering — the baseline ATG is compared
//! against in Fig. 10(a). Tiles are visited row-major, which breaks the
//! reuse of Gaussians that span tiles vertically (the paper's Challenge 2
//! example).

/// Raster-scan visit order for a `tiles_x × tiles_y` grid.
pub fn raster_order(tiles_x: usize, tiles_y: usize) -> Vec<usize> {
    (0..tiles_x * tiles_y).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raster_is_identity_permutation() {
        let o = raster_order(4, 3);
        assert_eq!(o, (0..12).collect::<Vec<_>>());
    }
}
