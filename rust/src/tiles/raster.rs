//! Conventional raster-scan tile ordering — the baseline ATG is compared
//! against in Fig. 10(a). Tiles are visited row-major, which breaks the
//! reuse of Gaussians that span tiles vertically (the paper's Challenge 2
//! example).

/// Raster-scan visit order for a `tiles_x × tiles_y` grid.
pub fn raster_order(tiles_x: usize, tiles_y: usize) -> Vec<usize> {
    (0..tiles_x * tiles_y).collect()
}

/// Pooled variant of [`raster_order`]: fills `out` in place, reusing its
/// capacity (stage-graph `FrameCtx` scratch contract).
pub fn raster_order_into(tiles_x: usize, tiles_y: usize, out: &mut Vec<usize>) {
    out.clear();
    out.extend(0..tiles_x * tiles_y);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raster_is_identity_permutation() {
        let o = raster_order(4, 3);
        assert_eq!(o, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn raster_into_matches_and_reuses() {
        let mut out = Vec::new();
        raster_order_into(5, 2, &mut out);
        assert_eq!(out, raster_order(5, 2));
        let cap = out.capacity();
        raster_order_into(5, 2, &mut out);
        assert_eq!(out, raster_order(5, 2));
        assert_eq!(out.capacity(), cap);
    }

    #[test]
    fn raster_into_is_invariant_to_prior_contents() {
        // The pooled output may hold any permutation (or garbage) from a
        // previous frame's ATG order — the refill must be insensitive to
        // it. This is what licenses sharing one `tile_order` pool between
        // the ATG and raster arms across frames.
        let expected = raster_order(4, 3);
        let mut permuted: Vec<usize> = (0..12).rev().collect();
        raster_order_into(4, 3, &mut permuted);
        assert_eq!(permuted, expected);

        let mut garbage: Vec<usize> = vec![9, 3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8, 9, 7];
        raster_order_into(4, 3, &mut garbage);
        assert_eq!(garbage, expected);

        let mut short: Vec<usize> = vec![2];
        raster_order_into(4, 3, &mut short);
        assert_eq!(short, expected);
    }
}
