//! Binary scene serialization (`.g4d` format).
//!
//! Layout: 16-byte header (`magic "G4D1"`, u32 count, u32 flags, u32
//! reserved) followed by `count` fixed-size little-endian f32 records.
//! Used to persist synthesized scenes so experiments can share inputs.

use super::gaussian::{Gaussian4D, SH_COEFFS};
use super::Scene;
use crate::math::{Quat, Vec3};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"G4D1";
const FLAG_DYNAMIC: u32 = 1;
/// f32 fields per record: mu 3, rot 4, scale 3, mu_t 1, sigma_t 1, vel 3,
/// opacity 1, sh 27, time_span handled in header-adjacent trailer = 43.
const RECORD_F32S: usize = 3 + 4 + 3 + 1 + 1 + 3 + 1 + 3 * SH_COEFFS;

/// Save a scene to `path`.
pub fn save(scene: &Scene, path: &Path) -> Result<()> {
    let mut buf: Vec<u8> =
        Vec::with_capacity(16 + 8 + scene.len() * RECORD_F32S * 4);
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(scene.len() as u32).to_le_bytes());
    buf.extend_from_slice(&(if scene.dynamic { FLAG_DYNAMIC } else { 0 }).to_le_bytes());
    buf.extend_from_slice(&0u32.to_le_bytes());
    buf.extend_from_slice(&scene.time_span.0.to_le_bytes());
    buf.extend_from_slice(&scene.time_span.1.to_le_bytes());

    for g in &scene.gaussians {
        let mut push = |v: f32| buf.extend_from_slice(&v.to_le_bytes());
        push(g.mu.x);
        push(g.mu.y);
        push(g.mu.z);
        push(g.rot.w);
        push(g.rot.x);
        push(g.rot.y);
        push(g.rot.z);
        push(g.scale.x);
        push(g.scale.y);
        push(g.scale.z);
        push(g.mu_t);
        push(g.sigma_t);
        push(g.velocity.x);
        push(g.velocity.y);
        push(g.velocity.z);
        push(g.opacity);
        for c in &g.sh {
            push(c.x);
            push(c.y);
            push(c.z);
        }
    }
    std::fs::write(path, &buf).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Load a scene from `path`.
pub fn load(path: &Path) -> Result<Scene> {
    let mut file =
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut buf = Vec::new();
    file.read_to_end(&mut buf)?;
    if buf.len() < 24 || &buf[0..4] != MAGIC {
        bail!("not a .g4d file: {}", path.display());
    }
    let count = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    let flags = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    let t0 = f32::from_le_bytes(buf[16..20].try_into().unwrap());
    let t1 = f32::from_le_bytes(buf[20..24].try_into().unwrap());

    let expect = 24 + count * RECORD_F32S * 4;
    if buf.len() != expect {
        bail!("truncated .g4d: {} bytes, expected {}", buf.len(), expect);
    }

    let mut off = 24usize;
    let mut next = || {
        let v = f32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
        off += 4;
        v
    };
    let mut gaussians = Vec::with_capacity(count);
    for _ in 0..count {
        let mu = Vec3::new(next(), next(), next());
        let rot = Quat::new(next(), next(), next(), next());
        let scale = Vec3::new(next(), next(), next());
        let mu_t = next();
        let sigma_t = next();
        let velocity = Vec3::new(next(), next(), next());
        let opacity = next();
        let mut sh = [Vec3::ZERO; SH_COEFFS];
        for c in &mut sh {
            *c = Vec3::new(next(), next(), next());
        }
        gaussians.push(Gaussian4D {
            mu,
            rot,
            scale,
            mu_t,
            sigma_t,
            velocity,
            opacity,
            sh,
        });
    }

    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "scene".to_string());
    let mut scene = Scene::new(name, gaussians, flags & FLAG_DYNAMIC != 0);
    scene.time_span = (t0, t1);
    Ok(scene)
}

/// Write `scene` only if `path` is missing (cache semantics for benches).
pub fn ensure_cached(scene_gen: impl FnOnce() -> Scene, path: &Path) -> Result<Scene> {
    if path.exists() {
        load(path)
    } else {
        let scene = scene_gen();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        save(&scene, path)?;
        Ok(scene)
    }
}

/// Convenience: save to any `Write` (used by tests).
pub fn save_to(scene: &Scene, w: &mut impl Write) -> Result<()> {
    let tmp = std::env::temp_dir().join(format!("g4d-{}.tmp", std::process::id()));
    save(scene, &tmp)?;
    let bytes = std::fs::read(&tmp)?;
    std::fs::remove_file(&tmp).ok();
    w.write_all(&bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::synth::{SceneKind, SynthParams};

    #[test]
    fn roundtrip_preserves_everything() {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 500).generate();
        let path = std::env::temp_dir().join("gaucim_test_roundtrip.g4d");
        save(&scene, &path).unwrap();
        let loaded = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.len(), scene.len());
        assert_eq!(loaded.dynamic, scene.dynamic);
        assert_eq!(loaded.time_span, scene.time_span);
        for (a, b) in scene.gaussians.iter().zip(&loaded.gaussians) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("gaucim_test_badmagic.g4d");
        std::fs::write(&path, b"NOPE0000000000000000000000").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncated() {
        let scene = SynthParams::new(SceneKind::StaticLarge, 10).generate();
        let path = std::env::temp_dir().join("gaucim_test_trunc.g4d");
        save(&scene, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ensure_cached_generates_once() {
        let path = std::env::temp_dir().join("gaucim_test_cache.g4d");
        std::fs::remove_file(&path).ok();
        let mut calls = 0;
        let s1 = ensure_cached(
            || {
                calls += 1;
                SynthParams::new(SceneKind::StaticLarge, 50).generate()
            },
            &path,
        )
        .unwrap();
        let s2 = ensure_cached(
            || {
                calls += 1;
                SynthParams::new(SceneKind::StaticLarge, 50).generate()
            },
            &path,
        )
        .unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(calls, 1);
        assert_eq!(s1.len(), s2.len());
    }
}
