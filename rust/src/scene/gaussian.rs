//! The 4D Gaussian primitive (paper §2.1, eqs. 2–6).
//!
//! ## Parameterization
//!
//! The paper represents Σ⁴ᴰ = U S Sᵀ Uᵀ. We store the equivalent
//! *conditional* (Schur-complement) form, which is both closer to what the
//! hardware consumes per frame and positive-semidefinite by construction:
//!
//! * `rot`, `scale` — conditional spatial covariance
//!   Σ³ᴰ|ᵗ = R · diag(s)² · Rᵀ  (eq. 6's left-hand side, which is constant
//!   in t);
//! * `velocity` — v = Σ⁴ᴰ₁:₃,₄ · λ, the linear motion rate of the
//!   conditional mean (eq. 5: μ³ᴰ|ᵗ = μ₁:₃ + v · (t − μₜ));
//! * `mu_t`, `sigma_t` — temporal mean and std-dev; λ = 1/σₜ² is eq. 4's
//!   temporal decay. Static Gaussians have `sigma_t = f32::INFINITY`
//!   (temporal weight ≡ 1) and zero velocity.
//!
//! The full 4-D covariance is recoverable as
//! Σ_spatial = Σ³ᴰ|ᵗ + v vᵀ σₜ², Σ₁:₃,₄ = v σₜ², Σ₄,₄ = σₜ².

use crate::math::{f16, Mat3, Quat, Vec3};

/// Number of spherical-harmonics coefficients per color channel (degree 2).
pub const SH_COEFFS: usize = 9;

/// One 4D Gaussian primitive.
#[derive(Debug, Clone, PartialEq)]
pub struct Gaussian4D {
    /// Spatial mean at t = `mu_t` (μ⁴ᴰ₁:₃).
    pub mu: Vec3,
    /// Orientation of the conditional spatial covariance.
    pub rot: Quat,
    /// Per-axis std-devs of the conditional spatial covariance.
    pub scale: Vec3,
    /// Temporal mean μₜ.
    pub mu_t: f32,
    /// Temporal std-dev σₜ (INFINITY ⇒ static).
    pub sigma_t: f32,
    /// Conditional-mean velocity v (world units per unit scene time).
    pub velocity: Vec3,
    /// Base opacity o ∈ [0, 1].
    pub opacity: f32,
    /// Degree-2 SH coefficients per RGB channel: `sh[k]` = (R,G,B) of basis k.
    pub sh: [Vec3; SH_COEFFS],
}

impl Gaussian4D {
    /// An isotropic static Gaussian — convenient for tests.
    pub fn isotropic(mu: Vec3, sigma: f32, opacity: f32, color: Vec3) -> Gaussian4D {
        let mut sh = [Vec3::ZERO; SH_COEFFS];
        // DC term: c_0 = color / Y00 so that degree-0 evaluation returns `color`.
        sh[0] = color * (1.0 / 0.282_094_8);
        Gaussian4D {
            mu,
            rot: Quat::IDENTITY,
            scale: Vec3::splat(sigma),
            mu_t: 0.0,
            sigma_t: f32::INFINITY,
            velocity: Vec3::ZERO,
            opacity,
            sh,
        }
    }

    /// Is this a static (time-invariant) primitive?
    #[inline]
    pub fn is_static(&self) -> bool {
        self.sigma_t.is_infinite()
    }

    /// Temporal decay λ = Σ⁴ᴰ₄,₄⁻¹ (eq. 4); 0 for static Gaussians.
    #[inline]
    pub fn lambda(&self) -> f32 {
        if self.is_static() {
            0.0
        } else {
            1.0 / (self.sigma_t * self.sigma_t)
        }
    }

    /// Conditional spatial covariance Σ³ᴰ|ᵗ = R diag(s²) Rᵀ (eq. 6).
    pub fn cov3d(&self) -> Mat3 {
        let r = self.rot.to_mat3();
        let s2 = Mat3::diag(self.scale.hadamard(self.scale));
        r.mul_mat(&s2).mul_mat(&r.transpose())
    }

    /// Conditional mean at scene time `t` (eq. 5).
    #[inline]
    pub fn mean_at(&self, t: f32) -> Vec3 {
        if self.is_static() {
            self.mu
        } else {
            self.mu + self.velocity * (t - self.mu_t)
        }
    }

    /// Temporal visibility weight G(t; μₜ, λ⁻¹) = exp(−λ(t−μₜ)²/2) (eq. 4).
    #[inline]
    pub fn temporal_weight(&self, t: f32) -> f32 {
        if self.is_static() {
            1.0
        } else {
            let d = t - self.mu_t;
            (-0.5 * self.lambda() * d * d).exp()
        }
    }

    /// Conservative world-space radius: 3σ of the largest covariance axis
    /// (used by exact per-Gaussian frustum tests and grid spanning).
    #[inline]
    pub fn radius3(&self) -> f32 {
        3.0 * self.scale.max_component()
    }

    /// Temporal span [μₜ − 3σₜ, μₜ + 3σₜ] during which the Gaussian is
    /// non-negligible; the whole timeline for static primitives.
    pub fn time_extent(&self) -> (f32, f32) {
        if self.is_static() {
            (f32::NEG_INFINITY, f32::INFINITY)
        } else {
            (self.mu_t - 3.0 * self.sigma_t, self.mu_t + 3.0 * self.sigma_t)
        }
    }

    /// DRAM storage footprint in bytes for FP16 parameters (§4 of the
    /// paper: numerical precision FP16). Dynamic primitives carry the
    /// temporal mean/extent and velocity on top of the static layout.
    pub fn dram_bytes(dynamic: bool) -> usize {
        // position 3 + rotation 4 + scale 3 + opacity 1 + SH 27 = 38 halves.
        let static_halves = 3 + 4 + 3 + 1 + 3 * SH_COEFFS;
        // + μₜ 1 + σₜ 1 + velocity 3 = 5 more.
        let halves = if dynamic { static_halves + 5 } else { static_halves };
        // 8-byte DRAM alignment for burst-friendly strides.
        (halves * 2 + 7) / 8 * 8
    }

    /// Quantize all parameters through FP16 storage — models what the
    /// parameters look like after a DRAM round trip.
    pub fn quantized_fp16(&self) -> Gaussian4D {
        let q = f16::quantize;
        let qv = |v: Vec3| Vec3::new(q(v.x), q(v.y), q(v.z));
        let mut sh = self.sh;
        for c in &mut sh {
            *c = qv(*c);
        }
        Gaussian4D {
            mu: qv(self.mu),
            rot: Quat::new(q(self.rot.w), q(self.rot.x), q(self.rot.y), q(self.rot.z)),
            scale: qv(self.scale),
            mu_t: q(self.mu_t),
            sigma_t: if self.sigma_t.is_infinite() { self.sigma_t } else { q(self.sigma_t) },
            velocity: qv(self.velocity),
            opacity: q(self.opacity),
            sh,
        }
    }

    /// Evaluate the view-dependent color via real spherical harmonics up to
    /// degree 2, clamped to [0, 1]. `dir` is the unit viewing direction.
    pub fn sh_color(&self, dir: Vec3) -> Vec3 {
        let basis = sh_basis(dir);
        let mut c = Vec3::ZERO;
        for (k, b) in basis.iter().enumerate() {
            c += self.sh[k] * *b;
        }
        // 3DGS convention: +0.5 offset on the DC-centered value.
        c += Vec3::splat(0.5);
        Vec3::new(c.x.clamp(0.0, 1.0), c.y.clamp(0.0, 1.0), c.z.clamp(0.0, 1.0))
    }
}

/// Real SH basis values up to degree 2 for a unit direction.
pub fn sh_basis(d: Vec3) -> [f32; SH_COEFFS] {
    const C0: f32 = 0.282_094_8; // Y00
    const C1: f32 = 0.488_602_5; // Y1*
    const C2: [f32; 5] = [1.092_548_4, 1.092_548_4, 0.315_391_57, 1.092_548_4, 0.546_274_2];
    let (x, y, z) = (d.x, d.y, d.z);
    [
        C0,
        -C1 * y,
        C1 * z,
        -C1 * x,
        C2[0] * x * y,
        C2[1] * y * z,
        C2[2] * (2.0 * z * z - x * x - y * y),
        C2[3] * x * z,
        C2[4] * (x * x - y * y),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_dynamic() -> Gaussian4D {
        let mut g = Gaussian4D::isotropic(Vec3::new(1.0, 2.0, 3.0), 0.5, 0.8, Vec3::splat(0.5));
        g.mu_t = 0.5;
        g.sigma_t = 0.1;
        g.velocity = Vec3::new(2.0, 0.0, -1.0);
        g
    }

    #[test]
    fn static_gaussian_time_invariant() {
        let g = Gaussian4D::isotropic(Vec3::ZERO, 1.0, 1.0, Vec3::ONE);
        assert!(g.is_static());
        assert_eq!(g.temporal_weight(0.0), 1.0);
        assert_eq!(g.temporal_weight(123.0), 1.0);
        assert_eq!(g.mean_at(55.0), g.mu);
        assert_eq!(g.lambda(), 0.0);
    }

    #[test]
    fn dynamic_mean_moves_linearly() {
        let g = sample_dynamic();
        assert_eq!(g.mean_at(0.5), g.mu);
        let m = g.mean_at(1.0);
        assert!((m - (g.mu + g.velocity * 0.5)).length() < 1e-6);
    }

    #[test]
    fn temporal_weight_peaks_at_mu_t() {
        let g = sample_dynamic();
        assert!((g.temporal_weight(0.5) - 1.0).abs() < 1e-6);
        let w1 = g.temporal_weight(0.6); // 1σ away
        assert!((w1 - (-0.5f32).exp()).abs() < 1e-5);
        assert!(g.temporal_weight(0.9) < g.temporal_weight(0.6));
    }

    #[test]
    fn cov3d_is_symmetric_psd() {
        let mut g = sample_dynamic();
        g.rot = Quat::from_axis_angle(Vec3::new(1.0, 1.0, 0.0), 0.8);
        g.scale = Vec3::new(0.2, 1.5, 0.7);
        let c = g.cov3d();
        assert!(c.is_symmetric(1e-5));
        // PSD check via quadratic form on several directions.
        for v in [Vec3::new(1.0, 0.0, 0.0), Vec3::new(-0.3, 0.9, 0.4), Vec3::ONE] {
            assert!(c.quadratic_form(v) > 0.0);
        }
        // Determinant = product of squared scales (rotation-invariant).
        let expect = (0.2f32 * 1.5 * 0.7).powi(2);
        assert!((c.determinant() - expect).abs() / expect < 1e-3);
    }

    #[test]
    fn sh_dc_only_gives_constant_color() {
        let g = Gaussian4D::isotropic(Vec3::ZERO, 1.0, 1.0, Vec3::new(0.25, 0.0, -0.25));
        // isotropic() sets DC so the evaluated color = color + 0.5 offset... verify:
        let c1 = g.sh_color(Vec3::new(0.0, 0.0, 1.0));
        let c2 = g.sh_color(Vec3::new(1.0, 0.0, 0.0).normalized());
        assert!((c1 - c2).length() < 1e-6, "DC-only must be view-independent");
        assert!((c1.x - 0.75).abs() < 1e-5);
        assert!((c1.y - 0.5).abs() < 1e-5);
        assert!((c1.z - 0.25).abs() < 1e-5);
    }

    #[test]
    fn sh_basis_degree1_flips_with_direction() {
        let b1 = sh_basis(Vec3::new(0.0, 1.0, 0.0));
        let b2 = sh_basis(Vec3::new(0.0, -1.0, 0.0));
        assert!((b1[1] + b2[1]).abs() < 1e-6);
    }

    #[test]
    fn dram_bytes_layout() {
        // 38 halves = 76 B → 80 B aligned; 43 halves = 86 B → 88 B aligned.
        assert_eq!(Gaussian4D::dram_bytes(false), 80);
        assert_eq!(Gaussian4D::dram_bytes(true), 88);
    }

    #[test]
    fn fp16_quantization_small_relative_error() {
        let g = sample_dynamic();
        let q = g.quantized_fp16();
        assert!((q.mu - g.mu).length() < 2e-3);
        assert!((q.opacity - g.opacity).abs() < 1e-3);
        assert!(q.sigma_t > 0.0);
        // Static stays static through quantization.
        let s = Gaussian4D::isotropic(Vec3::ZERO, 1.0, 1.0, Vec3::ONE).quantized_fp16();
        assert!(s.is_static());
    }

    #[test]
    fn time_extent_covers_3_sigma() {
        let g = sample_dynamic();
        let (t0, t1) = g.time_extent();
        assert!((t0 - 0.2).abs() < 1e-6);
        assert!((t1 - 0.8).abs() < 1e-6);
    }
}
