//! Temporal-delta update streaming for dynamic scenes.
//!
//! A dynamic scene's parameters change every frame (eq. 5: the conditional
//! mean moves with velocity), so a serving stack that keeps the scene in
//! DRAM must *write* the changed records each frame — a real workload that
//! contends with render reads. [`TemporalStream`] models the producer side
//! of that stream:
//!
//! * Each frame, every Gaussian's FP16 storage record is baked at the
//!   frame's scene time (`mean_at(t)` folded into the stored position; all
//!   other fields are time-invariant) and compared word-for-word against
//!   the previous frame's bake. Static Gaussians — and dynamic ones whose
//!   FP16 image happens not to move — produce bit-identical words and ship
//!   nothing.
//! * Changed records are XOR-delta encoded against their own previous
//!   frame (the [`super::compressed`] record codec applied *temporally*
//!   instead of spatially), prefixed per cell with a dirty-record bitmap so
//!   the consumer knows which slots to patch.
//! * Dirty tracking is per grid cell: a cell whose run saw no change ships
//!   **zero bytes** — no header, no write transaction. The per-frame write
//!   list ([`TemporalStream::take_writes`]) carries one `(addr, bytes)`
//!   entry per dirty cell, addressed at the cell run's base so the
//!   event-queue [`MemorySystem`](crate::memory::MemorySystem) shards it
//!   like any other traffic.
//!
//! The stream's first [`TemporalStream::advance`] bakes the baseline (the
//! scene image the render path already fetched during scene prep) and
//! ships nothing; every later advance ships the frame-over-frame delta.
//! Everything here is a pure function of `(quantized scene, layout, t)`,
//! so the write schedule is bit-identical across host thread counts.

use super::compressed::{encode_record, record_words, words_per_record};
use super::gaussian::Gaussian4D;
use super::layout::DramLayout;

/// Per-frame statistics of one [`TemporalStream::advance`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct UpdateFrameStats {
    /// Cells whose run changed this frame (each ships one delta write).
    pub dirty_cells: u64,
    /// Cells whose run was bit-identical to the previous frame (zero bytes).
    pub clean_cells: u64,
    /// Gaussian records whose FP16 image changed.
    pub updated_records: u64,
    /// Bytes actually shipped (bitmap headers + XOR-delta payloads).
    pub delta_bytes: u64,
    /// Bytes a raw full-record refresh of the same records would ship.
    pub raw_bytes: u64,
}

impl UpdateFrameStats {
    pub fn add(&mut self, o: &UpdateFrameStats) {
        self.dirty_cells += o.dirty_cells;
        self.clean_cells += o.clean_cells;
        self.updated_records += o.updated_records;
        self.delta_bytes += o.delta_bytes;
        self.raw_bytes += o.raw_bytes;
    }
}

/// The per-session producer of a dynamic scene's update stream. Owns the
/// previous frame's baked FP16 record words (the temporal delta baseline)
/// and the per-frame dirty flags the coherence optimizations
/// (dirty-cell-aware cull reuse) consume.
#[derive(Debug)]
pub struct TemporalStream {
    dynamic: bool,
    n_words: usize,
    /// Previous frame's record words, indexed `gi * n_words ..`.
    words: Vec<u16>,
    /// Per-cell dirty flag of the last advance.
    dirty_cells: Vec<bool>,
    /// Per-record (original Gaussian index) dirty flag of the last advance.
    dirty_records: Vec<bool>,
    /// Per-dirty-cell `(addr, bytes)` writes of the last advance.
    writes: Vec<(u64, u64)>,
    /// Scratch for the current record's bake.
    scratch: Vec<u16>,
    /// Scratch blob for one cell's delta encoding (only its length is
    /// charged; the simulated consumer never inspects payload bytes).
    blob: Vec<u8>,
    /// Frames advanced so far (0 = baseline not yet baked).
    frames: usize,
}

impl TemporalStream {
    /// A stream over `n_records` records of a scene with `n_cells` grid
    /// cells. `dynamic` selects the record layout (38 vs 43 FP16 words).
    pub fn new(dynamic: bool, n_records: usize, n_cells: usize) -> TemporalStream {
        let n_words = words_per_record(dynamic);
        TemporalStream {
            dynamic,
            n_words,
            words: vec![0u16; n_records * n_words],
            dirty_cells: vec![false; n_cells.max(1)],
            dirty_records: vec![false; n_records],
            writes: Vec::new(),
            scratch: Vec::with_capacity(n_words),
            blob: Vec::new(),
            frames: 0,
        }
    }

    /// Frames advanced so far.
    pub fn frames(&self) -> usize {
        self.frames
    }

    /// Per-cell dirty flags of the last [`TemporalStream::advance`]
    /// (all-clean before the first).
    pub fn dirty_cells(&self) -> &[bool] {
        &self.dirty_cells
    }

    /// Per-record dirty flags of the last advance (indexed by original
    /// Gaussian index).
    pub fn dirty_records(&self) -> &[bool] {
        &self.dirty_records
    }

    /// Drain the last advance's write list: one `(cell run base address,
    /// encoded bytes)` entry per dirty cell, in cell order.
    pub fn take_writes(&mut self) -> Vec<(u64, u64)> {
        std::mem::take(&mut self.writes)
    }

    /// Bake every record at scene time `t`, diff against the previous
    /// frame's bake, and stage the delta writes. The first call bakes the
    /// baseline and ships nothing. Pure host computation — no memory
    /// traffic is issued here; the caller replays
    /// [`TemporalStream::take_writes`] into its update port.
    pub fn advance(
        &mut self,
        quantized: &[Gaussian4D],
        layout: &DramLayout,
        t: f32,
    ) -> UpdateFrameStats {
        debug_assert_eq!(self.words.len(), quantized.len() * self.n_words);
        let baseline = self.frames == 0;
        self.frames += 1;
        self.writes.clear();
        let stride = layout.bytes_per_gaussian.max(1);
        let mut stats = UpdateFrameStats::default();

        for flag in self.dirty_records.iter_mut() {
            *flag = false;
        }
        for (ci, &(start, end)) in layout.cell_ranges.iter().enumerate() {
            let i0 = (start / stride) as usize;
            let i1 = (end / stride) as usize;
            self.blob.clear();
            // Dirty-record bitmap header for this cell's run.
            let header = (i1 - i0).div_ceil(8);
            self.blob.resize(header, 0u8);
            let mut cell_dirty = 0u64;
            for (slot, &gi) in layout.order[i0..i1].iter().enumerate() {
                let gi = gi as usize;
                let g = baked_at(&quantized[gi], t);
                record_words(&g, self.dynamic, &mut self.scratch);
                let prev = &mut self.words[gi * self.n_words..(gi + 1) * self.n_words];
                if self.scratch[..] == prev[..] {
                    continue;
                }
                self.dirty_records[gi] = true;
                cell_dirty += 1;
                if baseline {
                    prev.copy_from_slice(&self.scratch);
                } else {
                    self.blob[slot / 8] |= 1 << (slot % 8);
                    encode_record(&self.scratch, prev, &mut self.blob);
                }
            }
            self.dirty_cells[ci] = cell_dirty > 0;
            if baseline {
                continue;
            }
            if cell_dirty > 0 {
                stats.dirty_cells += 1;
                stats.updated_records += cell_dirty;
                stats.delta_bytes += self.blob.len() as u64;
                stats.raw_bytes += cell_dirty * stride;
                self.writes.push((start, self.blob.len() as u64));
            } else if i1 > i0 {
                stats.clean_cells += 1;
            }
        }
        if baseline {
            // The baseline bake is scene prep, not an update: every cell
            // reads clean so coherence reuse starts from frame 1 state.
            for flag in self.dirty_cells.iter_mut() {
                *flag = false;
            }
            for flag in self.dirty_records.iter_mut() {
                *flag = false;
            }
            return UpdateFrameStats::default();
        }
        stats
    }
}

/// The record image stored in DRAM at scene time `t`: the conditional mean
/// folded into the position field (eq. 5), every other parameter
/// time-invariant. FP16 re-quantization happens in `record_words`, exactly
/// as the original storage path quantizes.
fn baked_at(g: &Gaussian4D, t: f32) -> Gaussian4D {
    let mut out = g.clone();
    out.mu = g.mean_at(t);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::culling::grid::{GridConfig, GridPartition};
    use crate::scene::compressed::decode_record;
    use crate::scene::synth::{SceneKind, SynthParams};
    use crate::scene::Scene;

    fn scene_fixture(kind: SceneKind, n: usize) -> (Scene, DramLayout, Vec<Gaussian4D>) {
        let scene = SynthParams::new(kind, n).generate();
        let grid = GridPartition::build(
            &scene,
            if scene.dynamic { GridConfig::new(4) } else { GridConfig::static_scene(4) },
        );
        let layout = DramLayout::build(&scene, &grid);
        let quantized: Vec<Gaussian4D> =
            scene.gaussians.iter().map(|g| g.quantized_fp16()).collect();
        (scene, layout, quantized)
    }

    #[test]
    fn baseline_frame_ships_nothing() {
        let (scene, layout, quantized) = scene_fixture(SceneKind::DynamicLarge, 600);
        let mut ts = TemporalStream::new(scene.dynamic, quantized.len(), layout.cell_ranges.len());
        let s0 = ts.advance(&quantized, &layout, scene.time_span.0);
        assert_eq!(s0, UpdateFrameStats::default());
        assert!(ts.take_writes().is_empty());
        assert!(ts.dirty_cells().iter().all(|&d| !d));
    }

    #[test]
    fn moving_scene_ships_deltas_below_raw() {
        let (scene, layout, quantized) = scene_fixture(SceneKind::DynamicLarge, 600);
        let (t0, t1) = scene.time_span;
        let mut ts = TemporalStream::new(scene.dynamic, quantized.len(), layout.cell_ranges.len());
        ts.advance(&quantized, &layout, t0);
        let s = ts.advance(&quantized, &layout, t0 + 0.25 * (t1 - t0));
        assert!(s.updated_records > 0, "a dynamic scene must move");
        assert!(s.delta_bytes > 0);
        assert!(
            s.delta_bytes < s.raw_bytes,
            "temporal delta {} must undercut raw refresh {}",
            s.delta_bytes,
            s.raw_bytes
        );
        let writes = ts.take_writes();
        assert_eq!(writes.len() as u64, s.dirty_cells);
        assert_eq!(writes.iter().map(|&(_, b)| b).sum::<u64>(), s.delta_bytes);
    }

    #[test]
    fn static_scene_is_all_clean_after_baseline() {
        let (scene, layout, quantized) = scene_fixture(SceneKind::StaticLarge, 500);
        let mut ts = TemporalStream::new(scene.dynamic, quantized.len(), layout.cell_ranges.len());
        ts.advance(&quantized, &layout, 0.0);
        let s = ts.advance(&quantized, &layout, 0.7);
        assert_eq!(s.updated_records, 0);
        assert_eq!(s.delta_bytes, 0);
        assert_eq!(s.dirty_cells, 0);
        assert!(ts.take_writes().is_empty());
    }

    #[test]
    fn same_time_is_a_fixed_point() {
        let (scene, layout, quantized) = scene_fixture(SceneKind::DynamicLarge, 400);
        let mut ts = TemporalStream::new(scene.dynamic, quantized.len(), layout.cell_ranges.len());
        ts.advance(&quantized, &layout, 0.5);
        let s = ts.advance(&quantized, &layout, 0.5);
        assert_eq!(s.updated_records, 0, "re-baking the same t changes nothing");
        assert_eq!(s.delta_bytes, 0);
    }

    #[test]
    fn deltas_decode_back_to_the_new_bake() {
        // Round-trip the wire format: bitmap header + per-dirty-record
        // XOR-delta decodes to exactly the new frame's record words.
        let (scene, layout, quantized) = scene_fixture(SceneKind::DynamicLarge, 300);
        let (t0, t1) = scene.time_span;
        let n_words = words_per_record(scene.dynamic);
        let stride = layout.bytes_per_gaussian;
        let mut ts = TemporalStream::new(scene.dynamic, quantized.len(), layout.cell_ranges.len());
        ts.advance(&quantized, &layout, t0);
        // Consumer-side mirror of the baseline.
        let t_next = t0 + 0.4 * (t1 - t0);
        let mut mirror = vec![0u16; quantized.len() * n_words];
        let mut scratch = Vec::new();
        for (gi, g) in quantized.iter().enumerate() {
            record_words(&baked_at(g, t0), scene.dynamic, &mut scratch);
            mirror[gi * n_words..(gi + 1) * n_words].copy_from_slice(&scratch);
        }

        // Re-encode the frame the same way advance does, then decode.
        let mut producer =
            TemporalStream::new(scene.dynamic, quantized.len(), layout.cell_ranges.len());
        producer.advance(&quantized, &layout, t0);
        let mut blobs: Vec<(usize, Vec<u8>)> = Vec::new();
        for (ci, &(start, end)) in layout.cell_ranges.iter().enumerate() {
            let i0 = (start / stride) as usize;
            let i1 = (end / stride) as usize;
            let mut blob = vec![0u8; (i1 - i0).div_ceil(8)];
            let mut dirty = false;
            for (slot, &gi) in layout.order[i0..i1].iter().enumerate() {
                let gi = gi as usize;
                record_words(&baked_at(&quantized[gi], t_next), scene.dynamic, &mut scratch);
                let prev = &mut producer.words[gi * n_words..(gi + 1) * n_words];
                if scratch[..] != prev[..] {
                    blob[slot / 8] |= 1 << (slot % 8);
                    encode_record(&scratch, prev, &mut blob);
                    dirty = true;
                }
            }
            if dirty {
                blobs.push((ci, blob));
            }
        }
        for (ci, blob) in &blobs {
            let (start, end) = layout.cell_ranges[*ci];
            let i0 = (start / stride) as usize;
            let i1 = (end / stride) as usize;
            let header = (i1 - i0).div_ceil(8);
            let mut cursor = header;
            for (slot, &gi) in layout.order[i0..i1].iter().enumerate() {
                if blob[slot / 8] >> (slot % 8) & 1 == 0 {
                    continue;
                }
                let gi = gi as usize;
                let prev = &mut mirror[gi * n_words..(gi + 1) * n_words];
                cursor += decode_record(&blob[cursor..], prev);
            }
            assert_eq!(cursor, blob.len(), "cell {ci} blob fully consumed");
        }
        // The mirror now matches a fresh bake at t_next everywhere.
        for (gi, g) in quantized.iter().enumerate() {
            record_words(&baked_at(g, t_next), scene.dynamic, &mut scratch);
            assert_eq!(
                &mirror[gi * n_words..(gi + 1) * n_words],
                &scratch[..],
                "record {gi} mismatch after applying deltas"
            );
        }
    }
}
