//! Compressed backing-store layout for streaming scene residency.
//!
//! When a scene is larger than the DRAM capacity the residency layer is
//! given (`memory::residency`), DRAM acts as a page-granular cache over a
//! *compressed backing store* modeled by [`CompressedStore`]. The store
//! mirrors the uncompressed [`DramLayout`] address space — every page of
//! the scene span has a compressed byte count, a decode cost, and (for the
//! parameter region) an exactly-invertible encoding:
//!
//! * **Record codec** — each Gaussian is its FP16 storage image (38 halves
//!   static / 43 dynamic, the same words `Gaussian4D::quantized_fp16`
//!   models). Within one cell's contiguous run, records are XOR-delta
//!   encoded against the previous record word-for-word (the first record
//!   deltas against zero), and each 16-bit delta gets a 2-bit size code in
//!   a packed per-record header: `0` = delta is zero (no payload), `1` =
//!   low byte only, `2` = full 16 bits. Spatially sorted runs make most
//!   high bytes repeat, so deltas are short — and the round trip is exact
//!   by construction (bit-equal FP16 words).
//! * **Pointer tables** — neighbor reference tables are counted
//!   incompressible (ratio 1.0): they are already dense 4-byte indices.
//!
//! The store also pre-resolves the *cell → page* mapping the prefetch
//! policies need: central-run pages plus the cell's pointer-table pages.

use crate::math::f16::F16;
use crate::memory::ShardMap;
use crate::scene::gaussian::{Gaussian4D, SH_COEFFS};
use crate::scene::DramLayout;

/// FP16 words per stored record.
pub(crate) fn words_per_record(dynamic: bool) -> usize {
    let static_words = 3 + 4 + 3 + 1 + 3 * SH_COEFFS;
    if dynamic {
        static_words + 5
    } else {
        static_words
    }
}

/// Serialize one Gaussian into its FP16 storage words (the canonical field
/// order: position, rotation (w,x,y,z), scale, opacity, SH, then the
/// dynamic extension μₜ, σₜ, velocity).
pub(crate) fn record_words(g: &Gaussian4D, dynamic: bool, out: &mut Vec<u16>) {
    out.clear();
    let mut push = |v: f32| out.push(F16::from_f32(v).0);
    push(g.mu.x);
    push(g.mu.y);
    push(g.mu.z);
    push(g.rot.w);
    push(g.rot.x);
    push(g.rot.y);
    push(g.rot.z);
    push(g.scale.x);
    push(g.scale.y);
    push(g.scale.z);
    push(g.opacity);
    for c in &g.sh {
        push(c.x);
        push(c.y);
        push(c.z);
    }
    if dynamic {
        push(g.mu_t);
        push(g.sigma_t);
        push(g.velocity.x);
        push(g.velocity.y);
        push(g.velocity.z);
    }
}

/// Rebuild a Gaussian from its FP16 storage words (exact inverse of
/// [`record_words`] for FP16-quantized inputs).
pub(crate) fn gaussian_from_words(w: &[u16], dynamic: bool) -> Gaussian4D {
    use crate::math::{Quat, Vec3};
    let f = |i: usize| F16(w[i]).to_f32();
    let mut sh = [Vec3::ZERO; SH_COEFFS];
    for (k, c) in sh.iter_mut().enumerate() {
        *c = Vec3::new(f(11 + 3 * k), f(12 + 3 * k), f(13 + 3 * k));
    }
    let base = 11 + 3 * SH_COEFFS;
    Gaussian4D {
        mu: Vec3::new(f(0), f(1), f(2)),
        rot: Quat::new(f(3), f(4), f(5), f(6)),
        scale: Vec3::new(f(7), f(8), f(9)),
        opacity: f(10),
        sh,
        mu_t: if dynamic { f(base) } else { 0.0 },
        sigma_t: if dynamic { f(base + 1) } else { f32::INFINITY },
        velocity: if dynamic {
            Vec3::new(f(base + 2), f(base + 3), f(base + 4))
        } else {
            Vec3::ZERO
        },
    }
}

/// Append one record's XOR-delta encoding against `prev` to `out`,
/// returning the encoded byte count. `prev` is updated to this record's
/// words.
pub(crate) fn encode_record(words: &[u16], prev: &mut [u16], out: &mut Vec<u8>) -> usize {
    debug_assert_eq!(words.len(), prev.len());
    let header_bytes = (words.len() * 2).div_ceil(8);
    let header_at = out.len();
    out.resize(header_at + header_bytes, 0u8);
    for (i, (&w, p)) in words.iter().zip(prev.iter_mut()).enumerate() {
        let d = w ^ *p;
        *p = w;
        let code: u8 = if d == 0 {
            0
        } else if d <= 0xFF {
            out.push(d as u8);
            1
        } else {
            out.extend_from_slice(&d.to_le_bytes());
            2
        };
        out[header_at + i / 4] |= code << ((i % 4) * 2);
    }
    out.len() - header_at
}

/// Decode one record from `bytes`, XORing deltas into `prev` (which then
/// holds the record's words). Returns the number of bytes consumed.
pub(crate) fn decode_record(bytes: &[u8], prev: &mut [u16]) -> usize {
    let header_bytes = (prev.len() * 2).div_ceil(8);
    let mut cursor = header_bytes;
    for (i, p) in prev.iter_mut().enumerate() {
        let code = (bytes[i / 4] >> ((i % 4) * 2)) & 0b11;
        let d: u16 = match code {
            0 => 0,
            1 => {
                let b = bytes[cursor] as u16;
                cursor += 1;
                b
            }
            _ => {
                let d = u16::from_le_bytes([bytes[cursor], bytes[cursor + 1]]);
                cursor += 2;
                d
            }
        };
        *p ^= d;
    }
    cursor
}

/// The compressed backing store behind the residency layer: per-page
/// compressed sizes over the scene's DRAM span, per-cell encoded record
/// runs, and the cell → page mapping used by prefetch.
#[derive(Debug)]
pub struct CompressedStore {
    /// Page partition of the scene span (row-aligned, like channel shards
    /// but independent of them).
    pages: ShardMap,
    /// Compressed bytes attributed to each page.
    page_bytes: Vec<u64>,
    /// Uncompressed span (records + pointer tables).
    span_bytes: u64,
    /// Total compressed footprint.
    total_compressed: u64,
    /// Encoded record run per cell (delta chain restarts at each cell).
    cell_blobs: Vec<Vec<u8>>,
    /// Record count per cell.
    cell_records: Vec<usize>,
    /// Sorted, deduplicated pages each cell touches (central run +
    /// pointer table).
    cell_pages: Vec<Vec<u32>>,
    dynamic: bool,
}

impl CompressedStore {
    /// Build the store over a scene's FP16-quantized records and its DRAM
    /// layout. `n_pages` is the residency page count, `row_align` the DRAM
    /// row size (page boundaries stay row-aligned so fills stripe cleanly).
    pub fn build(
        quantized: &[Gaussian4D],
        dynamic: bool,
        layout: &DramLayout,
        n_pages: usize,
        row_align: u64,
    ) -> CompressedStore {
        let span = layout.total_span_bytes();
        let pages = ShardMap::build(span.max(1), n_pages, row_align);
        let mut page_bytes = vec![0u64; pages.shards];
        let n_words = words_per_record(dynamic);
        let stride = layout.bytes_per_gaussian.max(1);

        let n_cells = layout.cell_ranges.len();
        let mut cell_blobs = Vec::with_capacity(n_cells);
        let mut cell_records = Vec::with_capacity(n_cells);
        let mut cell_pages = Vec::with_capacity(n_cells);
        let mut total_compressed = 0u64;
        let mut words = Vec::with_capacity(n_words);
        let mut prev = vec![0u16; n_words];

        for ci in 0..n_cells {
            let (start, end) = layout.cell_ranges[ci];
            let i0 = (start / stride) as usize;
            let i1 = (end / stride) as usize;
            let mut blob = Vec::new();
            prev.fill(0);
            for &gi in &layout.order[i0..i1] {
                record_words(&quantized[gi as usize], dynamic, &mut words);
                let encoded = encode_record(&words, &mut prev, &mut blob) as u64;
                let page = pages.shard_of(layout.addr[gi as usize]);
                page_bytes[page] += encoded;
                total_compressed += encoded;
            }
            cell_records.push(i1 - i0);
            cell_blobs.push(blob);

            // Pointer tables are stored as-is (incompressible): attribute
            // their exact byte overlap to each page they cross.
            let (ps, pe) = layout.pointer_table_range(ci);
            total_compressed += pe - ps;
            pages.split(ps, pe - ps, |page, _, bytes| {
                page_bytes[page] += bytes;
            });

            // Cell → page mapping: central run plus pointer table.
            let mut touched: Vec<u32> = Vec::new();
            let mut collect = |a: u64, b: u64| {
                if b > a {
                    for p in pages.shard_of(a)..=pages.shard_of(b - 1) {
                        touched.push(p as u32);
                    }
                }
            };
            collect(start, end);
            collect(ps, pe);
            touched.sort_unstable();
            touched.dedup();
            cell_pages.push(touched);
        }

        CompressedStore {
            pages,
            page_bytes,
            span_bytes: span,
            total_compressed,
            cell_blobs,
            cell_records,
            cell_pages,
            dynamic,
        }
    }

    /// Number of residency pages over the span.
    pub fn n_pages(&self) -> usize {
        self.pages.shards
    }

    /// Uncompressed page size (last page may cover less of the span).
    pub fn page_size(&self) -> u64 {
        self.pages.shard_bytes
    }

    /// Uncompressed scene span (records + pointer tables).
    pub fn span_bytes(&self) -> u64 {
        self.span_bytes
    }

    /// Total compressed footprint.
    pub fn total_compressed_bytes(&self) -> u64 {
        self.total_compressed
    }

    /// Uncompressed-to-compressed ratio (≥ 1 in practice; 1.0 on an empty
    /// store).
    pub fn compression_ratio(&self) -> f64 {
        if self.total_compressed == 0 {
            1.0
        } else {
            self.span_bytes as f64 / self.total_compressed as f64
        }
    }

    /// Page index owning byte address `addr` (clamped like `ShardMap`).
    pub fn page_of(&self, addr: u64) -> usize {
        self.pages.shard_of(addr)
    }

    /// Inclusive page index range touched by `[addr, addr + bytes)`.
    pub fn page_range(&self, addr: u64, bytes: u64) -> (usize, usize) {
        let last = addr + bytes.max(1) - 1;
        (self.pages.shard_of(addr), self.pages.shard_of(last))
    }

    /// Uncompressed byte span of a page, clamped to the scene span.
    pub fn page_span(&self, page: usize) -> (u64, u64) {
        let (s, e) = self.pages.shard_range(page);
        (s.min(self.span_bytes), e.min(self.span_bytes))
    }

    /// Compressed bytes attributed to a page (drives decode cost and the
    /// cost-aware eviction tie-break).
    pub fn page_compressed_bytes(&self, page: usize) -> u64 {
        self.page_bytes[page]
    }

    /// Pages cell `ci` touches (central run + pointer table), sorted.
    pub fn cell_pages(&self, ci: usize) -> &[u32] {
        &self.cell_pages[ci]
    }

    /// Decode cell `ci`'s record run back into Gaussians — bit-exact
    /// against the FP16-quantized inputs the store was built from.
    pub fn decode_cell(&self, ci: usize) -> Vec<Gaussian4D> {
        let n_words = words_per_record(self.dynamic);
        let blob = &self.cell_blobs[ci];
        let mut prev = vec![0u16; n_words];
        let mut out = Vec::with_capacity(self.cell_records[ci]);
        let mut cursor = 0usize;
        for _ in 0..self.cell_records[ci] {
            cursor += decode_record(&blob[cursor..], &mut prev);
            out.push(gaussian_from_words(&prev, self.dynamic));
        }
        debug_assert_eq!(cursor, blob.len());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::culling::{GridConfig, GridPartition};
    use crate::scene::synth::{SceneKind, SynthParams};
    use crate::scene::Scene;

    fn build_store(kind: SceneKind, n: usize) -> (Scene, DramLayout, CompressedStore) {
        let scene = SynthParams::new(kind, n).generate();
        let grid = GridPartition::build(
            &scene,
            if scene.dynamic { GridConfig::new(4) } else { GridConfig::static_scene(4) },
        );
        let layout = DramLayout::build(&scene, &grid);
        let quantized: Vec<Gaussian4D> =
            scene.gaussians.iter().map(|g| g.quantized_fp16()).collect();
        let store = CompressedStore::build(&quantized, scene.dynamic, &layout, 64, 2048);
        (scene, layout, store)
    }

    #[test]
    fn record_codec_round_trips_bit_exactly() {
        for kind in [SceneKind::DynamicLarge, SceneKind::StaticLarge] {
            let (scene, layout, store) = build_store(kind, 800);
            let stride = layout.bytes_per_gaussian;
            for ci in 0..layout.cell_ranges.len() {
                let (s, e) = layout.cell_ranges[ci];
                let decoded = store.decode_cell(ci);
                let run = &layout.order[(s / stride) as usize..(e / stride) as usize];
                assert_eq!(decoded.len(), run.len());
                for (&gi, got) in run.iter().zip(&decoded) {
                    let want = scene.gaussians[gi as usize].quantized_fp16();
                    let mut ww = Vec::new();
                    let mut gw = Vec::new();
                    record_words(&want, scene.dynamic, &mut ww);
                    record_words(got, scene.dynamic, &mut gw);
                    assert_eq!(ww, gw, "cell {ci} gaussian {gi} round-trip mismatch");
                }
            }
        }
    }

    #[test]
    fn delta_coding_compresses_sorted_runs() {
        let (_, layout, store) = build_store(SceneKind::DynamicLarge, 2000);
        assert!(store.total_compressed_bytes() < layout.total_span_bytes());
        assert!(
            store.compression_ratio() > 1.2,
            "ratio {} too low for delta-coded FP16 records",
            store.compression_ratio()
        );
    }

    #[test]
    fn page_accounting_is_consistent() {
        let (_, layout, store) = build_store(SceneKind::DynamicLarge, 1500);
        let per_page: u64 = (0..store.n_pages()).map(|p| store.page_compressed_bytes(p)).sum();
        assert_eq!(per_page, store.total_compressed_bytes());
        assert_eq!(store.span_bytes(), layout.total_span_bytes());
        // Page spans tile the scene span without gaps.
        let mut cursor = 0u64;
        for p in 0..store.n_pages() {
            let (s, e) = store.page_span(p);
            if s >= store.span_bytes() {
                break;
            }
            assert_eq!(s, cursor);
            cursor = e;
        }
        assert_eq!(cursor, store.span_bytes());
        // Every cell's pages are valid indices.
        for ci in 0..layout.cell_ranges.len() {
            for &p in store.cell_pages(ci) {
                assert!((p as usize) < store.n_pages());
            }
        }
    }

    #[test]
    fn zero_and_single_word_deltas_take_the_short_paths() {
        let mut prev = vec![0u16; 4];
        let mut out = Vec::new();
        // First record vs zero: all full words.
        let n = encode_record(&[0x1234, 0x00AB, 0, 0x8000], &mut prev, &mut out);
        // header (1 byte) + 2 + 1 + 0 + 2 payload bytes.
        assert_eq!(n, 6);
        // Identical record: header only, all-zero codes.
        let n2 = encode_record(&[0x1234, 0x00AB, 0, 0x8000], &mut prev, &mut out);
        assert_eq!(n2, 1);
        // Decode both against a fresh chain.
        let mut chain = vec![0u16; 4];
        let used = decode_record(&out, &mut chain);
        assert_eq!(chain, vec![0x1234, 0x00AB, 0, 0x8000]);
        let used2 = decode_record(&out[used..], &mut chain);
        assert_eq!(chain, vec![0x1234, 0x00AB, 0, 0x8000]);
        assert_eq!(used + used2, out.len());
    }
}
