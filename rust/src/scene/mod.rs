//! 4D Gaussian-splatting scenes: the primitive representation (§2.1 of the
//! paper), deterministic synthetic large-scale scene generators (the
//! stand-ins for Neural-3D-Video / Tanks-and-Temples captures — see
//! DESIGN.md §2), binary scene I/O, and the DRAM placement layout used by
//! DR-FC.

pub mod compressed;
pub mod gaussian;
pub mod io;
pub mod layout;
pub mod synth;
pub mod temporal;

pub use compressed::CompressedStore;
pub use gaussian::{Gaussian4D, SH_COEFFS};
pub use layout::DramLayout;
pub use synth::{SceneKind, SynthParams};
pub use temporal::{TemporalStream, UpdateFrameStats};

use crate::math::Aabb;

/// A complete scene: primitives + metadata.
#[derive(Debug, Clone)]
pub struct Scene {
    pub name: String,
    pub gaussians: Vec<Gaussian4D>,
    /// Whether any primitive carries temporal extent/motion.
    pub dynamic: bool,
    /// Scene time span (0..=1 for static).
    pub time_span: (f32, f32),
}

impl Scene {
    pub fn new(name: impl Into<String>, gaussians: Vec<Gaussian4D>, dynamic: bool) -> Scene {
        Scene {
            name: name.into(),
            gaussians,
            dynamic,
            time_span: (0.0, 1.0),
        }
    }

    pub fn len(&self) -> usize {
        self.gaussians.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gaussians.is_empty()
    }

    /// Spatial bounds of all means (not extents).
    pub fn bounds(&self) -> Aabb {
        let mut b = Aabb::EMPTY;
        for g in &self.gaussians {
            b.expand(g.mu);
        }
        b
    }

    /// Bytes per Gaussian in FP16 DRAM storage (see [`Gaussian4D::dram_bytes`]).
    pub fn dram_bytes(&self) -> u64 {
        self.gaussians.len() as u64 * Gaussian4D::dram_bytes(self.dynamic) as u64
    }
}
