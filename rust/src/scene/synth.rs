//! Deterministic synthetic large-scale scenes.
//!
//! Stand-ins for the paper's datasets (DESIGN.md §2):
//!
//! * [`SceneKind::StaticLarge`] ≈ Tanks & Temples: a courtyard-scale static
//!   capture — ground plane, a central structure, surrounding walls, and
//!   scattered clutter, with anisotropic Gaussians and a near-field-dense
//!   depth profile.
//! * [`SceneKind::DynamicLarge`] ≈ Neural 3D Video: the same static shell
//!   (≈ 65 %) plus dynamic actors — moving clusters whose primitives carry
//!   temporal means spread over the clip, finite temporal extents, and
//!   coherent velocities.
//!
//! Everything is generated from a single seed; the experiments only depend
//! on the *statistics* (density, footprints, depth skew, temporal spread),
//! which these generators expose as tunable [`SynthParams`].

use super::gaussian::{Gaussian4D, SH_COEFFS};
use super::Scene;
use crate::math::{Quat, Vec3};
use crate::util::Rng;

/// Which dataset stand-in to synthesize.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SceneKind {
    /// Large-scale real-world *static* scene (Tanks & Temples class).
    StaticLarge,
    /// Large-scale real-world *dynamic* scene (Neural 3D Video class).
    DynamicLarge,
}

impl SceneKind {
    pub fn label(self) -> &'static str {
        match self {
            SceneKind::StaticLarge => "static-large",
            SceneKind::DynamicLarge => "dynamic-large",
        }
    }
}

/// Generator parameters (defaults sized for experiments; scale `n_gaussians`
/// down for unit tests).
#[derive(Debug, Clone)]
pub struct SynthParams {
    pub kind: SceneKind,
    pub n_gaussians: usize,
    pub seed: u64,
    /// Scene half-extent in world units (courtyard ≈ 30 m half-width).
    pub half_extent: f32,
    /// Fraction of primitives in the dynamic foreground (dynamic scenes).
    pub dynamic_fraction: f32,
    /// Number of moving actor clusters.
    pub n_actors: usize,
    /// Scene clip time span.
    pub time_span: (f32, f32),
}

impl SynthParams {
    pub fn new(kind: SceneKind, n_gaussians: usize) -> SynthParams {
        SynthParams {
            kind,
            n_gaussians,
            seed: 0xC1A0_5CEA,
            half_extent: 30.0,
            dynamic_fraction: 0.35,
            n_actors: 6,
            time_span: (0.0, 1.0),
        }
    }

    pub fn with_seed(mut self, seed: u64) -> SynthParams {
        self.seed = seed;
        self
    }

    /// Experiment-scale defaults: 1.0 M static / 2.0 M dynamic primitives
    /// (DESIGN.md §7). Benches that need faster turnaround pass a divisor.
    pub fn paper_scale(kind: SceneKind) -> SynthParams {
        match kind {
            SceneKind::StaticLarge => SynthParams::new(kind, 1_000_000),
            SceneKind::DynamicLarge => SynthParams::new(kind, 2_000_000),
        }
    }

    pub fn generate(&self) -> Scene {
        let mut rng = Rng::new(self.seed);
        let mut gs = Vec::with_capacity(self.n_gaussians);
        let dynamic = self.kind == SceneKind::DynamicLarge;

        let n_dynamic = if dynamic {
            (self.n_gaussians as f32 * self.dynamic_fraction) as usize
        } else {
            0
        };
        let n_static = self.n_gaussians - n_dynamic;

        self.gen_static_shell(&mut rng, n_static, &mut gs);
        if dynamic {
            // Trained 4DGS represents *everything* — background included —
            // with finite temporal supports: the fit re-expresses static
            // content across overlapping time windows, which is exactly why
            // "the temporal dimension substantially expands the parameter
            // count" (paper §1) and why DR-FC's 1-D temporal grids prune
            // effectively. Give the background primitives uniformly spread
            // temporal means and window-scale extents (zero velocity).
            let (t0, t1) = self.time_span;
            let span = (t1 - t0).max(1e-6);
            for g in gs.iter_mut() {
                g.mu_t = rng.range_f32(t0, t1);
                g.sigma_t = span * rng.range_f32(0.01, 0.05);
            }
        }
        if n_dynamic > 0 {
            self.gen_actors(&mut rng, n_dynamic, &mut gs);
        }

        let mut scene = Scene::new(
            format!("{}-{}k", self.kind.label(), self.n_gaussians / 1000),
            gs,
            dynamic,
        );
        scene.time_span = self.time_span;
        scene
    }

    /// Static background: ground + central structure + perimeter walls +
    /// scattered clutter. Shares: 30/30/25/15 %.
    fn gen_static_shell(&self, rng: &mut Rng, n: usize, out: &mut Vec<Gaussian4D>) {
        let he = self.half_extent;
        let n_ground = n * 30 / 100;
        let n_struct = n * 30 / 100;
        let n_walls = n * 25 / 100;
        let n_clutter = n - n_ground - n_struct - n_walls;

        for _ in 0..n_ground {
            // Flat disks on the ground plane, denser near the center
            // (log-normal radial distance ⇒ skewed depth from any orbiting
            // camera, matching captured-scene statistics).
            let r = rng.log_normal(1.8, 0.9).min(he * 1.4);
            let theta = rng.range_f32(0.0, std::f32::consts::TAU);
            let mu = Vec3::new(r * theta.cos(), rng.range_f32(-0.05, 0.15), r * theta.sin());
            let scale = Vec3::new(
                rng.log_normal(-2.2, 0.5),
                rng.log_normal(-3.2, 0.4), // thin vertically
                rng.log_normal(-2.2, 0.5),
            );
            let color = ground_palette(rng);
            out.push(self.make_static(rng, mu, scale, color));
        }

        for _ in 0..n_struct {
            // Central structure: a box-ish cluster of larger Gaussians.
            let mu = Vec3::new(
                rng.normal_ms(0.0, 3.0),
                rng.range_f32(0.0, 9.0),
                rng.normal_ms(0.0, 3.0),
            );
            let scale = Vec3::new(
                rng.log_normal(-2.0, 0.5),
                rng.log_normal(-2.0, 0.5),
                rng.log_normal(-2.0, 0.5),
            );
            let color = stone_palette(rng);
            out.push(self.make_static(rng, mu, scale, color));
        }

        for i in 0..n_walls {
            // Perimeter + interior columns: tall thin vertical Gaussians —
            // the ATG motivation case (Challenge 2) of primitives spanning
            // many tiles in a column. Captured scenes are full of such
            // edge-aligned anisotropic splats. Axis-aligned vertical (no
            // random rotation) like fitted wall/edge primitives.
            let (r, y_extent) = if i % 3 == 0 {
                (he * rng.range_f32(0.25, 0.6), 8.0) // interior columns
            } else {
                (he * rng.range_f32(0.8, 1.0), 6.0) // perimeter ring
            };
            let theta = rng.range_f32(0.0, std::f32::consts::TAU);
            let mu = Vec3::new(r * theta.cos(), rng.range_f32(0.0, y_extent), r * theta.sin());
            let scale = Vec3::new(
                rng.log_normal(-2.9, 0.3),
                rng.log_normal(-0.6, 0.4), // tall: σ_y ≈ 0.4–0.9
                rng.log_normal(-2.9, 0.3),
            );
            let color = stone_palette(rng);
            let mut g = self.make_static(rng, mu, scale, color);
            g.rot = Quat::IDENTITY; // keep the long axis vertical
            out.push(g);
        }

        for _ in 0..n_clutter {
            let mu = Vec3::new(
                rng.range_f32(-he, he),
                rng.range_f32(0.0, 4.0),
                rng.range_f32(-he, he),
            );
            let s = rng.log_normal(-2.4, 0.7);
            let color = any_palette(rng);
            out.push(self.make_static(rng, mu, Vec3::splat(s), color));
        }
    }

    /// Dynamic actors: `n_actors` clusters moving through the scene, each
    /// primitive a short-lived 4D Gaussian along the cluster path — the 4DGS
    /// representation of motion (temporal slicing re-creates the actor at
    /// each t from the primitives whose μₜ ≈ t).
    fn gen_actors(&self, rng: &mut Rng, n: usize, out: &mut Vec<Gaussian4D>) {
        let (t0, t1) = self.time_span;
        let per_actor = n / self.n_actors.max(1);
        for a in 0..self.n_actors {
            let mut arng = rng.fork(a as u64 + 1);
            // Path: start/end points within the inner court.
            let start = Vec3::new(
                arng.range_f32(-10.0, 10.0),
                arng.range_f32(0.5, 2.0),
                arng.range_f32(-10.0, 10.0),
            );
            let end = Vec3::new(
                arng.range_f32(-10.0, 10.0),
                arng.range_f32(0.5, 2.0),
                arng.range_f32(-10.0, 10.0),
            );
            let path_vel = (end - start) * (1.0 / (t1 - t0).max(1e-6));
            let count = if a + 1 == self.n_actors {
                n - per_actor * (self.n_actors - 1)
            } else {
                per_actor
            };
            for _ in 0..count {
                let mu_t = arng.range_f32(t0, t1);
                let body = Vec3::new(
                    arng.normal_ms(0.0, 0.5),
                    arng.normal_ms(0.9, 0.5),
                    arng.normal_ms(0.0, 0.5),
                );
                let center = start + path_vel * (mu_t - t0);
                let color = actor_palette(&mut arng, a);
                let scale = Vec3::new(
                    arng.log_normal(-2.6, 0.5),
                    arng.log_normal(-2.6, 0.5),
                    arng.log_normal(-2.6, 0.5),
                );
                let mut g = self.make_static(&mut arng, center + body, scale, color);
                g.mu_t = mu_t;
                // Short temporal support: each primitive covers a slice of
                // the clip (≈ 2–6 % of the span), as trained 4DGS exhibits.
                g.sigma_t = (t1 - t0) * arng.range_f32(0.02, 0.06);
                // Local velocity = path velocity + limb jitter.
                g.velocity = path_vel
                    + Vec3::new(
                        arng.normal_ms(0.0, 0.4),
                        arng.normal_ms(0.0, 0.3),
                        arng.normal_ms(0.0, 0.4),
                    );
                out.push(g);
            }
        }
    }

    fn make_static(&self, rng: &mut Rng, mu: Vec3, scale: Vec3, color: Vec3) -> Gaussian4D {
        let axis = Vec3::new(rng.normal(), rng.normal(), rng.normal());
        let rot = if axis.length() > 1e-6 {
            Quat::from_axis_angle(axis, rng.range_f32(0.0, std::f32::consts::TAU))
        } else {
            Quat::IDENTITY
        };
        let mut sh = [Vec3::ZERO; SH_COEFFS];
        sh[0] = (color - Vec3::splat(0.5)) * (1.0 / 0.282_094_8);
        // Mild view dependence on degree 1.
        for k in 1..4 {
            sh[k] = Vec3::new(rng.normal(), rng.normal(), rng.normal()) * 0.03;
        }
        Gaussian4D {
            mu,
            rot,
            scale,
            mu_t: 0.0,
            sigma_t: f32::INFINITY,
            velocity: Vec3::ZERO,
            opacity: rng.range_f32(0.4, 0.98),
            sh,
        }
    }
}

fn ground_palette(rng: &mut Rng) -> Vec3 {
    let g = rng.range_f32(0.25, 0.45);
    Vec3::new(g * 1.05, g, g * 0.8)
}

fn stone_palette(rng: &mut Rng) -> Vec3 {
    let g = rng.range_f32(0.45, 0.75);
    Vec3::new(g, g * 0.97, g * 0.9)
}

fn any_palette(rng: &mut Rng) -> Vec3 {
    Vec3::new(rng.f32(), rng.f32(), rng.f32())
}

fn actor_palette(rng: &mut Rng, idx: usize) -> Vec3 {
    // Distinct hue per actor with small per-primitive variation.
    let base = [
        Vec3::new(0.8, 0.2, 0.2),
        Vec3::new(0.2, 0.6, 0.9),
        Vec3::new(0.9, 0.7, 0.1),
        Vec3::new(0.3, 0.8, 0.3),
        Vec3::new(0.7, 0.3, 0.8),
        Vec3::new(0.9, 0.5, 0.2),
    ][idx % 6];
    base + Vec3::new(rng.normal(), rng.normal(), rng.normal()) * 0.05
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_scene_has_requested_count_and_no_motion() {
        let s = SynthParams::new(SceneKind::StaticLarge, 5000).generate();
        assert_eq!(s.len(), 5000);
        assert!(!s.dynamic);
        assert!(s.gaussians.iter().all(|g| g.is_static()));
    }

    #[test]
    fn dynamic_scene_fully_temporal_with_moving_actors() {
        let p = SynthParams::new(SceneKind::DynamicLarge, 10_000);
        let s = p.generate();
        assert_eq!(s.len(), 10_000);
        assert!(s.dynamic);
        // 4DGS: every primitive carries finite temporal support.
        assert!(s.gaussians.iter().all(|g| !g.is_static()));
        // Actors move; background does not.
        let movers = s
            .gaussians
            .iter()
            .filter(|g| g.velocity.length() > 1e-6)
            .count();
        let expect = (10_000.0 * p.dynamic_fraction) as usize;
        assert_eq!(movers, expect);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SynthParams::new(SceneKind::StaticLarge, 1000).generate();
        let b = SynthParams::new(SceneKind::StaticLarge, 1000).generate();
        assert_eq!(a.gaussians[123], b.gaussians[123]);
        let c = SynthParams::new(SceneKind::StaticLarge, 1000)
            .with_seed(99)
            .generate();
        assert_ne!(a.gaussians[123], c.gaussians[123]);
    }

    #[test]
    fn temporal_means_span_clip() {
        let s = SynthParams::new(SceneKind::DynamicLarge, 20_000).generate();
        let ts: Vec<f32> = s
            .gaussians
            .iter()
            .filter(|g| !g.is_static())
            .map(|g| g.mu_t)
            .collect();
        let min = ts.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = ts.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(min < 0.1, "min μt {min}");
        assert!(max > 0.9, "max μt {max}");
    }

    #[test]
    fn scene_bounds_reasonable() {
        let p = SynthParams::new(SceneKind::StaticLarge, 5000);
        let s = p.generate();
        let b = s.bounds();
        assert!(b.extent().x > p.half_extent); // walls reach the perimeter
        assert!(b.extent().y < 30.0); // but it is a ground-hugging scene
    }

    #[test]
    fn opacities_and_scales_valid() {
        let s = SynthParams::new(SceneKind::DynamicLarge, 5000).generate();
        for g in &s.gaussians {
            assert!(g.opacity > 0.0 && g.opacity <= 1.0);
            assert!(g.scale.x > 0.0 && g.scale.y > 0.0 && g.scale.z > 0.0);
            if !g.is_static() {
                assert!(g.sigma_t > 0.0);
            }
        }
    }
}
