//! DRAM placement of Gaussian parameters (paper §3.1, Fig. 5(b)).
//!
//! Gaussians are stored **contiguously per central grid cell** so a visible
//! cell is one burst-friendly DRAM range; cells keep only `(start, end)`
//! addresses on-chip. Gaussians that span into neighbor cells are placed at
//! the *front* of their central cell's run and referenced from neighbors by
//! pointer, so neighbor-driven fetches touch a compact prefix.

use super::Scene;
use crate::culling::grid::GridPartition;
use crate::scene::gaussian::Gaussian4D;

/// Byte-level DRAM layout of a scene under a given grid partition.
#[derive(Debug, Clone)]
pub struct DramLayout {
    /// Gaussian indices in DRAM order.
    pub order: Vec<u32>,
    /// Byte address of each Gaussian (indexed by original Gaussian index).
    pub addr: Vec<u64>,
    /// Per-cell `(start, end)` byte range (end exclusive); the only grid
    /// metadata the on-chip buffer must hold.
    pub cell_ranges: Vec<(u64, u64)>,
    /// Per-cell pointer table: Gaussians referenced from this cell but
    /// stored centrally elsewhere (original indices).
    pub cell_refs: Vec<Vec<u32>>,
    /// Record stride in bytes.
    pub bytes_per_gaussian: u64,
    /// DRAM start address of each cell's pointer table (tables are laid out
    /// contiguously after the parameter data).
    ptr_table_start: Vec<u64>,
}

impl DramLayout {
    /// Build the layout. Spanning Gaussians (those with neighbor references
    /// anywhere) are sorted to the front of their central cell's run.
    pub fn build(scene: &Scene, grid: &GridPartition) -> DramLayout {
        let stride = Gaussian4D::dram_bytes(scene.dynamic) as u64;
        let n = scene.len();

        // Mark which Gaussians are referenced by some non-central cell.
        let mut spanning = vec![false; n];
        for cell in &grid.cells {
            for &gi in &cell.refs {
                spanning[gi as usize] = true;
            }
        }

        let mut order = Vec::with_capacity(n);
        let mut addr = vec![0u64; n];
        let mut cell_ranges = Vec::with_capacity(grid.cells.len());
        let mut cursor = 0u64;
        for cell in &grid.cells {
            let start = cursor;
            // Spanning prefix first (paper: "Gaussians spanning adjacent
            // cubic grids are stored contiguously ... for efficient access
            // when referenced from neighboring grids").
            for pass in [true, false] {
                for &gi in &cell.central {
                    if spanning[gi as usize] == pass {
                        addr[gi as usize] = cursor;
                        order.push(gi);
                        cursor += stride;
                    }
                }
            }
            cell_ranges.push((start, cursor));
        }

        let cell_refs: Vec<Vec<u32>> = grid.cells.iter().map(|c| c.refs.clone()).collect();
        // Pointer tables live in DRAM right after the parameter data.
        let mut ptr_table_start = Vec::with_capacity(cell_refs.len());
        let mut ptr_cursor = cursor;
        for refs in &cell_refs {
            ptr_table_start.push(ptr_cursor);
            ptr_cursor += refs.len() as u64 * 4;
        }

        DramLayout {
            order,
            addr,
            cell_ranges,
            cell_refs,
            bytes_per_gaussian: stride,
            ptr_table_start,
        }
    }

    /// Total DRAM footprint in bytes.
    pub fn total_bytes(&self) -> u64 {
        self.order.len() as u64 * self.bytes_per_gaussian
    }

    /// Full DRAM address span of the scene: parameter records plus the
    /// per-cell neighbor pointer tables laid out after them. This is the
    /// span `ScenePrep` hands to [`crate::memory::ShardMap`] so every
    /// address the cull/blend paths can issue maps to a shard.
    pub fn total_span_bytes(&self) -> u64 {
        self.total_bytes() + self.pointer_table_bytes()
    }

    /// On-chip metadata footprint: one `(start, end)` pair per cell for the
    /// central run plus one `(start, count)` pair per cell locating its
    /// pointer table in DRAM. This is the buffer cost the Fig. 9 trade-off
    /// discussion refers to — the pointer tables themselves stay in DRAM
    /// (see [`DramLayout::pointer_table_bytes`]).
    pub fn metadata_bytes(&self) -> u64 {
        self.cell_ranges.len() as u64 * (16 + 8)
    }

    /// DRAM footprint of the per-cell neighbor pointer tables (4 B/pointer).
    pub fn pointer_table_bytes(&self) -> u64 {
        self.cell_refs.iter().map(|r| r.len() as u64 * 4).sum()
    }

    /// DRAM byte range of cell `ci`'s pointer table.
    pub fn pointer_table_range(&self, ci: usize) -> (u64, u64) {
        let start = self.ptr_table_start[ci];
        (start, start + self.cell_refs[ci].len() as u64 * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::culling::grid::{GridConfig, GridPartition};
    use crate::scene::synth::{SceneKind, SynthParams};

    fn build(n: usize, grid_n: usize) -> (Scene, GridPartition, DramLayout) {
        let scene = SynthParams::new(SceneKind::DynamicLarge, n).generate();
        let grid = GridPartition::build(&scene, GridConfig::new(grid_n));
        let layout = DramLayout::build(&scene, &grid);
        (scene, grid, layout)
    }

    #[test]
    fn every_gaussian_placed_exactly_once() {
        let (scene, _, layout) = build(2000, 4);
        assert_eq!(layout.order.len(), scene.len());
        let mut seen = vec![false; scene.len()];
        for &gi in &layout.order {
            assert!(!seen[gi as usize], "duplicate placement of {gi}");
            seen[gi as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn cell_ranges_are_contiguous_and_cover() {
        let (_, grid, layout) = build(2000, 4);
        let mut cursor = 0u64;
        for (i, &(s, e)) in layout.cell_ranges.iter().enumerate() {
            assert_eq!(s, cursor, "cell {i} range must start where previous ended");
            assert!(e >= s);
            let count = grid.cells[i].central.len() as u64;
            assert_eq!(e - s, count * layout.bytes_per_gaussian);
            cursor = e;
        }
        assert_eq!(cursor, layout.total_bytes());
    }

    #[test]
    fn addresses_fall_inside_central_cell_range() {
        let (_, grid, layout) = build(1000, 4);
        for (ci, cell) in grid.cells.iter().enumerate() {
            let (s, e) = layout.cell_ranges[ci];
            for &gi in &cell.central {
                let a = layout.addr[gi as usize];
                assert!(a >= s && a < e, "gaussian {gi} at {a} outside cell [{s},{e})");
            }
        }
    }

    #[test]
    fn spanning_gaussians_form_prefix() {
        let (scene, grid, layout) = build(3000, 4);
        let mut spanning = vec![false; scene.len()];
        for cell in &grid.cells {
            for &gi in &cell.refs {
                spanning[gi as usize] = true;
            }
        }
        for (ci, cell) in grid.cells.iter().enumerate() {
            let (s, _) = layout.cell_ranges[ci];
            // Collect cell members in address order; spanning must come first.
            let mut members: Vec<u32> = cell.central.clone();
            members.sort_by_key(|&gi| layout.addr[gi as usize]);
            let mut seen_non_spanning = false;
            for &gi in &members {
                if spanning[gi as usize] {
                    assert!(
                        !seen_non_spanning,
                        "cell {ci}: spanning gaussian {gi} after non-spanning (start {s})"
                    );
                } else {
                    seen_non_spanning = true;
                }
            }
        }
    }

    #[test]
    fn metadata_far_smaller_than_data() {
        let (_, _, layout) = build(5000, 4);
        assert!(layout.metadata_bytes() * 10 < layout.total_bytes());
    }

    #[test]
    fn span_covers_params_and_pointer_tables() {
        let (_, _, layout) = build(3000, 4);
        assert_eq!(
            layout.total_span_bytes(),
            layout.total_bytes() + layout.pointer_table_bytes()
        );
        // Every pointer table lies inside the span.
        for ci in 0..layout.cell_refs.len() {
            let (_, e) = layout.pointer_table_range(ci);
            assert!(e <= layout.total_span_bytes());
        }
    }
}
