//! Generic HLO-text → PJRT executor (the pattern from
//! /opt/xla-example/load_hlo): parse HLO text, compile on the CPU client,
//! execute with f32 literals, unwrap the tuple outputs.

use anyhow::{Context, Result};
use std::path::Path;
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// One compiled computation bound to a PJRT client.
pub struct HloExecutor {
    exe: PjRtLoadedExecutable,
    pub name: String,
}

impl HloExecutor {
    /// Load + compile an HLO text file on an existing client.
    pub fn load(client: &PjRtClient, path: &Path) -> Result<HloExecutor> {
        let proto = HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(HloExecutor {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().to_string())
                .unwrap_or_default(),
        })
    }

    /// Create the shared CPU client.
    pub fn cpu_client() -> Result<PjRtClient> {
        PjRtClient::cpu().context("creating PJRT CPU client")
    }

    /// Execute with the given inputs; returns the flattened tuple outputs.
    /// (aot.py lowers with `return_tuple=True`, so the single result literal
    /// is always a tuple.)
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let result = self
            .exe
            .execute::<Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(lit.to_tuple()?)
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let expect: i64 = dims.iter().product();
    anyhow::ensure!(
        expect as usize == data.len(),
        "literal shape {:?} needs {} elements, got {}",
        dims,
        expect,
        data.len()
    );
    Ok(Literal::vec1(data).reshape(dims)?)
}

/// Extract a literal's f32 payload.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }
}
