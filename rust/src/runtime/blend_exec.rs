//! Tile-blend executor: runs the AOT-compiled L1 Pallas blend kernel
//! (`artifacts/blend.hlo.txt`) for one 16×16 tile over up to
//! [`BLEND_MAX_G`](super::BLEND_MAX_G) depth-sorted splats.
//!
//! Interface (must match `python/compile/aot.py::lower_blend`):
//! inputs `means[G,2]` (pixel coords relative to the tile origin),
//! `conics[G,3]`, `colors[G,3]`, `alphas[G]` (0 ⇒ padding); output tuple
//! `(rgb[256,3],)` row-major over the tile's 16×16 pixels.

use super::executor::{literal_f32, to_vec_f32, HloExecutor};
use super::BLEND_MAX_G;
use crate::tiles::intersect::Splat2D;
use crate::tiles::TILE_PX;
use anyhow::Result;
use std::path::Path;
use xla::PjRtClient;

/// The compiled blend kernel.
pub struct BlendExecutor {
    exec: HloExecutor,
}

impl BlendExecutor {
    pub fn load(client: &PjRtClient, path: &Path) -> Result<BlendExecutor> {
        Ok(BlendExecutor { exec: HloExecutor::load(client, path)? })
    }

    /// Blend `splats` (already depth-sorted, front first) into the tile with
    /// pixel origin `(x0, y0)`. Splats beyond [`BLEND_MAX_G`] are blended in
    /// consecutive invocations is NOT supported here — callers chunk instead
    /// (chunking changes transmittance state; for the demo path we clamp).
    /// Returns 16×16 RGB rows.
    pub fn blend_tile(
        &self,
        splats: &[Splat2D],
        x0: f32,
        y0: f32,
    ) -> Result<Vec<[f32; 3]>> {
        let g = splats.len().min(BLEND_MAX_G);
        let mut means = vec![0.0f32; BLEND_MAX_G * 2];
        let mut conics = vec![0.0f32; BLEND_MAX_G * 3];
        let mut colors = vec![0.0f32; BLEND_MAX_G * 3];
        let mut alphas = vec![0.0f32; BLEND_MAX_G];
        for (i, s) in splats.iter().take(g).enumerate() {
            means[i * 2] = s.mean.x - x0;
            means[i * 2 + 1] = s.mean.y - y0;
            conics[i * 3] = s.conic[0];
            conics[i * 3 + 1] = s.conic[1];
            conics[i * 3 + 2] = s.conic[2];
            colors[i * 3] = s.color.x;
            colors[i * 3 + 1] = s.color.y;
            colors[i * 3 + 2] = s.color.z;
            alphas[i] = s.alpha_base;
        }

        let outputs = self.exec.run(&[
            literal_f32(&means, &[BLEND_MAX_G as i64, 2])?,
            literal_f32(&conics, &[BLEND_MAX_G as i64, 3])?,
            literal_f32(&colors, &[BLEND_MAX_G as i64, 3])?,
            literal_f32(&alphas, &[BLEND_MAX_G as i64])?,
        ])?;
        let rgb = to_vec_f32(&outputs[0])?;
        anyhow::ensure!(
            rgb.len() == TILE_PX * TILE_PX * 3,
            "blend output size {} != {}",
            rgb.len(),
            TILE_PX * TILE_PX * 3
        );
        Ok(rgb.chunks_exact(3).map(|c| [c[0], c[1], c[2]]).collect())
    }
}

/// Reference cumulative blend in plain Rust with the *same* no-early-exit
/// formulation the vectorized kernel uses — the parity oracle for tests.
pub fn cumulative_blend_reference(
    splats: &[Splat2D],
    x0: f32,
    y0: f32,
) -> Vec<[f32; 3]> {
    let mut out = vec![[0.0f32; 3]; TILE_PX * TILE_PX];
    for py in 0..TILE_PX {
        for px in 0..TILE_PX {
            let (fx, fy) = (x0 + px as f32 + 0.5, y0 + py as f32 + 0.5);
            let mut t = 1.0f32;
            let mut rgb = [0.0f32; 3];
            for s in splats.iter().take(BLEND_MAX_G) {
                let e = crate::tiles::intersect::splat_exponent(s, fx, fy);
                let mut a = (s.alpha_base * e.exp()).min(0.999);
                if a < 1.0 / 255.0 {
                    a = 0.0;
                }
                let w = a * t;
                rgb[0] += w * s.color.x;
                rgb[1] += w * s.color.y;
                rgb[2] += w * s.color.z;
                t *= 1.0 - a;
            }
            out[py * TILE_PX + px] = rgb;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{Vec2, Vec3};
    use crate::runtime::Artifacts;
    use crate::util::Rng;

    fn random_splats(n: usize, seed: u64) -> Vec<Splat2D> {
        let mut rng = Rng::new(seed);
        (0..n as u32)
            .map(|i| Splat2D {
                id: i,
                mean: Vec2::new(rng.range_f32(-4.0, 20.0), rng.range_f32(-4.0, 20.0)),
                conic: {
                    // Positive-definite conic.
                    let a = rng.range_f32(0.01, 0.5);
                    let c = rng.range_f32(0.01, 0.5);
                    let b = rng.range_f32(-0.05, 0.05).min((a * c).sqrt() * 0.8);
                    [a, b, c]
                },
                radius: 10.0,
                rx: 10.0,
                ry: 10.0,
                depth: rng.range_f32(1.0, 50.0),
                alpha_base: rng.range_f32(0.05, 0.95),
                color: Vec3::new(rng.f32(), rng.f32(), rng.f32()),
            })
            .collect()
    }

    #[test]
    fn pjrt_blend_matches_reference() {
        let artifacts = match Artifacts::discover() {
            Ok(a) if a.available() => a,
            _ => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        };
        let client = HloExecutor::cpu_client().unwrap();
        let blend = BlendExecutor::load(&client, &artifacts.blend_hlo()).unwrap();
        let splats = random_splats(40, 7);
        let got = blend.blend_tile(&splats, 0.0, 0.0).unwrap();
        let expect = cumulative_blend_reference(&splats, 0.0, 0.0);
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            for c in 0..3 {
                assert!(
                    (g[c] - e[c]).abs() < 2e-2,
                    "pixel {i} ch {c}: pjrt {} vs rust {}",
                    g[c],
                    e[c]
                );
            }
        }
    }

    #[test]
    fn empty_tile_is_black() {
        let artifacts = match Artifacts::discover() {
            Ok(a) if a.available() => a,
            _ => return,
        };
        let client = HloExecutor::cpu_client().unwrap();
        let blend = BlendExecutor::load(&client, &artifacts.blend_hlo()).unwrap();
        let got = blend.blend_tile(&[], 0.0, 0.0).unwrap();
        assert!(got.iter().all(|px| px.iter().all(|&v| v.abs() < 1e-6)));
    }

    #[test]
    fn reference_blend_front_to_back() {
        let mut splats = random_splats(2, 3);
        splats[0].mean = Vec2::new(8.0, 8.0);
        splats[1].mean = Vec2::new(8.0, 8.0);
        splats[0].alpha_base = 0.9;
        splats[0].color = Vec3::new(1.0, 0.0, 0.0);
        splats[1].color = Vec3::new(0.0, 1.0, 0.0);
        let out = cumulative_blend_reference(&splats, 0.0, 0.0);
        let center = out[8 * TILE_PX + 8];
        assert!(center[0] > center[1], "front red dominates: {center:?}");
    }
}
