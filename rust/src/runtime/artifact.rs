//! Artifact discovery: locates the `artifacts/` directory holding the AOT
//! HLO text files and validates their presence.

use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

/// The compiled-artifact set.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
}

impl Artifacts {
    /// Use an explicit directory.
    pub fn at(dir: impl Into<PathBuf>) -> Artifacts {
        Artifacts { dir: dir.into() }
    }

    /// Locate `artifacts/` relative to the current dir or the repo root
    /// (walks up from cwd; honors `GAUCIM_ARTIFACTS` env).
    pub fn discover() -> Result<Artifacts> {
        if let Ok(dir) = std::env::var("GAUCIM_ARTIFACTS") {
            let p = PathBuf::from(dir);
            if p.is_dir() {
                return Ok(Artifacts::at(p));
            }
            bail!("GAUCIM_ARTIFACTS={} is not a directory", p.display());
        }
        let mut cur = std::env::current_dir()?;
        loop {
            let cand = cur.join("artifacts");
            if cand.is_dir() {
                return Ok(Artifacts::at(cand));
            }
            if !cur.pop() {
                bail!(
                    "artifacts/ not found — run `make artifacts` first \
                     (or set GAUCIM_ARTIFACTS)"
                );
            }
        }
    }

    pub fn preprocess_hlo(&self) -> PathBuf {
        self.dir.join("preprocess.hlo.txt")
    }

    pub fn blend_hlo(&self) -> PathBuf {
        self.dir.join("blend.hlo.txt")
    }

    pub fn exp_lut_hlo(&self) -> PathBuf {
        self.dir.join("exp_lut.hlo.txt")
    }

    /// Check that every artifact exists.
    pub fn validate(&self) -> Result<()> {
        for p in [self.preprocess_hlo(), self.blend_hlo(), self.exp_lut_hlo()] {
            if !p.is_file() {
                bail!("missing artifact {} — run `make artifacts`", p.display());
            }
        }
        Ok(())
    }

    fn exists(p: &Path) -> bool {
        p.is_file()
    }

    /// True when all artifacts are present (non-fatal probe for tests that
    /// skip gracefully when `make artifacts` has not run).
    pub fn available(&self) -> bool {
        Self::exists(&self.preprocess_hlo())
            && Self::exists(&self.blend_hlo())
            && Self::exists(&self.exp_lut_hlo())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paths_join_correctly() {
        let a = Artifacts::at("/tmp/x");
        assert_eq!(a.preprocess_hlo(), PathBuf::from("/tmp/x/preprocess.hlo.txt"));
        assert_eq!(a.blend_hlo(), PathBuf::from("/tmp/x/blend.hlo.txt"));
        assert_eq!(a.exp_lut_hlo(), PathBuf::from("/tmp/x/exp_lut.hlo.txt"));
    }

    #[test]
    fn validate_fails_on_missing() {
        let a = Artifacts::at("/nonexistent-dir-gaucim");
        assert!(a.validate().is_err());
        assert!(!a.available());
    }
}
