//! Preprocess executor: runs the AOT-compiled L2 JAX graph
//! (`artifacts/preprocess.hlo.txt`) — temporal slicing (eq. 5–6),
//! 3D→2D projection (eq. 7–8) and SH color — for a padded chunk of
//! [`PREPROCESS_CHUNK`](super::PREPROCESS_CHUNK) Gaussians.
//!
//! Interface (must match `python/compile/aot.py::lower_preprocess`):
//! inputs `mu[K,3] rot[K,4] scale[K,3] mu_t[K] lam[K] vel[K,3] opa[K]
//! sh[K,27] view[4,4] intr[4](fx,fy,cx,cy) t[1]`;
//! outputs `(mean2[K,2], conic[K,3], depth[K], alpha[K], color[K,3])`,
//! `alpha = 0` marks culled/padding entries.

use super::executor::{literal_f32, to_vec_f32, HloExecutor};
use super::PREPROCESS_CHUNK;
use crate::camera::Camera;
use crate::math::{Vec2, Vec3};
use crate::scene::Gaussian4D;
use crate::tiles::intersect::{Splat2D, ALPHA_CUTOFF};
use anyhow::Result;
use std::path::Path;
use xla::PjRtClient;

/// The compiled preprocess graph.
pub struct PreprocessExecutor {
    exec: HloExecutor,
}

impl PreprocessExecutor {
    pub fn load(client: &PjRtClient, path: &Path) -> Result<PreprocessExecutor> {
        Ok(PreprocessExecutor { exec: HloExecutor::load(client, path)? })
    }

    /// Project up to [`PREPROCESS_CHUNK`] Gaussians at scene time `t`.
    /// Returns splats with `alpha_base ≥` cutoff; ids are `id_base + i`.
    pub fn project_chunk(
        &self,
        gaussians: &[Gaussian4D],
        id_base: u32,
        cam: &Camera,
        t: f32,
    ) -> Result<Vec<Splat2D>> {
        let k = PREPROCESS_CHUNK;
        let n = gaussians.len().min(k);
        let mut mu = vec![0.0f32; k * 3];
        let mut rot = vec![0.0f32; k * 4];
        let mut scale = vec![1e-6f32; k * 3];
        let mut mu_t = vec![0.0f32; k];
        let mut lam = vec![0.0f32; k];
        let mut vel = vec![0.0f32; k * 3];
        let mut opa = vec![0.0f32; k];
        let mut sh = vec![0.0f32; k * 27];
        for (i, g) in gaussians.iter().take(n).enumerate() {
            mu[i * 3..i * 3 + 3].copy_from_slice(&g.mu.to_array());
            rot[i * 4..i * 4 + 4].copy_from_slice(&[g.rot.w, g.rot.x, g.rot.y, g.rot.z]);
            scale[i * 3..i * 3 + 3].copy_from_slice(&g.scale.to_array());
            mu_t[i] = g.mu_t;
            lam[i] = g.lambda();
            vel[i * 3..i * 3 + 3].copy_from_slice(&g.velocity.to_array());
            opa[i] = g.opacity;
            for (c, coeff) in g.sh.iter().enumerate() {
                sh[i * 27 + c * 3] = coeff.x;
                sh[i * 27 + c * 3 + 1] = coeff.y;
                sh[i * 27 + c * 3 + 2] = coeff.z;
            }
        }

        let mut view = vec![0.0f32; 16];
        for r in 0..4 {
            for c in 0..4 {
                view[r * 4 + c] = cam.view.m[r][c];
            }
        }
        let intr = [
            cam.intrinsics.fx,
            cam.intrinsics.fy,
            cam.intrinsics.cx,
            cam.intrinsics.cy,
        ];

        let ki = k as i64;
        let outputs = self.exec.run(&[
            literal_f32(&mu, &[ki, 3])?,
            literal_f32(&rot, &[ki, 4])?,
            literal_f32(&scale, &[ki, 3])?,
            literal_f32(&mu_t, &[ki])?,
            literal_f32(&lam, &[ki])?,
            literal_f32(&vel, &[ki, 3])?,
            literal_f32(&opa, &[ki])?,
            literal_f32(&sh, &[ki, 27])?,
            literal_f32(&view, &[4, 4])?,
            literal_f32(&intr, &[4])?,
            literal_f32(&[t], &[1])?,
        ])?;

        let mean2 = to_vec_f32(&outputs[0])?;
        let conic = to_vec_f32(&outputs[1])?;
        let depth = to_vec_f32(&outputs[2])?;
        let alpha = to_vec_f32(&outputs[3])?;
        let color = to_vec_f32(&outputs[4])?;

        let mut out = Vec::new();
        for i in 0..n {
            if alpha[i] < ALPHA_CUTOFF {
                continue;
            }
            let a = conic[i * 3];
            let b = conic[i * 3 + 1];
            let c = conic[i * 3 + 2];
            // Radius from conic eigenvalues (conic = inverse covariance).
            let det = (a * c - b * b).max(1e-12);
            let (ca, cb, cc) = (c / det, -b / det, a / det);
            let mid = 0.5 * (ca + cc);
            let disc = (mid * mid - (ca * cc - cb * cb)).max(0.0).sqrt();
            let radius = 3.0 * (mid + disc).sqrt();
            out.push(Splat2D {
                id: id_base + i as u32,
                mean: Vec2::new(mean2[i * 2], mean2[i * 2 + 1]),
                conic: [a, b, c],
                radius,
                rx: 3.0 * ca.max(0.0).sqrt(),
                ry: 3.0 * cc.max(0.0).sqrt(),
                depth: depth[i],
                alpha_base: alpha[i],
                color: Vec3::new(color[i * 3], color[i * 3 + 1], color[i * 3 + 2]),
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Artifacts;
    use crate::scene::synth::{SceneKind, SynthParams};
    use crate::tiles::intersect::project_gaussian;

    fn camera() -> Camera {
        Camera::look_at(
            Vec3::new(0.0, 4.0, 22.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            16.0 / 9.0,
            0.1,
            200.0,
        )
    }

    #[test]
    fn pjrt_preprocess_matches_rust_projection() {
        let artifacts = match Artifacts::discover() {
            Ok(a) if a.available() => a,
            _ => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        };
        let client = HloExecutor::cpu_client().unwrap();
        let pre = PreprocessExecutor::load(&client, &artifacts.preprocess_hlo()).unwrap();

        let scene = SynthParams::new(SceneKind::DynamicLarge, 300).generate();
        let cam = camera();
        let t = 0.4;
        let got = pre
            .project_chunk(&scene.gaussians, 0, &cam, t)
            .unwrap();

        // Rust-side oracle over the same chunk.
        let expect: Vec<Splat2D> = scene
            .gaussians
            .iter()
            .enumerate()
            .filter_map(|(i, g)| project_gaussian(g, i as u32, &cam, t))
            .collect();

        let by_id: std::collections::HashMap<u32, &Splat2D> =
            expect.iter().map(|s| (s.id, s)).collect();
        assert!(!got.is_empty());
        let mut matched = 0;
        for s in &got {
            if let Some(e) = by_id.get(&s.id) {
                matched += 1;
                assert!((s.mean.x - e.mean.x).abs() < 0.5, "id {} mean.x {} vs {}", s.id, s.mean.x, e.mean.x);
                assert!((s.mean.y - e.mean.y).abs() < 0.5);
                assert!((s.depth - e.depth).abs() < 1e-2);
                assert!((s.alpha_base - e.alpha_base).abs() < 1e-3);
                for c in 0..3 {
                    assert!(
                        (s.conic[c] - e.conic[c]).abs() < 0.05 * e.conic[c].abs().max(0.1),
                        "id {} conic[{c}] {} vs {}",
                        s.id,
                        s.conic[c],
                        e.conic[c]
                    );
                }
                assert!((s.color - e.color).length() < 2e-2);
            }
        }
        // The overwhelming majority must agree on visibility.
        assert!(
            matched as f64 >= 0.95 * got.len() as f64,
            "{matched}/{} matched",
            got.len()
        );
    }
}
