//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py` → HLO **text**, see aot recipe in
//! /opt/xla-example) and executes them on the PJRT CPU client from the
//! frame path. Python is never needed at runtime.
//!
//! Executors wrap fixed-shape entry points:
//! * [`PreprocessExecutor`] — L2 graph: temporal slice + projection + SH for
//!   a padded chunk of [`PREPROCESS_CHUNK`] Gaussians;
//! * [`BlendExecutor`] — L1 Pallas tile kernel: 16×16-pixel tile ×
//!   [`BLEND_MAX_G`] depth-sorted splats;
//! * [`ExpLutExecutor`] — the standalone DD3D-Flow exp2 kernel (parity
//!   checks against the Rust [`crate::dcim::ExpLut`]).

pub mod artifact;
pub mod blend_exec;
pub mod executor;
pub mod preprocess_exec;

pub use artifact::Artifacts;
pub use blend_exec::BlendExecutor;
pub use executor::HloExecutor;
pub use preprocess_exec::PreprocessExecutor;

/// Gaussians per preprocess invocation (matches aot.py).
pub const PREPROCESS_CHUNK: usize = 1024;
/// Max splats per blend tile invocation (matches aot.py).
pub const BLEND_MAX_G: usize = 128;
/// Elements per exp-LUT invocation (matches aot.py).
pub const EXP_LUT_N: usize = 4096;
