//! `coordinator::loadgen` — seeded synthetic session-load generation.
//!
//! The scale harness needs scripts with thousands of joins and leaves
//! whose *shape* resembles real serving traffic — steady trickles, flash
//! crowds slamming the admission queue, diurnal waves — while staying
//! byte-reproducible: the same seed always generates the same
//! [`SessionScript`], so every scale benchmark, CI smoke diff, and
//! cross-thread determinism assertion replays the identical workload.
//! Everything draws from the repo's own splitmix64-seeded
//! [`Rng`](crate::util::Rng) (xoshiro256**) — no `rand`, no wall clock.
//!
//! A generated script is ordinary [`SessionScript`] data: it round-trips
//! exactly through [`SessionScript::to_json`] / `from_json` (the
//! unit-test contract), so a generated 10k-session workload can be dumped
//! to disk, versioned, and replayed with `multi_viewer --session-script`
//! like any hand-written script. `multi_viewer --loadgen <preset>` drives
//! the built-in [`LoadPreset`]s end to end and reports through
//! `obs::registry`, with flash-crowd admit/defer instants visible in the
//! `obs::trace` stream when tracing is on.

use crate::camera::ViewCondition;
use crate::obs::Component;
use crate::util::Rng;

use super::session::{SessionScript, SessionSpec};

/// The arrival process: how many sessions join at each round boundary.
/// All rates are expected joins per round; draws are Poisson (Knuth
/// sampler), so arrivals are bursty at small rates the way independent
/// viewers are.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson arrivals at `rate` joins/round.
    Steady { rate: f64 },
    /// Poisson arrivals at `base_rate`, plus `burst_sessions` joining in
    /// one round at `burst_round` — the admission-control stress case
    /// (the burst oversubscribes any finite DRAM budget, so the queue's
    /// defer/admit instants become visible in the trace).
    FlashCrowd { base_rate: f64, burst_round: usize, burst_sessions: usize },
    /// Sinusoidal rate between `trough_rate` and `peak_rate` with the
    /// given period — the day/night wave, starting at the trough.
    Diurnal { trough_rate: f64, peak_rate: f64, period_rounds: usize },
}

impl ArrivalProcess {
    /// Expected joins per round at `round`.
    fn rate_at(&self, round: usize) -> f64 {
        match *self {
            ArrivalProcess::Steady { rate } => rate,
            ArrivalProcess::FlashCrowd { base_rate, .. } => base_rate,
            ArrivalProcess::Diurnal { trough_rate, peak_rate, period_rounds } => {
                let period = period_rounds.max(1) as f64;
                let phase = std::f64::consts::TAU * (round as f64) / period;
                trough_rate + (peak_rate - trough_rate) * 0.5 * (1.0 - phase.cos())
            }
        }
    }

    fn label(&self) -> &'static str {
        match self {
            ArrivalProcess::Steady { .. } => "steady",
            ArrivalProcess::FlashCrowd { .. } => "flash_crowd",
            ArrivalProcess::Diurnal { .. } => "diurnal",
        }
    }
}

/// The built-in workload presets `multi_viewer --loadgen` accepts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadPreset {
    /// Steady trickle; no admission pressure.
    Steady,
    /// Flash crowd: 40% of the sessions arrive in one round.
    Flash,
    /// Diurnal wave over a 64-round period.
    Diurnal,
}

impl LoadPreset {
    pub const ALL: [LoadPreset; 3] = [LoadPreset::Steady, LoadPreset::Flash, LoadPreset::Diurnal];

    pub fn label(self) -> &'static str {
        match self {
            LoadPreset::Steady => "steady",
            LoadPreset::Flash => "flash",
            LoadPreset::Diurnal => "diurnal",
        }
    }

    pub fn from_label(s: &str) -> Option<LoadPreset> {
        LoadPreset::ALL.into_iter().find(|p| p.label() == s)
    }
}

/// A deterministic synthetic workload generator. Build one with
/// [`LoadGen::new`] or [`LoadGen::preset`], tweak the public knobs, and
/// call [`LoadGen::generate`].
#[derive(Debug, Clone)]
pub struct LoadGen {
    /// RNG seed — the workload's identity: same seed, same script.
    pub seed: u64,
    /// Total sessions the script joins.
    pub n_sessions: usize,
    pub arrival: ArrivalProcess,
    /// Mean frames a session renders (its spec's `frames`); log-normal
    /// jittered, clamped to `[1, 4 × dwell_mean_frames]`.
    pub dwell_mean_frames: usize,
    /// Log-normal sigma of the dwell jitter (0 = every session renders
    /// exactly the mean).
    pub dwell_sigma: f32,
    /// Rounds a session lingers after its last frame before its explicit
    /// leave (it occupies a ring slot but renders nothing — mostly-idle
    /// membership, the 10k-session memory story). Every generated session
    /// leaves explicitly, so live state is bounded by concurrency, not by
    /// total session count.
    pub linger_rounds: usize,
    /// Weights of the `[Static, Average, Extreme]` view-condition mix.
    pub condition_mix: [f64; 3],
    /// Fraction of sessions carrying a frame deadline (`target_fps` drawn
    /// from 30/60/120); the rest are throughput streams EDF orders last.
    pub deadline_fraction: f32,
    /// Fraction of deadline sessions at double DWFQ weight.
    pub heavy_weight_fraction: f32,
    /// Suggested concurrent-stream capacity for the driver: the
    /// admission budget that keeps roughly this many mean-demand streams
    /// admitted at once (`None` = run unbudgeted). Presets with bursts
    /// set it so deferral actually happens.
    pub target_concurrency: Option<usize>,
}

impl LoadGen {
    /// A steady workload with neutral knobs (see field docs).
    pub fn new(n_sessions: usize, seed: u64) -> LoadGen {
        LoadGen {
            seed,
            n_sessions,
            arrival: ArrivalProcess::Steady { rate: (n_sessions as f64 / 64.0).max(1.0) },
            dwell_mean_frames: 3,
            dwell_sigma: 0.35,
            linger_rounds: 2,
            condition_mix: [0.3, 0.5, 0.2],
            deadline_fraction: 0.5,
            heavy_weight_fraction: 0.25,
            target_concurrency: None,
        }
    }

    /// One of the built-in presets at the given scale.
    pub fn preset(preset: LoadPreset, n_sessions: usize, seed: u64) -> LoadGen {
        let mut lg = LoadGen::new(n_sessions, seed);
        match preset {
            LoadPreset::Steady => {}
            LoadPreset::Flash => {
                let burst = (n_sessions * 2) / 5;
                lg.arrival = ArrivalProcess::FlashCrowd {
                    base_rate: (n_sessions as f64 / 96.0).max(1.0),
                    burst_round: 8,
                    burst_sessions: burst,
                };
                // Tight enough that the burst visibly queues.
                lg.target_concurrency = Some((n_sessions / 20).clamp(4, 256));
            }
            LoadPreset::Diurnal => {
                let peak = (n_sessions as f64 / 24.0).max(2.0);
                lg.arrival = ArrivalProcess::Diurnal {
                    trough_rate: peak / 8.0,
                    peak_rate: peak,
                    period_rounds: 64,
                };
            }
        }
        lg
    }

    /// Generate the script: joins drawn round by round from the arrival
    /// process until `n_sessions` have arrived, each with a spec from the
    /// dwell/mix distributions and an explicit leave at
    /// `join + frames + linger_rounds`. Deterministic in `seed` (and only
    /// `seed`): the generator never consults the clock.
    pub fn generate(&self) -> SessionScript {
        let mut rng = Rng::new(self.seed ^ 0x10AD_6E4E_5E55_1045);
        let mut script = SessionScript::new();
        let mut emitted = 0usize;
        let mut round = 0usize;
        // Safety valve for degenerate rates: past the cap the remainder
        // arrives at once (the script stays exactly n_sessions joins).
        let round_cap = 512 + self.n_sessions * 64;
        while emitted < self.n_sessions {
            let burst = match self.arrival {
                ArrivalProcess::FlashCrowd { burst_round, burst_sessions, .. }
                    if round == burst_round =>
                {
                    burst_sessions
                }
                _ => 0,
            };
            let mut k = burst + poisson(&mut rng, self.arrival.rate_at(round));
            if round >= round_cap {
                k = self.n_sessions - emitted;
            }
            for _ in 0..k.min(self.n_sessions - emitted) {
                let spec = self.draw_spec(&mut rng);
                let leave = round + spec.frames + self.linger_rounds.max(1);
                script = script.join_at(round, spec).leave_at(leave, emitted);
                emitted += 1;
            }
            round += 1;
        }
        script
    }

    /// One session spec from the dwell / condition / deadline / weight
    /// distributions.
    fn draw_spec(&self, rng: &mut Rng) -> SessionSpec {
        let condition = match pick(rng, &self.condition_mix) {
            0 => ViewCondition::Static,
            1 => ViewCondition::Average,
            _ => ViewCondition::Extreme,
        };
        let mean = self.dwell_mean_frames.max(1);
        let frames = if self.dwell_sigma > 0.0 {
            let f = rng.log_normal((mean as f32).ln(), self.dwell_sigma);
            (f.round() as usize).clamp(1, mean * 4)
        } else {
            mean
        };
        let mut spec = SessionSpec::stream(condition, frames);
        if rng.chance(self.deadline_fraction) {
            spec.target_fps = [30.0, 60.0, 120.0][rng.below(3)];
            if rng.chance(self.heavy_weight_fraction) {
                spec.weight = 2.0;
            }
        }
        spec
    }

    /// Registry [`Component`] describing the generated workload's
    /// parameters (all deterministic — part of the BENCH scale block).
    pub fn component(&self) -> Component {
        let mut c = Component::new()
            .set("seed", self.seed)
            .set("n_sessions", self.n_sessions)
            .set("arrival", self.arrival.label())
            .set("dwell_mean_frames", self.dwell_mean_frames)
            .set("dwell_sigma", self.dwell_sigma as f64)
            .set("linger_rounds", self.linger_rounds)
            .set("deadline_fraction", self.deadline_fraction as f64);
        if let Some(tc) = self.target_concurrency {
            c = c.set("target_concurrency", tc);
        }
        c
    }
}

/// Knuth's Poisson sampler (exact for the small per-round rates used
/// here; rates are clamped so the rejection loop stays bounded).
fn poisson(rng: &mut Rng, rate: f64) -> usize {
    if rate <= 0.0 {
        return 0;
    }
    let l = (-rate.min(30.0)).exp();
    let mut k = 0usize;
    let mut p = 1.0f64;
    loop {
        p *= rng.f64();
        if p <= l {
            return k;
        }
        k += 1;
    }
}

/// Weighted index draw (weights need not be normalized; non-positive
/// total falls back to index 0).
fn pick(rng: &mut Rng, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().filter(|w| w.is_finite() && **w > 0.0).sum();
    if total <= 0.0 {
        return 0;
    }
    let mut x = rng.f64() * total;
    for (i, &w) in weights.iter().enumerate() {
        if w.is_finite() && w > 0.0 {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
    }
    weights.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::SessionEvent;

    #[test]
    fn same_seed_generates_identical_scripts() {
        for preset in LoadPreset::ALL {
            let a = LoadGen::preset(preset, 200, 7).generate();
            let b = LoadGen::preset(preset, 200, 7).generate();
            assert_eq!(
                a.to_json().pretty(),
                b.to_json().pretty(),
                "preset {}",
                preset.label()
            );
            let c = LoadGen::preset(preset, 200, 8).generate();
            assert_ne!(
                a.to_json().pretty(),
                c.to_json().pretty(),
                "different seeds must differ ({})",
                preset.label()
            );
        }
    }

    #[test]
    fn generated_scripts_round_trip_through_json() {
        let script = LoadGen::preset(LoadPreset::Flash, 300, 42).generate();
        let text = script.to_json().pretty();
        let parsed = SessionScript::from_json_str(&text).expect("generated script parses");
        assert_eq!(parsed.to_json().pretty(), text);
    }

    #[test]
    fn every_session_joins_once_and_leaves_strictly_later() {
        for preset in LoadPreset::ALL {
            let n = 500;
            let script = LoadGen::preset(preset, n, 3).generate();
            assert_eq!(script.n_sessions(), n, "{}", preset.label());
            let mut join_round = vec![None; n];
            let mut leave_round = vec![None; n];
            let mut next_id = 0usize;
            for ev in &script.events {
                match ev {
                    SessionEvent::JoinAt { frame, .. } => {
                        join_round[next_id] = Some(*frame);
                        next_id += 1;
                    }
                    SessionEvent::LeaveAt { frame, session } => {
                        assert!(leave_round[*session].is_none(), "duplicate leave");
                        leave_round[*session] = Some(*frame);
                    }
                }
            }
            for id in 0..n {
                let j = join_round[id].expect("join exists");
                let l = leave_round[id].expect("leave exists");
                assert!(l > j, "session {id}: leave {l} not after join {j}");
            }
            // Bounded live set: peak concurrency is well below the total.
            assert!(script.peak_concurrency() < n, "{}", preset.label());
        }
    }

    #[test]
    fn flash_preset_bursts_at_the_configured_round() {
        let lg = LoadGen::preset(LoadPreset::Flash, 500, 11);
        let ArrivalProcess::FlashCrowd { burst_round, burst_sessions, .. } = lg.arrival else {
            panic!("flash preset must use FlashCrowd arrivals");
        };
        assert!(lg.target_concurrency.is_some());
        let script = lg.generate();
        let at_burst = script
            .events
            .iter()
            .filter(|e| matches!(e, SessionEvent::JoinAt { frame, .. } if *frame == burst_round))
            .count();
        assert!(
            at_burst >= burst_sessions,
            "expected ≥{burst_sessions} joins at round {burst_round}, got {at_burst}"
        );
    }

    #[test]
    fn diurnal_rate_oscillates_between_trough_and_peak() {
        let arrival =
            ArrivalProcess::Diurnal { trough_rate: 1.0, peak_rate: 9.0, period_rounds: 64 };
        assert!((arrival.rate_at(0) - 1.0).abs() < 1e-9);
        assert!((arrival.rate_at(32) - 9.0).abs() < 1e-9);
        assert!((arrival.rate_at(64) - 1.0).abs() < 1e-9);
        let mid = arrival.rate_at(16);
        assert!(mid > 1.0 && mid < 9.0);
    }
}
