//! `coordinator::session` — long-lived viewer sessions over one shared,
//! contended memory system.
//!
//! The batch paths ([`RenderServer::render_batch`] /
//! [`RenderServer::render_batch_contended`]) treat serving as fixed-size
//! jobs: every viewer exists for the whole batch and the issue order is a
//! hard-coded rotation. Real edge serving is a *stream*: viewers join
//! mid-flight, move, and leave while the renderer sustains its frame rate
//! under a shared DRAM budget. This module adds that layer:
//!
//! * [`SessionScript`] — a deterministic event script
//!   (`JoinAt { frame, spec }` / `LeaveAt { frame, session }`) describing
//!   when viewers enter and exit the stream. Scripts are data: replaying
//!   the same script always reproduces the same simulated statistics, at
//!   any host thread count.
//! * [`ViewerSession`]s retain their per-viewer pipeline state across
//!   scheduling rounds — the pooled `FrameCtx` scratch, the ATG grouping
//!   and AII interval posteriori, the early-termination calibration, and
//!   the camera-trajectory cursor — instead of cold-starting, so interval
//!   hit rates and buffer reuse reflect steady-state streaming. A departed
//!   session's state is detached ([`crate::pipeline::SessionState`]) and
//!   can seed a later joiner's AII intervals (`SessionSpec::warm_from`).
//! * [`SchedPolicy`] — the pluggable per-round issue-order policy:
//!   [`SchedPolicy::RoundRobin`] (the rotating lockstep, bit-compatible
//!   with `render_batch_contended` for a no-join/no-leave script),
//!   [`SchedPolicy::Dwfq`] (deficit-weighted fair queueing: the session
//!   with the least weighted DRAM service goes first), and
//!   [`SchedPolicy::Edf`] (earliest deadline first by per-session target
//!   FPS). Ordering moves *when* a session's requests meet the channels —
//!   per-session byte counts never change, only waits and latency.
//! * Admission control: an optional DRAM-bandwidth budget
//!   ([`SessionScheduler::dram_budget_gbps`]) defers joins whose
//!   estimated demand (measured bytes/frame × target FPS) would oversubscribe
//!   the channels; admission is work-conserving (a deferred session is
//!   admitted as soon as the stream would otherwise idle).
//! * [`SessionReport`] / [`SessionBatchReport`] — per-session frame-latency
//!   percentiles vs. deadline, missed-deadline counts, retained-state hit
//!   rates, and the same [`ContendedMemReport`] roll-up the batch path
//!   emits (assembled by the shared `contended_rollup` helper, so the two
//!   cannot drift).
//!
//! # Determinism contract
//!
//! One scheduling round = one simulated frame epoch: the shared
//! [`MemorySystem`](crate::memory::MemorySystem) takes a frame barrier,
//! then every renderable session renders exactly one frame in the
//! policy's issue order. Execution goes through the shared
//! [`RoundEngine`](super::rounds::RoundEngine): at `threads > 1` a
//! round's frames render **host-parallel** against trace-recording ports
//! and the recorded DRAM requests replay into the shared system in the
//! exact policy order, so session rounds scale with cores while the
//! request schedule — and therefore every statistic — matches the serial
//! lockstep bit-for-bit. Everything the scheduler consumes — cumulative
//! busy time, cursors, deadlines — lives on the simulated timeline, so
//! reports are bit-identical across runs and host thread counts (enforced
//! by the `session_scheduler` suite and the CI `session-smoke` job, which
//! diffs the `sessions` block at `PALLAS_THREADS=1/4/8`).

use crate::camera::ViewCondition;
use crate::obs::{Component, LatencyLadder, Track};
use crate::pipeline::{FramePipeline, SessionState};
use crate::render::ReferenceRenderer;
use crate::util::json::Json;
use crate::util::KeyedMinHeap;
use std::collections::VecDeque;
use std::time::Instant;

use super::app::{scene_trajectory_from, viewer_label, SequenceAgg};
use super::rounds::{RoundEngine, RoundJob, RoundPorts};
use super::server::{
    contended_rollup, ContendedMemReport, RenderServer, ViewerMemStats, ViewerSpec,
};
use super::SequenceReport;

/// Demand estimate FPS for sessions that declare no deadline.
pub const DEFAULT_STREAM_FPS: f64 = 30.0;

/// One viewer session's streaming parameters.
#[derive(Debug, Clone)]
pub struct SessionSpec {
    pub condition: ViewCondition,
    /// Frames this session renders (its share of the stream).
    pub frames: usize,
    /// Trajectory cursor at join: a mid-stream viewer `start_frame` frames
    /// into its walk renders frames `[start_frame, start_frame + frames)`
    /// of the full trajectory — identical to the tail a frame-0 joiner
    /// would render from `start_frame` on.
    pub start_frame: usize,
    /// Render every n-th frame numerically for PSNR (0 = perf path only).
    pub psnr_every: usize,
    /// Target frame rate: the per-frame deadline is `1e9 / target_fps` ns
    /// of simulated latency (0 = no deadline; EDF orders such sessions
    /// last).
    pub target_fps: f64,
    /// DWFQ weight (> 0; a weight-2 session is entitled to twice the DRAM
    /// service before yielding priority).
    pub weight: f64,
    /// Warm-start the AII sort intervals from this departed session's
    /// retained state (by session id). Ignored when the donor has not left
    /// or retained nothing.
    pub warm_from: Option<usize>,
    /// Resume the full pipeline state seeded under this key by
    /// [`SessionScheduler::seed_detached`] (a departed session of a
    /// *previous* scheduler run). The continuation is bit-identical to an
    /// uninterrupted stream; without a matching seeded state the join
    /// falls back to a cold start. Mutually exclusive with `warm_from`
    /// (resume carries the AII intervals already).
    pub resume_from: Option<usize>,
}

impl SessionSpec {
    /// A perf-path streaming session with no deadline and unit weight.
    pub fn stream(condition: ViewCondition, frames: usize) -> SessionSpec {
        SessionSpec {
            condition,
            frames,
            start_frame: 0,
            psnr_every: 0,
            target_fps: 0.0,
            weight: 1.0,
            warm_from: None,
            resume_from: None,
        }
    }

    /// Adopt a batch [`ViewerSpec`] unchanged (frame-0 join, no deadline).
    pub fn from_viewer(spec: &ViewerSpec) -> SessionSpec {
        SessionSpec {
            psnr_every: spec.psnr_every,
            ..SessionSpec::stream(spec.condition, spec.frames)
        }
    }

    pub fn with_start(mut self, start_frame: usize) -> SessionSpec {
        self.start_frame = start_frame;
        self
    }

    pub fn with_deadline_fps(mut self, target_fps: f64) -> SessionSpec {
        self.target_fps = target_fps;
        self
    }

    pub fn with_weight(mut self, weight: f64) -> SessionSpec {
        self.weight = weight;
        self
    }

    pub fn with_psnr_every(mut self, psnr_every: usize) -> SessionSpec {
        self.psnr_every = psnr_every;
        self
    }

    pub fn with_warm_from(mut self, donor: usize) -> SessionSpec {
        self.warm_from = Some(donor);
        self
    }

    pub fn with_resume_from(mut self, key: usize) -> SessionSpec {
        self.resume_from = Some(key);
        self
    }

    /// Simulated per-frame deadline (ns); infinite without a target FPS.
    pub fn deadline_ns(&self) -> f64 {
        if self.target_fps > 0.0 {
            1e9 / self.target_fps
        } else {
            f64::INFINITY
        }
    }

    /// The declarative JSON form (see [`SessionScript::to_json`]).
    pub fn to_json(&self) -> Json {
        let mut js = Json::obj()
            .set("condition", self.condition.label())
            .set("frames", self.frames)
            .set("start_frame", self.start_frame)
            .set("psnr_every", self.psnr_every)
            .set("target_fps", self.target_fps)
            .set("weight", self.weight);
        if let Some(d) = self.warm_from {
            js = js.set("warm_from", d);
        }
        if let Some(k) = self.resume_from {
            js = js.set("resume_from", k);
        }
        js
    }

    /// Parse a spec from its JSON form. `condition` and `frames` are
    /// required; every other field defaults to [`SessionSpec::stream`]'s
    /// values. Strict: a present-but-mistyped field (string FPS,
    /// fractional frame count) and an unknown key (a typo like
    /// `"warm_form"`) are hard errors, never silent defaults.
    pub fn from_json(v: &Json) -> Result<SessionSpec, String> {
        const KNOWN: [&str; 8] = [
            "condition",
            "frames",
            "start_frame",
            "psnr_every",
            "target_fps",
            "weight",
            "warm_from",
            "resume_from",
        ];
        if let Json::Obj(map) = v {
            for key in map.keys() {
                if !KNOWN.contains(&key.as_str()) {
                    return Err(format!("spec: unknown field {key:?}"));
                }
            }
        } else {
            return Err("spec: not an object".to_string());
        }
        // Present-but-wrong-type fields are errors, not defaults.
        let opt_uint = |key: &str| -> Result<Option<usize>, String> {
            match v.get(key) {
                None => Ok(None),
                Some(x) => x
                    .as_f64()
                    .filter(|f| *f >= 0.0 && f.fract() == 0.0)
                    .map(|f| Some(f as usize))
                    .ok_or_else(|| format!("spec: {key:?} must be a non-negative integer")),
            }
        };
        let opt_num = |key: &str| -> Result<Option<f64>, String> {
            match v.get(key) {
                None => Ok(None),
                Some(x) => x
                    .as_f64()
                    .map(Some)
                    .ok_or_else(|| format!("spec: {key:?} must be a number")),
            }
        };

        let label = v
            .get("condition")
            .and_then(Json::as_str)
            .ok_or_else(|| "spec: missing \"condition\"".to_string())?;
        let condition = ViewCondition::from_label(label)
            .ok_or_else(|| format!("spec: unknown view condition {label:?}"))?;
        let frames =
            opt_uint("frames")?.ok_or_else(|| "spec: missing \"frames\"".to_string())?;
        let mut spec = SessionSpec::stream(condition, frames);
        if let Some(x) = opt_uint("start_frame")? {
            spec.start_frame = x;
        }
        if let Some(x) = opt_uint("psnr_every")? {
            spec.psnr_every = x;
        }
        if let Some(x) = opt_num("target_fps")? {
            spec.target_fps = x;
        }
        if let Some(x) = opt_num("weight")? {
            spec.weight = x;
        }
        spec.warm_from = opt_uint("warm_from")?;
        spec.resume_from = opt_uint("resume_from")?;
        if spec.warm_from.is_some() && spec.resume_from.is_some() {
            return Err(
                "spec: \"warm_from\" and \"resume_from\" are mutually exclusive".to_string()
            );
        }
        Ok(spec)
    }
}

/// One lifecycle event of a session stream. Events fire at *round
/// boundaries*: a `LeaveAt { frame: k }` session does not render round k.
#[derive(Debug, Clone)]
pub enum SessionEvent {
    /// A viewer joins at the start of round `frame`. Session ids are
    /// assigned by join order within the script (0, 1, …).
    JoinAt { frame: usize, spec: SessionSpec },
    /// Session `session` (join-order id) departs at the start of round
    /// `frame`; its pipeline state is detached and retained, its memory
    /// ports retire.
    LeaveAt { frame: usize, session: usize },
}

/// A deterministic join/leave script — the replayable description of one
/// streaming workload.
#[derive(Debug, Clone, Default)]
pub struct SessionScript {
    pub events: Vec<SessionEvent>,
}

impl SessionScript {
    pub fn new() -> SessionScript {
        SessionScript::default()
    }

    pub fn join_at(mut self, frame: usize, spec: SessionSpec) -> SessionScript {
        self.events.push(SessionEvent::JoinAt { frame, spec });
        self
    }

    pub fn leave_at(mut self, frame: usize, session: usize) -> SessionScript {
        self.events.push(SessionEvent::LeaveAt { frame, session });
        self
    }

    /// The static-batch script: every spec joins at frame 0 and streams to
    /// completion — the workload under which round-robin scheduling is
    /// bit-compatible with [`RenderServer::render_batch_contended`].
    pub fn from_specs(specs: &[ViewerSpec]) -> SessionScript {
        let mut script = SessionScript::new();
        for spec in specs {
            script = script.join_at(0, SessionSpec::from_viewer(spec));
        }
        script
    }

    /// Sessions the script joins.
    pub fn n_sessions(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, SessionEvent::JoinAt { .. }))
            .count()
    }

    /// The maximum number of simultaneously-live sessions the script can
    /// reach: leaves fire before joins of the same round (matching the
    /// scheduler), and a session without an explicit leave counts as live
    /// to stream end. This is the host parallelism a round can actually
    /// exploit — [`SessionScheduler::run`] sizes its round engine with it,
    /// so a script whose sessions never overlap keeps the lockstep path
    /// and its intra-frame executor parallelism instead of pinning every
    /// frame to one thread.
    pub fn peak_concurrency(&self) -> usize {
        // round -> (leaves, joins) in ascending round order.
        let mut deltas: std::collections::BTreeMap<usize, (usize, usize)> =
            std::collections::BTreeMap::new();
        for ev in &self.events {
            match ev {
                SessionEvent::JoinAt { frame, .. } => deltas.entry(*frame).or_default().1 += 1,
                SessionEvent::LeaveAt { frame, .. } => deltas.entry(*frame).or_default().0 += 1,
            }
        }
        let mut live = 0usize;
        let mut peak = 0usize;
        for (leaves, joins) in deltas.into_values() {
            live = live.saturating_sub(leaves) + joins;
            peak = peak.max(live);
        }
        peak
    }

    /// The declarative JSON form of the script:
    ///
    /// ```json
    /// { "events": [
    ///     { "type": "join",  "frame": 0, "spec": { "condition": "average",
    ///       "frames": 8, "start_frame": 0, "psnr_every": 0,
    ///       "target_fps": 120, "weight": 1 } },
    ///     { "type": "leave", "frame": 4, "session": 0 }
    /// ] }
    /// ```
    ///
    /// `to_json` → [`SessionScript::from_json`] round-trips exactly (the
    /// unit-test contract), so scripts can be authored by hand or dumped
    /// from code and replayed from disk
    /// (`examples/multi_viewer.rs --session-script <path>`).
    pub fn to_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|e| match e {
                SessionEvent::JoinAt { frame, spec } => Json::obj()
                    .set("type", "join")
                    .set("frame", *frame)
                    .set("spec", spec.to_json()),
                SessionEvent::LeaveAt { frame, session } => Json::obj()
                    .set("type", "leave")
                    .set("frame", *frame)
                    .set("session", *session),
            })
            .collect();
        Json::obj().set("events", Json::Arr(events))
    }

    /// Parse a script from its JSON form (inverse of
    /// [`SessionScript::to_json`]).
    pub fn from_json(v: &Json) -> Result<SessionScript, String> {
        let Some(Json::Arr(events)) = v.get("events") else {
            return Err("script: missing \"events\" array".to_string());
        };
        let mut script = SessionScript::new();
        for (i, ev) in events.iter().enumerate() {
            let ty = ev
                .get("type")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("event {i}: missing \"type\""))?;
            let frame = ev
                .get("frame")
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("event {i}: missing \"frame\""))?;
            match ty {
                "join" => {
                    let spec = ev
                        .get("spec")
                        .ok_or_else(|| format!("event {i}: join without \"spec\""))?;
                    let spec =
                        SessionSpec::from_json(spec).map_err(|e| format!("event {i}: {e}"))?;
                    script.events.push(SessionEvent::JoinAt { frame, spec });
                }
                "leave" => {
                    let session = ev
                        .get("session")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| format!("event {i}: leave without \"session\""))?;
                    script.events.push(SessionEvent::LeaveAt { frame, session });
                }
                other => return Err(format!("event {i}: unknown type {other:?}")),
            }
        }
        Ok(script)
    }

    /// Parse a script from JSON text (file contents of
    /// `--session-script`).
    pub fn from_json_str(s: &str) -> Result<SessionScript, String> {
        SessionScript::from_json(&crate::util::json::parse(s)?)
    }
}

/// Per-round issue-order policy of the [`SessionScheduler`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Rotating lockstep (round r issues session `(r + k) mod n` for
    /// k = 0..n over the join-ordered ring) — the batch path's order, kept
    /// bit-compatible as the baseline.
    RoundRobin,
    /// Deficit-weighted fair queueing: ascending cumulative DRAM busy time
    /// over weight — the least-served session (per its entitlement) issues
    /// first each round.
    Dwfq,
    /// Earliest deadline first: ascending next-frame deadline
    /// (`(cursor + 1) / target_fps` on the session's stream clock);
    /// deadline-free sessions go last.
    Edf,
}

impl SchedPolicy {
    pub const ALL: [SchedPolicy; 3] =
        [SchedPolicy::RoundRobin, SchedPolicy::Dwfq, SchedPolicy::Edf];

    pub fn label(self) -> &'static str {
        match self {
            SchedPolicy::RoundRobin => "round_robin",
            SchedPolicy::Dwfq => "dwfq",
            SchedPolicy::Edf => "edf",
        }
    }
}

/// Which bookkeeping implementation [`SessionScheduler::run`] uses. The
/// two produce **byte-identical** [`SessionBatchReport`] JSON for every
/// script, policy, and host thread count (the `session_scheduler` gate
/// tests enforce it); they differ only in per-round cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedImpl {
    /// Round-indexed script events, an O(1)-removal issue ring, and keyed
    /// min-heaps with lazy invalidation for DWFQ/EDF — per-round cost
    /// scales with the sessions that actually changed, not the total
    /// session count. The default.
    Indexed,
    /// The historical path: per-round event scans, `Vec::retain` ring
    /// maintenance, and a full policy sort every round — kept as the
    /// measurable baseline for the `scale` BENCH speedup.
    ReferenceSort,
}

impl SchedImpl {
    pub fn label(self) -> &'static str {
        match self {
            SchedImpl::Indexed => "indexed",
            SchedImpl::ReferenceSort => "reference_sort",
        }
    }
}

/// Final report of one session's lifetime in the stream.
#[derive(Debug, Clone)]
pub struct SessionReport {
    pub session: usize,
    /// Round the script joined the session.
    pub joined_round: usize,
    /// Round admission control actually admitted it.
    pub admitted_round: usize,
    /// Rounds spent deferred by the DRAM-bandwidth budget.
    pub deferred_rounds: usize,
    /// Round the session left (explicit leave, or the stream's last round).
    pub left_round: usize,
    /// Frames actually rendered.
    pub frames: usize,
    pub target_fps: f64,
    pub weight: f64,
    /// Whether the session warm-started its AII intervals from a departed
    /// donor's retained state.
    pub warm_started: bool,
    /// Whether the session resumed a full pipeline state seeded from a
    /// previous scheduler run ([`SessionScheduler::seed_detached`]).
    pub resumed: bool,
    /// Frames whose simulated latency exceeded the deadline.
    pub missed_deadlines: u64,
    /// `missed_deadlines / frames` (0 without a deadline).
    pub deadline_miss_rate: f64,
    /// Simulated frame-latency percentiles (pipelined ns) over the
    /// session's lifetime.
    pub frame_latency_pctl: LatencyLadder,
    /// Retained-state hit rate of AII interval initialization: the
    /// fraction of sorted elements that skipped the phase-1 min/max scan
    /// because their block's intervals were carried across frames.
    pub aii_interval_hit_rate: f64,
    /// Per-port DRAM statistics under contention.
    pub mem: ViewerMemStats,
    /// The standard per-viewer sequence report (energy, FPS, PSNR, …).
    pub seq: SequenceReport,
}

impl SessionReport {
    /// Registry [`Component`] of the session's lifetime stats (same JSON
    /// keys as the pre-registry report; the latency block carries the full
    /// [`LatencyLadder`]).
    pub fn component(&self) -> Component {
        Component::new()
            .set("session", self.session)
            .set("joined_round", self.joined_round)
            .set("admitted_round", self.admitted_round)
            .set("deferred_rounds", self.deferred_rounds)
            .set("left_round", self.left_round)
            .set("frames", self.frames)
            .set("target_fps", self.target_fps)
            .set("weight", self.weight)
            .set("warm_started", self.warm_started)
            .set("resumed", self.resumed)
            .set("missed_deadlines", self.missed_deadlines as f64)
            .set("deadline_miss_rate", self.deadline_miss_rate)
            .set("frame_latency_ns_pctl", self.frame_latency_pctl)
            .set("aii_interval_hit_rate", self.aii_interval_hit_rate)
            .set("mem", self.mem.component())
            .set("report", self.seq.to_json())
    }

    pub fn to_json(&self) -> Json {
        self.component().to_json()
    }
}

/// Stream-level report of one scheduler run.
#[derive(Debug, Clone)]
pub struct SessionBatchReport {
    pub policy: SchedPolicy,
    /// Scheduling rounds driven (frame epochs on the shared system).
    pub rounds: usize,
    pub total_frames: usize,
    /// Most frames any single round issued (the stream's peak concurrent
    /// render load).
    pub peak_live: usize,
    /// Host wall-clock of the run (not part of the simulated projection).
    pub wall_s: f64,
    /// Missed-deadline fraction across all deadline-bearing frames.
    pub deadline_miss_rate: f64,
    /// Frame-latency percentiles across every session frame.
    pub frame_latency_pctl: LatencyLadder,
    /// Admission-queue wait percentiles: rounds each session spent
    /// deferred by the DRAM budget before admission (0 everywhere without
    /// a budget).
    pub admission_wait_rounds: LatencyLadder,
    pub sessions: Vec<SessionReport>,
    /// The shared-memory roll-up, structurally identical to the batch
    /// path's `contended_mem` block.
    pub contended: ContendedMemReport,
}

impl SessionBatchReport {
    /// Jain fairness over per-session DRAM busy time (lifetime).
    pub fn fairness(&self) -> f64 {
        self.contended.fairness
    }

    /// Registry [`Component`] of the stream report — the deterministic
    /// section of the run (host wall-clock deliberately excluded).
    pub fn component(&self) -> Component {
        Component::new()
            .set("policy", self.policy.label())
            .set("rounds", self.rounds)
            .set("total_frames", self.total_frames)
            .set("peak_live", self.peak_live)
            .set("deadline_miss_rate", self.deadline_miss_rate)
            .set("frame_latency_ns_pctl", self.frame_latency_pctl)
            .set("admission_wait_rounds_pctl", self.admission_wait_rounds)
            .set("fairness", self.fairness())
            .list("sessions", self.sessions.iter().map(SessionReport::component))
            .set("contended_mem", self.contended.component())
    }

    /// Simulated-statistics JSON: everything except host wall-clock — the
    /// surface that must be bit-identical across host thread counts (the
    /// CI `session-smoke` diff and the determinism suite compare this).
    pub fn to_json(&self) -> Json {
        self.component().to_json()
    }

    /// The wall-clock-free projection used by determinism assertions.
    pub fn simulated_projection(&self) -> String {
        self.to_json().pretty()
    }
}

/// A live session inside one scheduler run (internal).
struct ViewerSession<'s> {
    spec: SessionSpec,
    pipeline: Option<FramePipeline<'s>>,
    ports: Option<RoundPorts>,
    traj: Vec<(crate::camera::Camera, f32)>,
    /// Frames rendered so far (the camera-trajectory cursor, relative to
    /// `spec.start_frame`).
    cursor: usize,
    joined_round: usize,
    admitted_round: Option<usize>,
    left_round: Option<usize>,
    deferred_rounds: usize,
    agg: SequenceAgg,
    latency: Vec<f64>,
    missed: u64,
    /// Cumulative DRAM busy time (DWFQ service measure).
    busy_ns: f64,
    minmax_scanned: u64,
    bucketed: u64,
    warm_started: bool,
    resumed: bool,
    /// Bandwidth demand charged against the admission budget while the
    /// session streams.
    demand_bytes_per_s: f64,
    /// Detached pipeline state after leaving (warm-start donor within the
    /// run; collected into [`SessionScheduler::take_detached`] after it).
    retained: Option<SessionState>,
}

impl ViewerSession<'_> {
    fn renderable(&self) -> bool {
        self.pipeline.is_some() && self.left_round.is_none() && self.cursor < self.traj.len()
    }
}

/// The stream scheduler: owns the shared contended
/// [`MemorySystem`](crate::memory::MemorySystem) and the
/// [`ViewerSession`]s of one script run. Built by
/// [`RenderServer::sessions`]. Rounds execute through the shared
/// [`RoundEngine`](super::rounds::RoundEngine), so at `threads > 1` a
/// round's sessions render host-parallel while the policy-ordered trace
/// replay keeps every statistic bit-identical to the serial schedule.
pub struct SessionScheduler<'a> {
    pub server: &'a RenderServer,
    pub policy: SchedPolicy,
    /// Admission budget (bytes/s of estimated DRAM demand); `None` admits
    /// every join immediately.
    pub dram_budget_bytes_per_s: Option<f64>,
    /// Bookkeeping implementation ([`SchedImpl::Indexed`] by default);
    /// byte-identical reports either way.
    pub sched_impl: SchedImpl,
    /// Whether to retain every session's detached pipeline state for
    /// [`SessionScheduler::take_detached`] (the default). Off, departed
    /// sessions free their working set immediately unless a later join
    /// warm-starts from them.
    collect_detached: bool,
    /// Detached pipeline states collected by the last [`SessionScheduler::run`].
    detached: Vec<(usize, SessionState)>,
    /// States seeded for the next run's `resume_from` joins.
    seeded: Vec<(usize, SessionState)>,
    /// Host ns of scheduler bookkeeping per round of the last run.
    last_overhead_ns: Vec<f64>,
}

impl RenderServer {
    /// A session scheduler over this server's shared scene preparation.
    pub fn sessions(&self, policy: SchedPolicy) -> SessionScheduler<'_> {
        SessionScheduler {
            server: self,
            policy,
            dram_budget_bytes_per_s: None,
            sched_impl: SchedImpl::Indexed,
            collect_detached: true,
            detached: Vec::new(),
            seeded: Vec::new(),
            last_overhead_ns: Vec::new(),
        }
    }

    /// Run a session script to completion under `policy` (convenience for
    /// [`SessionScheduler::run`]).
    pub fn render_sessions(
        &self,
        script: &SessionScript,
        policy: SchedPolicy,
    ) -> SessionBatchReport {
        self.sessions(policy).run(script)
    }
}

impl<'a> SessionScheduler<'a> {
    /// Cap admitted sessions' estimated aggregate DRAM demand at `gbps`
    /// GB/s. Demand is estimated as measured mean bytes/frame × the
    /// session's target FPS ([`DEFAULT_STREAM_FPS`] without a deadline);
    /// joins that would exceed the cap wait in join order. Admission is
    /// work-conserving: the head of the wait queue is admitted whenever
    /// the stream would otherwise go idle.
    pub fn dram_budget_gbps(mut self, gbps: f64) -> SessionScheduler<'a> {
        self.dram_budget_bytes_per_s = Some(gbps * 1e9);
        self
    }

    /// Select the bookkeeping implementation (see [`SchedImpl`]).
    pub fn with_sched_impl(mut self, imp: SchedImpl) -> SessionScheduler<'a> {
        self.sched_impl = imp;
        self
    }

    /// Run on the historical per-round-scan + full-sort path — the
    /// measurable baseline of the indexed hot path (byte-identical
    /// reports, superlinear round overhead).
    pub fn with_reference_order(self) -> SessionScheduler<'a> {
        self.with_sched_impl(SchedImpl::ReferenceSort)
    }

    /// Don't collect detached pipeline states: a departed session's
    /// working set is dropped at its leave round instead of being parked
    /// for [`SessionScheduler::take_detached`] (donors that a later
    /// `warm_from` join names are still retained, with their pooled
    /// `FrameCtx` scratch trimmed). This is what keeps a 10k-session
    /// churn script's memory bounded by *peak concurrency*, not total
    /// session count. Reports are unaffected.
    pub fn discard_detached(mut self) -> SessionScheduler<'a> {
        self.collect_detached = false;
        self
    }

    /// Host nanoseconds of scheduler bookkeeping per round of the last
    /// [`SessionScheduler::run`]: event application, admission, issue
    /// ordering, and outcome accounting — render/engine time excluded.
    /// Host-measured, so never part of any report JSON; the `scale` BENCH
    /// block aggregates it.
    pub fn last_overhead_ns(&self) -> &[f64] {
        &self.last_overhead_ns
    }

    /// Take the detached per-session pipeline states the last
    /// [`SessionScheduler::run`] collected (keyed by session id):
    /// explicitly-departed sessions and sessions still live at stream end.
    /// Seed them into a later scheduler (same server / scene preparation)
    /// via [`SessionScheduler::seed_detached`] so a second run's
    /// `SessionSpec::resume_from` joins continue the streams
    /// bit-identically — cross-run retention used to be pipeline-level
    /// only.
    ///
    /// Caveat: a departed session whose AII intervals were donated to a
    /// `warm_from` joiner *within* the run is exported with those
    /// intervals drained (`SessionState::take_aii_intervals` cools the
    /// donor by design) — its resume carries everything else warm but
    /// pays the AII phase-1 rescan. Check
    /// [`SessionState::aii_warm_blocks`] if that matters to the caller.
    pub fn take_detached(&mut self) -> Vec<(usize, SessionState)> {
        std::mem::take(&mut self.detached)
    }

    /// Seed detached states (a previous run's
    /// [`SessionScheduler::take_detached`]) for the next run: a join whose
    /// spec sets `resume_from = Some(key)` adopts the state stored under
    /// `key` instead of cold-starting. Unmatched keys fall back to a fresh
    /// pipeline; unclaimed states are dropped when the run ends.
    ///
    /// The resuming spec's `start_frame` must continue the donor's camera
    /// walk — for a chain that began at frame 0 that is the state's
    /// [`SessionState::frame_idx`] — and its `condition` must match the
    /// donor's; the scheduler does not validate trajectory coherence (a
    /// mismatched resume runs, but is not a continuation of anything).
    pub fn seed_detached(&mut self, states: Vec<(usize, SessionState)>) {
        self.seeded.extend(states);
    }

    /// Drive `script` to completion: every joined session is admitted,
    /// streams its frames, and leaves (explicitly or at stream end); the
    /// run returns when no session is renderable and no event is pending.
    /// Rounds go through the shared round engine — host-parallel two-phase
    /// at `threads > 1`, lockstep otherwise — with bit-identical reports
    /// either way.
    ///
    /// # Panics
    ///
    /// Panics on malformed scripts: a leave for an unknown session, a
    /// leave at or before its session's join frame, or a duplicate leave.
    pub fn run(&mut self, script: &SessionScript) -> SessionBatchReport {
        let t0 = Instant::now();
        let server = self.server;
        let shared = &server.shared;
        // Size the engine by the script's *peak concurrency*, not its
        // total joins: a stream whose sessions never overlap gets the
        // lockstep path (full intra-frame parallelism per lone frame)
        // instead of one-thread trace pipelines.
        let mut engine = server.round_engine(script.peak_concurrency());
        if let Some(sink) = &server.tracer {
            engine.set_tracer(sink, &format!("sessions-{}", self.policy.label()));
        }
        let engine = engine;
        let reference = ReferenceRenderer::new(server.config.width, server.config.height)
            .with_backend(server.config.render_backend);
        let fallback_bytes_per_frame = shared.prep.layout.total_span_bytes() as f64 / 10.0;
        let mut seeded = std::mem::take(&mut self.seeded);

        // Split the script into join-ordered sessions and leave events.
        let mut joins: Vec<(usize, SessionSpec)> = Vec::new();
        let mut leaves: Vec<(usize, usize)> = Vec::new();
        for ev in &script.events {
            match ev {
                SessionEvent::JoinAt { frame, spec } => joins.push((*frame, spec.clone())),
                SessionEvent::LeaveAt { frame, session } => leaves.push((*frame, *session)),
            }
        }
        // One-pass validation: the `seen` bitset replaces the former
        // O(L²) duplicate-leave scan.
        {
            let mut seen = vec![false; joins.len()];
            for &(frame, session) in &leaves {
                assert!(session < joins.len(), "leave for unknown session {session}");
                assert!(
                    frame > joins[session].0,
                    "session {session} leaves at round {frame}, on or before its join round {}",
                    joins[session].0
                );
                assert!(!seen[session], "session {session} leaves twice");
                seen[session] = true;
            }
        }
        let last_event_round = joins
            .iter()
            .map(|&(f, _)| f)
            .chain(leaves.iter().map(|&(f, _)| f))
            .max()
            .unwrap_or(0);

        let indexed = self.sched_impl == SchedImpl::Indexed;
        // Donors a later cold-start join warm-starts from: their retained
        // state must survive the leave even in discard-detached mode.
        let mut warm_needed = vec![false; joins.len()];
        for (_, spec) in &joins {
            if spec.resume_from.is_none() {
                if let Some(d) = spec.warm_from {
                    if let Some(slot) = warm_needed.get_mut(d) {
                        *slot = true;
                    }
                }
            }
        }

        // Event index (indexed mode): events stable-sorted by round, with
        // monotone cursors — each event is visited exactly once over the
        // whole run instead of once per round. Stability preserves the
        // reference semantics within a round: ids ascending for joins
        // (session ids are join-ordered), script order for leaves.
        let mut joins_by_round: Vec<(usize, usize)> = Vec::new();
        let mut leaves_by_round: Vec<(usize, usize)> = Vec::new();
        if indexed {
            joins_by_round = joins.iter().enumerate().map(|(id, &(f, _))| (f, id)).collect();
            joins_by_round.sort_by_key(|&(f, _)| f);
            leaves_by_round = leaves.clone();
            leaves_by_round.sort_by_key(|&(f, _)| f);
        }
        let mut join_cursor = 0usize;
        let mut leave_cursor = 0usize;

        let mut sessions: Vec<Option<ViewerSession<'a>>> =
            (0..joins.len()).map(|_| None).collect();
        let mut ring: Vec<usize> = Vec::new(); // reference: admitted, not-left, join order
        // Indexed equivalents of the ring scans: an O(1)-removal linked
        // ring with identical traversal order, the DWFQ/EDF keyed heap,
        // and a maintained renderable-member count.
        let mut ring2 = LinkedRing::new(joins.len());
        let mut heap = KeyedMinHeap::new();
        let mut renderable_count = 0usize;
        let mut pending: VecDeque<usize> = VecDeque::new();
        let mut pre_latency: Vec<f64> = Vec::new();
        let mut blend_latency: Vec<f64> = Vec::new();
        let mut admitted_demand = 0.0f64;
        let mut measured_bytes = 0.0f64;
        let mut measured_frames = 0u64;
        let mut fire: Vec<usize> = Vec::new(); // this round's event ids (reused)
        let mut order: Vec<usize> = Vec::new(); // this round's issue order (reused)
        let mut overhead_ns: Vec<f64> = Vec::new();
        let mut peak_live = 0usize;

        let mut round = 0usize;
        loop {
            let t_round = Instant::now();
            // Simulated timestamp this round's lifecycle instants anchor
            // to: the shared system's horizon entering the round —
            // deterministic across host thread counts.
            let round_t = if engine.tracer().is_some() {
                engine.sys().lock().expect("memory system lock poisoned").horizon_ns()
            } else {
                0.0
            };

            // 1 — departures scheduled this round (before joins, so a
            // leaver's bandwidth is released to the admission check). The
            // session record always exists here: its join round is
            // strictly earlier (validated above). The indexed path reads
            // this round's slice of the event index; the reference path
            // re-scans every leave event.
            fire.clear();
            if indexed {
                while leave_cursor < leaves_by_round.len()
                    && leaves_by_round[leave_cursor].0 == round
                {
                    fire.push(leaves_by_round[leave_cursor].1);
                    leave_cursor += 1;
                }
            } else {
                fire.extend(
                    leaves.iter().filter(|&&(frame, _)| frame == round).map(|&(_, id)| id),
                );
            }
            for &id in &fire {
                let s = sessions[id].as_mut().expect("leave validated against join round");
                let was_renderable = s.renderable();
                let was_pending = s.admitted_round.is_none();
                s.left_round = Some(round);
                admitted_demand -= s.demand_bytes_per_s;
                s.demand_bytes_per_s = 0.0;
                if let Some(pipeline) = s.pipeline.take() {
                    if self.collect_detached || warm_needed[id] {
                        let mut state = pipeline.detach_session();
                        if !self.collect_detached {
                            // Retained only as a warm-start donor: its AII
                            // intervals matter, its pooled FrameCtx scratch
                            // does not — trim it so parked donors don't hold
                            // peak working set.
                            state.trim_scratch();
                        }
                        s.retained = Some(state);
                    }
                    // else: the pipeline (and its FrameCtx pools) drops here.
                    let mut sys_l =
                        engine.sys().lock().expect("memory system lock poisoned");
                    if let Some(ports) = s.ports {
                        sys_l.retire_port(ports.cull);
                        sys_l.retire_port(ports.blend);
                        if let Some(update) = ports.update {
                            sys_l.retire_port(update);
                        }
                    }
                }
                let detached = s.retained.is_some();
                if indexed {
                    if was_pending {
                        // Deferred past its own leave: close out the defer
                        // count arithmetically (the reference incremented it
                        // once per pending round, i.e. rounds join..round)
                        // and let the queue entry go stale — admission pops
                        // dead heads lazily.
                        s.deferred_rounds = round - s.joined_round;
                    }
                    ring2.remove(id);
                    if was_renderable {
                        renderable_count -= 1;
                        heap.remove(id);
                    }
                } else {
                    ring.retain(|&x| x != id);
                    // A session deferred past its own leave never streams:
                    // drop it from the admission queue too, or a later round
                    // would admit a departed viewer and leak its bandwidth
                    // demand.
                    pending.retain(|&x| x != id);
                }
                lifecycle_instant(
                    &engine,
                    Track::Viewer(id),
                    "leave",
                    round_t,
                    vec![("round", Json::from(round)), ("detached", Json::from(detached))],
                );
            }

            // 2 — arrivals scheduled this round enter the wait queue.
            fire.clear();
            if indexed {
                while join_cursor < joins_by_round.len() && joins_by_round[join_cursor].0 == round
                {
                    fire.push(joins_by_round[join_cursor].1);
                    join_cursor += 1;
                }
            } else {
                fire.extend(
                    joins
                        .iter()
                        .enumerate()
                        .filter(|&(_, &(frame, _))| frame == round)
                        .map(|(id, _)| id),
                );
            }
            for &id in &fire {
                let spec = &joins[id].1;
                let traj = scene_trajectory_from(
                    &shared.scene,
                    &server.config,
                    server.orbit_radius,
                    spec.condition,
                    spec.start_frame,
                    spec.frames,
                );
                sessions[id] = Some(ViewerSession {
                    spec: spec.clone(),
                    pipeline: None,
                    ports: None,
                    traj,
                    cursor: 0,
                    joined_round: round,
                    admitted_round: None,
                    left_round: None,
                    deferred_rounds: 0,
                    agg: SequenceAgg::new(),
                    latency: Vec::new(),
                    missed: 0,
                    busy_ns: 0.0,
                    minmax_scanned: 0,
                    bucketed: 0,
                    warm_started: false,
                    resumed: false,
                    demand_bytes_per_s: 0.0,
                    retained: None,
                });
                pending.push_back(id);
                lifecycle_instant(
                    &engine,
                    Track::Viewer(id),
                    "join",
                    round_t,
                    vec![("round", Json::from(round))],
                );
            }

            // 3 — admission control (join order; work-conserving).
            while let Some(&cand) = pending.front() {
                // Indexed mode leaves departed-while-pending entries in the
                // queue (leave is O(1)); they are popped here, lazily —
                // always before any admission decision, so `pending` is
                // never non-empty with only dead entries after this loop.
                if indexed
                    && sessions[cand].as_ref().is_some_and(|s| s.left_round.is_some())
                {
                    pending.pop_front();
                    continue;
                }
                let est_bytes_per_frame = if measured_frames > 0 {
                    measured_bytes / measured_frames as f64
                } else {
                    fallback_bytes_per_frame
                };
                let demand = {
                    let s = sessions[cand].as_ref().expect("pending session exists");
                    let fps = if s.spec.target_fps > 0.0 {
                        s.spec.target_fps
                    } else {
                        DEFAULT_STREAM_FPS
                    };
                    // A session with no frames to stream reserves nothing —
                    // it can never reach the completion branch that would
                    // release the reservation.
                    if s.traj.is_empty() { 0.0 } else { est_bytes_per_frame * fps }
                };
                let stream_busy = if indexed {
                    renderable_count > 0
                } else {
                    ring.iter()
                        .any(|&id| sessions[id].as_ref().is_some_and(ViewerSession::renderable))
                };
                let fits = match self.dram_budget_bytes_per_s {
                    None => true,
                    Some(budget) => admitted_demand + demand <= budget || !stream_busy,
                };
                if !fits {
                    break;
                }
                pending.pop_front();
                // Resume a seeded detached state from a previous run if
                // the spec asks for one; otherwise build fresh, optionally
                // warm-starting AII intervals from an in-run departed
                // donor's retained state.
                let resume_state = {
                    let key = sessions[cand].as_ref().unwrap().spec.resume_from;
                    key.and_then(|k| {
                        seeded
                            .iter()
                            .position(|&(id, _)| id == k)
                            .map(|pos| seeded.swap_remove(pos).1)
                    })
                };
                let (pipeline, ports, resumed, warm_started) = match resume_state {
                    Some(state) => {
                        let (pipeline, ports) = engine.resume_pipeline(shared, state);
                        (pipeline, ports, true, false)
                    }
                    None => {
                        // `resume_from` and `warm_from` are mutually
                        // exclusive (a resume carries the AII intervals
                        // already): a `resume_from` join whose key was not
                        // seeded cold-starts, exactly as documented —
                        // never silently taking the warm-start path.
                        let warm = {
                            let spec = &sessions[cand].as_ref().unwrap().spec;
                            let donor =
                                if spec.resume_from.is_some() { None } else { spec.warm_from };
                            donor.and_then(|d| {
                                if d == cand {
                                    return None;
                                }
                                sessions
                                    .get_mut(d)
                                    .and_then(|slot| slot.as_mut())
                                    .and_then(|donor| donor.retained.as_mut())
                                    .and_then(SessionState::take_aii_intervals)
                            })
                        };
                        let (mut pipeline, ports) = engine.make_pipeline(shared);
                        let warm_started =
                            warm.map(|iv| pipeline.warm_start_aii(iv)).unwrap_or(false);
                        (pipeline, ports, false, warm_started)
                    }
                };
                let s = sessions[cand].as_mut().unwrap();
                s.warm_started = warm_started;
                s.resumed = resumed;
                s.pipeline = Some(pipeline);
                s.ports = Some(ports);
                s.admitted_round = Some(round);
                s.demand_bytes_per_s = demand;
                admitted_demand += demand;
                if indexed {
                    // Rounds spent deferred = join-to-admission distance
                    // (the reference incremented once per deferred round).
                    s.deferred_rounds = round - s.joined_round;
                    ring2.push_back(cand);
                    if s.renderable() {
                        renderable_count += 1;
                        if self.policy != SchedPolicy::RoundRobin {
                            heap.update(cand, policy_key(self.policy, s));
                        }
                    }
                } else {
                    ring.push(cand);
                }
                lifecycle_instant(
                    &engine,
                    Track::Viewer(cand),
                    if resumed { "resume" } else { "admit" },
                    round_t,
                    vec![
                        ("round", Json::from(round)),
                        ("warm_started", Json::from(warm_started)),
                    ],
                );
            }
            if indexed {
                // The reference's per-round defer bookkeeping is folded
                // into the arithmetic above; only the trace instants remain
                // (same stream: dead queue entries were never emitted by
                // the reference either).
                if engine.tracer().is_some() {
                    for &id in &pending {
                        if sessions[id].as_ref().is_some_and(|s| s.left_round.is_none()) {
                            lifecycle_instant(
                                &engine,
                                Track::Scheduler,
                                "defer",
                                round_t,
                                vec![
                                    ("session", Json::from(id)),
                                    ("round", Json::from(round)),
                                ],
                            );
                        }
                    }
                }
            } else {
                for &id in &pending {
                    if let Some(s) = sessions[id].as_mut() {
                        s.deferred_rounds += 1;
                    }
                    lifecycle_instant(
                        &engine,
                        Track::Scheduler,
                        "defer",
                        round_t,
                        vec![("session", Json::from(id)), ("round", Json::from(round))],
                    );
                }
            }

            // 4 — stream end?
            let renderable = if indexed {
                renderable_count > 0
            } else {
                ring.iter()
                    .any(|&id| sessions[id].as_ref().is_some_and(ViewerSession::renderable))
            };
            if !renderable && pending.is_empty() && round >= last_event_round {
                overhead_ns.push(t_round.elapsed().as_secs_f64() * 1e9);
                break;
            }

            // 5 — policy-ordered round through the shared engine (which
            // takes the frame-epoch barrier; an idle round awaiting a
            // future join still advances the epoch).
            let mut jobs: Vec<RoundJob<'_, '_>> = Vec::new();
            if indexed {
                match self.policy {
                    SchedPolicy::RoundRobin => {
                        // Ring traversal = admission order = the reference
                        // ring; the same `(round + k) mod n` rotation.
                        ring2.collect_into(&mut order);
                        if !order.is_empty() {
                            let n = order.len();
                            order.rotate_left(round % n);
                        }
                    }
                    // Ascending (key, id) straight off the heap — the exact
                    // order the reference's full sort produces. The drain
                    // empties the queue; rendered-and-still-renderable
                    // sessions re-enter below with their fresh keys.
                    _ => heap.drain_ordered_into(&mut order),
                }
                jobs.reserve(order.len());
                let base = sessions.as_mut_ptr();
                for &id in &order {
                    // SAFETY: `order` holds distinct session ids (the ring
                    // is a permutation of admitted live sessions; the heap
                    // pops each live id at most once per drain), so each
                    // iteration borrows a *different* `sessions` element,
                    // and the Vec is never resized while the borrows live.
                    let slot = unsafe { &mut *base.add(id) };
                    let Some(s) = slot.as_mut() else { continue };
                    if !s.renderable() {
                        continue;
                    }
                    let (cam, t) = s.traj[s.cursor];
                    jobs.push(RoundJob {
                        key: id,
                        cam,
                        t,
                        render: s.spec.psnr_every > 0 && s.cursor % s.spec.psnr_every == 0,
                        ports: s.ports.expect("renderable session has ports"),
                        pipeline: s
                            .pipeline
                            .as_mut()
                            .expect("renderable session has a pipeline"),
                    });
                }
            } else {
                order = issue_order(self.policy, round, &ring, &sessions);
                let mut rank = vec![usize::MAX; sessions.len()];
                for (i, &id) in order.iter().enumerate() {
                    rank[id] = i;
                }
                jobs.reserve(order.len());
                for (id, slot) in sessions.iter_mut().enumerate() {
                    let Some(s) = slot.as_mut() else { continue };
                    // Round-robin keeps completed sessions in the issue order
                    // (rotation parity with the batch path); they are skipped
                    // here, at render time.
                    if rank[id] == usize::MAX || !s.renderable() {
                        continue;
                    }
                    let (cam, t) = s.traj[s.cursor];
                    jobs.push(RoundJob {
                        key: id,
                        cam,
                        t,
                        render: s.spec.psnr_every > 0 && s.cursor % s.spec.psnr_every == 0,
                        ports: s.ports.expect("renderable session has ports"),
                        pipeline: s
                            .pipeline
                            .as_mut()
                            .expect("renderable session has a pipeline"),
                    });
                }
                jobs.sort_by_key(|j| rank[j.key]);
            }
            peak_live = peak_live.max(jobs.len());
            let pre_ns = t_round.elapsed().as_secs_f64() * 1e9;
            let outcomes = engine.run_round(&shared.scene, &reference, jobs);
            let t_post = Instant::now();
            for out in outcomes {
                let s = sessions[out.key].as_mut().expect("outcome for a live session");
                let r = &out.result;
                pre_latency.push(r.latency.preprocess_ns);
                blend_latency.push(r.latency.blend_ns);
                let frame_ns = r.latency.pipelined_ns();
                s.latency.push(frame_ns);
                if frame_ns > s.spec.deadline_ns() {
                    s.missed += 1;
                }
                let frame_busy = r.traffic.preprocess_dram.busy_ns
                    + r.traffic.blend_dram.busy_ns
                    + r.traffic.update_dram.busy_ns;
                s.busy_ns += frame_busy;
                let frame_bytes = r.traffic.total_dram_bytes() as f64;
                measured_bytes += frame_bytes;
                measured_frames += 1;
                s.minmax_scanned += r.sort.minmax_scanned;
                s.bucketed += r.sort.bucketed;
                s.agg.push(r, out.scored);
                s.cursor += 1;
                if s.cursor >= s.traj.len() {
                    // Completed: release the bandwidth reservation (the
                    // session stays in the ring for rotation parity with
                    // the batch path until it leaves or the stream ends).
                    admitted_demand -= s.demand_bytes_per_s;
                    s.demand_bytes_per_s = 0.0;
                    if indexed {
                        renderable_count -= 1;
                    }
                } else if indexed && self.policy != SchedPolicy::RoundRobin {
                    // Re-key only the sessions that rendered this round —
                    // the indexed replacement for the per-round full sort.
                    heap.update(out.key, policy_key(self.policy, s));
                }
            }
            overhead_ns.push(pre_ns + t_post.elapsed().as_secs_f64() * 1e9);
            round += 1;
        }

        self.last_overhead_ns = overhead_ns;
        self.assemble(sessions, round, &engine, pre_latency, blend_latency, peak_live, t0)
    }

    /// Final report assembly (per-session reports + the shared roll-up),
    /// also collecting every session's detached pipeline state for
    /// [`SessionScheduler::take_detached`] (unless the scheduler runs
    /// [`SessionScheduler::discard_detached`]).
    #[allow(clippy::too_many_arguments)]
    fn assemble(
        &mut self,
        sessions: Vec<Option<ViewerSession<'_>>>,
        rounds: usize,
        engine: &RoundEngine,
        pre_latency: Vec<f64>,
        blend_latency: Vec<f64>,
        peak_live: usize,
        t0: Instant,
    ) -> SessionBatchReport {
        let scene = &self.server.shared.scene;
        let sys = engine.sys();
        let config = engine.config();
        // Port list of admitted sessions, in session-id order (un-admitted
        // sessions rendered nothing and own no ports).
        let port_ids: Vec<RoundPorts> =
            sessions.iter().flatten().filter_map(|s| s.ports).collect();
        // Session ids owning those ports, in the same order, so the roll-up
        // labels its viewer rows directly (identical to the old positional
        // re-attribution pass, without it).
        let admitted_ids: Vec<usize> = sessions
            .iter()
            .enumerate()
            .filter(|(_, s)| s.as_ref().is_some_and(|s| s.ports.is_some()))
            .map(|(id, _)| id)
            .collect();
        let contended = contended_rollup(
            sys,
            &port_ids,
            Some(&admitted_ids),
            config.mem.outstanding,
            &pre_latency,
            &blend_latency,
        );
        // Row index by session id — the per-session lookup below used to
        // re-scan the row list per session (O(n²) at 10k sessions).
        let row_of: std::collections::BTreeMap<usize, usize> =
            contended.viewers.iter().enumerate().map(|(i, v)| (v.viewer, i)).collect();

        let mut reports = Vec::with_capacity(sessions.len());
        let mut all_latency: Vec<f64> = Vec::new();
        let mut admission_waits: Vec<f64> = Vec::new();
        let mut missed_total = 0u64;
        let mut deadline_frames = 0u64;
        let mut total_frames = 0usize;
        let mut detached: Vec<(usize, SessionState)> = Vec::new();
        for (id, slot) in sessions.into_iter().enumerate() {
            let Some(mut s) = slot else { continue };
            // Persist the session's pipeline state for a future run: an
            // explicitly-departed session detached at its leave round; a
            // session still live at stream end detaches here. In
            // discard-detached mode nothing is parked — states exist only
            // while a `warm_from` donor needs them.
            if self.collect_detached {
                if let Some(state) = s.retained.take() {
                    detached.push((id, state));
                } else if let Some(pipeline) = s.pipeline.take() {
                    detached.push((id, pipeline.detach_session()));
                }
            }
            let frames = s.cursor;
            total_frames += frames;
            all_latency.extend_from_slice(&s.latency);
            admission_waits.push(s.deferred_rounds as f64);
            if s.spec.target_fps > 0.0 {
                missed_total += s.missed;
                deadline_frames += frames as u64;
            }
            let agg = std::mem::replace(&mut s.agg, SequenceAgg::new());
            let seq = agg.finish(
                viewer_label(&scene.name, id, s.spec.condition),
                config.dcim.area_mm2,
                scene.dynamic,
            );
            let mem = row_of
                .get(&id)
                .map(|&i| contended.viewers[i].clone())
                .unwrap_or_else(|| ViewerMemStats {
                    viewer: id,
                    preprocess: Default::default(),
                    blend: Default::default(),
                    update: None,
                });
            reports.push(SessionReport {
                session: id,
                joined_round: s.joined_round,
                admitted_round: s.admitted_round.unwrap_or(s.joined_round),
                deferred_rounds: s.deferred_rounds,
                left_round: s.left_round.unwrap_or(rounds),
                frames,
                target_fps: s.spec.target_fps,
                weight: s.spec.weight,
                warm_started: s.warm_started,
                resumed: s.resumed,
                missed_deadlines: s.missed,
                deadline_miss_rate: if s.spec.target_fps > 0.0 && frames > 0 {
                    s.missed as f64 / frames as f64
                } else {
                    0.0
                },
                frame_latency_pctl: LatencyLadder::of(&s.latency),
                aii_interval_hit_rate: if s.bucketed > 0 {
                    1.0 - s.minmax_scanned as f64 / s.bucketed as f64
                } else {
                    0.0
                },
                mem,
                seq,
            });
        }

        let report = SessionBatchReport {
            policy: self.policy,
            rounds,
            total_frames,
            peak_live,
            wall_s: t0.elapsed().as_secs_f64(),
            deadline_miss_rate: if deadline_frames > 0 {
                missed_total as f64 / deadline_frames as f64
            } else {
                0.0
            },
            frame_latency_pctl: LatencyLadder::of(&all_latency),
            admission_wait_rounds: LatencyLadder::of(&admission_waits),
            sessions: reports,
            contended,
        };
        self.detached = detached;
        report
    }
}

/// Emit one session-lifecycle instant onto the engine's trace process (a
/// no-op without an attached tracer). `ts_ns` is a simulated-time
/// quantity and the call sites run in deterministic script order, so the
/// recorded stream is bit-identical across host thread counts.
fn lifecycle_instant(
    engine: &RoundEngine,
    track: Track,
    name: &str,
    ts_ns: f64,
    args: Vec<(&'static str, Json)>,
) {
    if let Some((sink, pid)) = engine.tracer() {
        let mut tr = sink.lock().expect("tracer lock poisoned");
        tr.instant(*pid, track, name, "session", ts_ns, args);
    }
}

/// The per-policy scheduling key (ascending issues first). Shared by the
/// sort-based reference and the indexed keyed heap, so the two orderings
/// cannot drift. Round-robin never consults a key (its order is the ring
/// rotation).
fn policy_key(policy: SchedPolicy, s: &ViewerSession<'_>) -> f64 {
    match policy {
        SchedPolicy::RoundRobin => 0.0,
        SchedPolicy::Dwfq => s.busy_ns / s.spec.weight.max(1e-9),
        SchedPolicy::Edf => (s.cursor + 1) as f64 * s.spec.deadline_ns(),
    }
}

/// Ascending `(key, session id)` via `f64::total_cmp`: a NaN key orders
/// deterministically after `+inf` instead of collapsing the comparison to
/// `Equal` (which would silently defeat the id tie-break and leave the
/// issue order at the sort algorithm's mercy).
fn key_order(a: (f64, usize), b: (f64, usize)) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
}

/// The policy-ordered issue list of one round — the sort-based reference
/// path. Round-robin rotates the whole ring (completed sessions are
/// skipped at render time, preserving the batch path's `(round + k) mod n`
/// arithmetic); DWFQ and EDF sort the renderable sessions by
/// [`policy_key`] with session-id tie-breaks — every input is simulated
/// state, so the order is deterministic.
fn issue_order(
    policy: SchedPolicy,
    round: usize,
    ring: &[usize],
    sessions: &[Option<ViewerSession<'_>>],
) -> Vec<usize> {
    if ring.is_empty() {
        return Vec::new();
    }
    match policy {
        SchedPolicy::RoundRobin => {
            (0..ring.len()).map(|k| ring[(round + k) % ring.len()]).collect()
        }
        _ => sorted_by_key(ring, sessions, |id| {
            policy_key(policy, sessions[id].as_ref().expect("ring holds live sessions"))
        }),
    }
}

/// Renderable ring members sorted ascending by `key`, ties broken by
/// session id ([`key_order`]).
fn sorted_by_key(
    ring: &[usize],
    sessions: &[Option<ViewerSession<'_>>],
    key: impl Fn(usize) -> f64,
) -> Vec<usize> {
    let mut ids: Vec<usize> = ring
        .iter()
        .copied()
        .filter(|&id| sessions[id].as_ref().is_some_and(ViewerSession::renderable))
        .collect();
    ids.sort_by(|&a, &b| key_order((key(a), a), (key(b), b)));
    ids
}

/// An array-backed doubly-linked list over session ids with O(1)
/// membership, O(1) push-back, and O(1) *order-preserving* removal — the
/// indexed replacement for the reference scheduler's `Vec` ring, whose
/// `retain`-based removal is O(ring) per leave. Traversal order is
/// insertion order, exactly like push + retain, so the round-robin
/// rotation arithmetic lands on the same sessions.
struct LinkedRing {
    /// `next[id]` / `prev[id]`; index `n` is the sentinel closing the
    /// cycle. `ABSENT` marks non-members.
    next: Vec<usize>,
    prev: Vec<usize>,
    len: usize,
}

const ABSENT: usize = usize::MAX;

impl LinkedRing {
    fn new(n: usize) -> LinkedRing {
        let mut next = vec![ABSENT; n + 1];
        let mut prev = vec![ABSENT; n + 1];
        next[n] = n; // empty cycle: sentinel points at itself
        prev[n] = n;
        LinkedRing { next, prev, len: 0 }
    }

    fn sentinel(&self) -> usize {
        self.next.len() - 1
    }

    fn contains(&self, id: usize) -> bool {
        self.next[id] != ABSENT
    }

    fn push_back(&mut self, id: usize) {
        debug_assert!(!self.contains(id), "ring already holds {id}");
        let s = self.sentinel();
        let tail = self.prev[s];
        self.next[tail] = id;
        self.prev[id] = tail;
        self.next[id] = s;
        self.prev[s] = id;
        self.len += 1;
    }

    /// Unlink `id` (no-op if absent), preserving the order of the rest.
    fn remove(&mut self, id: usize) {
        if !self.contains(id) {
            return;
        }
        let (p, n) = (self.prev[id], self.next[id]);
        self.next[p] = n;
        self.prev[n] = p;
        self.next[id] = ABSENT;
        self.prev[id] = ABSENT;
        self.len -= 1;
    }

    /// Members in insertion order, into a reused buffer.
    fn collect_into(&self, into: &mut Vec<usize>) {
        into.clear();
        into.reserve(self.len);
        let s = self.sentinel();
        let mut cur = self.next[s];
        while cur != s {
            into.push(cur);
            cur = self.next[cur];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn script_builder_counts_sessions() {
        let script = SessionScript::new()
            .join_at(0, SessionSpec::stream(ViewCondition::Average, 4))
            .join_at(2, SessionSpec::stream(ViewCondition::Static, 2).with_deadline_fps(90.0))
            .leave_at(3, 0);
        assert_eq!(script.n_sessions(), 2);
        assert_eq!(script.events.len(), 3);
    }

    #[test]
    fn peak_concurrency_processes_leaves_before_joins() {
        // Non-overlapping handoff: the leaver exits the round its
        // successor joins, so at most one session is ever live.
        let handoff = SessionScript::new()
            .join_at(0, SessionSpec::stream(ViewCondition::Average, 8))
            .leave_at(8, 0)
            .join_at(8, SessionSpec::stream(ViewCondition::Static, 4));
        assert_eq!(handoff.n_sessions(), 2);
        assert_eq!(handoff.peak_concurrency(), 1);

        let overlapping = SessionScript::new()
            .join_at(0, SessionSpec::stream(ViewCondition::Average, 8))
            .join_at(2, SessionSpec::stream(ViewCondition::Static, 4))
            .leave_at(4, 0)
            .join_at(6, SessionSpec::stream(ViewCondition::Extreme, 2));
        assert_eq!(overlapping.peak_concurrency(), 2);

        assert_eq!(SessionScript::new().peak_concurrency(), 0);
    }

    #[test]
    fn static_script_adopts_viewer_specs() {
        let specs = [
            ViewerSpec::perf(ViewCondition::Average, 3),
            ViewerSpec { condition: ViewCondition::Static, frames: 2, psnr_every: 2 },
        ];
        let script = SessionScript::from_specs(&specs);
        assert_eq!(script.n_sessions(), 2);
        match &script.events[1] {
            SessionEvent::JoinAt { frame, spec } => {
                assert_eq!(*frame, 0);
                assert_eq!(spec.frames, 2);
                assert_eq!(spec.psnr_every, 2);
                assert_eq!(spec.start_frame, 0);
            }
            other => panic!("expected JoinAt, got {other:?}"),
        }
    }

    #[test]
    fn spec_deadline_conversion() {
        let spec = SessionSpec::stream(ViewCondition::Average, 1).with_deadline_fps(200.0);
        assert!((spec.deadline_ns() - 5e6).abs() < 1e-6);
        assert_eq!(SessionSpec::stream(ViewCondition::Average, 1).deadline_ns(), f64::INFINITY);
    }

    #[test]
    fn policy_labels_are_stable() {
        assert_eq!(SchedPolicy::RoundRobin.label(), "round_robin");
        assert_eq!(SchedPolicy::Dwfq.label(), "dwfq");
        assert_eq!(SchedPolicy::Edf.label(), "edf");
        assert_eq!(SchedPolicy::ALL.len(), 3);
    }

    #[test]
    fn script_json_round_trips_exactly() {
        let script = SessionScript::new()
            .join_at(
                0,
                SessionSpec::stream(ViewCondition::Average, 12)
                    .with_deadline_fps(120.0)
                    .with_weight(2.0)
                    .with_psnr_every(3),
            )
            .join_at(
                4,
                SessionSpec::stream(ViewCondition::Extreme, 8).with_start(4),
            )
            .leave_at(8, 0)
            .join_at(8, SessionSpec::stream(ViewCondition::Static, 6).with_warm_from(0))
            .join_at(9, SessionSpec::stream(ViewCondition::Static, 4).with_resume_from(2));
        let text = script.to_json().pretty();
        let parsed = SessionScript::from_json_str(&text).expect("round-trip parse");
        assert_eq!(parsed.to_json().pretty(), text);
        assert_eq!(parsed.n_sessions(), 4);
        match &parsed.events[0] {
            SessionEvent::JoinAt { frame, spec } => {
                assert_eq!(*frame, 0);
                assert_eq!(spec.frames, 12);
                assert_eq!(spec.target_fps, 120.0);
                assert_eq!(spec.weight, 2.0);
                assert_eq!(spec.psnr_every, 3);
                assert_eq!(spec.warm_from, None);
            }
            other => panic!("expected JoinAt, got {other:?}"),
        }
        match &parsed.events[3] {
            SessionEvent::JoinAt { spec, .. } => {
                assert_eq!(spec.warm_from, Some(0));
                assert_eq!(spec.resume_from, None);
            }
            other => panic!("expected JoinAt, got {other:?}"),
        }
        match &parsed.events[4] {
            SessionEvent::JoinAt { spec, .. } => {
                assert_eq!(spec.warm_from, None);
                assert_eq!(spec.resume_from, Some(2));
            }
            other => panic!("expected JoinAt, got {other:?}"),
        }
    }

    #[test]
    fn script_json_rejects_malformed_documents() {
        assert!(SessionScript::from_json_str("{}").is_err());
        assert!(SessionScript::from_json_str("not json").is_err());
        assert!(SessionScript::from_json_str(
            r#"{"events": [{"type": "join", "frame": 0}]}"#
        )
        .is_err());
        assert!(SessionScript::from_json_str(
            r#"{"events": [{"type": "join", "frame": 0,
                "spec": {"condition": "sideways", "frames": 2}}]}"#
        )
        .is_err());
        assert!(SessionScript::from_json_str(
            r#"{"events": [{"type": "leave", "frame": 1}]}"#
        )
        .is_err());
        assert!(SessionScript::from_json_str(
            r#"{"events": [{"type": "warp", "frame": 1}]}"#
        )
        .is_err());
        // warm_from and resume_from are mutually exclusive.
        assert!(SessionScript::from_json_str(
            r#"{"events": [{"type": "join", "frame": 0,
                "spec": {"condition": "static", "frames": 2,
                         "warm_from": 0, "resume_from": 0}}]}"#
        )
        .is_err());
        // Present-but-mistyped fields are errors, not silent defaults…
        assert!(SessionScript::from_json_str(
            r#"{"events": [{"type": "join", "frame": 0,
                "spec": {"condition": "static", "frames": 2, "target_fps": "120"}}]}"#
        )
        .is_err());
        assert!(SessionScript::from_json_str(
            r#"{"events": [{"type": "join", "frame": 0,
                "spec": {"condition": "static", "frames": 2.5}}]}"#
        )
        .is_err());
        // …and so are unknown spec fields (typos never pass silently).
        assert!(SessionScript::from_json_str(
            r#"{"events": [{"type": "join", "frame": 0,
                "spec": {"condition": "static", "frames": 2, "warm_form": 0}}]}"#
        )
        .is_err());
        // Defaults: a minimal join spec parses to SessionSpec::stream.
        let minimal = SessionScript::from_json_str(
            r#"{"events": [{"type": "join", "frame": 0,
                "spec": {"condition": "static", "frames": 3}}]}"#,
        )
        .expect("minimal spec parses");
        match &minimal.events[0] {
            SessionEvent::JoinAt { spec, .. } => {
                assert_eq!(spec.frames, 3);
                assert_eq!(spec.start_frame, 0);
                assert_eq!(spec.target_fps, 0.0);
                assert_eq!(spec.weight, 1.0);
                assert_eq!(spec.warm_from, None);
                assert_eq!(spec.resume_from, None);
            }
            other => panic!("expected JoinAt, got {other:?}"),
        }
    }

    #[test]
    fn sched_impl_labels_are_stable() {
        assert_eq!(SchedImpl::Indexed.label(), "indexed");
        assert_eq!(SchedImpl::ReferenceSort.label(), "reference_sort");
    }

    #[test]
    fn key_order_is_total_over_nan_keys() {
        use std::cmp::Ordering;
        // NaN orders after +inf under total_cmp — never Equal to a real
        // key, so the id tie-break is reserved for true key ties.
        assert_eq!(key_order((f64::NAN, 0), (f64::INFINITY, 1)), Ordering::Greater);
        assert_eq!(key_order((1.0, 5), (f64::NAN, 0)), Ordering::Less);
        assert_eq!(key_order((f64::NAN, 2), (f64::NAN, 7)), Ordering::Less);
        assert_eq!(key_order((3.5, 9), (3.5, 4)), Ordering::Greater);
        // A full sort with NaN keys is deterministic: NaNs sink to the
        // end, id-ordered.
        let mut items = vec![(f64::NAN, 4), (2.0, 1), (f64::NAN, 3), (f64::INFINITY, 0)];
        items.sort_by(|&a, &b| key_order(a, b));
        let ids: Vec<usize> = items.iter().map(|&(_, id)| id).collect();
        assert_eq!(ids, vec![1, 0, 3, 4]);
    }

    #[test]
    fn linked_ring_preserves_insertion_order_across_removals() {
        let mut ring = LinkedRing::new(6);
        let mut got = Vec::new();
        ring.collect_into(&mut got);
        assert!(got.is_empty());

        for id in [3, 0, 5, 1, 4] {
            ring.push_back(id);
        }
        ring.collect_into(&mut got);
        assert_eq!(got, vec![3, 0, 5, 1, 4]);
        assert_eq!(ring.len, 5);

        ring.remove(5); // middle
        ring.remove(3); // head
        ring.remove(4); // tail
        ring.remove(2); // never inserted: no-op
        ring.collect_into(&mut got);
        assert_eq!(got, vec![0, 1]);
        assert_eq!(ring.len, 2);
        assert!(ring.contains(0) && ring.contains(1));
        assert!(!ring.contains(5));

        // Re-insertion goes to the back, like Vec push after retain.
        ring.push_back(5);
        ring.collect_into(&mut got);
        assert_eq!(got, vec![0, 1, 5]);
    }
}
