//! L3 coordinator: drives whole rendering sequences and viewer fleets —
//! scene synthesis (with on-disk caching), trajectory generation, the
//! stage-graph frame pipeline with its posteriori state, PSNR evaluation
//! against the reference renderer, Table-I style report generation, the
//! multi-viewer [`RenderServer`] that shares one immutable scene
//! preparation across N concurrent per-viewer sessions — in parallel with
//! private memory systems (host throughput) or in deterministic lockstep
//! on one shared, contended event-queue memory system
//! ([`RenderServer::render_batch_contended`]) — and the long-lived
//! streaming layer ([`session::SessionScheduler`]): deterministic
//! join/leave scripts (builder or declarative JSON), retained per-session
//! pipeline state (in-run and across runs via `take_detached` /
//! `seed_detached`), pluggable fairness/deadline scheduling policies, and
//! DRAM-bandwidth admission control. Both contended paths execute through
//! the shared two-phase round engine (`rounds`): policy-ordered rounds
//! render host-parallel against trace-recording ports and replay into the
//! shared memory system in the exact policy order, bit-identically to the
//! serial schedule. See `README.md` in this directory for the
//! session/scheduler and round-engine contracts.

pub mod app;
pub mod config;
pub mod loadgen;
pub(crate) mod rounds;
pub mod server;
pub mod session;

pub use app::{App, DynamicSequenceStats, SequenceReport};
pub use config::ExperimentConfig;
pub use loadgen::{ArrivalProcess, LoadGen, LoadPreset};
pub use server::{
    ContendedMemReport, RenderServer, ServerReport, SharedScene, ViewerMemStats, ViewerSpec,
};
pub use session::{
    SchedImpl, SchedPolicy, SessionBatchReport, SessionEvent, SessionReport, SessionScheduler,
    SessionScript, SessionSpec,
};
