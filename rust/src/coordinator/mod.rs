//! L3 coordinator: drives whole rendering sequences — scene synthesis (with
//! on-disk caching), trajectory generation, the frame pipeline with its
//! posteriori state, PSNR evaluation against the reference renderer, and
//! Table-I style report generation.

pub mod app;
pub mod config;

pub use app::{App, SequenceReport};
pub use config::ExperimentConfig;
