//! L3 coordinator: drives whole rendering sequences and viewer fleets —
//! scene synthesis (with on-disk caching), trajectory generation, the
//! stage-graph frame pipeline with its posteriori state, PSNR evaluation
//! against the reference renderer, Table-I style report generation, and the
//! multi-viewer [`RenderServer`] that shares one immutable scene
//! preparation across N concurrent per-viewer sessions — in parallel with
//! private memory systems (host throughput) or in deterministic lockstep
//! on one shared, contended event-queue memory system
//! ([`RenderServer::render_batch_contended`]).

pub mod app;
pub mod config;
pub mod server;

pub use app::{App, SequenceReport};
pub use config::ExperimentConfig;
pub use server::{
    ContendedMemReport, Percentiles, RenderServer, ServerReport, SharedScene, ViewerMemStats,
    ViewerSpec,
};
