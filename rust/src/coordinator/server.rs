//! Multi-viewer render server: one immutable scene preparation, N
//! concurrent per-viewer frame pipelines.
//!
//! [`SharedScene`] owns the scene plus its offline
//! [`ScenePrep`](crate::pipeline::ScenePrep) (grid partition, DRAM layout,
//! FP16-quantized copy, shard map) behind `Arc`s.
//! [`RenderServer::render_batch`] fans a batch of [`ViewerSpec`]s out over
//! `std::thread::scope` — every viewer gets its own [`FramePipeline`]
//! (hardware models + posteriori state are per-session) borrowing the
//! shared preparation — and reports both the per-viewer
//! [`SequenceReport`]s and the batch's aggregate host throughput.
//!
//! [`RenderServer::render_batch_contended`] is the *memory-fidelity* mode:
//! all viewers register ports on **one shared event-queue
//! [`MemorySystem`]** and are stepped frame-round by frame-round in
//! lockstep (rotating issue order for fairness). Contention is a
//! simulated-time property, so the lockstep *request schedule* keeps it
//! exactly deterministic: per-viewer byte/burst counts stay identical to
//! isolated runs while per-viewer `busy_ns` rises with queueing behind the
//! other viewers' traffic. Execution goes through the shared
//! [`RoundEngine`](super::rounds::RoundEngine): with
//! `PipelineConfig::threads > 1` the batch runs **two-phase** — each
//! round's viewer frames render in parallel against trace-recording ports,
//! then the recorded DRAM requests replay into the shared system in the
//! exact rotating lockstep order — so host throughput scales with cores
//! while every contention stat (fairness, channel utilization, wait/stall)
//! stays bit-identical to the single-threaded lockstep (enforced by the
//! `render_server` suite and the CI threads-matrix job). The per-viewer
//! fairness and channel-utilization roll-up lands in
//! [`ContendedMemReport`].
//!
//! Two throughput numbers must not be confused:
//! * `SequenceReport::report.fps` — the **modeled accelerator** frame rate
//!   (hardware cycles/energy roll-up), independent of the host machine;
//! * [`ServerReport::aggregate_frames_per_s`] — the **host simulation**
//!   throughput across all viewers (total frames / wall-clock), the number
//!   multi-viewer parallelism improves.
//!
//! Determinism contract (enforced by the `render_server` test): a batch of
//! N viewers produces per-viewer stats identical to N sequential
//! single-viewer runs — both paths execute the exact same shared
//! sequence-runner over the exact same trajectories.

use crate::camera::{Camera, ViewCondition};
use crate::memory::{DramStats, MemStage, MemorySystem, ResidencyReport, ShardMap};
use crate::obs::{Component, LatencyLadder, TraceSink};
use crate::pipeline::{FramePipeline, FrameResult, PipelineConfig, ScenePrep};
use crate::render::ReferenceRenderer;
use crate::scene::Scene;
use crate::util::json::Json;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::app::{
    camera_template, run_frames_report, scene_trajectory, viewer_label, SequenceAgg,
};
use super::rounds::{RoundJob, RoundPorts};
use super::SequenceReport;

/// A scene plus its shared, immutable preparation.
#[derive(Debug, Clone)]
pub struct SharedScene {
    pub scene: Scene,
    pub prep: ScenePrep,
}

impl SharedScene {
    /// Build the preparation once for `scene` under `config`.
    pub fn prepare(scene: Scene, config: &PipelineConfig) -> SharedScene {
        let prep = ScenePrep::build(&scene, config);
        SharedScene { scene, prep }
    }

    /// A per-viewer pipeline borrowing this preparation (cheap: four `Arc`
    /// clones + per-session hardware-model state).
    pub fn pipeline(&self, config: PipelineConfig) -> FramePipeline<'_> {
        FramePipeline::with_prep(&self.scene, self.prep.clone(), config)
    }

    /// A per-viewer pipeline whose cull/blend ports register on a shared,
    /// contended event-queue memory system.
    pub fn pipeline_with_memory(
        &self,
        config: PipelineConfig,
        sys: Arc<Mutex<MemorySystem>>,
    ) -> FramePipeline<'_> {
        FramePipeline::with_shared_memory(&self.scene, self.prep.clone(), config, sys)
    }

    /// Shard-aware address translation of the scene's DRAM layout.
    pub fn shard_map(&self) -> &ShardMap {
        &self.prep.shard_map
    }

    /// Which channel-group shard Gaussian `gi`'s parameter record lives on.
    pub fn gaussian_shard(&self, gi: usize) -> usize {
        self.prep.shard_map.shard_of(self.prep.layout.addr[gi])
    }
}

/// One viewer session request.
#[derive(Debug, Clone, Copy)]
pub struct ViewerSpec {
    pub condition: ViewCondition,
    pub frames: usize,
    /// Render every n-th frame numerically for PSNR (0 = perf path only).
    pub psnr_every: usize,
}

impl ViewerSpec {
    pub fn perf(condition: ViewCondition, frames: usize) -> ViewerSpec {
        ViewerSpec { condition, frames, psnr_every: 0 }
    }
}

/// Per-viewer DRAM statistics under the shared, contended memory system.
#[derive(Debug, Clone)]
pub struct ViewerMemStats {
    pub viewer: usize,
    pub preprocess: DramStats,
    pub blend: DramStats,
    /// Update-write stream (dynamic serving only — `None` keeps static
    /// reports byte-identical).
    pub update: Option<DramStats>,
}

impl ViewerMemStats {
    pub fn total_busy_ns(&self) -> f64 {
        self.preprocess.busy_ns
            + self.blend.busy_ns
            + self.update.map_or(0.0, |u| u.busy_ns)
    }

    pub fn total_wait_ns(&self) -> f64 {
        self.preprocess.wait_ns
            + self.blend.wait_ns
            + self.update.map_or(0.0, |u| u.wait_ns)
    }

    pub fn total_bytes(&self) -> u64 {
        self.preprocess.bytes + self.blend.bytes + self.update.map_or(0, |u| u.bytes)
    }

    /// Registry [`Component`] of this viewer's contended-memory stats
    /// (nested DRAM stats ride along as raw nodes).
    pub fn component(&self) -> Component {
        let mut c = Component::new()
            .set("viewer", self.viewer)
            .set("preprocess", self.preprocess.to_json())
            .set("blend", self.blend.to_json());
        if let Some(upd) = &self.update {
            c.insert("update", upd.to_json());
        }
        c.set("total_busy_ns", self.total_busy_ns())
            .set("total_wait_ns", self.total_wait_ns())
    }

    pub fn to_json(&self) -> Json {
        self.component().to_json()
    }
}

/// Memory-system roll-up of one contended batch: per-viewer fairness,
/// channel utilization, and per-stage simulated-latency percentiles.
#[derive(Debug, Clone)]
pub struct ContendedMemReport {
    pub shards: usize,
    pub channels: usize,
    pub outstanding: usize,
    /// Simulated completion horizon of the whole batch (ns).
    pub makespan_ns: f64,
    /// Jain fairness index over per-viewer total busy time (1 = perfectly
    /// fair).
    pub fairness: f64,
    /// Per-channel occupancy over the makespan.
    pub channel_util: Vec<f64>,
    pub channel_util_pctl: LatencyLadder,
    /// Per-frame simulated stage latencies across all viewers (ns).
    pub preprocess_latency_pctl: LatencyLadder,
    pub blend_latency_pctl: LatencyLadder,
    pub viewers: Vec<ViewerMemStats>,
    /// Residency-layer roll-up. `Some` only when the shared memory system
    /// pages against a compressed backing store; fully-resident batches
    /// carry `None` so their reports stay byte-identical to a build
    /// without the residency layer.
    pub residency: Option<ResidencyReport>,
}

impl ContendedMemReport {
    /// Registry [`Component`] of the roll-up. Every pre-registry JSON key
    /// is preserved; the percentile blocks carry the full
    /// [`LatencyLadder`] (a strict superset of the old `{p50,p90,p99}`
    /// triple, identical at the shared ranks).
    pub fn component(&self) -> Component {
        let mut c = Component::new()
            .set("shards", self.shards)
            .set("channels", self.channels)
            .set("outstanding", self.outstanding)
            .set("makespan_ns", self.makespan_ns)
            .set("fairness", self.fairness)
            .set(
                "channel_util",
                Json::Arr(self.channel_util.iter().map(|&u| Json::from(u)).collect()),
            )
            .set("channel_util_pctl", self.channel_util_pctl)
            .set("preprocess_latency_ns_pctl", self.preprocess_latency_pctl)
            .set("blend_latency_ns_pctl", self.blend_latency_pctl)
            .set(
                "viewers",
                Json::Arr(self.viewers.iter().map(ViewerMemStats::to_json).collect()),
            );
        if let Some(res) = &self.residency {
            c.insert("residency", res.to_json());
        }
        c
    }

    pub fn to_json(&self) -> Json {
        self.component().to_json()
    }
}

/// Result of one viewer batch.
#[derive(Debug, Clone)]
pub struct ServerReport {
    /// Per-viewer reports, in `specs` order.
    pub viewers: Vec<SequenceReport>,
    /// Wall-clock time of the whole batch (host seconds).
    pub wall_s: f64,
    /// Frames rendered across all viewers.
    pub total_frames: usize,
    /// Host simulation throughput: `total_frames / wall_s`.
    pub aggregate_frames_per_s: f64,
    /// Shared-memory contention roll-up (contended batches only).
    pub contended_mem: Option<ContendedMemReport>,
}

impl ServerReport {
    /// The wall-clock-free projection of a contended report: per-viewer
    /// simulated stats plus the full contended-memory roll-up, as JSON
    /// text (identical f64 values print identically). This is the
    /// bit-identity surface the two-phase executor must preserve — shared
    /// by the determinism unit test and the `multi_viewer` runtime
    /// assertion so the two checks cannot drift apart.
    ///
    /// # Panics
    ///
    /// Panics if the report carries no contended-memory roll-up.
    pub fn simulated_projection(&self) -> String {
        let viewers =
            Json::Arr(self.viewers.iter().map(SequenceReport::to_json).collect()).pretty();
        let mem = self.contended_mem.as_ref().expect("contended roll-up").to_json().pretty();
        format!("{viewers}\n{mem}")
    }

    pub fn to_json(&self) -> Json {
        let mut js = Json::obj()
            .set("viewers", self.viewers.len())
            .set("total_frames", self.total_frames)
            .set("wall_s", self.wall_s)
            .set("aggregate_frames_per_s", self.aggregate_frames_per_s)
            .set(
                "viewer_reports",
                Json::Arr(self.viewers.iter().map(SequenceReport::to_json).collect()),
            );
        if let Some(mem) = &self.contended_mem {
            js = js.set("contended_mem", mem.to_json());
        }
        js
    }
}

/// Assemble the [`ContendedMemReport`] of a shared, contended
/// [`MemorySystem`]: per-viewer port statistics (in `port_ids` order,
/// `(cull, blend)` per viewer), Jain fairness over per-viewer busy time,
/// channel utilization, and the per-frame simulated stage-latency
/// percentiles collected by the caller. `viewer_ids` labels the rows
/// (parallel to `port_ids`); `None` labels them positionally — the batch
/// paths' viewer numbering. Shared by the contended batch paths and the
/// [`super::session::SessionScheduler`] so the roll-ups cannot drift
/// apart — which is what makes the session scheduler's round-robin report
/// bit-comparable to `render_batch_contended`.
pub(crate) fn contended_rollup(
    sys: &Arc<Mutex<MemorySystem>>,
    port_ids: &[RoundPorts],
    viewer_ids: Option<&[usize]>,
    outstanding: usize,
    pre_latency: &[f64],
    blend_latency: &[f64],
) -> ContendedMemReport {
    if let Some(ids) = viewer_ids {
        debug_assert_eq!(ids.len(), port_ids.len(), "viewer_ids must parallel port_ids");
    }
    let sys = sys.lock().expect("memory system lock poisoned");
    let rows: Vec<ViewerMemStats> = port_ids
        .iter()
        .enumerate()
        .map(|(i, ports)| ViewerMemStats {
            viewer: viewer_ids.map_or(i, |ids| ids[i]),
            preprocess: sys.port_stage_stats(ports.cull, MemStage::Preprocess),
            blend: sys.port_stage_stats(ports.blend, MemStage::Blend),
            update: ports.update.map(|uid| sys.port_stage_stats(uid, MemStage::Update)),
        })
        .collect();
    let busy: Vec<f64> = rows.iter().map(ViewerMemStats::total_busy_ns).collect();
    let channel_util = sys.channel_utilization();
    ContendedMemReport {
        shards: sys.shard_map.shards,
        channels: sys.n_channels(),
        outstanding,
        makespan_ns: sys.horizon_ns(),
        fairness: jain_fairness(&busy),
        channel_util_pctl: LatencyLadder::of(&channel_util),
        channel_util,
        preprocess_latency_pctl: LatencyLadder::of(pre_latency),
        blend_latency_pctl: LatencyLadder::of(blend_latency),
        viewers: rows,
        residency: sys.residency_stats(),
    }
}

/// Jain's fairness index over non-negative shares: `(Σx)² / (n·Σx²)`.
pub(crate) fn jain_fairness(shares: &[f64]) -> f64 {
    if shares.is_empty() {
        return 1.0;
    }
    let sum: f64 = shares.iter().sum();
    let sq: f64 = shares.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        1.0
    } else {
        (sum * sum) / (shares.len() as f64 * sq)
    }
}

/// The multi-viewer server.
pub struct RenderServer {
    pub shared: SharedScene,
    pub config: PipelineConfig,
    /// Camera orbit radius (matches [`super::App`]'s default so viewer
    /// trajectories are identical to single-viewer runs).
    pub orbit_radius: f32,
    /// Simulated-time trace sink contended batches and session streams
    /// record into (opt-in; `None` keeps the hot path untouched).
    pub(crate) tracer: Option<TraceSink>,
}

impl RenderServer {
    /// Build a server for `scene` under `config` (prepares the shared
    /// state once).
    pub fn new(scene: Scene, config: PipelineConfig) -> RenderServer {
        let shared = SharedScene::prepare(scene, &config);
        RenderServer { shared, config, orbit_radius: 26.0, tracer: None }
    }

    /// Promote a single-viewer [`super::App`] into a server, reusing its
    /// scene, configuration, and orbit radius.
    pub fn from_app(app: super::App) -> RenderServer {
        let orbit_radius = app.orbit_radius;
        let config = app.config.clone();
        let shared = SharedScene::prepare(app.scene, &config);
        RenderServer { shared, config, orbit_radius, tracer: None }
    }

    /// Attach a simulated-time trace sink: subsequent contended batches
    /// ([`RenderServer::render_batch_contended`]) and session streams
    /// record frame/DRAM spans into it, one Chrome-trace process per run.
    /// Recorded timestamps are simulated ns, so the stream is bit-identical
    /// across host thread counts (enforced by the `observability` suite).
    pub fn set_tracer(&mut self, sink: TraceSink) {
        self.tracer = Some(sink);
    }

    /// The camera template every viewer starts from.
    pub fn camera_template(&self) -> Camera {
        camera_template(&self.config, self.orbit_radius)
    }

    /// The trajectory a given spec resolves to.
    pub fn trajectory(&self, spec: &ViewerSpec) -> Vec<(Camera, f32)> {
        scene_trajectory(
            &self.shared.scene,
            &self.config,
            self.orbit_radius,
            spec.condition,
            spec.frames,
        )
    }

    /// Pin the executor thread count used by subsequent batches (`0` =
    /// auto). Simulated stats are thread-count invariant; this only moves
    /// host wall-clock.
    pub fn set_threads(&mut self, threads: usize) {
        self.config.threads = threads;
    }

    /// Run one viewer session to completion (sequentially, on the calling
    /// thread). This is the exact unit of work `render_batch` parallelizes.
    pub fn render_viewer(&self, viewer_idx: usize, spec: &ViewerSpec) -> SequenceReport {
        self.render_viewer_with(viewer_idx, spec, self.config.clone())
    }

    fn render_viewer_with(
        &self,
        viewer_idx: usize,
        spec: &ViewerSpec,
        config: PipelineConfig,
    ) -> SequenceReport {
        let seq = self.trajectory(spec);
        let mut pipeline = self.shared.pipeline(config);
        run_frames_report(
            &self.shared.scene,
            &mut pipeline,
            &seq,
            spec.psnr_every,
            viewer_label(&self.shared.scene.name, viewer_idx, spec.condition),
        )
    }

    /// Render a batch of viewer sessions in parallel (one scoped thread per
    /// viewer, all borrowing the shared scene preparation). Reports are
    /// returned in `specs` order; a panicking viewer thread propagates.
    /// Every viewer keeps a private memory system — the host-throughput
    /// mode; the viewer thread itself is the parallel unit, so per-viewer
    /// pipelines run their executor serially (`threads = 1`) instead of
    /// oversubscribing the host. See
    /// [`RenderServer::render_batch_contended`] for the shared, contended
    /// memory mode.
    pub fn render_batch(&self, specs: &[ViewerSpec]) -> ServerReport {
        let t0 = Instant::now();
        let viewer_cfg = PipelineConfig { threads: 1, ..self.config.clone() };
        let viewers: Vec<SequenceReport> = std::thread::scope(|scope| {
            let handles: Vec<_> = specs
                .iter()
                .enumerate()
                .map(|(i, spec)| {
                    let cfg = viewer_cfg.clone();
                    scope.spawn(move || self.render_viewer_with(i, spec, cfg))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("viewer session panicked"))
                .collect()
        });
        let wall_s = t0.elapsed().as_secs_f64();
        let total_frames: usize = specs.iter().map(|s| s.frames).sum();
        ServerReport {
            viewers,
            wall_s,
            total_frames,
            aggregate_frames_per_s: total_frames as f64 / wall_s.max(1e-12),
            contended_mem: None,
        }
    }

    /// Render a batch against **one shared, contended event-queue memory
    /// system**: every viewer's cull/blend ports register on the same
    /// [`MemorySystem`], and the request schedule steps frame-round by
    /// frame-round in lockstep (issue order rotates each round so no
    /// viewer systematically goes first). Deterministic by construction —
    /// contention lives on the simulated timeline, not in host scheduling.
    /// Per-viewer byte/burst counts are identical to isolated runs;
    /// per-viewer `busy_ns` additionally carries the queueing behind the
    /// other viewers' traffic.
    ///
    /// Execution is a thin client of the shared
    /// [`RoundEngine`](super::rounds::RoundEngine): with
    /// `PipelineConfig::threads > 1` (and more than one viewer) each
    /// round's frames render in parallel against trace-recording ports and
    /// the traces replay in the exact rotating order above —
    /// [`ContendedMemReport`] and every per-viewer stat stay bit-identical
    /// to the single-threaded lockstep while host throughput scales with
    /// cores. The session scheduler
    /// ([`super::session::SessionScheduler`]) drives its policy-ordered
    /// rounds through the same engine.
    pub fn render_batch_contended(&self, specs: &[ViewerSpec]) -> ServerReport {
        let t0 = Instant::now();
        let mut engine = self.round_engine(specs.len());
        if let Some(sink) = &self.tracer {
            engine.set_tracer(sink, "contended-batch");
        }
        let mut built: Vec<(FramePipeline<'_>, RoundPorts)> =
            specs.iter().map(|_| engine.make_pipeline(&self.shared)).collect();
        let port_ids: Vec<RoundPorts> = built.iter().map(|&(_, ports)| ports).collect();
        let trajectories: Vec<Vec<(Camera, f32)>> =
            specs.iter().map(|s| self.trajectory(s)).collect();
        let reference = ReferenceRenderer::new(self.config.width, self.config.height)
            .with_backend(self.config.render_backend);

        let n = specs.len();
        let max_frames = specs.iter().map(|s| s.frames).max().unwrap_or(0);
        let mut run = ContendedAgg::new(n);

        for round in 0..max_frames {
            let mut jobs: Vec<RoundJob<'_, '_>> = built
                .iter_mut()
                .enumerate()
                .filter(|(v, _)| round < trajectories[*v].len())
                .map(|(v, (pipeline, ports))| {
                    let (cam, t) = trajectories[v][round];
                    let spec = &specs[v];
                    RoundJob {
                        key: v,
                        cam,
                        t,
                        render: spec.psnr_every > 0 && round % spec.psnr_every == 0,
                        ports: *ports,
                        pipeline,
                    }
                })
                .collect();
            // The rotating lockstep order: round r issues viewer
            // (r + k) mod n at position k.
            jobs.sort_by_key(|j| (j.key + n - round % n) % n);
            for out in engine.run_round(&self.shared.scene, &reference, jobs) {
                run.push(out.key, &out.result, out.scored);
            }
        }

        self.finish_contended(engine.sys(), &port_ids, engine.config(), run, specs, t0)
    }

    /// Shared tail of both contended implementations: per-viewer reports,
    /// the memory roll-up, and the batch report.
    fn finish_contended(
        &self,
        sys: &Arc<Mutex<MemorySystem>>,
        port_ids: &[RoundPorts],
        config: &PipelineConfig,
        run: ContendedAgg,
        specs: &[ViewerSpec],
        t0: Instant,
    ) -> ServerReport {
        let ContendedAgg { aggs, pre_latency, blend_latency } = run;
        let viewers: Vec<SequenceReport> = aggs
            .into_iter()
            .enumerate()
            .map(|(i, agg)| {
                agg.finish(
                    viewer_label(&self.shared.scene.name, i, specs[i].condition),
                    config.dcim.area_mm2,
                    self.shared.scene.dynamic,
                )
            })
            .collect();

        let contended = contended_rollup(
            sys,
            port_ids,
            None,
            config.mem.outstanding,
            &pre_latency,
            &blend_latency,
        );

        let wall_s = t0.elapsed().as_secs_f64();
        let total_frames: usize = specs.iter().map(|s| s.frames).sum();
        ServerReport {
            viewers,
            wall_s,
            total_frames,
            aggregate_frames_per_s: total_frames as f64 / wall_s.max(1e-12),
            contended_mem: Some(contended),
        }
    }
}

/// Streaming state both contended implementations feed in the rotating
/// lockstep order: per-viewer aggregates plus the per-frame simulated
/// stage-latency samples of the batch.
struct ContendedAgg {
    aggs: Vec<SequenceAgg>,
    pre_latency: Vec<f64>,
    blend_latency: Vec<f64>,
}

impl ContendedAgg {
    fn new(n: usize) -> ContendedAgg {
        ContendedAgg {
            aggs: (0..n).map(|_| SequenceAgg::new()).collect(),
            pre_latency: Vec::new(),
            blend_latency: Vec::new(),
        }
    }

    fn push(&mut self, viewer: usize, r: &FrameResult, scored: Option<(f64, f64)>) {
        self.pre_latency.push(r.latency.preprocess_ns);
        self.blend_latency.push(r.latency.blend_ns);
        self.aggs[viewer].push(r, scored);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::synth::{SceneKind, SynthParams};

    #[test]
    fn batch_reports_come_back_in_spec_order() {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 1500).generate();
        let config = PipelineConfig::paper(true).with_resolution(128, 72);
        let server = RenderServer::new(scene, config);
        let specs = [
            ViewerSpec::perf(ViewCondition::Average, 2),
            ViewerSpec::perf(ViewCondition::Static, 3),
        ];
        let report = server.render_batch(&specs);
        assert_eq!(report.viewers.len(), 2);
        assert_eq!(report.viewers[0].frames, 2);
        assert_eq!(report.viewers[1].frames, 3);
        assert_eq!(report.total_frames, 5);
        assert!(report.viewers[0].label.starts_with("viewer-0"));
        assert!(report.viewers[1].label.starts_with("viewer-1"));
        assert!(report.aggregate_frames_per_s > 0.0);
        assert!(report.contended_mem.is_none());
        let js = report.to_json().pretty();
        assert!(js.contains("aggregate_frames_per_s"));
        assert!(!js.contains("contended_mem"));
    }

    #[test]
    fn contended_batch_reports_memory_rollup() {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 1500).generate();
        let config = PipelineConfig::paper(true).with_resolution(128, 72);
        let server = RenderServer::new(scene, config);
        let specs = [
            ViewerSpec::perf(ViewCondition::Average, 2),
            ViewerSpec::perf(ViewCondition::Static, 2),
        ];
        let report = server.render_batch_contended(&specs);
        assert_eq!(report.viewers.len(), 2);
        let mem = report.contended_mem.as_ref().expect("contended roll-up");
        assert_eq!(mem.viewers.len(), 2);
        assert!(mem.makespan_ns > 0.0);
        assert!(mem.fairness > 0.0 && mem.fairness <= 1.0 + 1e-12);
        assert_eq!(mem.channel_util.len(), mem.channels);
        assert!(mem.viewers.iter().all(|v| v.total_bytes() > 0));
        // Both viewers queued behind each other at least once.
        assert!(
            mem.viewers.iter().all(|v| v.total_wait_ns() > 0.0),
            "lockstep rounds must produce contention for every viewer"
        );
        let js = report.to_json().pretty();
        assert!(js.contains("contended_mem"));
        assert!(js.contains("channel_util_pctl"));
        assert!(js.contains("preprocess_latency_ns_pctl"));
    }

    #[test]
    fn contended_two_phase_is_bit_identical_to_lockstep() {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 1500).generate();
        let config = PipelineConfig::paper(true).with_resolution(128, 72);
        let mut server = RenderServer::new(scene, config);
        // Uneven frame counts exercise the round-skip path; one viewer
        // renders numerically so PSNR scoring crosses the phase boundary.
        let specs = [
            ViewerSpec { condition: ViewCondition::Average, frames: 3, psnr_every: 2 },
            ViewerSpec::perf(ViewCondition::Static, 2),
            ViewerSpec::perf(ViewCondition::Extreme, 3),
        ];

        server.set_threads(1);
        let lockstep = server.render_batch_contended(&specs);
        let baseline = lockstep.simulated_projection();
        for threads in [2, 8] {
            server.set_threads(threads);
            let par = server.render_batch_contended(&specs);
            assert_eq!(
                baseline,
                par.simulated_projection(),
                "two-phase contended batch diverged at threads={threads}"
            );
        }
        // Sanity: the roll-up still reports real contention.
        let mem = lockstep.contended_mem.as_ref().unwrap();
        assert!(mem.viewers.iter().all(|v| v.total_bytes() > 0));
        assert!(mem.makespan_ns > 0.0);
    }

    #[test]
    fn ladder_matches_nearest_rank_convention() {
        use crate::math::stats::percentile;
        let xs: Vec<f64> = (0..100).map(|i| (i * 7 % 100) as f64).collect();
        let p = LatencyLadder::of(&xs);
        assert_eq!(p.p50, percentile(&xs, 50.0));
        assert_eq!(p.p90, percentile(&xs, 90.0));
        assert_eq!(p.p99, percentile(&xs, 99.0));
        let empty = LatencyLadder::of(&[]);
        assert_eq!(empty.count, 0);
        assert_eq!(empty.p50, 0.0);
        assert_eq!(empty.p99, 0.0);
        // The ladder JSON keeps the pre-registry percentile keys — the
        // contended report's `*_pctl` blocks stay a superset.
        let js = p.to_json().pretty();
        for key in ["p50", "p90", "p99", "p75", "p95", "p99_9", "count", "mean"] {
            assert!(js.contains(key), "ladder JSON missing {key}");
        }
    }

    #[test]
    fn jain_fairness_bounds() {
        assert_eq!(jain_fairness(&[]), 1.0);
        assert!((jain_fairness(&[5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        let skewed = jain_fairness(&[10.0, 0.0, 0.0]);
        assert!((skewed - 1.0 / 3.0).abs() < 1e-12);
    }
}
