//! Multi-viewer render server: one immutable scene preparation, N
//! concurrent per-viewer frame pipelines.
//!
//! [`SharedScene`] owns the scene plus its offline
//! [`ScenePrep`](crate::pipeline::ScenePrep) (grid partition, DRAM layout,
//! FP16-quantized copy) behind `Arc`s. [`RenderServer::render_batch`] fans
//! a batch of [`ViewerSpec`]s out over `std::thread::scope` — every viewer
//! gets its own [`FramePipeline`] (hardware models + posteriori state are
//! per-session) borrowing the shared preparation — and reports both the
//! per-viewer [`SequenceReport`]s and the batch's aggregate host
//! throughput.
//!
//! Two throughput numbers must not be confused:
//! * `SequenceReport::report.fps` — the **modeled accelerator** frame rate
//!   (hardware cycles/energy roll-up), independent of the host machine;
//! * [`ServerReport::aggregate_frames_per_s`] — the **host simulation**
//!   throughput across all viewers (total frames / wall-clock), the number
//!   multi-viewer parallelism improves.
//!
//! Determinism contract (enforced by the `render_server` test): a batch of
//! N viewers produces per-viewer stats identical to N sequential
//! single-viewer runs — both paths execute the exact same shared
//! sequence-runner over the exact same trajectories.

use crate::camera::{Camera, ViewCondition};
use crate::pipeline::{FramePipeline, PipelineConfig, ScenePrep};
use crate::scene::Scene;
use crate::util::json::Json;
use std::time::Instant;

use super::app::{camera_template, run_frames_report, scene_trajectory};
use super::SequenceReport;

/// A scene plus its shared, immutable preparation.
#[derive(Debug, Clone)]
pub struct SharedScene {
    pub scene: Scene,
    pub prep: ScenePrep,
}

impl SharedScene {
    /// Build the preparation once for `scene` under `config`.
    pub fn prepare(scene: Scene, config: &PipelineConfig) -> SharedScene {
        let prep = ScenePrep::build(&scene, config);
        SharedScene { scene, prep }
    }

    /// A per-viewer pipeline borrowing this preparation (cheap: three `Arc`
    /// clones + per-session hardware-model state).
    pub fn pipeline(&self, config: PipelineConfig) -> FramePipeline<'_> {
        FramePipeline::with_prep(&self.scene, self.prep.clone(), config)
    }
}

/// One viewer session request.
#[derive(Debug, Clone, Copy)]
pub struct ViewerSpec {
    pub condition: ViewCondition,
    pub frames: usize,
    /// Render every n-th frame numerically for PSNR (0 = perf path only).
    pub psnr_every: usize,
}

impl ViewerSpec {
    pub fn perf(condition: ViewCondition, frames: usize) -> ViewerSpec {
        ViewerSpec { condition, frames, psnr_every: 0 }
    }
}

/// Result of one viewer batch.
#[derive(Debug, Clone)]
pub struct ServerReport {
    /// Per-viewer reports, in `specs` order.
    pub viewers: Vec<SequenceReport>,
    /// Wall-clock time of the whole batch (host seconds).
    pub wall_s: f64,
    /// Frames rendered across all viewers.
    pub total_frames: usize,
    /// Host simulation throughput: `total_frames / wall_s`.
    pub aggregate_frames_per_s: f64,
}

impl ServerReport {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("viewers", self.viewers.len())
            .set("total_frames", self.total_frames)
            .set("wall_s", self.wall_s)
            .set("aggregate_frames_per_s", self.aggregate_frames_per_s)
            .set(
                "viewer_reports",
                Json::Arr(self.viewers.iter().map(SequenceReport::to_json).collect()),
            )
    }
}

/// The multi-viewer server.
pub struct RenderServer {
    pub shared: SharedScene,
    pub config: PipelineConfig,
    /// Camera orbit radius (matches [`super::App`]'s default so viewer
    /// trajectories are identical to single-viewer runs).
    pub orbit_radius: f32,
}

impl RenderServer {
    /// Build a server for `scene` under `config` (prepares the shared
    /// state once).
    pub fn new(scene: Scene, config: PipelineConfig) -> RenderServer {
        let shared = SharedScene::prepare(scene, &config);
        RenderServer { shared, config, orbit_radius: 26.0 }
    }

    /// Promote a single-viewer [`super::App`] into a server, reusing its
    /// scene, configuration, and orbit radius.
    pub fn from_app(app: super::App) -> RenderServer {
        let orbit_radius = app.orbit_radius;
        let config = app.config.clone();
        let shared = SharedScene::prepare(app.scene, &config);
        RenderServer { shared, config, orbit_radius }
    }

    /// The camera template every viewer starts from.
    pub fn camera_template(&self) -> Camera {
        camera_template(&self.config, self.orbit_radius)
    }

    /// The trajectory a given spec resolves to.
    pub fn trajectory(&self, spec: &ViewerSpec) -> Vec<(Camera, f32)> {
        scene_trajectory(
            &self.shared.scene,
            &self.config,
            self.orbit_radius,
            spec.condition,
            spec.frames,
        )
    }

    /// Run one viewer session to completion (sequentially, on the calling
    /// thread). This is the exact unit of work `render_batch` parallelizes.
    pub fn render_viewer(&self, viewer_idx: usize, spec: &ViewerSpec) -> SequenceReport {
        let seq = self.trajectory(spec);
        let mut pipeline = self.shared.pipeline(self.config.clone());
        run_frames_report(
            &self.shared.scene,
            &mut pipeline,
            &seq,
            spec.psnr_every,
            format!(
                "viewer-{viewer_idx} {} ({})",
                self.shared.scene.name,
                spec.condition.label()
            ),
        )
    }

    /// Render a batch of viewer sessions in parallel (one scoped thread per
    /// viewer, all borrowing the shared scene preparation). Reports are
    /// returned in `specs` order; a panicking viewer thread propagates.
    pub fn render_batch(&self, specs: &[ViewerSpec]) -> ServerReport {
        let t0 = Instant::now();
        let viewers: Vec<SequenceReport> = std::thread::scope(|scope| {
            let handles: Vec<_> = specs
                .iter()
                .enumerate()
                .map(|(i, spec)| scope.spawn(move || self.render_viewer(i, spec)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("viewer session panicked"))
                .collect()
        });
        let wall_s = t0.elapsed().as_secs_f64();
        let total_frames: usize = specs.iter().map(|s| s.frames).sum();
        ServerReport {
            viewers,
            wall_s,
            total_frames,
            aggregate_frames_per_s: total_frames as f64 / wall_s.max(1e-12),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::synth::{SceneKind, SynthParams};

    #[test]
    fn batch_reports_come_back_in_spec_order() {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 1500).generate();
        let config = PipelineConfig::paper(true).with_resolution(128, 72);
        let server = RenderServer::new(scene, config);
        let specs = [
            ViewerSpec::perf(ViewCondition::Average, 2),
            ViewerSpec::perf(ViewCondition::Static, 3),
        ];
        let report = server.render_batch(&specs);
        assert_eq!(report.viewers.len(), 2);
        assert_eq!(report.viewers[0].frames, 2);
        assert_eq!(report.viewers[1].frames, 3);
        assert_eq!(report.total_frames, 5);
        assert!(report.viewers[0].label.starts_with("viewer-0"));
        assert!(report.viewers[1].label.starts_with("viewer-1"));
        assert!(report.aggregate_frames_per_s > 0.0);
        let js = report.to_json().pretty();
        assert!(js.contains("aggregate_frames_per_s"));
    }
}
