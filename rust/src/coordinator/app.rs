//! The application driver used by the CLI, the examples, and the
//! table/figure benches.

use crate::camera::{Camera, Trajectory, ViewCondition};
use crate::culling::CullReuseStats;
use crate::energy::{FrameEnergy, PowerReport, StageLatency};
use crate::math::Vec3;
use crate::obs::Component;
use crate::pipeline::{FramePipeline, FrameResult, PipelineConfig};
use crate::render::{psnr, Image, ReferenceRenderer};
use crate::scene::synth::{SceneKind, SynthParams};
use crate::scene::{Scene, UpdateFrameStats};
use crate::util::json::Json;
use anyhow::Result;
use std::path::PathBuf;

/// Aggregated results of a rendered sequence.
#[derive(Debug, Clone)]
pub struct SequenceReport {
    pub label: String,
    pub frames: usize,
    /// Per-frame averages.
    pub energy: FrameEnergy,
    pub latency: StageLatency,
    pub avg_visible: f64,
    pub avg_dram_accesses: f64,
    pub avg_dram_bytes: f64,
    pub sram_hit_rate: f64,
    pub avg_sort_cycles: f64,
    pub avg_atg_ops: f64,
    /// PSNR of the hardware path vs the exact reference (sampled frames);
    /// NaN when no frames were rendered numerically.
    pub psnr_db: f64,
    /// Mean SSIM over the same sampled frames (NaN when none rendered).
    pub ssim: f64,
    /// Temporal-serving roll-up — `None` on static runs (and sequences that
    /// never shipped an update) so their reports stay byte-identical.
    pub dynamic: Option<DynamicSequenceStats>,
    pub report: PowerReport,
}

impl SequenceReport {
    /// Registry [`Component`] of the sequence roll-up (keys unchanged from
    /// the pre-registry encoding — every value is a simulated quantity).
    pub fn component(&self) -> Component {
        let mut c = Component::new()
            .set("label", self.label.as_str())
            .set("frames", self.frames)
            .set("fps", self.report.fps)
            .set("power_w", self.report.power_w)
            .set("area_mm2", self.report.area_mm2)
            .set("psnr_db", self.psnr_db)
            .set("ssim", self.ssim)
            .set("avg_visible", self.avg_visible)
            .set("avg_dram_accesses", self.avg_dram_accesses)
            .set("avg_dram_bytes", self.avg_dram_bytes)
            .set("sram_hit_rate", self.sram_hit_rate)
            .set("avg_sort_cycles", self.avg_sort_cycles)
            .set("avg_atg_ops", self.avg_atg_ops);
        if let Some(d) = &self.dynamic {
            c.insert("dynamic", d.component());
        }
        c
    }

    pub fn to_json(&self) -> Json {
        self.component().to_json()
    }
}

/// Sequence totals of the dynamic update stream and the temporal-coherence
/// savings built on it (frame-0 baseline bake excluded by construction).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DynamicSequenceStats {
    /// Update-delta totals over the sequence.
    pub update: UpdateFrameStats,
    /// Dirty-cell cull-reuse totals (all-zero when reuse is disabled).
    pub cull_reuse: CullReuseStats,
    /// Bytes actually streamed through the `MemStage::Update` DRAM port
    /// (delta bytes after burst rounding).
    pub update_dram_bytes: u64,
}

impl DynamicSequenceStats {
    /// Registry [`Component`] of the dynamic-serving totals (counters plus
    /// the hit-rate gauge).
    pub fn component(&self) -> Component {
        Component::new()
            .set("dirty_cells", self.update.dirty_cells)
            .set("clean_cells", self.update.clean_cells)
            .set("updated_records", self.update.updated_records)
            .set("update_delta_bytes", self.update.delta_bytes)
            .set("update_raw_bytes", self.update.raw_bytes)
            .set("update_dram_bytes", self.update_dram_bytes)
            .set("cull_cells_reused", self.cull_reuse.cells_reused)
            .set("cull_cells_fetched", self.cull_reuse.cells_fetched)
            .set("cull_refs_reused", self.cull_reuse.refs_reused)
            .set("cull_bytes_saved", self.cull_reuse.bytes_saved)
            .set("cull_cell_hit_rate", self.cull_reuse.cell_hit_rate())
    }

    pub fn to_json(&self) -> Json {
        self.component().to_json()
    }
}

/// The coordinator application.
pub struct App {
    pub scene: Scene,
    pub config: PipelineConfig,
    /// Camera orbit radius (scene-dependent).
    pub orbit_radius: f32,
}

impl App {
    /// Synthesize (or load from cache) the scene for `kind` with
    /// `n_gaussians`, and set the paper configuration.
    pub fn new(kind: SceneKind, n_gaussians: usize, seed: u64) -> App {
        let scene = SynthParams::new(kind, n_gaussians).with_seed(seed).generate();
        let dynamic = kind == SceneKind::DynamicLarge;
        App {
            scene,
            config: PipelineConfig::paper(dynamic),
            orbit_radius: 26.0,
        }
    }

    /// Load the scene from cache if present, else synthesize + persist.
    pub fn cached(kind: SceneKind, n_gaussians: usize, seed: u64, dir: &PathBuf) -> Result<App> {
        let path = dir.join(format!("{}-{}-{}.g4d", kind.label(), n_gaussians, seed));
        let scene = crate::scene::io::ensure_cached(
            || SynthParams::new(kind, n_gaussians).with_seed(seed).generate(),
            &path,
        )?;
        let dynamic = kind == SceneKind::DynamicLarge;
        Ok(App {
            scene,
            config: PipelineConfig::paper(dynamic),
            orbit_radius: 26.0,
        })
    }

    pub fn with_config(mut self, config: PipelineConfig) -> App {
        self.config = config;
        self
    }

    /// Camera template for the configured resolution.
    pub fn camera_template(&self) -> Camera {
        camera_template(&self.config, self.orbit_radius)
    }

    /// Trajectory for a view condition across the scene's clip.
    pub fn trajectory(&self, condition: ViewCondition, frames: usize) -> Vec<(Camera, f32)> {
        scene_trajectory(&self.scene, &self.config, self.orbit_radius, condition, frames)
    }

    /// Run a sequence. `psnr_every` > 0 renders every n-th frame numerically
    /// and scores it against the exact reference renderer.
    pub fn run_sequence(
        &self,
        condition: ViewCondition,
        frames: usize,
        psnr_every: usize,
    ) -> SequenceReport {
        let seq = self.trajectory(condition, frames);
        let mut pipeline = FramePipeline::new(&self.scene, self.config.clone());
        run_frames_report(
            &self.scene,
            &mut pipeline,
            &seq,
            psnr_every,
            format!("{} ({})", self.scene.name, condition.label()),
        )
    }

    /// Render a single frame to an image (for the CLI / examples).
    pub fn render_one(&self, t: f32) -> (Image, SequenceReport) {
        let mut pipeline = FramePipeline::new(&self.scene, self.config.clone());
        let cam = self.camera_template();
        let r = pipeline.render_frame(&cam, t, true);
        let report = PowerReport::from_frame(
            self.scene.name.clone(),
            r.energy,
            r.latency,
            self.config.dcim.area_mm2,
            self.scene.dynamic,
        );
        let reference = ReferenceRenderer::new(self.config.width, self.config.height)
            .with_backend(self.config.render_backend);
        let ref_img = reference.render(&self.scene, &cam, t);
        let image = r.image.expect("rendered");
        let p = psnr(&ref_img, &image);
        let s = crate::render::ssim(&ref_img, &image);
        let seq = SequenceReport {
            label: self.scene.name.clone(),
            frames: 1,
            energy: r.energy,
            latency: r.latency,
            avg_visible: r.n_visible as f64,
            avg_dram_accesses: r.traffic.total_dram_accesses() as f64,
            avg_dram_bytes: r.traffic.total_dram_bytes() as f64,
            sram_hit_rate: r.traffic.blend_sram.hit_rate(),
            avg_sort_cycles: r.sort.cycles as f64,
            avg_atg_ops: r.atg_ops as f64,
            psnr_db: p,
            ssim: s,
            dynamic: dynamic_block(r.update, r.cull_reuse, r.traffic.update_dram.bytes),
            report,
        };
        (image, seq)
    }
}

/// Camera template for a configuration + orbit radius (shared by [`App`]
/// and [`super::RenderServer`] so single- and multi-viewer paths see the
/// identical pose).
pub(crate) fn camera_template(config: &PipelineConfig, orbit_radius: f32) -> Camera {
    let mut cam = Camera::look_at(
        Vec3::new(0.0, 5.0, orbit_radius),
        Vec3::new(0.0, 1.0, 0.0),
        Vec3::new(0.0, 1.0, 0.0),
        60f32.to_radians(),
        config.width as f32 / config.height as f32,
        0.1,
        200.0,
    );
    cam.set_resolution(config.width, config.height);
    cam
}

/// Viewing trajectory across the scene's clip (shared single-/multi-viewer).
pub(crate) fn scene_trajectory(
    scene: &Scene,
    config: &PipelineConfig,
    orbit_radius: f32,
    condition: ViewCondition,
    frames: usize,
) -> Vec<(Camera, f32)> {
    let (t0, t1) = scene.time_span;
    Trajectory::new(condition, frames)
        .with_scene(Vec3::new(0.0, 1.0, 0.0), orbit_radius)
        .with_time_span(t0, t1)
        .generate(&camera_template(config, orbit_radius))
}

/// A trajectory suffix for a viewer already `start` frames into a stream:
/// frames `[start, start + frames)` of the full walk — what a mid-stream
/// joiner renders, and by construction identical to the tail a viewer who
/// joined at frame 0 would render from frame `start` on.
pub(crate) fn scene_trajectory_from(
    scene: &Scene,
    config: &PipelineConfig,
    orbit_radius: f32,
    condition: ViewCondition,
    start: usize,
    frames: usize,
) -> Vec<(Camera, f32)> {
    let mut full = scene_trajectory(scene, config, orbit_radius, condition, start + frames);
    full.split_off(start)
}

/// The canonical per-viewer report label — shared by the sequential,
/// batched, contended, and session paths so their reports stay
/// string-comparable.
pub(crate) fn viewer_label(scene_name: &str, viewer: usize, condition: ViewCondition) -> String {
    format!("viewer-{viewer} {scene_name} ({})", condition.label())
}

/// Score one rendered frame against the exact reference renderer,
/// returning `(PSNR dB, SSIM)` — `None` for perf-only frames. The single
/// scoring path every sequence runner shares.
pub(crate) fn score_frame(
    reference: &ReferenceRenderer,
    scene: &Scene,
    cam: &Camera,
    t: f32,
    r: &FrameResult,
) -> Option<(f64, f64)> {
    r.image.as_ref().map(|img| {
        let ref_img = reference.render(scene, cam, t);
        (psnr(&ref_img, img), crate::render::ssim(&ref_img, img))
    })
}

/// Streaming aggregator of per-frame [`FrameResult`]s into a
/// [`SequenceReport`]. The sequential runner ([`run_frames_report`]) and
/// the lockstep contended batch (`RenderServer::render_batch_contended`)
/// both push into this, which is what keeps their per-viewer reports
/// structurally identical.
pub(crate) struct SequenceAgg {
    frames: usize,
    energy: FrameEnergy,
    latency: StageLatency,
    visible: f64,
    dram_accesses: f64,
    dram_bytes: f64,
    sram_hits: u64,
    sram_lookups: u64,
    sort_cycles: f64,
    atg_ops: f64,
    update: UpdateFrameStats,
    reuse: CullReuseStats,
    update_dram_bytes: u64,
    psnr_sum: f64,
    ssim_sum: f64,
    psnr_count: usize,
}

/// `Some` only when the sequence actually carried dynamic-serving state —
/// static runs fold all-zero stats and keep their reports byte-identical.
fn dynamic_block(
    update: UpdateFrameStats,
    cull_reuse: CullReuseStats,
    update_dram_bytes: u64,
) -> Option<DynamicSequenceStats> {
    let d = DynamicSequenceStats { update, cull_reuse, update_dram_bytes };
    (d != DynamicSequenceStats::default()).then_some(d)
}

impl SequenceAgg {
    pub(crate) fn new() -> SequenceAgg {
        SequenceAgg {
            frames: 0,
            energy: FrameEnergy::default(),
            latency: StageLatency::default(),
            visible: 0.0,
            dram_accesses: 0.0,
            dram_bytes: 0.0,
            sram_hits: 0,
            sram_lookups: 0,
            sort_cycles: 0.0,
            atg_ops: 0.0,
            update: UpdateFrameStats::default(),
            reuse: CullReuseStats::default(),
            update_dram_bytes: 0,
            psnr_sum: 0.0,
            ssim_sum: 0.0,
            psnr_count: 0,
        }
    }

    /// Fold one frame in. `scored` carries (PSNR, SSIM) when the frame was
    /// rendered numerically and compared against the reference.
    pub(crate) fn push(&mut self, r: &crate::pipeline::FrameResult, scored: Option<(f64, f64)>) {
        self.frames += 1;
        self.energy.add(&r.energy);
        self.latency.add(&r.latency);
        self.visible += r.n_visible as f64;
        self.dram_accesses += r.traffic.total_dram_accesses() as f64;
        self.dram_bytes += r.traffic.total_dram_bytes() as f64;
        self.sram_hits += r.traffic.blend_sram.hits;
        self.sram_lookups += r.traffic.blend_sram.lookups;
        self.sort_cycles += r.sort.cycles as f64;
        self.atg_ops += r.atg_ops as f64;
        self.update.add(&r.update);
        self.reuse.add(&r.cull_reuse);
        self.update_dram_bytes += r.traffic.update_dram.bytes;
        if let Some((p, s)) = scored {
            self.psnr_sum += p;
            self.ssim_sum += s;
            self.psnr_count += 1;
        }
    }

    pub(crate) fn finish(
        self,
        label: String,
        dcim_area_mm2: f64,
        dynamic: bool,
    ) -> SequenceReport {
        let n = self.frames.max(1) as f64;
        let energy = self.energy.scale(1.0 / n);
        let latency = self.latency.scale(1.0 / n);
        let report = PowerReport::from_frame(label, energy, latency, dcim_area_mm2, dynamic);
        SequenceReport {
            label: report.label.clone(),
            frames: self.frames,
            energy,
            latency,
            avg_visible: self.visible / n,
            avg_dram_accesses: self.dram_accesses / n,
            avg_dram_bytes: self.dram_bytes / n,
            sram_hit_rate: if self.sram_lookups > 0 {
                self.sram_hits as f64 / self.sram_lookups as f64
            } else {
                0.0
            },
            avg_sort_cycles: self.sort_cycles / n,
            avg_atg_ops: self.atg_ops / n,
            psnr_db: if self.psnr_count > 0 {
                self.psnr_sum / self.psnr_count as f64
            } else {
                f64::NAN
            },
            ssim: if self.psnr_count > 0 {
                self.ssim_sum / self.psnr_count as f64
            } else {
                f64::NAN
            },
            dynamic: dynamic_block(self.update, self.reuse, self.update_dram_bytes),
            report,
        }
    }
}

/// Drive `pipeline` over `seq` and aggregate the per-frame results into a
/// [`SequenceReport`] — the single sequence-execution path shared by
/// [`App::run_sequence`] and every [`super::RenderServer`] viewer session
/// (which is what makes batched per-viewer stats identical to sequential
/// single-viewer runs by construction).
pub(crate) fn run_frames_report(
    scene: &Scene,
    pipeline: &mut FramePipeline<'_>,
    seq: &[(Camera, f32)],
    psnr_every: usize,
    label: String,
) -> SequenceReport {
    let width = pipeline.config.width;
    let height = pipeline.config.height;
    let dcim_area_mm2 = pipeline.config.dcim.area_mm2;
    let reference =
        ReferenceRenderer::new(width, height).with_backend(pipeline.config.render_backend);

    let mut agg = SequenceAgg::new();
    for (i, (cam, t)) in seq.iter().enumerate() {
        let render = psnr_every > 0 && i % psnr_every == 0;
        let r = pipeline.render_frame(cam, *t, render);
        let scored = score_frame(&reference, scene, cam, *t, &r);
        agg.push(&r, scored);
    }
    agg.finish(label, dcim_area_mm2, scene.dynamic)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_app(kind: SceneKind) -> App {
        let mut app = App::new(kind, 3000, 7);
        app.config = app.config.clone().with_resolution(192, 108);
        app
    }

    #[test]
    fn sequence_report_aggregates() {
        let app = small_app(SceneKind::DynamicLarge);
        let rep = app.run_sequence(ViewCondition::Average, 3, 0);
        assert_eq!(rep.frames, 3);
        assert!(rep.avg_visible > 0.0);
        assert!(rep.report.fps > 0.0);
        assert!(rep.psnr_db.is_nan(), "no numeric render requested");
        let js = rep.to_json().pretty();
        assert!(js.contains("power_w"));
    }

    #[test]
    fn psnr_sampling_produces_high_fidelity() {
        let app = small_app(SceneKind::StaticLarge);
        let rep = app.run_sequence(ViewCondition::Static, 2, 1);
        assert!(
            rep.psnr_db > 24.0,
            "hw-vs-reference PSNR should be high: {}",
            rep.psnr_db
        );
    }

    #[test]
    fn render_one_returns_image() {
        let app = small_app(SceneKind::StaticLarge);
        let (img, rep) = app.render_one(0.0);
        assert_eq!(img.width, 192);
        assert!(rep.psnr_db > 24.0);
        assert!(img.mean_luma() > 0.005);
    }
}
