//! Experiment configuration files: a JSON schema describing scene,
//! trajectory, pipeline knobs, and outputs, so whole runs are launchable
//! from declarative configs (`gaucim run --config configs/table1.json`).

use crate::camera::ViewCondition;
use crate::pipeline::PipelineConfig;
use crate::scene::synth::SceneKind;
use crate::tiles::atg::AtgConfig;
use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// A declarative experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub scene_kind: SceneKind,
    pub gaussians: usize,
    pub seed: u64,
    pub width: usize,
    pub height: usize,
    pub condition: ViewCondition,
    pub frames: usize,
    /// Render every n-th frame numerically for PSNR (0 = never).
    pub psnr_every: usize,
    pub pipeline: PipelineConfig,
    /// Optional output paths.
    pub report_json: Option<String>,
    pub frame_ppm: Option<String>,
}

impl ExperimentConfig {
    /// Parse from a JSON document. Unknown keys are rejected (config typos
    /// should fail loudly).
    pub fn from_json(doc: &Json) -> Result<ExperimentConfig> {
        const KNOWN: &[&str] = &[
            "name", "scene", "gaussians", "seed", "width", "height",
            "condition", "frames", "psnr_every", "grid_n", "atg_threshold",
            "tile_block", "n_buckets", "use_drfc", "use_atg", "use_aii",
            "sram_kb", "threads", "render_backend", "residency_mb",
            "prefetch_policy", "dynamic_updates", "cull_reuse", "aii_retain",
            "report_json", "frame_ppm",
        ];
        if let Json::Obj(m) = doc {
            for k in m.keys() {
                if !KNOWN.contains(&k.as_str()) {
                    bail!("unknown config key '{k}' (known: {KNOWN:?})");
                }
            }
        } else {
            bail!("config must be a JSON object");
        }

        let scene_kind = match doc.get("scene").and_then(Json::as_str).unwrap_or("dynamic") {
            "static" => SceneKind::StaticLarge,
            "dynamic" => SceneKind::DynamicLarge,
            other => bail!("scene must be 'static' or 'dynamic', got '{other}'"),
        };
        let condition = match doc
            .get("condition")
            .and_then(Json::as_str)
            .unwrap_or("average")
        {
            "average" => ViewCondition::Average,
            "extreme" => ViewCondition::Extreme,
            "static" => ViewCondition::Static,
            other => bail!("condition must be average|extreme|static, got '{other}'"),
        };

        let get_usize = |key: &str, default: usize| -> usize {
            doc.get(key).and_then(Json::as_usize).unwrap_or(default)
        };
        let get_bool = |key: &str, default: bool| -> bool {
            doc.get(key).and_then(Json::as_bool).unwrap_or(default)
        };

        let dynamic = scene_kind == SceneKind::DynamicLarge;
        let mut pipeline = PipelineConfig::paper(dynamic)
            .with_resolution(get_usize("width", 1280), get_usize("height", 720));
        pipeline.grid_n = get_usize("grid_n", pipeline.grid_n);
        pipeline.n_buckets = get_usize("n_buckets", pipeline.n_buckets);
        pipeline.use_drfc = get_bool("use_drfc", true);
        pipeline.use_atg = get_bool("use_atg", true);
        pipeline.use_aii = get_bool("use_aii", true);
        pipeline.sram_bytes = get_usize("sram_kb", pipeline.sram_bytes / 1024) * 1024;
        // Executor threads: 0 = auto (PALLAS_THREADS env, else available
        // parallelism). Stat outputs are thread-count invariant.
        pipeline.threads = get_usize("threads", 0);
        // Render backend: scalar | lanes (default: PALLAS_RENDER_BACKEND
        // env, else lanes). Stat outputs are backend invariant too.
        if let Some(s) = doc.get("render_backend").and_then(Json::as_str) {
            pipeline.render_backend = crate::render::RenderBackend::from_label(s)
                .ok_or_else(|| anyhow::anyhow!("render_backend must be scalar|lanes, got '{s}'"))?;
        }
        // Residency: DRAM capacity in MB (0 = fully resident, residency
        // layer off) and the prefetch policy that pages ahead of demand.
        if let Some(mb) = doc.get("residency_mb").and_then(Json::as_f64) {
            pipeline.mem.residency.capacity_mb = mb.max(0.0);
        }
        if let Some(s) = doc.get("prefetch_policy").and_then(Json::as_str) {
            pipeline.mem.residency.policy =
                crate::memory::PrefetchPolicy::from_label(s).ok_or_else(|| {
                    anyhow!("prefetch_policy must be none|next-frame-cull|lookahead[:K], got '{s}'")
                })?;
        }
        // Dynamic serving: stream per-frame gaussian update deltas into
        // DRAM (off by default — static runs stay byte-identical), with
        // dirty-cell cull reuse and cross-update AII retention on top.
        pipeline.dynamic_updates = get_bool("dynamic_updates", false);
        pipeline.cull_reuse = get_bool("cull_reuse", pipeline.cull_reuse);
        pipeline.aii_retain = get_bool("aii_retain", pipeline.aii_retain);
        pipeline.atg = AtgConfig {
            user_threshold: doc
                .get("atg_threshold")
                .and_then(Json::as_f64)
                .unwrap_or(0.5) as f32,
            tile_block: get_usize("tile_block", 4),
            ..AtgConfig::default()
        };

        Ok(ExperimentConfig {
            name: doc
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("experiment")
                .to_string(),
            scene_kind,
            gaussians: get_usize("gaussians", 100_000),
            seed: get_usize("seed", 42) as u64,
            width: pipeline.width,
            height: pipeline.height,
            condition,
            frames: get_usize("frames", 8),
            psnr_every: get_usize("psnr_every", 0),
            pipeline,
            report_json: doc
                .get("report_json")
                .and_then(Json::as_str)
                .map(String::from),
            frame_ppm: doc
                .get("frame_ppm")
                .and_then(Json::as_str)
                .map(String::from),
        })
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        Self::from_json(&doc).with_context(|| format!("in {}", path.display()))
    }

    /// Execute the experiment: build the app, run the sequence, write
    /// outputs, and return the report.
    pub fn run(&self) -> Result<crate::coordinator::SequenceReport> {
        let mut app =
            crate::coordinator::App::new(self.scene_kind, self.gaussians, self.seed);
        app.config = self.pipeline.clone();
        let rep = app.run_sequence(self.condition, self.frames, self.psnr_every);
        if let Some(path) = &self.report_json {
            if let Some(dir) = Path::new(path).parent() {
                std::fs::create_dir_all(dir).ok();
            }
            std::fs::write(path, rep.to_json().pretty())?;
        }
        if let Some(path) = &self.frame_ppm {
            let (img, _) = app.render_one(app.scene.time_span.0);
            crate::render::ppm::save(&img, Path::new(path))?;
        }
        Ok(rep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let doc = parse(
            r#"{
                "name": "smoke",
                "scene": "dynamic",
                "gaussians": 5000,
                "width": 320, "height": 180,
                "condition": "extreme",
                "frames": 3,
                "grid_n": 8,
                "atg_threshold": 0.7,
                "tile_block": 2,
                "n_buckets": 16,
                "use_aii": false,
                "sram_kb": 64,
                "threads": 3,
                "residency_mb": 0.25,
                "prefetch_policy": "lookahead:3"
            }"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.name, "smoke");
        assert_eq!(cfg.gaussians, 5000);
        assert_eq!(cfg.pipeline.grid_n, 8);
        assert_eq!(cfg.pipeline.atg.user_threshold, 0.7);
        assert_eq!(cfg.pipeline.atg.tile_block, 2);
        assert_eq!(cfg.pipeline.n_buckets, 16);
        assert!(!cfg.pipeline.use_aii);
        assert_eq!(cfg.pipeline.sram_bytes, 64 * 1024);
        assert_eq!(cfg.pipeline.threads, 3);
        assert_eq!(cfg.pipeline.resolved_threads(), 3);
        assert_eq!(cfg.condition, ViewCondition::Extreme);
        assert_eq!(cfg.pipeline.mem.residency.capacity_mb, 0.25);
        assert_eq!(
            cfg.pipeline.mem.residency.policy,
            crate::memory::PrefetchPolicy::TrajectoryLookahead { k: 3 }
        );
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        let doc = parse(r#"{"typo_key": 1}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
        let doc = parse(r#"{"scene": "martian"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
        let doc = parse(r#"{"condition": "warp"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
        let doc = parse(r#"{"prefetch_policy": "psychic"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&doc).is_err());
    }

    #[test]
    fn defaults_are_paper_operating_point() {
        let doc = parse(r#"{"scene": "static"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        assert_eq!(cfg.pipeline.grid_n, 4);
        assert_eq!(cfg.pipeline.n_buckets, 8);
        assert_eq!(cfg.pipeline.atg.user_threshold, 0.5);
        assert_eq!(cfg.pipeline.atg.tile_block, 4);
        assert!(cfg.pipeline.use_drfc && cfg.pipeline.use_atg && cfg.pipeline.use_aii);
    }

    #[test]
    fn end_to_end_run_from_config() {
        let doc = parse(
            r#"{"scene": "static", "gaussians": 2000, "width": 192,
                "height": 108, "condition": "static", "frames": 2,
                "psnr_every": 2}"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&doc).unwrap();
        let rep = cfg.run().unwrap();
        assert_eq!(rep.frames, 2);
        assert!(rep.report.fps > 0.0);
        assert!(rep.psnr_db > 20.0);
    }
}
