//! `coordinator::rounds` — the shared two-phase round engine.
//!
//! Both contended execution paths — [`RenderServer::render_batch_contended`]
//! (fixed viewer batches) and [`super::session::SessionScheduler::run`]
//! (long-lived join/leave streams) — drive the same unit of work: a
//! **round** of policy-ordered frames over one shared, contended
//! event-queue [`MemorySystem`]. Before this module each path carried its
//! own copy of the execution machinery (and the session path only had the
//! serial one); [`RoundEngine`] is the single implementation both are thin
//! clients of.
//!
//! # Execution modes
//!
//! * **Lockstep** (`threads == 1`, or a single participant): pipelines
//!   register their cull/blend ports directly on the shared system and a
//!   round renders its frames serially in the caller's policy order,
//!   issuing DRAM requests as it goes — the reference schedule.
//! * **Two-phase** (`threads > 1` and more than one participant): pipelines
//!   are built with **trace-recording ports**
//!   ([`MemPort::trace`](crate::memory::MemPort::trace)) and
//!   their port pairs are registered on the shared system separately (same
//!   registration order as lockstep: participant order, cull before
//!   blend). Phase 1 renders a round's frames concurrently on the engine's
//!   [`WorkerPool`] (PSNR scoring included — pure per-frame work); phase 2
//!   replays every recorded `(addr, bytes)` request into the shared system
//!   in the exact policy order and patches each frame's DRAM-dependent
//!   outputs (per-stage traffic, DRAM energy, the `max(compute, DRAM)`
//!   stage latencies) from the replayed per-port deltas — the same values
//!   the lockstep stages compute inline, because trace-port frames carry
//!   zero DRAM busy time/energy.
//!
//! Either way the shared system observes the identical request schedule,
//! so every contention statistic (fairness, channel utilization,
//! wait/stall, latency percentiles) and every per-frame stat handed back
//! through [`RoundOutcome`] is **bit-identical across modes and host
//! thread counts** — enforced by the `render_server` and
//! `session_scheduler` suites and the CI `threads-matrix` /
//! `session-smoke` jobs.
//!
//! The engine also owns pipeline construction
//! ([`RoundEngine::make_pipeline`] / [`RoundEngine::resume_pipeline`]) so
//! clients never branch on the mode: lockstep builds shared-port
//! pipelines, two-phase builds trace-port pipelines — ports come back
//! uniformly as `(cull, blend)` [`PortId`] pairs.

use crate::camera::Camera;
use crate::memory::{MemMode, MemStage, MemorySystem, PortId};
use crate::obs::{TraceSink, Track};
use crate::pipeline::{
    FramePipeline, FrameResult, PipelineConfig, ScenePrep, SessionState, WorkerPool,
};
use crate::render::ReferenceRenderer;
use crate::scene::Scene;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use super::app::score_frame;
use super::server::{RenderServer, SharedScene};

/// One participant's ports on the shared system: the cull/blend read
/// streams plus, for dynamic serving (`PipelineConfig::dynamic_updates`),
/// the update-write stream.
#[derive(Debug, Clone, Copy)]
pub(crate) struct RoundPorts {
    pub cull: PortId,
    pub blend: PortId,
    /// The [`MemStage::Update`] write port (None for static serving, so
    /// static port registration — and every static report — is untouched).
    pub update: Option<PortId>,
}

/// One frame of work inside a round, in the caller's policy order.
pub(crate) struct RoundJob<'j, 'scene> {
    /// Caller's participant id (viewer / session), handed back on the
    /// outcome — the engine never interprets it.
    pub key: usize,
    pub cam: Camera,
    pub t: f32,
    /// Render this frame numerically (PSNR scoring).
    pub render: bool,
    /// The participant's ports on the shared system.
    pub ports: RoundPorts,
    pub pipeline: &'j mut FramePipeline<'scene>,
}

/// One completed (and, in two-phase mode, replay-patched) frame of a
/// round, returned in the round's policy order.
pub(crate) struct RoundOutcome {
    pub key: usize,
    pub result: FrameResult,
    /// `(PSNR dB, SSIM)` when the frame was rendered numerically.
    pub scored: Option<(f64, f64)>,
}

/// A rendered-but-not-yet-replayed frame of a two-phase round (internal).
struct RoundFrame {
    result: FrameResult,
    scored: Option<(f64, f64)>,
    /// Prefetch pages the frame's predictor issued before its demand reads
    /// (replayed into the residency layer ahead of the cull trace).
    prefetch: Vec<usize>,
    /// Update-stream writes the frame staged *before* any render read —
    /// replayed first, mirroring the lockstep issue order.
    update_trace: Vec<(u64, u64)>,
    cull_trace: Vec<(u64, u64)>,
    blend_trace: Vec<(u64, u64)>,
}

/// The shared two-phase round engine (see the module docs).
pub(crate) struct RoundEngine {
    sys: Arc<Mutex<MemorySystem>>,
    pool: WorkerPool,
    two_phase: bool,
    /// The caller's configuration forced to the event-queue backend — what
    /// lockstep (shared-port) pipelines are built with, and the source of
    /// report parameters (`mem.outstanding`, `dcim.area_mm2`).
    config: PipelineConfig,
    /// `threads = 1` clone of `config` for two-phase per-frame pipelines:
    /// the round is the parallel unit, so frames run their intra-frame
    /// executor serially instead of oversubscribing the host.
    frame_cfg: PipelineConfig,
    /// Simulated-time trace sink plus this engine's Chrome-trace process
    /// id (opt-in). Frame spans are emitted post-replay in the round's
    /// policy order — identical in lockstep and two-phase mode, so the
    /// recorded stream is bit-identical across host thread counts.
    tracer: Option<(TraceSink, u64)>,
    /// Per-participant frame counters for span labels (`frame {n}`),
    /// touched only when a tracer is attached. Interior mutability because
    /// `run_round` takes `&self`.
    frame_counts: Mutex<BTreeMap<usize, usize>>,
}

impl RoundEngine {
    /// Build an engine over a fresh shared [`MemorySystem`].
    /// `parallel_units` is the number of participants the caller expects a
    /// round to fan out over (batch viewer count; a session script's
    /// `peak_concurrency`): two-phase mode engages only when both the
    /// resolved thread count and `parallel_units` exceed one — otherwise
    /// rounds hold at most one frame at a time, and the lockstep path
    /// keeps that frame's intra-frame executor parallelism instead of
    /// pinning it to one thread.
    pub(crate) fn new(
        base: &PipelineConfig,
        prep: &ScenePrep,
        parallel_units: usize,
    ) -> RoundEngine {
        let mut config = base.clone();
        config.mem.mode = MemMode::EventQueue;
        let threads = config.resolved_threads();
        let two_phase = threads > 1 && parallel_units > 1;
        let mut sys = MemorySystem::new(config.mem.clone(), *prep.shard_map);
        // Streaming residency: the shared system pages against the scene's
        // compressed backing store (no-op when disabled / fully resident).
        if let Some(store) = &prep.compressed {
            sys.attach_residency(store);
        }
        let sys = Arc::new(Mutex::new(sys));
        let frame_cfg = PipelineConfig { threads: 1, ..config.clone() };
        RoundEngine {
            sys,
            pool: WorkerPool::new(if two_phase { threads } else { 1 }),
            two_phase,
            config,
            frame_cfg,
            tracer: None,
            frame_counts: Mutex::new(BTreeMap::new()),
        }
    }

    /// The shared, contended memory system the engine replays into.
    pub(crate) fn sys(&self) -> &Arc<Mutex<MemorySystem>> {
        &self.sys
    }

    /// Attach a simulated-time trace sink: opens one Chrome-trace process
    /// named `label`, wires the shared memory system's per-channel DRAM
    /// spans onto it, and makes every subsequent round emit per-frame
    /// stage spans (post-replay, in policy order). Lock order is always
    /// system → tracer, never the reverse.
    pub(crate) fn set_tracer(&mut self, sink: &TraceSink, label: &str) {
        let pid = sink.lock().expect("tracer lock poisoned").begin_process(label);
        self.sys
            .lock()
            .expect("memory system lock poisoned")
            .set_tracer(sink.clone(), pid);
        self.tracer = Some((sink.clone(), pid));
    }

    /// The attached trace sink and process id, if any (session schedulers
    /// emit lifecycle instants onto the engine's process).
    pub(crate) fn tracer(&self) -> Option<&(TraceSink, u64)> {
        self.tracer.as_ref()
    }

    /// Emit one round's frame spans in outcome (= policy) order. Each
    /// participant's frames chain on its own viewer track: a frame starts
    /// at `max(track cursor, round epoch)` — rounds never overlap the
    /// epoch barrier, and a participant's frames never overlap each other.
    fn trace_outcomes(&self, outcomes: &[RoundOutcome], round_epoch: f64) {
        let Some((sink, pid)) = &self.tracer else { return };
        let mut tr = sink.lock().expect("tracer lock poisoned");
        let mut counts = self.frame_counts.lock().expect("frame counter lock poisoned");
        for out in outcomes {
            let track = Track::Viewer(out.key);
            let idx = counts.entry(out.key).or_insert(0);
            let t0 = tr.cursor(*pid, track).max(round_epoch);
            out.result.trace_spans(&mut tr, *pid, track, *idx, t0);
            *idx += 1;
        }
    }

    /// The event-queue configuration the engine runs under.
    pub(crate) fn config(&self) -> &PipelineConfig {
        &self.config
    }

    /// Register one participant's ports on the shared system (two-phase
    /// mode; lockstep pipelines register through their own shared ports).
    /// Same order as `FramePipeline::make_ports`: cull, blend, then — for
    /// dynamic serving only — the update-write port, so per-port statistics
    /// line up bit-for-bit across modes.
    fn register_ports(&self) -> RoundPorts {
        let mut sys = self.sys.lock().expect("memory system lock poisoned");
        let cull = sys.register_port();
        let blend = sys.register_port();
        let update = self.config.dynamic_updates.then(|| sys.register_port());
        RoundPorts { cull, blend, update }
    }

    /// Build a participant's pipeline for the engine's mode. Ports are
    /// registered in call order, cull before blend — identical in both
    /// modes, so per-port statistics line up bit-for-bit.
    pub(crate) fn make_pipeline<'s>(
        &self,
        shared: &'s SharedScene,
    ) -> (FramePipeline<'s>, RoundPorts) {
        if self.two_phase {
            let pipeline = FramePipeline::with_trace_ports(
                &shared.scene,
                shared.prep.clone(),
                self.frame_cfg.clone(),
            );
            (pipeline, self.register_ports())
        } else {
            let pipeline =
                shared.pipeline_with_memory(self.config.clone(), Arc::clone(&self.sys));
            let (cull, blend) = pipeline
                .mem_port_ids()
                .expect("shared-memory pipelines register ports");
            let update = pipeline.update_port_id();
            (pipeline, RoundPorts { cull, blend, update })
        }
    }

    /// Resume a detached [`SessionState`] as a participant pipeline (the
    /// [`RoundEngine::make_pipeline`] counterpart for
    /// `SessionScheduler::seed_detached`). The continuation is
    /// bit-identical in either mode — retained state never carries port
    /// handles, and the executor thread count is not part of the state's
    /// shape.
    pub(crate) fn resume_pipeline<'s>(
        &self,
        shared: &'s SharedScene,
        state: SessionState,
    ) -> (FramePipeline<'s>, RoundPorts) {
        if self.two_phase {
            let pipeline = FramePipeline::resume_with_trace_ports(
                &shared.scene,
                shared.prep.clone(),
                self.frame_cfg.clone(),
                state,
            );
            (pipeline, self.register_ports())
        } else {
            let pipeline = FramePipeline::resume_with_shared_memory(
                &shared.scene,
                shared.prep.clone(),
                self.config.clone(),
                Arc::clone(&self.sys),
                state,
            );
            let (cull, blend) = pipeline
                .mem_port_ids()
                .expect("shared-memory pipelines register ports");
            let update = pipeline.update_port_id();
            (pipeline, RoundPorts { cull, blend, update })
        }
    }

    /// Drive one round: take the frame-epoch barrier on the shared system,
    /// render every job, and return the completed frames **in the given
    /// policy order** (`jobs` must already be ordered by the caller's
    /// policy). In two-phase mode the jobs render concurrently and their
    /// traces replay in that order; in lockstep mode they simply run in
    /// it. An empty job list still takes the epoch barrier (an idle round
    /// of a stream awaiting a future join).
    pub(crate) fn run_round(
        &self,
        scene: &Scene,
        reference: &ReferenceRenderer,
        mut jobs: Vec<RoundJob<'_, '_>>,
    ) -> Vec<RoundOutcome> {
        // Frame barrier: all in-flight transactions retire, port clocks
        // align — every participant's next frame starts at the same epoch
        // and contends on the channels within the round. The epoch horizon
        // anchors this round's trace spans in both modes.
        let round_epoch = {
            let mut sys = self.sys.lock().expect("memory system lock poisoned");
            sys.advance_epoch();
            sys.horizon_ns()
        };

        // Idle round (a stream awaiting a future join): the barrier above
        // already advanced the epoch; skip the worker-pool round-trip. At
        // 10k-session churn scale most rounds trail off with long idle
        // stretches, so this is on the scheduler's hot path.
        if jobs.is_empty() {
            return Vec::new();
        }

        if !self.two_phase {
            let out: Vec<RoundOutcome> = jobs
                .iter_mut()
                .map(|job| {
                    let result = job.pipeline.render_frame(&job.cam, job.t, job.render);
                    let scored = score_frame(reference, scene, &job.cam, job.t, &result);
                    RoundOutcome { key: job.key, result, scored }
                })
                .collect();
            self.trace_outcomes(&out, round_epoch);
            return out;
        }

        // Phase 1 — render this round's frames in parallel against the
        // jobs' trace-recording ports (PSNR scoring included: pure
        // per-frame work).
        let mut slots: Vec<Option<RoundFrame>> = (0..jobs.len()).map(|_| None).collect();
        self.pool.scope(|scope| {
            for (job, slot) in jobs.iter_mut().zip(slots.iter_mut()) {
                scope.spawn(move || {
                    let result = job.pipeline.render_frame(&job.cam, job.t, job.render);
                    let (cull_trace, blend_trace, update_trace) =
                        job.pipeline.take_frame_traces();
                    let prefetch = job.pipeline.take_frame_prefetch();
                    let scored = score_frame(reference, scene, &job.cam, job.t, &result);
                    *slot = Some(RoundFrame {
                        result,
                        scored,
                        prefetch,
                        update_trace,
                        cull_trace,
                        blend_trace,
                    });
                });
            }
        });

        // Phase 2 — replay into the shared system in the policy order,
        // then patch each frame's DRAM-dependent outputs from the replayed
        // per-port deltas.
        let mut sys = self.sys.lock().expect("memory system lock poisoned");
        let mut out = Vec::with_capacity(jobs.len());
        for (job, slot) in jobs.iter().zip(slots.iter_mut()) {
            let Some(mut frame) = slot.take() else { continue };
            let RoundPorts { cull: cull_id, blend: blend_id, update: update_id } = job.ports;
            // Update writes issue first — render_frame stages them before
            // any render read, and the replay mirrors that order.
            let update = update_id.map(|uid| {
                let base = sys.port_stage_stats(uid, MemStage::Update);
                for &(addr, bytes) in &frame.update_trace {
                    sys.read(uid, MemStage::Update, addr, bytes);
                }
                sys.port_stage_stats(uid, MemStage::Update).delta(&base)
            });
            // Prefetch fills land before the frame's demand reads — the
            // same issue order the lockstep cull stage produces.
            let cull_pg_base = sys.port_stage_stats(cull_id, MemStage::Paging);
            sys.residency_prefetch(cull_id, &frame.prefetch);
            let pre_base = sys.port_stage_stats(cull_id, MemStage::Preprocess);
            for &(addr, bytes) in &frame.cull_trace {
                sys.read(cull_id, MemStage::Preprocess, addr, bytes);
            }
            let pre = sys.port_stage_stats(cull_id, MemStage::Preprocess).delta(&pre_base);
            let cull_pg = sys.port_stage_stats(cull_id, MemStage::Paging).delta(&cull_pg_base);
            let blend_base = sys.port_stage_stats(blend_id, MemStage::Blend);
            let blend_pg_base = sys.port_stage_stats(blend_id, MemStage::Paging);
            for &(addr, bytes) in &frame.blend_trace {
                sys.read(blend_id, MemStage::Blend, addr, bytes);
            }
            let blend = sys.port_stage_stats(blend_id, MemStage::Blend).delta(&blend_base);
            let blend_pg =
                sys.port_stage_stats(blend_id, MemStage::Paging).delta(&blend_pg_base);

            let r = &mut frame.result;
            r.traffic.preprocess_dram = pre;
            r.traffic.blend_dram = blend;
            r.traffic.paging_dram = cull_pg;
            r.traffic.paging_dram.add(&blend_pg);
            // Trace-port frames carried zero DRAM energy/busy time, so
            // these recompute exactly what the lockstep stages produce:
            // dram_pj = pre + blend (+ paging), stage latency =
            // max(compute, DRAM + stage-issued paging).
            r.energy.dram_pj =
                pre.energy_pj + blend.energy_pj + cull_pg.energy_pj + blend_pg.energy_pj;
            r.latency.preprocess_ns =
                r.latency.preprocess_ns.max(pre.busy_ns + cull_pg.busy_ns);
            r.latency.blend_ns = r.latency.blend_ns.max(blend.busy_ns + blend_pg.busy_ns);
            // The update stream patches last: its busy time never enters
            // the stage latencies (writes are double-buffered per cell, so
            // the frame's reads don't wait on them) — it contends only
            // through the shared channels, exactly as in lockstep.
            if let Some(upd) = update {
                r.traffic.update_dram = upd;
                r.energy.dram_pj += upd.energy_pj;
            }
            out.push(RoundOutcome { key: job.key, result: frame.result, scored: frame.scored });
        }
        drop(sys);
        self.trace_outcomes(&out, round_epoch);
        out
    }
}

impl RenderServer {
    /// A round engine over this server's configuration and shard map (a
    /// fresh shared memory system per call).
    pub(crate) fn round_engine(&self, parallel_units: usize) -> RoundEngine {
        RoundEngine::new(&self.config, &self.shared.prep, parallel_units)
    }
}
