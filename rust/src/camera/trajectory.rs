//! Head-movement camera trajectories.
//!
//! The paper's experiments evaluate ATG and AII-Sort under two viewing
//! conditions derived from the VR-viewport study of Xu, Han & Qian
//! (CoNEXT'19, 275 users / 156 h):
//!
//! * **average** — median angular speeds: 14.8 °/s latitude (pitch),
//!   27.6 °/s longitude (yaw);
//! * **extreme** — 180 °/s on both axes (the study's maximum).
//!
//! The generator performs an orbital/pan walk around the scene center with
//! per-frame angular increments drawn around those speeds, giving the
//! frame-to-frame coherence (average) or near-incoherence (extreme) that the
//! posteriori-knowledge techniques exploit.

use crate::camera::Camera;
use crate::math::Vec3;
use crate::util::Rng;

/// Viewing condition from the user-behavior study (paper §2.2, §4.B/4.C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ViewCondition {
    /// Median head-movement speeds (14.8 °/s pitch, 27.6 °/s yaw).
    Average,
    /// Maximum speeds (180 °/s both axes).
    Extreme,
    /// No movement at all (upper bound for posteriori reuse).
    Static,
}

impl ViewCondition {
    /// (pitch °/s, yaw °/s)
    pub fn speeds_deg(self) -> (f32, f32) {
        match self {
            ViewCondition::Average => (14.8, 27.6),
            ViewCondition::Extreme => (180.0, 180.0),
            ViewCondition::Static => (0.0, 0.0),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ViewCondition::Average => "average",
            ViewCondition::Extreme => "extreme",
            ViewCondition::Static => "static",
        }
    }

    /// Inverse of [`ViewCondition::label`] (declarative config parsing).
    pub fn from_label(label: &str) -> Option<ViewCondition> {
        match label {
            "average" => Some(ViewCondition::Average),
            "extreme" => Some(ViewCondition::Extreme),
            "static" => Some(ViewCondition::Static),
            _ => None,
        }
    }
}

/// Generates a sequence of camera poses (+ scene time) for `frames` frames
/// at `fps`, orbiting `center` at `radius`.
#[derive(Debug, Clone)]
pub struct Trajectory {
    pub condition: ViewCondition,
    pub frames: usize,
    pub fps: f32,
    pub center: Vec3,
    pub radius: f32,
    pub seed: u64,
    /// Scene-time span [t0, t1] of the clip (dynamic scenes).
    pub time_span: (f32, f32),
    /// Wall-clock length of the clip in seconds: scene time advances at
    /// real-time playback rate, (1/fps)/clip_seconds of the span per frame
    /// (N3V-class clips are ~10 s / 300 frames).
    pub clip_seconds: f32,
}

impl Trajectory {
    pub fn new(condition: ViewCondition, frames: usize) -> Trajectory {
        Trajectory {
            condition,
            frames,
            fps: 30.0,
            center: Vec3::ZERO,
            radius: 12.0,
            seed: 0x3D6A_0C1A,
            time_span: (0.0, 1.0),
            clip_seconds: 10.0,
        }
    }

    pub fn with_scene(mut self, center: Vec3, radius: f32) -> Trajectory {
        self.center = center;
        self.radius = radius;
        self
    }

    pub fn with_time_span(mut self, t0: f32, t1: f32) -> Trajectory {
        self.time_span = (t0, t1);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Trajectory {
        self.seed = seed;
        self
    }

    /// Override the clip's wall-clock length (controls how fast scene time
    /// advances per frame).
    pub fn with_clip_seconds(mut self, secs: f32) -> Trajectory {
        self.clip_seconds = secs;
        self
    }

    /// Generate all (camera, scene-time) pairs.
    pub fn generate(&self, template: &Camera) -> Vec<(Camera, f32)> {
        let mut rng = Rng::new(self.seed);
        let (pitch_speed, yaw_speed) = self.condition.speeds_deg();
        let dt = 1.0 / self.fps;

        let mut yaw = 0.0f32; // degrees
        let mut pitch = 10.0f32; // slight downward look
        // Direction of travel flips occasionally (random walk with momentum),
        // matching the study's bounded per-frame angular displacement.
        let mut yaw_dir = 1.0f32;
        let mut pitch_dir = 1.0f32;

        let mut out = Vec::with_capacity(self.frames);
        let total_clip_frames = (self.fps * self.clip_seconds).max(1.0);
        for i in 0..self.frames {
            // Real-time playback: scene time advances 1/(fps·clip_s) of the
            // span per frame (clamped at the clip end).
            let frac = (i as f32 / total_clip_frames).min(1.0);
            let t = self.time_span.0 + frac * (self.time_span.1 - self.time_span.0);

            let eye = self.center
                + Vec3::new(
                    self.radius * yaw.to_radians().cos() * pitch.to_radians().cos(),
                    self.radius * pitch.to_radians().sin(),
                    self.radius * yaw.to_radians().sin() * pitch.to_radians().cos(),
                );
            let mut cam = *template;
            cam.set_pose(eye, self.center, Vec3::new(0.0, 1.0, 0.0));
            out.push((cam, t));

            // Advance angles: jittered speed (±30 %), occasional direction flip.
            let jitter = 0.7 + 0.6 * rng.f32();
            yaw += yaw_dir * yaw_speed * dt * jitter;
            pitch += pitch_dir * pitch_speed * dt * jitter;
            if rng.chance(0.04) {
                yaw_dir = -yaw_dir;
            }
            if rng.chance(0.06) || !(-35.0..=55.0).contains(&pitch) {
                pitch_dir = -pitch_dir;
                pitch = pitch.clamp(-35.0, 55.0);
            }
        }
        out
    }

    /// Per-frame angular displacement (degrees) implied by the condition —
    /// used by analytic models and tests.
    pub fn per_frame_displacement(&self) -> (f32, f32) {
        let (p, y) = self.condition.speeds_deg();
        (p / self.fps, y / self.fps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn template() -> Camera {
        Camera::look_at(
            Vec3::new(0.0, 0.0, 10.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            16.0 / 9.0,
            0.1,
            200.0,
        )
    }

    #[test]
    fn generates_requested_frames_with_realtime_pacing() {
        let tr = Trajectory::new(ViewCondition::Average, 30).with_time_span(0.0, 2.0);
        let seq = tr.generate(&template());
        assert_eq!(seq.len(), 30);
        assert_eq!(seq[0].1, 0.0);
        // 30 frames of a 10 s / 30 FPS clip = 29/300 of the 2.0 span.
        assert!((seq[29].1 - 2.0 * 29.0 / 300.0).abs() < 1e-5, "got {}", seq[29].1);
        // A full-clip render reaches the end of the span.
        let full = Trajectory::new(ViewCondition::Average, 301).with_time_span(0.0, 2.0);
        let seq = full.generate(&template());
        assert!((seq[300].1 - 2.0).abs() < 1e-6);
    }

    #[test]
    fn average_moves_less_than_extreme() {
        let t = template();
        let avg: Vec<_> = Trajectory::new(ViewCondition::Average, 60).generate(&t);
        let ext: Vec<_> = Trajectory::new(ViewCondition::Extreme, 60).generate(&t);
        let disp = |seq: &[(Camera, f32)]| -> f32 {
            seq.windows(2)
                .map(|w| (w[1].0.position - w[0].0.position).length())
                .sum()
        };
        assert!(
            disp(&ext) > 3.0 * disp(&avg),
            "extreme {} vs average {}",
            disp(&ext),
            disp(&avg)
        );
    }

    #[test]
    fn static_condition_does_not_move() {
        let t = template();
        let seq = Trajectory::new(ViewCondition::Static, 10).generate(&t);
        for w in seq.windows(2) {
            assert!((w[1].0.position - w[0].0.position).length() < 1e-5);
        }
    }

    #[test]
    fn cameras_look_at_center() {
        let t = template();
        let seq = Trajectory::new(ViewCondition::Average, 20).generate(&t);
        for (cam, _) in &seq {
            // Scene center should project near the principal point.
            let (px, _) = cam.project(Vec3::ZERO).expect("center visible");
            assert!((px.x - cam.intrinsics.cx).abs() < 1.0);
            assert!((px.y - cam.intrinsics.cy).abs() < 1.0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let t = template();
        let a = Trajectory::new(ViewCondition::Average, 15).generate(&t);
        let b = Trajectory::new(ViewCondition::Average, 15).generate(&t);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.0.position, y.0.position);
        }
    }
}
