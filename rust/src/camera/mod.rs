//! Camera model: intrinsics/extrinsics, view & projection matrices, and the
//! head-movement trajectory generator used for the paper's *average* /
//! *extreme* viewing-condition experiments.

pub mod trajectory;

pub use trajectory::{Trajectory, ViewCondition};

use crate::math::{Frustum, Mat3, Mat4, Vec2, Vec3};

/// Pinhole intrinsics.
#[derive(Debug, Clone, Copy)]
pub struct Intrinsics {
    pub fx: f32,
    pub fy: f32,
    pub cx: f32,
    pub cy: f32,
    pub width: usize,
    pub height: usize,
}

impl Intrinsics {
    /// From a vertical field of view and image size.
    pub fn from_fov(fov_y: f32, width: usize, height: usize) -> Intrinsics {
        let fy = height as f32 / (2.0 * (fov_y * 0.5).tan());
        let fx = fy; // square pixels
        Intrinsics {
            fx,
            fy,
            cx: width as f32 * 0.5,
            cy: height as f32 * 0.5,
            width,
            height,
        }
    }

    pub fn aspect(&self) -> f32 {
        self.width as f32 / self.height as f32
    }

    pub fn fov_y(&self) -> f32 {
        2.0 * (self.height as f32 / (2.0 * self.fy)).atan()
    }
}

/// Full camera: pose (world→camera) + intrinsics + clip range.
///
/// Camera space follows the 3DGS convention: +z looks *forward* into the
/// scene after the view transform (we use a right-handed look-at with the
/// camera looking down −z in world space, mapped to +z depth in camera
/// space for splatting depth).
#[derive(Debug, Clone, Copy)]
pub struct Camera {
    /// World → camera rigid transform.
    pub view: Mat4,
    pub intrinsics: Intrinsics,
    pub near: f32,
    pub far: f32,
    /// Camera position in world space (cached).
    pub position: Vec3,
}

impl Camera {
    /// Construct from eye/target/up plus perspective parameters.
    #[allow(clippy::too_many_arguments)]
    pub fn look_at(
        eye: Vec3,
        target: Vec3,
        up: Vec3,
        fov_y: f32,
        aspect: f32,
        near: f32,
        far: f32,
    ) -> Camera {
        let height = 720usize;
        let width = (height as f32 * aspect).round() as usize;
        let mut cam = Camera {
            view: Mat4::IDENTITY,
            intrinsics: Intrinsics::from_fov(fov_y, width, height),
            near,
            far,
            position: eye,
        };
        cam.set_pose(eye, target, up);
        cam
    }

    /// Change the image resolution, rebuilding the intrinsics for the same
    /// vertical field of view.
    pub fn set_resolution(&mut self, width: usize, height: usize) {
        let fov = self.intrinsics.fov_y();
        self.intrinsics = Intrinsics::from_fov(fov, width, height);
    }

    /// Re-point the camera (keeps intrinsics/clip planes).
    pub fn set_pose(&mut self, eye: Vec3, target: Vec3, up: Vec3) {
        // Right-handed basis: f = forward (into scene), r = right, u = true up.
        let f = (target - eye).normalized();
        let r = f.cross(up).normalized();
        let u = r.cross(f);
        // View matrix maps world → camera with camera looking down +z:
        // rows are (r, u, f) so depth = f·(p - eye) > 0 in front.
        self.view = Mat4 {
            m: [
                [r.x, r.y, r.z, -r.dot(eye)],
                [u.x, u.y, u.z, -u.dot(eye)],
                [f.x, f.y, f.z, -f.dot(eye)],
                [0.0, 0.0, 0.0, 1.0],
            ],
        };
        self.position = eye;
    }

    /// Perspective projection matrix (OpenGL-style clip volume, z into [-w,w]).
    pub fn projection(&self) -> Mat4 {
        let fov_y = self.intrinsics.fov_y();
        let aspect = self.intrinsics.aspect();
        let t = 1.0 / (fov_y * 0.5).tan();
        let (n, f) = (self.near, self.far);
        Mat4 {
            m: [
                [t / aspect, 0.0, 0.0, 0.0],
                [0.0, t, 0.0, 0.0],
                [0.0, 0.0, (f + n) / (f - n), -2.0 * f * n / (f - n)],
                [0.0, 0.0, 1.0, 0.0],
            ],
        }
    }

    /// Combined view-projection.
    pub fn view_proj(&self) -> Mat4 {
        self.projection().mul_mat(&self.view)
    }

    /// The camera's frustum in world space.
    pub fn frustum(&self) -> Frustum {
        Frustum::from_view_proj(&self.view_proj())
    }

    /// World point → camera space (x right, y up, z = depth into scene).
    #[inline]
    pub fn to_camera(&self, p: Vec3) -> Vec3 {
        self.view.transform_point(p).truncate()
    }

    /// Camera-space point → pixel coordinates + depth.
    /// Returns `None` when behind the near plane.
    #[inline]
    pub fn project_cam(&self, pc: Vec3) -> Option<(Vec2, f32)> {
        if pc.z < self.near {
            return None;
        }
        let k = &self.intrinsics;
        Some((
            Vec2::new(k.fx * pc.x / pc.z + k.cx, k.fy * pc.y / pc.z + k.cy),
            pc.z,
        ))
    }

    /// World point → pixel coordinates + depth.
    pub fn project(&self, p: Vec3) -> Option<(Vec2, f32)> {
        self.project_cam(self.to_camera(p))
    }

    /// Jacobian of the perspective projection at camera-space point `pc`
    /// (eq. 8's `J`, the EWA-splatting local affine approximation).
    pub fn projection_jacobian(&self, pc: Vec3) -> Mat3 {
        let k = &self.intrinsics;
        let (x, y, z) = (pc.x, pc.y, pc.z.max(1e-6));
        Mat3 {
            m: [
                [k.fx / z, 0.0, -k.fx * x / (z * z)],
                [0.0, k.fy / z, -k.fy * y / (z * z)],
                [0.0, 0.0, 0.0],
            ],
        }
    }

    /// Rotation part of the view transform (eq. 8's `W`).
    pub fn view_rotation(&self) -> Mat3 {
        self.view.upper3()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cam() -> Camera {
        Camera::look_at(
            Vec3::new(0.0, 0.0, 5.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            16.0 / 9.0,
            0.1,
            100.0,
        )
    }

    #[test]
    fn center_projects_to_principal_point() {
        let c = cam();
        let (px, depth) = c.project(Vec3::ZERO).unwrap();
        assert!((px.x - c.intrinsics.cx).abs() < 1e-3);
        assert!((px.y - c.intrinsics.cy).abs() < 1e-3);
        assert!((depth - 5.0).abs() < 1e-4);
    }

    #[test]
    fn behind_camera_is_rejected() {
        let c = cam();
        assert!(c.project(Vec3::new(0.0, 0.0, 10.0)).is_none());
    }

    #[test]
    fn depth_increases_away_from_camera() {
        let c = cam();
        let (_, d1) = c.project(Vec3::new(0.0, 0.0, 0.0)).unwrap();
        let (_, d2) = c.project(Vec3::new(0.0, 0.0, -10.0)).unwrap();
        assert!(d2 > d1);
    }

    #[test]
    fn jacobian_matches_finite_difference() {
        let c = cam();
        let pc = Vec3::new(0.5, -0.3, 4.0);
        let j = c.projection_jacobian(pc);
        let eps = 1e-3;
        let f = |p: Vec3| {
            let k = &c.intrinsics;
            Vec2::new(k.fx * p.x / p.z, k.fy * p.y / p.z)
        };
        for (axis, dv) in [
            (0, Vec3::new(eps, 0.0, 0.0)),
            (1, Vec3::new(0.0, eps, 0.0)),
            (2, Vec3::new(0.0, 0.0, eps)),
        ] {
            let d = (f(pc + dv) - f(pc - dv)) * (1.0 / (2.0 * eps));
            assert!((j.m[0][axis] - d.x).abs() < 0.05, "J[0][{axis}] {} vs {}", j.m[0][axis], d.x);
            assert!((j.m[1][axis] - d.y).abs() < 0.05, "J[1][{axis}] {} vs {}", j.m[1][axis], d.y);
        }
    }

    #[test]
    fn view_rotation_orthonormal() {
        let c = cam();
        let r = c.view_rotation();
        let rrt = r.mul_mat(&r.transpose());
        for i in 0..3 {
            for j in 0..3 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((rrt.m[i][j] - expect).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn intrinsics_fov_roundtrip() {
        let k = Intrinsics::from_fov(1.0, 1280, 720);
        assert!((k.fov_y() - 1.0).abs() < 1e-5);
    }
}
