//! Lane-batched (SIMD-style) blend datapath — 8 horizontally-adjacent
//! pixels per iteration.
//!
//! Std-only: [`F32x8`] is a plain `[f32; 8]` with element-wise operations
//! the autovectorizer turns into vector code (no nightly `std::simd`, no
//! dependencies). The payoff is not reordered arithmetic — it is amortized
//! per-splat work (one depth-order walk, one parameter load per 8 pixels)
//! plus straight-line loop bodies the compiler can vectorize.
//!
//! **Bit-identity contract.** Every lane performs the *identical scalar
//! f32 op sequence* the per-pixel kernels run — same expression shapes,
//! same evaluation order, same `f16` quantization points, same LUT
//! gathers — and lanes that the scalar code would have skipped
//! (`continue`) or stopped (`break` on saturation) are masked out with
//! selects that leave their state untouched. IEEE f32 arithmetic is
//! deterministic per op, so pixels *and* NMC integer op-counts are
//! byte-identical to the scalar backend (see `render/README.md`).

use crate::dcim::nmc::{NmcAccumulator, T_MIN};
use crate::dcim::ExpLut;
use crate::math::f16;
use crate::render::reference::EXP_CUTOFF;
use crate::tiles::intersect::Splat2D;

/// Lane width of the batched kernels (one tile row holds two spans).
pub const LANES: usize = 8;

/// Which blend datapath the rasterizers run. Both produce bit-identical
/// pixels and NMC statistics; the choice only trades host wall-clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RenderBackend {
    /// The original per-pixel `shade_pixel` loop.
    Scalar,
    /// The 8-wide lane-batched kernel (this module).
    Lanes,
}

impl RenderBackend {
    /// Default when neither config nor environment says otherwise.
    pub const DEFAULT: RenderBackend = RenderBackend::Lanes;

    pub fn label(self) -> &'static str {
        match self {
            RenderBackend::Scalar => "scalar",
            RenderBackend::Lanes => "lanes",
        }
    }

    pub fn from_label(s: &str) -> Option<RenderBackend> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(RenderBackend::Scalar),
            "lanes" => Some(RenderBackend::Lanes),
            _ => None,
        }
    }

    /// Resolve from the `PALLAS_RENDER_BACKEND` environment variable
    /// (`scalar` | `lanes`), else [`RenderBackend::DEFAULT`] — the same
    /// shape as `resolve_threads`/`PALLAS_THREADS`.
    pub fn from_env() -> RenderBackend {
        std::env::var("PALLAS_RENDER_BACKEND")
            .ok()
            .and_then(|s| RenderBackend::from_label(&s))
            .unwrap_or(RenderBackend::DEFAULT)
    }
}

/// Eight f32 lanes; every operation is element-wise (same op per lane).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct F32x8(pub [f32; LANES]);

impl F32x8 {
    #[inline(always)]
    pub fn splat(v: f32) -> F32x8 {
        F32x8([v; LANES])
    }

    #[inline(always)]
    pub fn from_fn(f: impl FnMut(usize) -> f32) -> F32x8 {
        F32x8(std::array::from_fn(f))
    }

    #[inline(always)]
    pub fn map(self, mut f: impl FnMut(f32) -> f32) -> F32x8 {
        F32x8(std::array::from_fn(|i| f(self.0[i])))
    }

    /// Per-lane `a < b` (false for NaN operands, like the scalar `<`).
    #[inline(always)]
    pub fn lt(self, o: F32x8) -> Mask8 {
        Mask8(std::array::from_fn(|i| self.0[i] < o.0[i]))
    }
}

impl std::ops::Add for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn add(self, o: F32x8) -> F32x8 {
        F32x8(std::array::from_fn(|i| self.0[i] + o.0[i]))
    }
}

impl std::ops::Sub for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn sub(self, o: F32x8) -> F32x8 {
        F32x8(std::array::from_fn(|i| self.0[i] - o.0[i]))
    }
}

impl std::ops::Mul for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn mul(self, o: F32x8) -> F32x8 {
        F32x8(std::array::from_fn(|i| self.0[i] * o.0[i]))
    }
}

/// Eight boolean lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mask8(pub [bool; LANES]);

impl Mask8 {
    pub const ALL: Mask8 = Mask8([true; LANES]);
    pub const NONE: Mask8 = Mask8([false; LANES]);

    #[inline(always)]
    pub fn any(self) -> bool {
        self.0.iter().any(|&b| b)
    }

    /// Number of set lanes (the NMC op-count increment).
    #[inline(always)]
    pub fn count(self) -> u64 {
        self.0.iter().map(|&b| b as u64).sum()
    }

    #[inline(always)]
    pub fn and(self, o: Mask8) -> Mask8 {
        Mask8(std::array::from_fn(|i| self.0[i] && o.0[i]))
    }

    #[inline(always)]
    pub fn and_not(self, o: Mask8) -> Mask8 {
        Mask8(std::array::from_fn(|i| self.0[i] && !o.0[i]))
    }

    /// Per-lane `if mask { a } else { b }`.
    #[inline(always)]
    pub fn select(self, a: F32x8, b: F32x8) -> F32x8 {
        F32x8(std::array::from_fn(|i| if self.0[i] { a.0[i] } else { b.0[i] }))
    }
}

/// Per-lane merged exponent for 8 adjacent pixels of one row — the exact
/// expression shape of [`splat_exponent`](crate::tiles::intersect::splat_exponent)
/// (`dy` is row-constant, so its terms splat):
/// `-0.5 * (a·dx·dx + (2a₁)·dx·dy + c·dy·dy)` with left-associated
/// products and sums, identical per lane to the scalar evaluation.
#[inline(always)]
fn splat_exponent_lanes(s: &Splat2D, pxc: F32x8, pyc: f32) -> F32x8 {
    let dx = pxc - F32x8::splat(s.mean.x);
    let dy = pyc - s.mean.y;
    let t1 = F32x8::splat(s.conic[0]) * dx * dx;
    let t2 = F32x8::splat(2.0 * s.conic[1]) * dx * F32x8::splat(dy);
    let t3 = F32x8::splat(s.conic[2] * dy * dy);
    F32x8::splat(-0.5) * (t1 + t2 + t3)
}

/// Pixel-center x coordinates of the 8-lane span starting at `px0`.
#[inline(always)]
fn span_centers(px0: usize) -> F32x8 {
    F32x8::from_fn(|i| (px0 + i) as f32 + 0.5)
}

/// Transpose three RGB lane vectors into 8 per-pixel triples.
#[inline(always)]
fn transpose_rgb(rgb: [F32x8; 3]) -> [[f32; 3]; LANES] {
    std::array::from_fn(|i| [rgb[0].0[i], rgb[1].0[i], rgb[2].0[i]])
}

/// Hardware-path lane kernel: blend 8 adjacent pixels of row `py`
/// (starting at `px0`) through the depth-ordered splat list, charging
/// blend arithmetic to `nmc`. Bit-identical per lane to
/// `HwRenderer::shade_pixel` — the skip masks are the *negations* of the
/// scalar `continue` conditions (so NaN exponents take the same path) and
/// saturation deactivates a lane exactly where the scalar loop breaks.
pub fn shade_span_hw(
    exp: &ExpLut,
    splats: &[Splat2D],
    order: &[u32],
    px0: usize,
    py: usize,
    nmc: &mut NmcAccumulator,
) -> [[f32; 3]; LANES] {
    let pxc = span_centers(px0);
    let pyc = py as f32 + 0.5;
    let mut rgb = [F32x8::splat(0.0); 3];
    let mut trans = F32x8::splat(1.0);
    let mut active = Mask8::ALL;
    let mut blend_ops = 0u64;
    let mut saturated = 0u64;

    let cutoff = F32x8::splat(EXP_CUTOFF);
    let alpha_min = F32x8::splat(1.0 / 255.0);
    let t_min = F32x8::splat(T_MIN);

    for &si in order {
        if !active.any() {
            break;
        }
        let s = &splats[si as usize];
        let e = splat_exponent_lanes(s, pxc, pyc);
        let skip_far = e.lt(cutoff);
        let e_hw = e.map(f16::quantize);
        // DD3D-Flow: exponent pre-scaled by 1/ln2 offline.
        let x = e_hw * F32x8::splat(std::f32::consts::LOG2_E);
        let alpha = F32x8::splat(s.alpha_base) * F32x8(exp.exp2_lanes(x.0));
        let skip_dim = alpha.lt(alpha_min);
        let contribute = active.and_not(skip_far).and_not(skip_dim);
        if !contribute.any() {
            continue;
        }
        blend_ops += contribute.count();
        // NmcAccumulator::blend, lane-wise with masked state updates.
        let a = alpha.map(|v| v.clamp(0.0, 0.999));
        let w = a * trans;
        let color = [s.color.x, s.color.y, s.color.z];
        for (acc, c) in rgb.iter_mut().zip(color) {
            *acc = contribute.select(*acc + w * F32x8::splat(c), *acc);
        }
        let t_new = trans * (F32x8::splat(1.0) - a);
        trans = contribute.select(t_new, trans);
        let sat = contribute.and(t_new.lt(t_min));
        saturated += sat.count();
        active = active.and_not(sat);
    }
    nmc.tally(blend_ops, saturated);
    transpose_rgb(rgb)
}

/// Reference-path lane kernel: exact `exp()` per lane, the precise op
/// sequence of `ReferenceRenderer::render_splats`'s inner loop (note the
/// reference clamps alpha with `.min(0.999)` *before* its dim-splat skip,
/// and has no NMC counters).
pub fn shade_span_reference(
    splats: &[Splat2D],
    order: &[u32],
    px0: usize,
    py: usize,
) -> [[f32; 3]; LANES] {
    let pxc = span_centers(px0);
    let pyc = py as f32 + 0.5;
    let mut rgb = [F32x8::splat(0.0); 3];
    let mut trans = F32x8::splat(1.0);
    let mut active = Mask8::ALL;

    let cutoff = F32x8::splat(EXP_CUTOFF);
    let alpha_min = F32x8::splat(1.0 / 255.0);

    for &si in order {
        if !active.any() {
            break;
        }
        let s = &splats[si as usize];
        let e = splat_exponent_lanes(s, pxc, pyc);
        let skip_far = e.lt(cutoff);
        let alpha = (F32x8::splat(s.alpha_base) * e.map(f32::exp)).map(|v| v.min(0.999));
        let skip_dim = alpha.lt(alpha_min);
        let contribute = active.and_not(skip_far).and_not(skip_dim);
        if !contribute.any() {
            continue;
        }
        let w = alpha * trans;
        let color = [s.color.x, s.color.y, s.color.z];
        for (acc, c) in rgb.iter_mut().zip(color) {
            *acc = contribute.select(*acc + w * F32x8::splat(c), *acc);
        }
        let t_new = trans * (F32x8::splat(1.0) - alpha);
        trans = contribute.select(t_new, trans);
        let dead = contribute.and(t_new.lt(F32x8::splat(1.0 / 255.0)));
        active = active.and_not(dead);
    }
    transpose_rgb(rgb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_select_and_count() {
        let m = Mask8([true, false, true, false, true, false, true, false]);
        assert_eq!(m.count(), 4);
        let a = F32x8::splat(1.0);
        let b = F32x8::splat(2.0);
        let s = m.select(a, b);
        assert_eq!(s.0, [1.0, 2.0, 1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
        assert!(Mask8::ALL.any() && !Mask8::NONE.any());
    }

    #[test]
    fn lt_is_false_for_nan_like_scalar() {
        let a = F32x8([f32::NAN, 1.0, f32::NAN, 0.0, 0.0, 0.0, 0.0, 0.0]);
        let b = F32x8::splat(0.5);
        let m = a.lt(b);
        assert!(!m.0[0], "NaN < x must be false");
        assert!(!m.0[1]);
        assert!(m.0[3]);
    }

    #[test]
    fn lane_arithmetic_is_elementwise() {
        let a = F32x8::from_fn(|i| i as f32);
        let b = F32x8::splat(2.0);
        assert_eq!((a * b).0[3], 6.0);
        assert_eq!((a + b).0[0], 2.0);
        assert_eq!((a - b).0[1], -1.0);
    }

    #[test]
    fn backend_labels_round_trip() {
        for b in [RenderBackend::Scalar, RenderBackend::Lanes] {
            assert_eq!(RenderBackend::from_label(b.label()), Some(b));
        }
        assert_eq!(RenderBackend::from_label(" LANES "), Some(RenderBackend::Lanes));
        assert_eq!(RenderBackend::from_label("simd"), None);
    }
}
