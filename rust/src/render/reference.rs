//! Exact f32 CPU reference rasterizer — the correctness oracle.
//!
//! Full pipeline in plain f32 with `exp()` from libm: project (eqs. 5–8),
//! tile-bin, depth-sort per tile (exact comparison sort), front-to-back
//! blend (eqs. 9–10). No FP16, no LUT, no early-exit approximations beyond
//! the standard 3DGS cutoffs (shared with the hardware path so both
//! renderers draw the same primitive set).

use super::lanes::{self, RenderBackend, LANES};
use super::Image;
use crate::camera::Camera;
use crate::scene::Scene;
use crate::tiles::intersect::{bin_splats, project_gaussian, splat_exponent, Splat2D, TileGrid};

/// Exponent below which a contribution is invisible (α < ~1e-6): skip.
pub const EXP_CUTOFF: f32 = -14.0;

/// The reference renderer.
pub struct ReferenceRenderer {
    pub grid: TileGrid,
    /// Blend datapath: scalar per-pixel loop or the 8-wide lane kernel
    /// with exact `exp()` per lane — bit-identical images either way.
    pub backend: RenderBackend,
}

impl ReferenceRenderer {
    pub fn new(width: usize, height: usize) -> ReferenceRenderer {
        ReferenceRenderer {
            grid: TileGrid::new(width, height),
            backend: RenderBackend::from_env(),
        }
    }

    /// Pin the blend datapath (builder form — `new` reads the
    /// `PALLAS_RENDER_BACKEND` environment default).
    pub fn with_backend(mut self, backend: RenderBackend) -> ReferenceRenderer {
        self.backend = backend;
        self
    }

    /// Render the scene at time `t`.
    pub fn render(&self, scene: &Scene, cam: &Camera, t: f32) -> Image {
        let splats = self.project_all(scene, cam, t);
        self.render_splats(&splats)
    }

    /// Projection stage (exposed so tests can reuse the splat list).
    /// Applies the standard 3DGS frustum cull (3σ sphere) so the primitive
    /// set matches the hardware path exactly.
    pub fn project_all(&self, scene: &Scene, cam: &Camera, t: f32) -> Vec<Splat2D> {
        let frustum = cam.frustum();
        scene
            .gaussians
            .iter()
            .enumerate()
            .filter(|(_, g)| crate::culling::gaussian_visible_in(g, &frustum, t))
            .filter_map(|(i, g)| project_gaussian(g, i as u32, cam, t))
            .collect()
    }

    /// Blend one pixel through the depth-ordered splat list — the exact
    /// scalar inner loop (also the ragged-row tail of the lanes backend).
    fn shade_pixel(&self, splats: &[Splat2D], order: &[u32], px: usize, py: usize) -> [f32; 3] {
        let mut rgb = [0.0f32; 3];
        let mut transmittance = 1.0f32;
        for &si in order {
            let s = &splats[si as usize];
            let e = splat_exponent(s, px as f32 + 0.5, py as f32 + 0.5);
            if e < EXP_CUTOFF {
                continue;
            }
            let alpha = (s.alpha_base * e.exp()).min(0.999);
            if alpha < 1.0 / 255.0 {
                continue;
            }
            let w = alpha * transmittance;
            rgb[0] += w * s.color.x;
            rgb[1] += w * s.color.y;
            rgb[2] += w * s.color.z;
            transmittance *= 1.0 - alpha;
            if transmittance < 1.0 / 255.0 {
                break;
            }
        }
        rgb
    }

    /// Rasterize pre-projected splats.
    pub fn render_splats(&self, splats: &[Splat2D]) -> Image {
        let mut img = Image::new(self.grid.width, self.grid.height);
        let bins = bin_splats(&self.grid, splats);
        // Pooled across tiles: one depth-order buffer for the whole frame
        // instead of a `bins[tile].clone()` per non-empty tile.
        let mut order: Vec<u32> = Vec::new();

        for tile in 0..self.grid.n_tiles() {
            if bins[tile].is_empty() {
                continue;
            }
            order.clear();
            order.extend_from_slice(&bins[tile]);
            // Exact depth sort.
            order.sort_by(|&a, &b| {
                splats[a as usize]
                    .depth
                    .partial_cmp(&splats[b as usize].depth)
                    .unwrap_or(std::cmp::Ordering::Equal)
            });

            let (x0, y0, x1, y1) = self.grid.tile_pixels(tile);
            for py in y0..y1 {
                let mut px = x0;
                if self.backend == RenderBackend::Lanes {
                    while px + LANES <= x1 {
                        let span = lanes::shade_span_reference(splats, &order, px, py);
                        for (i, rgb) in span.iter().enumerate() {
                            img.set_pixel(px + i, py, *rgb);
                        }
                        px += LANES;
                    }
                }
                while px < x1 {
                    img.set_pixel(px, py, self.shade_pixel(splats, &order, px, py));
                    px += 1;
                }
            }
        }
        img
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec3;
    use crate::scene::Gaussian4D;

    fn cam(w: usize, h: usize) -> Camera {
        let mut c = Camera::look_at(
            Vec3::new(0.0, 0.0, 10.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            w as f32 / h as f32,
            0.1,
            100.0,
        );
        c.set_resolution(w, h);
        c
    }

    fn one_gaussian_scene(color: Vec3) -> Scene {
        Scene::new(
            "one",
            vec![Gaussian4D::isotropic(Vec3::ZERO, 0.8, 0.95, color)],
            false,
        )
    }

    #[test]
    fn single_gaussian_renders_at_center() {
        let scene = one_gaussian_scene(Vec3::new(0.4, 0.1, -0.2));
        let c = cam(128, 128);
        let r = ReferenceRenderer::new(128, 128);
        let img = r.render(&scene, &c, 0.0);
        let center = img.pixel(64, 64);
        let corner = img.pixel(0, 0);
        // isotropic() color mapping: evaluated = color + 0.5 clamped.
        assert!(center[0] > 0.5, "center red {}", center[0]);
        assert!(corner[0] < 1e-3, "corner must stay background");
        // Color ordering preserved: r > g > b since 0.9 > 0.6 > 0.3.
        assert!(center[0] > center[1] && center[1] > center[2]);
    }

    #[test]
    fn occlusion_front_wins() {
        let mut front = Gaussian4D::isotropic(Vec3::new(0.0, 0.0, 3.0), 0.6, 0.95, Vec3::new(0.5, -0.5, -0.5));
        let back = Gaussian4D::isotropic(Vec3::new(0.0, 0.0, -3.0), 0.6, 0.95, Vec3::new(-0.5, 0.5, -0.5));
        front.opacity = 0.95;
        let scene = Scene::new("two", vec![back, front], false);
        let c = cam(96, 96);
        let r = ReferenceRenderer::new(96, 96);
        let img = r.render(&scene, &c, 0.0);
        let center = img.pixel(48, 48);
        // Front is red (1.0, 0, 0): red must dominate green.
        assert!(center[0] > 2.0 * center[1], "front splat should occlude: {center:?}");
    }

    #[test]
    fn empty_scene_black_image() {
        let scene = Scene::new("empty", vec![], false);
        let c = cam(64, 64);
        let img = ReferenceRenderer::new(64, 64).render(&scene, &c, 0.0);
        assert!(img.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn dynamic_gaussian_moves_between_frames() {
        let mut g = Gaussian4D::isotropic(Vec3::new(-2.0, 0.0, 0.0), 0.5, 0.95, Vec3::new(0.5, 0.5, 0.5));
        g.mu_t = 0.5;
        g.sigma_t = 10.0; // visible all clip
        g.velocity = Vec3::new(8.0, 0.0, 0.0);
        let scene = Scene::new("mover", vec![g], true);
        let c = cam(128, 64);
        let r = ReferenceRenderer::new(128, 64);
        let img0 = r.render(&scene, &c, 0.25);
        let img1 = r.render(&scene, &c, 0.75);
        // Center of mass must move right.
        let com = |img: &Image| -> f32 {
            let mut wsum = 0.0;
            let mut xsum = 0.0;
            for y in 0..64 {
                for x in 0..128 {
                    let l = img.pixel(x, y)[0];
                    wsum += l;
                    xsum += l * x as f32;
                }
            }
            xsum / wsum.max(1e-9)
        };
        assert!(com(&img1) > com(&img0) + 10.0, "{} vs {}", com(&img1), com(&img0));
    }
}
