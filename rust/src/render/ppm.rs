//! Binary PPM (P6) image output with sRGB-ish gamma — lets examples dump
//! inspectable frames without an image-crate dependency.

use super::Image;
use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// Encode with gamma 1/2.2 and 8-bit quantization.
pub fn encode(img: &Image) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + img.data.len());
    out.extend_from_slice(format!("P6\n{} {}\n255\n", img.width, img.height).as_bytes());
    for &v in &img.data {
        let g = v.clamp(0.0, 1.0).powf(1.0 / 2.2);
        out.push((g * 255.0 + 0.5) as u8);
    }
    out
}

/// Write to a file.
pub fn save(img: &Image, path: &Path) -> Result<()> {
    let bytes = encode(img);
    let mut f =
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    f.write_all(&bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_and_size() {
        let img = Image::new(3, 2);
        let bytes = encode(&img);
        assert!(bytes.starts_with(b"P6\n3 2\n255\n"));
        assert_eq!(bytes.len(), 11 + 3 * 2 * 3);
    }

    #[test]
    fn white_maps_to_255_black_to_0() {
        let mut img = Image::new(1, 1);
        img.set_pixel(0, 0, [1.0, 0.0, 1.0]);
        let bytes = encode(&img);
        let px = &bytes[bytes.len() - 3..];
        assert_eq!(px[0], 255);
        assert_eq!(px[1], 0);
        assert_eq!(px[2], 255);
    }

    #[test]
    fn values_clamped() {
        let mut img = Image::new(1, 1);
        img.set_pixel(0, 0, [2.0, -1.0, 0.5]);
        let bytes = encode(&img);
        let px = &bytes[bytes.len() - 3..];
        assert_eq!(px[0], 255);
        assert_eq!(px[1], 0);
        assert!(px[2] > 100 && px[2] < 255);
    }

    #[test]
    fn save_roundtrip() {
        let img = Image::new(4, 4);
        let path = std::env::temp_dir().join("gaucim_test.ppm");
        save(&img, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(bytes, encode(&img));
    }
}
