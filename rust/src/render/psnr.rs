//! PSNR / MSE between rendered images (Table I's quality metric).

use super::Image;

/// Mean squared error over all channels (images must match in size).
pub fn mse(a: &Image, b: &Image) -> f64 {
    assert_eq!(a.width, b.width, "image width mismatch");
    assert_eq!(a.height, b.height, "image height mismatch");
    if a.data.is_empty() {
        return 0.0;
    }
    let mut sum = 0.0f64;
    for (&x, &y) in a.data.iter().zip(&b.data) {
        let d = (x - y) as f64;
        sum += d * d;
    }
    sum / a.data.len() as f64
}

/// Peak signal-to-noise ratio in dB for a peak value of 1.0 (linear RGB).
/// Identical images return +inf.
pub fn psnr(a: &Image, b: &Image) -> f64 {
    let m = mse(a, b);
    if m <= 0.0 {
        f64::INFINITY
    } else {
        10.0 * (1.0 / m).log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_images_infinite_psnr() {
        let img = Image::new(8, 8);
        assert_eq!(psnr(&img, &img), f64::INFINITY);
        assert_eq!(mse(&img, &img), 0.0);
    }

    #[test]
    fn known_mse() {
        let a = Image::new(2, 1);
        let mut b = Image::new(2, 1);
        // One channel off by 0.5 across 6 values → MSE = 0.25/6.
        b.data[0] = 0.5;
        assert!((mse(&a, &b) - 0.25 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn psnr_decreases_with_noise() {
        let a = Image::new(4, 4);
        let mut slight = a.clone();
        let mut heavy = a.clone();
        for (i, v) in slight.data.iter_mut().enumerate() {
            *v += if i % 2 == 0 { 0.01 } else { -0.01 };
        }
        for (i, v) in heavy.data.iter_mut().enumerate() {
            *v += if i % 2 == 0 { 0.2 } else { -0.2 };
        }
        assert!(psnr(&a, &slight) > psnr(&a, &heavy));
        assert!((psnr(&a, &slight) - 40.0).abs() < 1e-6); // 20·log10(1/0.01)
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn size_mismatch_panics() {
        mse(&Image::new(2, 2), &Image::new(3, 2));
    }
}

/// Mean SSIM (structural similarity) over 8×8 windows on luma — the
/// second quality metric common in the 3DGS literature. Returns 1.0 for
/// identical images.
pub fn ssim(a: &Image, b: &Image) -> f64 {
    assert_eq!(a.width, b.width, "image width mismatch");
    assert_eq!(a.height, b.height, "image height mismatch");
    const C1: f64 = 0.01 * 0.01;
    const C2: f64 = 0.03 * 0.03;
    const W: usize = 8;

    let luma = |img: &Image, x: usize, y: usize| -> f64 {
        let p = img.pixel(x, y);
        (0.2126 * p[0] + 0.7152 * p[1] + 0.0722 * p[2]) as f64
    };

    let mut sum = 0.0;
    let mut windows = 0usize;
    let mut wy = 0;
    while wy + W <= a.height.max(W).min(a.height + W) && wy < a.height {
        let mut wx = 0;
        while wx < a.width {
            let x1 = (wx + W).min(a.width);
            let y1 = (wy + W).min(a.height);
            let n = ((x1 - wx) * (y1 - wy)) as f64;
            let (mut ma, mut mb) = (0.0, 0.0);
            for y in wy..y1 {
                for x in wx..x1 {
                    ma += luma(a, x, y);
                    mb += luma(b, x, y);
                }
            }
            ma /= n;
            mb /= n;
            let (mut va, mut vb, mut cov) = (0.0, 0.0, 0.0);
            for y in wy..y1 {
                for x in wx..x1 {
                    let da = luma(a, x, y) - ma;
                    let db = luma(b, x, y) - mb;
                    va += da * da;
                    vb += db * db;
                    cov += da * db;
                }
            }
            va /= n;
            vb /= n;
            cov /= n;
            sum += ((2.0 * ma * mb + C1) * (2.0 * cov + C2))
                / ((ma * ma + mb * mb + C1) * (va + vb + C2));
            windows += 1;
            wx += W;
        }
        wy += W;
    }
    if windows == 0 {
        1.0
    } else {
        sum / windows as f64
    }
}

#[cfg(test)]
mod ssim_tests {
    use super::*;

    #[test]
    fn identical_images_ssim_one() {
        let mut img = Image::new(32, 24);
        for y in 0..24 {
            for x in 0..32 {
                img.set_pixel(x, y, [(x as f32) / 32.0, 0.5, (y as f32) / 24.0]);
            }
        }
        assert!((ssim(&img, &img) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn noise_reduces_ssim_monotonically() {
        let mut base = Image::new(32, 32);
        for y in 0..32 {
            for x in 0..32 {
                base.set_pixel(x, y, [((x + y) % 7) as f32 / 7.0; 3]);
            }
        }
        let noisy = |amp: f32| {
            let mut img = base.clone();
            // Per-pixel alternating sign so the luma perturbation does not
            // collapse into a uniform shift.
            for (i, px) in img.data.chunks_exact_mut(3).enumerate() {
                let s = if i % 2 == 0 { amp } else { -amp };
                for v in px {
                    *v += s;
                }
            }
            img
        };
        let s_small = ssim(&base, &noisy(0.02));
        let s_big = ssim(&base, &noisy(0.3));
        assert!(s_small > s_big, "{s_small} vs {s_big}");
        assert!(s_small > 0.9, "small noise keeps structure: {s_small}");
        assert!(s_big < 0.7, "large noise destroys structure: {s_big}");
    }
}
