//! Hardware-faithful rasterizer: what the 3DGauCIM datapath actually
//! computes. Differences from the reference:
//!
//! * Gaussian parameters are **FP16-quantized** (DRAM storage precision, §4);
//! * the exponential is the **DD3D-Flow LUT path** ([`crate::dcim::ExpLut`]):
//!   base conversion with ln2 fused offline + SIF decouple + 4-stage cascade;
//! * blending runs through the **NMC accumulator** arithmetic;
//! * tiles are visited in a caller-supplied order (ATG groups or raster).
//!
//! PSNR(reference, hw) is the paper's §3.4 fidelity claim: 12-bit fractions
//! keep PSNR undegraded.

use super::Image;
use crate::camera::Camera;
use crate::dcim::nmc::{NmcAccumulator, NmcStats, PixelState};
use crate::dcim::ExpLut;
use crate::math::f16;
use crate::pipeline::par::{SharedSlice, WorkerPool};
use crate::scene::Scene;
use crate::tiles::intersect::{bin_splats, project_gaussian, splat_exponent, Splat2D, TileGrid};

/// Exponent cutoff shared with the reference renderer.
use super::reference::EXP_CUTOFF;

/// The hardware-model renderer.
#[derive(Debug)]
pub struct HwRenderer {
    pub grid: TileGrid,
    pub exp: ExpLut,
    /// Quantize parameters through FP16 storage (paper's precision).
    pub fp16_params: bool,
}

impl HwRenderer {
    pub fn new(width: usize, height: usize) -> HwRenderer {
        HwRenderer {
            grid: TileGrid::new(width, height),
            exp: ExpLut::paper(),
            fp16_params: true,
        }
    }

    /// Ablation constructor with a custom-precision LUT.
    pub fn with_exp(width: usize, height: usize, exp: ExpLut) -> HwRenderer {
        HwRenderer { grid: TileGrid::new(width, height), exp, fp16_params: true }
    }

    /// Projection with FP16 parameter quantization (same frustum cull as
    /// the reference so both paths draw the identical primitive set).
    pub fn project_all(&self, scene: &Scene, cam: &Camera, t: f32) -> Vec<Splat2D> {
        let frustum = cam.frustum();
        scene
            .gaussians
            .iter()
            .enumerate()
            .filter(|(_, g)| crate::culling::gaussian_visible_in(g, &frustum, t))
            .filter_map(|(i, g)| {
                if self.fp16_params {
                    let q = g.quantized_fp16();
                    project_gaussian(&q, i as u32, cam, t)
                } else {
                    project_gaussian(g, i as u32, cam, t)
                }
            })
            .collect()
    }

    /// Render with the default raster tile order.
    pub fn render(&self, scene: &Scene, cam: &Camera, t: f32) -> Image {
        let splats = self.project_all(scene, cam, t);
        let order: Vec<usize> = (0..self.grid.n_tiles()).collect();
        self.render_splats_ordered(&splats, &order, &mut NmcAccumulator::new())
    }

    /// Front-to-back depth order of one tile's bin (stable by splat index
    /// on ties — the exact order the serial rasterizer always used).
    fn tile_depth_order(&self, splats: &[Splat2D], bin: &[u32]) -> Vec<u32> {
        let mut order: Vec<u32> = bin.to_vec();
        order.sort_by(|&a, &b| {
            splats[a as usize]
                .depth
                .partial_cmp(&splats[b as usize].depth)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        order
    }

    /// Blend one pixel through the depth-ordered splat list (merged
    /// exponent, FP16 operands, DD3D-Flow LUT exponential, NMC
    /// accumulation) — the shared inner loop of the serial and
    /// tile-parallel rasterizers.
    fn shade_pixel(
        &self,
        splats: &[Splat2D],
        order: &[u32],
        px: usize,
        py: usize,
        nmc: &mut NmcAccumulator,
    ) -> [f32; 3] {
        let mut state = PixelState::default();
        for &si in order {
            let s = &splats[si as usize];
            // Merged exponent, FP16 like the datapath operands.
            let e = splat_exponent(s, px as f32 + 0.5, py as f32 + 0.5);
            if e < EXP_CUTOFF {
                continue;
            }
            let e_hw = f16::quantize(e);
            // DD3D-Flow: exponent pre-scaled by 1/ln2 offline.
            let alpha = s.alpha_base * self.exp.exp2(e_hw * std::f32::consts::LOG2_E);
            if alpha < 1.0 / 255.0 {
                continue;
            }
            if !nmc.blend(&mut state, alpha, [s.color.x, s.color.y, s.color.z]) {
                break;
            }
        }
        state.rgb
    }

    /// Rasterize pre-projected splats visiting tiles in `tile_order`,
    /// charging blend arithmetic to `nmc`.
    pub fn render_splats_ordered(
        &self,
        splats: &[Splat2D],
        tile_order: &[usize],
        nmc: &mut NmcAccumulator,
    ) -> Image {
        let mut img = Image::new(self.grid.width, self.grid.height);
        let bins = bin_splats(&self.grid, splats);

        for &tile in tile_order {
            if bins[tile].is_empty() {
                continue;
            }
            let order = self.tile_depth_order(splats, &bins[tile]);
            let (x0, y0, x1, y1) = self.grid.tile_pixels(tile);
            for py in y0..y1 {
                for px in x0..x1 {
                    let rgb = self.shade_pixel(splats, &order, px, py, nmc);
                    img.set_pixel(px, py, rgb);
                }
            }
        }
        img
    }

    /// Tile-parallel rasterization on a [`WorkerPool`]. Tiles own disjoint
    /// pixel rectangles, so workers write the image without coordination
    /// (`tile_order` must be a permutation of the tile indices, which every
    /// ATG/raster order is); per-tile NMC counters reduce in tile order and
    /// energy derives from op counts, so pixels *and* statistics are
    /// bit-identical to [`HwRenderer::render_splats_ordered`] at any worker
    /// count.
    pub fn render_splats_ordered_par(
        &self,
        splats: &[Splat2D],
        tile_order: &[usize],
        nmc: &mut NmcAccumulator,
        pool: &WorkerPool,
    ) -> Image {
        let mut img = Image::new(self.grid.width, self.grid.height);
        let bins = bin_splats(&self.grid, splats);
        let n_pos = tile_order.len();
        let width = self.grid.width;
        // The disjoint-pixel contract requires each tile at most once —
        // a repeated tile would hand the same pixels to two workers.
        debug_assert!(
            {
                let mut seen = vec![false; self.grid.n_tiles()];
                tile_order.iter().all(|&tile| !std::mem::replace(&mut seen[tile], true))
            },
            "tile_order must not repeat tiles (disjoint-pixel fan-out contract)"
        );
        let mut tile_stats: Vec<NmcStats> = vec![NmcStats::default(); n_pos];
        let t = pool.threads().max(1);
        {
            let data_sl = SharedSlice::new(img.data.as_mut_slice());
            let stats_sl = SharedSlice::new(tile_stats.as_mut_slice());
            let bins = &bins;
            pool.scope(|scope| {
                for w in 0..t {
                    scope.spawn(move || {
                        let mut pos = w;
                        while pos < n_pos {
                            let tile = tile_order[pos];
                            if !bins[tile].is_empty() {
                                let order = self.tile_depth_order(splats, &bins[tile]);
                                let mut local = NmcAccumulator::new();
                                let (x0, y0, x1, y1) = self.grid.tile_pixels(tile);
                                for py in y0..y1 {
                                    for px in x0..x1 {
                                        let rgb =
                                            self.shade_pixel(splats, &order, px, py, &mut local);
                                        let i = (py * width + px) * 3;
                                        // SAFETY: tiles cover disjoint pixel
                                        // rectangles and order positions are
                                        // strided by worker — no index is
                                        // written twice.
                                        unsafe {
                                            *data_sl.get_mut(i) = rgb[0];
                                            *data_sl.get_mut(i + 1) = rgb[1];
                                            *data_sl.get_mut(i + 2) = rgb[2];
                                        }
                                    }
                                }
                                // SAFETY: one stats cell per order position.
                                unsafe { *stats_sl.get_mut(pos) = local.stats() };
                            }
                            pos += t;
                        }
                    });
                }
            });
        }
        // Reduce the per-tile counters in fixed tile order.
        for s in &tile_stats {
            nmc.absorb(s);
        }
        img
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec3;
    use crate::render::psnr::psnr;
    use crate::render::ReferenceRenderer;
    use crate::scene::synth::{SceneKind, SynthParams};

    fn cam(w: usize, h: usize, dist: f32) -> Camera {
        let mut c = Camera::look_at(
            Vec3::new(0.0, 3.0, dist),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            w as f32 / h as f32,
            0.1,
            200.0,
        );
        c.set_resolution(w, h);
        c
    }

    #[test]
    fn lut_exponential_alone_preserves_psnr() {
        // The §3.4 claim isolated: 12-bit LUT exp (exact f32 parameters)
        // must be visually indistinguishable from the exact exponential.
        let scene = SynthParams::new(SceneKind::StaticLarge, 3000).generate();
        let c = cam(160, 96, 25.0);
        let reference = ReferenceRenderer::new(160, 96).render(&scene, &c, 0.0);
        let mut hw = HwRenderer::new(160, 96);
        hw.fp16_params = false;
        let img = hw.render(&scene, &c, 0.0);
        let p = psnr(&reference, &img);
        assert!(p > 45.0, "LUT-only PSNR {p} dB");
    }

    #[test]
    fn full_hw_path_matches_reference_within_fp16_noise() {
        // With FP16 parameter storage on top (the paper's precision), small
        // sub-pixel mean shifts bound PSNR lower but it stays high.
        let scene = SynthParams::new(SceneKind::StaticLarge, 3000).generate();
        let c = cam(160, 96, 25.0);
        let reference = ReferenceRenderer::new(160, 96).render(&scene, &c, 0.0);
        let hw = HwRenderer::new(160, 96).render(&scene, &c, 0.0);
        let p = psnr(&reference, &hw);
        assert!(p > 24.0, "hw-vs-reference PSNR {p} dB");
    }

    #[test]
    fn coarse_lut_degrades_alpha_accuracy() {
        // At scene level FP16 noise can mask the LUT precision, so the
        // ablation asserts on the alpha path itself: per-splat alpha error.
        let e12 = crate::dcim::ExpLut::with_frac_bits(12);
        let e4 = crate::dcim::ExpLut::with_frac_bits(4);
        let mut worst12 = 0.0f32;
        let mut worst4 = 0.0f32;
        for i in 0..2000 {
            let x = -10.0 * (i as f32 / 2000.0);
            let exact = x.exp();
            worst12 = worst12.max((e12.exp(x) - exact).abs() / exact.max(1e-9));
            worst4 = worst4.max((e4.exp(x) - exact).abs() / exact.max(1e-9));
        }
        assert!(worst4 > 4.0 * worst12, "4-bit {worst4} vs 12-bit {worst12}");
        assert!(worst12 < 4e-3);
    }

    #[test]
    fn tile_order_does_not_change_pixels() {
        // ATG reorders *tiles*; pixels blend identically regardless.
        let scene = SynthParams::new(SceneKind::StaticLarge, 1500).generate();
        let c = cam(96, 96, 25.0);
        let r = HwRenderer::new(96, 96);
        let splats = r.project_all(&scene, &c, 0.0);
        let fwd: Vec<usize> = (0..r.grid.n_tiles()).collect();
        let mut rev = fwd.clone();
        rev.reverse();
        let img_f = r.render_splats_ordered(&splats, &fwd, &mut NmcAccumulator::new());
        let img_r = r.render_splats_ordered(&splats, &rev, &mut NmcAccumulator::new());
        assert_eq!(img_f, img_r);
    }

    #[test]
    fn parallel_render_is_bit_identical_to_serial() {
        let scene = SynthParams::new(SceneKind::StaticLarge, 1500).generate();
        let c = cam(96, 96, 25.0);
        let r = HwRenderer::new(96, 96);
        let splats = r.project_all(&scene, &c, 0.0);
        let order: Vec<usize> = (0..r.grid.n_tiles()).collect();
        let mut serial_nmc = NmcAccumulator::new();
        let serial = r.render_splats_ordered(&splats, &order, &mut serial_nmc);
        for threads in [1, 3, 8] {
            let pool = crate::pipeline::par::WorkerPool::new(threads);
            let mut par_nmc = NmcAccumulator::new();
            let par = r.render_splats_ordered_par(&splats, &order, &mut par_nmc, &pool);
            assert_eq!(serial, par, "pixels diverged at {threads} workers");
            assert_eq!(serial_nmc.stats(), par_nmc.stats(), "NMC stats at {threads} workers");
        }
    }

    #[test]
    fn nmc_records_blend_work() {
        let scene = SynthParams::new(SceneKind::StaticLarge, 800).generate();
        let c = cam(64, 64, 25.0);
        let r = HwRenderer::new(64, 64);
        let splats = r.project_all(&scene, &c, 0.0);
        let order: Vec<usize> = (0..r.grid.n_tiles()).collect();
        let mut nmc = NmcAccumulator::new();
        r.render_splats_ordered(&splats, &order, &mut nmc);
        assert!(nmc.stats().blend_ops > 0);
        assert!(nmc.stats().energy_pj > 0.0);
    }
}
