//! Hardware-faithful rasterizer: what the 3DGauCIM datapath actually
//! computes. Differences from the reference:
//!
//! * Gaussian parameters are **FP16-quantized** (DRAM storage precision, §4);
//! * the exponential is the **DD3D-Flow LUT path** ([`crate::dcim::ExpLut`]):
//!   base conversion with ln2 fused offline + SIF decouple + 4-stage cascade;
//! * blending runs through the **NMC accumulator** arithmetic;
//! * tiles are visited in a caller-supplied order (ATG groups or raster).
//!
//! PSNR(reference, hw) is the paper's §3.4 fidelity claim: 12-bit fractions
//! keep PSNR undegraded.

use super::lanes::{self, RenderBackend, LANES};
use super::Image;
use crate::camera::Camera;
use crate::dcim::nmc::{NmcAccumulator, NmcStats, PixelState};
use crate::dcim::ExpLut;
use crate::math::f16;
use crate::pipeline::par::{SharedSlice, WorkerPool};
use crate::scene::Scene;
use crate::tiles::intersect::{bin_splats, project_gaussian, splat_exponent, Splat2D, TileGrid};
use crate::tiles::TILE_PX;

/// Exponent cutoff shared with the reference renderer.
use super::reference::EXP_CUTOFF;

/// Pooled rasterizer scratch: per-worker depth-order buffers, the
/// per-tile NMC partials of the parallel reduction, and the debug-only
/// duplicate-tile bitmap. Hold one per long-lived renderer call site
/// (`BlendStage` owns one) so steady-state rendering allocates nothing —
/// the `FrameCtx` zero-allocation contract extended to the rasterizer.
#[derive(Debug, Default)]
pub struct RenderScratch {
    /// Per-worker front-to-back depth order (index 0 serves the serial path).
    order: Vec<Vec<u32>>,
    /// Per-tile-position NMC partials (reduced in tile order).
    tile_stats: Vec<NmcStats>,
    /// Pooled seen-bitmap for the debug-only disjoint-tile check.
    seen: Vec<bool>,
}

impl RenderScratch {
    fn ensure_workers(&mut self, n: usize) {
        if self.order.len() < n {
            self.order.resize_with(n, Vec::new);
        }
    }

    /// Release the pooled capacity (parked-session trimming — see
    /// `FrameCtx::trim_scratch`). Everything here is refilled per frame,
    /// so a later frame just re-grows the pools.
    pub fn trim(&mut self) {
        *self = RenderScratch::default();
    }

    /// Capacities of the pooled buffers (zero-allocation contract probes).
    pub fn capacities(&self) -> Vec<usize> {
        vec![
            self.order.capacity(),
            self.order.iter().map(Vec::capacity).sum(),
            self.tile_stats.capacity(),
            self.seen.capacity(),
        ]
    }
}

/// The hardware-model renderer.
#[derive(Debug)]
pub struct HwRenderer {
    pub grid: TileGrid,
    pub exp: ExpLut,
    /// Quantize parameters through FP16 storage (paper's precision).
    pub fp16_params: bool,
    /// Blend datapath: the scalar per-pixel loop or the 8-wide lane
    /// kernel ([`crate::render::lanes`]) — bit-identical outputs, only
    /// host wall-clock differs.
    pub backend: RenderBackend,
}

impl HwRenderer {
    pub fn new(width: usize, height: usize) -> HwRenderer {
        HwRenderer {
            grid: TileGrid::new(width, height),
            exp: ExpLut::paper(),
            fp16_params: true,
            backend: RenderBackend::from_env(),
        }
    }

    /// Ablation constructor with a custom-precision LUT.
    pub fn with_exp(width: usize, height: usize, exp: ExpLut) -> HwRenderer {
        HwRenderer {
            grid: TileGrid::new(width, height),
            exp,
            fp16_params: true,
            backend: RenderBackend::from_env(),
        }
    }

    /// Pin the blend datapath (builder form — `new` reads the
    /// `PALLAS_RENDER_BACKEND` environment default).
    pub fn with_backend(mut self, backend: RenderBackend) -> HwRenderer {
        self.backend = backend;
        self
    }

    /// Projection with FP16 parameter quantization (same frustum cull as
    /// the reference so both paths draw the identical primitive set).
    pub fn project_all(&self, scene: &Scene, cam: &Camera, t: f32) -> Vec<Splat2D> {
        let frustum = cam.frustum();
        scene
            .gaussians
            .iter()
            .enumerate()
            .filter(|(_, g)| crate::culling::gaussian_visible_in(g, &frustum, t))
            .filter_map(|(i, g)| {
                if self.fp16_params {
                    let q = g.quantized_fp16();
                    project_gaussian(&q, i as u32, cam, t)
                } else {
                    project_gaussian(g, i as u32, cam, t)
                }
            })
            .collect()
    }

    /// Render with the default raster tile order.
    pub fn render(&self, scene: &Scene, cam: &Camera, t: f32) -> Image {
        let splats = self.project_all(scene, cam, t);
        let order: Vec<usize> = (0..self.grid.n_tiles()).collect();
        self.render_splats_ordered(&splats, &order, &mut NmcAccumulator::new())
    }

    /// Front-to-back depth order of one tile's bin (stable by splat index
    /// on ties — the exact order the serial rasterizer always used),
    /// written into a pooled buffer.
    fn tile_depth_order_into(&self, splats: &[Splat2D], bin: &[u32], order: &mut Vec<u32>) {
        order.clear();
        order.extend_from_slice(bin);
        order.sort_by(|&a, &b| {
            splats[a as usize]
                .depth
                .partial_cmp(&splats[b as usize].depth)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
    }

    /// Blend one pixel through the depth-ordered splat list (merged
    /// exponent, FP16 operands, DD3D-Flow LUT exponential, NMC
    /// accumulation) — the shared inner loop of the serial and
    /// tile-parallel rasterizers.
    fn shade_pixel(
        &self,
        splats: &[Splat2D],
        order: &[u32],
        px: usize,
        py: usize,
        nmc: &mut NmcAccumulator,
    ) -> [f32; 3] {
        let mut state = PixelState::default();
        for &si in order {
            let s = &splats[si as usize];
            // Merged exponent, FP16 like the datapath operands.
            let e = splat_exponent(s, px as f32 + 0.5, py as f32 + 0.5);
            if e < EXP_CUTOFF {
                continue;
            }
            let e_hw = f16::quantize(e);
            // DD3D-Flow: exponent pre-scaled by 1/ln2 offline.
            let alpha = s.alpha_base * self.exp.exp2(e_hw * std::f32::consts::LOG2_E);
            if alpha < 1.0 / 255.0 {
                continue;
            }
            if !nmc.blend(&mut state, alpha, [s.color.x, s.color.y, s.color.z]) {
                break;
            }
        }
        state.rgb
    }

    /// Shade one tile row `[x0, x0 + row.len()) × {py}` into `row`.
    /// The lanes backend batches 8-pixel spans through
    /// [`lanes::shade_span_hw`] and falls back to the scalar
    /// [`HwRenderer::shade_pixel`] for the ragged tail (tile widths not
    /// divisible by [`LANES`]) — which is also the whole row on the scalar
    /// backend, so both paths are literally the same code for the tail.
    #[inline]
    fn shade_row(
        &self,
        splats: &[Splat2D],
        order: &[u32],
        x0: usize,
        py: usize,
        nmc: &mut NmcAccumulator,
        row: &mut [[f32; 3]],
    ) {
        let x1 = x0 + row.len();
        let mut px = x0;
        if self.backend == RenderBackend::Lanes {
            while px + LANES <= x1 {
                let span = lanes::shade_span_hw(&self.exp, splats, order, px, py, nmc);
                row[px - x0..px - x0 + LANES].copy_from_slice(&span);
                px += LANES;
            }
        }
        while px < x1 {
            row[px - x0] = self.shade_pixel(splats, order, px, py, nmc);
            px += 1;
        }
    }

    /// Rasterize pre-projected splats visiting tiles in `tile_order`,
    /// charging blend arithmetic to `nmc` — convenience wrapper that bins
    /// the splats itself (standalone / oracle use). The stage graph calls
    /// [`HwRenderer::render_splats_binned`] with the bins `IntersectStage`
    /// already produced.
    pub fn render_splats_ordered(
        &self,
        splats: &[Splat2D],
        tile_order: &[usize],
        nmc: &mut NmcAccumulator,
    ) -> Image {
        let bins = bin_splats(&self.grid, splats);
        self.render_splats_binned(splats, &bins, tile_order, nmc, &mut RenderScratch::default())
    }

    /// Rasterize with caller-provided per-tile bins (must be the
    /// ascending-splat-index bins `bin_splats` produces for this grid —
    /// exactly what `IntersectStage` leaves in `FrameCtx::bins`, so the
    /// hot path never re-bins) and pooled scratch.
    pub fn render_splats_binned(
        &self,
        splats: &[Splat2D],
        bins: &[Vec<u32>],
        tile_order: &[usize],
        nmc: &mut NmcAccumulator,
        scratch: &mut RenderScratch,
    ) -> Image {
        let mut img = Image::new(self.grid.width, self.grid.height);
        scratch.ensure_workers(1);
        let order = &mut scratch.order[0];
        let mut row = [[0.0f32; 3]; TILE_PX];

        for &tile in tile_order {
            if bins[tile].is_empty() {
                continue;
            }
            self.tile_depth_order_into(splats, &bins[tile], order);
            let (x0, y0, x1, y1) = self.grid.tile_pixels(tile);
            let w = x1 - x0;
            for py in y0..y1 {
                self.shade_row(splats, order, x0, py, nmc, &mut row[..w]);
                for (i, rgb) in row[..w].iter().enumerate() {
                    img.set_pixel(x0 + i, py, *rgb);
                }
            }
        }
        img
    }

    /// Tile-parallel wrapper that bins the splats itself — see
    /// [`HwRenderer::render_splats_binned_par`].
    pub fn render_splats_ordered_par(
        &self,
        splats: &[Splat2D],
        tile_order: &[usize],
        nmc: &mut NmcAccumulator,
        pool: &WorkerPool,
    ) -> Image {
        let bins = bin_splats(&self.grid, splats);
        self.render_splats_binned_par(
            splats,
            &bins,
            tile_order,
            nmc,
            pool,
            &mut RenderScratch::default(),
        )
    }

    /// Tile-parallel rasterization on a [`WorkerPool`] with caller-provided
    /// bins and pooled scratch. Tiles own disjoint pixel rectangles, so
    /// workers write the image without coordination (`tile_order` must be a
    /// permutation of the tile indices, which every ATG/raster order is);
    /// per-tile NMC counters reduce in tile order and energy derives from
    /// op counts, so pixels *and* statistics are bit-identical to
    /// [`HwRenderer::render_splats_binned`] at any worker count — and, via
    /// the lane kernel's masked-select construction, at either backend.
    pub fn render_splats_binned_par(
        &self,
        splats: &[Splat2D],
        bins: &[Vec<u32>],
        tile_order: &[usize],
        nmc: &mut NmcAccumulator,
        pool: &WorkerPool,
        scratch: &mut RenderScratch,
    ) -> Image {
        let mut img = Image::new(self.grid.width, self.grid.height);
        let n_pos = tile_order.len();
        let width = self.grid.width;
        let t = pool.threads().max(1);
        scratch.ensure_workers(t);
        // The disjoint-pixel contract requires each tile at most once —
        // a repeated tile would hand the same pixels to two workers. The
        // seen-bitmap is pooled (set bits are cleared again afterwards).
        if cfg!(debug_assertions) {
            scratch.seen.resize(self.grid.n_tiles(), false);
            for &tile in tile_order {
                assert!(
                    !std::mem::replace(&mut scratch.seen[tile], true),
                    "tile_order must not repeat tiles (disjoint-pixel fan-out contract)"
                );
            }
            for &tile in tile_order {
                scratch.seen[tile] = false;
            }
        }
        let RenderScratch { order, tile_stats, .. } = scratch;
        tile_stats.clear();
        tile_stats.resize(n_pos, NmcStats::default());
        {
            let data_sl = SharedSlice::new(img.data.as_mut_slice());
            let stats_sl = SharedSlice::new(tile_stats.as_mut_slice());
            let order_sl = SharedSlice::new(order.as_mut_slice());
            pool.scope(|scope| {
                for w in 0..t {
                    scope.spawn(move || {
                        // SAFETY: one depth-order buffer per worker.
                        let order = unsafe { order_sl.get_mut(w) };
                        let mut row = [[0.0f32; 3]; TILE_PX];
                        let mut pos = w;
                        while pos < n_pos {
                            let tile = tile_order[pos];
                            if bins[tile].is_empty() {
                                // Every order position writes its stats
                                // cell, so the reduction is total by
                                // construction.
                                // SAFETY: one stats cell per position.
                                unsafe { *stats_sl.get_mut(pos) = NmcStats::default() };
                            } else {
                                self.tile_depth_order_into(splats, &bins[tile], order);
                                let mut local = NmcAccumulator::new();
                                let (x0, y0, x1, y1) = self.grid.tile_pixels(tile);
                                let tw = x1 - x0;
                                for py in y0..y1 {
                                    self.shade_row(
                                        splats,
                                        order,
                                        x0,
                                        py,
                                        &mut local,
                                        &mut row[..tw],
                                    );
                                    for (i, rgb) in row[..tw].iter().enumerate() {
                                        let j = (py * width + x0 + i) * 3;
                                        // SAFETY: tiles cover disjoint pixel
                                        // rectangles and order positions are
                                        // strided by worker — no index is
                                        // written twice.
                                        unsafe {
                                            *data_sl.get_mut(j) = rgb[0];
                                            *data_sl.get_mut(j + 1) = rgb[1];
                                            *data_sl.get_mut(j + 2) = rgb[2];
                                        }
                                    }
                                }
                                // SAFETY: one stats cell per order position.
                                unsafe { *stats_sl.get_mut(pos) = local.stats() };
                            }
                            pos += t;
                        }
                    });
                }
            });
        }
        // Reduce the per-tile counters in fixed tile order.
        for s in tile_stats.iter() {
            nmc.absorb(s);
        }
        img
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Vec3;
    use crate::render::psnr::psnr;
    use crate::render::ReferenceRenderer;
    use crate::scene::synth::{SceneKind, SynthParams};

    fn cam(w: usize, h: usize, dist: f32) -> Camera {
        let mut c = Camera::look_at(
            Vec3::new(0.0, 3.0, dist),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            w as f32 / h as f32,
            0.1,
            200.0,
        );
        c.set_resolution(w, h);
        c
    }

    #[test]
    fn lut_exponential_alone_preserves_psnr() {
        // The §3.4 claim isolated: 12-bit LUT exp (exact f32 parameters)
        // must be visually indistinguishable from the exact exponential.
        let scene = SynthParams::new(SceneKind::StaticLarge, 3000).generate();
        let c = cam(160, 96, 25.0);
        let reference = ReferenceRenderer::new(160, 96).render(&scene, &c, 0.0);
        let mut hw = HwRenderer::new(160, 96);
        hw.fp16_params = false;
        let img = hw.render(&scene, &c, 0.0);
        let p = psnr(&reference, &img);
        assert!(p > 45.0, "LUT-only PSNR {p} dB");
    }

    #[test]
    fn full_hw_path_matches_reference_within_fp16_noise() {
        // With FP16 parameter storage on top (the paper's precision), small
        // sub-pixel mean shifts bound PSNR lower but it stays high.
        let scene = SynthParams::new(SceneKind::StaticLarge, 3000).generate();
        let c = cam(160, 96, 25.0);
        let reference = ReferenceRenderer::new(160, 96).render(&scene, &c, 0.0);
        let hw = HwRenderer::new(160, 96).render(&scene, &c, 0.0);
        let p = psnr(&reference, &hw);
        assert!(p > 24.0, "hw-vs-reference PSNR {p} dB");
    }

    #[test]
    fn coarse_lut_degrades_alpha_accuracy() {
        // At scene level FP16 noise can mask the LUT precision, so the
        // ablation asserts on the alpha path itself: per-splat alpha error.
        let e12 = crate::dcim::ExpLut::with_frac_bits(12);
        let e4 = crate::dcim::ExpLut::with_frac_bits(4);
        let mut worst12 = 0.0f32;
        let mut worst4 = 0.0f32;
        for i in 0..2000 {
            let x = -10.0 * (i as f32 / 2000.0);
            let exact = x.exp();
            worst12 = worst12.max((e12.exp(x) - exact).abs() / exact.max(1e-9));
            worst4 = worst4.max((e4.exp(x) - exact).abs() / exact.max(1e-9));
        }
        assert!(worst4 > 4.0 * worst12, "4-bit {worst4} vs 12-bit {worst12}");
        assert!(worst12 < 4e-3);
    }

    #[test]
    fn tile_order_does_not_change_pixels() {
        // ATG reorders *tiles*; pixels blend identically regardless.
        let scene = SynthParams::new(SceneKind::StaticLarge, 1500).generate();
        let c = cam(96, 96, 25.0);
        let r = HwRenderer::new(96, 96);
        let splats = r.project_all(&scene, &c, 0.0);
        let fwd: Vec<usize> = (0..r.grid.n_tiles()).collect();
        let mut rev = fwd.clone();
        rev.reverse();
        let img_f = r.render_splats_ordered(&splats, &fwd, &mut NmcAccumulator::new());
        let img_r = r.render_splats_ordered(&splats, &rev, &mut NmcAccumulator::new());
        assert_eq!(img_f, img_r);
    }

    #[test]
    fn parallel_render_is_bit_identical_to_serial() {
        let scene = SynthParams::new(SceneKind::StaticLarge, 1500).generate();
        let c = cam(96, 96, 25.0);
        let r = HwRenderer::new(96, 96);
        let splats = r.project_all(&scene, &c, 0.0);
        let order: Vec<usize> = (0..r.grid.n_tiles()).collect();
        let mut serial_nmc = NmcAccumulator::new();
        let serial = r.render_splats_ordered(&splats, &order, &mut serial_nmc);
        for threads in [1, 3, 8] {
            let pool = crate::pipeline::par::WorkerPool::new(threads);
            let mut par_nmc = NmcAccumulator::new();
            let par = r.render_splats_ordered_par(&splats, &order, &mut par_nmc, &pool);
            assert_eq!(serial, par, "pixels diverged at {threads} workers");
            assert_eq!(serial_nmc.stats(), par_nmc.stats(), "NMC stats at {threads} workers");
        }
    }

    #[test]
    fn nmc_records_blend_work() {
        let scene = SynthParams::new(SceneKind::StaticLarge, 800).generate();
        let c = cam(64, 64, 25.0);
        let r = HwRenderer::new(64, 64);
        let splats = r.project_all(&scene, &c, 0.0);
        let order: Vec<usize> = (0..r.grid.n_tiles()).collect();
        let mut nmc = NmcAccumulator::new();
        r.render_splats_ordered(&splats, &order, &mut nmc);
        assert!(nmc.stats().blend_ops > 0);
        assert!(nmc.stats().energy_pj > 0.0);
    }
}
