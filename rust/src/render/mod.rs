//! Rendering: the exact f32 CPU reference rasterizer (the PSNR oracle), the
//! hardware-faithful rasterizer (FP16 parameters + DD3D-Flow LUT
//! exponential), PSNR computation, and PPM image output.

pub mod hw;
pub mod lanes;
pub mod ppm;
pub mod psnr;
pub mod reference;

pub use hw::{HwRenderer, RenderScratch};
pub use lanes::RenderBackend;
pub use psnr::{mse, psnr, ssim};
pub use reference::ReferenceRenderer;

/// A linear-RGB f32 image.
#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    /// Row-major RGB triples.
    pub data: Vec<f32>,
}

impl Image {
    pub fn new(width: usize, height: usize) -> Image {
        Image { width, height, data: vec![0.0; width * height * 3] }
    }

    #[inline]
    pub fn pixel(&self, x: usize, y: usize) -> [f32; 3] {
        let i = (y * self.width + x) * 3;
        [self.data[i], self.data[i + 1], self.data[i + 2]]
    }

    #[inline]
    pub fn set_pixel(&mut self, x: usize, y: usize, rgb: [f32; 3]) {
        let i = (y * self.width + x) * 3;
        self.data[i] = rgb[0];
        self.data[i + 1] = rgb[1];
        self.data[i + 2] = rgb[2];
    }

    /// Mean luminance (diagnostics).
    pub fn mean_luma(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        let mut sum = 0.0f64;
        for px in self.data.chunks_exact(3) {
            sum += (0.2126 * px[0] + 0.7152 * px[1] + 0.0722 * px[2]) as f64;
        }
        (sum / (self.width * self.height) as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixel_roundtrip() {
        let mut img = Image::new(4, 3);
        img.set_pixel(2, 1, [0.1, 0.2, 0.3]);
        assert_eq!(img.pixel(2, 1), [0.1, 0.2, 0.3]);
        assert_eq!(img.pixel(0, 0), [0.0, 0.0, 0.0]);
    }

    #[test]
    fn mean_luma_of_white() {
        let mut img = Image::new(2, 2);
        for y in 0..2 {
            for x in 0..2 {
                img.set_pixel(x, y, [1.0, 1.0, 1.0]);
            }
        }
        assert!((img.mean_luma() - 1.0).abs() < 1e-5);
    }
}
