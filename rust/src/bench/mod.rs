//! Minimal criterion-style benchmark harness (the `criterion` crate is
//! unavailable offline — DESIGN.md §3). Provides warmup, repeated sampling,
//! robust statistics, and a uniform report format for the `cargo bench`
//! targets that regenerate the paper's tables and figures.

use crate::math::stats::Running;
use crate::util::json::Json;
use std::time::Instant;

/// One benchmark's timing summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} {:>10} ±{:>9}  ({} samples)",
            self.name,
            human_time(self.median_s),
            human_time(self.mean_s),
            human_time(self.std_s),
            self.samples
        )
    }
}

fn human_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, samples: 10 }
    }
}

impl Bench {
    pub fn quick() -> Bench {
        Bench { warmup: 1, samples: 5 }
    }

    /// Time `f` and return stats. The closure's return value is consumed
    /// through `std::hint::black_box` to defeat dead-code elimination.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut stats = Running::new();
        let mut all = Vec::with_capacity(self.samples);
        for _ in 0..self.samples.max(1) {
            let t = Instant::now();
            std::hint::black_box(f());
            let dt = t.elapsed().as_secs_f64();
            stats.push(dt);
            all.push(dt);
        }
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = all[all.len() / 2];
        BenchResult {
            name: name.to_string(),
            samples: self.samples.max(1),
            mean_s: stats.mean(),
            median_s: median,
            std_s: stats.std_dev(),
            min_s: stats.min(),
            max_s: stats.max(),
        }
    }
}

/// Print a section header in the bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a figure/table row (uniform formatting across benches).
pub fn metric_row(label: &str, value: f64, unit: &str) {
    println!("  {label:<52} {value:>12.4} {unit}");
}

/// Persist a benchmark record as pretty JSON (the `BENCH_*.json` convention:
/// one file per perf surface at the repository root, so successive PRs have
/// a throughput trajectory to compare against).
pub fn write_bench_json(path: &str, json: &Json) -> std::io::Result<()> {
    std::fs::write(path, json.pretty())
}

/// Environment knob: `GAUCIM_BENCH_SCALE` divides workload sizes so CI can
/// run the full suite quickly (default 1 = paper-scale divisors chosen per
/// bench).
pub fn bench_scale() -> usize {
    std::env::var("GAUCIM_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&v| v >= 1)
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bench { warmup: 1, samples: 5 };
        let r = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert_eq!(r.samples, 5);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.median_s && r.median_s <= r.max_s);
        assert!(r.row().contains("spin"));
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(2.0).ends_with(" s"));
        assert!(human_time(2e-3).ends_with(" ms"));
        assert!(human_time(2e-6).ends_with(" µs"));
        assert!(human_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn bench_scale_default_one() {
        std::env::remove_var("GAUCIM_BENCH_SCALE");
        assert_eq!(bench_scale(), 1);
    }
}
