//! Deterministic observability layer: the typed metrics registry
//! ([`registry`]) every report assembles its JSON through, and the
//! simulated-time frame tracer ([`trace`]) exporting Chrome trace-event
//! JSON. See `README.md` in this directory for the schema, the
//! determinism contract, and how to open a trace in Perfetto.

pub mod registry;
pub mod trace;

pub use registry::{
    percentile, percentile_sorted, Component, LatencyLadder, Node, Registry, SCHEMA_VERSION,
};
pub use trace::{sink, TraceEvent, TraceSink, Tracer, Track, DEFAULT_TRACE_CAPACITY};
