//! Simulated-time frame tracer: an opt-in ring-buffered event sink that
//! records stage spans, per-channel DRAM transaction spans, and session
//! lifecycle instants — all stamped in **simulated nanoseconds** — and
//! exports them as Chrome trace-event JSON loadable in Perfetto /
//! `chrome://tracing`.
//!
//! # Determinism contract
//!
//! Every timestamp recorded here comes from the simulated timeline (the
//! event-queue memory system's clocks and the modeled stage latencies),
//! never from host wall-clock, and every emission site runs in the
//! deterministic order the round engine already guarantees (lockstep
//! serial, or policy-ordered replay in the two-phase path). The exported
//! byte stream is therefore bit-identical across `PALLAS_THREADS=1/4/8`
//! for every scheduling policy — `tests/observability.rs` and the CI
//! `obs-smoke` job diff it.
//!
//! # Track model
//!
//! One Chrome *process* (`pid`) per traced run section (a contended batch,
//! one session-policy run, a standalone pipeline); within a process, one
//! *thread* track per viewer/session ([`Track::Viewer`]), one per DRAM
//! channel ([`Track::Channel`]), and one for scheduler lifecycle events
//! ([`Track::Scheduler`]). Span nesting on a track is monotone: frames
//! enclose stages, stages enclose their sub-spans, and the per-track
//! cursor lays consecutive frames out without overlap.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Default ring capacity (events). Old events are dropped (and counted)
/// once the buffer is full — deterministically, since recording order is.
pub const DEFAULT_TRACE_CAPACITY: usize = 1 << 16;

/// Shared handle to a [`Tracer`] — the form it is threaded through
/// `FrameCtx`, the round engine, and the memory system in.
pub type TraceSink = Arc<Mutex<Tracer>>;

/// New shared tracer at the default ring capacity.
pub fn sink() -> TraceSink {
    Arc::new(Mutex::new(Tracer::new()))
}

/// A timeline within one traced process.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Track {
    /// Scheduler / lifecycle events (admission, rounds).
    Scheduler,
    /// One viewer or session stream.
    Viewer(usize),
    /// One DRAM channel of the shared memory system.
    Channel(usize),
}

impl Track {
    /// Stable Chrome `tid` encoding: scheduler = 1, viewers from 10,
    /// channels from 1000.
    pub fn tid(self) -> u64 {
        match self {
            Track::Scheduler => 1,
            Track::Viewer(v) => 10 + v as u64,
            Track::Channel(c) => 1000 + c as u64,
        }
    }

    pub fn label(self) -> String {
        match self {
            Track::Scheduler => "scheduler".to_string(),
            Track::Viewer(v) => format!("viewer-{v}"),
            Track::Channel(c) => format!("dram-ch{c}"),
        }
    }
}

/// One recorded event: a complete span (`dur_ns = Some`) or an instant.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    /// Chrome category (filterable in the UI): `"stage"`, `"dram"`,
    /// `"session"`, …
    pub cat: &'static str,
    pub pid: u64,
    pub track: Track,
    /// Simulated nanoseconds.
    pub ts_ns: f64,
    /// Span duration in simulated ns; `None` ⇒ instant event.
    pub dur_ns: Option<f64>,
    pub args: Vec<(&'static str, Json)>,
}

/// The ring-buffered simulated-time event sink.
#[derive(Debug)]
pub struct Tracer {
    capacity: usize,
    events: VecDeque<TraceEvent>,
    dropped: u64,
    /// Process labels in creation order; `pid = index + 1`.
    processes: Vec<String>,
    /// Registered `(pid, tid) → label` track names (export metadata).
    tracks: BTreeMap<(u64, u64), String>,
    /// Per-`(pid, tid)` simulated-time cursor: where the next frame span
    /// on that track may start (sequential, non-overlapping layout).
    cursors: BTreeMap<(u64, u64), f64>,
}

impl Default for Tracer {
    fn default() -> Tracer {
        Tracer::new()
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer::with_capacity(DEFAULT_TRACE_CAPACITY)
    }

    pub fn with_capacity(capacity: usize) -> Tracer {
        Tracer {
            capacity: capacity.max(1),
            events: VecDeque::new(),
            dropped: 0,
            processes: Vec::new(),
            tracks: BTreeMap::new(),
            cursors: BTreeMap::new(),
        }
    }

    /// Open a new traced run section; returns its `pid`. Section creation
    /// follows program order, which is thread-count independent.
    pub fn begin_process(&mut self, label: &str) -> u64 {
        self.processes.push(label.to_string());
        self.processes.len() as u64
    }

    /// Register `track` under `pid` (idempotent) so the export carries its
    /// `thread_name` metadata.
    pub fn ensure_track(&mut self, pid: u64, track: Track) {
        self.tracks.entry((pid, track.tid())).or_insert_with(|| track.label());
    }

    /// Record a complete span (`ph: "X"`).
    pub fn span(
        &mut self,
        pid: u64,
        track: Track,
        name: &str,
        cat: &'static str,
        ts_ns: f64,
        dur_ns: f64,
        args: Vec<(&'static str, Json)>,
    ) {
        self.ensure_track(pid, track);
        self.record(TraceEvent {
            name: name.to_string(),
            cat,
            pid,
            track,
            ts_ns,
            dur_ns: Some(dur_ns),
            args,
        });
    }

    /// Record an instant event (`ph: "i"`).
    pub fn instant(
        &mut self,
        pid: u64,
        track: Track,
        name: &str,
        cat: &'static str,
        ts_ns: f64,
        args: Vec<(&'static str, Json)>,
    ) {
        self.ensure_track(pid, track);
        self.record(TraceEvent {
            name: name.to_string(),
            cat,
            pid,
            track,
            ts_ns,
            dur_ns: None,
            args,
        });
    }

    fn record(&mut self, ev: TraceEvent) {
        if self.events.len() == self.capacity {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }

    /// The sequential-layout cursor of a track (0 before any span).
    pub fn cursor(&self, pid: u64, track: Track) -> f64 {
        self.cursors.get(&(pid, track.tid())).copied().unwrap_or(0.0)
    }

    pub fn set_cursor(&mut self, pid: u64, track: Track, ts_ns: f64) {
        self.cursors.insert((pid, track.tid()), ts_ns);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted by the ring so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Export as a Chrome trace-event document: metadata (process/thread
    /// names) first, then the events in recording order. `ts`/`dur` are in
    /// microseconds per the trace-event spec (simulated ns / 1000).
    pub fn chrome_json(&self) -> Json {
        let mut evs: Vec<Json> = Vec::with_capacity(
            self.events.len() + self.processes.len() + self.tracks.len(),
        );
        for (i, label) in self.processes.iter().enumerate() {
            evs.push(
                Json::obj()
                    .set("args", Json::obj().set("name", label.as_str()))
                    .set("cat", "__metadata")
                    .set("name", "process_name")
                    .set("ph", "M")
                    .set("pid", (i + 1) as u64)
                    .set("tid", 0u64)
                    .set("ts", 0.0),
            );
        }
        for ((pid, tid), label) in &self.tracks {
            evs.push(
                Json::obj()
                    .set("args", Json::obj().set("name", label.as_str()))
                    .set("cat", "__metadata")
                    .set("name", "thread_name")
                    .set("ph", "M")
                    .set("pid", *pid)
                    .set("tid", *tid)
                    .set("ts", 0.0),
            );
        }
        for ev in &self.events {
            let mut args = Json::obj();
            for (k, v) in &ev.args {
                args = args.set(k, v.clone());
            }
            let mut js = Json::obj()
                .set("args", args)
                .set("cat", ev.cat)
                .set("name", ev.name.as_str())
                .set("pid", ev.pid)
                .set("tid", ev.track.tid())
                .set("ts", ev.ts_ns / 1000.0);
            js = match ev.dur_ns {
                Some(d) => js.set("ph", "X").set("dur", d / 1000.0),
                // Thread-scoped instant: renders as a tick on its track.
                None => js.set("ph", "i").set("s", "t"),
            };
            evs.push(js);
        }
        Json::obj()
            .set("traceEvents", Json::Arr(evs))
            .set("displayTimeUnit", "ms")
            .set(
                "otherData",
                Json::obj()
                    .set("clock", "simulated-ns")
                    .set("dropped_events", self.dropped),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn track_tids_are_disjoint() {
        assert_ne!(Track::Scheduler.tid(), Track::Viewer(0).tid());
        assert_ne!(Track::Viewer(989).tid(), Track::Channel(0).tid());
        assert_eq!(Track::Viewer(3).label(), "viewer-3");
        assert_eq!(Track::Channel(2).label(), "dram-ch2");
    }

    #[test]
    fn ring_drops_oldest_deterministically() {
        let mut t = Tracer::with_capacity(2);
        let pid = t.begin_process("p");
        for i in 0..5 {
            t.span(pid, Track::Viewer(0), &format!("e{i}"), "stage", i as f64, 1.0, vec![]);
        }
        assert_eq!(t.len(), 2);
        assert_eq!(t.dropped(), 3);
        let js = t.chrome_json().pretty();
        assert!(js.contains("\"e3\""));
        assert!(js.contains("\"e4\""));
        assert!(!js.contains("\"e0\""));
    }

    #[test]
    fn chrome_export_parses_and_carries_metadata() {
        let mut t = Tracer::new();
        let pid = t.begin_process("run-a");
        t.span(pid, Track::Viewer(1), "frame", "stage", 2000.0, 1000.0, vec![
            ("frame", Json::from(0u64)),
        ]);
        t.instant(pid, Track::Scheduler, "join", "session", 0.0, vec![]);
        let js = t.chrome_json();
        let parsed = crate::util::json::parse(&js.pretty()).expect("valid JSON");
        let evs = match parsed.get("traceEvents") {
            Some(Json::Arr(v)) => v.clone(),
            other => panic!("traceEvents missing: {other:?}"),
        };
        // 1 process_name + 2 thread_name + 2 events.
        assert_eq!(evs.len(), 5);
        assert!(evs.iter().any(|e| e.get("name").and_then(Json::as_str)
            == Some("process_name")));
        let frame = evs
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("frame"))
            .unwrap();
        assert_eq!(frame.get("ts").unwrap().as_f64(), Some(2.0));
        assert_eq!(frame.get("dur").unwrap().as_f64(), Some(1.0));
        assert_eq!(frame.get("ph").unwrap().as_str(), Some("X"));
    }

    #[test]
    fn cursors_default_zero_and_persist() {
        let mut t = Tracer::new();
        let pid = t.begin_process("p");
        assert_eq!(t.cursor(pid, Track::Viewer(0)), 0.0);
        t.set_cursor(pid, Track::Viewer(0), 42.0);
        assert_eq!(t.cursor(pid, Track::Viewer(0)), 42.0);
        assert_eq!(t.cursor(pid, Track::Viewer(1)), 0.0);
    }
}
