//! Typed metrics registry: the single percentile/ladder implementation and
//! the hierarchical component tree every report in the crate assembles its
//! JSON through.
//!
//! # Why a registry
//!
//! Before this module, ~14 report types hand-rolled their `to_json`
//! assembly and at least four modules carried private percentile code. The
//! registry replaces both: [`LatencyLadder::of`] is the one place sample
//! vectors become percentile ladders (nearest-rank, the convention the
//! pre-registry `Percentiles`/`math::stats::percentile` code used, so
//! existing `p50`/`p90`/`p99` JSON values are byte-identical), and
//! [`Component`] is the one place metric trees become [`Json`] objects.
//!
//! # Determinism contract
//!
//! A [`Registry`] splits its tree into two sections:
//!
//! - `deterministic` — metrics derived from *simulated* time and modeled
//!   counters only. This section must be byte-identical across
//!   `PALLAS_THREADS=1/4/8` for every scheduling policy; CI diffs it.
//! - `host` — wall-clock measurements, speedups, fps: anything the host
//!   machine or thread count can perturb. Excluded from CI diffs.
//!
//! Because [`Component`] stores children in a `BTreeMap`, JSON key order is
//! insertion-order independent — re-assembling an existing report through
//! the registry cannot reorder its keys.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Version stamp of the registry JSON encoding ([`Registry::to_json`]'s
/// `schema` key). Bump when the section layout or ladder shape changes.
pub const SCHEMA_VERSION: u64 = 1;

/// p-th percentile (0..=100) of an ascending-sorted slice by nearest rank:
/// `rank = round(p/100 · (n−1))`. Empty input ⇒ 0.0.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy — the
/// crate's single percentile implementation (`math::stats::percentile`
/// delegates here; everything else goes through [`LatencyLadder::of`]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    percentile_sorted(&v, p)
}

/// The full latency ladder of one sample population:
/// count/min/mean/p50/p75/p90/p95/p99/p99.9/max, all computed from a
/// single sort. `p90` is carried alongside the ladder rungs the yb_stats
/// schema uses so the pre-registry `{p50, p90, p99}` values survive
/// byte-identically in re-assembled reports.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyLadder {
    pub count: u64,
    pub min: f64,
    pub mean: f64,
    pub p50: f64,
    pub p75: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub p99_9: f64,
    pub max: f64,
}

impl LatencyLadder {
    /// Build the ladder from unsorted samples (one sort; empty ⇒ all-zero).
    pub fn of(samples: &[f64]) -> LatencyLadder {
        if samples.is_empty() {
            return LatencyLadder::default();
        }
        let mut v: Vec<f64> = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let count = v.len() as u64;
        // Summing in ascending order keeps the mean deterministic for any
        // input permutation of the same multiset.
        let mean = v.iter().sum::<f64>() / count as f64;
        LatencyLadder {
            count,
            min: v[0],
            mean,
            p50: percentile_sorted(&v, 50.0),
            p75: percentile_sorted(&v, 75.0),
            p90: percentile_sorted(&v, 90.0),
            p95: percentile_sorted(&v, 95.0),
            p99: percentile_sorted(&v, 99.0),
            p99_9: percentile_sorted(&v, 99.9),
            max: v[v.len() - 1],
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("count", self.count)
            .set("min", self.min)
            .set("mean", self.mean)
            .set("p50", self.p50)
            .set("p75", self.p75)
            .set("p90", self.p90)
            .set("p95", self.p95)
            .set("p99", self.p99)
            .set("p99_9", self.p99_9)
            .set("max", self.max)
    }
}

/// One node of the metric tree: a typed leaf metric, a nested component, a
/// list (per-viewer / per-session report rows), or a raw [`Json`] escape
/// hatch for sub-blocks that already have a stable encoding (e.g. the
/// per-stage `DramStats` objects of `TrafficLog`).
#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    /// Monotone integer count (reads, frames, evictions, …).
    Counter(u64),
    /// Point-in-time float (rates, ratios, simulated ns, …).
    Gauge(f64),
    Flag(bool),
    Text(String),
    Ladder(LatencyLadder),
    Component(Component),
    List(Vec<Node>),
    Raw(Json),
}

impl Node {
    pub fn to_json(&self) -> Json {
        match self {
            Node::Counter(v) => Json::from(*v),
            Node::Gauge(v) => Json::from(*v),
            Node::Flag(v) => Json::from(*v),
            Node::Text(v) => Json::from(v.as_str()),
            Node::Ladder(l) => l.to_json(),
            Node::Component(c) => c.to_json(),
            Node::List(xs) => Json::Arr(xs.iter().map(Node::to_json).collect()),
            Node::Raw(j) => j.clone(),
        }
    }
}

impl From<u64> for Node {
    fn from(v: u64) -> Node {
        Node::Counter(v)
    }
}
impl From<usize> for Node {
    fn from(v: usize) -> Node {
        Node::Counter(v as u64)
    }
}
impl From<f64> for Node {
    fn from(v: f64) -> Node {
        Node::Gauge(v)
    }
}
impl From<bool> for Node {
    fn from(v: bool) -> Node {
        Node::Flag(v)
    }
}
impl From<&str> for Node {
    fn from(v: &str) -> Node {
        Node::Text(v.to_string())
    }
}
impl From<String> for Node {
    fn from(v: String) -> Node {
        Node::Text(v)
    }
}
impl From<LatencyLadder> for Node {
    fn from(v: LatencyLadder) -> Node {
        Node::Ladder(v)
    }
}
impl From<Component> for Node {
    fn from(v: Component) -> Node {
        Node::Component(v)
    }
}
impl From<Json> for Node {
    fn from(v: Json) -> Node {
        Node::Raw(v)
    }
}

/// A named subtree of metrics (native-link-style component hierarchy).
/// Children live in a `BTreeMap`, so the JSON encoding is independent of
/// insertion order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Component {
    children: BTreeMap<String, Node>,
}

impl Component {
    pub fn new() -> Component {
        Component::default()
    }

    /// Insert any node (builder-style, like `Json::set`).
    pub fn set(mut self, name: &str, node: impl Into<Node>) -> Component {
        self.children.insert(name.to_string(), node.into());
        self
    }

    /// In-place insert, for loops building lists of siblings.
    pub fn insert(&mut self, name: &str, node: impl Into<Node>) {
        self.children.insert(name.to_string(), node.into());
    }

    /// Insert a list of components (per-viewer rows and the like).
    pub fn list(self, name: &str, items: impl IntoIterator<Item = Component>) -> Component {
        self.set(name, Node::List(items.into_iter().map(Node::Component).collect()))
    }

    pub fn get(&self, name: &str) -> Option<&Node> {
        self.children.get(name)
    }

    pub fn is_empty(&self) -> bool {
        self.children.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in &self.children {
            m.insert(k.clone(), v.to_json());
        }
        Json::Obj(m)
    }
}

/// The two-section metrics registry: everything under `deterministic` obeys
/// the cross-thread-count byte-identity contract; everything under `host`
/// is wall-clock territory and excluded from CI diffs.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    pub deterministic: Component,
    pub host: Component,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Schema-versioned encoding: `{"schema": N, "deterministic": {...},
    /// "host": {...}}`.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("schema", SCHEMA_VERSION)
            .set("deterministic", self.deterministic.to_json())
            .set("host", self.host.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_empty_single_and_ties() {
        let empty = LatencyLadder::of(&[]);
        assert_eq!(empty, LatencyLadder::default());
        assert_eq!(empty.count, 0);

        let one = LatencyLadder::of(&[7.0]);
        assert_eq!(one.count, 1);
        assert_eq!(one.min, 7.0);
        assert_eq!(one.mean, 7.0);
        assert_eq!(one.p50, 7.0);
        assert_eq!(one.p99_9, 7.0);
        assert_eq!(one.max, 7.0);

        let ties = LatencyLadder::of(&[3.0, 3.0, 3.0, 3.0]);
        assert_eq!(ties.p50, 3.0);
        assert_eq!(ties.p75, 3.0);
        assert_eq!(ties.max, 3.0);
    }

    #[test]
    fn ladder_matches_percentile_helper() {
        let xs: Vec<f64> = (0..=100).rev().map(|i| i as f64).collect();
        let l = LatencyLadder::of(&xs);
        assert_eq!(l.p50, percentile(&xs, 50.0));
        assert_eq!(l.p75, percentile(&xs, 75.0));
        assert_eq!(l.p90, percentile(&xs, 90.0));
        assert_eq!(l.p95, percentile(&xs, 95.0));
        assert_eq!(l.p99, percentile(&xs, 99.0));
        assert_eq!(l.p99_9, percentile(&xs, 99.9));
        assert_eq!(l.min, 0.0);
        assert_eq!(l.max, 100.0);
        assert_eq!(l.mean, 50.0);
    }

    #[test]
    fn component_json_is_insertion_order_independent() {
        let a = Component::new().set("b", 1u64).set("a", 2.0);
        let b = Component::new().set("a", 2.0).set("b", 1u64);
        assert_eq!(a.to_json().pretty(), b.to_json().pretty());
    }

    #[test]
    fn registry_sections_and_schema() {
        let mut r = Registry::new();
        r.deterministic = r.deterministic.set("frames", 3u64);
        r.host = r.host.set("wall_s", 0.5);
        let js = r.to_json();
        assert_eq!(js.get("schema").unwrap().as_usize(), Some(1));
        assert!(js.get("deterministic").unwrap().get("frames").is_some());
        assert!(js.get("host").unwrap().get("wall_s").is_some());
    }

    #[test]
    fn node_json_shapes() {
        let c = Component::new()
            .set("n", 3u64)
            .set("g", 1.5)
            .set("f", true)
            .set("t", "x")
            .set("l", LatencyLadder::of(&[1.0, 2.0]))
            .set("raw", Json::Arr(vec![Json::Num(1.0)]))
            .list("rows", vec![Component::new().set("v", 0u64)]);
        let js = c.to_json();
        assert_eq!(js.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(js.get("g").unwrap().as_f64(), Some(1.5));
        assert_eq!(js.get("f").unwrap().as_bool(), Some(true));
        assert_eq!(js.get("t").unwrap().as_str(), Some("x"));
        assert!(js.get("l").unwrap().get("p99_9").is_some());
        assert!(matches!(js.get("raw"), Some(Json::Arr(_))));
        assert!(matches!(js.get("rows"), Some(Json::Arr(v)) if v.len() == 1));
    }
}
