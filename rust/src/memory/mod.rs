//! Memory-system models: off-chip LPDDR5 DRAM, the event-queue memory
//! subsystem, scene sharding, and the 256 KB on-chip SRAM buffer with the
//! depth-segmented 2-way associative organization of paper §3.3-III.
//!
//! Layout of the subsystem (see `README.md` in this directory):
//!
//! * [`dram`] — configuration ([`DramConfig`]), the statistics contract
//!   ([`DramStats`], now including contention fields), and the [`MemSink`]
//!   request trait every backend implements;
//! * [`oracle`] — [`SyncDramModel`], the frozen synchronous-per-read model
//!   (determinism baseline; re-exported as [`DramModel`] for the frozen
//!   pipeline monolith and the figure benches);
//! * [`event_queue`] — the [`MemorySystem`]: per-channel FIFO queues with
//!   row-buffer state, per-port outstanding-transaction windows, shard
//!   channel groups, epoch barriers, and the [`MemPort`] handle the
//!   pipeline stages issue requests through;
//! * [`shard`] — [`ShardMap`], the row-aligned partition of a scene's DRAM
//!   span into channel groups (built offline by `pipeline::ScenePrep`);
//! * [`sram`] — the blending buffer model (lookups, miss fills via any
//!   [`MemSink`], LRU within depth segments);
//! * [`traffic`] — [`TrafficLog`], the per-frame roll-up every stage
//!   deposits its statistics into.

pub mod dram;
pub mod event_queue;
pub mod oracle;
pub mod residency;
pub mod shard;
pub mod sram;
pub mod traffic;

pub use dram::{DramConfig, DramModel, DramStats, MemSink};
pub use event_queue::{
    MemMode, MemPort, MemRequest, MemSimConfig, MemStage, MemorySystem, PortId,
};
pub use oracle::SyncDramModel;
pub use residency::{
    EvictPolicy, PrefetchPolicy, ResidencyConfig, ResidencyPrefetcher, ResidencyReport,
    ResidencyState, ResidencyStats,
};
pub use shard::ShardMap;
pub use sram::{SegmentWalker, SramBuffer, SramConfig, SramStats};
pub use traffic::TrafficLog;
