//! Memory-system models: off-chip LPDDR5 DRAM (Ramulator-2.0 stand-in, see
//! DESIGN.md §2) and the 256 KB on-chip SRAM buffer with the depth-segmented
//! 2-way associative organization of paper §3.3-III.

pub mod dram;
pub mod sram;
pub mod traffic;

pub use dram::{DramConfig, DramModel, DramStats};
pub use sram::{SramBuffer, SramConfig, SramStats};
pub use traffic::TrafficLog;
