//! On-chip SRAM buffer model (paper §3.3 implementation consideration III).
//!
//! The 256 KB blending buffer is partitioned into **N equal depth segments**
//! (N = the AII-Sort bucket count); a Gaussian's parameters are cached in the
//! segment matching its depth bucket, and lookups are **2-way associative**
//! within the segment. Tracks hits/misses/evictions and read/write energy —
//! the buffer-reuse signal behind the ATG experiments (Fig. 10). Miss
//! fills issue their DRAM traffic through any [`MemSink`] (see
//! [`SramBuffer::lookup_or_fill`]), so the buffer works against both the
//! synchronous oracle and the event-queue memory system.

use crate::memory::dram::MemSink;

/// Buffer configuration.
#[derive(Debug, Clone, Copy)]
pub struct SramConfig {
    /// Total capacity (paper: 256 KB).
    pub capacity_bytes: usize,
    /// Depth segments (paper couples this to AII-Sort's N buckets).
    pub segments: usize,
    /// Associativity within a segment (paper: 2-way).
    pub ways: usize,
    /// Cached line size = one Gaussian parameter record.
    pub line_bytes: usize,
    /// Read energy per bit (pJ) — 16 nm SRAM class.
    pub e_read_pj_per_bit: f64,
    /// Write energy per bit (pJ).
    pub e_write_pj_per_bit: f64,
}

impl SramConfig {
    pub fn paper_default(line_bytes: usize, segments: usize) -> SramConfig {
        SramConfig {
            capacity_bytes: 256 * 1024,
            segments,
            ways: 2,
            line_bytes,
            e_read_pj_per_bit: 0.012,
            e_write_pj_per_bit: 0.015,
        }
    }

    /// Cache sets per segment.
    pub fn sets_per_segment(&self) -> usize {
        let seg_bytes = self.capacity_bytes / self.segments.max(1);
        (seg_bytes / (self.line_bytes.max(1) * self.ways)).max(1)
    }
}

/// Statistics. `energy_pj` is **derived** from the hit/write counters when
/// a buffer snapshot is taken ([`SramBuffer::stats`]) rather than
/// accumulated per operation, so it reduces exactly no matter how a lookup
/// stream was partitioned across the executor's segment walkers.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SramStats {
    pub lookups: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Line writes (miss fills / inserts).
    pub writes: u64,
    pub energy_pj: f64,
}

impl SramStats {
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    pub fn add(&mut self, o: &SramStats) {
        self.lookups += o.lookups;
        self.hits += o.hits;
        self.misses += o.misses;
        self.evictions += o.evictions;
        self.writes += o.writes;
        self.energy_pj += o.energy_pj;
    }
}

#[derive(Debug, Clone, Copy)]
struct Way {
    key: u64,
    last_use: u64,
    valid: bool,
}

/// The buffer: `segments × sets × ways` of Gaussian-record lines with LRU
/// replacement inside each set.
#[derive(Debug)]
pub struct SramBuffer {
    pub config: SramConfig,
    sets: Vec<Way>, // flattened [segment][set][way]
    sets_per_segment: usize,
    clock: u64,
    stats: SramStats,
}

impl SramBuffer {
    pub fn new(config: SramConfig) -> SramBuffer {
        let sets_per_segment = config.sets_per_segment();
        let total = config.segments * sets_per_segment * config.ways;
        SramBuffer {
            config,
            sets: vec![Way { key: 0, last_use: 0, valid: false }; total],
            sets_per_segment,
            clock: 0,
            stats: SramStats::default(),
        }
    }

    #[inline]
    fn set_range(&self, segment: usize, key: u64) -> (usize, usize) {
        let seg = segment.min(self.config.segments - 1);
        // Multiplicative hash for set selection.
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32;
        let set = (h as usize) % self.sets_per_segment;
        let base = (seg * self.sets_per_segment + set) * self.config.ways;
        (base, base + self.config.ways)
    }

    /// Look up `key` in `segment`; on hit, refresh LRU (a line read is
    /// charged when statistics are snapshotted). Returns `true` on hit. On
    /// miss the caller fetches from DRAM and calls [`SramBuffer::insert`].
    pub fn lookup(&mut self, segment: usize, key: u64) -> bool {
        self.clock += 1;
        self.stats.lookups += 1;
        let (lo, hi) = self.set_range(segment, key);
        for i in lo..hi {
            if self.sets[i].valid && self.sets[i].key == key {
                self.sets[i].last_use = self.clock;
                self.stats.hits += 1;
                return true;
            }
        }
        self.stats.misses += 1;
        false
    }

    /// Insert `key` into `segment` (after a miss fill), LRU-evicting.
    pub fn insert(&mut self, segment: usize, key: u64) {
        self.clock += 1;
        self.stats.writes += 1;

        // Reuse an invalid way if present.
        let (lo, hi) = self.set_range(segment, key);
        let mut victim = lo;
        let mut oldest = u64::MAX;
        for i in lo..hi {
            if !self.sets[i].valid {
                victim = i;
                break;
            }
            if self.sets[i].last_use < oldest {
                oldest = self.sets[i].last_use;
                victim = i;
            }
        }
        if self.sets[victim].valid {
            self.stats.evictions += 1;
        }
        self.sets[victim] = Way { key, last_use: self.clock, valid: true };
    }

    /// Look up `key` in `segment`; on a miss, fill the line from DRAM by
    /// issuing `bytes` at `addr` through `mem` and insert it. Returns
    /// `true` on hit. This is the blend-stage miss-fill path: the buffer
    /// issues its own DRAM traffic through a
    /// [`MemPort`](crate::memory::MemPort) (or any [`MemSink`]) instead of
    /// the caller juggling a raw DRAM model — operation order (lookup,
    /// fill, insert) matches the pre-refactor inline sequence exactly.
    pub fn lookup_or_fill<M: MemSink>(
        &mut self,
        segment: usize,
        key: u64,
        addr: u64,
        bytes: u64,
        mem: &mut M,
    ) -> bool {
        if self.lookup(segment, key) {
            return true;
        }
        mem.read(addr, bytes);
        self.insert(segment, key);
        false
    }

    /// Statistics snapshot. Energy derives from the counters here —
    /// `hits·E_read + writes·E_write` per line (tag checks are negligible
    /// next to the line access) — so it is independent of how the lookup
    /// stream was partitioned across segment walkers: a requirement of the
    /// parallel executor's bit-identical-stats contract.
    pub fn stats(&self) -> SramStats {
        let mut s = self.stats;
        let bits = (self.config.line_bytes * 8) as f64;
        s.energy_pj = s.hits as f64 * self.config.e_read_pj_per_bit * bits
            + s.writes as f64 * self.config.e_write_pj_per_bit * bits;
        s
    }

    /// Split the buffer into independent per-depth-segment walkers (one
    /// per segment, each owning that segment's way storage). Lookups are
    /// already segment-local (set selection never crosses a segment), and
    /// LRU only compares ages *within a set*, so replaying each segment's
    /// subsequence of a global lookup stream — in stream order, under a
    /// segment-local clock — reproduces the exact hit/miss/eviction
    /// sequence of the monolithic walk. The caller folds walker counters
    /// back with [`SramBuffer::merge_stats`] in segment order.
    pub fn segment_walkers(&mut self) -> Vec<SegmentWalker<'_>> {
        let config = self.config;
        let sets_per_segment = self.sets_per_segment;
        let per = (sets_per_segment * config.ways).max(1);
        self.sets
            .chunks_mut(per)
            .map(|ways| SegmentWalker {
                config,
                sets_per_segment,
                ways,
                clock: 0,
                stats: SramStats::default(),
            })
            .collect()
    }

    /// Fold per-segment walker counters back into the buffer's statistics
    /// (callers iterate segments in fixed 0..N order; all fields are
    /// integer counters, so the reduction is exact).
    pub fn merge_stats(&mut self, per_segment: &[SramStats]) {
        for s in per_segment {
            self.stats.add(s);
        }
    }

    /// Clear contents and stats (new frame sweep with cold buffer).
    pub fn reset(&mut self) {
        for w in &mut self.sets {
            w.valid = false;
        }
        self.clock = 0;
        self.stats = SramStats::default();
    }

    /// Clear contents but keep statistics (e.g. between tile groups when
    /// modeling a flushed buffer).
    pub fn invalidate(&mut self) {
        for w in &mut self.sets {
            w.valid = false;
        }
    }

    /// Lines the whole buffer can hold.
    pub fn capacity_lines(&self) -> usize {
        self.config.segments * self.sets_per_segment * self.config.ways
    }
}

/// Independent per-segment view of an [`SramBuffer`] (see
/// [`SramBuffer::segment_walkers`]): replays one depth segment's lookup
/// subsequence with segment-local state, so the executor fans the blend
/// walk out across segments while keeping every counter bit-identical to
/// the monolithic serial walk.
#[derive(Debug)]
pub struct SegmentWalker<'a> {
    config: SramConfig,
    sets_per_segment: usize,
    ways: &'a mut [Way],
    clock: u64,
    stats: SramStats,
}

impl SegmentWalker<'_> {
    /// One lookup; on a miss the line is inserted immediately (the caller
    /// records the DRAM fill and issues it later in global request order).
    /// Returns `true` on hit. Mirrors `lookup` + `insert` of the owning
    /// buffer exactly, under a segment-local clock — LRU only compares
    /// ages within a set, so relative order (all that matters) is
    /// preserved.
    pub fn lookup_or_note(&mut self, key: u64) -> bool {
        self.clock += 1;
        self.stats.lookups += 1;
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32;
        let set = (h as usize) % self.sets_per_segment.max(1);
        let lo = set * self.config.ways;
        let hi = (lo + self.config.ways).min(self.ways.len());
        for i in lo..hi {
            if self.ways[i].valid && self.ways[i].key == key {
                self.ways[i].last_use = self.clock;
                self.stats.hits += 1;
                return true;
            }
        }
        self.stats.misses += 1;

        // Miss: fill the line (LRU-evicting), like `SramBuffer::insert`.
        self.clock += 1;
        self.stats.writes += 1;
        let mut victim = lo;
        let mut oldest = u64::MAX;
        for i in lo..hi {
            if !self.ways[i].valid {
                victim = i;
                break;
            }
            if self.ways[i].last_use < oldest {
                oldest = self.ways[i].last_use;
                victim = i;
            }
        }
        if self.ways[victim].valid {
            self.stats.evictions += 1;
        }
        self.ways[victim] = Way { key, last_use: self.clock, valid: true };
        false
    }

    /// Raw walker counters (energy stays 0 here — it derives from the
    /// merged counters at [`SramBuffer::stats`] time).
    pub fn stats(&self) -> SramStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SramBuffer {
        // 8 KB, 4 segments, 2-way, 64 B lines → 16 sets/segment.
        SramBuffer::new(SramConfig {
            capacity_bytes: 8 * 1024,
            segments: 4,
            ways: 2,
            line_bytes: 64,
            e_read_pj_per_bit: 0.01,
            e_write_pj_per_bit: 0.012,
        })
    }

    #[test]
    fn miss_then_hit() {
        let mut s = small();
        assert!(!s.lookup(0, 42));
        s.insert(0, 42);
        assert!(s.lookup(0, 42));
        let st = s.stats();
        assert_eq!(st.hits, 1);
        assert_eq!(st.misses, 1);
    }

    #[test]
    fn segments_are_isolated() {
        let mut s = small();
        s.insert(0, 7);
        assert!(s.lookup(0, 7));
        assert!(!s.lookup(1, 7), "other segment must not hit");
    }

    #[test]
    fn lru_evicts_oldest_within_set() {
        let mut s = small();
        // Find three keys mapping to the same set of segment 0.
        let (lo0, _) = s.set_range(0, 1);
        let mut same: Vec<u64> = Vec::new();
        let mut k = 1u64;
        while same.len() < 3 {
            if s.set_range(0, k).0 == lo0 {
                same.push(k);
            }
            k += 1;
        }
        s.insert(0, same[0]);
        s.insert(0, same[1]);
        assert!(s.lookup(0, same[0])); // refresh key0 → key1 is LRU
        s.insert(0, same[2]); // evicts key1
        assert!(s.lookup(0, same[0]));
        assert!(!s.lookup(0, same[1]), "LRU victim must be gone");
        assert!(s.lookup(0, same[2]));
        assert_eq!(s.stats().evictions, 1);
    }

    #[test]
    fn capacity_lines_matches_config() {
        let s = small();
        // 8 KB / 4 segments / (64 B × 2 ways) = 16 sets → 128 lines.
        assert_eq!(s.capacity_lines(), 4 * 16 * 2);
        let paper = SramBuffer::new(SramConfig::paper_default(88, 8));
        // 256 KB / 8 segments / (88 B × 2 ways) = 186 sets → 2976 lines.
        assert_eq!(paper.capacity_lines(), 8 * 186 * 2);
    }

    #[test]
    fn energy_accumulates() {
        let mut s = small();
        s.insert(0, 1);
        let e1 = s.stats().energy_pj;
        assert!(e1 > 0.0);
        s.lookup(0, 1);
        assert!(s.stats().energy_pj > e1);
    }

    #[test]
    fn lookup_or_fill_reads_dram_only_on_miss() {
        use crate::memory::oracle::SyncDramModel;
        let mut s = small();
        let mut dram = SyncDramModel::default_lpddr5();
        assert!(!s.lookup_or_fill(0, 9, 4096, 64, &mut dram));
        assert_eq!(dram.stats().reads, 1);
        assert!(s.lookup_or_fill(0, 9, 4096, 64, &mut dram));
        assert_eq!(dram.stats().reads, 1, "hit must not touch DRAM");
        assert_eq!(s.stats().hits, 1);
        assert_eq!(s.stats().misses, 1);
    }

    #[test]
    fn segment_walkers_match_monolithic_walk() {
        use crate::memory::dram::MemSink;

        struct AddrLog(Vec<u64>);
        impl MemSink for AddrLog {
            fn read(&mut self, addr: u64, _bytes: u64) {
                self.0.push(addr);
            }
        }

        // A deterministic interleaved stream over all 4 segments with
        // reuse (hits), conflicts, and evictions (modulus chosen so all
        // three counters are exercised; validated against a Python mirror
        // of both walks).
        let stream: Vec<(usize, u64)> = (0..600u64)
            .map(|i| (((i * 7 + i / 5) % 4) as usize, (i * 31 + 11) % 37))
            .collect();

        // (a) The monolithic serial walk.
        let mut mono = small();
        let mut fills = AddrLog(Vec::new());
        for &(seg, key) in &stream {
            mono.lookup_or_fill(seg, key, key * 64, 64, &mut fills);
        }

        // (b) The executor's sharded walk: per-segment subsequences in
        // stream order, misses replayed by global stream index.
        let mut sharded = small();
        let mut misses: Vec<(usize, u64)> = Vec::new();
        let per_segment: Vec<SramStats> = {
            let mut walkers = sharded.segment_walkers();
            assert_eq!(walkers.len(), 4);
            for (i, &(seg, key)) in stream.iter().enumerate() {
                if !walkers[seg].lookup_or_note(key) {
                    misses.push((i, key));
                }
            }
            walkers.iter().map(SegmentWalker::stats).collect()
        };
        sharded.merge_stats(&per_segment);

        assert_eq!(mono.stats(), sharded.stats());
        assert!(mono.stats().hits > 0, "stream must exercise the hit path");
        assert!(mono.stats().evictions > 0, "stream must exercise eviction");
        let replayed: Vec<u64> = misses.iter().map(|&(_, key)| key * 64).collect();
        assert_eq!(fills.0, replayed, "miss-fill order must match the serial walk");
    }

    #[test]
    fn invalidate_keeps_stats_reset_clears() {
        let mut s = small();
        s.insert(0, 1);
        s.lookup(0, 1);
        s.invalidate();
        assert!(!s.lookup(0, 1));
        assert_eq!(s.stats().hits, 1);
        s.reset();
        assert_eq!(s.stats(), SramStats::default());
    }
}
