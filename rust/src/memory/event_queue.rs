//! Event-queue memory subsystem: asynchronous DRAM channels with
//! outstanding-transaction limits, shard-aware channel groups, and
//! port-attributed contention statistics.
//!
//! # Timing model
//!
//! The system simulates one global nanosecond timeline. Every *port* (one
//! per pipeline stage per viewer) carries its own issue clock; every
//! *channel* carries row-buffer state, a FIFO service horizon (`free_at`),
//! and cumulative occupancy. A request [`MemRequest`] is split at shard
//! boundaries (see [`ShardMap`]), its bursts striped row-wise across the
//! shard's channel group, and each channel serves its share in
//! simulated-time arrival order:
//!
//! ```text
//! issue      = max(port clock, oldest outstanding completion if the
//!              per-port outstanding-transaction window is full)
//! start[ch]  = max(issue, channel free_at)
//! finish[ch] = start[ch] + service(row walk)
//! ```
//!
//! Because arrival order equals processing order, the per-channel pending
//! queue collapses to its completion horizon — the queue is implicit in
//! `free_at`, which is what "retired in simulated-time order" needs while
//! keeping the hot path allocation-free.
//!
//! Per-port statistics separate **service** from **contention**:
//! `busy_ns` accumulates the union of issue→completion intervals (so
//! overlapped in-flight transactions are not double counted), while
//! `wait_ns` / `stalls` meter only *cross-stream* queueing — channel busy
//! time beyond the port's own completion horizon. An isolated stream
//! therefore waits for nothing at any outstanding depth (queueing behind
//! your own in-flight transactions is pipelining, not contention); with
//! `channels = 1, outstanding = 1, shards = 1` the model reproduces the
//! synchronous oracle ([`SyncDramModel`](super::oracle::SyncDramModel))
//! statistics bit-for-bit (the `memory_event_queue` determinism suite).
//!
//! Frame pacing: [`MemorySystem::advance_epoch`] aligns every port clock to
//! the global completion horizon — callers invoke it at frame boundaries
//! (a private pipeline per frame; the contended `RenderServer` batch per
//! viewer round) so stale horizons never masquerade as contention.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::dram::{DramConfig, DramStats, MemSink};
use super::oracle::SyncDramModel;
use super::residency::{ResidencyConfig, ResidencyReport, ResidencyState};
use super::shard::ShardMap;
use crate::obs::{TraceSink, Track};
use crate::scene::CompressedStore;
use crate::util::json::Json;

/// Which pipeline stage a request belongs to (per-stage stats + completion
/// times are what let cull fetch and blend miss-fill overlap in the model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemStage {
    /// Culling parameter fetch (preprocess superstage).
    Preprocess,
    /// Blend-buffer miss fill.
    Blend,
    /// Residency-layer paging traffic: demand/prefetch page fills and
    /// eviction write-backs issued by the [`ResidencyState`] cache. Bypasses
    /// the residency hook (a page fill must not page).
    Paging,
    /// Dynamic-scene update stream: per-frame temporal-delta writes of
    /// changed Gaussian records into their cell runs
    /// (`scene::temporal`). Modeled with the read service timing (LPDDR5
    /// write bursts walk the same row buffers) and double-buffered per
    /// cell, so a frame's render reads never stall on its own updates —
    /// updates contend on the channels like any other stream but add no
    /// read-after-write dependency. Bypasses the residency hook (updates
    /// target the resident working set directly).
    Update,
}

impl MemStage {
    #[inline]
    pub fn idx(self) -> usize {
        match self {
            MemStage::Preprocess => 0,
            MemStage::Blend => 1,
            MemStage::Paging => 2,
            MemStage::Update => 3,
        }
    }

    /// Stable lowercase name (trace span names, report keys).
    pub fn label(self) -> &'static str {
        match self {
            MemStage::Preprocess => "preprocess",
            MemStage::Blend => "blend",
            MemStage::Paging => "paging",
            MemStage::Update => "update",
        }
    }
}

/// One memory request as it enters the per-channel queues.
#[derive(Debug, Clone, Copy)]
pub struct MemRequest {
    /// Byte address (global scene address space).
    pub addr: u64,
    /// Byte count; must not cross a shard boundary (the port front-end
    /// splits requests before submission).
    pub bytes: u64,
    /// Issuing pipeline stage.
    pub stage: MemStage,
    /// Target shard = channel group (from [`ShardMap::shard_of`]).
    pub shard: usize,
}

/// Which DRAM timing backend a pipeline simulates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemMode {
    /// The original synchronous-per-read model (the determinism baseline —
    /// bit-identical to the frozen `pipeline::oracle` monolith).
    Sync,
    /// The event-queue model: outstanding transactions, channel queues,
    /// shard groups, contention.
    EventQueue,
}

/// Memory-simulation configuration carried by `PipelineConfig`.
#[derive(Debug, Clone)]
pub struct MemSimConfig {
    pub mode: MemMode,
    /// Per-channel(-group) LPDDR5 timing. Under [`MemMode::EventQueue`],
    /// `dram.channels` is the channel count *per shard group*.
    pub dram: DramConfig,
    /// Outstanding-transaction window per port (≥ 1).
    pub outstanding: usize,
    /// Scene shards = channel groups (≥ 1).
    pub shards: usize,
    /// Streaming-residency layer (disabled by default: fully-resident DRAM,
    /// bit-identical to the pre-residency model).
    pub residency: ResidencyConfig,
}

impl Default for MemSimConfig {
    fn default() -> Self {
        MemSimConfig {
            mode: MemMode::Sync,
            dram: DramConfig::default(),
            outstanding: 4,
            shards: 1,
            residency: ResidencyConfig::default(),
        }
    }
}

impl MemSimConfig {
    /// Event-queue mode at the default LPDDR5 operating point.
    pub fn event_queue() -> MemSimConfig {
        MemSimConfig { mode: MemMode::EventQueue, ..MemSimConfig::default() }
    }

    /// The determinism-suite configuration: one channel, one outstanding
    /// transaction, one shard — the operating point that must reproduce
    /// the synchronous oracle bit-for-bit.
    pub fn oracle_point() -> MemSimConfig {
        MemSimConfig {
            mode: MemMode::EventQueue,
            dram: DramConfig { channels: 1, ..DramConfig::default() },
            outstanding: 1,
            shards: 1,
            residency: ResidencyConfig::default(),
        }
    }

    /// Total simulated channels (`shards × channels-per-group`).
    pub fn total_channels(&self) -> usize {
        self.shards.max(1) * self.dram.channels.max(1)
    }
}

/// Port identifier within one [`MemorySystem`].
pub type PortId = usize;

#[derive(Debug)]
struct Channel {
    open_row: Option<u64>,
    /// Completion horizon of the implicit FIFO queue.
    free_at_ns: f64,
    /// Cumulative service time (occupancy) on this channel.
    service_ns: f64,
    /// Requests (or request slices) served.
    served: u64,
}

impl Channel {
    fn new() -> Channel {
        Channel { open_row: None, free_at_ns: 0.0, service_ns: 0.0, served: 0 }
    }
}

#[derive(Debug)]
struct PortState {
    /// Port-local issue clock.
    now_ns: f64,
    /// Completion times of in-flight transactions, in issue order.
    inflight: VecDeque<f64>,
    /// Latest completion observed by this port (any stage).
    last_completion_ns: f64,
    /// Cumulative per-stage statistics.
    stats: [DramStats; 4],
    /// Per-stage first-issue / last-completion timestamps.
    first_issue_ns: [f64; 4],
    last_completion_stage_ns: [f64; 4],
    /// Retired ports (departed viewer sessions) keep their statistics
    /// readable but issue no further traffic and are skipped by epoch
    /// barriers.
    retired: bool,
}

impl PortState {
    fn new(now_ns: f64) -> PortState {
        PortState {
            now_ns,
            inflight: VecDeque::new(),
            last_completion_ns: now_ns,
            stats: [DramStats::default(); 4],
            first_issue_ns: [f64::INFINITY; 4],
            last_completion_stage_ns: [0.0; 4],
            retired: false,
        }
    }
}

/// The shared, contended event-queue memory system.
#[derive(Debug)]
pub struct MemorySystem {
    pub config: MemSimConfig,
    pub shard_map: ShardMap,
    channels: Vec<Channel>,
    ports: Vec<PortState>,
    /// Per-request scratch: service time per channel of the active group.
    svc_ns: Vec<f64>,
    /// Per-request scratch (fast path): bursts / rows per group channel.
    svc_bursts: Vec<u64>,
    svc_rows: Vec<u64>,
    /// Page-granular residency cache over the compressed backing store.
    /// `None` when the scene is fully DRAM-resident (the default) — in that
    /// state the system is bit-identical to the pre-residency model.
    residency: Option<ResidencyState>,
    /// Opt-in frame tracer `(sink, pid)`: when attached, every served
    /// request slice emits a span on its channel's [`Track::Channel`]
    /// timeline. Request order under the system lock is deterministic, so
    /// the emitted stream is bit-identical across host thread counts.
    tracer: Option<(TraceSink, u64)>,
}

impl MemorySystem {
    /// Build the system over `shard_map`. The map is the single source of
    /// truth for the shard count: `config.shards` is normalized to it so
    /// the channel array, the address translation, and every report agree.
    pub fn new(mut config: MemSimConfig, shard_map: ShardMap) -> MemorySystem {
        let group = config.dram.channels.max(1);
        config.shards = shard_map.shards.max(1);
        let total = config.shards * group;
        MemorySystem {
            channels: (0..total).map(|_| Channel::new()).collect(),
            svc_ns: vec![0.0; group],
            svc_bursts: vec![0; group],
            svc_rows: vec![0; group],
            config,
            shard_map,
            ports: Vec::new(),
            residency: None,
            tracer: None,
        }
    }

    /// Attach an opt-in frame tracer: every subsequently served request
    /// slice emits a DRAM transaction span on its channel's track under
    /// `pid`. Lock ordering is system → tracer (the caller holds the
    /// system lock while requests are served); never lock the system while
    /// holding the tracer.
    pub fn set_tracer(&mut self, sink: TraceSink, pid: u64) {
        self.tracer = Some((sink, pid));
    }

    /// Attach the residency layer: DRAM becomes a page-granular cache over
    /// `store`. No-op (fully resident, zero model change) when residency is
    /// disabled in the config or the configured capacity already holds the
    /// whole scene span.
    pub fn attach_residency(&mut self, store: &Arc<CompressedStore>) {
        let cfg = &self.config.residency;
        if !cfg.enabled() || cfg.capacity_bytes() >= store.span_bytes() {
            self.residency = None;
            return;
        }
        self.residency = Some(ResidencyState::new(cfg, Arc::clone(store)));
    }

    /// Is a residency layer attached (i.e. can reads page)?
    pub fn residency_attached(&self) -> bool {
        self.residency.is_some()
    }

    /// Residency snapshot for reports; `None` when fully resident.
    pub fn residency_stats(&self) -> Option<ResidencyReport> {
        self.residency.as_ref().map(|r| r.report())
    }

    /// Background-fill `pages` on behalf of `port` (sorted, deduplicated
    /// page indices from a [`ResidencyPrefetcher`](super::residency::ResidencyPrefetcher)).
    /// Already-resident pages only get their recency refreshed; fills that
    /// would evict a recently-touched page are skipped (thrash guard).
    pub fn residency_prefetch(&mut self, port: PortId, pages: &[usize]) {
        let Some(mut r) = self.residency.take() else { return };
        for &page in pages {
            if page >= r.store().n_pages() {
                continue;
            }
            if r.is_resident(page) {
                r.refresh(page);
            } else {
                self.fill_page(&mut r, port, page, false);
            }
        }
        self.residency = Some(r);
    }

    /// The demand-side residency hook: every non-paging request touches the
    /// pages its byte span covers; misses stall the issuing port with a
    /// demand fill. Runs in deterministic request order (the caller holds
    /// the system lock), so hit/miss/eviction sequences are bit-identical
    /// across thread counts.
    fn residency_touch(&mut self, port: PortId, addr: u64, bytes: u64) {
        let Some(mut r) = self.residency.take() else { return };
        let first = r.store().page_of(addr);
        let last = r.store().page_of(addr + bytes - 1);
        for page in first..=last {
            if r.is_resident(page) {
                r.note_hit(page);
            } else {
                r.stats.misses += 1;
                self.fill_page(&mut r, port, page, true);
            }
        }
        self.residency = Some(r);
    }

    /// Fetch one page into DRAM: evict while at capacity (charging the
    /// victim write-back as paging traffic), then charge the fill read over
    /// the page's uncompressed span. Demand fills account the paging busy
    /// delta plus the modeled decode time as stall; prefetch fills are
    /// background traffic (and bail out instead of evicting hot pages).
    fn fill_page(&mut self, r: &mut ResidencyState, port: PortId, page: usize, demand: bool) {
        let pre = self.port_stage_stats(port, MemStage::Paging).busy_ns;
        while r.at_capacity() {
            let Some(victim) = r.evict_victim(demand) else { return };
            let (a, b) = r.store().page_span(victim);
            if b > a {
                self.read(port, MemStage::Paging, a, b - a);
            }
        }
        let (a, b) = r.store().page_span(page);
        if b > a {
            self.read(port, MemStage::Paging, a, b - a);
        }
        let busy_delta = self.port_stage_stats(port, MemStage::Paging).busy_ns - pre;
        r.complete_fill(page, demand, busy_delta);
    }

    /// Register a new request port (one per stage per viewer). Ports
    /// registered after simulation started join at the current horizon,
    /// never in the past.
    pub fn register_port(&mut self) -> PortId {
        let at = self.horizon_ns();
        self.ports.push(PortState::new(at));
        self.ports.len() - 1
    }

    pub fn n_ports(&self) -> usize {
        self.ports.len()
    }

    /// Ports still eligible to issue traffic (registered, not retired).
    pub fn n_active_ports(&self) -> usize {
        self.ports.iter().filter(|p| !p.retired).count()
    }

    /// Retire a port at the end of its session: in-flight transactions are
    /// dropped from the issue window (their channel occupancy has already
    /// been charged), the port stops participating in epoch barriers, and
    /// any later read on it is a logic error. Cumulative statistics stay
    /// readable — the final session report is assembled after retirement.
    pub fn retire_port(&mut self, port: PortId) {
        let p = &mut self.ports[port];
        p.inflight.clear();
        p.retired = true;
    }

    /// Has `port` been retired?
    pub fn port_retired(&self, port: PortId) -> bool {
        self.ports[port].retired
    }

    pub fn n_channels(&self) -> usize {
        self.channels.len()
    }

    /// Read `bytes` at `addr` on behalf of `port`/`stage`, splitting at
    /// shard boundaries.
    pub fn read(&mut self, port: PortId, stage: MemStage, addr: u64, bytes: u64) {
        if bytes == 0 {
            return;
        }
        if stage != MemStage::Paging && stage != MemStage::Update {
            self.residency_touch(port, addr, bytes);
        }
        let map = self.shard_map;
        map.split(addr, bytes, |shard, a, b| {
            self.submit(port, MemRequest { addr: a, bytes: b, stage, shard });
        });
    }

    /// Submit one shard-local request to its channel group's queues.
    pub fn submit(&mut self, port: PortId, req: MemRequest) {
        if req.bytes == 0 {
            return;
        }
        let cfg = self.config.dram;
        let group = cfg.channels.max(1);
        let base_ch = req.shard.min(self.shard_map.shards - 1) * group;
        let outstanding = self.config.outstanding.max(1);
        let stage = req.stage.idx();

        let first_burst = req.addr / cfg.burst_bytes;
        let last_burst = (req.addr + req.bytes - 1) / cfg.burst_bytes;
        let n_bursts = last_burst - first_burst + 1;
        let bursts_per_row = cfg.row_bytes / cfg.burst_bytes;

        // ---- issue time: the outstanding-transaction window -------------
        let issue = {
            let p = &mut self.ports[port];
            debug_assert!(!p.retired, "read on retired port {port}");
            let mut issue = p.now_ns;
            if p.inflight.len() >= outstanding {
                if let Some(oldest) = p.inflight.pop_front() {
                    if oldest > issue {
                        issue = oldest;
                    }
                }
            }
            p.now_ns = issue;
            issue
        };

        // ---- service: row-buffer walk over the shard's channel group ----
        // Per-channel service time of this request lands in `svc_ns`;
        // hit/miss counts and energy accumulate into the locals below in
        // the same order the synchronous oracle uses (bit-exactness with
        // one channel per group).
        let channels = &mut self.channels;
        let svc_ns = &mut self.svc_ns;
        for v in svc_ns.iter_mut() {
            *v = 0.0;
        }
        let mut hits = 0u64;
        let mut misses = 0u64;
        let mut pj = 0.0f64;
        if n_bursts > 4 * bursts_per_row {
            // Analytic fast path (mirrors the oracle): one activation per
            // row touched, rows striped row-wise over the group.
            let first_row = (first_burst * cfg.burst_bytes) / cfg.row_bytes;
            let last_row = (last_burst * cfg.burst_bytes) / cfg.row_bytes;
            let g = group as u64;
            let svc_bursts = &mut self.svc_bursts;
            let svc_rows = &mut self.svc_rows;
            for c in 0..group {
                // Rows r in [first_row, last_row] with r % g == c.
                let c64 = c as u64;
                let offset = (c64 + g - (first_row % g)) % g;
                let first_c = first_row + offset;
                let rows_c =
                    if first_c > last_row { 0 } else { (last_row - first_c) / g + 1 };
                svc_rows[c] = rows_c;
                svc_bursts[c] = rows_c * bursts_per_row;
            }
            // The first and last rows are only partially covered.
            let lead = first_burst % bursts_per_row;
            let tail = bursts_per_row - 1 - (last_burst % bursts_per_row);
            svc_bursts[(first_row % g) as usize] -= lead;
            svc_bursts[(last_row % g) as usize] -= tail;
            for c in 0..group {
                let rows_c = svc_rows[c];
                let bursts_c = svc_bursts[c];
                if bursts_c == 0 {
                    continue;
                }
                misses += rows_c;
                hits += bursts_c - rows_c;
                svc_ns[c] = rows_c as f64 * (cfg.t_rp_ns + cfg.t_rcd_ns)
                    + bursts_c as f64 * cfg.t_burst_ns;
                pj += rows_c as f64 * cfg.e_activate_pj
                    + bursts_c as f64 * cfg.e_access_pj_per_bit * (cfg.burst_bytes * 8) as f64;
                // Leave the channel's open row as the last row it serves.
                let c64 = c as u64;
                let last_c = last_row - ((last_row % g) + g - c64) % g;
                if last_c >= first_row {
                    channels[base_ch + c].open_row = Some(last_c);
                }
            }
        } else {
            for b in first_burst..=last_burst {
                let byte_addr = b * cfg.burst_bytes;
                let row = byte_addr / cfg.row_bytes;
                let c = (row as usize) % group;
                let ch = &mut channels[base_ch + c];
                if ch.open_row == Some(row) {
                    hits += 1;
                } else {
                    misses += 1;
                    ch.open_row = Some(row);
                    svc_ns[c] += cfg.t_rp_ns + cfg.t_rcd_ns;
                    pj += cfg.e_activate_pj;
                }
                svc_ns[c] += cfg.t_burst_ns;
                pj += cfg.e_access_pj_per_bit * (cfg.burst_bytes * 8) as f64;
            }
        }

        // ---- queueing: arrival-ordered FIFO per channel -----------------
        let base = {
            let p = &self.ports[port];
            if p.last_completion_ns > issue { p.last_completion_ns } else { issue }
        };
        let mut completion = issue;
        let mut wait = 0.0f64;
        let mut involved = 0usize;
        let mut single_ns = 0.0f64;
        let mut single_start = issue;
        // Channel transaction spans for the tracer: collected locally
        // (the channel array is mutably borrowed here), emitted once the
        // request's wait attribution is known. No allocation unless a
        // tracer is attached.
        let mut spans: Option<Vec<(usize, f64, f64)>> = self.tracer.is_some().then(Vec::new);
        for c in 0..group {
            let ns = svc_ns[c];
            if ns <= 0.0 {
                continue;
            }
            let ch = &mut channels[base_ch + c];
            let start = if ch.free_at_ns > issue { ch.free_at_ns } else { issue };
            let comp = start + ns;
            ch.free_at_ns = comp;
            ch.service_ns += ns;
            ch.served += 1;
            if let Some(spans) = &mut spans {
                spans.push((base_ch + c, start, ns));
            }
            // Contention wait: channel busy time beyond this port's own
            // completion horizon (`base`). Queueing behind the port's own
            // earlier in-flight transactions is pipelining, not
            // contention — an isolated stream waits for nothing at any
            // `outstanding` setting.
            if start - base > wait {
                wait = start - base;
            }
            if comp > completion {
                completion = comp;
            }
            involved += 1;
            single_ns = ns;
            single_start = start;
        }
        // Union-of-intervals busy increment. The single-channel sequential
        // case is computed as (start − base) + service so the no-wait path
        // stays exactly equal to the service time (oracle bit-identity).
        let busy_inc = if involved == 1 {
            let lead = single_start - base;
            if lead >= 0.0 {
                lead + single_ns
            } else {
                let inc = (single_start + single_ns) - base;
                if inc > 0.0 { inc } else { 0.0 }
            }
        } else {
            let inc = completion - base;
            if inc > 0.0 { inc } else { 0.0 }
        };

        // ---- retire into port statistics --------------------------------
        let p = &mut self.ports[port];
        p.inflight.push_back(completion);
        if completion > p.last_completion_ns {
            p.last_completion_ns = completion;
        }
        if issue < p.first_issue_ns[stage] {
            p.first_issue_ns[stage] = issue;
        }
        if completion > p.last_completion_stage_ns[stage] {
            p.last_completion_stage_ns[stage] = completion;
        }
        let s = &mut p.stats[stage];
        s.reads += 1;
        s.bursts += n_bursts;
        s.bytes += n_bursts * cfg.burst_bytes;
        s.row_hits += hits;
        s.row_misses += misses;
        s.energy_pj += pj;
        s.busy_ns += busy_inc;
        s.wait_ns += wait;
        if wait > 0.0 {
            s.stalls += 1;
        }

        // Emit the collected channel spans (system → tracer lock order;
        // the caller already holds the system lock).
        if let Some(spans) = spans {
            if let Some((sink, pid)) = &self.tracer {
                let mut tr = sink.lock().expect("tracer lock poisoned");
                for (ch, start, ns) in spans {
                    tr.span(
                        *pid,
                        Track::Channel(ch),
                        req.stage.label(),
                        "dram",
                        start,
                        ns,
                        vec![
                            ("port", Json::from(port as u64)),
                            ("bytes", Json::from(req.bytes)),
                            ("wait_ns", Json::from(wait)),
                        ],
                    );
                }
            }
        }
    }

    /// Global completion horizon: the latest simulated time any channel or
    /// port has reached.
    pub fn horizon_ns(&self) -> f64 {
        let mut h = 0.0f64;
        for ch in &self.channels {
            if ch.free_at_ns > h {
                h = ch.free_at_ns;
            }
        }
        for p in &self.ports {
            if p.last_completion_ns > h {
                h = p.last_completion_ns;
            }
        }
        h
    }

    /// Frame barrier: advance every port clock to the completion horizon
    /// (all in-flight transactions retire). Returns the new epoch time.
    pub fn advance_epoch(&mut self) -> f64 {
        let epoch = self.horizon_ns();
        for p in &mut self.ports {
            if p.retired {
                continue;
            }
            p.now_ns = epoch;
            p.inflight.clear();
        }
        epoch
    }

    /// Cumulative statistics of one port's stage stream.
    pub fn port_stage_stats(&self, port: PortId, stage: MemStage) -> DramStats {
        self.ports[port].stats[stage.idx()]
    }

    /// Per-stage (first issue, last completion) span of a port: the
    /// overlap-aware window on the simulated timeline during which the
    /// stage's requests were in flight. `(0, 0)` before any traffic.
    pub fn port_stage_span(&self, port: PortId, stage: MemStage) -> (f64, f64) {
        let p = &self.ports[port];
        let i = stage.idx();
        if p.first_issue_ns[i].is_finite() {
            (p.first_issue_ns[i], p.last_completion_stage_ns[i])
        } else {
            (0.0, 0.0)
        }
    }

    /// Cumulative service occupancy per channel (ns).
    pub fn channel_service_ns(&self) -> Vec<f64> {
        self.channels.iter().map(|c| c.service_ns).collect()
    }

    /// Requests (or shard-split request slices) served per channel.
    pub fn channel_served(&self) -> Vec<u64> {
        self.channels.iter().map(|c| c.served).collect()
    }

    /// Per-channel utilization over the simulated makespan (0 when idle).
    pub fn channel_utilization(&self) -> Vec<f64> {
        let makespan = self.horizon_ns();
        if makespan <= 0.0 {
            return vec![0.0; self.channels.len()];
        }
        self.channels.iter().map(|c| c.service_ns / makespan).collect()
    }
}

/// The stage-facing request handle: either a private synchronous model
/// (the determinism baseline) or a registered port of a shared event-queue
/// [`MemorySystem`].
#[derive(Debug)]
pub struct MemPort {
    stage: MemStage,
    backend: PortBackend,
    /// Snapshot taken by `begin_frame` (shared backend): frame statistics
    /// are reported as deltas so channel state persists across frames.
    frame_base: DramStats,
    /// `begin_frame` snapshot of this port's [`MemStage::Paging`] stream —
    /// residency traffic the port's demand reads triggered this frame.
    frame_base_paging: DramStats,
    /// Lifetime totals of frames already retired by `begin_frame`
    /// (synchronous backend only — the model itself resets per frame).
    sync_lifetime: DramStats,
    /// Prefetch page lists recorded by a trace backend this frame, for the
    /// coordinator to replay before the frame's demand trace.
    trace_prefetch: Vec<usize>,
}

#[derive(Debug)]
enum PortBackend {
    Sync(SyncDramModel),
    Shared { sys: Arc<Mutex<MemorySystem>>, id: PortId },
    /// Record `(addr, bytes)` requests instead of simulating them — the
    /// capture side of the two-phase contended batch: frames render in
    /// parallel against trace ports, then the coordinator replays each
    /// frame's trace into the shared `MemorySystem` in the deterministic
    /// lockstep order. Statistics report zero until replayed.
    Trace(Vec<(u64, u64)>),
}

impl MemPort {
    /// Private synchronous backend (bit-identical to the pre-refactor
    /// per-stage `DramModel`).
    pub fn sync(config: DramConfig, stage: MemStage) -> MemPort {
        MemPort {
            stage,
            backend: PortBackend::Sync(SyncDramModel::new(config)),
            frame_base: DramStats::default(),
            frame_base_paging: DramStats::default(),
            sync_lifetime: DramStats::default(),
            trace_prefetch: Vec::new(),
        }
    }

    /// Trace-recording backend (see [`PortBackend::Trace`]).
    pub fn trace(stage: MemStage) -> MemPort {
        MemPort {
            stage,
            backend: PortBackend::Trace(Vec::new()),
            frame_base: DramStats::default(),
            frame_base_paging: DramStats::default(),
            sync_lifetime: DramStats::default(),
            trace_prefetch: Vec::new(),
        }
    }

    /// Drain the recorded request trace (empty for non-trace backends).
    /// `begin_frame` also clears it, so after a frame this returns exactly
    /// that frame's requests in issue order.
    pub fn take_trace(&mut self) -> Vec<(u64, u64)> {
        match &mut self.backend {
            PortBackend::Trace(log) => std::mem::take(log),
            _ => Vec::new(),
        }
    }

    /// Register a new port on a shared event-queue system.
    pub fn shared(sys: &Arc<Mutex<MemorySystem>>, stage: MemStage) -> MemPort {
        let id = sys.lock().expect("memory system lock poisoned").register_port();
        MemPort {
            stage,
            backend: PortBackend::Shared { sys: Arc::clone(sys), id },
            frame_base: DramStats::default(),
            frame_base_paging: DramStats::default(),
            sync_lifetime: DramStats::default(),
            trace_prefetch: Vec::new(),
        }
    }

    pub fn stage(&self) -> MemStage {
        self.stage
    }

    /// The registered [`PortId`] on the shared event-queue system (None
    /// for a private synchronous backend). This is how owners of a shared
    /// `MemorySystem` (the contended batch) map ports back to viewers
    /// without assuming a registration order.
    pub fn shared_id(&self) -> Option<PortId> {
        match &self.backend {
            PortBackend::Shared { id, .. } => Some(*id),
            PortBackend::Sync(_) | PortBackend::Trace(_) => None,
        }
    }

    /// Start a new frame: the synchronous backend resets (cold rows, zero
    /// stats — the pre-refactor per-frame contract); the shared backend
    /// snapshots cumulative statistics and keeps all channel state.
    pub fn begin_frame(&mut self) {
        let stage = self.stage;
        match &mut self.backend {
            PortBackend::Sync(m) => {
                self.sync_lifetime.add(&m.stats());
                m.reset();
            }
            PortBackend::Shared { sys, id } => {
                let sys = sys.lock().expect("memory system lock poisoned");
                self.frame_base = sys.port_stage_stats(*id, stage);
                self.frame_base_paging = sys.port_stage_stats(*id, MemStage::Paging);
            }
            PortBackend::Trace(log) => {
                log.clear();
                self.trace_prefetch.clear();
            }
        }
    }

    /// Hand a prefetch page list to the memory system (shared backend) or
    /// record it for replay (trace backend). No-op on the synchronous
    /// backend, which has no residency layer.
    pub fn prefetch(&mut self, pages: &[usize]) {
        match &mut self.backend {
            PortBackend::Sync(_) => {}
            PortBackend::Shared { sys, id } => sys
                .lock()
                .expect("memory system lock poisoned")
                .residency_prefetch(*id, pages),
            PortBackend::Trace(_) => self.trace_prefetch.extend_from_slice(pages),
        }
    }

    /// Drain the recorded prefetch list (trace backend; empty otherwise).
    pub fn take_prefetch(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.trace_prefetch)
    }

    /// Paging traffic this port's requests triggered since the last
    /// `begin_frame` (shared backend; zero otherwise — the synchronous
    /// backend never pages and trace ports report zero until replayed).
    pub fn paging_stats(&self) -> DramStats {
        match &self.backend {
            PortBackend::Shared { sys, id } => sys
                .lock()
                .expect("memory system lock poisoned")
                .port_stage_stats(*id, MemStage::Paging)
                .delta(&self.frame_base_paging),
            PortBackend::Sync(_) | PortBackend::Trace(_) => DramStats::default(),
        }
    }

    /// Issue a read on this port.
    pub fn read(&mut self, addr: u64, bytes: u64) {
        let stage = self.stage;
        match &mut self.backend {
            PortBackend::Sync(m) => m.read(addr, bytes),
            PortBackend::Shared { sys, id } => sys
                .lock()
                .expect("memory system lock poisoned")
                .read(*id, stage, addr, bytes),
            PortBackend::Trace(log) => log.push((addr, bytes)),
        }
    }

    /// Statistics since the last `begin_frame` (or construction).
    pub fn stats(&self) -> DramStats {
        match &self.backend {
            PortBackend::Sync(m) => m.stats(),
            PortBackend::Shared { sys, id } => sys
                .lock()
                .expect("memory system lock poisoned")
                .port_stage_stats(*id, self.stage)
                .delta(&self.frame_base),
            PortBackend::Trace(_) => DramStats::default(),
        }
    }

    /// Cumulative statistics across the port's lifetime (both simulating
    /// backends: every frame ever issued, not just the one since
    /// `begin_frame`; zero for trace ports).
    pub fn cumulative(&self) -> DramStats {
        match &self.backend {
            PortBackend::Sync(m) => {
                let mut s = self.sync_lifetime;
                s.add(&m.stats());
                s
            }
            PortBackend::Shared { sys, id } => sys
                .lock()
                .expect("memory system lock poisoned")
                .port_stage_stats(*id, self.stage),
            PortBackend::Trace(_) => DramStats::default(),
        }
    }
}

impl MemSink for MemPort {
    fn read(&mut self, addr: u64, bytes: u64) {
        MemPort::read(self, addr, bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn oracle_sys() -> MemorySystem {
        let cfg = MemSimConfig::oracle_point();
        let map = ShardMap::single(1 << 24);
        MemorySystem::new(cfg, map)
    }

    #[test]
    fn isolated_sequential_stream_never_waits() {
        let mut sys = oracle_sys();
        let p = sys.register_port();
        for i in 0..64u64 {
            sys.read(p, MemStage::Preprocess, i * 4096, 128);
        }
        let s = sys.port_stage_stats(p, MemStage::Preprocess);
        assert_eq!(s.reads, 64);
        assert_eq!(s.wait_ns, 0.0);
        assert_eq!(s.stalls, 0);
        assert!(s.busy_ns > 0.0);
    }

    #[test]
    fn isolated_stream_with_deep_outstanding_window_never_waits() {
        // Queueing behind one's own in-flight transactions is pipelining,
        // not contention: at any `outstanding` depth an isolated stream
        // must report zero wait/stalls.
        let cfg = MemSimConfig {
            mode: MemMode::EventQueue,
            dram: DramConfig { channels: 2, ..DramConfig::default() },
            outstanding: 8,
            shards: 1,
            ..MemSimConfig::default()
        };
        let mut sys = MemorySystem::new(cfg, ShardMap::single(1 << 24));
        let p = sys.register_port();
        for i in 0..128u64 {
            sys.read(p, MemStage::Preprocess, i * 2048 * 3, 64);
        }
        let s = sys.port_stage_stats(p, MemStage::Preprocess);
        assert_eq!(s.wait_ns, 0.0, "self-queueing must not count as contention");
        assert_eq!(s.stalls, 0);
        assert!(s.busy_ns > 0.0);
        // The stage span is the overlap-aware in-flight window: with two
        // channels and a deep outstanding window it is shorter than the
        // serial service sum but never shorter than the busy union.
        let (first, last) = sys.port_stage_span(p, MemStage::Preprocess);
        assert_eq!(first, 0.0);
        assert_eq!(last, sys.horizon_ns());
        assert!(last - first >= s.busy_ns - 1e-9);
    }

    #[test]
    fn contending_ports_keep_their_byte_counts_but_pay_in_time() {
        let cfg = MemSimConfig {
            mode: MemMode::EventQueue,
            dram: DramConfig { channels: 2, ..DramConfig::default() },
            outstanding: 4,
            shards: 1,
            ..MemSimConfig::default()
        };
        let mk = || MemorySystem::new(cfg.clone(), ShardMap::single(1 << 24));

        // Isolated: each stream alone on its own system.
        let mut iso_a = mk();
        let mut iso_b = mk();
        let pa = iso_a.register_port();
        let pb = iso_b.register_port();
        for i in 0..128u64 {
            iso_a.read(pa, MemStage::Preprocess, i * 2048 * 3, 64);
        }
        for i in 0..128u64 {
            iso_b.read(pb, MemStage::Blend, (i + 7) * 2048 * 5, 64);
        }
        let a_alone = iso_a.port_stage_stats(pa, MemStage::Preprocess);
        let b_alone = iso_b.port_stage_stats(pb, MemStage::Blend);
        assert_eq!(a_alone.wait_ns, 0.0);
        assert_eq!(b_alone.wait_ns, 0.0);

        // Shared: B's stream lands while A's traffic still occupies the
        // channels (both ports join at epoch 0 — the lockstep-round
        // arrival model).
        let mut sys = mk();
        let qa = sys.register_port();
        let qb = sys.register_port();
        for i in 0..128u64 {
            sys.read(qa, MemStage::Preprocess, i * 2048 * 3, 64);
        }
        for i in 0..128u64 {
            sys.read(qb, MemStage::Blend, (i + 7) * 2048 * 5, 64);
        }
        let a_shared = sys.port_stage_stats(qa, MemStage::Preprocess);
        let b_shared = sys.port_stage_stats(qb, MemStage::Blend);

        // Addresses are timing-independent: transfer counts identical.
        assert_eq!(a_shared.bytes, a_alone.bytes);
        assert_eq!(a_shared.bursts, a_alone.bursts);
        assert_eq!(b_shared.bytes, b_alone.bytes);
        assert_eq!(b_shared.bursts, b_alone.bursts);
        // Contention is port-attributed: A (first in) waits for nothing;
        // B queues behind A's backlog beyond its own horizon.
        assert_eq!(a_shared.wait_ns, 0.0);
        assert!(b_shared.wait_ns > 0.0, "port B should queue behind A");
        assert!(b_shared.stalls > 0);
        assert!(
            a_shared.busy_ns + b_shared.busy_ns > a_alone.busy_ns + b_alone.busy_ns,
            "shared busy {} + {} vs isolated {} + {}",
            a_shared.busy_ns,
            b_shared.busy_ns,
            a_alone.busy_ns,
            b_alone.busy_ns
        );
    }

    #[test]
    fn retired_ports_keep_stats_and_skip_epochs() {
        let mut sys = oracle_sys();
        let a = sys.register_port();
        let b = sys.register_port();
        sys.read(a, MemStage::Preprocess, 0, 4096);
        sys.read(b, MemStage::Blend, 1 << 16, 4096);
        let a_stats = sys.port_stage_stats(a, MemStage::Preprocess);
        assert!(a_stats.bytes > 0);
        assert_eq!(sys.n_active_ports(), 2);

        // A session departs mid-stream: its port retires, its stats stay.
        sys.retire_port(a);
        assert!(sys.port_retired(a));
        assert!(!sys.port_retired(b));
        assert_eq!(sys.n_active_ports(), 1);
        assert_eq!(sys.port_stage_stats(a, MemStage::Preprocess), a_stats);

        // Epoch barriers keep pacing the survivors; the retired port's
        // horizon contribution (past traffic) is still real.
        let h = sys.horizon_ns();
        let epoch = sys.advance_epoch();
        assert_eq!(epoch, h);
        sys.read(b, MemStage::Blend, 1 << 17, 4096);
        assert!(sys.port_stage_stats(b, MemStage::Blend).bytes > 4096);
        assert_eq!(sys.port_stage_stats(a, MemStage::Preprocess), a_stats);
    }

    #[test]
    fn advance_epoch_aligns_ports_to_horizon() {
        let mut sys = oracle_sys();
        let p = sys.register_port();
        sys.read(p, MemStage::Preprocess, 0, 1 << 16);
        let h = sys.horizon_ns();
        assert!(h > 0.0);
        let epoch = sys.advance_epoch();
        assert_eq!(epoch, h);
        // A port registered after traffic joins at the horizon, not at 0.
        let q = sys.register_port();
        sys.read(q, MemStage::Blend, 0, 64);
        let s = sys.port_stage_stats(q, MemStage::Blend);
        assert_eq!(s.wait_ns, 0.0, "fresh port must not see stale horizons as waits");
    }

    #[test]
    fn shard_split_preserves_totals() {
        let cfg = MemSimConfig {
            mode: MemMode::EventQueue,
            dram: DramConfig { channels: 1, ..DramConfig::default() },
            outstanding: 1,
            shards: 4,
            ..MemSimConfig::default()
        };
        let map = ShardMap::build(1 << 20, 4, 2048);
        let mut sys = MemorySystem::new(cfg, map);
        assert_eq!(sys.n_channels(), 4);
        let p = sys.register_port();
        // One read spanning all four shards.
        let bytes = map.shard_bytes * 3;
        sys.read(p, MemStage::Preprocess, map.shard_bytes / 2, bytes);
        let s = sys.port_stage_stats(p, MemStage::Preprocess);
        assert_eq!(s.bytes, bytes); // burst-aligned addresses: exact
        assert!(s.reads >= 4, "split into at least one piece per shard");
        // All four channel groups saw traffic (one request slice each).
        let svc = sys.channel_service_ns();
        assert!(svc.iter().all(|&v| v > 0.0), "service {svc:?}");
        assert!(sys.channel_served().iter().all(|&n| n >= 1));
    }

    #[test]
    fn more_channels_per_group_shorten_busy_time() {
        let mk = |channels: usize| {
            let cfg = MemSimConfig {
                mode: MemMode::EventQueue,
                dram: DramConfig { channels, ..DramConfig::default() },
                outstanding: 4,
                shards: 1,
                ..MemSimConfig::default()
            };
            MemorySystem::new(cfg, ShardMap::single(1 << 24))
        };
        let mut one = mk(1);
        let mut four = mk(4);
        let p1 = one.register_port();
        let p4 = four.register_port();
        one.read(p1, MemStage::Preprocess, 0, 1 << 20);
        four.read(p4, MemStage::Preprocess, 0, 1 << 20);
        let s1 = one.port_stage_stats(p1, MemStage::Preprocess);
        let s4 = four.port_stage_stats(p4, MemStage::Preprocess);
        assert_eq!(s1.bytes, s4.bytes);
        assert!(
            s4.busy_ns < s1.busy_ns / 2.0,
            "4-channel sweep {} should be well under half the 1-channel {}",
            s4.busy_ns,
            s1.busy_ns
        );
    }

    #[test]
    fn sync_port_cumulative_spans_frames() {
        let mut port = MemPort::sync(DramConfig::default(), MemStage::Preprocess);
        assert_eq!(port.shared_id(), None);
        port.begin_frame();
        port.read(0, 4096);
        assert_eq!(port.stats().bytes, 4096);
        port.begin_frame();
        port.read(0, 1024);
        // Frame stats are the current frame; cumulative covers every frame.
        assert_eq!(port.stats().bytes, 1024);
        assert_eq!(port.cumulative().bytes, 4096 + 1024);
        assert_eq!(port.cumulative().reads, 2);
    }

    #[test]
    fn trace_port_records_requests_and_reports_zero_stats() {
        let mut p = MemPort::trace(MemStage::Blend);
        assert_eq!(p.shared_id(), None);
        p.begin_frame();
        p.read(64, 128);
        p.read(4096, 32);
        assert_eq!(p.stats(), DramStats::default());
        assert_eq!(p.cumulative(), DramStats::default());
        assert_eq!(p.take_trace(), vec![(64, 128), (4096, 32)]);
        assert!(p.take_trace().is_empty(), "take_trace drains");
        p.begin_frame();
        p.read(1, 2);
        p.begin_frame();
        assert!(p.take_trace().is_empty(), "begin_frame clears the frame trace");
    }

    #[test]
    fn mem_port_frame_delta_reporting() {
        let sys = Arc::new(Mutex::new(MemorySystem::new(
            MemSimConfig::event_queue(),
            ShardMap::single(1 << 20),
        )));
        let mut port = MemPort::shared(&sys, MemStage::Blend);
        port.begin_frame();
        port.read(0, 4096);
        let f1 = port.stats();
        assert_eq!(f1.bytes, 4096);
        port.begin_frame();
        assert_eq!(port.stats(), DramStats::default());
        port.read(0, 1024);
        assert_eq!(port.stats().bytes, 1024);
        assert_eq!(port.cumulative().bytes, 4096 + 1024);
    }
}
