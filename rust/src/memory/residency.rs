//! Streaming scene residency: DRAM as a page-granular cache over the
//! compressed backing store (`scene::compressed`).
//!
//! When a [`ResidencyConfig`] caps DRAM capacity below the scene span, the
//! event-queue [`MemorySystem`](super::event_queue::MemorySystem) routes
//! every cull/blend request through a [`ResidencyState`] page table first:
//! a touched non-resident page triggers a *demand fill* — an eviction
//! (clock or cost-aware victim) plus a fill transaction, both charged to
//! the issuing port on the [`MemStage::Paging`](super::event_queue::MemStage)
//! stream so contention, fairness, and latency percentiles see the paging
//! traffic. Demand fills additionally model the backing-store decode cost
//! (`compressed bytes × decode_ns_per_byte`) as stall time.
//!
//! [`ResidencyPrefetcher`] turns misses into background fills: the
//! `NextFrameCull` policy replays the previous frame's visible-cell pages;
//! `TrajectoryLookahead{k}` extrapolates the camera path and frustum-tests
//! grid cells for the next `k` frames (with a zero-velocity fallback on
//! the first frame, so a still camera prefetches exactly its own working
//! set). Prefetch fills never evict recently-touched pages (thrash guard)
//! and are not counted as misses.
//!
//! **Determinism:** every decision is a pure function of the request
//! stream and the camera path — both byte-identical across thread counts
//! (lockstep and two-phase replay drive the same deterministic order), so
//! residency statistics inherit the repo-wide thread-matrix contract.

use std::sync::Arc;

use crate::camera::Camera;
use crate::culling::{Containment, GridPartition};
use crate::math::Vec3;
use crate::scene::CompressedStore;
use crate::util::json::Json;

/// Which pages to pull ahead of demand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefetchPolicy {
    /// Demand paging only.
    None,
    /// Prefetch the pages of the cells the *previous* frame's cull pass
    /// found visible.
    NextFrameCull,
    /// Extrapolate the camera path (position + forward, linear) and
    /// prefetch the pages of every cell the next `k` predicted frames
    /// would cull in.
    TrajectoryLookahead { k: usize },
}

impl PrefetchPolicy {
    /// Parse a CLI/config label: `none`, `next-frame-cull`, `lookahead`
    /// (k = 2) or `lookahead:<k>`.
    pub fn from_label(s: &str) -> Option<PrefetchPolicy> {
        match s {
            "none" => Some(PrefetchPolicy::None),
            "next-frame-cull" => Some(PrefetchPolicy::NextFrameCull),
            "lookahead" => Some(PrefetchPolicy::TrajectoryLookahead { k: 2 }),
            _ => {
                let k = s.strip_prefix("lookahead:")?.parse::<usize>().ok()?;
                Some(PrefetchPolicy::TrajectoryLookahead { k: k.max(1) })
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            PrefetchPolicy::None => "none".into(),
            PrefetchPolicy::NextFrameCull => "next-frame-cull".into(),
            PrefetchPolicy::TrajectoryLookahead { k } => format!("lookahead:{k}"),
        }
    }
}

/// Victim choice when a fill needs space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Second-chance clock sweep over reference bits.
    Clock,
    /// Oldest last-touch first; ties broken by smallest compressed size
    /// (cheapest to re-fetch), then page index.
    CostAware,
}

impl EvictPolicy {
    pub fn from_label(s: &str) -> Option<EvictPolicy> {
        match s {
            "clock" => Some(EvictPolicy::Clock),
            "cost-aware" => Some(EvictPolicy::CostAware),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            EvictPolicy::Clock => "clock",
            EvictPolicy::CostAware => "cost-aware",
        }
    }
}

/// Residency configuration carried by
/// [`MemSimConfig`](super::event_queue::MemSimConfig). Disabled by default
/// (`capacity_mb = 0`): the scene is fully DRAM-resident and no paging
/// layer is attached, preserving pre-residency reports byte-for-byte.
#[derive(Debug, Clone, PartialEq)]
pub struct ResidencyConfig {
    /// DRAM capacity available to the scene, in MiB. `0` disables the
    /// residency layer; a capacity at or above the scene span is treated
    /// as fully resident (also no paging layer).
    pub capacity_mb: f64,
    /// Prefetch policy.
    pub policy: PrefetchPolicy,
    /// Page count to partition the scene span into (row-aligned).
    pub pages: usize,
    /// Eviction victim choice.
    pub evict: EvictPolicy,
    /// Modeled backing-store decode cost per *compressed* byte (ns).
    pub decode_ns_per_byte: f64,
}

impl Default for ResidencyConfig {
    fn default() -> Self {
        ResidencyConfig {
            capacity_mb: 0.0,
            policy: PrefetchPolicy::None,
            pages: 64,
            evict: EvictPolicy::Clock,
            decode_ns_per_byte: 0.25,
        }
    }
}

impl ResidencyConfig {
    /// Defaults with the `PALLAS_RESIDENCY_MB` environment override
    /// (mirrors `PALLAS_THREADS` / `PALLAS_RENDER_BACKEND`).
    pub fn from_env() -> ResidencyConfig {
        let mut cfg = ResidencyConfig::default();
        if let Ok(v) = std::env::var("PALLAS_RESIDENCY_MB") {
            if let Ok(mb) = v.trim().parse::<f64>() {
                cfg.capacity_mb = mb.max(0.0);
            }
        }
        cfg
    }

    /// Is the residency layer requested at all?
    pub fn enabled(&self) -> bool {
        self.capacity_mb > 0.0
    }

    /// Capacity in bytes (MiB-based).
    pub fn capacity_bytes(&self) -> u64 {
        (self.capacity_mb * (1u64 << 20) as f64) as u64
    }
}

/// Raw residency counters (all deterministic functions of the request
/// stream).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResidencyStats {
    /// Page touches that found the page resident.
    pub hits: u64,
    /// Page touches that required a demand fill.
    pub misses: u64,
    /// Pages evicted (demand + prefetch fills).
    pub evictions: u64,
    /// Fills triggered by a miss (stall the issuing stage).
    pub demand_fills: u64,
    /// Fills issued ahead of demand (background traffic).
    pub prefetch_fills: u64,
    /// Compressed bytes fetched from the backing store.
    pub fetched_compressed_bytes: u64,
    /// Time demand fills stalled the issuing stage: paging busy delta plus
    /// decode time (ns).
    pub stall_ns: f64,
    /// Modeled backing-store decode time, all fills (ns).
    pub decode_ns: f64,
}

impl ResidencyStats {
    /// Page-touch hit rate; 0 before any traffic.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Snapshot surfaced into reports (`contended_mem.residency` and the
/// `multi_viewer` residency sweep).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResidencyReport {
    pub stats: ResidencyStats,
    pub capacity_pages: usize,
    pub total_pages: usize,
    pub resident_pages: usize,
    pub page_size_bytes: u64,
    pub compression_ratio: f64,
}

impl ResidencyReport {
    /// Registry [`Component`](crate::obs::Component) of the residency
    /// roll-up: counters for the event counts, gauges for the rates and
    /// simulated times (keys unchanged from the pre-registry encoding).
    pub fn component(&self) -> crate::obs::Component {
        crate::obs::Component::new()
            .set("hits", self.stats.hits)
            .set("misses", self.stats.misses)
            .set("hit_rate", self.stats.hit_rate())
            .set("evictions", self.stats.evictions)
            .set("demand_fills", self.stats.demand_fills)
            .set("prefetch_fills", self.stats.prefetch_fills)
            .set("fetched_compressed_bytes", self.stats.fetched_compressed_bytes)
            .set("stall_ns", self.stats.stall_ns)
            .set("decode_ns", self.stats.decode_ns)
            .set("capacity_pages", self.capacity_pages)
            .set("total_pages", self.total_pages)
            .set("resident_pages", self.resident_pages)
            .set("page_size_bytes", self.page_size_bytes)
            .set("compression_ratio", self.compression_ratio)
    }

    pub fn to_json(&self) -> Json {
        self.component().to_json()
    }
}

/// The page table the event-queue memory system consults on every
/// non-paging request. Owned by `MemorySystem`; all mutation happens under
/// its lock, in deterministic request order.
#[derive(Debug)]
pub struct ResidencyState {
    store: Arc<CompressedStore>,
    evict: EvictPolicy,
    decode_ns_per_byte: f64,
    resident: Vec<bool>,
    /// Second-chance reference bits (set on touch/fill, cleared by the
    /// clock sweep; the prefetch thrash guard reads them under both
    /// eviction policies).
    ref_bit: Vec<bool>,
    /// Logical touch stamps for the cost-aware policy.
    last_touch: Vec<u64>,
    touch_counter: u64,
    hand: usize,
    capacity_pages: usize,
    n_resident: usize,
    pub stats: ResidencyStats,
}

impl ResidencyState {
    pub fn new(cfg: &ResidencyConfig, store: Arc<CompressedStore>) -> ResidencyState {
        let n = store.n_pages();
        let page = store.page_size().max(1);
        let capacity_pages = ((cfg.capacity_bytes() / page) as usize).clamp(1, n.max(1));
        ResidencyState {
            evict: cfg.evict,
            decode_ns_per_byte: cfg.decode_ns_per_byte,
            resident: vec![false; n],
            ref_bit: vec![false; n],
            last_touch: vec![0; n],
            touch_counter: 0,
            hand: 0,
            capacity_pages,
            n_resident: 0,
            store,
            stats: ResidencyStats::default(),
        }
    }

    pub fn store(&self) -> &Arc<CompressedStore> {
        &self.store
    }

    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }

    pub fn n_resident(&self) -> usize {
        self.n_resident
    }

    pub fn is_resident(&self, page: usize) -> bool {
        self.resident[page]
    }

    /// Record a resident touch: hit count + recency state.
    pub fn note_hit(&mut self, page: usize) {
        self.stats.hits += 1;
        self.touch(page);
    }

    /// Refresh recency without counting a demand touch (prefetch of an
    /// already-resident page keeps the working set warm but is neither a
    /// hit nor a miss).
    pub fn refresh(&mut self, page: usize) {
        self.touch(page);
    }

    /// Mark a page resident after its fill traffic was charged.
    /// `busy_delta_ns` is the paging busy time the fill added on the
    /// issuing port.
    pub fn complete_fill(&mut self, page: usize, demand: bool, busy_delta_ns: f64) {
        let compressed = self.store.page_compressed_bytes(page);
        let decode = compressed as f64 * self.decode_ns_per_byte;
        self.stats.decode_ns += decode;
        self.stats.fetched_compressed_bytes += compressed;
        if demand {
            self.stats.demand_fills += 1;
            self.stats.stall_ns += busy_delta_ns + decode;
        } else {
            self.stats.prefetch_fills += 1;
        }
        if !self.resident[page] {
            self.resident[page] = true;
            self.n_resident += 1;
        }
        self.touch(page);
    }

    /// Does a fill need an eviction first?
    pub fn at_capacity(&self) -> bool {
        self.n_resident >= self.capacity_pages
    }

    /// Pick and evict a victim page, returning it so the caller can charge
    /// the write-back transaction. Demand fills may evict anything;
    /// prefetch fills only evict pages with a clear reference bit (thrash
    /// guard) and return `None` when every resident page was recently
    /// touched.
    pub fn evict_victim(&mut self, demand: bool) -> Option<usize> {
        let victim = match self.evict {
            EvictPolicy::Clock => self.clock_victim(demand),
            EvictPolicy::CostAware => self.cost_victim(demand),
        }?;
        self.resident[victim] = false;
        self.ref_bit[victim] = false;
        self.n_resident -= 1;
        self.stats.evictions += 1;
        Some(victim)
    }

    pub fn report(&self) -> ResidencyReport {
        ResidencyReport {
            stats: self.stats,
            capacity_pages: self.capacity_pages,
            total_pages: self.store.n_pages(),
            resident_pages: self.n_resident,
            page_size_bytes: self.store.page_size(),
            compression_ratio: self.store.compression_ratio(),
        }
    }

    fn touch(&mut self, page: usize) {
        self.ref_bit[page] = true;
        self.touch_counter += 1;
        self.last_touch[page] = self.touch_counter;
    }

    fn clock_victim(&mut self, demand: bool) -> Option<usize> {
        let n = self.resident.len();
        if demand {
            // Second chance: first pass clears reference bits, so at most
            // two sweeps find a victim whenever anything is resident.
            for _ in 0..2 * n + 1 {
                let p = self.hand;
                self.hand = (self.hand + 1) % n;
                if !self.resident[p] {
                    continue;
                }
                if self.ref_bit[p] {
                    self.ref_bit[p] = false;
                    continue;
                }
                return Some(p);
            }
            None
        } else {
            // Thrash guard: scan without disturbing reference bits.
            for i in 0..n {
                let p = (self.hand + i) % n;
                if self.resident[p] && !self.ref_bit[p] {
                    self.hand = (p + 1) % n;
                    return Some(p);
                }
            }
            None
        }
    }

    fn cost_victim(&self, demand: bool) -> Option<usize> {
        let mut best: Option<(u64, u64, usize)> = None;
        for p in 0..self.resident.len() {
            if !self.resident[p] || (!demand && self.ref_bit[p]) {
                continue;
            }
            let key = (self.last_touch[p], self.store.page_compressed_bytes(p), p);
            if best.map(|b| key < b).unwrap_or(true) {
                best = Some(key);
            }
        }
        best.map(|(_, _, p)| p)
    }
}

/// Camera pose sample for trajectory extrapolation.
#[derive(Debug, Clone, Copy)]
struct CamSample {
    eye: Vec3,
    fwd: Vec3,
}

/// Host-side prefetch predictor. Lives in the pipeline's `FrameCtx` (so it
/// rides session detach/resume) and runs *before* the cull stage issues
/// demand reads; its page list is handed to the cull port, which either
/// issues the prefetch fills directly (lockstep) or records them for the
/// round engine's policy-ordered replay (two-phase). Prediction only reads
/// the camera path and the grid — never simulated timing — so both modes
/// see identical prefetch streams.
#[derive(Debug)]
pub struct ResidencyPrefetcher {
    policy: PrefetchPolicy,
    grid: Arc<GridPartition>,
    store: Arc<CompressedStore>,
    prev: Option<CamSample>,
    last_cull_pages: Vec<usize>,
    pages: Vec<usize>,
    cells: Vec<usize>,
}

impl ResidencyPrefetcher {
    pub fn new(
        policy: PrefetchPolicy,
        grid: Arc<GridPartition>,
        store: Arc<CompressedStore>,
    ) -> ResidencyPrefetcher {
        ResidencyPrefetcher {
            policy,
            grid,
            store,
            prev: None,
            last_cull_pages: Vec::new(),
            pages: Vec::new(),
            cells: Vec::new(),
        }
    }

    pub fn policy(&self) -> PrefetchPolicy {
        self.policy
    }

    /// Pages to prefetch for the frame about to render at `(cam, t)`.
    /// Sorted and deduplicated — a deterministic fill order.
    pub fn predict(&mut self, cam: &Camera, t: f32) -> &[usize] {
        match self.policy {
            PrefetchPolicy::None => &[],
            PrefetchPolicy::NextFrameCull => &self.last_cull_pages,
            PrefetchPolicy::TrajectoryLookahead { k } => {
                self.cells.clear();
                // Anchor step: the current pose. With no history this is
                // the zero-velocity fallback — a still camera prefetches
                // exactly the working set it is about to cull.
                visible_cells(&self.grid, cam, t, &mut self.cells);
                if let Some(p) = self.prev {
                    let eye = cam.position;
                    let fwd = forward_of(cam);
                    let up = up_of(cam);
                    let vel = eye - p.eye;
                    let dfw = fwd - p.fwd;
                    for i in 1..=k {
                        let s = i as f32;
                        let eye_i = eye + vel * s;
                        let mut fwd_i = fwd + dfw * s;
                        if fwd_i.length() < 1e-6 {
                            fwd_i = fwd;
                        }
                        let mut c = *cam;
                        c.set_pose(eye_i, eye_i + fwd_i, up);
                        visible_cells(&self.grid, &c, t, &mut self.cells);
                    }
                }
                self.pages.clear();
                for &flat in &self.cells {
                    for &p in self.store.cell_pages(flat) {
                        self.pages.push(p as usize);
                    }
                }
                self.pages.sort_unstable();
                self.pages.dedup();
                &self.pages
            }
        }
    }

    /// Record the frame that just culled at `(cam, t)`: its visible-cell
    /// pages (NextFrameCull) and its pose (trajectory history).
    pub fn observe(&mut self, cam: &Camera, t: f32) {
        match self.policy {
            PrefetchPolicy::None => {}
            PrefetchPolicy::NextFrameCull => {
                self.cells.clear();
                visible_cells(&self.grid, cam, t, &mut self.cells);
                self.last_cull_pages.clear();
                for &flat in &self.cells {
                    for &p in self.store.cell_pages(flat) {
                        self.last_cull_pages.push(p as usize);
                    }
                }
                self.last_cull_pages.sort_unstable();
                self.last_cull_pages.dedup();
            }
            PrefetchPolicy::TrajectoryLookahead { .. } => {
                self.prev = Some(CamSample { eye: cam.position, fwd: forward_of(cam) });
            }
        }
    }
}

fn forward_of(cam: &Camera) -> Vec3 {
    Vec3::new(cam.view.m[2][0], cam.view.m[2][1], cam.view.m[2][2])
}

fn up_of(cam: &Camera) -> Vec3 {
    Vec3::new(cam.view.m[1][0], cam.view.m[1][1], cam.view.m[1][2])
}

/// Non-empty grid cells of `t`'s temporal slice whose AABB intersects the
/// camera frustum — the same pass-1 test DR-FC culling schedules with.
fn visible_cells(grid: &GridPartition, cam: &Camera, t: f32, out: &mut Vec<usize>) {
    let frustum = cam.frustum();
    let cps = grid.config.cells_per_slice();
    let slice = {
        let (t0, t1) = grid.time_span;
        let n = grid.config.n_temporal;
        if n <= 1 || t1 <= t0 {
            0
        } else {
            let f = ((t - t0) / (t1 - t0)).clamp(0.0, 1.0);
            ((f * n as f32) as usize).min(n - 1)
        }
    };
    for flat in slice * cps..(slice + 1) * cps {
        let cell = &grid.cells[flat];
        if cell.central.is_empty() && cell.refs.is_empty() {
            continue;
        }
        if frustum.test_aabb(&grid.cell_aabb(flat)) != Containment::Outside {
            out.push(flat);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::culling::GridConfig;
    use crate::scene::synth::{SceneKind, SynthParams};
    use crate::scene::{DramLayout, Gaussian4D};

    fn small_store() -> (Arc<GridPartition>, Arc<CompressedStore>) {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 600).generate();
        let grid = GridPartition::build(&scene, GridConfig::new(4));
        let layout = DramLayout::build(&scene, &grid);
        let quantized: Vec<Gaussian4D> =
            scene.gaussians.iter().map(|g| g.quantized_fp16()).collect();
        let store = CompressedStore::build(&quantized, scene.dynamic, &layout, 32, 2048);
        (Arc::new(grid), Arc::new(store))
    }

    fn test_cam() -> Camera {
        Camera::look_at(
            Vec3::new(0.0, 0.0, 26.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            16.0 / 9.0,
            0.1,
            200.0,
        )
    }

    #[test]
    fn policy_labels_round_trip() {
        for p in [
            PrefetchPolicy::None,
            PrefetchPolicy::NextFrameCull,
            PrefetchPolicy::TrajectoryLookahead { k: 2 },
            PrefetchPolicy::TrajectoryLookahead { k: 7 },
        ] {
            assert_eq!(PrefetchPolicy::from_label(&p.label()), Some(p));
        }
        assert_eq!(
            PrefetchPolicy::from_label("lookahead"),
            Some(PrefetchPolicy::TrajectoryLookahead { k: 2 })
        );
        assert_eq!(PrefetchPolicy::from_label("bogus"), None);
        for e in [EvictPolicy::Clock, EvictPolicy::CostAware] {
            assert_eq!(EvictPolicy::from_label(e.label()), Some(e));
        }
    }

    #[test]
    fn config_defaults_are_disabled() {
        let cfg = ResidencyConfig::default();
        assert!(!cfg.enabled());
        assert_eq!(cfg.capacity_bytes(), 0);
        let on = ResidencyConfig { capacity_mb: 0.5, ..ResidencyConfig::default() };
        assert!(on.enabled());
        assert_eq!(on.capacity_bytes(), 1 << 19);
    }

    #[test]
    fn clock_eviction_gives_second_chances() {
        let (_, store) = small_store();
        let cfg = ResidencyConfig {
            capacity_mb: 1.0,
            evict: EvictPolicy::Clock,
            ..ResidencyConfig::default()
        };
        let mut st = ResidencyState::new(&cfg, store);
        // Fill pages 0..3; touch 0 and 2 so their ref bits are set.
        for p in 0..4 {
            st.complete_fill(p, false, 0.0);
        }
        // All ref bits are set by the fills; one demand sweep clears them
        // and the second finds page 0 (hand order).
        let v = st.evict_victim(true).unwrap();
        assert_eq!(v, 0);
        // Page 1's bit was cleared by that sweep; it goes next.
        assert_eq!(st.evict_victim(true).unwrap(), 1);
        // A prefetch eviction only takes ref-clear pages.
        st.note_hit(2);
        st.note_hit(3);
        assert_eq!(st.evict_victim(false), None, "all remaining pages recently touched");
    }

    #[test]
    fn cost_aware_prefers_oldest_then_smallest() {
        let (_, store) = small_store();
        let cfg = ResidencyConfig {
            capacity_mb: 1.0,
            evict: EvictPolicy::CostAware,
            ..ResidencyConfig::default()
        };
        let mut st = ResidencyState::new(&cfg, store);
        st.complete_fill(5, false, 0.0);
        st.complete_fill(3, false, 0.0);
        st.complete_fill(7, false, 0.0);
        // 5 is the oldest touch → demand-evicted first.
        assert_eq!(st.evict_victim(true).unwrap(), 5);
        st.note_hit(3); // 3 is now newer than 7
        assert_eq!(st.evict_victim(true).unwrap(), 7);
    }

    #[test]
    fn lookahead_fallback_predicts_current_working_set() {
        let (grid, store) = small_store();
        let mut pf = ResidencyPrefetcher::new(
            PrefetchPolicy::TrajectoryLookahead { k: 2 },
            Arc::clone(&grid),
            Arc::clone(&store),
        );
        let cam = test_cam();
        // No history yet: the prediction is the current pose's cells.
        let predicted: Vec<usize> = pf.predict(&cam, 0.5).to_vec();
        assert!(!predicted.is_empty(), "camera looking at the scene must predict pages");
        let mut cells = Vec::new();
        visible_cells(&grid, &cam, 0.5, &mut cells);
        let mut want: Vec<usize> = cells
            .iter()
            .flat_map(|&c| store.cell_pages(c).iter().map(|&p| p as usize))
            .collect();
        want.sort_unstable();
        want.dedup();
        assert_eq!(predicted, want);
    }

    #[test]
    fn next_frame_cull_replays_observed_frame() {
        let (grid, store) = small_store();
        let mut pf = ResidencyPrefetcher::new(
            PrefetchPolicy::NextFrameCull,
            Arc::clone(&grid),
            Arc::clone(&store),
        );
        let cam = test_cam();
        assert!(pf.predict(&cam, 0.5).is_empty(), "no history on the first frame");
        pf.observe(&cam, 0.5);
        assert!(!pf.predict(&cam, 0.5).is_empty());
        // None policy never predicts.
        let mut none =
            ResidencyPrefetcher::new(PrefetchPolicy::None, Arc::clone(&grid), store);
        none.observe(&cam, 0.5);
        assert!(none.predict(&cam, 0.5).is_empty());
    }
}
