//! The pre-refactor synchronous DRAM model, frozen as the determinism
//! oracle for the event-queue memory subsystem.
//!
//! [`SyncDramModel`] is the original per-call-synchronous LPDDR5 model the
//! repo shipped with: every `read` retires instantly, charging burst/row
//! statistics and an analytically striped busy time. The event-queue
//! [`MemorySystem`](super::event_queue::MemorySystem) must reproduce these
//! statistics **bit-for-bit** when configured with `channels = 1,
//! outstanding = 1, shards = 1` (enforced by the `memory_event_queue`
//! integration suite) — the same freeze-the-monolith pattern
//! `pipeline::oracle` uses for the stage graph.
//!
//! Do not "improve" this module; its value is that it does not change.

use super::dram::{DramConfig, DramStats, MemSink};

/// The synchronous DRAM model: tracks per-bank open rows and accumulates
/// stats, retiring every read instantly (no outstanding transactions, no
/// queueing, no cross-stream contention).
#[derive(Debug)]
pub struct SyncDramModel {
    pub config: DramConfig,
    stats: DramStats,
    /// Open row per channel (we model one bank group per channel — the
    /// locality signal the experiments need is sequential-vs-scattered).
    open_row: Vec<Option<u64>>,
}

impl SyncDramModel {
    pub fn new(config: DramConfig) -> SyncDramModel {
        SyncDramModel {
            open_row: vec![None; config.channels],
            config,
            stats: DramStats::default(),
        }
    }

    pub fn default_lpddr5() -> SyncDramModel {
        SyncDramModel::new(DramConfig::default())
    }

    /// Read `bytes` starting at `addr`. Contiguous ranges amortize row
    /// activations; scattered single-record reads mostly miss.
    pub fn read(&mut self, addr: u64, bytes: u64) {
        if bytes == 0 {
            return;
        }
        let cfg = self.config;
        let first_burst = addr / cfg.burst_bytes;
        let last_burst = (addr + bytes - 1) / cfg.burst_bytes;
        let n_bursts = last_burst - first_burst + 1;
        let bursts_per_row = cfg.row_bytes / cfg.burst_bytes;

        let mut ns;
        let mut pj;
        if n_bursts > 4 * bursts_per_row {
            // Analytic fast path for long contiguous sweeps (equivalent to
            // the per-burst walk: one activation per row touched) — the
            // per-burst loop was a host hot spot on multi-MB reads
            // (EXPERIMENTS.md §Perf).
            let first_row = (first_burst * cfg.burst_bytes) / cfg.row_bytes;
            let last_row = (last_burst * cfg.burst_bytes) / cfg.row_bytes;
            let rows = last_row - first_row + 1;
            self.stats.row_misses += rows;
            self.stats.row_hits += n_bursts - rows;
            for ch in 0..cfg.channels {
                // Leave each channel's open row as the last row it serves.
                let r = last_row.saturating_sub(ch as u64);
                if r >= first_row {
                    let ch_idx = (r as usize) % cfg.channels;
                    self.open_row[ch_idx] = Some(r);
                }
            }
            ns = rows as f64 * (cfg.t_rp_ns + cfg.t_rcd_ns)
                + n_bursts as f64 * cfg.t_burst_ns;
            pj = rows as f64 * cfg.e_activate_pj
                + n_bursts as f64 * cfg.e_access_pj_per_bit * (cfg.burst_bytes * 8) as f64;
        } else {
            ns = 0.0;
            pj = 0.0;
            for b in first_burst..=last_burst {
                let byte_addr = b * cfg.burst_bytes;
                let row = byte_addr / cfg.row_bytes;
                let ch = (row as usize) % cfg.channels;
                if self.open_row[ch] == Some(row) {
                    self.stats.row_hits += 1;
                } else {
                    self.stats.row_misses += 1;
                    self.open_row[ch] = Some(row);
                    ns += cfg.t_rp_ns + cfg.t_rcd_ns;
                    pj += cfg.e_activate_pj;
                }
                ns += cfg.t_burst_ns;
                pj += cfg.e_access_pj_per_bit * (cfg.burst_bytes * 8) as f64;
            }
        }

        self.stats.reads += 1;
        self.stats.bursts += n_bursts;
        self.stats.bytes += n_bursts * cfg.burst_bytes;
        self.stats.energy_pj += pj;
        // Channel-level parallelism: striped traffic divides busy time.
        self.stats.busy_ns += ns / cfg.channels as f64;
    }

    pub fn stats(&self) -> DramStats {
        self.stats
    }

    pub fn reset(&mut self) {
        self.stats = DramStats::default();
        for r in &mut self.open_row {
            *r = None;
        }
    }
}

impl MemSink for SyncDramModel {
    fn read(&mut self, addr: u64, bytes: u64) {
        SyncDramModel::read(self, addr, bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_read_counts_bursts() {
        let mut d = SyncDramModel::default_lpddr5();
        d.read(0, 1024);
        let s = d.stats();
        assert_eq!(s.reads, 1);
        assert_eq!(s.bursts, 32); // 1024 / 32
        assert_eq!(s.bytes, 1024);
    }

    #[test]
    fn contiguous_has_high_row_hit_rate() {
        let mut d = SyncDramModel::default_lpddr5();
        d.read(0, 64 * 1024);
        assert!(d.stats().hit_rate() > 0.9, "hit rate {}", d.stats().hit_rate());
    }

    #[test]
    fn scattered_reads_mostly_miss() {
        let mut d = SyncDramModel::default_lpddr5();
        // Stride row-sized: every read opens a new row.
        for i in 0..256u64 {
            d.read(i * 2048 * 7, 32);
        }
        assert!(d.stats().hit_rate() < 0.1);
    }

    #[test]
    fn scattered_costs_more_energy_per_byte() {
        let mut seq = SyncDramModel::default_lpddr5();
        seq.read(0, 8192);
        let e_seq = seq.stats().energy_pj / seq.stats().bytes as f64;

        let mut sc = SyncDramModel::default_lpddr5();
        for i in 0..256u64 {
            sc.read(i * 2048 * 3, 32);
        }
        let e_sc = sc.stats().energy_pj / sc.stats().bytes as f64;
        assert!(e_sc > 2.0 * e_seq, "scattered {e_sc} vs sequential {e_seq}");
    }

    #[test]
    fn partial_burst_rounds_up() {
        let mut d = SyncDramModel::default_lpddr5();
        d.read(10, 8); // spans a single burst
        assert_eq!(d.stats().bursts, 1);
        assert_eq!(d.stats().bytes, 32);
        let mut d2 = SyncDramModel::default_lpddr5();
        d2.read(30, 8); // straddles a burst boundary
        assert_eq!(d2.stats().bursts, 2);
    }

    #[test]
    fn reset_clears() {
        let mut d = SyncDramModel::default_lpddr5();
        d.read(0, 4096);
        d.reset();
        assert_eq!(d.stats(), DramStats::default());
    }

    #[test]
    fn stats_add_accumulates() {
        let mut a = DramStats::default();
        let mut d = SyncDramModel::default_lpddr5();
        d.read(0, 1024);
        a.add(&d.stats());
        a.add(&d.stats());
        assert_eq!(a.bytes, 2048);
        assert_eq!(a.reads, 2);
    }

    /// Regression for the analytic fast path: at the `4 * bursts_per_row`
    /// boundary the model switches from the per-burst walk (`<=`) to the
    /// analytic row-count expression (`>`). Both must agree on every
    /// statistic for a cold model — checked just below, at, and above the
    /// boundary, plus deep into fast-path territory.
    #[test]
    fn analytic_fast_path_matches_per_burst_walk_at_boundary() {
        let cfg = DramConfig::default();
        let bursts_per_row = cfg.row_bytes / cfg.burst_bytes;
        let threshold = 4 * bursts_per_row; // walk for n <= threshold, fast path above

        // Reference: per-burst walk on a cold model, reimplemented
        // independently of the shipping code path.
        let walk_reference = |addr: u64, bytes: u64| -> DramStats {
            let mut stats = DramStats::default();
            let mut open_row: Vec<Option<u64>> = vec![None; cfg.channels];
            let first_burst = addr / cfg.burst_bytes;
            let last_burst = (addr + bytes - 1) / cfg.burst_bytes;
            let mut ns = 0.0;
            for b in first_burst..=last_burst {
                let row = (b * cfg.burst_bytes) / cfg.row_bytes;
                let ch = (row as usize) % cfg.channels;
                if open_row[ch] == Some(row) {
                    stats.row_hits += 1;
                } else {
                    stats.row_misses += 1;
                    open_row[ch] = Some(row);
                    ns += cfg.t_rp_ns + cfg.t_rcd_ns;
                    stats.energy_pj += cfg.e_activate_pj;
                }
                ns += cfg.t_burst_ns;
                stats.energy_pj += cfg.e_access_pj_per_bit * (cfg.burst_bytes * 8) as f64;
            }
            stats.reads = 1;
            stats.bursts = last_burst - first_burst + 1;
            stats.bytes = stats.bursts * cfg.burst_bytes;
            stats.busy_ns = ns / cfg.channels as f64;
            stats
        };

        for n_bursts in [threshold - 1, threshold, threshold + 1, 16 * threshold] {
            // Row-aligned start: the regimes must agree exactly on a cold
            // model (one activation per touched row either way).
            let bytes = n_bursts * cfg.burst_bytes;
            let mut model = SyncDramModel::new(cfg);
            model.read(0, bytes);
            let reference = walk_reference(0, bytes);
            let got = model.stats();
            assert_eq!(got.reads, reference.reads, "n_bursts={n_bursts}");
            assert_eq!(got.bursts, reference.bursts, "n_bursts={n_bursts}");
            assert_eq!(got.bytes, reference.bytes, "n_bursts={n_bursts}");
            assert_eq!(got.row_hits, reference.row_hits, "n_bursts={n_bursts}");
            assert_eq!(got.row_misses, reference.row_misses, "n_bursts={n_bursts}");
            let e_rel = (got.energy_pj - reference.energy_pj).abs() / reference.energy_pj;
            let t_rel = (got.busy_ns - reference.busy_ns).abs() / reference.busy_ns;
            assert!(e_rel < 1e-9, "n_bursts={n_bursts}: energy {e_rel}");
            assert!(t_rel < 1e-9, "n_bursts={n_bursts}: busy {t_rel}");
        }
    }
}
