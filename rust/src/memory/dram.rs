//! Event-based LPDDR5 DRAM model (stand-in for Ramulator 2.0 — DESIGN.md §2).
//!
//! Models the properties the paper's experiments measure: access counts,
//! burst efficiency of contiguous ranges, row-buffer locality, per-access
//! energy, and channel busy time. Timing/energy constants follow published
//! LPDDR5-6400 figures.

/// LPDDR5 channel configuration.
#[derive(Debug, Clone, Copy)]
pub struct DramConfig {
    /// Bytes transferred per burst (BL16 × 16-bit channel = 32 B).
    pub burst_bytes: u64,
    /// Row (page) size in bytes.
    pub row_bytes: u64,
    /// Burst transfer time at 6400 MT/s on a ×16 channel (ns).
    pub t_burst_ns: f64,
    /// Row activate-to-read (tRCD, ns).
    pub t_rcd_ns: f64,
    /// Precharge (tRP, ns).
    pub t_rp_ns: f64,
    /// Access energy per bit (pJ/bit, incl. I/O) for data on an open row.
    pub e_access_pj_per_bit: f64,
    /// Extra energy per row activation (pJ).
    pub e_activate_pj: f64,
    /// Number of independent channels (accesses are striped round-robin).
    pub channels: usize,
}

impl Default for DramConfig {
    fn default() -> Self {
        DramConfig {
            burst_bytes: 32,
            row_bytes: 2048,
            t_burst_ns: 2.5,
            t_rcd_ns: 18.0,
            t_rp_ns: 18.0,
            e_access_pj_per_bit: 4.5,
            e_activate_pj: 1500.0,
            channels: 2,
        }
    }
}

/// Accumulated statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DramStats {
    /// Read request count (one per `read` call).
    pub reads: u64,
    /// Bytes actually transferred (rounded up to bursts).
    pub bytes: u64,
    /// Burst transactions issued.
    pub bursts: u64,
    /// Row-buffer hits / misses (per burst).
    pub row_hits: u64,
    pub row_misses: u64,
    /// Total access energy (pJ).
    pub energy_pj: f64,
    /// Channel busy time (ns), after striping across channels.
    pub busy_ns: f64,
}

impl DramStats {
    pub fn energy_mj(&self) -> f64 {
        self.energy_pj * 1e-9
    }

    /// Row-buffer hit rate over all bursts.
    pub fn hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }

    pub fn add(&mut self, o: &DramStats) {
        self.reads += o.reads;
        self.bytes += o.bytes;
        self.bursts += o.bursts;
        self.row_hits += o.row_hits;
        self.row_misses += o.row_misses;
        self.energy_pj += o.energy_pj;
        self.busy_ns += o.busy_ns;
    }
}

/// The DRAM model: tracks per-bank open rows and accumulates stats.
#[derive(Debug)]
pub struct DramModel {
    pub config: DramConfig,
    stats: DramStats,
    /// Open row per channel (we model one bank group per channel — the
    /// locality signal the experiments need is sequential-vs-scattered).
    open_row: Vec<Option<u64>>,
}

impl DramModel {
    pub fn new(config: DramConfig) -> DramModel {
        DramModel {
            open_row: vec![None; config.channels],
            config,
            stats: DramStats::default(),
        }
    }

    pub fn default_lpddr5() -> DramModel {
        DramModel::new(DramConfig::default())
    }

    /// Read `bytes` starting at `addr`. Contiguous ranges amortize row
    /// activations; scattered single-record reads mostly miss.
    pub fn read(&mut self, addr: u64, bytes: u64) {
        if bytes == 0 {
            return;
        }
        let cfg = self.config;
        let first_burst = addr / cfg.burst_bytes;
        let last_burst = (addr + bytes - 1) / cfg.burst_bytes;
        let n_bursts = last_burst - first_burst + 1;
        let bursts_per_row = cfg.row_bytes / cfg.burst_bytes;

        let mut ns;
        let mut pj;
        if n_bursts > 4 * bursts_per_row {
            // Analytic fast path for long contiguous sweeps (equivalent to
            // the per-burst walk: one activation per row touched) — the
            // per-burst loop was a host hot spot on multi-MB reads
            // (EXPERIMENTS.md §Perf).
            let first_row = (first_burst * cfg.burst_bytes) / cfg.row_bytes;
            let last_row = (last_burst * cfg.burst_bytes) / cfg.row_bytes;
            let rows = last_row - first_row + 1;
            self.stats.row_misses += rows;
            self.stats.row_hits += n_bursts - rows;
            for ch in 0..cfg.channels {
                // Leave each channel's open row as the last row it serves.
                let r = last_row.saturating_sub(ch as u64);
                if r >= first_row {
                    let ch_idx = (r as usize) % cfg.channels;
                    self.open_row[ch_idx] = Some(r);
                }
            }
            ns = rows as f64 * (cfg.t_rp_ns + cfg.t_rcd_ns)
                + n_bursts as f64 * cfg.t_burst_ns;
            pj = rows as f64 * cfg.e_activate_pj
                + n_bursts as f64 * cfg.e_access_pj_per_bit * (cfg.burst_bytes * 8) as f64;
        } else {
            ns = 0.0;
            pj = 0.0;
            for b in first_burst..=last_burst {
                let byte_addr = b * cfg.burst_bytes;
                let row = byte_addr / cfg.row_bytes;
                let ch = (row as usize) % cfg.channels;
                if self.open_row[ch] == Some(row) {
                    self.stats.row_hits += 1;
                } else {
                    self.stats.row_misses += 1;
                    self.open_row[ch] = Some(row);
                    ns += cfg.t_rp_ns + cfg.t_rcd_ns;
                    pj += cfg.e_activate_pj;
                }
                ns += cfg.t_burst_ns;
                pj += cfg.e_access_pj_per_bit * (cfg.burst_bytes * 8) as f64;
            }
        }

        self.stats.reads += 1;
        self.stats.bursts += n_bursts;
        self.stats.bytes += n_bursts * cfg.burst_bytes;
        self.stats.energy_pj += pj;
        // Channel-level parallelism: striped traffic divides busy time.
        self.stats.busy_ns += ns / cfg.channels as f64;
    }

    pub fn stats(&self) -> DramStats {
        self.stats
    }

    pub fn reset(&mut self) {
        self.stats = DramStats::default();
        for r in &mut self.open_row {
            *r = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_read_counts_bursts() {
        let mut d = DramModel::default_lpddr5();
        d.read(0, 1024);
        let s = d.stats();
        assert_eq!(s.reads, 1);
        assert_eq!(s.bursts, 32); // 1024 / 32
        assert_eq!(s.bytes, 1024);
    }

    #[test]
    fn contiguous_has_high_row_hit_rate() {
        let mut d = DramModel::default_lpddr5();
        d.read(0, 64 * 1024);
        assert!(d.stats().hit_rate() > 0.9, "hit rate {}", d.stats().hit_rate());
    }

    #[test]
    fn scattered_reads_mostly_miss() {
        let mut d = DramModel::default_lpddr5();
        // Stride row-sized: every read opens a new row.
        for i in 0..256u64 {
            d.read(i * 2048 * 7, 32);
        }
        assert!(d.stats().hit_rate() < 0.1);
    }

    #[test]
    fn scattered_costs_more_energy_per_byte() {
        let mut seq = DramModel::default_lpddr5();
        seq.read(0, 8192);
        let e_seq = seq.stats().energy_pj / seq.stats().bytes as f64;

        let mut sc = DramModel::default_lpddr5();
        for i in 0..256u64 {
            sc.read(i * 2048 * 3, 32);
        }
        let e_sc = sc.stats().energy_pj / sc.stats().bytes as f64;
        assert!(e_sc > 2.0 * e_seq, "scattered {e_sc} vs sequential {e_seq}");
    }

    #[test]
    fn partial_burst_rounds_up() {
        let mut d = DramModel::default_lpddr5();
        d.read(10, 8); // spans a single burst
        assert_eq!(d.stats().bursts, 1);
        assert_eq!(d.stats().bytes, 32);
        let mut d2 = DramModel::default_lpddr5();
        d2.read(30, 8); // straddles a burst boundary
        assert_eq!(d2.stats().bursts, 2);
    }

    #[test]
    fn reset_clears() {
        let mut d = DramModel::default_lpddr5();
        d.read(0, 4096);
        d.reset();
        assert_eq!(d.stats(), DramStats::default());
    }

    #[test]
    fn stats_add_accumulates() {
        let mut a = DramStats::default();
        let mut d = DramModel::default_lpddr5();
        d.read(0, 1024);
        a.add(&d.stats());
        a.add(&d.stats());
        assert_eq!(a.bytes, 2048);
        assert_eq!(a.reads, 2);
    }
}
