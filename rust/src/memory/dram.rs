//! DRAM front-end: configuration, statistics, and the request-sink
//! interface shared by every memory backend.
//!
//! The crate has two DRAM timing backends behind one statistics contract:
//!
//! * [`SyncDramModel`](super::oracle::SyncDramModel) — the original
//!   synchronous-per-read model, frozen in `memory::oracle` as the
//!   determinism oracle (re-exported here as [`DramModel`] for the frozen
//!   `pipeline::oracle` monolith and the figure benches);
//! * [`MemorySystem`](super::event_queue::MemorySystem) — the event-queue
//!   model with per-channel queues, outstanding-transaction limits, and
//!   cross-stream contention, reached through a
//!   [`MemPort`](super::event_queue::MemPort) handle.
//!
//! Stage code issues requests through the [`MemSink`] trait so the cull and
//! blend paths are backend-agnostic; which backend a pipeline uses is a
//! [`MemSimConfig`](super::event_queue::MemSimConfig) decision.

use crate::util::json::Json;

/// The request interface every DRAM backend implements. Stage code (DR-FC
/// culling, the conventional sweep, the blend miss-fill) is generic over
/// this trait, so the same request stream can be charged to the synchronous
/// oracle or queued into the event-queue [`MemorySystem`]
/// (`super::event_queue::MemorySystem`).
pub trait MemSink {
    /// Read `bytes` starting at byte address `addr`.
    fn read(&mut self, addr: u64, bytes: u64);
}

/// LPDDR5 channel configuration.
#[derive(Debug, Clone, Copy)]
pub struct DramConfig {
    /// Bytes transferred per burst (BL16 × 16-bit channel = 32 B).
    pub burst_bytes: u64,
    /// Row (page) size in bytes.
    pub row_bytes: u64,
    /// Burst transfer time at 6400 MT/s on a ×16 channel (ns).
    pub t_burst_ns: f64,
    /// Row activate-to-read (tRCD, ns).
    pub t_rcd_ns: f64,
    /// Precharge (tRP, ns).
    pub t_rp_ns: f64,
    /// Access energy per bit (pJ/bit, incl. I/O) for data on an open row.
    pub e_access_pj_per_bit: f64,
    /// Extra energy per row activation (pJ).
    pub e_activate_pj: f64,
    /// Number of independent channels. The synchronous oracle stripes
    /// accesses round-robin; the event-queue model reads this as *channels
    /// per shard group*.
    pub channels: usize,
}

impl Default for DramConfig {
    fn default() -> Self {
        DramConfig {
            burst_bytes: 32,
            row_bytes: 2048,
            t_burst_ns: 2.5,
            t_rcd_ns: 18.0,
            t_rp_ns: 18.0,
            e_access_pj_per_bit: 4.5,
            e_activate_pj: 1500.0,
            channels: 2,
        }
    }
}

/// Accumulated statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DramStats {
    /// Read request count (one per `read` call).
    pub reads: u64,
    /// Bytes actually transferred (rounded up to bursts).
    pub bytes: u64,
    /// Burst transactions issued.
    pub bursts: u64,
    /// Row-buffer hits / misses (per burst).
    pub row_hits: u64,
    pub row_misses: u64,
    /// Total access energy (pJ).
    pub energy_pj: f64,
    /// Time the memory system was busy on this stream's behalf (ns). The
    /// synchronous oracle charges service time striped across channels; the
    /// event-queue model charges the union of issue→completion intervals,
    /// which additionally covers contention wait.
    pub busy_ns: f64,
    /// Simulated time requests spent waiting on channels occupied by
    /// *other* request streams, beyond this stream's own completion
    /// horizon (ns). Always 0 under the synchronous oracle — and 0 for any
    /// isolated single-port stream at any outstanding depth: queueing
    /// behind one's own in-flight transactions is pipelining, not
    /// contention.
    pub wait_ns: f64,
    /// Requests that paid a nonzero cross-stream wait. Always 0 under the
    /// synchronous oracle and for isolated streams.
    pub stalls: u64,
}

impl DramStats {
    pub fn energy_mj(&self) -> f64 {
        self.energy_pj * 1e-9
    }

    /// Row-buffer hit rate over all bursts.
    pub fn hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }

    /// Mean contention wait per request (ns); 0 when no requests were made.
    pub fn avg_wait_ns(&self) -> f64 {
        if self.reads == 0 {
            0.0
        } else {
            self.wait_ns / self.reads as f64
        }
    }

    pub fn add(&mut self, o: &DramStats) {
        self.reads += o.reads;
        self.bytes += o.bytes;
        self.bursts += o.bursts;
        self.row_hits += o.row_hits;
        self.row_misses += o.row_misses;
        self.energy_pj += o.energy_pj;
        self.busy_ns += o.busy_ns;
        self.wait_ns += o.wait_ns;
        self.stalls += o.stalls;
    }

    /// Counter-wise difference against an earlier snapshot of the same
    /// stream (`self` cumulative, `base` the snapshot). Used by shared-mode
    /// ports to report per-frame deltas without resetting channel state.
    ///
    /// Saturating: after trace replay the round engine *patches* a port's
    /// cumulative counters, so a stale snapshot can momentarily exceed the
    /// cumulative value. A paging-aware roll-up must never panic or wrap on
    /// that — negative deltas clamp to zero.
    pub fn delta(&self, base: &DramStats) -> DramStats {
        DramStats {
            reads: self.reads.saturating_sub(base.reads),
            bytes: self.bytes.saturating_sub(base.bytes),
            bursts: self.bursts.saturating_sub(base.bursts),
            row_hits: self.row_hits.saturating_sub(base.row_hits),
            row_misses: self.row_misses.saturating_sub(base.row_misses),
            energy_pj: (self.energy_pj - base.energy_pj).max(0.0),
            busy_ns: (self.busy_ns - base.busy_ns).max(0.0),
            wait_ns: (self.wait_ns - base.wait_ns).max(0.0),
            stalls: self.stalls.saturating_sub(base.stalls),
        }
    }

    /// Full statistics as a JSON object — one schema for every stage block
    /// in `TrafficLog::to_json` and the server's contended-memory report,
    /// so benches stop recomputing derived rates.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("reads", self.reads)
            .set("bytes", self.bytes)
            .set("bursts", self.bursts)
            .set("row_hits", self.row_hits)
            .set("row_misses", self.row_misses)
            .set("hit_rate", self.hit_rate())
            .set("energy_pj", self.energy_pj)
            .set("busy_ns", self.busy_ns)
            .set("wait_ns", self.wait_ns)
            .set("stalls", self.stalls)
    }
}

/// The synchronous model under its historical name: the frozen
/// `pipeline::oracle` monolith and the figure benches construct a
/// `DramModel` directly, and that behavior must never drift — it *is* the
/// determinism baseline. New code takes a
/// [`MemPort`](super::event_queue::MemPort) (or `impl MemSink`) instead.
pub type DramModel = super::oracle::SyncDramModel;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_with_zero_bursts_is_zero() {
        let s = DramStats::default();
        assert_eq!(s.hit_rate(), 0.0);
        assert_eq!(s.avg_wait_ns(), 0.0);
        // One miss, no hits: rate is well-defined and zero.
        let s = DramStats { row_misses: 1, ..DramStats::default() };
        assert_eq!(s.hit_rate(), 0.0);
    }

    #[test]
    fn add_accumulates_latency_and_contention_fields() {
        let mut a = DramStats {
            reads: 2,
            busy_ns: 10.0,
            wait_ns: 3.0,
            stalls: 1,
            ..DramStats::default()
        };
        let b = DramStats {
            reads: 3,
            busy_ns: 5.0,
            wait_ns: 2.5,
            stalls: 2,
            ..DramStats::default()
        };
        a.add(&b);
        assert_eq!(a.reads, 5);
        assert_eq!(a.busy_ns, 15.0);
        assert_eq!(a.wait_ns, 5.5);
        assert_eq!(a.stalls, 3);
        assert_eq!(a.avg_wait_ns(), 1.1);
    }

    #[test]
    fn delta_subtracts_snapshot() {
        let base = DramStats {
            reads: 1,
            bytes: 32,
            bursts: 1,
            row_hits: 0,
            row_misses: 1,
            energy_pj: 10.0,
            busy_ns: 4.0,
            wait_ns: 0.0,
            stalls: 0,
        };
        let mut cum = base;
        cum.add(&DramStats {
            reads: 2,
            bytes: 64,
            bursts: 2,
            row_hits: 2,
            row_misses: 0,
            energy_pj: 6.0,
            busy_ns: 2.0,
            wait_ns: 1.0,
            stalls: 1,
        });
        let d = cum.delta(&base);
        assert_eq!(d.reads, 2);
        assert_eq!(d.bytes, 64);
        assert_eq!(d.bursts, 2);
        assert_eq!(d.row_hits, 2);
        assert_eq!(d.row_misses, 0);
        assert!((d.energy_pj - 6.0).abs() < 1e-12);
        assert!((d.busy_ns - 2.0).abs() < 1e-12);
        assert!((d.wait_ns - 1.0).abs() < 1e-12);
        assert_eq!(d.stalls, 1);
    }

    #[test]
    fn delta_saturates_when_base_exceeds_cumulative() {
        // Trace replay patches port counters; a snapshot taken before the
        // patch can exceed the cumulative stream. The delta must clamp to
        // zero instead of wrapping (u64) or going negative (f64).
        let base = DramStats {
            reads: 10,
            bytes: 320,
            bursts: 10,
            row_hits: 8,
            row_misses: 2,
            energy_pj: 100.0,
            busy_ns: 50.0,
            wait_ns: 5.0,
            stalls: 3,
        };
        let cum = DramStats {
            reads: 4,
            bytes: 128,
            bursts: 4,
            row_hits: 3,
            row_misses: 1,
            energy_pj: 40.0,
            busy_ns: 20.0,
            wait_ns: 1.0,
            stalls: 1,
        };
        let d = cum.delta(&base);
        assert_eq!(d, DramStats::default());
        // Mixed direction: only the underflowing fields clamp.
        let cum2 = DramStats { reads: 12, busy_ns: 60.0, ..cum };
        let d2 = cum2.delta(&base);
        assert_eq!(d2.reads, 2);
        assert!((d2.busy_ns - 10.0).abs() < 1e-12);
        assert_eq!(d2.bytes, 0);
        assert_eq!(d2.stalls, 0);
        assert_eq!(d2.wait_ns, 0.0);
    }

    #[test]
    fn stats_json_has_full_schema() {
        let s = DramStats { row_hits: 3, row_misses: 1, ..DramStats::default() };
        let js = s.to_json().pretty();
        for key in
            ["reads", "bytes", "bursts", "hit_rate", "energy_pj", "busy_ns", "wait_ns", "stalls"]
        {
            assert!(js.contains(key), "missing {key} in {js}");
        }
    }
}
