//! Scene sharding: partitioning the Gaussian DRAM layout across channel
//! groups.
//!
//! A [`ShardMap`] splits the scene's byte-contiguous DRAM span (parameter
//! records + neighbor pointer tables, see `scene::DramLayout`) into `N`
//! equal contiguous shards, each mapped to its own group of DRAM channels
//! in the event-queue [`MemorySystem`](super::event_queue::MemorySystem).
//! Shard boundaries are aligned up to the DRAM row size so a row never
//! straddles two channel groups and the row→channel striping inside a
//! group stays well-defined.
//!
//! `ScenePrep` builds the map offline alongside the grid partition and
//! layout; `SharedScene` exposes the translation so serving code can reason
//! about which channel group a Gaussian's record lands on. With `shards =
//! 1` the map is the identity and the event-queue model collapses to a
//! single channel group — the configuration the determinism suite pins
//! against the synchronous oracle.

/// Address-space partition of one scene's DRAM span into channel-group
/// shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardMap {
    /// Number of shards (≥ 1).
    pub shards: usize,
    /// Bytes per shard (row-aligned; the last shard absorbs the remainder
    /// of the span).
    pub shard_bytes: u64,
    /// Total bytes of the mapped span.
    pub total_bytes: u64,
}

impl ShardMap {
    /// Partition `total_bytes` into `shards` contiguous ranges, aligning
    /// each boundary up to `align_bytes` (the DRAM row size).
    pub fn build(total_bytes: u64, shards: usize, align_bytes: u64) -> ShardMap {
        let shards = shards.max(1);
        let align = align_bytes.max(1);
        let raw = total_bytes.div_ceil(shards as u64).max(1);
        let shard_bytes = raw.div_ceil(align) * align;
        ShardMap { shards, shard_bytes, total_bytes }
    }

    /// The identity map: one shard covering the whole span.
    pub fn single(total_bytes: u64) -> ShardMap {
        ShardMap { shards: 1, shard_bytes: total_bytes.max(1), total_bytes }
    }

    /// Which shard a byte address belongs to. Addresses past the mapped
    /// span clamp to the last shard (the span is an upper bound, not a
    /// hardware fault model).
    pub fn shard_of(&self, addr: u64) -> usize {
        ((addr / self.shard_bytes) as usize).min(self.shards - 1)
    }

    /// Byte range `[start, end)` of shard `s` within the address space.
    /// The last shard is unbounded above (clamping mirror of `shard_of`).
    pub fn shard_range(&self, s: usize) -> (u64, u64) {
        let start = s as u64 * self.shard_bytes;
        if s + 1 >= self.shards {
            (start, u64::MAX)
        } else {
            (start, start + self.shard_bytes)
        }
    }

    /// Split the request `[addr, addr + bytes)` at shard boundaries,
    /// invoking `f(shard, addr, bytes)` once per contiguous piece in
    /// ascending address order. With `shards = 1` this is exactly one call —
    /// the determinism-critical case adds no arithmetic to the request.
    pub fn split<F: FnMut(usize, u64, u64)>(&self, addr: u64, bytes: u64, mut f: F) {
        if bytes == 0 {
            return;
        }
        let mut cur = addr;
        let end = addr.saturating_add(bytes);
        while cur < end {
            let s = self.shard_of(cur);
            let (_, shard_end) = self.shard_range(s);
            let piece_end = end.min(shard_end);
            f(s, cur, piece_end - cur);
            cur = piece_end;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_is_identity() {
        let m = ShardMap::single(1 << 20);
        assert_eq!(m.shards, 1);
        assert_eq!(m.shard_of(0), 0);
        assert_eq!(m.shard_of(u64::MAX / 2), 0);
        let mut pieces = Vec::new();
        m.split(100, 5000, |s, a, b| pieces.push((s, a, b)));
        assert_eq!(pieces, vec![(0, 100, 5000)]);
    }

    #[test]
    fn boundaries_are_row_aligned() {
        let m = ShardMap::build(1_000_000, 4, 2048);
        assert_eq!(m.shard_bytes % 2048, 0);
        assert!(m.shard_bytes * 4 >= 1_000_000);
        // Every byte of the span maps to a valid shard.
        assert_eq!(m.shard_of(0), 0);
        assert_eq!(m.shard_of(999_999), 3);
    }

    #[test]
    fn split_covers_range_without_gaps() {
        let m = ShardMap::build(64 * 2048, 4, 2048);
        // A request spanning all four shards.
        let (addr, bytes) = (m.shard_bytes / 2, m.shard_bytes * 3);
        let mut pieces = Vec::new();
        m.split(addr, bytes, |s, a, b| pieces.push((s, a, b)));
        assert!(pieces.len() >= 3);
        // Contiguity + total coverage.
        let mut cur = addr;
        let mut total = 0;
        for (i, &(s, a, b)) in pieces.iter().enumerate() {
            assert_eq!(a, cur, "piece {i} not contiguous");
            assert_eq!(s, m.shard_of(a));
            assert_eq!(m.shard_of(a + b - 1), s, "piece {i} crosses a boundary");
            cur += b;
            total += b;
        }
        assert_eq!(total, bytes);
    }

    #[test]
    fn clamps_past_span_to_last_shard() {
        let m = ShardMap::build(10_000, 2, 2048);
        assert_eq!(m.shard_of(10 * m.shard_bytes), 1);
        let mut pieces = Vec::new();
        m.split(m.shard_bytes * 5, 128, |s, a, b| pieces.push((s, a, b)));
        assert_eq!(pieces.len(), 1);
        assert_eq!(pieces[0].0, 1);
    }
}
