//! Frame-level traffic bookkeeping: one place where culling / blending /
//! sorting stages deposit their DRAM & SRAM statistics so the energy/FPS
//! roll-up and the per-figure benches can read consistent numbers.

use super::dram::DramStats;
use super::sram::SramStats;
use crate::util::json::Json;

/// Aggregated memory traffic for one frame (or one experiment run).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TrafficLog {
    /// DRAM traffic during preprocessing (culling fetches).
    pub preprocess_dram: DramStats,
    /// DRAM traffic during blending (buffer miss fills).
    pub blend_dram: DramStats,
    /// Paging traffic (residency miss fills, prefetch, eviction
    /// write-backs) charged by the residency layer. Zero when the scene is
    /// fully DRAM-resident.
    pub paging_dram: DramStats,
    /// Dynamic-scene update-stream traffic (temporal-delta writes of
    /// changed Gaussian records, `scene::temporal`). Zero for static
    /// scenes or when no update stream is attached.
    pub update_dram: DramStats,
    /// SRAM buffer activity during blending.
    pub blend_sram: SramStats,
    /// Gaussian parameter records fetched from DRAM (count, dedup applied).
    pub gaussians_fetched: u64,
    /// Gaussian records that passed exact culling.
    pub gaussians_visible: u64,
}

impl TrafficLog {
    pub fn new() -> TrafficLog {
        TrafficLog::default()
    }

    /// Zero every counter in place (per-frame reuse in the pooled
    /// `FrameCtx`; the log holds no heap storage, so this is allocation-free
    /// by construction).
    pub fn clear(&mut self) {
        *self = TrafficLog::default();
    }

    /// Total DRAM bytes across stages.
    pub fn total_dram_bytes(&self) -> u64 {
        self.preprocess_dram.bytes
            + self.blend_dram.bytes
            + self.paging_dram.bytes
            + self.update_dram.bytes
    }

    /// Total DRAM energy (pJ).
    pub fn total_dram_energy_pj(&self) -> f64 {
        self.preprocess_dram.energy_pj
            + self.blend_dram.energy_pj
            + self.paging_dram.energy_pj
            + self.update_dram.energy_pj
    }

    /// Total DRAM *access count* — the Fig. 9 / Fig. 10(a) metric. The paper
    /// counts parameter-fetch transactions; we count bursts, which is what a
    /// DRAM controller issues.
    pub fn total_dram_accesses(&self) -> u64 {
        self.preprocess_dram.bursts
            + self.blend_dram.bursts
            + self.paging_dram.bursts
            + self.update_dram.bursts
    }

    pub fn add(&mut self, o: &TrafficLog) {
        self.preprocess_dram.add(&o.preprocess_dram);
        self.blend_dram.add(&o.blend_dram);
        self.paging_dram.add(&o.paging_dram);
        self.update_dram.add(&o.update_dram);
        self.blend_sram.add(&o.blend_sram);
        self.gaussians_fetched += o.gaussians_fetched;
        self.gaussians_visible += o.gaussians_visible;
    }

    pub fn to_json(&self) -> Json {
        let mut js = Json::obj()
            // Full per-stage DRAM statistics (busy/wait/hit-rate included)
            // so benches consume them instead of recomputing.
            .set("preprocess_dram", self.preprocess_dram.to_json())
            .set("blend_dram", self.blend_dram.to_json());
        // The paging stage appears only when the residency layer actually
        // moved data — fully-resident reports stay byte-identical to the
        // pre-residency schema.
        if self.paging_dram != DramStats::default() {
            js = js.set("paging_dram", self.paging_dram.to_json());
        }
        // Likewise the update stream: only dynamic runs with an attached
        // update stream emit it, so static reports stay byte-identical.
        if self.update_dram != DramStats::default() {
            js = js.set("update_dram", self.update_dram.to_json());
        }
        js
            // Flat legacy keys, kept for existing report consumers.
            .set("preprocess_dram_bytes", self.preprocess_dram.bytes)
            .set("preprocess_dram_bursts", self.preprocess_dram.bursts)
            .set("blend_dram_bytes", self.blend_dram.bytes)
            .set("blend_dram_bursts", self.blend_dram.bursts)
            .set("sram_hit_rate", self.blend_sram.hit_rate())
            .set("sram_lookups", self.blend_sram.lookups)
            .set("gaussians_fetched", self.gaussians_fetched)
            .set("gaussians_visible", self.gaussians_visible)
            .set("total_dram_energy_pj", self.total_dram_energy_pj())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_combine_stages() {
        let mut t = TrafficLog::new();
        t.preprocess_dram.bytes = 100;
        t.preprocess_dram.bursts = 4;
        t.blend_dram.bytes = 50;
        t.blend_dram.bursts = 2;
        assert_eq!(t.total_dram_bytes(), 150);
        assert_eq!(t.total_dram_accesses(), 6);
    }

    #[test]
    fn add_accumulates() {
        let mut a = TrafficLog::new();
        a.gaussians_fetched = 10;
        let mut b = TrafficLog::new();
        b.gaussians_fetched = 5;
        b.blend_sram.lookups = 7;
        a.add(&b);
        assert_eq!(a.gaussians_fetched, 15);
        assert_eq!(a.blend_sram.lookups, 7);
    }

    #[test]
    fn clear_zeroes_everything() {
        let mut t = TrafficLog::new();
        t.gaussians_fetched = 9;
        t.preprocess_dram.bytes = 512;
        t.blend_sram.lookups = 3;
        t.clear();
        assert_eq!(t, TrafficLog::default());
    }

    #[test]
    fn json_has_expected_keys() {
        let t = TrafficLog::new();
        let s = t.to_json().pretty();
        assert!(s.contains("sram_hit_rate"));
        assert!(s.contains("gaussians_visible"));
    }

    #[test]
    fn paging_block_only_present_when_nonzero() {
        let mut t = TrafficLog::new();
        assert!(!t.to_json().pretty().contains("\"paging_dram\""));
        t.paging_dram.bytes = 2048;
        t.paging_dram.bursts = 64;
        let s = t.to_json().pretty();
        assert!(s.contains("\"paging_dram\""), "{s}");
        assert_eq!(t.total_dram_bytes(), 2048);
        assert_eq!(t.total_dram_accesses(), 64);
    }

    #[test]
    fn update_block_only_present_when_nonzero() {
        let mut t = TrafficLog::new();
        assert!(!t.to_json().pretty().contains("\"update_dram\""));
        t.update_dram.bytes = 4096;
        t.update_dram.bursts = 128;
        let s = t.to_json().pretty();
        assert!(s.contains("\"update_dram\""), "{s}");
        assert_eq!(t.total_dram_bytes(), 4096);
        assert_eq!(t.total_dram_accesses(), 128);
    }

    #[test]
    fn json_emits_full_dram_stats_per_stage() {
        let mut t = TrafficLog::new();
        t.preprocess_dram.busy_ns = 12.5;
        t.preprocess_dram.row_hits = 3;
        t.preprocess_dram.row_misses = 1;
        t.blend_dram.wait_ns = 4.0;
        t.blend_dram.stalls = 2;
        let s = t.to_json().pretty();
        // Nested per-stage blocks with the complete DramStats schema.
        assert!(s.contains("\"preprocess_dram\""), "{s}");
        assert!(s.contains("\"blend_dram\""), "{s}");
        assert!(s.contains("\"busy_ns\""), "{s}");
        assert!(s.contains("\"hit_rate\""), "{s}");
        assert!(s.contains("\"wait_ns\""), "{s}");
        assert!(s.contains("\"stalls\""), "{s}");
    }
}
