//! Streaming and batch statistics used by the benchmark harness, the
//! ATG threshold computation (K-highest/K-lowest medians, paper eq. 11),
//! and the evaluation reports.

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Median of a slice (average of middle two for even length).
/// O(n log n); fine for the sizes we use (K ≤ dozens, bench samples ≤ 1e4).
pub fn median(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy. Thin
/// delegation to the crate's single percentile implementation in
/// [`crate::obs::registry`] (kept here so callers of `math::stats` don't
/// need to know about the observability layer).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    crate::obs::registry::percentile(xs, p)
}

/// Coefficient of variation of bucket occupancies — the balance metric for
/// AII-Sort's "near-uniform distribution" claim (0 = perfectly balanced).
pub fn occupancy_cv(counts: &[usize]) -> f64 {
    if counts.is_empty() {
        return 0.0;
    }
    let n = counts.len() as f64;
    let mean = counts.iter().sum::<usize>() as f64 / n;
    if mean == 0.0 {
        return 0.0;
    }
    let var = counts.iter().map(|&c| (c as f64 - mean).powi(2)).sum::<f64>() / n;
    var.sqrt() / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        // Sample variance of that classic dataset is 32/7.
        assert!((r.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn median_odd_even_empty() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(median(&[7.0]), 7.0);
    }

    #[test]
    fn percentile_basic() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn occupancy_cv_uniform_is_zero() {
        assert_eq!(occupancy_cv(&[10, 10, 10, 10]), 0.0);
        assert!(occupancy_cv(&[40, 0, 0, 0]) > 1.0);
        assert_eq!(occupancy_cv(&[]), 0.0);
    }
}
