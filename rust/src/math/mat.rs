//! 3×3 and 4×4 matrices (row-major), used for covariances, rotations, and
//! camera view/projection transforms.

use super::vec::{Vec3, Vec4};

/// Row-major 3×3 matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Mat3 {
    /// `m[row][col]`
    pub m: [[f32; 3]; 3],
}

/// Row-major 4×4 matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Mat4 {
    /// `m[row][col]`
    pub m: [[f32; 4]; 4],
}

impl Mat3 {
    pub const IDENTITY: Mat3 = Mat3 {
        m: [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]],
    };

    pub const ZERO: Mat3 = Mat3 { m: [[0.0; 3]; 3] };

    #[inline]
    pub fn from_rows(r0: Vec3, r1: Vec3, r2: Vec3) -> Self {
        Mat3 {
            m: [r0.to_array(), r1.to_array(), r2.to_array()],
        }
    }

    #[inline]
    pub fn from_cols(c0: Vec3, c1: Vec3, c2: Vec3) -> Self {
        Mat3 {
            m: [
                [c0.x, c1.x, c2.x],
                [c0.y, c1.y, c2.y],
                [c0.z, c1.z, c2.z],
            ],
        }
    }

    /// Diagonal matrix from a vector.
    #[inline]
    pub fn diag(d: Vec3) -> Self {
        Mat3 {
            m: [[d.x, 0.0, 0.0], [0.0, d.y, 0.0], [0.0, 0.0, d.z]],
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> Vec3 {
        Vec3::from_array(self.m[r])
    }

    #[inline]
    pub fn col(&self, c: usize) -> Vec3 {
        Vec3::new(self.m[0][c], self.m[1][c], self.m[2][c])
    }

    #[inline]
    pub fn transpose(&self) -> Mat3 {
        Mat3::from_cols(self.row(0), self.row(1), self.row(2))
    }

    pub fn mul_mat(&self, o: &Mat3) -> Mat3 {
        let mut r = Mat3::ZERO;
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += self.m[i][k] * o.m[k][j];
                }
                r.m[i][j] = s;
            }
        }
        r
    }

    #[inline]
    pub fn mul_vec(&self, v: Vec3) -> Vec3 {
        Vec3::new(self.row(0).dot(v), self.row(1).dot(v), self.row(2).dot(v))
    }

    #[inline]
    pub fn scale(&self, s: f32) -> Mat3 {
        let mut r = *self;
        for i in 0..3 {
            for j in 0..3 {
                r.m[i][j] *= s;
            }
        }
        r
    }

    #[inline]
    pub fn add(&self, o: &Mat3) -> Mat3 {
        let mut r = *self;
        for i in 0..3 {
            for j in 0..3 {
                r.m[i][j] += o.m[i][j];
            }
        }
        r
    }

    #[inline]
    pub fn sub(&self, o: &Mat3) -> Mat3 {
        let mut r = *self;
        for i in 0..3 {
            for j in 0..3 {
                r.m[i][j] -= o.m[i][j];
            }
        }
        r
    }

    pub fn determinant(&self) -> f32 {
        let m = &self.m;
        m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
            - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
            + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
    }

    /// Inverse; returns `None` when the determinant is (near) zero.
    pub fn inverse(&self) -> Option<Mat3> {
        let det = self.determinant();
        if det.abs() < 1e-20 {
            return None;
        }
        let inv_det = 1.0 / det;
        let m = &self.m;
        let mut r = Mat3::ZERO;
        r.m[0][0] = (m[1][1] * m[2][2] - m[1][2] * m[2][1]) * inv_det;
        r.m[0][1] = (m[0][2] * m[2][1] - m[0][1] * m[2][2]) * inv_det;
        r.m[0][2] = (m[0][1] * m[1][2] - m[0][2] * m[1][1]) * inv_det;
        r.m[1][0] = (m[1][2] * m[2][0] - m[1][0] * m[2][2]) * inv_det;
        r.m[1][1] = (m[0][0] * m[2][2] - m[0][2] * m[2][0]) * inv_det;
        r.m[1][2] = (m[0][2] * m[1][0] - m[0][0] * m[1][2]) * inv_det;
        r.m[2][0] = (m[1][0] * m[2][1] - m[1][1] * m[2][0]) * inv_det;
        r.m[2][1] = (m[0][1] * m[2][0] - m[0][0] * m[2][1]) * inv_det;
        r.m[2][2] = (m[0][0] * m[1][1] - m[0][1] * m[1][0]) * inv_det;
        Some(r)
    }

    /// `v^T M v` quadratic form.
    #[inline]
    pub fn quadratic_form(&self, v: Vec3) -> f32 {
        v.dot(self.mul_vec(v))
    }

    /// Is this matrix symmetric within `eps`?
    pub fn is_symmetric(&self, eps: f32) -> bool {
        (self.m[0][1] - self.m[1][0]).abs() <= eps
            && (self.m[0][2] - self.m[2][0]).abs() <= eps
            && (self.m[1][2] - self.m[2][1]).abs() <= eps
    }
}

impl Mat4 {
    pub const IDENTITY: Mat4 = Mat4 {
        m: [
            [1.0, 0.0, 0.0, 0.0],
            [0.0, 1.0, 0.0, 0.0],
            [0.0, 0.0, 1.0, 0.0],
            [0.0, 0.0, 0.0, 1.0],
        ],
    };

    pub const ZERO: Mat4 = Mat4 { m: [[0.0; 4]; 4] };

    #[inline]
    pub fn row(&self, r: usize) -> Vec4 {
        Vec4::new(self.m[r][0], self.m[r][1], self.m[r][2], self.m[r][3])
    }

    pub fn mul_mat(&self, o: &Mat4) -> Mat4 {
        let mut r = Mat4::ZERO;
        for i in 0..4 {
            for j in 0..4 {
                let mut s = 0.0;
                for k in 0..4 {
                    s += self.m[i][k] * o.m[k][j];
                }
                r.m[i][j] = s;
            }
        }
        r
    }

    #[inline]
    pub fn mul_vec(&self, v: Vec4) -> Vec4 {
        Vec4::new(
            self.row(0).dot(v),
            self.row(1).dot(v),
            self.row(2).dot(v),
            self.row(3).dot(v),
        )
    }

    /// Transform a point (w = 1) without the perspective divide.
    #[inline]
    pub fn transform_point(&self, p: Vec3) -> Vec4 {
        self.mul_vec(p.extend(1.0))
    }

    /// Upper-left 3×3 block.
    #[inline]
    pub fn upper3(&self) -> Mat3 {
        Mat3 {
            m: [
                [self.m[0][0], self.m[0][1], self.m[0][2]],
                [self.m[1][0], self.m[1][1], self.m[1][2]],
                [self.m[2][0], self.m[2][1], self.m[2][2]],
            ],
        }
    }

    /// Rigid-transform inverse (rotation + translation only).
    pub fn rigid_inverse(&self) -> Mat4 {
        let r = self.upper3().transpose();
        let t = Vec3::new(self.m[0][3], self.m[1][3], self.m[2][3]);
        let ti = -r.mul_vec(t);
        Mat4 {
            m: [
                [r.m[0][0], r.m[0][1], r.m[0][2], ti.x],
                [r.m[1][0], r.m[1][1], r.m[1][2], ti.y],
                [r.m[2][0], r.m[2][1], r.m[2][2], ti.z],
                [0.0, 0.0, 0.0, 1.0],
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f32, b: f32) -> bool {
        (a - b).abs() < 1e-5
    }

    #[test]
    fn mat3_identity_mul() {
        let a = Mat3::from_rows(
            Vec3::new(1.0, 2.0, 3.0),
            Vec3::new(4.0, 5.0, 6.0),
            Vec3::new(7.0, 8.0, 10.0),
        );
        assert_eq!(a.mul_mat(&Mat3::IDENTITY), a);
        assert_eq!(Mat3::IDENTITY.mul_mat(&a), a);
    }

    #[test]
    fn mat3_inverse_roundtrip() {
        let a = Mat3::from_rows(
            Vec3::new(2.0, 0.5, 0.1),
            Vec3::new(0.5, 3.0, 0.2),
            Vec3::new(0.1, 0.2, 1.5),
        );
        let inv = a.inverse().unwrap();
        let prod = a.mul_mat(&inv);
        for i in 0..3 {
            for j in 0..3 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!(approx(prod.m[i][j], expect), "prod[{i}][{j}]={}", prod.m[i][j]);
            }
        }
    }

    #[test]
    fn mat3_singular_inverse_none() {
        let a = Mat3::from_rows(
            Vec3::new(1.0, 2.0, 3.0),
            Vec3::new(2.0, 4.0, 6.0),
            Vec3::new(0.0, 1.0, 1.0),
        );
        assert!(a.inverse().is_none());
    }

    #[test]
    fn mat3_transpose_symmetric() {
        let a = Mat3::from_rows(
            Vec3::new(2.0, 0.5, 0.1),
            Vec3::new(0.5, 3.0, 0.2),
            Vec3::new(0.1, 0.2, 1.5),
        );
        assert_eq!(a.transpose(), a);
        assert!(a.is_symmetric(0.0));
    }

    #[test]
    fn mat3_quadratic_form_positive_definite() {
        let a = Mat3::diag(Vec3::new(1.0, 2.0, 3.0));
        let v = Vec3::new(1.0, 1.0, 1.0);
        assert!(approx(a.quadratic_form(v), 6.0));
    }

    #[test]
    fn mat4_rigid_inverse() {
        // Rotation about z by 90° plus translation.
        let m = Mat4 {
            m: [
                [0.0, -1.0, 0.0, 3.0],
                [1.0, 0.0, 0.0, -2.0],
                [0.0, 0.0, 1.0, 5.0],
                [0.0, 0.0, 0.0, 1.0],
            ],
        };
        let inv = m.rigid_inverse();
        let prod = m.mul_mat(&inv);
        for i in 0..4 {
            for j in 0..4 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!(approx(prod.m[i][j], expect));
            }
        }
    }

    #[test]
    fn mat4_transform_point() {
        let m = Mat4::IDENTITY;
        let p = Vec3::new(1.0, 2.0, 3.0);
        assert_eq!(m.transform_point(p).truncate(), p);
    }
}
