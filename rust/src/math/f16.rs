//! IEEE-754 binary16 (half precision).
//!
//! The paper sets the accelerator's numerical precision to FP16 (§4). All
//! Gaussian parameters stored in DRAM/SRAM are FP16; the hardware-faithful
//! renderer quantizes through this type so PSNR reflects storage precision.
//! Implemented in-repo because the `half` crate is unavailable offline;
//! round-to-nearest-even, with correct subnormal/inf/NaN behavior.

/// A 16-bit IEEE-754 half-precision float stored as its bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct F16(pub u16);

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const ONE: F16 = F16(0x3C00);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    /// Largest finite half value (65504).
    pub const MAX: F16 = F16(0x7BFF);

    /// Convert from `f32` with round-to-nearest-even.
    pub fn from_f32(v: f32) -> F16 {
        let bits = v.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let frac = bits & 0x7F_FFFF;

        if exp == 0xFF {
            // Inf / NaN: preserve NaN-ness (quiet bit set).
            return if frac == 0 {
                F16(sign | 0x7C00)
            } else {
                F16(sign | 0x7E00)
            };
        }

        // Unbiased exponent, then re-bias for half (15).
        let e = exp - 127 + 15;
        if e >= 0x1F {
            // Overflow → ±inf.
            return F16(sign | 0x7C00);
        }
        if e <= 0 {
            // Subnormal half (or zero). Shift includes the implicit bit.
            if e < -10 {
                return F16(sign); // Rounds to ±0.
            }
            let mant = frac | 0x80_0000;
            let shift = 14 - e; // 14..24
            let half_frac = (mant >> shift) as u16;
            // Round-to-nearest-even on the dropped bits.
            let rem = mant & ((1 << shift) - 1);
            let halfway = 1u32 << (shift - 1);
            let rounded = if rem > halfway || (rem == halfway && (half_frac & 1) == 1) {
                half_frac + 1
            } else {
                half_frac
            };
            return F16(sign | rounded);
        }

        // Normal half. Keep 10 fraction bits, round-to-nearest-even.
        let half_frac = (frac >> 13) as u16;
        let rem = frac & 0x1FFF;
        let base = sign | ((e as u16) << 10) | half_frac;
        let rounded = if rem > 0x1000 || (rem == 0x1000 && (base & 1) == 1) {
            base + 1 // May carry into the exponent — that is correct rounding.
        } else {
            base
        };
        F16(rounded)
    }

    /// Convert to `f32` (exact).
    pub fn to_f32(self) -> f32 {
        let bits = self.0 as u32;
        let sign = (bits & 0x8000) << 16;
        let exp = (bits >> 10) & 0x1F;
        let frac = bits & 0x3FF;

        let out = if exp == 0 {
            if frac == 0 {
                sign // ±0
            } else {
                // Subnormal: normalize. A half subnormal is frac × 2⁻²⁴;
                // with the leading bit at position p the value is
                // 1.xxx × 2^(p−24), i.e. f32 exponent field 113 − shifts.
                let mut e = 0i32;
                let mut f = frac;
                while f & 0x400 == 0 {
                    f <<= 1;
                    e -= 1;
                }
                let f = f & 0x3FF;
                sign | (((113 + e) as u32) << 23) | (f << 13)
            }
        } else if exp == 0x1F {
            sign | 0x7F80_0000 | (frac << 13) // inf / NaN
        } else {
            sign | ((exp + 127 - 15) << 23) | (frac << 13)
        };
        f32::from_bits(out)
    }

    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x3FF) != 0
    }

    #[inline]
    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }
}

/// Quantize an `f32` through FP16 storage and back — what a parameter
/// experiences on its DRAM→SRAM→datapath round trip.
#[inline]
pub fn quantize(v: f32) -> f32 {
    F16::from_f32(v).to_f32()
}

/// Quantize a slice in place.
pub fn quantize_slice(vs: &mut [f32]) {
    for v in vs.iter_mut() {
        *v = quantize(*v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_roundtrip() {
        for i in -2048..=2048 {
            let v = i as f32;
            assert_eq!(quantize(v), v, "half must represent |int| <= 2048 exactly: {v}");
        }
    }

    #[test]
    fn one_and_fractions() {
        assert_eq!(F16::from_f32(1.0), F16::ONE);
        assert_eq!(quantize(0.5), 0.5);
        assert_eq!(quantize(0.25), 0.25);
        assert_eq!(quantize(1.5), 1.5);
    }

    #[test]
    fn overflow_to_infinity() {
        assert_eq!(F16::from_f32(1e6), F16::INFINITY);
        assert_eq!(F16::from_f32(-1e6), F16::NEG_INFINITY);
        assert_eq!(F16::from_f32(65504.0), F16::MAX);
        assert!(F16::from_f32(65536.0).is_infinite());
    }

    #[test]
    fn subnormals() {
        // Smallest positive subnormal half = 2^-24.
        let tiny = 2.0f32.powi(-24);
        assert_eq!(quantize(tiny), tiny);
        // Below half of it rounds to zero.
        assert_eq!(quantize(tiny / 4.0), 0.0);
        // Largest subnormal.
        let lsub = 2.0f32.powi(-14) - 2.0f32.powi(-24);
        assert_eq!(quantize(lsub), lsub);
    }

    #[test]
    fn nan_preserved() {
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert!(F16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10: ties to even → 1.0.
        let v = 1.0 + 2.0f32.powi(-11);
        assert_eq!(quantize(v), 1.0);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9 → ties to even → 1+2^-9.
        let v = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(quantize(v), 1.0 + 2.0 * 2.0f32.powi(-10));
    }

    #[test]
    fn quantization_error_bounded() {
        // Relative error of normal halves ≤ 2^-11.
        let mut x = 1.0e-3f32;
        while x < 6.0e4 {
            let q = quantize(x);
            assert!(((q - x) / x).abs() <= 2.0f32.powi(-11) + 1e-9, "x={x} q={q}");
            x *= 1.37;
        }
    }

    #[test]
    fn quantize_slice_works() {
        let mut v = vec![0.1f32, 0.2, 0.3];
        quantize_slice(&mut v);
        for (q, orig) in v.iter().zip([0.1f32, 0.2, 0.3]) {
            assert!((q - orig).abs() < 1e-3);
            assert_eq!(*q, quantize(orig));
        }
    }
}
