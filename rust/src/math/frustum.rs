//! View-frustum representation and AABB/sphere visibility tests.
//!
//! DR-FC (paper §3.1) tests whole cubic grids against the frustum before any
//! DRAM access; per-Gaussian exact culling afterwards uses a conservative
//! sphere test around the Gaussian's 3σ extent.

use super::aabb::Aabb;
use super::mat::Mat4;
use super::vec::Vec3;

/// A plane `n·x + d = 0` with `n` pointing toward the *inside* of the frustum.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Plane {
    pub n: Vec3,
    pub d: f32,
}

impl Plane {
    /// Normalize so |n| = 1 (keeps signed distances metric).
    pub fn normalized(self) -> Plane {
        let l = self.n.length();
        if l > 0.0 {
            Plane { n: self.n / l, d: self.d / l }
        } else {
            self
        }
    }

    /// Signed distance of a point (positive = inside halfspace).
    #[inline]
    pub fn distance(&self, p: Vec3) -> f32 {
        self.n.dot(p) + self.d
    }
}

/// Frustum culling verdict for a volume.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Containment {
    Outside,
    Intersecting,
    Inside,
}

/// Six-plane view frustum extracted from a view-projection matrix
/// (Gribb–Hartmann extraction, row-major `clip = VP * world`).
#[derive(Debug, Clone, Copy)]
pub struct Frustum {
    /// Order: left, right, bottom, top, near, far.
    pub planes: [Plane; 6],
}

impl Frustum {
    /// Extract from a combined view-projection matrix.
    pub fn from_view_proj(vp: &Mat4) -> Frustum {
        let r = |i: usize| vp.row(i);
        let (r0, r1, r2, r3) = (r(0), r(1), r(2), r(3));
        let mk = |v: super::vec::Vec4| {
            Plane { n: Vec3::new(v.x, v.y, v.z), d: v.w }.normalized()
        };
        Frustum {
            planes: [
                mk(r3 + r0), // left:   w + x >= 0
                mk(r3 - r0), // right:  w - x >= 0
                mk(r3 + r1), // bottom
                mk(r3 - r1), // top
                mk(r3 + r2), // near (z in [-w, w] convention)
                mk(r3 - r2), // far
            ],
        }
    }

    /// Conservative AABB test (positive-vertex method).
    pub fn test_aabb(&self, b: &Aabb) -> Containment {
        let mut inside_all = true;
        for p in &self.planes {
            let pv = b.positive_vertex(p.n);
            if p.distance(pv) < 0.0 {
                return Containment::Outside;
            }
            // Negative vertex = corner least along n.
            let nv = b.positive_vertex(-p.n);
            if p.distance(nv) < 0.0 {
                inside_all = false;
            }
        }
        if inside_all {
            Containment::Inside
        } else {
            Containment::Intersecting
        }
    }

    /// Sphere visibility (center + radius), the per-Gaussian exact test.
    pub fn test_sphere(&self, c: Vec3, r: f32) -> bool {
        self.planes.iter().all(|p| p.distance(c) >= -r)
    }

    /// Point visibility.
    pub fn contains_point(&self, p: Vec3) -> bool {
        self.test_sphere(p, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::Camera;

    fn test_camera() -> Camera {
        Camera::look_at(
            Vec3::new(0.0, 0.0, 0.0),
            Vec3::new(0.0, 0.0, -1.0),
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            16.0 / 9.0,
            0.1,
            100.0,
        )
    }

    #[test]
    fn point_straight_ahead_is_visible() {
        let cam = test_camera();
        let f = cam.frustum();
        assert!(f.contains_point(Vec3::new(0.0, 0.0, -10.0)));
        assert!(!f.contains_point(Vec3::new(0.0, 0.0, 10.0)), "behind camera");
        assert!(!f.contains_point(Vec3::new(0.0, 0.0, -200.0)), "beyond far");
    }

    #[test]
    fn aabb_containment_levels() {
        let cam = test_camera();
        let f = cam.frustum();
        let inside = Aabb::from_center_half(Vec3::new(0.0, 0.0, -10.0), Vec3::splat(0.5));
        let outside = Aabb::from_center_half(Vec3::new(0.0, 0.0, 50.0), Vec3::splat(0.5));
        let straddle = Aabb::from_center_half(Vec3::new(0.0, 0.0, -0.1), Vec3::splat(5.0));
        assert_eq!(f.test_aabb(&inside), Containment::Inside);
        assert_eq!(f.test_aabb(&outside), Containment::Outside);
        assert_eq!(f.test_aabb(&straddle), Containment::Intersecting);
    }

    #[test]
    fn sphere_near_edge() {
        let cam = test_camera();
        let f = cam.frustum();
        // A point far off to the side is out, but a big enough sphere pokes in.
        let p = Vec3::new(30.0, 0.0, -10.0);
        assert!(!f.contains_point(p));
        assert!(f.test_sphere(p, 25.0));
    }

    #[test]
    fn aabb_test_is_conservative_wrt_points() {
        // If any sampled point of the box is visible, the box must not be Outside.
        let cam = test_camera();
        let f = cam.frustum();
        let b = Aabb::from_center_half(Vec3::new(3.0, 1.0, -20.0), Vec3::splat(4.0));
        let mut any_visible = false;
        for i in 0..5 {
            for j in 0..5 {
                for k in 0..5 {
                    let p = b.min
                        + Vec3::new(
                            b.extent().x * i as f32 / 4.0,
                            b.extent().y * j as f32 / 4.0,
                            b.extent().z * k as f32 / 4.0,
                        );
                    if f.contains_point(p) {
                        any_visible = true;
                    }
                }
            }
        }
        if any_visible {
            assert_ne!(f.test_aabb(&b), Containment::Outside);
        }
    }
}
