//! Small fixed-size vectors (`Vec2`, `Vec3`, `Vec4`) in `f32`.
//!
//! The pipeline's numeric path is `f32` end to end; FP16 storage effects are
//! applied explicitly via [`crate::math::f16`] when quantizing parameters.

use std::ops::{Add, AddAssign, Div, Index, Mul, Neg, Sub};

/// 2-component vector (pixel coordinates, 2D means).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Vec2 {
    pub x: f32,
    pub y: f32,
}

/// 3-component vector (positions, scales, colors).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Vec3 {
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

/// 4-component vector (homogeneous positions, 4D means, quaternion storage).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Vec4 {
    pub x: f32,
    pub y: f32,
    pub z: f32,
    pub w: f32,
}

impl Vec2 {
    pub const ZERO: Vec2 = Vec2 { x: 0.0, y: 0.0 };

    #[inline]
    pub fn new(x: f32, y: f32) -> Self {
        Vec2 { x, y }
    }

    #[inline]
    pub fn dot(self, o: Vec2) -> f32 {
        self.x * o.x + self.y * o.y
    }

    #[inline]
    pub fn length(self) -> f32 {
        self.dot(self).sqrt()
    }
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };
    pub const ONE: Vec3 = Vec3 { x: 1.0, y: 1.0, z: 1.0 };

    #[inline]
    pub fn new(x: f32, y: f32, z: f32) -> Self {
        Vec3 { x, y, z }
    }

    #[inline]
    pub fn splat(v: f32) -> Self {
        Vec3::new(v, v, v)
    }

    #[inline]
    pub fn dot(self, o: Vec3) -> f32 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    #[inline]
    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    #[inline]
    pub fn length(self) -> f32 {
        self.dot(self).sqrt()
    }

    #[inline]
    pub fn normalized(self) -> Vec3 {
        let l = self.length();
        if l > 0.0 {
            self / l
        } else {
            Vec3::ZERO
        }
    }

    /// Component-wise minimum.
    #[inline]
    pub fn min(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.min(o.x), self.y.min(o.y), self.z.min(o.z))
    }

    /// Component-wise maximum.
    #[inline]
    pub fn max(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.max(o.x), self.y.max(o.y), self.z.max(o.z))
    }

    /// Component-wise product.
    #[inline]
    pub fn hadamard(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x * o.x, self.y * o.y, self.z * o.z)
    }

    /// Largest component.
    #[inline]
    pub fn max_component(self) -> f32 {
        self.x.max(self.y).max(self.z)
    }

    #[inline]
    pub fn to_array(self) -> [f32; 3] {
        [self.x, self.y, self.z]
    }

    #[inline]
    pub fn from_array(a: [f32; 3]) -> Self {
        Vec3::new(a[0], a[1], a[2])
    }

    /// Extend with a w component.
    #[inline]
    pub fn extend(self, w: f32) -> Vec4 {
        Vec4::new(self.x, self.y, self.z, w)
    }
}

impl Vec4 {
    pub const ZERO: Vec4 = Vec4 { x: 0.0, y: 0.0, z: 0.0, w: 0.0 };

    #[inline]
    pub fn new(x: f32, y: f32, z: f32, w: f32) -> Self {
        Vec4 { x, y, z, w }
    }

    #[inline]
    pub fn dot(self, o: Vec4) -> f32 {
        self.x * o.x + self.y * o.y + self.z * o.z + self.w * o.w
    }

    /// Drop the w component.
    #[inline]
    pub fn truncate(self) -> Vec3 {
        Vec3::new(self.x, self.y, self.z)
    }

    /// Perspective divide (panics in debug if w == 0).
    #[inline]
    pub fn project(self) -> Vec3 {
        debug_assert!(self.w != 0.0, "perspective divide by zero");
        Vec3::new(self.x / self.w, self.y / self.w, self.z / self.w)
    }

    #[inline]
    pub fn to_array(self) -> [f32; 4] {
        [self.x, self.y, self.z, self.w]
    }
}

macro_rules! impl_vec_ops {
    ($t:ty, $($f:ident),+) => {
        impl Add for $t {
            type Output = $t;
            #[inline]
            fn add(self, o: $t) -> $t { <$t>::new($(self.$f + o.$f),+) }
        }
        impl Sub for $t {
            type Output = $t;
            #[inline]
            fn sub(self, o: $t) -> $t { <$t>::new($(self.$f - o.$f),+) }
        }
        impl Mul<f32> for $t {
            type Output = $t;
            #[inline]
            fn mul(self, s: f32) -> $t { <$t>::new($(self.$f * s),+) }
        }
        impl Mul<$t> for f32 {
            type Output = $t;
            #[inline]
            fn mul(self, v: $t) -> $t { v * self }
        }
        impl Div<f32> for $t {
            type Output = $t;
            #[inline]
            fn div(self, s: f32) -> $t { <$t>::new($(self.$f / s),+) }
        }
        impl Neg for $t {
            type Output = $t;
            #[inline]
            fn neg(self) -> $t { <$t>::new($(-self.$f),+) }
        }
        impl AddAssign for $t {
            #[inline]
            fn add_assign(&mut self, o: $t) { *self = *self + o; }
        }
    };
}

impl_vec_ops!(Vec2, x, y);
impl_vec_ops!(Vec3, x, y, z);
impl_vec_ops!(Vec4, x, y, z, w);

impl Index<usize> for Vec3 {
    type Output = f32;
    #[inline]
    fn index(&self, i: usize) -> &f32 {
        match i {
            0 => &self.x,
            1 => &self.y,
            2 => &self.z,
            _ => panic!("Vec3 index {i} out of range"),
        }
    }
}

impl Index<usize> for Vec4 {
    type Output = f32;
    #[inline]
    fn index(&self, i: usize) -> &f32 {
        match i {
            0 => &self.x,
            1 => &self.y,
            2 => &self.z,
            3 => &self.w,
            _ => panic!("Vec4 index {i} out of range"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec3_basic_ops() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(4.0, 5.0, 6.0);
        assert_eq!(a + b, Vec3::new(5.0, 7.0, 9.0));
        assert_eq!(b - a, Vec3::new(3.0, 3.0, 3.0));
        assert_eq!(a * 2.0, Vec3::new(2.0, 4.0, 6.0));
        assert_eq!(a.dot(b), 32.0);
    }

    #[test]
    fn vec3_cross_is_orthogonal() {
        let a = Vec3::new(1.0, 0.5, -2.0);
        let b = Vec3::new(-0.3, 2.0, 1.0);
        let c = a.cross(b);
        assert!(c.dot(a).abs() < 1e-5);
        assert!(c.dot(b).abs() < 1e-5);
    }

    #[test]
    fn vec3_normalized_unit_length() {
        let v = Vec3::new(3.0, 4.0, 12.0).normalized();
        assert!((v.length() - 1.0).abs() < 1e-6);
        assert_eq!(Vec3::ZERO.normalized(), Vec3::ZERO);
    }

    #[test]
    fn vec4_project() {
        let v = Vec4::new(2.0, 4.0, 6.0, 2.0);
        assert_eq!(v.project(), Vec3::new(1.0, 2.0, 3.0));
    }

    #[test]
    fn vec3_minmax_hadamard() {
        let a = Vec3::new(1.0, 5.0, 3.0);
        let b = Vec3::new(2.0, 4.0, 9.0);
        assert_eq!(a.min(b), Vec3::new(1.0, 4.0, 3.0));
        assert_eq!(a.max(b), Vec3::new(2.0, 5.0, 9.0));
        assert_eq!(a.hadamard(b), Vec3::new(2.0, 20.0, 27.0));
        assert_eq!(a.max_component(), 5.0);
    }

    #[test]
    fn vec2_length() {
        assert_eq!(Vec2::new(3.0, 4.0).length(), 5.0);
    }
}
