//! Linear algebra and numeric substrate.
//!
//! Everything the splatting pipeline needs and the offline registry does not
//! provide: small fixed-size vectors/matrices, quaternions, IEEE-754 half
//! precision (the paper stores all Gaussian parameters as FP16), axis-aligned
//! bounding boxes, view-frustum plane tests, and streaming statistics.

pub mod aabb;
pub mod f16;
pub mod frustum;
pub mod mat;
pub mod quat;
pub mod stats;
pub mod vec;

pub use aabb::Aabb;
pub use f16::F16;
pub use frustum::Frustum;
pub use mat::{Mat3, Mat4};
pub use quat::Quat;
pub use vec::{Vec2, Vec3, Vec4};
