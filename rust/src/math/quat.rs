//! Unit quaternions for Gaussian orientation.
//!
//! 4DGS parameterizes Σ⁴ᴰ = U S Sᵀ Uᵀ with U built from a *pair* of unit
//! quaternions (left/right isoclinic rotations of SO(4)); for the 3-D spatial
//! block we only need the classic quaternion → rotation-matrix map.

use super::mat::Mat3;
use super::vec::Vec3;

/// Quaternion `w + xi + yj + zk`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quat {
    pub w: f32,
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

impl Quat {
    pub const IDENTITY: Quat = Quat { w: 1.0, x: 0.0, y: 0.0, z: 0.0 };

    #[inline]
    pub fn new(w: f32, x: f32, y: f32, z: f32) -> Self {
        Quat { w, x, y, z }
    }

    /// Rotation of `angle` radians about `axis` (need not be normalized).
    pub fn from_axis_angle(axis: Vec3, angle: f32) -> Self {
        let a = axis.normalized();
        let (s, c) = (angle * 0.5).sin_cos();
        Quat::new(c, a.x * s, a.y * s, a.z * s)
    }

    #[inline]
    pub fn norm(self) -> f32 {
        (self.w * self.w + self.x * self.x + self.y * self.y + self.z * self.z).sqrt()
    }

    pub fn normalized(self) -> Quat {
        let n = self.norm();
        if n > 0.0 {
            Quat::new(self.w / n, self.x / n, self.y / n, self.z / n)
        } else {
            Quat::IDENTITY
        }
    }

    /// Hamilton product.
    pub fn mul(self, o: Quat) -> Quat {
        Quat::new(
            self.w * o.w - self.x * o.x - self.y * o.y - self.z * o.z,
            self.w * o.x + self.x * o.w + self.y * o.z - self.z * o.y,
            self.w * o.y - self.x * o.z + self.y * o.w + self.z * o.x,
            self.w * o.z + self.x * o.y - self.y * o.x + self.z * o.w,
        )
    }

    /// Rotation matrix of the (assumed unit) quaternion.
    pub fn to_mat3(self) -> Mat3 {
        let Quat { w, x, y, z } = self;
        let (x2, y2, z2) = (x + x, y + y, z + z);
        let (xx, yy, zz) = (x * x2, y * y2, z * z2);
        let (xy, xz, yz) = (x * y2, x * z2, y * z2);
        let (wx, wy, wz) = (w * x2, w * y2, w * z2);
        Mat3 {
            m: [
                [1.0 - (yy + zz), xy - wz, xz + wy],
                [xy + wz, 1.0 - (xx + zz), yz - wx],
                [xz - wy, yz + wx, 1.0 - (xx + yy)],
            ],
        }
    }

    /// Rotate a vector.
    #[inline]
    pub fn rotate(self, v: Vec3) -> Vec3 {
        self.to_mat3().mul_vec(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx_vec(a: Vec3, b: Vec3) -> bool {
        (a - b).length() < 1e-5
    }

    #[test]
    fn identity_rotation() {
        let v = Vec3::new(1.0, 2.0, 3.0);
        assert!(approx_vec(Quat::IDENTITY.rotate(v), v));
    }

    #[test]
    fn z_axis_quarter_turn() {
        let q = Quat::from_axis_angle(Vec3::new(0.0, 0.0, 1.0), std::f32::consts::FRAC_PI_2);
        let v = q.rotate(Vec3::new(1.0, 0.0, 0.0));
        assert!(approx_vec(v, Vec3::new(0.0, 1.0, 0.0)), "got {v:?}");
    }

    #[test]
    fn rotation_matrix_is_orthogonal() {
        let q = Quat::from_axis_angle(Vec3::new(1.0, 2.0, -0.5), 1.234).normalized();
        let r = q.to_mat3();
        let rrt = r.mul_mat(&r.transpose());
        for i in 0..3 {
            for j in 0..3 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((rrt.m[i][j] - expect).abs() < 1e-5);
            }
        }
        assert!((r.determinant() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn hamilton_product_composes_rotations() {
        let qa = Quat::from_axis_angle(Vec3::new(0.0, 0.0, 1.0), 0.7);
        let qb = Quat::from_axis_angle(Vec3::new(0.0, 1.0, 0.0), -0.4);
        let v = Vec3::new(0.3, -1.0, 2.0);
        let ab = qa.mul(qb);
        assert!(approx_vec(ab.rotate(v), qa.rotate(qb.rotate(v))));
    }

    #[test]
    fn normalize_handles_zero() {
        let q = Quat::new(0.0, 0.0, 0.0, 0.0).normalized();
        assert_eq!(q, Quat::IDENTITY);
    }
}
