//! Axis-aligned bounding boxes, used for cubic culling grids and Gaussian
//! spatial extents (mean ± k·σ per axis).

use super::vec::Vec3;

/// Axis-aligned box `[min, max]` (inclusive).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Aabb {
    pub min: Vec3,
    pub max: Vec3,
}

impl Aabb {
    /// Empty box (min > max); grows on the first `expand`.
    pub const EMPTY: Aabb = Aabb {
        min: Vec3 { x: f32::INFINITY, y: f32::INFINITY, z: f32::INFINITY },
        max: Vec3 { x: f32::NEG_INFINITY, y: f32::NEG_INFINITY, z: f32::NEG_INFINITY },
    };

    #[inline]
    pub fn new(min: Vec3, max: Vec3) -> Self {
        Aabb { min, max }
    }

    /// Box centered at `c` with half-extents `h` (per axis).
    #[inline]
    pub fn from_center_half(c: Vec3, h: Vec3) -> Self {
        Aabb { min: c - h, max: c + h }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.min.x > self.max.x || self.min.y > self.max.y || self.min.z > self.max.z
    }

    #[inline]
    pub fn center(&self) -> Vec3 {
        (self.min + self.max) * 0.5
    }

    #[inline]
    pub fn extent(&self) -> Vec3 {
        self.max - self.min
    }

    /// Grow to include a point.
    #[inline]
    pub fn expand(&mut self, p: Vec3) {
        self.min = self.min.min(p);
        self.max = self.max.max(p);
    }

    /// Grow to include another box.
    #[inline]
    pub fn union(&self, o: &Aabb) -> Aabb {
        Aabb { min: self.min.min(o.min), max: self.max.max(o.max) }
    }

    #[inline]
    pub fn contains(&self, p: Vec3) -> bool {
        p.x >= self.min.x
            && p.x <= self.max.x
            && p.y >= self.min.y
            && p.y <= self.max.y
            && p.z >= self.min.z
            && p.z <= self.max.z
    }

    #[inline]
    pub fn intersects(&self, o: &Aabb) -> bool {
        self.min.x <= o.max.x
            && self.max.x >= o.min.x
            && self.min.y <= o.max.y
            && self.max.y >= o.min.y
            && self.min.z <= o.max.z
            && self.max.z >= o.min.z
    }

    /// The corner most positive along `n` — used for plane-side tests.
    #[inline]
    pub fn positive_vertex(&self, n: Vec3) -> Vec3 {
        Vec3::new(
            if n.x >= 0.0 { self.max.x } else { self.min.x },
            if n.y >= 0.0 { self.max.y } else { self.min.y },
            if n.z >= 0.0 { self.max.z } else { self.min.z },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_then_expand() {
        let mut b = Aabb::EMPTY;
        assert!(b.is_empty());
        b.expand(Vec3::new(1.0, 2.0, 3.0));
        assert!(!b.is_empty());
        assert_eq!(b.min, b.max);
        b.expand(Vec3::new(-1.0, 5.0, 0.0));
        assert_eq!(b.min, Vec3::new(-1.0, 2.0, 0.0));
        assert_eq!(b.max, Vec3::new(1.0, 5.0, 3.0));
    }

    #[test]
    fn contains_and_intersects() {
        let a = Aabb::new(Vec3::ZERO, Vec3::ONE);
        assert!(a.contains(Vec3::splat(0.5)));
        assert!(!a.contains(Vec3::splat(1.5)));
        let b = Aabb::new(Vec3::splat(0.9), Vec3::splat(2.0));
        let c = Aabb::new(Vec3::splat(1.1), Vec3::splat(2.0));
        assert!(a.intersects(&b));
        assert!(!a.intersects(&c));
    }

    #[test]
    fn center_extent_union() {
        let a = Aabb::from_center_half(Vec3::splat(1.0), Vec3::splat(0.5));
        assert_eq!(a.center(), Vec3::splat(1.0));
        assert_eq!(a.extent(), Vec3::splat(1.0));
        let b = Aabb::new(Vec3::splat(2.0), Vec3::splat(3.0));
        let u = a.union(&b);
        assert_eq!(u.min, Vec3::splat(0.5));
        assert_eq!(u.max, Vec3::splat(3.0));
    }

    #[test]
    fn positive_vertex_picks_corner() {
        let a = Aabb::new(Vec3::ZERO, Vec3::ONE);
        assert_eq!(a.positive_vertex(Vec3::new(1.0, -1.0, 1.0)), Vec3::new(1.0, 0.0, 1.0));
    }
}
