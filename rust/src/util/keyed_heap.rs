//! A keyed min-heap with lazy invalidation — the indexed priority
//! structure behind the session scheduler's DWFQ/EDF issue order.
//!
//! The scheduler needs, every round, the ascending `(key, id)` order of
//! the *renderable* sessions — and only sessions that rendered this round
//! change their key. A full sort re-pays `O(n log n)` over the whole ring
//! (including completed-but-not-departed members it then filters out);
//! this heap pays `O(log n)` per re-keyed member instead, and stale
//! entries left behind by re-keys and removals are skipped lazily at pop
//! time via per-id generation stamps.
//!
//! Ordering contract: entries pop in ascending `f64::total_cmp` key
//! order, ties broken by ascending id — **exactly** the comparator of the
//! sort-based reference (`coordinator::session::key_order`), including
//! NaN keys (which `total_cmp` places after `+inf`, deterministically).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One heap entry. `gen` stamps the insertion; an entry is live only
/// while it matches the id's current generation.
#[derive(Debug, Clone, Copy)]
struct Entry {
    key: f64,
    id: usize,
    gen: u64,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    /// Inverted on purpose: `BinaryHeap` is a max-heap, so "greater"
    /// here means smaller `(key, id)` — pops come out ascending. The
    /// generation tie-break only keeps `Ord` total (at most one
    /// generation per id is ever live).
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .key
            .total_cmp(&self.key)
            .then(other.id.cmp(&self.id))
            .then(other.gen.cmp(&self.gen))
    }
}

/// Keyed min-heap over `usize` ids with `f64` keys and O(1) lazy removal.
#[derive(Debug, Default)]
pub struct KeyedMinHeap {
    heap: BinaryHeap<Entry>,
    /// Current generation per id; bumped on every update/remove so older
    /// heap entries for the id turn stale.
    gen: Vec<u64>,
    /// Whether the id is currently a live member.
    live: Vec<bool>,
    len: usize,
}

impl KeyedMinHeap {
    pub fn new() -> KeyedMinHeap {
        KeyedMinHeap::default()
    }

    /// Live member count.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn contains(&self, id: usize) -> bool {
        self.live.get(id).copied().unwrap_or(false)
    }

    fn ensure(&mut self, id: usize) {
        if id >= self.gen.len() {
            self.gen.resize(id + 1, 0);
            self.live.resize(id + 1, false);
        }
    }

    /// Insert `id` with `key`, or re-key it if already a member. The old
    /// entry (if any) is invalidated lazily, not searched for.
    pub fn update(&mut self, id: usize, key: f64) {
        self.ensure(id);
        self.gen[id] += 1;
        if !self.live[id] {
            self.live[id] = true;
            self.len += 1;
        }
        self.heap.push(Entry { key, id, gen: self.gen[id] });
    }

    /// Remove `id` from the queue (no-op if absent). O(1): the heap entry
    /// goes stale and is discarded whenever it surfaces.
    pub fn remove(&mut self, id: usize) {
        if self.contains(id) {
            self.gen[id] += 1;
            self.live[id] = false;
            self.len -= 1;
        }
    }

    /// Pop the minimum live `(id, key)` (ascending `total_cmp` key, ties
    /// by ascending id), discarding stale entries on the way.
    pub fn pop(&mut self) -> Option<(usize, f64)> {
        while let Some(e) = self.heap.pop() {
            if self.live.get(e.id).copied().unwrap_or(false) && self.gen[e.id] == e.gen {
                self.live[e.id] = false;
                self.len -= 1;
                return Some((e.id, e.key));
            }
        }
        None
    }

    /// Drain every live member into `into` in ascending `(key, id)` order
    /// (the queue is empty afterwards — the caller re-inserts whichever
    /// members remain eligible with their fresh keys).
    pub fn drain_ordered_into(&mut self, into: &mut Vec<usize>) {
        into.clear();
        while let Some((id, _)) = self.pop() {
            into.push(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// The sort-based reference order: ascending (total_cmp key, id).
    fn reference_order(pairs: &[(usize, f64)]) -> Vec<usize> {
        let mut ids: Vec<usize> = pairs.iter().map(|&(id, _)| id).collect();
        let key = |id: usize| pairs.iter().find(|&&(i, _)| i == id).unwrap().1;
        ids.sort_by(|&a, &b| key(a).total_cmp(&key(b)).then(a.cmp(&b)));
        ids
    }

    #[test]
    fn drains_in_ascending_key_then_id_order() {
        let mut h = KeyedMinHeap::new();
        for &(id, key) in &[(3usize, 2.0f64), (0, 5.0), (7, 2.0), (1, 0.5)] {
            h.update(id, key);
        }
        let mut out = Vec::new();
        h.drain_ordered_into(&mut out);
        assert_eq!(out, vec![1, 3, 7, 0]);
        assert!(h.is_empty());
    }

    #[test]
    fn update_rekeys_and_remove_invalidates_lazily() {
        let mut h = KeyedMinHeap::new();
        h.update(0, 1.0);
        h.update(1, 2.0);
        h.update(2, 3.0);
        h.update(0, 10.0); // re-key: old entry goes stale
        h.remove(1);
        assert_eq!(h.len(), 2);
        assert!(!h.contains(1));
        let mut out = Vec::new();
        h.drain_ordered_into(&mut out);
        assert_eq!(out, vec![2, 0]);
        // Removing an absent id is a no-op.
        h.remove(17);
        assert!(h.is_empty());
    }

    #[test]
    fn nan_keys_order_after_infinity_deterministically() {
        let mut h = KeyedMinHeap::new();
        h.update(4, f64::NAN);
        h.update(2, f64::INFINITY);
        h.update(9, 1.0);
        h.update(5, f64::NAN);
        let mut out = Vec::new();
        h.drain_ordered_into(&mut out);
        // total_cmp places positive NaN after +inf; NaN ties break by id.
        assert_eq!(out, vec![9, 2, 4, 5]);
    }

    #[test]
    fn randomized_drain_matches_sort_reference() {
        let mut rng = Rng::new(0xC1A0);
        for case in 0..50u64 {
            let mut r = rng.fork(case);
            let n = 1 + r.below(40);
            let mut pairs: Vec<(usize, f64)> = (0..n)
                .map(|id| {
                    let key = match r.below(10) {
                        0 => f64::INFINITY,
                        1 => f64::NAN,
                        _ => r.f64() * 1e9,
                    };
                    (id, key)
                })
                .collect();
            // Duplicate keys to exercise the id tie-break.
            if n > 2 {
                let k = pairs[0].1;
                pairs[n / 2].1 = k;
            }
            let mut h = KeyedMinHeap::new();
            for &(id, key) in &pairs {
                h.update(id, key);
            }
            // Churn: re-key a third, remove a few, re-add one.
            for &(id, _) in pairs.iter().filter(|&&(id, _)| id % 3 == 0) {
                let fresh = r.f64() * 1e9;
                h.update(id, fresh);
                if let Some(p) = pairs.iter_mut().find(|p| p.0 == id) {
                    p.1 = fresh;
                }
            }
            if n > 4 {
                h.remove(1);
                pairs.retain(|&(id, _)| id != 1);
            }
            let mut got = Vec::new();
            h.drain_ordered_into(&mut got);
            assert_eq!(got, reference_order(&pairs), "case {case}");
        }
    }
}
