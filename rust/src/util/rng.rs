//! Deterministic pseudo-random numbers: SplitMix64 seeding + xoshiro256**.
//!
//! Every stochastic component (scene synthesis, trajectories, property
//! tests) takes an explicit seed so all experiments are reproducible
//! bit-for-bit — a requirement for regenerating the paper's figures.

/// SplitMix64 step — used to expand a single u64 seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator (Blackman & Vigna), period 2^256 − 1.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per scene cluster / per test case).
    pub fn fork(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ label.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 high bits → exactly representable dyadics in [0,1).
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection-free
    /// approximation (bias < 2^-32 for n << 2^32 — fine for simulation).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (std::f32::consts::TAU * u2).cos()
    }

    /// Normal with mean/σ.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, sigma: f32) -> f32 {
        mean + sigma * self.normal()
    }

    /// Log-normal (μ, σ of the underlying normal) — matches the skewed
    /// near-field-dense depth distributions of real captured scenes.
    pub fn log_normal(&mut self, mu: f32, sigma: f32) -> f32 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely to be identity");
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
