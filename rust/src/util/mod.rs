//! Offline-friendly utilities replacing crates unavailable in this
//! environment's registry (see DESIGN.md §3 "Offline-dependency note"):
//! deterministic RNG (`rand`), arg parsing (`clap`), JSON emission
//! (`serde_json`), wall-clock timers, and a seeded property-testing harness
//! (`proptest`).

pub mod cli;
pub mod json;
pub mod keyed_heap;
pub mod proptest;
pub mod rng;
pub mod timer;

pub use keyed_heap::KeyedMinHeap;
pub use rng::Rng;
pub use timer::Timer;
