//! Tiny JSON document builder (offline replacement for `serde_json`) used to
//! dump metrics and experiment reports under `reports/`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if not an object — builder misuse).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad_in = "  ".repeat(indent + 1);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    out.push_str(&pad_in);
                    x.write(out, indent + 1);
                    if i + 1 < xs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad_in);
                    Json::Str(k.clone()).write(out, indent + 1);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_serializes() {
        let j = Json::obj()
            .set("name", "fig9")
            .set("grid", 4u64)
            .set("reduction", 2.94f64)
            .set("ok", true)
            .set("series", vec![1.0f64, 2.0, 3.0]);
        let s = j.pretty();
        assert!(s.contains("\"name\": \"fig9\""));
        assert!(s.contains("\"grid\": 4"));
        assert!(s.contains("2.94"));
        assert!(s.contains("true"));
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.pretty(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(42.0).pretty(), "42");
        assert_eq!(Json::Num(1.5).pretty(), "1.5");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).pretty(), "null");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).pretty(), "[]");
        assert_eq!(Json::obj().pretty(), "{}");
    }
}

/// Parse a JSON document (offline replacement for `serde_json::from_str`).
/// Supports the full JSON grammar except `\uXXXX` surrogate pairs (BMP
/// escapes are handled).
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|e| e.to_string())?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

impl Json {
    /// Object field accessor (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0).map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[cfg(test)]
mod parse_tests {
    use super::*;

    #[test]
    fn roundtrip_pretty_parse() {
        let j = Json::obj()
            .set("name", "fig9")
            .set("grid", 4u64)
            .set("nested", Json::obj().set("ok", true).set("pi", 3.25f64))
            .set("arr", vec![1.0f64, 2.0, 3.0])
            .set("nul", Json::Null);
        let parsed = parse(&j.pretty()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#"{"s": "a\"b\\c\ndAé"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\"b\\c\ndAé");
    }

    #[test]
    fn numbers_and_accessors() {
        let v = parse(r#"{"a": -1.5e2, "b": 42, "c": true, "d": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(-150.0));
        assert_eq!(v.get("b").unwrap().as_usize(), Some(42));
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("d").unwrap().as_str(), Some("x"));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} trailing").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn empty_containers_parse() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::obj());
    }
}
