//! Minimal command-line parsing (offline replacement for `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional args,
//! and subcommands; generates usage text from registered options.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand, `--key value` options, flags, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Known boolean flags (everything else with `--` takes a value unless
    /// it is last or followed by another `--` token).
    pub const KNOWN_FLAGS: &'static [&'static str] =
        &["verbose", "quiet", "help", "sessions", "dynamic"];

    /// Parse raw arguments (without argv[0]). `subcommands` lists words that,
    /// when found first, become the subcommand.
    pub fn parse(raw: &[String], subcommands: &[&str]) -> Args {
        let mut a = Args::default();
        let mut it = raw.iter().peekable();
        if let Some(first) = it.peek() {
            if subcommands.contains(&first.as_str()) {
                a.subcommand = Some(it.next().unwrap().clone());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if Self::KNOWN_FLAGS.contains(&stripped) {
                    a.flags.push(stripped.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    a.opts.insert(stripped.to_string(), it.next().unwrap().clone());
                } else {
                    a.flags.push(stripped.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        a
    }

    /// Parse from the process environment.
    pub fn from_env(subcommands: &[&str]) -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, subcommands)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            Some(s) => s.parse().unwrap_or(default),
            None => default,
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get_parsed(name, default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get_parsed(name, default)
    }

    pub fn get_f32(&self, name: &str, default: f32) -> f32 {
        self.get_parsed(name, default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::parse(
            &sv(&["render", "--frames", "10", "--scene=dynamic", "--verbose", "out.ppm"]),
            &["render", "bench"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("render"));
        assert_eq!(a.get("frames"), Some("10"));
        assert_eq!(a.get("scene"), Some("dynamic"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.ppm"]);
    }

    #[test]
    fn typed_getters_with_defaults() {
        let a = Args::parse(&sv(&["--n", "8", "--th", "0.5"]), &[]);
        assert_eq!(a.get_usize("n", 4), 8);
        assert_eq!(a.get_usize("missing", 4), 4);
        assert_eq!(a.get_f32("th", 0.3), 0.5);
        assert_eq!(a.get_u64("seed", 42), 42);
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let a = Args::parse(&sv(&["--quiet", "--frames", "3"]), &[]);
        assert!(a.flag("quiet"));
        assert_eq!(a.get_usize("frames", 0), 3);
    }

    #[test]
    fn bad_parse_falls_back_to_default() {
        let a = Args::parse(&sv(&["--n", "notanumber"]), &[]);
        assert_eq!(a.get_usize("n", 7), 7);
    }
}
