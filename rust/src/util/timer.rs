//! Wall-clock phase timers for the Fig. 2(a) latency breakdown and for the
//! host-side performance profiling pass.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::obs::registry::LatencyLadder;

/// A simple start/stop timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Per-frame sample cap of [`PhaseProfile`] (keeps long sequences bounded;
/// totals and counts keep accumulating past it).
pub const PHASE_SAMPLES: usize = 4096;

/// Accumulated statistics of one named phase: total/count plus the capped
/// per-call sample vector percentiles are computed from.
#[derive(Debug, Clone, Default)]
pub struct PhaseStats {
    pub total: Duration,
    pub count: u64,
    samples: Vec<f64>,
}

impl PhaseStats {
    fn add(&mut self, d: Duration) {
        self.total += d;
        self.count += 1;
        if self.samples.len() < PHASE_SAMPLES {
            self.samples.push(d.as_secs_f64());
        }
    }

    /// Per-call samples in seconds (capped at [`PHASE_SAMPLES`]).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Full percentile ladder over the recorded samples (seconds) — the
    /// same shared helper every simulated-latency report uses.
    pub fn ladder(&self) -> LatencyLadder {
        LatencyLadder::of(&self.samples)
    }
}

/// Accumulates named phase durations across frames — the instrumentation
/// behind the Fig. 2(a) profiling reproduction and the `stage_wall_*`
/// BENCH blocks. Phase names are interned `&'static str` keys (no
/// per-`add` allocation on the hot path), and each phase records a capped
/// sample vector so reports get p50/p99 from [`LatencyLadder`] instead of
/// bare totals. Host wall-clock only — never part of a determinism
/// contract.
#[derive(Debug, Clone, Default)]
pub struct PhaseProfile {
    phases: BTreeMap<&'static str, PhaseStats>,
}

impl PhaseProfile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a phase name.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(phase, t.elapsed());
        out
    }

    pub fn add(&mut self, phase: &'static str, d: Duration) {
        self.phases.entry(phase).or_default().add(d);
    }

    /// Statistics of one phase (`None` if it never ran).
    pub fn stats(&self, phase: &str) -> Option<&PhaseStats> {
        self.phases.get(phase)
    }

    pub fn total(&self, phase: &str) -> Duration {
        self.phases.get(phase).map(|s| s.total).unwrap_or_default()
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.phases.get(phase).map(|s| s.count).unwrap_or_default()
    }

    /// Percentile ladder of a phase's per-call seconds (all-zero if the
    /// phase never ran).
    pub fn ladder(&self, phase: &str) -> LatencyLadder {
        self.phases.get(phase).map(PhaseStats::ladder).unwrap_or_default()
    }

    pub fn grand_total(&self) -> Duration {
        self.phases.values().map(|s| s.total).sum()
    }

    /// (phase, total seconds, share of grand total) sorted by share desc.
    pub fn breakdown(&self) -> Vec<(&'static str, f64, f64)> {
        let grand = self.grand_total().as_secs_f64().max(1e-12);
        let mut rows: Vec<(&'static str, f64, f64)> = self
            .phases
            .iter()
            .map(|(k, v)| (*k, v.total.as_secs_f64(), v.total.as_secs_f64() / grand))
            .collect();
        rows.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn phase_profile_accumulates() {
        let mut p = PhaseProfile::new();
        p.add("sort", Duration::from_millis(30));
        p.add("sort", Duration::from_millis(30));
        p.add("blend", Duration::from_millis(40));
        assert_eq!(p.total("sort"), Duration::from_millis(60));
        assert_eq!(p.count("sort"), 2);
        assert_eq!(p.grand_total(), Duration::from_millis(100));
        let rows = p.breakdown();
        assert_eq!(rows[0].0, "sort");
        assert!((rows[0].2 - 0.6).abs() < 1e-9);
    }

    #[test]
    fn phase_ladder_from_samples() {
        let mut p = PhaseProfile::new();
        for ms in [10u64, 20, 30, 40] {
            p.add("sort", Duration::from_millis(ms));
        }
        let l = p.ladder("sort");
        assert_eq!(l.count, 4);
        assert!((l.min - 0.010).abs() < 1e-9);
        assert!((l.max - 0.040).abs() < 1e-9);
        assert!((l.mean - 0.025).abs() < 1e-9);
        // Nearest-rank: p50 of 4 samples picks rank round(0.5·3) = 2.
        assert!((l.p50 - 0.030).abs() < 1e-9);
        assert_eq!(p.ladder("never-ran"), LatencyLadder::default());
    }

    #[test]
    fn time_closure_returns_value() {
        let mut p = PhaseProfile::new();
        let v = p.time("work", || 21 * 2);
        assert_eq!(v, 42);
        assert!(p.total("work") > Duration::ZERO);
        assert_eq!(p.stats("work").unwrap().samples().len(), 1);
    }
}
