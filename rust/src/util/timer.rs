//! Wall-clock phase timers for the Fig. 2(a) latency breakdown and for the
//! host-side performance profiling pass.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A simple start/stop timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulates named phase durations across frames — the instrumentation
/// behind the Fig. 2(a) profiling reproduction.
#[derive(Debug, Default)]
pub struct PhaseProfile {
    totals: BTreeMap<String, Duration>,
    counts: BTreeMap<String, u64>,
}

impl PhaseProfile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a phase name.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(phase, t.elapsed());
        out
    }

    pub fn add(&mut self, phase: &str, d: Duration) {
        *self.totals.entry(phase.to_string()).or_default() += d;
        *self.counts.entry(phase.to_string()).or_default() += 1;
    }

    pub fn total(&self, phase: &str) -> Duration {
        self.totals.get(phase).copied().unwrap_or_default()
    }

    pub fn grand_total(&self) -> Duration {
        self.totals.values().sum()
    }

    /// (phase, total seconds, share of grand total) sorted by share desc.
    pub fn breakdown(&self) -> Vec<(String, f64, f64)> {
        let grand = self.grand_total().as_secs_f64().max(1e-12);
        let mut rows: Vec<(String, f64, f64)> = self
            .totals
            .iter()
            .map(|(k, v)| (k.clone(), v.as_secs_f64(), v.as_secs_f64() / grand))
            .collect();
        rows.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn phase_profile_accumulates() {
        let mut p = PhaseProfile::new();
        p.add("sort", Duration::from_millis(30));
        p.add("sort", Duration::from_millis(30));
        p.add("blend", Duration::from_millis(40));
        assert_eq!(p.total("sort"), Duration::from_millis(60));
        assert_eq!(p.grand_total(), Duration::from_millis(100));
        let rows = p.breakdown();
        assert_eq!(rows[0].0, "sort");
        assert!((rows[0].2 - 0.6).abs() < 1e-9);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut p = PhaseProfile::new();
        let v = p.time("work", || 21 * 2);
        assert_eq!(v, 42);
        assert!(p.total("work") > Duration::ZERO);
    }
}
