//! Seeded randomized property testing (offline replacement for `proptest`).
//!
//! `check(cases, seed, |rng| ...)` runs a property over `cases` random
//! inputs; on failure it reports the case index and the per-case fork seed so
//! the exact failing input can be replayed deterministically.

use super::rng::Rng;

/// Outcome of a property over one generated case.
pub type CaseResult = Result<(), String>;

/// Run `prop` over `cases` deterministic random cases. Panics with a
/// replayable diagnostic on the first failure.
pub fn check<F>(cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let fork_label = case as u64;
        let mut rng = root.fork(fork_label);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at case {case}/{cases} (seed={seed}, fork={fork_label}): {msg}"
            );
        }
    }
}

/// Assert two f32 values are close (absolute + relative tolerance), property
/// style: returns a `CaseResult` for use inside `check` closures.
pub fn close(a: f32, b: f32, atol: f32, rtol: f32, what: &str) -> CaseResult {
    let tol = atol + rtol * b.abs().max(a.abs());
    if (a - b).abs() <= tol || (a.is_nan() && b.is_nan()) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Assert a boolean condition.
pub fn ensure(cond: bool, what: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(what.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(50, 1, |rng| {
            n += 1;
            let x = rng.f32();
            ensure((0.0..1.0).contains(&x), "unit interval")
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(10, 2, |rng| {
            let x = rng.f32();
            ensure(x < 0.5, format!("x={x} not < 0.5"))
        });
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-6, 0.0, "t").is_ok());
        assert!(close(100.0, 100.1, 0.0, 1e-2, "t").is_ok());
        assert!(close(1.0, 2.0, 1e-6, 1e-6, "t").is_err());
    }
}
