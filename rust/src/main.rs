//! `gaucim` — CLI for the 3DGauCIM reproduction.
//!
//! Subcommands:
//! * `render`  — render one frame (hardware path), write a PPM + report;
//! * `sequence`— run a trajectory, print the Table-I style report;
//! * `profile` — Fig. 2(a) phase breakdown of the baseline pipeline;
//! * `table1`  — the full Table I comparison (3DGauCIM vs GSCore vs Orin);
//! * `pjrt`    — smoke-run the AOT artifacts through the PJRT runtime;
//! * `info`    — environment / configuration dump.

use anyhow::Result;
use gaucim::baseline::{gscore, jetson, GscoreModel, JetsonModel};
use gaucim::camera::ViewCondition;
use gaucim::coordinator::App;
use gaucim::culling::{GridConfig, GridPartition};
use gaucim::memory::PrefetchPolicy;
use gaucim::pipeline::{profile_breakdown, PipelineConfig};
use gaucim::render::{ppm, RenderBackend};
use gaucim::scene::synth::SceneKind;
use gaucim::scene::DramLayout;
use gaucim::util::cli::Args;

const SUBCOMMANDS: &[&str] = &["render", "sequence", "profile", "table1", "pjrt", "run", "info"];

fn main() -> Result<()> {
    let args = Args::from_env(SUBCOMMANDS);
    match args.subcommand.as_deref() {
        Some("render") => cmd_render(&args),
        Some("sequence") => cmd_sequence(&args),
        Some("profile") => cmd_profile(&args),
        Some("table1") => cmd_table1(&args),
        Some("pjrt") => cmd_pjrt(&args),
        Some("run") => cmd_run(&args),
        Some("info") | None => cmd_info(&args),
        Some(other) => {
            eprintln!("unknown subcommand {other}");
            usage();
            std::process::exit(2);
        }
    }
}

fn usage() {
    eprintln!(
        "usage: gaucim <render|sequence|profile|table1|pjrt|run|info> \
         [--scene static|dynamic] [--gaussians N] [--frames N] \
         [--width W --height H] [--condition average|extreme|static] \
         [--seed S] [--threads N] [--render-backend scalar|lanes] \
         [--residency-mb MB] [--prefetch-policy none|next-frame-cull|lookahead[:K]] \
         [--dynamic] [--out FILE]"
    );
}

fn scene_kind(args: &Args) -> SceneKind {
    match args.get_str("scene", "dynamic").as_str() {
        "static" => SceneKind::StaticLarge,
        _ => SceneKind::DynamicLarge,
    }
}

fn condition(args: &Args) -> ViewCondition {
    match args.get_str("condition", "average").as_str() {
        "extreme" => ViewCondition::Extreme,
        "static" => ViewCondition::Static,
        _ => ViewCondition::Average,
    }
}

fn build_app(args: &Args) -> App {
    let kind = scene_kind(args);
    let n = args.get_usize("gaussians", 20_000);
    let seed = args.get_u64("seed", 42);
    let mut app = App::new(kind, n, seed);
    let w = args.get_usize("width", 640);
    let h = args.get_usize("height", 360);
    // Executor threads: 0 = auto (PALLAS_THREADS env, else available
    // parallelism). Simulated stats are thread-count invariant.
    let threads = args.get_usize("threads", 0);
    app.config = app.config.clone().with_resolution(w, h).with_threads(threads);
    // Blend datapath: scalar | lanes (bit-identical outputs; lanes is the
    // faster default — see rust/src/render/README.md).
    if let Some(s) = args.get("render-backend") {
        match RenderBackend::from_label(s) {
            Some(b) => app.config.render_backend = b,
            None => {
                eprintln!("--render-backend must be scalar|lanes, got '{s}'");
                std::process::exit(2);
            }
        }
    }
    // DRAM residency capacity in MB (0 = fully resident, paging layer off;
    // default: PALLAS_RESIDENCY_MB env) and the prefetch policy that pages
    // the compressed backing store ahead of demand misses.
    if args.get("residency-mb").is_some() {
        app.config.mem.residency.capacity_mb = args.get_parsed("residency-mb", 0.0f64).max(0.0);
    }
    if let Some(s) = args.get("prefetch-policy") {
        match PrefetchPolicy::from_label(s) {
            Some(p) => app.config.mem.residency.policy = p,
            None => {
                eprintln!("--prefetch-policy must be none|next-frame-cull|lookahead[:K], got '{s}'");
                std::process::exit(2);
            }
        }
    }
    // Dynamic serving: stream per-frame gaussian update deltas into DRAM
    // (MemStage::Update) with dirty-cell cull reuse + AII retention on top.
    if args.flag("dynamic") {
        app.config.dynamic_updates = true;
    }
    app
}

fn cmd_render(args: &Args) -> Result<()> {
    let app = build_app(args);
    let t = args.get_f32("time", 0.5);
    let (img, rep) = app.render_one(t);
    let out = args.get_str("out", "frame.ppm");
    ppm::save(&img, std::path::Path::new(&out))?;
    println!("wrote {out}");
    println!("{}", rep.report.row());
    println!("PSNR vs reference: {:.2} dB", rep.psnr_db);
    Ok(())
}

fn cmd_sequence(args: &Args) -> Result<()> {
    let app = build_app(args);
    let frames = args.get_usize("frames", 16);
    let rep = app.run_sequence(condition(args), frames, 0);
    println!("{}", rep.report.row());
    println!("{}", rep.to_json().pretty());
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let app = build_app(args);
    let frames = app.trajectory(condition(args), args.get_usize("frames", 4));
    println!("Fig. 2(a) — baseline dynamic-3DGS latency breakdown");
    let shares = profile_breakdown(
        &app.scene,
        PipelineConfig::baseline(app.scene.dynamic)
            .with_resolution(app.config.width, app.config.height),
        &frames,
    );
    for s in &shares {
        println!("  {:<16} {:>10.3} ms  {:>5.1}%", s.phase, s.ns / 1e6, s.share * 100.0);
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    println!("Table I — 3DGauCIM vs baselines (scaled workload)");
    for kind in [SceneKind::DynamicLarge, SceneKind::StaticLarge] {
        let mut app = App::new(kind, args.get_usize("gaussians", 20_000), 42);
        app.config = app
            .config
            .clone()
            .with_resolution(args.get_usize("width", 640), args.get_usize("height", 360));
        let cond = if kind == SceneKind::DynamicLarge {
            ViewCondition::Average
        } else {
            ViewCondition::Static
        };
        let rep = app.run_sequence(cond, args.get_usize("frames", 8), 0);
        println!("{}", rep.report.row());

        // GSCore comparison on the same scene.
        let grid = GridPartition::build(
            &app.scene,
            if app.scene.dynamic {
                GridConfig::new(4)
            } else {
                GridConfig::static_scene(4)
            },
        );
        let layout = DramLayout::build(&app.scene, &grid);
        let model = GscoreModel::new(&app.scene, &layout, app.config.width, app.config.height);
        let traj = app.trajectory(cond, 4);
        let mut g_lat = gaucim::energy::StageLatency::default();
        for (cam, t) in &traj {
            g_lat.add(&model.render_frame(cam, *t).latency);
        }
        let g_lat = g_lat.scale(1.0 / traj.len() as f64);
        println!(
            "  gscore-model ({})          {:>7.1} FPS (published {} FPS / {} W / {} mm²)",
            app.scene.name,
            1e9 / g_lat.pipelined_ns(),
            gscore::published::FPS_STATIC_LARGE,
            gscore::published::POWER_W,
            gscore::published::AREA_MM2,
        );

        // Jetson roofline on the same workload.
        let jf = JetsonModel::from_workload(
            (rep.energy.dcim_pj / 0.033) as u64,
            rep.avg_dram_bytes as u64,
        );
        println!(
            "  jetson-orin roofline          {:>7.1} FPS @ {} W (published {} FPS)",
            jf.fps,
            jetson::published::POWER_W,
            jetson::published::FPS_DYNAMIC
        );
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_pjrt(args: &Args) -> Result<()> {
    use gaucim::runtime::{Artifacts, BlendExecutor, HloExecutor, PreprocessExecutor};

    let artifacts = Artifacts::discover()?;
    artifacts.validate()?;
    println!("artifacts at {}", artifacts.dir.display());
    let client = HloExecutor::cpu_client()?;

    // Preprocess smoke.
    let app = build_app(args);
    let pre = PreprocessExecutor::load(&client, &artifacts.preprocess_hlo())?;
    let cam = app.camera_template();
    let splats = pre.project_chunk(
        &app.scene.gaussians[..app.scene.len().min(1024)],
        0,
        &cam,
        0.5,
    )?;
    println!("preprocess.hlo: {} visible splats from first 1024 gaussians", splats.len());

    // Blend smoke: blend the first tile's worth of splats.
    let blend = BlendExecutor::load(&client, &artifacts.blend_hlo())?;
    let rgb = blend.blend_tile(&splats, cam.intrinsics.cx - 8.0, cam.intrinsics.cy - 8.0)?;
    let mean: f32 = rgb.iter().map(|p| p[0] + p[1] + p[2]).sum::<f32>() / (rgb.len() * 3) as f32;
    println!("blend.hlo: 16x16 tile rendered, mean value {mean:.4}");
    println!("pjrt OK");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_pjrt(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "the `pjrt` subcommand requires the PJRT runtime — rebuild with \
         `--features xla` (needs the toolchain-provided xla crate)"
    )
}

fn cmd_run(args: &Args) -> Result<()> {
    let path = args
        .get("config")
        .map(String::from)
        .or_else(|| args.positional.first().cloned())
        .ok_or_else(|| anyhow::anyhow!("usage: gaucim run --config <file.json>"))?;
    let cfg = gaucim::coordinator::ExperimentConfig::load(std::path::Path::new(&path))?;
    println!("running '{}' ({} gaussians, {} frames)", cfg.name, cfg.gaussians, cfg.frames);
    let rep = cfg.run()?;
    println!("{}", rep.report.row());
    println!("{}", rep.to_json().pretty());
    Ok(())
}

fn cmd_info(_args: &Args) -> Result<()> {
    println!("gaucim — 3DGauCIM reproduction (Rust + JAX + Pallas, AOT via PJRT)");
    println!("paper operating point: grid=4, ATG th=0.5 TB=4, AII N=8, FP16 + 12-bit exp LUT");
    #[cfg(feature = "xla")]
    {
        match gaucim::runtime::Artifacts::discover() {
            Ok(a) if a.available() => println!("artifacts: {} (ready)", a.dir.display()),
            Ok(a) => {
                println!("artifacts: {} (INCOMPLETE — run `make artifacts`)", a.dir.display())
            }
            Err(_) => println!("artifacts: not found — run `make artifacts`"),
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("artifacts: n/a (built without the `xla` feature)");
    usage();
    Ok(())
}
