//! Near-memory-compute (NMC) transmittance accumulation (paper §3.4,
//! Fig. 8(b)): units at the DCIM periphery receive α values and locally
//! accumulate the running transmittance Π(1−αⱼ), then combine it with the
//! DCIM-computed α·RGB to produce the final pixel output (eq. 9).

/// Per-pixel front-to-back blending state kept in an NMC unit.
#[derive(Debug, Clone, Copy)]
pub struct PixelState {
    /// Accumulated RGB.
    pub rgb: [f32; 3],
    /// Remaining transmittance Π(1−αⱼ).
    pub transmittance: f32,
}

impl Default for PixelState {
    fn default() -> Self {
        PixelState { rgb: [0.0; 3], transmittance: 1.0 }
    }
}

/// Early-termination threshold: once transmittance falls below this the
/// pixel is saturated and further splats are skipped (3DGS convention).
pub const T_MIN: f32 = 1.0 / 255.0;

/// NMC activity counters + energy.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NmcStats {
    /// blend steps executed (α received).
    pub blend_ops: u64,
    /// pixels that early-terminated.
    pub saturated: u64,
    pub energy_pj: f64,
}

/// The accumulator bank: models energy/op and provides the arithmetic used
/// by the hardware-faithful renderer.
#[derive(Debug)]
pub struct NmcAccumulator {
    /// Energy per blend step (1 mul for T update + 3 MAC for RGB, 16 nm
    /// digital near-memory logic).
    pub e_blend_pj: f64,
    stats: NmcStats,
}

impl NmcAccumulator {
    pub fn new() -> NmcAccumulator {
        NmcAccumulator { e_blend_pj: 0.35, stats: NmcStats::default() }
    }

    /// One front-to-back blend step: `state` ← state ⊕ (α, rgb).
    /// Returns `false` once the pixel saturates (caller should stop).
    #[inline]
    pub fn blend(&mut self, state: &mut PixelState, alpha: f32, rgb: [f32; 3]) -> bool {
        self.stats.blend_ops += 1;
        let a = alpha.clamp(0.0, 0.999);
        let w = a * state.transmittance;
        state.rgb[0] += w * rgb[0];
        state.rgb[1] += w * rgb[1];
        state.rgb[2] += w * rgb[2];
        state.transmittance *= 1.0 - a;
        if state.transmittance < T_MIN {
            self.stats.saturated += 1;
            false
        } else {
            true
        }
    }

    /// Statistics snapshot. Energy derives from the op count here
    /// (`blend_ops · e_blend_pj`), so per-tile partial accumulators reduce
    /// exactly — the tile-parallel rasterizer depends on this for its
    /// bit-identical-stats contract.
    pub fn stats(&self) -> NmcStats {
        let mut s = self.stats;
        s.energy_pj = s.blend_ops as f64 * self.e_blend_pj;
        s
    }

    /// Charge `blend_ops` blend steps of which `saturated` crossed the
    /// [`T_MIN`] early-termination threshold — the lane-batched kernel's
    /// counter path ([`crate::render::lanes`]): it performs the blend
    /// arithmetic lane-wise itself and tallies the popcounts here, so the
    /// integer counters (and the op-derived energy) stay bit-identical to
    /// per-pixel [`NmcAccumulator::blend`] calls.
    #[inline]
    pub fn tally(&mut self, blend_ops: u64, saturated: u64) {
        self.stats.blend_ops += blend_ops;
        self.stats.saturated += saturated;
    }

    /// Fold a partial (per-tile) counter set in; energy re-derives at
    /// [`NmcAccumulator::stats`] time.
    pub fn absorb(&mut self, o: &NmcStats) {
        self.stats.blend_ops += o.blend_ops;
        self.stats.saturated += o.saturated;
    }

    pub fn reset(&mut self) {
        self.stats = NmcStats::default();
    }
}

impl Default for NmcAccumulator {
    fn default() -> Self {
        NmcAccumulator::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_opaque_splat_dominates() {
        let mut nmc = NmcAccumulator::new();
        let mut px = PixelState::default();
        nmc.blend(&mut px, 0.9, [1.0, 0.5, 0.0]);
        assert!((px.rgb[0] - 0.9).abs() < 1e-6);
        assert!((px.rgb[1] - 0.45).abs() < 1e-6);
        assert!((px.transmittance - 0.1).abs() < 1e-6);
    }

    #[test]
    fn front_to_back_order_matters() {
        let mut nmc = NmcAccumulator::new();
        let mut a = PixelState::default();
        nmc.blend(&mut a, 0.8, [1.0, 0.0, 0.0]);
        nmc.blend(&mut a, 0.8, [0.0, 1.0, 0.0]);
        // First (red) splat dominates.
        assert!(a.rgb[0] > 3.0 * a.rgb[1]);
    }

    #[test]
    fn saturation_stops_blending() {
        let mut nmc = NmcAccumulator::new();
        let mut px = PixelState::default();
        let mut steps = 0;
        for _ in 0..100 {
            steps += 1;
            if !nmc.blend(&mut px, 0.9, [0.5; 3]) {
                break;
            }
        }
        assert!(steps < 10, "0.9-alpha splats saturate quickly: {steps}");
        assert_eq!(nmc.stats().saturated, 1);
        assert_eq!(nmc.stats().blend_ops, steps);
    }

    #[test]
    fn transmittance_times_color_bounded() {
        // Blending any number of [0,1] colors keeps rgb in [0,1].
        let mut nmc = NmcAccumulator::new();
        let mut px = PixelState::default();
        for i in 0..50 {
            let alpha = 0.02 + 0.01 * (i % 7) as f32;
            if !nmc.blend(&mut px, alpha, [1.0, 1.0, 1.0]) {
                break;
            }
        }
        for c in px.rgb {
            assert!((0.0..=1.0 + 1e-5).contains(&c));
        }
    }

    #[test]
    fn energy_per_op() {
        let mut nmc = NmcAccumulator::new();
        let mut px = PixelState::default();
        nmc.blend(&mut px, 0.1, [0.5; 3]);
        nmc.blend(&mut px, 0.1, [0.5; 3]);
        assert!((nmc.stats().energy_pj - 0.7).abs() < 1e-9);
    }
}
