//! Gain-cell DCIM macro model, parameterized from the measured 16 nm
//! prototype the paper uses (Khwa et al., ISSCC 2024 [5]): a 96 Kb
//! integer/floating-point dual-mode gain-cell CIM macro achieving
//! 73.3–163.3 TOPS/W (INT8) and 33.2–91.2 TFLOPS/W (FP16).
//!
//! Geometry follows the paper's Fig. 8(b): the accelerator's DCIM tier is
//! built from **24 gain-cell DCIM arrays × 64 computing blocks**, each block
//! a 64-bit gain-cell matrix with a local computing cell (LCC). We model
//! throughput (MACs/cycle), energy (pJ/MAC from the measured TFLOPS/W), and
//! storage (LUT + opacity + SH-derived RGB residency).

/// Macro configuration (defaults = paper operating point).
#[derive(Debug, Clone, Copy)]
pub struct DcimConfig {
    /// DCIM arrays in the tier (paper Fig. 8(b): 24).
    pub arrays: usize,
    /// Computing blocks per array (paper: 64).
    pub blocks_per_array: usize,
    /// FP16 MACs each block completes per cycle (gain-cell matrix + LCC).
    pub macs_per_block_per_cycle: f64,
    /// Clock frequency (GHz) — ISSCC'24 class macros run sub-GHz.
    pub freq_ghz: f64,
    /// FP16 energy per MAC (pJ). Mid-range of the measured 33.2–91.2
    /// TFLOPS/W: 60 TFLOPS/W ⇒ 2 ops/MAC ⇒ ≈ 0.033 pJ/MAC.
    pub e_mac_fp16_pj: f64,
    /// Energy per LUT lookup (one DCIM row activation; pJ).
    pub e_lut_lookup_pj: f64,
    /// DCIM storage capacity (KB). Paper Table I: 144 KB (dynamic config) /
    /// 48 KB (static config).
    pub storage_kb: usize,
    /// Macro area (mm², 16 nm) — contributes to the Table I area roll-up.
    pub area_mm2: f64,
}

impl DcimConfig {
    /// Dynamic-scene configuration (Table I: DCIM 144 KB).
    pub fn paper_dynamic() -> DcimConfig {
        DcimConfig {
            arrays: 24,
            blocks_per_array: 64,
            macs_per_block_per_cycle: 1.0,
            freq_ghz: 0.5,
            e_mac_fp16_pj: 0.033,
            e_lut_lookup_pj: 0.05,
            storage_kb: 144,
            area_mm2: 1.9,
        }
    }

    /// Static-scene configuration (Table I: DCIM 48 KB, smaller tier).
    pub fn paper_static() -> DcimConfig {
        DcimConfig {
            arrays: 8,
            blocks_per_array: 64,
            macs_per_block_per_cycle: 1.0,
            freq_ghz: 0.5,
            e_mac_fp16_pj: 0.033,
            e_lut_lookup_pj: 0.05,
            storage_kb: 48,
            area_mm2: 0.65,
        }
    }

    /// Peak MAC throughput per cycle.
    pub fn macs_per_cycle(&self) -> f64 {
        self.arrays as f64 * self.blocks_per_array as f64 * self.macs_per_block_per_cycle
    }

    /// Peak FP16 throughput (GFLOPS; 2 ops per MAC).
    pub fn peak_gflops(&self) -> f64 {
        self.macs_per_cycle() * self.freq_ghz * 2.0
    }
}

/// Accumulated DCIM activity.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DcimStats {
    pub macs: u64,
    pub lut_lookups: u64,
    pub energy_pj: f64,
}

impl DcimStats {
    pub fn add(&mut self, o: &DcimStats) {
        self.macs += o.macs;
        self.lut_lookups += o.lut_lookups;
        self.energy_pj += o.energy_pj;
    }
}

/// The macro model: an event counter with energy/latency roll-ups.
#[derive(Debug)]
pub struct DcimMacro {
    pub config: DcimConfig,
    stats: DcimStats,
}

impl DcimMacro {
    pub fn new(config: DcimConfig) -> DcimMacro {
        DcimMacro { config, stats: DcimStats::default() }
    }

    /// Record `n` FP16 MACs.
    pub fn macs(&mut self, n: u64) {
        self.stats.macs += n;
        self.stats.energy_pj += n as f64 * self.config.e_mac_fp16_pj;
    }

    /// Record `n` LUT lookups (exp2 cascade stages).
    pub fn lut_lookups(&mut self, n: u64) {
        self.stats.lut_lookups += n;
        self.stats.energy_pj += n as f64 * self.config.e_lut_lookup_pj;
    }

    pub fn stats(&self) -> DcimStats {
        self.stats
    }

    pub fn reset(&mut self) {
        self.stats = DcimStats::default();
    }

    /// Busy time implied by the recorded activity (ns); LUT lookups ride the
    /// same array cycles as MACs (they *are* CIM row operations).
    pub fn busy_ns(&self) -> f64 {
        let cycles =
            (self.stats.macs + self.stats.lut_lookups) as f64 / self.config.macs_per_cycle();
        cycles / self.config.freq_ghz
    }

    /// Effective utilization for an activity burst that had to finish within
    /// `window_ns` (1 = the macro was the bottleneck the whole window).
    pub fn utilization(&self, window_ns: f64) -> f64 {
        if window_ns <= 0.0 {
            return 0.0;
        }
        (self.busy_ns() / window_ns).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_dynamic_geometry() {
        let c = DcimConfig::paper_dynamic();
        assert_eq!(c.macs_per_cycle() as u64, 24 * 64);
        // 1536 MACs/cycle × 0.5 GHz × 2 = 1.536 TFLOPS peak.
        assert!((c.peak_gflops() - 1536.0).abs() < 1.0);
    }

    #[test]
    fn energy_tracks_measured_efficiency() {
        let c = DcimConfig::paper_dynamic();
        let mut m = DcimMacro::new(c);
        m.macs(1_000_000_000); // 1 G MACs = 2 GFLOP
        let joules = m.stats().energy_pj * 1e-12;
        let tflops_per_w = 2e9 / joules / 1e12;
        // Must land inside the ISSCC'24 measured FP16 band.
        assert!(
            (33.2..=91.2).contains(&tflops_per_w),
            "TFLOPS/W {tflops_per_w}"
        );
    }

    #[test]
    fn busy_time_scales_with_work() {
        let mut m = DcimMacro::new(DcimConfig::paper_dynamic());
        m.macs(1536 * 500); // 500 cycles of work
        let ns = m.busy_ns();
        assert!((ns - 1000.0).abs() < 1.0, "500 cycles @ 0.5 GHz = 1000 ns, got {ns}");
        assert!((m.utilization(2000.0) - 0.5).abs() < 1e-6);
        assert_eq!(m.utilization(0.0), 0.0);
    }

    #[test]
    fn static_config_smaller() {
        let d = DcimConfig::paper_dynamic();
        let s = DcimConfig::paper_static();
        assert!(s.storage_kb < d.storage_kb);
        assert!(s.macs_per_cycle() < d.macs_per_cycle());
        assert!(s.area_mm2 < d.area_mm2);
    }

    #[test]
    fn reset_and_add() {
        let mut m = DcimMacro::new(DcimConfig::paper_static());
        m.macs(100);
        m.lut_lookups(50);
        let mut total = DcimStats::default();
        total.add(&m.stats());
        total.add(&m.stats());
        assert_eq!(total.macs, 200);
        assert_eq!(total.lut_lookups, 100);
        m.reset();
        assert_eq!(m.stats(), DcimStats::default());
    }
}
