//! DD3D-Flow exponential evaluation (paper §3.4, Fig. 8(a)) — bit-faithful.
//!
//! **Phase 1 — base conversion**: `e^x → 2^(x/ln2)`; the 1/ln2 factor is
//! fused *offline* into the Gaussian parameters, so the hardware only ever
//! sees base-2 exponents `x'`.
//!
//! **Phase 2 — sign-integer-fraction (SIF) decouple**: `x' = int + frac`
//! with `frac ∈ [0,1)` (for negative `x'` this is exactly the two's-
//! complement of the fraction with the borrow folded into `int`). `2^int`
//! is a pure exponent shift; `2^frac` uses a **12-bit LUT split into four
//! 3-bit segments, each an 8-entry DCIM table**:
//!
//! `2^frac = 2^(s₁·2⁻³) · 2^(s₂·2⁻⁶) · 2^(s₃·2⁻⁹) · 2^(s₄·2⁻¹²)`
//!
//! — four cascaded DCIM multiply stages, matching the paper's "12-bit LUT
//! divided into four segments, each requiring 8 LUT values … four cascaded
//! DCIM stages". LUT entries and the cascade multiplies are FP16-quantized,
//! as they live in the DCIM arrays.

use crate::math::f16;

/// Number of fraction bits (paper: 12, shown to preserve PSNR).
pub const DEFAULT_FRAC_BITS: u32 = 12;
/// Segments and entries: 4 × 3-bit → 8 entries each.
pub const SEGMENTS: usize = 4;
pub const ENTRIES_PER_SEGMENT: usize = 8;

/// The LUT-based base-2 exponential unit.
#[derive(Debug, Clone)]
pub struct ExpLut {
    /// `lut[k][v] = fp16(2^(v · 2^-(3(k+1))))`.
    lut: [[f32; ENTRIES_PER_SEGMENT]; SEGMENTS],
    /// Fraction bits actually used (ablation knob; paper value 12).
    pub frac_bits: u32,
    bits_per_segment: u32,
}

impl ExpLut {
    /// Paper configuration: 12 fraction bits in 4×3-bit segments.
    pub fn paper() -> ExpLut {
        ExpLut::with_frac_bits(DEFAULT_FRAC_BITS)
    }

    /// Ablation constructor: `frac_bits` must be a multiple of
    /// [`SEGMENTS`] (we keep 4 segments and scale the bits per segment).
    /// 12 bits is the ceiling: 4 segments × 8-entry tables hold at most
    /// 3 bits each — precisely the paper's chosen geometry.
    pub fn with_frac_bits(frac_bits: u32) -> ExpLut {
        assert!(
            (4..=12).contains(&frac_bits) && frac_bits % SEGMENTS as u32 == 0,
            "frac_bits must be in 4..=12 and divisible by {SEGMENTS}              (8-entry segments hold at most 3 bits)"
        );
        let bps = frac_bits / SEGMENTS as u32;
        let mut lut = [[0.0f32; ENTRIES_PER_SEGMENT]; SEGMENTS];
        for (k, seg) in lut.iter_mut().enumerate() {
            for (v, entry) in seg.iter_mut().enumerate() {
                let weight = 2.0f64.powi(-(bps as i32) * (k as i32 + 1));
                *entry = f16::quantize(2.0f64.powf(v as f64 * weight) as f32);
            }
        }
        ExpLut { lut, frac_bits, bits_per_segment: bps }
    }

    /// `2^x` through the hardware dataflow (shift + 4 cascaded FP16 stages).
    pub fn exp2(&self, x: f32) -> f32 {
        if !x.is_finite() {
            return if x > 0.0 { f32::INFINITY } else { 0.0 };
        }
        // SIF decouple.
        let int = x.floor();
        let frac = x - int; // ∈ [0,1), two's-complement handling for x < 0
        let scale = (1u64 << self.frac_bits) as f32;
        let q = ((frac * scale) as u32).min((1u32 << self.frac_bits) - 1);

        // Cascaded LUT stages (FP16 multiplies, as in the DCIM arrays).
        let mask = (1u32 << self.bits_per_segment) - 1;
        let mut acc = 1.0f32;
        for k in 0..SEGMENTS {
            let shift = self.frac_bits - self.bits_per_segment * (k as u32 + 1);
            let idx = ((q >> shift) & mask) as usize;
            // Entries beyond table width (bps < 3 unused slots) index low.
            acc = f16::quantize(acc * self.lut[k][idx.min(ENTRIES_PER_SEGMENT - 1)]);
        }

        // 2^int is an exponent shift (exact in FP until under/overflow).
        let shifted = libm_exp2i(int as i32);
        acc * shifted
    }

    /// Lane-vectorized [`ExpLut::exp2`]: 8 exponents at once for the
    /// lane-batched rasterizer ([`crate::render::lanes`]). Each lane runs
    /// the *identical* scalar op sequence — per-lane `floor`/subtract for
    /// the bit-decomposed integer part, per-lane fraction LUT gather
    /// through the same four FP16 cascade stages, same saturating casts —
    /// so `exp2_lanes(x)[i]` is bit-identical to `exp2(x[i])` for every
    /// input including ±∞, NaN, and subnormal-producing exponents. The
    /// non-finite early return of the scalar path becomes a final
    /// per-lane patch (the discarded finite-path arithmetic is defined
    /// for any input — Rust float→int casts saturate).
    pub fn exp2_lanes(&self, x: [f32; 8]) -> [f32; 8] {
        // SIF decouple, element-wise.
        let scale = (1u64 << self.frac_bits) as f32;
        let q_max = (1u32 << self.frac_bits) - 1;
        let mut int = [0.0f32; 8];
        let mut q = [0u32; 8];
        for i in 0..8 {
            int[i] = x[i].floor();
            let frac = x[i] - int[i];
            q[i] = ((frac * scale) as u32).min(q_max);
        }

        // Cascaded LUT stages: per-lane gather, shared segment table.
        let mask = (1u32 << self.bits_per_segment) - 1;
        let mut acc = [1.0f32; 8];
        for (k, seg) in self.lut.iter().enumerate() {
            let shift = self.frac_bits - self.bits_per_segment * (k as u32 + 1);
            for i in 0..8 {
                let idx = ((q[i] >> shift) & mask) as usize;
                acc[i] = f16::quantize(acc[i] * seg[idx.min(ENTRIES_PER_SEGMENT - 1)]);
            }
        }

        let mut out = [0.0f32; 8];
        for i in 0..8 {
            out[i] = if x[i].is_finite() {
                acc[i] * libm_exp2i(int[i] as i32)
            } else if x[i] > 0.0 {
                f32::INFINITY
            } else {
                0.0
            };
        }
        out
    }

    /// `e^x` with the ln2 base conversion applied here (in deployment the
    /// 1/ln2 is folded into the parameters offline — see `mapping`).
    pub fn exp(&self, x: f32) -> f32 {
        self.exp2(x * std::f32::consts::LOG2_E)
    }

    /// Worst-case relative error of the LUT path over a sample grid —
    /// used by the precision ablation (paper claim: 12 bits ⇒ no PSNR loss).
    pub fn max_rel_error(&self, lo: f32, hi: f32, steps: usize) -> f32 {
        let mut worst = 0.0f32;
        for i in 0..=steps {
            let x = lo + (hi - lo) * i as f32 / steps as f32;
            let approx = self.exp2(x);
            let exact = 2.0f64.powf(x as f64) as f32;
            if exact > 0.0 {
                worst = worst.max(((approx - exact) / exact).abs());
            }
        }
        worst
    }

    /// LUT storage footprint in DCIM (bits): entries × FP16.
    pub fn storage_bits(&self) -> usize {
        SEGMENTS * ENTRIES_PER_SEGMENT * 16
    }
}

/// Exact 2^i for integer i via exponent construction (no libm dependency).
fn libm_exp2i(i: i32) -> f32 {
    match i {
        i if i > 127 => f32::INFINITY,
        i if i >= -126 => f32::from_bits((((i + 127) as u32) << 23) as u32),
        // Subnormal range: build via division to keep gradual underflow.
        i if i >= -149 => f32::from_bits(1u32 << (149 + i) as u32),
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, close};

    #[test]
    fn exact_at_integer_exponents() {
        let lut = ExpLut::paper();
        for i in -20..=20 {
            let got = lut.exp2(i as f32);
            let exact = 2.0f32.powi(i);
            assert!(
                ((got - exact) / exact).abs() < 1e-3,
                "2^{i}: {got} vs {exact}"
            );
        }
    }

    #[test]
    fn paper_12bit_error_small_enough_for_psnr() {
        let lut = ExpLut::paper();
        // Blend exponents live in roughly [-30, 0] (alpha cutoff at ~1/255²).
        let err = lut.max_rel_error(-30.0, 0.0, 20_000);
        // 2^-12·ln2 ≈ 1.7e-4 from truncation + FP16 cascade ≈ few × 1e-3.
        assert!(err < 4e-3, "12-bit LUT rel error {err}");
    }

    #[test]
    fn fewer_bits_more_error_monotonic() {
        let e12 = ExpLut::with_frac_bits(12).max_rel_error(-10.0, 0.0, 5000);
        let e8 = ExpLut::with_frac_bits(8).max_rel_error(-10.0, 0.0, 5000);
        let e4 = ExpLut::with_frac_bits(4).max_rel_error(-10.0, 0.0, 5000);
        assert!(e4 > e8, "4-bit {e4} vs 8-bit {e8}");
        assert!(e8 > e12, "8-bit {e8} vs 12-bit {e12}");
        // 4 bits is catastrophically coarse — the ablation's bad end.
        assert!(e4 > 0.02);
    }

    #[test]
    fn exp_matches_std_exp() {
        let lut = ExpLut::paper();
        for x in [-8.0f32, -2.5, -0.7, 0.0] {
            let got = lut.exp(x);
            let exact = x.exp();
            assert!(
                ((got - exact) / exact.max(1e-12)).abs() < 5e-3,
                "exp({x}): {got} vs {exact}"
            );
        }
    }

    #[test]
    fn handles_extremes() {
        let lut = ExpLut::paper();
        assert_eq!(lut.exp2(f32::NEG_INFINITY), 0.0);
        assert_eq!(lut.exp2(f32::INFINITY), f32::INFINITY);
        assert_eq!(lut.exp2(-200.0), 0.0); // underflow
        assert!(lut.exp2(-126.0) > 0.0);
    }

    #[test]
    fn property_relative_error_bounded_on_blend_range() {
        let lut = ExpLut::paper();
        check(500, 21, |rng| {
            let x = -30.0 + 30.0 * rng.f32();
            let got = lut.exp2(x);
            let exact = 2.0f64.powf(x as f64) as f32;
            close(got, exact, 1e-12, 4e-3, "2^x")
        });
    }

    #[test]
    fn storage_matches_paper_geometry() {
        let lut = ExpLut::paper();
        // 4 segments × 8 entries × 16 bits = 512 bits of LUT in DCIM.
        assert_eq!(lut.storage_bits(), 512);
    }

    #[test]
    fn exp2i_helper_edges() {
        assert_eq!(super::libm_exp2i(0), 1.0);
        assert_eq!(super::libm_exp2i(10), 1024.0);
        assert_eq!(super::libm_exp2i(-1), 0.5);
        assert_eq!(super::libm_exp2i(128), f32::INFINITY);
        assert_eq!(super::libm_exp2i(-150), 0.0);
        assert!(super::libm_exp2i(-149) > 0.0);
    }
}
