//! Digital compute-in-memory (DCIM) modeling: the DD3D-Flow exponential
//! dataflow (paper §3.4), the gain-cell DCIM macro model parameterized from
//! the measured 16 nm prototype (ISSCC'24 [5]), the near-memory-compute
//! transmittance accumulator, and the blend→DCIM operation mapping.

pub mod exp_lut;
pub mod macro_model;
pub mod mapping;
pub mod nmc;

pub use exp_lut::ExpLut;
pub use macro_model::{DcimConfig, DcimMacro, DcimStats};
pub use mapping::BlendOpCounts;
pub use nmc::NmcAccumulator;
