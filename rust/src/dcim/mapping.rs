//! Blend-stage → DCIM operation mapping (DD3D-Flow, paper §3.4).
//!
//! For a tile of `pixels` and `gaussians` splats the DCIM tier executes, per
//! (pixel, splat) pair:
//!
//! * the merged exponent `P_i(u,v,t)` — the conic quadratic form
//!   (dx², dx·dy, dy² products + weighted sum: 6 MACs; the temporal factor
//!   is pre-merged into the exponent offline, which is exactly why the
//!   hardware evaluates **one** exponential per pair);
//! * the exp2 cascade — 4 LUT lookups + 4 multiplies (counted as 4 LUT ops
//!   + 4 MACs);
//! * α·RGB weighting — 3 MACs (RGB stored in DCIM, precomputed via SH);
//!
//! plus per-splat one-off work: SH color evaluation (degree-2: 9 basis × 3
//! channels = 27 MACs + ~15 basis-construction MACs).
//!
//! Transmittance accumulation happens in the NMC units and is charged there.

use super::macro_model::DcimMacro;

/// MACs per (pixel, splat) pair for the merged exponent.
pub const MACS_EXPONENT: u64 = 6;
/// Cascade stages per exponential.
pub const LUT_STAGES: u64 = 4;
/// MACs per cascade (one multiply per stage).
pub const MACS_CASCADE: u64 = 4;
/// MACs per (pixel, splat) for α·RGB.
pub const MACS_COLOR: u64 = 3;
/// Per-splat SH evaluation MACs (basis + projection).
pub const MACS_SH: u64 = 27 + 15;

/// Operation counts for one tile's blend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlendOpCounts {
    pub pairs: u64,
    pub macs: u64,
    pub lut_lookups: u64,
}

impl BlendOpCounts {
    /// Counts for a tile of `pixels` × `gaussians` (upper bound: no early
    /// termination; pass the post-termination pair count for exact numbers).
    pub fn for_tile(pixels: u64, gaussians: u64) -> BlendOpCounts {
        let pairs = pixels * gaussians;
        BlendOpCounts {
            pairs,
            macs: pairs * (MACS_EXPONENT + MACS_CASCADE + MACS_COLOR) + gaussians * MACS_SH,
            lut_lookups: pairs * LUT_STAGES,
        }
    }

    /// Exact counts from measured blended pairs (early termination applied)
    /// plus the per-splat SH work.
    pub fn from_pairs(pairs: u64, gaussians: u64) -> BlendOpCounts {
        BlendOpCounts {
            pairs,
            macs: pairs * (MACS_EXPONENT + MACS_CASCADE + MACS_COLOR) + gaussians * MACS_SH,
            lut_lookups: pairs * LUT_STAGES,
        }
    }

    /// Charge these counts to a DCIM macro model.
    pub fn charge(&self, dcim: &mut DcimMacro) {
        dcim.macs(self.macs);
        dcim.lut_lookups(self.lut_lookups);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcim::macro_model::DcimConfig;

    #[test]
    fn tile_counts_scale_with_pairs() {
        let c = BlendOpCounts::for_tile(256, 100);
        assert_eq!(c.pairs, 25_600);
        assert_eq!(c.lut_lookups, 25_600 * 4);
        assert_eq!(c.macs, 25_600 * 13 + 100 * 42);
    }

    #[test]
    fn early_termination_reduces_work() {
        let full = BlendOpCounts::for_tile(256, 100);
        let cut = BlendOpCounts::from_pairs(10_000, 100);
        assert!(cut.macs < full.macs);
        assert!(cut.lut_lookups < full.lut_lookups);
    }

    #[test]
    fn charge_accumulates_into_macro() {
        let mut m = DcimMacro::new(DcimConfig::paper_dynamic());
        let c = BlendOpCounts::for_tile(256, 10);
        c.charge(&mut m);
        assert_eq!(m.stats().macs, c.macs);
        assert_eq!(m.stats().lut_lookups, c.lut_lookups);
        assert!(m.stats().energy_pj > 0.0);
    }
}
