//! Frustum culling: the paper's DR-FC (DRAM-access-reduction frustum
//! culling, §3.1) and the conventional fetch-everything baseline it is
//! compared against in Fig. 9.

pub mod conventional;
pub mod drfc;
pub mod grid;

pub use drfc::{CullOutput, CullReuse, CullReuseStats, DrFc};
pub use grid::{GridCell, GridConfig, GridPartition};

pub use crate::math::frustum::Containment;

use crate::camera::Camera;
use crate::math::Frustum;
use crate::scene::Gaussian4D;

/// Exact per-Gaussian visibility at time `t`: temporal support + a
/// conservative 3σ sphere-vs-frustum test. Both DR-FC and the conventional
/// path apply this after their respective fetch strategies; they differ in
/// *which Gaussians reach this test via DRAM*.
pub fn gaussian_visible(g: &Gaussian4D, cam: &Camera, t: f32) -> bool {
    gaussian_visible_in(g, &cam.frustum(), t)
}

/// Hot-path variant with a precomputed frustum (building the frustum is
/// ~6 plane extractions + normalizations — done once per frame, not once
/// per Gaussian; see EXPERIMENTS.md §Perf).
#[inline]
pub fn gaussian_visible_in(g: &Gaussian4D, frustum: &Frustum, t: f32) -> bool {
    // Temporal cut: beyond 3σₜ the temporal weight < 1.2e-2 — the paper's
    // temporal slicing treats those as invisible.
    if !g.is_static() {
        let (t0, t1) = g.time_extent();
        if t < t0 || t > t1 {
            return false;
        }
    }
    frustum.test_sphere(g.mean_at(t), g.radius3())
}
