//! Conventional frustum culling baseline (paper's comparison in Fig. 9,
//! as in GSCore-class designs): fetch **all** Gaussian parameters from DRAM
//! each frame, then run the exact per-Gaussian test on-chip.

use super::drfc::CullOutput;
use crate::camera::Camera;
use crate::memory::dram::{DramModel, MemSink};
use crate::scene::{DramLayout, Scene};

/// Fetch-everything culling.
pub struct ConventionalCulling<'a> {
    pub scene: &'a Scene,
    pub layout: &'a DramLayout,
}

impl<'a> ConventionalCulling<'a> {
    pub fn new(scene: &'a Scene, layout: &'a DramLayout) -> Self {
        ConventionalCulling { scene, layout }
    }

    /// Cull at time `t`, charging the full-scene parameter fetch to `dram`.
    /// Convenience wrapper over [`ConventionalCulling::cull_into`].
    pub fn cull(&self, cam: &Camera, t: f32, dram: &mut DramModel) -> CullOutput {
        let mut out = CullOutput::default();
        self.cull_into(cam, t, dram, &mut out);
        out
    }

    /// Cull into a pooled [`CullOutput`], issuing the full-scene sweep
    /// through `mem` (a [`MemPort`](crate::memory::MemPort) on the
    /// pipeline path).
    pub fn cull_into<M: MemSink>(
        &self,
        cam: &Camera,
        t: f32,
        mem: &mut M,
        out: &mut CullOutput,
    ) {
        // One big sequential sweep over the whole parameter array — the
        // best case for the baseline (maximum burst efficiency), which makes
        // the Fig. 9 comparison conservative in the baseline's favor.
        mem.read(0, self.layout.total_bytes());

        out.clear();
        out.candidates.extend(0..self.scene.len() as u32);
        out.fetched = self.scene.len() as u64;
        let frustum = cam.frustum();
        for gi in 0..self.scene.len() as u32 {
            if super::gaussian_visible_in(&self.scene.gaussians[gi as usize], &frustum, t) {
                out.visible.push(gi);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::culling::grid::{GridConfig, GridPartition};
    use crate::culling::DrFc;
    use crate::math::Vec3;
    use crate::scene::synth::{SceneKind, SynthParams};

    fn camera() -> Camera {
        Camera::look_at(
            Vec3::new(0.0, 4.0, 25.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            16.0 / 9.0,
            0.1,
            200.0,
        )
    }

    #[test]
    fn fetches_entire_scene() {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 2000).generate();
        let grid = GridPartition::build(&scene, GridConfig::new(4));
        let layout = crate::scene::DramLayout::build(&scene, &grid);
        let conv = ConventionalCulling::new(&scene, &layout);
        let mut dram = DramModel::default_lpddr5();
        let out = conv.cull(&camera(), 0.5, &mut dram);
        assert_eq!(out.fetched, scene.len() as u64);
        assert_eq!(dram.stats().bytes, layout.total_bytes().div_ceil(32) * 32);
    }

    #[test]
    fn agrees_with_drfc_on_visible_set() {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 3000).generate();
        let grid = GridPartition::build(&scene, GridConfig::new(4));
        let layout = crate::scene::DramLayout::build(&scene, &grid);
        let cam = camera();
        let t = 0.62;

        let mut d1 = DramModel::default_lpddr5();
        let conv = ConventionalCulling::new(&scene, &layout).cull(&cam, t, &mut d1);
        let mut d2 = DramModel::default_lpddr5();
        let drfc = DrFc::new(&scene, &grid, &layout).cull(&cam, t, &mut d2);

        let a: std::collections::BTreeSet<u32> = conv.visible.into_iter().collect();
        let b: std::collections::BTreeSet<u32> = drfc.visible.into_iter().collect();
        assert_eq!(a, b, "both culling paths must produce the identical visible set");
        // And DR-FC must use (weakly) less DRAM.
        assert!(d2.stats().bytes <= d1.stats().bytes);
    }
}
