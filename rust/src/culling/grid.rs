//! Two-stage coarse grid partition (paper §3.1, Fig. 5(a)).
//!
//! Stage 1 distributes Gaussians into `n` coarse 1-D **temporal** grids by
//! temporal mean; stage 2 partitions each temporal slice into `n×n×n` coarse
//! **cubic** grids by spatial mean. A Gaussian lives in exactly one *central*
//! cell (by its means); when its 3σ spatial extent or motion path spans
//! neighbor cells, those cells hold *pointer references* (Fig. 5(b)).
//!
//! Static scenes use a single temporal slice; static Gaussians in dynamic
//! scenes are replicated by reference across the temporal slices their
//! (infinite) support covers — we place them centrally in slice 0 and
//! reference them from every other slice, matching the paper's
//! pointer-not-copy rule.

use crate::math::{Aabb, Vec3};
use crate::scene::Scene;

/// Grid resolution: `n` temporal slices × `n³` cubic cells per slice
/// (the paper's Fig. 9 sweeps n ∈ {4, 8, 16}; "the grid number represents
/// both the depth of 1D time grids and the dimensions of cubic grids").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GridConfig {
    /// Temporal slices (1 for static scenes).
    pub n_temporal: usize,
    /// Cubic cells per axis.
    pub n_spatial: usize,
}

impl GridConfig {
    /// The paper's single-knob configuration.
    pub fn new(n: usize) -> GridConfig {
        GridConfig { n_temporal: n, n_spatial: n }
    }

    /// For static scenes: one temporal slice.
    pub fn static_scene(n: usize) -> GridConfig {
        GridConfig { n_temporal: 1, n_spatial: n }
    }

    pub fn cells_per_slice(&self) -> usize {
        self.n_spatial * self.n_spatial * self.n_spatial
    }

    pub fn total_cells(&self) -> usize {
        self.n_temporal * self.cells_per_slice()
    }
}

/// One grid cell's membership lists (original Gaussian indices).
#[derive(Debug, Clone, Default)]
pub struct GridCell {
    /// Gaussians stored centrally in this cell.
    pub central: Vec<u32>,
    /// Gaussians referenced by pointer (central elsewhere).
    pub refs: Vec<u32>,
}

/// The built partition.
#[derive(Debug, Clone)]
pub struct GridPartition {
    pub config: GridConfig,
    /// Spatial bounds covered by the cubic grids.
    pub bounds: Aabb,
    /// Temporal span covered by the 1-D grids.
    pub time_span: (f32, f32),
    /// Cells in `t-major` order: `cell[t * n³ + (z*n + y)*n + x]`.
    pub cells: Vec<GridCell>,
}

impl GridPartition {
    /// Offline partition build (runs once per scene; not on the frame path).
    pub fn build(scene: &Scene, mut config: GridConfig) -> GridPartition {
        if !scene.dynamic {
            config.n_temporal = 1;
        }
        let bounds = pad_bounds(scene.bounds());
        let time_span = scene.time_span;
        let mut cells = vec![GridCell::default(); config.total_cells()];

        let part = GridPartitionRef {
            config,
            bounds,
            time_span,
        };

        for (gi, g) in scene.gaussians.iter().enumerate() {
            let gi = gi as u32;
            // Central cell from the means.
            let t_idx = part.temporal_index(if g.is_static() { time_span.0 } else { g.mu_t });
            let s_idx = part.spatial_index(g.mu);
            let central_cell = part.cell_of(t_idx, s_idx);
            cells[central_cell].central.push(gi);

            // Neighbor references: every other (t, cell) the support touches.
            let r = g.radius3();
            let (gt0, gt1) = g.time_extent();
            let t_lo = part.temporal_index(gt0.max(time_span.0));
            let t_hi = part.temporal_index(gt1.min(time_span.1));
            for ti in t_lo..=t_hi {
                // Spatial extent at the slice's representative times: the
                // mean moves with velocity, so take the AABB of the swept
                // 3σ sphere across the slice's time range.
                let (st0, st1) = part.temporal_range(ti);
                let m0 = g.mean_at(st0.max(gt0));
                let m1 = g.mean_at(st1.min(gt1));
                let swept = Aabb::new(m0.min(m1) - Vec3::splat(r), m0.max(m1) + Vec3::splat(r));
                part.for_each_overlapping_cell(&swept, |si| {
                    let ci = part.cell_of(ti, si);
                    if ci != central_cell {
                        cells[ci].refs.push(gi);
                    }
                });
            }
        }

        GridPartition {
            config,
            bounds,
            time_span,
            cells,
        }
    }

    #[inline]
    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    /// Flat cell index from temporal slice + spatial (x, y, z).
    #[inline]
    pub fn cell_of(&self, t: usize, s: usize) -> usize {
        self.as_ref().cell_of(t, s)
    }

    /// AABB of a cell (by flat index).
    pub fn cell_aabb(&self, flat: usize) -> Aabb {
        let n = self.config.n_spatial;
        let s = flat % self.config.cells_per_slice();
        let x = s % n;
        let y = (s / n) % n;
        let z = s / (n * n);
        let ext = self.bounds.extent();
        let step = Vec3::new(ext.x / n as f32, ext.y / n as f32, ext.z / n as f32);
        let min = self.bounds.min
            + Vec3::new(step.x * x as f32, step.y * y as f32, step.z * z as f32);
        Aabb::new(min, min + step)
    }

    /// Time range of a cell's temporal slice (by flat index).
    pub fn cell_time_range(&self, flat: usize) -> (f32, f32) {
        let t = flat / self.config.cells_per_slice();
        self.as_ref().temporal_range(t)
    }

    /// Total stored references (pointer-table size driver).
    pub fn total_refs(&self) -> usize {
        self.cells.iter().map(|c| c.refs.len()).sum()
    }

    fn as_ref(&self) -> GridPartitionRef {
        GridPartitionRef {
            config: self.config,
            bounds: self.bounds,
            time_span: self.time_span,
        }
    }
}

/// The pure geometry of a partition (no membership) — shared by build and
/// query code.
#[derive(Debug, Clone, Copy)]
struct GridPartitionRef {
    config: GridConfig,
    bounds: Aabb,
    time_span: (f32, f32),
}

impl GridPartitionRef {
    #[inline]
    fn cell_of(&self, t: usize, s: usize) -> usize {
        t * self.config.cells_per_slice() + s
    }

    fn temporal_index(&self, t: f32) -> usize {
        let (t0, t1) = self.time_span;
        let n = self.config.n_temporal;
        if n <= 1 || t1 <= t0 {
            return 0;
        }
        let f = ((t - t0) / (t1 - t0)).clamp(0.0, 1.0);
        ((f * n as f32) as usize).min(n - 1)
    }

    fn temporal_range(&self, idx: usize) -> (f32, f32) {
        let (t0, t1) = self.time_span;
        let n = self.config.n_temporal.max(1);
        let step = (t1 - t0) / n as f32;
        (t0 + step * idx as f32, t0 + step * (idx + 1) as f32)
    }

    fn spatial_index(&self, p: Vec3) -> usize {
        let n = self.config.n_spatial;
        let ext = self.bounds.extent();
        let f = |v: f32, lo: f32, e: f32| -> usize {
            if e <= 0.0 {
                return 0;
            }
            (((v - lo) / e * n as f32) as usize).min(n - 1)
        };
        let x = f(p.x, self.bounds.min.x, ext.x);
        let y = f(p.y, self.bounds.min.y, ext.y);
        let z = f(p.z, self.bounds.min.z, ext.z);
        (z * n + y) * n + x
    }

    fn for_each_overlapping_cell(&self, b: &Aabb, mut f: impl FnMut(usize)) {
        let n = self.config.n_spatial;
        let ext = self.bounds.extent();
        let idx = |v: f32, lo: f32, e: f32| -> usize {
            if e <= 0.0 {
                return 0;
            }
            (((v - lo) / e * n as f32).floor().max(0.0) as usize).min(n - 1)
        };
        let x0 = idx(b.min.x, self.bounds.min.x, ext.x);
        let x1 = idx(b.max.x, self.bounds.min.x, ext.x);
        let y0 = idx(b.min.y, self.bounds.min.y, ext.y);
        let y1 = idx(b.max.y, self.bounds.min.y, ext.y);
        let z0 = idx(b.min.z, self.bounds.min.z, ext.z);
        let z1 = idx(b.max.z, self.bounds.min.z, ext.z);
        for z in z0..=z1 {
            for y in y0..=y1 {
                for x in x0..=x1 {
                    f((z * n + y) * n + x);
                }
            }
        }
    }
}

/// Pad scene bounds by 1 % so boundary means index cleanly.
fn pad_bounds(b: Aabb) -> Aabb {
    if b.is_empty() {
        return Aabb::new(Vec3::ZERO, Vec3::ONE);
    }
    let pad = b.extent() * 0.005 + Vec3::splat(1e-4);
    Aabb::new(b.min - pad, b.max + pad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::synth::{SceneKind, SynthParams};

    #[test]
    fn every_gaussian_has_exactly_one_central_cell() {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 3000).generate();
        let grid = GridPartition::build(&scene, GridConfig::new(4));
        let total: usize = grid.cells.iter().map(|c| c.central.len()).sum();
        assert_eq!(total, scene.len());
    }

    #[test]
    fn static_scene_collapses_to_one_temporal_slice() {
        let scene = SynthParams::new(SceneKind::StaticLarge, 1000).generate();
        let grid = GridPartition::build(&scene, GridConfig::new(8));
        assert_eq!(grid.config.n_temporal, 1);
        assert_eq!(grid.n_cells(), 8 * 8 * 8);
    }

    #[test]
    fn central_cell_contains_mean() {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 1000).generate();
        let grid = GridPartition::build(&scene, GridConfig::new(4));
        for (ci, cell) in grid.cells.iter().enumerate() {
            let b = grid.cell_aabb(ci);
            for &gi in &cell.central {
                let g = &scene.gaussians[gi as usize];
                assert!(
                    b.contains(g.mu),
                    "gaussian {gi} mean {:?} not inside its central cell {ci} {:?}",
                    g.mu,
                    b
                );
            }
        }
    }

    #[test]
    fn refs_never_duplicate_central() {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 2000).generate();
        let grid = GridPartition::build(&scene, GridConfig::new(4));
        for cell in &grid.cells {
            for &r in &cell.refs {
                assert!(!cell.central.contains(&r));
            }
        }
    }

    #[test]
    fn gaussians_reachable_across_their_temporal_support() {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 2000).generate();
        let grid = GridPartition::build(&scene, GridConfig::new(4));
        let (t0, t1) = grid.time_span;
        let n_slices = grid.config.n_temporal;
        let slice_of = |t: f32| -> usize {
            let f = ((t - t0) / (t1 - t0)).clamp(0.0, 1.0);
            ((f * n_slices as f32) as usize).min(n_slices - 1)
        };
        // Every Gaussian must appear (central or ref) in every temporal
        // slice its 3σ time extent overlaps — otherwise DR-FC would lose it.
        for gi in (0..scene.len() as u32).step_by(37) {
            let g = &scene.gaussians[gi as usize];
            let (gt0, gt1) = g.time_extent();
            let lo = slice_of(gt0.max(t0));
            let hi = slice_of(gt1.min(t1));
            let mut slices_seen = vec![false; n_slices];
            for (ci, cell) in grid.cells.iter().enumerate() {
                if cell.central.contains(&gi) || cell.refs.contains(&gi) {
                    slices_seen[ci / grid.config.cells_per_slice()] = true;
                }
            }
            for s in lo..=hi {
                assert!(
                    slices_seen[s],
                    "gaussian {gi} with time extent ({gt0},{gt1}) missing from slice {s}"
                );
            }
        }
    }

    #[test]
    fn finer_grids_have_more_cells_fewer_central_per_cell() {
        let scene = SynthParams::new(SceneKind::DynamicLarge, 5000).generate();
        let g4 = GridPartition::build(&scene, GridConfig::new(4));
        let g8 = GridPartition::build(&scene, GridConfig::new(8));
        assert!(g8.n_cells() > g4.n_cells());
        let max4 = g4.cells.iter().map(|c| c.central.len()).max().unwrap();
        let max8 = g8.cells.iter().map(|c| c.central.len()).max().unwrap();
        assert!(max8 <= max4);
    }

    #[test]
    fn cell_aabbs_tile_bounds() {
        let scene = SynthParams::new(SceneKind::StaticLarge, 500).generate();
        let grid = GridPartition::build(&scene, GridConfig::new(4));
        let mut union = Aabb::EMPTY;
        let mut volume = 0.0f64;
        for ci in 0..grid.n_cells() {
            let b = grid.cell_aabb(ci);
            union = union.union(&b);
            let e = b.extent();
            volume += e.x as f64 * e.y as f64 * e.z as f64;
        }
        let be = grid.bounds.extent();
        let bounds_volume = be.x as f64 * be.y as f64 * be.z as f64;
        assert!((volume / bounds_volume - 1.0).abs() < 1e-3);
        assert!((union.min - grid.bounds.min).length() < 1e-3);
        assert!((union.max - grid.bounds.max).length() < 1e-3);
    }
}
