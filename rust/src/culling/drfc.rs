//! DRAM-access-reduction frustum culling (DR-FC, paper §3.1).
//!
//! On-chip grid metadata (cell AABBs + DRAM address ranges) lets the
//! controller identify out-of-frustum cells **without any DRAM access**.
//! Visible cells' central runs are fetched as contiguous bursts; Gaussians
//! referenced from visible neighbor cells are fetched individually unless
//! their central cell is itself scheduled (the duplicate-reference skip).

use super::grid::GridPartition;
use super::{gaussian_visible, Containment};
use crate::camera::Camera;
use crate::math::Frustum;
use crate::memory::dram::{DramModel, MemSink};
use crate::scene::{DramLayout, Scene};

/// Result of one culling pass. The output vectors *and* the dedup /
/// coalescing scratch are pooled: [`DrFc::cull_into`] clears and refills
/// them in place, so a steady-state frame allocates nothing (the
/// zero-allocation preprocess contract, asserted by the stage-graph
/// determinism suite through [`CullOutput::scratch_capacities`]).
#[derive(Debug, Clone, Default)]
pub struct CullOutput {
    /// Cells whose AABB intersects the frustum (flat indices).
    pub visible_cells: Vec<usize>,
    /// Gaussians fetched from DRAM (deduplicated, original indices).
    pub candidates: Vec<u32>,
    /// Candidates that passed exact (per-Gaussian) culling.
    pub visible: Vec<u32>,
    /// Gaussian records fetched (== candidates.len(), kept for symmetry
    /// with the conventional path where all N are fetched).
    pub fetched: u64,
    /// Pooled per-Gaussian dedup flags (sized to the scene).
    seen: Vec<bool>,
    /// Pooled neighbor-reference address scratch (burst coalescing).
    ref_addrs: Vec<u64>,
}

impl CullOutput {
    /// Reset the per-frame outputs, keeping every buffer's capacity.
    pub fn clear(&mut self) {
        self.visible_cells.clear();
        self.candidates.clear();
        self.visible.clear();
        self.fetched = 0;
    }

    /// Capacities of every pooled buffer — folded into the pipeline's
    /// zero-allocation signature.
    pub fn scratch_capacities(&self) -> [usize; 5] {
        [
            self.visible_cells.capacity(),
            self.candidates.capacity(),
            self.visible.capacity(),
            self.seen.capacity(),
            self.ref_addrs.capacity(),
        ]
    }
}

/// Persistent cross-frame fetch-residency state for
/// [`DrFc::cull_scheduled_reuse`]: which cell runs (central records +
/// pointer table) and which individually-referenced records are still held
/// on-chip from an earlier frame's fetch. The model idealizes the paper's
/// on-chip retention of the visible working set — a fetched run stays
/// resident until the update stream dirties it ([`CullReuse::invalidate`]
/// drops residency for dirtied cells/records each frame), so a reused
/// fetch is always bit-fresh: *clean* means the DRAM bytes are unchanged
/// since they were last read.
#[derive(Debug, Clone, Default)]
pub struct CullReuse {
    /// Per-cell: central run + pointer table held from a prior fetch.
    cell_resident: Vec<bool>,
    /// Per-record (original Gaussian index): record bytes held from a
    /// prior fetch (central-run or individual neighbor-reference read).
    record_resident: Vec<bool>,
}

impl CullReuse {
    /// Fresh (nothing resident) state for a scene with `n_cells` grid
    /// cells and `n_records` Gaussians.
    pub fn new(n_cells: usize, n_records: usize) -> CullReuse {
        CullReuse {
            cell_resident: vec![false; n_cells],
            record_resident: vec![false; n_records],
        }
    }

    /// Drop residency for everything this frame's update stream changed.
    /// Must run after [`TemporalStream::advance`](crate::scene::TemporalStream)
    /// and *before* culling: a dirtied cell run (or record) is stale
    /// on-chip and must be re-fetched from DRAM.
    pub fn invalidate(&mut self, dirty_cells: &[bool], dirty_records: &[bool]) {
        debug_assert_eq!(dirty_cells.len(), self.cell_resident.len());
        debug_assert_eq!(dirty_records.len(), self.record_resident.len());
        for (res, &dirty) in self.cell_resident.iter_mut().zip(dirty_cells) {
            *res &= !dirty;
        }
        for (res, &dirty) in self.record_resident.iter_mut().zip(dirty_records) {
            *res &= !dirty;
        }
    }

    /// Forget everything (cold start — e.g. a session resume on fresh
    /// hardware state).
    pub fn reset(&mut self) {
        self.cell_resident.iter_mut().for_each(|r| *r = false);
        self.record_resident.iter_mut().for_each(|r| *r = false);
    }
}

/// Per-frame statistics of one [`DrFc::cull_scheduled_reuse`] pass.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CullReuseStats {
    /// Visible cells whose run (+ pointer table) replayed a prior fetch.
    pub cells_reused: u64,
    /// Visible cells whose run was (re-)fetched from DRAM this frame.
    pub cells_fetched: u64,
    /// Neighbor-referenced records that replayed a prior fetch.
    pub refs_reused: u64,
    /// Neighbor-referenced records fetched from DRAM this frame.
    pub refs_fetched: u64,
    /// DRAM bytes the reused fetches would have cost.
    pub bytes_saved: u64,
}

impl CullReuseStats {
    pub fn add(&mut self, o: &CullReuseStats) {
        self.cells_reused += o.cells_reused;
        self.cells_fetched += o.cells_fetched;
        self.refs_reused += o.refs_reused;
        self.refs_fetched += o.refs_fetched;
        self.bytes_saved += o.bytes_saved;
    }

    /// Fraction of visible-cell fetches served from retained state.
    pub fn cell_hit_rate(&self) -> f64 {
        let total = self.cells_reused + self.cells_fetched;
        if total == 0 {
            return 0.0;
        }
        self.cells_reused as f64 / total as f64
    }
}

/// The DR-FC engine: borrows the offline-built partition + layout.
pub struct DrFc<'a> {
    pub scene: &'a Scene,
    pub grid: &'a GridPartition,
    pub layout: &'a DramLayout,
}

impl<'a> DrFc<'a> {
    pub fn new(scene: &'a Scene, grid: &'a GridPartition, layout: &'a DramLayout) -> Self {
        DrFc { scene, grid, layout }
    }

    /// Cull for camera pose + scene time `t`, charging fetches to `dram`.
    /// Convenience wrapper over [`DrFc::cull_into`] building a fresh
    /// [`CullOutput`] (benches, baselines, tests).
    pub fn cull(&self, cam: &Camera, t: f32, dram: &mut DramModel) -> CullOutput {
        let mut out = CullOutput::default();
        self.cull_into(cam, t, dram, &mut out);
        out
    }

    /// Cull into a pooled [`CullOutput`], issuing every DRAM request
    /// through `mem` — a [`MemPort`](crate::memory::MemPort) on the
    /// pipeline path, the synchronous oracle in the baselines. Request
    /// order and output contents are identical to the pre-refactor
    /// allocating path (the stage-graph determinism suite pins this).
    pub fn cull_into<M: MemSink>(
        &self,
        cam: &Camera,
        t: f32,
        mem: &mut M,
        out: &mut CullOutput,
    ) {
        out.clear();
        // Pass 1 (no DRAM): find visible cells in the temporal slice of t.
        let frustum = cam.frustum();
        for flat in self.slice_cell_range(t) {
            if self.cell_test(flat, &frustum) {
                out.visible_cells.push(flat);
            }
        }
        self.cull_scheduled(cam, t, mem, out);
    }

    /// The flat grid-cell index range of the temporal slice containing `t`
    /// — the pass-1 test domain. The range is contiguous, so the parallel
    /// executor can chunk it per worker and concatenate the per-worker
    /// visible-cell partials in worker order to reproduce the serial
    /// ascending-flat-index scan exactly.
    pub fn slice_cell_range(&self, t: f32) -> std::ops::Range<usize> {
        let slice = self.temporal_slice_of(t);
        let per_slice = self.grid.config.cells_per_slice();
        slice * per_slice..(slice + 1) * per_slice
    }

    /// The pass-1 visibility test of one grid cell (pure, no DRAM): skip
    /// empty cells outright, else AABB-vs-frustum.
    pub fn cell_test(&self, flat: usize, frustum: &Frustum) -> bool {
        let cell = &self.grid.cells[flat];
        if cell.central.is_empty() && cell.refs.is_empty() {
            return false;
        }
        frustum.test_aabb(&self.grid.cell_aabb(flat)) != Containment::Outside
    }

    /// Passes 2–3 over an already-populated `out.visible_cells` list
    /// (candidate fetch scheduling + exact per-Gaussian culling). Pass 1 —
    /// serial in [`DrFc::cull_into`], fanned out per cell chunk by the
    /// pipeline's cull stage — must have pushed the slice's visible cells
    /// in ascending flat order; request order and outputs are then
    /// identical to the pre-refactor single-pass path.
    pub fn cull_scheduled<M: MemSink>(
        &self,
        cam: &Camera,
        t: f32,
        mem: &mut M,
        out: &mut CullOutput,
    ) {
        let frustum = cam.frustum();
        let CullOutput { visible_cells, candidates, visible, fetched, seen, ref_addrs } = out;

        // Pass 2: schedule DRAM reads. Central runs as big contiguous reads.
        seen.clear();
        seen.resize(self.scene.len(), false);
        for &flat in visible_cells.iter() {
            let (start, end) = self.layout.cell_ranges[flat];
            if end > start {
                mem.read(start, end - start);
            }
            for &gi in &self.grid.cells[flat].central {
                if !seen[gi as usize] {
                    seen[gi as usize] = true;
                    candidates.push(gi);
                }
            }
        }
        // Neighbor references: skip when the central cell is scheduled
        // (duplicate-reference skip) or the record was already fetched.
        // Because spanning Gaussians are stored contiguously at the front of
        // their central cell (Fig. 5(b)), referenced records coalesce into
        // few burst-friendly ranges: sort addresses and merge adjacent runs.
        let stride = self.layout.bytes_per_gaussian;
        ref_addrs.clear();
        for &flat in visible_cells.iter() {
            // The cell's pointer table itself is a contiguous DRAM read.
            let (ps, pe) = self.layout.pointer_table_range(flat);
            if pe > ps {
                mem.read(ps, pe - ps);
            }
            for &gi in &self.layout.cell_refs[flat] {
                if seen[gi as usize] {
                    continue; // central run already read (or earlier ref)
                }
                seen[gi as usize] = true;
                ref_addrs.push(self.layout.addr[gi as usize]);
                candidates.push(gi);
            }
        }
        ref_addrs.sort_unstable();
        let mut i = 0;
        while i < ref_addrs.len() {
            let start = ref_addrs[i];
            let mut end = start + stride;
            let mut j = i + 1;
            while j < ref_addrs.len() && ref_addrs[j] <= end {
                end = ref_addrs[j] + stride;
                j += 1;
            }
            mem.read(start, end - start);
            i = j;
        }
        *fetched = candidates.len() as u64;

        // Pass 3: exact per-Gaussian culling on fetched candidates.
        for &gi in candidates.iter() {
            if super::gaussian_visible_in(&self.scene.gaussians[gi as usize], &frustum, t) {
                visible.push(gi);
            }
        }
    }

    /// Passes 2–3 with dirty-cell-aware fetch reuse — the temporal
    /// extension of DR-FC. Outputs (`visible_cells` / `candidates` /
    /// `visible` / `fetched`) are bit-identical to [`DrFc::cull_scheduled`]
    /// by construction: every visibility decision is recomputed from the
    /// immutable 4D scene exactly as the full pass does. Only the *DRAM
    /// traffic* changes: a cell run (or neighbor-referenced record) that
    /// was fetched by an earlier frame and whose records did not change
    /// since ([`CullReuse`] residency, invalidated per frame from the
    /// update stream's dirty flags) replays last frame's fetch instead of
    /// re-reading DRAM. The caller must run
    /// [`CullReuse::invalidate`] with the frame's dirty flags *before*
    /// culling.
    pub fn cull_scheduled_reuse<M: MemSink>(
        &self,
        cam: &Camera,
        t: f32,
        mem: &mut M,
        out: &mut CullOutput,
        reuse: &mut CullReuse,
    ) -> CullReuseStats {
        let frustum = cam.frustum();
        let CullOutput { visible_cells, candidates, visible, fetched, seen, ref_addrs } = out;
        let mut stats = CullReuseStats::default();

        // Pass 2: schedule DRAM reads, skipping runs that are still clean
        // since their last fetch. The candidate list is built identically
        // either way — reuse replays the verdict, not the records.
        seen.clear();
        seen.resize(self.scene.len(), false);
        for &flat in visible_cells.iter() {
            let (start, end) = self.layout.cell_ranges[flat];
            // Pointer tables are immutable under updates (record *values*
            // change, references don't), so they ride the cell's residency.
            let (ps, pe) = self.layout.pointer_table_range(flat);
            if reuse.cell_resident[flat] {
                stats.cells_reused += 1;
                stats.bytes_saved += (end - start) + (pe - ps);
            } else {
                stats.cells_fetched += 1;
                reuse.cell_resident[flat] = true;
                if end > start {
                    mem.read(start, end - start);
                }
                if pe > ps {
                    mem.read(ps, pe - ps);
                }
            }
            for &gi in &self.grid.cells[flat].central {
                reuse.record_resident[gi as usize] = true;
                if !seen[gi as usize] {
                    seen[gi as usize] = true;
                    candidates.push(gi);
                }
            }
        }
        let stride = self.layout.bytes_per_gaussian;
        ref_addrs.clear();
        for &flat in visible_cells.iter() {
            for &gi in &self.layout.cell_refs[flat] {
                if seen[gi as usize] {
                    continue; // central run already read (or earlier ref)
                }
                seen[gi as usize] = true;
                candidates.push(gi);
                if reuse.record_resident[gi as usize] {
                    stats.refs_reused += 1;
                    stats.bytes_saved += stride;
                } else {
                    stats.refs_fetched += 1;
                    reuse.record_resident[gi as usize] = true;
                    ref_addrs.push(self.layout.addr[gi as usize]);
                }
            }
        }
        ref_addrs.sort_unstable();
        let mut i = 0;
        while i < ref_addrs.len() {
            let start = ref_addrs[i];
            let mut end = start + stride;
            let mut j = i + 1;
            while j < ref_addrs.len() && ref_addrs[j] <= end {
                end = ref_addrs[j] + stride;
                j += 1;
            }
            mem.read(start, end - start);
            i = j;
        }
        *fetched = candidates.len() as u64;

        // Pass 3: exact per-Gaussian culling, identical to the full pass.
        for &gi in candidates.iter() {
            if super::gaussian_visible_in(&self.scene.gaussians[gi as usize], &frustum, t) {
                visible.push(gi);
            }
        }
        stats
    }

    /// Which temporal slice contains scene time `t`.
    fn temporal_slice_of(&self, t: f32) -> usize {
        let (t0, t1) = self.grid.time_span;
        let n = self.grid.config.n_temporal;
        if n <= 1 || t1 <= t0 {
            return 0;
        }
        let f = ((t - t0) / (t1 - t0)).clamp(0.0, 1.0);
        ((f * n as f32) as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::culling::grid::GridConfig;
    use crate::math::Vec3;
    use crate::scene::synth::{SceneKind, SynthParams};

    fn setup(n: usize, grid_n: usize) -> (Scene, GridPartition, DramLayout) {
        let scene = SynthParams::new(SceneKind::DynamicLarge, n).generate();
        let grid = GridPartition::build(&scene, GridConfig::new(grid_n));
        let layout = DramLayout::build(&scene, &grid);
        (scene, grid, layout)
    }

    fn camera() -> Camera {
        Camera::look_at(
            Vec3::new(0.0, 4.0, 25.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            16.0 / 9.0,
            0.1,
            200.0,
        )
    }

    #[test]
    fn no_candidate_duplicates() {
        let (scene, grid, layout) = setup(4000, 4);
        let drfc = DrFc::new(&scene, &grid, &layout);
        let mut dram = DramModel::default_lpddr5();
        let out = drfc.cull(&camera(), 0.5, &mut dram);
        let mut seen = std::collections::HashSet::new();
        for &c in &out.candidates {
            assert!(seen.insert(c), "duplicate candidate {c}");
        }
    }

    #[test]
    fn finds_same_visible_set_as_exhaustive() {
        // Correctness invariant: DR-FC must not lose any visible Gaussian.
        let (scene, grid, layout) = setup(3000, 4);
        let drfc = DrFc::new(&scene, &grid, &layout);
        let cam = camera();
        let t = 0.37;
        let mut dram = DramModel::default_lpddr5();
        let out = drfc.cull(&cam, t, &mut dram);

        let exhaustive: Vec<u32> = (0..scene.len() as u32)
            .filter(|&gi| gaussian_visible(&scene.gaussians[gi as usize], &cam, t))
            .collect();
        let got: std::collections::HashSet<u32> = out.visible.iter().copied().collect();
        for gi in &exhaustive {
            assert!(got.contains(gi), "DR-FC missed visible gaussian {gi}");
        }
        // And it must not report anything the exact test rejects.
        let exact: std::collections::HashSet<u32> = exhaustive.into_iter().collect();
        for gi in &out.visible {
            assert!(exact.contains(gi));
        }
    }

    #[test]
    fn fetches_fewer_records_than_scene() {
        let (scene, grid, layout) = setup(6000, 8);
        let drfc = DrFc::new(&scene, &grid, &layout);
        let mut dram = DramModel::default_lpddr5();
        let out = drfc.cull(&camera(), 0.1, &mut dram);
        assert!(
            (out.fetched as usize) < scene.len(),
            "DR-FC should cull out-of-frustum/out-of-time cells: fetched {} of {}",
            out.fetched,
            scene.len()
        );
        assert!(out.fetched > 0);
    }

    #[test]
    fn dram_traffic_less_than_full_scene() {
        let (scene, grid, layout) = setup(6000, 8);
        let drfc = DrFc::new(&scene, &grid, &layout);
        let mut dram = DramModel::default_lpddr5();
        drfc.cull(&camera(), 0.1, &mut dram);
        assert!(dram.stats().bytes < scene.dram_bytes());
    }

    #[test]
    fn cull_into_reuses_buffers_and_matches_cull() {
        let (scene, grid, layout) = setup(3000, 4);
        let drfc = DrFc::new(&scene, &grid, &layout);
        let cam = camera();

        let mut d1 = DramModel::default_lpddr5();
        let fresh = drfc.cull(&cam, 0.4, &mut d1);

        let mut out = CullOutput::default();
        let mut d2 = DramModel::default_lpddr5();
        drfc.cull_into(&cam, 0.4, &mut d2, &mut out);
        assert_eq!(out.visible_cells, fresh.visible_cells);
        assert_eq!(out.candidates, fresh.candidates);
        assert_eq!(out.visible, fresh.visible);
        assert_eq!(out.fetched, fresh.fetched);
        assert_eq!(d1.stats(), d2.stats(), "identical request streams");

        // Re-culling the same view must not grow any pooled buffer.
        let caps = out.scratch_capacities();
        let mut d3 = DramModel::default_lpddr5();
        drfc.cull_into(&cam, 0.4, &mut d3, &mut out);
        assert_eq!(out.scratch_capacities(), caps, "steady-state reallocation");
        assert_eq!(out.candidates, fresh.candidates);
    }

    #[test]
    fn scheduled_split_matches_single_pass_cull() {
        // The executor's fan-out contract: pass 1 computed externally (in
        // ascending flat order) + `cull_scheduled` must equal `cull_into`.
        let (scene, grid, layout) = setup(3000, 4);
        let drfc = DrFc::new(&scene, &grid, &layout);
        let cam = camera();
        let t = 0.4;

        let mut d1 = DramModel::default_lpddr5();
        let single = drfc.cull(&cam, t, &mut d1);

        let mut out = CullOutput::default();
        out.clear();
        let frustum = cam.frustum();
        for flat in drfc.slice_cell_range(t) {
            if drfc.cell_test(flat, &frustum) {
                out.visible_cells.push(flat);
            }
        }
        let mut d2 = DramModel::default_lpddr5();
        drfc.cull_scheduled(&cam, t, &mut d2, &mut out);
        assert_eq!(out.visible_cells, single.visible_cells);
        assert_eq!(out.candidates, single.candidates);
        assert_eq!(out.visible, single.visible);
        assert_eq!(out.fetched, single.fetched);
        assert_eq!(d1.stats(), d2.stats(), "identical request streams");
    }

    #[test]
    fn reuse_outputs_match_full_recull_bit_exactly() {
        let (scene, grid, layout) = setup(3000, 4);
        let drfc = DrFc::new(&scene, &grid, &layout);
        let cam = camera();
        let t = 0.4;
        let frustum = cam.frustum();

        let pass1 = |out: &mut CullOutput| {
            out.clear();
            for flat in drfc.slice_cell_range(t) {
                if drfc.cell_test(flat, &frustum) {
                    out.visible_cells.push(flat);
                }
            }
        };

        let mut full = CullOutput::default();
        let mut d_full = DramModel::default_lpddr5();
        pass1(&mut full);
        drfc.cull_scheduled(&cam, t, &mut d_full, &mut full);

        // Cold reuse pass: nothing resident yet, everything fetches.
        let mut reuse = CullReuse::new(grid.cells.len(), scene.len());
        let mut out = CullOutput::default();
        let mut d_cold = DramModel::default_lpddr5();
        pass1(&mut out);
        let cold = drfc.cull_scheduled_reuse(&cam, t, &mut d_cold, &mut out, &mut reuse);
        assert_eq!(out.visible_cells, full.visible_cells);
        assert_eq!(out.candidates, full.candidates);
        assert_eq!(out.visible, full.visible);
        assert_eq!(out.fetched, full.fetched);
        assert_eq!(cold.cells_reused, 0);
        assert_eq!(cold.refs_reused, 0);
        assert_eq!(
            d_cold.stats().bytes,
            d_full.stats().bytes,
            "cold reuse fetches exactly the full pass's bytes"
        );

        // Warm pass, nothing dirtied: outputs identical, zero DRAM bytes.
        let mut d_warm = DramModel::default_lpddr5();
        pass1(&mut out);
        let warm = drfc.cull_scheduled_reuse(&cam, t, &mut d_warm, &mut out, &mut reuse);
        assert_eq!(out.candidates, full.candidates);
        assert_eq!(out.visible, full.visible);
        assert_eq!(out.fetched, full.fetched);
        assert_eq!(warm.cells_fetched, 0);
        assert_eq!(warm.refs_fetched, 0);
        assert_eq!(d_warm.stats().bytes, 0, "fully-clean frame re-reads nothing");
        assert!(warm.bytes_saved > 0);

        // Dirty half the cells: outputs still identical, partial re-fetch.
        let mut dirty_cells = vec![false; grid.cells.len()];
        let mut dirty_records = vec![false; scene.len()];
        let stride = layout.bytes_per_gaussian;
        for (ci, flag) in dirty_cells.iter_mut().enumerate() {
            if ci % 2 == 0 {
                *flag = true;
                let (start, end) = layout.cell_ranges[ci];
                for k in (start / stride) as usize..(end / stride) as usize {
                    dirty_records[layout.order[k] as usize] = true;
                }
            }
        }
        reuse.invalidate(&dirty_cells, &dirty_records);
        let mut d_dirty = DramModel::default_lpddr5();
        pass1(&mut out);
        let part = drfc.cull_scheduled_reuse(&cam, t, &mut d_dirty, &mut out, &mut reuse);
        assert_eq!(out.candidates, full.candidates);
        assert_eq!(out.visible, full.visible);
        assert_eq!(out.fetched, full.fetched);
        assert!(part.cells_fetched > 0, "dirtied cells must re-fetch");
        assert!(part.cells_reused > 0, "clean cells must replay");
        assert!(d_dirty.stats().bytes > 0);
        assert!(d_dirty.stats().bytes < d_full.stats().bytes);
    }

    #[test]
    fn temporal_slice_selection() {
        let (scene, grid, layout) = setup(1000, 4);
        let drfc = DrFc::new(&scene, &grid, &layout);
        assert_eq!(drfc.temporal_slice_of(0.0), 0);
        assert_eq!(drfc.temporal_slice_of(0.3), 1);
        assert_eq!(drfc.temporal_slice_of(0.99), 3);
        assert_eq!(drfc.temporal_slice_of(1.0), 3);
    }
}
