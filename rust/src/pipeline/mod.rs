//! The per-frame rendering engine: preprocess → sort → blend, with the
//! paper's four techniques as switchable features, dual-tracked as a
//! numeric path (real pixels) and a performance path (hardware events →
//! cycles/energy). See DESIGN.md §3.

pub mod frame;
pub mod profile;

pub use frame::{FramePipeline, FrameResult, PipelineConfig};
pub use profile::{profile_breakdown, PhaseShare};
