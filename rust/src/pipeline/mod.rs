//! The per-frame rendering engine, structured as an explicit **stage
//! graph** (mirroring how streaming 3DGS accelerators organize their
//! datapath into stages with reusable on-chip state):
//!
//! ```text
//!            ┌──────────── FrameBind (shared, immutable) ────────────┐
//!            │ scene · grid partition · DRAM layout · FP16 copy ·    │
//!            │ pipeline config · tile grid                           │
//!            └───────────────────────────────────────────────────────┘
//!   CullStage → ProjectStage → IntersectStage → GroupStage → SortStage → BlendStage
//!     DR-FC      eq. 7–8 +       tile binning +    ATG +      AII-Sort    SRAM/DRAM
//!     §3.1       DCIM MACs       connection graph  posteriori  §3.2       reuse + NMC
//!            ┌───────────────────────────────────────────────────────┐
//!            │ FrameCtx (shared, mutable): energy/latency/traffic    │
//!            │ accumulators + pooled scratch (splats, bins, block    │
//!            │ working sets, sorted bins, tile order, conn graph)    │
//!            └───────────────────────────────────────────────────────┘
//! ```
//!
//! * [`FramePipeline::render_frame`] is a linear composition of the six
//!   stage calls over the pooled [`FrameCtx`]; **steady-state frames
//!   allocate no scratch vectors** (buffers are `clear()`ed, never dropped
//!   — asserted by the capacity-reuse test via
//!   [`FramePipeline::scratch_capacities`]).
//! * Stages own the persistent hardware models and posteriori state they
//!   simulate (the SRAM buffer, ATG groups, AII boundaries,
//!   early-termination calibration); DRAM traffic is issued through the
//!   context's cull/blend [`crate::memory::MemPort`] handles, whose backend
//!   (`PipelineConfig::mem`) is the synchronous oracle or the event-queue
//!   `MemorySystem` — so ablations swap stage internals, never the graph.
//! * The offline scene preparation ([`ScenePrep`]) sits behind `Arc`s:
//!   [`crate::coordinator::RenderServer`] builds it once and shares it
//!   across N concurrent per-viewer pipelines.
//! * [`oracle::MonolithPipeline`] is the frozen pre-refactor single-call
//!   engine; the `stage_graph_determinism` test asserts the stage graph's
//!   per-frame stat outputs stay **bit-identical** to it.
//!
//! Every frame is dual-tracked as a numeric path (real pixels) and a
//! performance path (hardware events → cycles/energy). See DESIGN.md §3.

pub mod ctx;
pub mod frame;
pub mod oracle;
pub mod par;
pub mod profile;
pub mod stages;

pub use ctx::{FrameBind, FrameCtx, WorkerScratch};
pub use frame::{
    FramePipeline, FrameResult, HostStageWall, PipelineConfig, ScenePrep, SessionState,
};
pub use par::{resolve_threads, SharedSlice, WorkerPool};
pub use profile::{profile_breakdown, PhaseShare};
pub use stages::{BlendStage, CullStage, GroupStage, IntersectStage, ProjectStage, SortStage};
