//! The pre-refactor monolithic frame engine, frozen verbatim as a
//! determinism oracle.
//!
//! [`MonolithPipeline::render_frame`] is the single ~280-line body the
//! stage graph in [`super::frame`] / [`super::stages`] was split out of:
//! per-frame scratch allocations, the linear-scan depth-segment lookup, the
//! inline ablation branches — all preserved. The
//! `stage_graph_determinism` integration test drives both engines over the
//! same trajectory and asserts **bit-identical** stat outputs
//! (`TrafficLog`/`SortStats`/energy/latency/`n_visible`/images), which is
//! what licenses every future optimization of the stage graph.
//!
//! Do not "improve" this module; its value is that it does not change.

use crate::camera::Camera;
use crate::culling::conventional::ConventionalCulling;
use crate::culling::{CullOutput, DrFc, GridConfig, GridPartition};
use crate::dcim::mapping::BlendOpCounts;
use crate::dcim::nmc::NmcAccumulator;
use crate::dcim::DcimMacro;
use crate::energy::{ops, FrameEnergy, StageLatency};
use crate::memory::dram::DramModel;
use crate::memory::sram::{SramBuffer, SramConfig};
use crate::memory::TrafficLog;
use crate::render::HwRenderer;
use crate::scene::{DramLayout, Gaussian4D, Scene};
use crate::sorting::{conventional_bucket_bitonic, AiiSort, SortStats};
use crate::tiles::atg::Atg;
use crate::tiles::connection::ConnectionGraph;
use crate::tiles::intersect::{bin_splats, Splat2D, TileGrid};
use crate::tiles::raster::raster_order;

use super::frame::{
    FrameResult, PipelineConfig, DIGITAL_FREQ_GHZ, EARLY_TERMINATION_FACTOR,
    PREPROCESS_MACS_PER_GAUSSIAN,
};

/// The frozen monolithic engine. Owns all hardware models and the
/// posteriori state (ATG groups, AII boundaries) carried between frames —
/// exactly as `FramePipeline` did before the stage-graph refactor.
pub struct MonolithPipeline<'a> {
    pub config: PipelineConfig,
    pub scene: &'a Scene,
    pub grid: GridPartition,
    pub layout: DramLayout,
    pub tile_grid: TileGrid,
    dram: DramModel,
    sram: SramBuffer,
    atg: Atg,
    aii: AiiSort,
    renderer: HwRenderer,
    frame_idx: usize,
    /// Live early-termination factor (calibrated by rendered frames).
    et_factor: f64,
    /// Per-frame balanced depth-segment boundaries (§3.3-III).
    depth_boundaries: Vec<f32>,
    /// FP16-quantized copy of the scene (what the datapath reads from
    /// DRAM) — computed once at build instead of per frame (§Perf).
    quantized: Vec<Gaussian4D>,
}

impl<'a> MonolithPipeline<'a> {
    /// Build (includes the offline grid partition + DRAM layout).
    pub fn new(scene: &'a Scene, config: PipelineConfig) -> MonolithPipeline<'a> {
        let grid_cfg = if scene.dynamic {
            GridConfig::new(config.grid_n)
        } else {
            GridConfig::static_scene(config.grid_n)
        };
        let grid = GridPartition::build(scene, grid_cfg);
        let layout = DramLayout::build(scene, &grid);
        let tile_grid = TileGrid::new(config.width, config.height);
        let conn =
            ConnectionGraph::new(tile_grid.tiles_x, tile_grid.tiles_y, config.atg.tile_block);
        let n_blocks = conn.n_blocks();
        let sram = SramBuffer::new(SramConfig {
            capacity_bytes: config.sram_bytes,
            ..SramConfig::paper_default(
                Gaussian4D::dram_bytes(scene.dynamic),
                config.n_buckets,
            )
        });
        let quantized: Vec<Gaussian4D> =
            scene.gaussians.iter().map(|g| g.quantized_fp16()).collect();
        MonolithPipeline {
            atg: Atg::new(config.atg),
            aii: AiiSort::new(config.n_buckets, n_blocks, config.sort_hw),
            renderer: HwRenderer::new(config.width, config.height),
            dram: DramModel::default_lpddr5(),
            sram,
            grid,
            layout,
            tile_grid,
            config,
            scene,
            frame_idx: 0,
            et_factor: EARLY_TERMINATION_FACTOR,
            depth_boundaries: Vec::new(),
            quantized,
        }
    }

    /// Reset posteriori state and frame counter (scene cut).
    pub fn reset(&mut self) {
        self.atg.reset();
        self.aii.reset();
        self.frame_idx = 0;
    }

    /// Process one frame — the pre-refactor single-call path.
    pub fn render_frame(&mut self, cam: &Camera, t: f32, render_image: bool) -> FrameResult {
        let mut energy = FrameEnergy::default();
        let mut traffic = TrafficLog::new();
        let mut latency = StageLatency::default();

        // ------------------------------------------------- preprocess ----
        self.dram.reset();
        let cull = self.cull(cam, t, &mut energy);
        traffic.preprocess_dram = self.dram.stats();
        energy.dram_pj += traffic.preprocess_dram.energy_pj;
        traffic.gaussians_fetched = cull.fetched;
        traffic.gaussians_visible = cull.visible.len() as u64;

        // Projection of visible Gaussians (DCIM work).
        let mut dcim = DcimMacro::new(self.config.dcim);
        dcim.macs(cull.visible.len() as u64 * PREPROCESS_MACS_PER_GAUSSIAN);
        let splats: Vec<Splat2D> = cull
            .visible
            .iter()
            .filter_map(|&gi| {
                crate::tiles::intersect::project_gaussian(
                    &self.quantized[gi as usize],
                    gi,
                    cam,
                    t,
                )
            })
            .collect();

        // Intersection testing + connection tracking.
        let mut conn = ConnectionGraph::new(
            self.tile_grid.tiles_x,
            self.tile_grid.tiles_y,
            self.config.atg.tile_block,
        );
        let bins = bin_splats(&self.tile_grid, &splats);
        let mut intersections = 0u64;
        for s in &splats {
            if let Some((tx0, ty0, tx1, ty1)) = self.tile_grid.tile_range(s) {
                intersections += ((tx1 - tx0 + 1) * (ty1 - ty0 + 1)) as u64;
                conn.record_footprint(tx0, ty0, tx1, ty1);
            }
        }
        energy.intersect_pj += intersections as f64 * ops::E_INTERSECT_PJ;

        // Block-level unique-splat working sets (needed by the sort stage
        // and by ATG's buffer-capacity calibration below).
        let mut block_tiles: Vec<Vec<usize>> = vec![Vec::new(); conn.n_blocks()];
        for tile in 0..bins.len() {
            let (tx, ty) = self.tile_grid.tile_xy(tile);
            block_tiles[conn.block_of_tile(tx, ty)].push(tile);
        }
        let mut member = vec![false; splats.len()];
        let mut block_items: Vec<Vec<(f32, u32)>> = Vec::with_capacity(conn.n_blocks());
        for tiles in &block_tiles {
            let mut items: Vec<(f32, u32)> = Vec::new();
            for &tile in tiles {
                for &si in &bins[tile] {
                    if !member[si as usize] {
                        member[si as usize] = true;
                        items.push((splats[si as usize].depth, si));
                    }
                }
            }
            for &(_, si) in &items {
                member[si as usize] = false;
            }
            block_items.push(items);
        }

        // Calibrate ATG's group-size cap to the buffer: a group's combined
        // working set should fit ~70% of the buffer lines (§3.3: grouping
        // "optimizes on-chip buffer data reuse" — oversized groups thrash).
        if self.config.use_atg {
            let occupied: Vec<usize> = block_items
                .iter()
                .map(|b| b.len())
                .filter(|&l| l > 0)
                .collect();
            if !occupied.is_empty() {
                let avg_unique = occupied.iter().sum::<usize>() as f64 / occupied.len() as f64;
                // Grouped blocks are grouped *because* they share splats;
                // the marginal working set per extra block is roughly half
                // its standalone unique count.
                let budget = self.sram.capacity_lines() as f64;
                self.atg.config.max_group_blocks =
                    ((budget / (0.5 * avg_unique).max(1.0)) as usize).clamp(4, 256);
            }
        }

        // Balanced depth-segment boundaries (§3.3-III: the buffer's N depth
        // segments are co-designed with AII-Sort's buckets — equal-count
        // intervals over this frame's visible depths).
        self.calibrate_depth_segments(&splats);

        // ATG (grouping decision feeds the blend tile order).
        let (tile_order, atg_ops, atg_flags) = if self.config.use_atg {
            let out = self.atg.update(&conn);
            energy.atg_pj += out.scan_ops as f64 * ops::E_CMP_FP16_PJ
                + out.uf_ops as f64 * ops::E_UNIONFIND_PJ;
            (
                out.groups.tile_order(
                    self.tile_grid.tiles_x,
                    self.tile_grid.tiles_y,
                    self.config.atg.tile_block,
                ),
                out.regroup_ops(),
                out.flags,
            )
        } else {
            (raster_order(self.tile_grid.tiles_x, self.tile_grid.tiles_y), 0, 0)
        };

        // Preprocess latency: DRAM fetch ∥ grid tests + projection + binning.
        let proj_ns = dcim.busy_ns();
        let test_ns = (cull.fetched as f64 + self.grid.n_cells() as f64
            + intersections as f64 / 4.0)
            / DIGITAL_FREQ_GHZ;
        latency.preprocess_ns =
            traffic.preprocess_dram.busy_ns.max(proj_ns + test_ns);

        // ------------------------------------------------------- sort ----
        let mut sort = SortStats::default();
        let mut sorted_bins: Vec<Vec<u32>> = vec![Vec::new(); bins.len()];
        let mut in_tile = vec![false; splats.len()];
        for (block, tiles) in block_tiles.iter().enumerate() {
            let items = &mut block_items[block];
            if items.is_empty() {
                continue;
            }
            let items: &mut Vec<(f32, u32)> = items;
            let stats = if self.config.use_aii {
                self.aii.sort_tile(block, items)
            } else {
                conventional_bucket_bitonic(items, self.config.n_buckets, &self.config.sort_hw)
            };
            sort.add(&stats);
            // Per-tile extraction (stable, order-preserving).
            for &tile in tiles {
                for &si in &bins[tile] {
                    in_tile[si as usize] = true;
                }
                for &(_, si) in items.iter() {
                    if in_tile[si as usize] {
                        sorted_bins[tile].push(si);
                    }
                }
                for &si in &bins[tile] {
                    in_tile[si as usize] = false;
                }
            }
        }
        energy.sort_pj += sort.comparisons as f64 * ops::E_CMP_FP16_PJ
            + sort.bucketed as f64 * ops::E_ROUTE_PJ;
        latency.sort_ns = sort.cycles as f64 / DIGITAL_FREQ_GHZ;

        // ------------------------------------------------------ blend ----
        self.dram.reset();
        self.sram.reset();
        let mut blend_pairs_upper = 0u64;
        for &tile in &tile_order {
            let (x0, y0, x1, y1) = self.tile_grid.tile_pixels(tile);
            let pixels = ((x1 - x0) * (y1 - y0)) as u64;
            blend_pairs_upper += pixels * sorted_bins[tile].len() as u64;
            for &si in &sorted_bins[tile] {
                let s = &splats[si as usize];
                let segment = self.depth_segment(s.depth);
                if !self.sram.lookup(segment, s.id as u64) {
                    self.dram.read(
                        self.layout.addr[s.id as usize],
                        self.layout.bytes_per_gaussian,
                    );
                    self.sram.insert(segment, s.id as u64);
                }
            }
        }
        traffic.blend_dram = self.dram.stats();
        traffic.blend_sram = self.sram.stats();
        energy.dram_pj += traffic.blend_dram.energy_pj;
        energy.sram_pj += traffic.blend_sram.energy_pj;

        // Numeric render (optional) gives the exact blended-pair count.
        let mut nmc = NmcAccumulator::new();
        let (image, blend_pairs) = if render_image {
            let img = self
                .renderer
                .render_splats_ordered(&splats, &tile_order, &mut nmc);
            let exact = nmc.stats().blend_ops;
            if blend_pairs_upper > 0 {
                // Calibrate the live factor for subsequent perf-only frames.
                self.et_factor = exact as f64 / blend_pairs_upper as f64;
            }
            (Some(img), exact)
        } else {
            (None, (blend_pairs_upper as f64 * self.et_factor) as u64)
        };
        let counts = BlendOpCounts::from_pairs(blend_pairs, splats.len() as u64);
        counts.charge(&mut dcim);
        energy.dcim_pj = dcim.stats().energy_pj;
        energy.nmc_pj = if render_image {
            nmc.stats().energy_pj
        } else {
            blend_pairs as f64 * nmc.e_blend_pj
        };

        // Blend latency: DCIM compute vs DRAM miss-fill, overlapped.
        let blend_dcim_ns = {
            // Only the blend share of DCIM work (subtract preprocess).
            let blend_ops = counts.macs + counts.lut_lookups;
            blend_ops as f64 / self.config.dcim.macs_per_cycle() / self.config.dcim.freq_ghz
        };
        latency.blend_ns = blend_dcim_ns.max(traffic.blend_dram.busy_ns);

        self.frame_idx += 1;
        FrameResult {
            image,
            traffic,
            energy,
            latency,
            sort,
            atg_ops,
            atg_flags,
            n_visible: splats.len(),
            blend_pairs,
            intersections,
            preprocess_breakdown: Default::default(),
            update: Default::default(),
            cull_reuse: Default::default(),
        }
    }

    fn cull(&mut self, cam: &Camera, t: f32, energy: &mut FrameEnergy) -> CullOutput {
        if self.config.use_drfc {
            let drfc = DrFc::new(self.scene, &self.grid, &self.layout);
            let out = drfc.cull(cam, t, &mut self.dram);
            energy.cull_pj += self.grid.n_cells() as f64 * ops::E_GRID_TEST_PJ
                + out.fetched as f64 * ops::E_FRUSTUM_PJ;
            out
        } else {
            let conv = ConventionalCulling::new(self.scene, &self.layout);
            let out = conv.cull(cam, t, &mut self.dram);
            energy.cull_pj += out.fetched as f64 * ops::E_FRUSTUM_PJ;
            out
        }
    }

    /// The live early-termination factor.
    pub fn et_factor(&self) -> f64 {
        self.et_factor
    }

    /// Equal-count depth quantiles (pre-refactor allocation pattern).
    fn calibrate_depth_segments(&mut self, splats: &[Splat2D]) {
        let n = self.config.n_buckets;
        if n <= 1 || splats.is_empty() {
            self.depth_boundaries.clear();
            return;
        }
        let mut depths: Vec<f32> = splats.iter().map(|s| s.depth).collect();
        depths.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        self.depth_boundaries = (1..n)
            .map(|i| depths[(i * depths.len() / n).min(depths.len() - 1)])
            .collect();
    }

    /// The pre-refactor linear-scan segment lookup (the stage graph uses a
    /// `partition_point` binary search; the determinism test proves them
    /// equivalent on real frames).
    fn depth_segment(&self, depth: f32) -> usize {
        let mut seg = 0;
        while seg < self.depth_boundaries.len() && depth >= self.depth_boundaries[seg] {
            seg += 1;
        }
        seg
    }
}
