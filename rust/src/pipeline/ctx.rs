//! The stage-graph frame context: everything the pipeline stages
//! communicate through.
//!
//! [`FrameCtx`] owns two kinds of state:
//!
//! * **per-frame outputs** (energy/latency/traffic accumulators, the DCIM
//!   event counter, the cull result, stat scalars, the optional image) —
//!   zeroed by [`FrameCtx::begin_frame`] at the top of every frame;
//! * **pooled scratch buffers** (projected splats, per-tile bins, block
//!   working sets, sorted bins, visit order, the connection graph, depth
//!   boundaries, the pooled cull output, the executor's per-worker and
//!   per-segment pools) — `clear()`ed, never dropped, so their capacities
//!   survive across frames and **steady-state frames allocate no scratch
//!   vectors** (asserted by the capacity-reuse test via
//!   [`FrameCtx::scratch_capacities`]);
//! * **memory ports** ([`crate::memory::MemPort`]): the cull and blend
//!   DRAM request handles, threaded through the context so the stages are
//!   agnostic to whether they talk to a private synchronous model, a
//!   shared, contended event-queue `MemorySystem`, or a trace recorder.
//!
//! [`FrameBind`] is the borrowed, immutable per-frame view of the shared
//! scene preparation (scene, grid partition, DRAM layout, quantized copy,
//! configuration, tile grid) handed to every stage alongside the context —
//! the same preparation a [`crate::coordinator::RenderServer`] shares across
//! N concurrent viewer sessions.
//!
//! [`WorkerScratch`] is the per-executor-worker slice of the pool: the
//! cull stage's visible-cell partials, the project stage's splat
//! partials, the intersect stage's per-tile binning partials and
//! working-set membership flags, the sort stage's extraction flags and
//! bucket-routing scratch, and the blend stage's per-depth-segment
//! request streams. Workers receive disjoint `&mut WorkerScratch`
//! entries, so the fan-out never shares hot scratch.

use crate::culling::{CullOutput, CullReuse, CullReuseStats, GridPartition};
use crate::dcim::{DcimConfig, DcimMacro};
use crate::energy::{FrameEnergy, PreprocessBreakdown, StageLatency};
use crate::memory::{MemPort, ResidencyPrefetcher, SramStats, TrafficLog};
use crate::pipeline::PipelineConfig;
use crate::render::Image;
use crate::scene::{DramLayout, Gaussian4D, Scene, TemporalStream, UpdateFrameStats};
use crate::sorting::{SortItem, SortStats};
use crate::tiles::connection::ConnectionGraph;
use crate::tiles::intersect::{Splat2D, TileGrid};

/// Borrowed immutable frame inputs: the scene and its offline preparation.
/// Cheap to construct per frame (all references); shared unchanged between
/// every stage and, through `Arc`s in the pipeline, between viewers.
pub struct FrameBind<'s> {
    pub scene: &'s Scene,
    pub grid: &'s GridPartition,
    pub layout: &'s DramLayout,
    /// FP16-quantized copy of the scene (what the datapath reads from DRAM).
    pub quantized: &'s [Gaussian4D],
    pub config: &'s PipelineConfig,
    pub tile_grid: &'s TileGrid,
}

/// Per-worker pooled scratch of the parallel executor (one entry per pool
/// thread; entry 0 doubles as the serial path's scratch).
#[derive(Debug, Default)]
pub struct WorkerScratch {
    /// Visible-cell partials of the DR-FC pass-1 fan-out (this worker's
    /// contiguous chunk of the temporal slice's cells, ascending flat
    /// order; worker-order concatenation reproduces the serial scan).
    pub cells: Vec<usize>,
    /// Projected-splat partials of the project-stage fan-out (this
    /// worker's contiguous chunk of the visible set, ascending gaussian
    /// order; worker-order concatenation reproduces the serial
    /// projection).
    pub splats: Vec<Splat2D>,
    /// Per-tile splat-index partials of the intersect-stage binning
    /// fan-out (this worker's contiguous splat chunk routed to every tile
    /// it touches; per-tile worker-order concatenation reproduces the
    /// serial ascending-splat bins).
    pub bins: Vec<Vec<u32>>,
    /// Splat-in-tile / splat-in-block membership flags (the per-block
    /// working-set dedup of the intersect stage and the per-tile
    /// extraction filter of the sort stage).
    pub in_tile: Vec<bool>,
    /// Bucket-routing scratch for the sort engine (see
    /// [`crate::sorting::assign_buckets_into`]).
    pub buckets: Vec<Vec<SortItem>>,
    /// Per-depth-segment blend request streams: `(global pair index,
    /// gaussian id)`, ordered within each worker's contiguous chunk of the
    /// tile order.
    pub seg_streams: Vec<Vec<(u64, u32)>>,
}

/// Shared mutable frame state: stage outputs + pooled scratch.
#[derive(Debug)]
pub struct FrameCtx {
    // ---- per-frame outputs (reset by `begin_frame`) ---------------------
    pub energy: FrameEnergy,
    pub traffic: TrafficLog,
    pub latency: StageLatency,
    /// Modeled sub-stage attribution inside `latency.preprocess_ns` (the
    /// six-granular cull/project/intersect/group spans of the frame
    /// tracer). Filled by the group stage alongside `preprocess_ns`.
    pub preprocess_breakdown: PreprocessBreakdown,
    pub sort: SortStats,
    /// Per-frame DCIM event counter (preprocess MACs charged by the project
    /// stage, blend ops by the blend stage). Stats reset per frame; the
    /// configuration is fixed at pipeline build.
    pub dcim: DcimMacro,
    /// Culling result of the current frame — pooled: the cull models refill
    /// it in place via `cull_into`, so its vectors and dedup scratch keep
    /// their capacity across frames.
    pub cull: CullOutput,
    /// DRAM request port of the cull/preprocess stage. Backend chosen by
    /// `PipelineConfig::mem`: a private synchronous model (determinism
    /// baseline), a registered port of a shared event-queue
    /// `MemorySystem`, or a trace recorder (two-phase contended batches).
    pub cull_port: MemPort,
    /// DRAM request port of the blend miss-fill path.
    pub blend_port: MemPort,
    /// DRAM write port of the dynamic-scene update stream
    /// ([`crate::memory::MemStage::Update`]) — `None` unless
    /// `PipelineConfig::dynamic_updates` is on.
    pub update_port: Option<MemPort>,
    /// Temporal-delta producer of the update stream (carried per-session
    /// state: the previous frame's baked record words are the delta
    /// baseline). `None` unless dynamic updates are on.
    pub temporal: Option<TemporalStream>,
    /// Cross-frame fetch-residency state of the dirty-cell-aware cull
    /// reuse (the temporal extension of DR-FC). `None` when dynamic
    /// updates or the reuse knob are off.
    pub cull_reuse: Option<CullReuse>,
    /// Per-frame statistics of the update stream's advance (zero when the
    /// stream is off or the frame shipped nothing).
    pub update_stats: UpdateFrameStats,
    /// Per-frame statistics of the dirty-cell-aware cull reuse pass (zero
    /// when reuse is off).
    pub reuse_stats: CullReuseStats,
    /// Streaming-residency prefetch predictor (`None` when the residency
    /// layer is disabled). Carried per-session state: the cull stage asks
    /// it for next-frame pages before issuing demand reads and feeds it the
    /// frame it just culled.
    pub prefetcher: Option<ResidencyPrefetcher>,
    pub atg_ops: u64,
    pub atg_flags: u64,
    pub intersections: u64,
    pub blend_pairs: u64,
    pub image: Option<Image>,

    // ---- pooled scratch (cleared, never dropped) ------------------------
    /// Projected visible splats.
    pub splats: Vec<Splat2D>,
    /// Per-tile splat index lists (intersection binning).
    pub bins: Vec<Vec<u32>>,
    /// Tiles belonging to each tile block.
    pub block_tiles: Vec<Vec<usize>>,
    /// Per-block unique (depth, splat) working sets — the sort inputs.
    pub block_items: Vec<Vec<SortItem>>,
    /// Per-tile depth-ordered splat lists extracted from the block sorts.
    pub sorted_bins: Vec<Vec<u32>>,
    /// Tile visit order (ATG groups or raster).
    pub tile_order: Vec<usize>,
    /// Per-group block sort scratch for the ATG tile order.
    pub block_scratch: Vec<u32>,
    /// Depth sample scratch for the §3.3-III boundary calibration.
    pub depth_scratch: Vec<f32>,
    /// Balanced depth-segment boundaries (§3.3-III).
    pub depth_boundaries: Vec<f32>,
    /// Tile-block connection-strength graph, rebuilt (cleared) per frame —
    /// hoisted out of the old per-frame `ConnectionGraph::new` allocation.
    pub conn: ConnectionGraph,

    // ---- executor pools (cleared, never dropped) ------------------------
    /// Per-worker scratch of the parallel executor (entry 0 = serial path).
    pub workers: Vec<WorkerScratch>,
    /// Per-block sort stat partials, reduced in block order after the
    /// fan-out.
    pub block_sort_stats: Vec<SortStats>,
    /// Global pair index at which each tile-order position starts (blend
    /// request enumeration prefix).
    pub pair_base: Vec<u64>,
    /// Per-depth-segment SRAM stat partials, reduced in segment order.
    pub seg_stats: Vec<SramStats>,
    /// Per-depth-segment miss lists: `(global pair index, gaussian id)`.
    pub seg_misses: Vec<Vec<(u64, u32)>>,
    /// Miss merge buffer: all segments' misses, sorted by global pair
    /// index — the serial DRAM issue order.
    pub miss_order: Vec<(u64, u32)>,
}

impl FrameCtx {
    /// Build the context for a pipeline with the given connection-graph
    /// geometry and DCIM configuration. `n_blocks`/`n_tiles` size the
    /// block- and tile-indexed pools once, up front. The executor pools
    /// default to one worker; see [`FrameCtx::with_workers`].
    pub fn new(
        conn: ConnectionGraph,
        dcim: DcimConfig,
        n_blocks: usize,
        n_tiles: usize,
        cull_port: MemPort,
        blend_port: MemPort,
    ) -> FrameCtx {
        FrameCtx {
            energy: FrameEnergy::default(),
            traffic: TrafficLog::new(),
            latency: StageLatency::default(),
            preprocess_breakdown: PreprocessBreakdown::default(),
            sort: SortStats::default(),
            dcim: DcimMacro::new(dcim),
            cull: CullOutput::default(),
            cull_port,
            blend_port,
            update_port: None,
            temporal: None,
            cull_reuse: None,
            update_stats: UpdateFrameStats::default(),
            reuse_stats: CullReuseStats::default(),
            prefetcher: None,
            atg_ops: 0,
            atg_flags: 0,
            intersections: 0,
            blend_pairs: 0,
            image: None,
            splats: Vec::new(),
            bins: vec![Vec::new(); n_tiles],
            block_tiles: vec![Vec::new(); n_blocks],
            block_items: vec![Vec::new(); n_blocks],
            sorted_bins: vec![Vec::new(); n_tiles],
            tile_order: Vec::new(),
            block_scratch: Vec::new(),
            depth_scratch: Vec::new(),
            depth_boundaries: Vec::new(),
            conn,
            workers: vec![WorkerScratch::default()],
            block_sort_stats: vec![SortStats::default(); n_blocks],
            pair_base: Vec::new(),
            seg_stats: Vec::new(),
            seg_misses: Vec::new(),
            miss_order: Vec::new(),
        }
    }

    /// Size the executor's per-worker pool (`threads` entries).
    pub fn with_workers(mut self, threads: usize) -> FrameCtx {
        let t = threads.max(1);
        self.workers = (0..t).map(|_| WorkerScratch::default()).collect();
        self
    }

    /// Zero the per-frame outputs. Pooled scratch is *not* touched here —
    /// each stage clears exactly the buffers it refills, so capacities are
    /// preserved end to end.
    pub fn begin_frame(&mut self) {
        self.energy = FrameEnergy::default();
        self.traffic.clear();
        self.latency = StageLatency::default();
        self.preprocess_breakdown = PreprocessBreakdown::default();
        self.sort = SortStats::default();
        self.update_stats = UpdateFrameStats::default();
        self.reuse_stats = CullReuseStats::default();
        self.dcim.reset();
        self.atg_ops = 0;
        self.atg_flags = 0;
        self.intersections = 0;
        self.blend_pairs = 0;
        self.image = None;
    }

    /// Capacities of every pooled scratch buffer (outer capacity plus the
    /// sum of inner capacities for nested pools). Steady-state frames must
    /// leave this signature unchanged — the zero-allocation assertion used
    /// by the determinism tests.
    pub fn scratch_capacities(&self) -> Vec<usize> {
        fn nested<T>(v: &[Vec<T>]) -> usize {
            v.iter().map(Vec::capacity).sum()
        }
        let mut caps = vec![
            self.splats.capacity(),
            self.bins.capacity(),
            nested(&self.bins),
            self.block_tiles.capacity(),
            nested(&self.block_tiles),
            self.block_items.capacity(),
            nested(&self.block_items),
            self.sorted_bins.capacity(),
            nested(&self.sorted_bins),
            self.tile_order.capacity(),
            self.block_scratch.capacity(),
            self.depth_scratch.capacity(),
            self.depth_boundaries.capacity(),
            self.block_sort_stats.capacity(),
            self.pair_base.capacity(),
            self.seg_stats.capacity(),
            self.seg_misses.capacity(),
            nested(&self.seg_misses),
            self.miss_order.capacity(),
        ];
        // Per-worker executor scratch (sort flags, bucket routing, segment
        // streams) is part of the zero-allocation contract too.
        for ws in &self.workers {
            caps.push(ws.cells.capacity());
            caps.push(ws.splats.capacity());
            caps.push(ws.bins.capacity());
            caps.push(nested(&ws.bins));
            caps.push(ws.in_tile.capacity());
            caps.push(ws.buckets.capacity());
            caps.push(nested(&ws.buckets));
            caps.push(ws.seg_streams.capacity());
            caps.push(nested(&ws.seg_streams));
        }
        // The pooled cull output (zero-allocation preprocess contract).
        caps.extend(self.cull.scratch_capacities());
        caps
    }

    /// Release the pooled scratch capacity of a *parked* context. The
    /// pools exist to amortize allocation across a stream's frames; a
    /// detached session that is only being retained (e.g. as a
    /// `warm_from` AII donor in a 10k-session churn script) pays their
    /// peak working set for nothing. Only per-frame-refilled buffers are
    /// touched — carried semantic state (`temporal`, `cull_reuse`,
    /// `prefetcher`, the connection graph, the pooled cull output) and
    /// the tile-/block-indexed outer lengths are preserved, so a trimmed
    /// context that *is* later resumed re-grows its pools on the next
    /// frame and renders bit-identically, just without the warm capacity.
    pub fn trim_scratch(&mut self) {
        fn trim<T>(v: &mut Vec<T>) {
            v.clear();
            v.shrink_to_fit();
        }
        fn trim_inner<T>(v: &mut [Vec<T>]) {
            for inner in v.iter_mut() {
                trim(inner);
            }
        }
        trim(&mut self.splats);
        trim_inner(&mut self.bins);
        trim_inner(&mut self.block_tiles);
        trim_inner(&mut self.block_items);
        trim_inner(&mut self.sorted_bins);
        trim(&mut self.tile_order);
        trim(&mut self.block_scratch);
        trim(&mut self.depth_scratch);
        trim(&mut self.depth_boundaries);
        for ws in self.workers.iter_mut() {
            *ws = WorkerScratch::default();
        }
        trim(&mut self.pair_base);
        trim(&mut self.seg_stats);
        trim(&mut self.seg_misses);
        trim(&mut self.miss_order);
        self.image = None;
    }
}
