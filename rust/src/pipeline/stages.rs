//! The six stage units of the frame graph (paper pipeline order):
//!
//! ```text
//! CullStage → ProjectStage → IntersectStage → GroupStage → SortStage → BlendStage
//!   DR-FC       eq. 7–8        tile binning      ATG        AII-Sort    DCIM+NMC
//! ```
//!
//! Stages communicate exclusively through the pooled
//! [`FrameCtx`](super::FrameCtx) and the borrowed
//! [`FrameBind`](super::FrameBind); each stage owns the *persistent*
//! hardware state it models (SRAM buffer, ATG/AII posteriori state,
//! renderer, early-termination calibration), while DRAM traffic is issued
//! through the context's cull/blend [`MemPort`](crate::memory::MemPort)
//! handles (synchronous oracle, shared event-queue backend, or trace
//! recorder), so a [`FramePipeline`](super::FramePipeline) is just the
//! linear composition of the six `run` calls.
//!
//! Every stage with per-frame bulk work fans out across the pipeline's
//! [`WorkerPool`](super::par::WorkerPool): the DR-FC grid-cell tests (per
//! contiguous cell chunk, partials concatenated in worker order), splat
//! projection (per contiguous gaussian chunk), tile binning and the
//! block-level working sets (per tile block, worker-order partial merge),
//! per-block sorting (disjoint posteriori slots + per-block stat partials
//! reduced in block order), and the per-depth-segment blend-buffer walk
//! (disjoint segment state, DRAM miss fills replayed in global pair
//! order); only the ATG union-find and the connection-footprint scan that
//! feeds it stay serial (order-sequential posteriori state). Per-frame
//! stat outputs are bit-identical to the pre-refactor monolithic
//! `render_frame` at **any** thread count (enforced against
//! [`super::oracle::MonolithPipeline`] and across thread counts by the
//! determinism suite).

use super::ctx::{FrameBind, FrameCtx, WorkerScratch};
use super::frame::{DIGITAL_FREQ_GHZ, EARLY_TERMINATION_FACTOR, PREPROCESS_MACS_PER_GAUSSIAN};
use super::par::{chunk_bounds, SharedSlice, WorkerPool};
use crate::camera::Camera;
use crate::culling::conventional::ConventionalCulling;
use crate::culling::DrFc;
use crate::dcim::mapping::BlendOpCounts;
use crate::dcim::nmc::NmcAccumulator;
use crate::energy::{ops, PreprocessBreakdown};
use crate::memory::sram::SramBuffer;
use crate::memory::SramStats;
use crate::render::{HwRenderer, RenderScratch};
use crate::sorting::{conventional_bucket_bitonic_into, AiiSort, SortEngine, SortStats};
use crate::tiles::atg::Atg;
use crate::tiles::intersect::{project_gaussian, Splat2D};
use crate::tiles::raster::raster_order_into;

/// Stage 1 — frustum culling (DR-FC or the conventional full fetch) and its
/// DRAM traffic, issued through the context's preprocess
/// [`MemPort`](crate::memory::MemPort) into the pooled cull output
/// (zero steady-state allocations).
///
/// **Executor fan-out (DR-FC pass 1):** the temporal slice's grid-cell
/// visibility tests are chunked contiguously across the pool's workers;
/// each worker appends its chunk's visible cells to a private pooled
/// partial (disjoint writes), and the partials concatenate on the calling
/// thread in fixed worker order — reproducing the serial ascending
/// flat-index scan exactly, so the scheduled DRAM request stream (passes
/// 2–3, [`DrFc::cull_scheduled`]) is bit-identical at any thread count.
#[derive(Debug)]
pub struct CullStage;

impl CullStage {
    pub fn run(
        &mut self,
        bind: &FrameBind,
        cam: &Camera,
        t: f32,
        ctx: &mut FrameCtx,
        pool: &WorkerPool,
    ) {
        ctx.cull_port.begin_frame();
        // Residency prefetch: predict the pages the upcoming frames touch
        // and hand them to the memory system *before* any demand read of
        // this frame — background fills land first in both the lockstep
        // order and the two-phase trace replay.
        if let Some(pf) = &mut ctx.prefetcher {
            let pages = pf.predict(cam, t);
            if !pages.is_empty() {
                ctx.cull_port.prefetch(pages);
            }
        }
        {
            let FrameCtx { cull, cull_port, energy, workers, cull_reuse, reuse_stats, .. } = ctx;
            if bind.config.use_drfc {
                let drfc = DrFc::new(bind.scene, bind.grid, bind.layout);
                cull.clear();
                // Pass 1 — fan the grid-cell tests out per contiguous cell
                // chunk (pure reads of the shared preparation; per-worker
                // visible-cell partials are disjoint writes).
                let range = drfc.slice_cell_range(t);
                let frustum = cam.frustum();
                let n_cells = range.len();
                let start = range.start;
                let tw = workers.len();
                {
                    let drfc = &drfc;
                    let frustum = &frustum;
                    pool.scope(|scope| {
                        for (w, ws) in workers.iter_mut().enumerate() {
                            scope.spawn(move || {
                                ws.cells.clear();
                                let (lo, hi) = chunk_bounds(w, n_cells, tw);
                                for i in lo..hi {
                                    let flat = start + i;
                                    if drfc.cell_test(flat, frustum) {
                                        ws.cells.push(flat);
                                    }
                                }
                            });
                        }
                    });
                }
                // Fixed worker-order concatenation = ascending flat order.
                for ws in workers.iter() {
                    cull.visible_cells.extend_from_slice(&ws.cells);
                }
                // Dirty-cell-aware reuse (dynamic serving): clean cell runs
                // replay last frame's fetch — identical cull output, fewer
                // DRAM reads. Full re-fetch otherwise.
                if let Some(reuse) = cull_reuse.as_mut() {
                    *reuse_stats = drfc.cull_scheduled_reuse(cam, t, cull_port, cull, reuse);
                } else {
                    drfc.cull_scheduled(cam, t, cull_port, cull);
                }
                energy.cull_pj += bind.grid.n_cells() as f64 * ops::E_GRID_TEST_PJ
                    + cull.fetched as f64 * ops::E_FRUSTUM_PJ;
            } else {
                let conv = ConventionalCulling::new(bind.scene, bind.layout);
                conv.cull_into(cam, t, cull_port, cull);
                energy.cull_pj += cull.fetched as f64 * ops::E_FRUSTUM_PJ;
            }
        }
        ctx.traffic.preprocess_dram = ctx.cull_port.stats();
        ctx.energy.dram_pj += ctx.traffic.preprocess_dram.energy_pj;
        // Paging traffic this frame's prefetch + cull demand reads
        // triggered on the residency layer (zero when fully resident).
        ctx.traffic.paging_dram = ctx.cull_port.paging_stats();
        ctx.energy.dram_pj += ctx.traffic.paging_dram.energy_pj;
        // Feed the predictor the frame that just culled (pose history /
        // visible pages for the next frame's prediction).
        if let Some(pf) = &mut ctx.prefetcher {
            pf.observe(cam, t);
        }
        ctx.traffic.gaussians_fetched = ctx.cull.fetched;
        ctx.traffic.gaussians_visible = ctx.cull.visible.len() as u64;
    }
}

/// Stage 2 — projection of the visible set to screen-space splats
/// (quantized FP16 parameters, DCIM preprocess MACs). Stateless.
///
/// **Executor fan-out:** the visible set is chunked contiguously across
/// the pool's workers; each worker projects its chunk into a private
/// pooled splat partial (`project_gaussian` is pure — every per-splat
/// value is independent of its neighbors), and the partials concatenate
/// on the calling thread in fixed worker order — reproducing the serial
/// ascending-gaussian walk exactly, so the splat list every later stage
/// consumes is bit-identical at any thread count.
#[derive(Debug)]
pub struct ProjectStage;

impl ProjectStage {
    pub fn run(
        &self,
        bind: &FrameBind,
        cam: &Camera,
        t: f32,
        ctx: &mut FrameCtx,
        pool: &WorkerPool,
    ) {
        ctx.dcim
            .macs(ctx.cull.visible.len() as u64 * PREPROCESS_MACS_PER_GAUSSIAN);
        let FrameCtx { splats, cull, workers, .. } = ctx;
        splats.clear();
        let visible: &[u32] = &cull.visible;
        let n = visible.len();
        let tw = workers.len();
        pool.scope(|scope| {
            for (w, ws) in workers.iter_mut().enumerate() {
                scope.spawn(move || {
                    ws.splats.clear();
                    let (lo, hi) = chunk_bounds(w, n, tw);
                    for &gi in &visible[lo..hi] {
                        if let Some(s) =
                            project_gaussian(&bind.quantized[gi as usize], gi, cam, t)
                        {
                            ws.splats.push(s);
                        }
                    }
                });
            }
        });
        // Fixed worker-order concatenation = ascending gaussian order.
        for ws in workers.iter() {
            splats.extend_from_slice(&ws.splats);
        }
    }
}

/// Stage 3 — splat–tile intersection testing: per-tile bins, the
/// connection-strength graph, and the block-level unique-splat working sets
/// consumed by grouping and sorting. Stateless (scratch lives in the ctx).
///
/// **Executor fan-out (tile binning, per tile block):** two phases under
/// the standard disjoint-write + fixed-order-reduction contract:
///
/// 1. *route* — contiguous splat chunks are binned by each worker into
///    private per-tile partials (`WorkerScratch::bins`);
/// 2. *merge* — tile blocks are strided across workers; each block's tiles
///    concatenate the workers' partials in fixed worker order (a tile
///    belongs to exactly one block, so the writes are disjoint), which
///    reproduces the serial ascending-splat bin contents exactly.
///
/// The block-level working sets then fan out per tile block too (strided
/// blocks, per-worker membership flags), feeding the sort stage and ATG's
/// buffer calibration the identical serial-order inputs at any thread
/// count. The footprint/connection scan stays serial — it feeds the ATG
/// union-find, which is inherently order-sequential posteriori state.
#[derive(Debug)]
pub struct IntersectStage;

impl IntersectStage {
    pub fn run(&self, bind: &FrameBind, ctx: &mut FrameCtx, pool: &WorkerPool) {
        ctx.conn.clear();
        let n_tiles = bind.tile_grid.n_tiles();

        // Tiles of each tile block (static geometry — computed up front so
        // the binning merge below can fan out per block).
        {
            let FrameCtx { block_tiles, conn, .. } = ctx;
            for v in block_tiles.iter_mut() {
                v.clear();
            }
            for tile in 0..n_tiles {
                let (tx, ty) = bind.tile_grid.tile_xy(tile);
                block_tiles[conn.block_of_tile(tx, ty)].push(tile);
            }
        }

        // Binning phase 1 — route contiguous splat chunks into per-worker
        // per-tile partials (private writes; chunks are ascending splat
        // ranges, so each partial is internally in serial order).
        {
            let FrameCtx { splats, workers, .. } = ctx;
            let n_splats = splats.len();
            let tw = workers.len();
            let splats_ref: &[Splat2D] = splats;
            let tile_grid = bind.tile_grid;
            pool.scope(|scope| {
                for (w, ws) in workers.iter_mut().enumerate() {
                    scope.spawn(move || {
                        if ws.bins.len() != n_tiles {
                            ws.bins.resize_with(n_tiles, Vec::new);
                        }
                        for b in ws.bins.iter_mut() {
                            b.clear();
                        }
                        let (lo, hi) = chunk_bounds(w, n_splats, tw);
                        for si in lo..hi {
                            tile_grid.splat_tiles(&splats_ref[si], |tile| {
                                ws.bins[tile].push(si as u32)
                            });
                        }
                    });
                }
            });
        }

        // Binning phase 2 — merge the partials per tile, fanned out per
        // tile block: fixed worker-order concatenation of ascending chunks
        // = the serial ascending-splat bin contents.
        {
            let FrameCtx { bins, block_tiles, workers, .. } = ctx;
            if bins.len() != n_tiles {
                bins.resize_with(n_tiles, Vec::new);
            }
            let n_blocks = block_tiles.len();
            let tw = workers.len().max(1);
            let bins_sl = SharedSlice::new(bins.as_mut_slice());
            let workers_ref: &[WorkerScratch] = workers;
            let block_tiles: &[Vec<usize>] = block_tiles;
            pool.scope(|scope| {
                for w in 0..tw {
                    scope.spawn(move || {
                        let mut block = w;
                        while block < n_blocks {
                            for &tile in &block_tiles[block] {
                                // SAFETY: every tile belongs to exactly one
                                // block and blocks are strided per worker —
                                // no two workers touch the same tile's bin.
                                let out = unsafe { bins_sl.get_mut(tile) };
                                out.clear();
                                for ws in workers_ref {
                                    if let Some(part) = ws.bins.get(tile) {
                                        out.extend_from_slice(part);
                                    }
                                }
                            }
                            block += tw;
                        }
                    });
                }
            });
        }

        // Footprint / connection tracking (serial: feeds the ATG
        // union-find's order-sequential posteriori state).
        let mut intersections = 0u64;
        for s in &ctx.splats {
            if let Some((tx0, ty0, tx1, ty1)) = bind.tile_grid.tile_range(s) {
                intersections += ((tx1 - tx0 + 1) * (ty1 - ty0 + 1)) as u64;
                ctx.conn.record_footprint(tx0, ty0, tx1, ty1);
            }
        }
        ctx.intersections = intersections;
        ctx.energy.intersect_pj += intersections as f64 * ops::E_INTERSECT_PJ;

        // Block-level unique-splat working sets (needed by the sort stage
        // and by ATG's buffer-capacity calibration), fanned out per tile
        // block with per-worker membership flags.
        {
            let FrameCtx { splats, bins, block_tiles, block_items, workers, .. } = ctx;
            let n_blocks = block_tiles.len();
            let n_splats = splats.len();
            let tw = workers.len().max(1);
            let items_sl = SharedSlice::new(block_items.as_mut_slice());
            let bins: &[Vec<u32>] = bins;
            let block_tiles: &[Vec<usize>] = block_tiles;
            let splats: &[Splat2D] = splats;
            pool.scope(|scope| {
                for (w, ws) in workers.iter_mut().enumerate() {
                    scope.spawn(move || {
                        ws.in_tile.clear();
                        ws.in_tile.resize(n_splats, false);
                        let mut block = w;
                        while block < n_blocks {
                            // SAFETY: blocks are strided per worker — each
                            // block's working set is written by exactly one
                            // worker.
                            let items = unsafe { items_sl.get_mut(block) };
                            items.clear();
                            for &tile in &block_tiles[block] {
                                for &si in &bins[tile] {
                                    if !ws.in_tile[si as usize] {
                                        ws.in_tile[si as usize] = true;
                                        items.push((splats[si as usize].depth, si));
                                    }
                                }
                            }
                            for &(_, si) in items.iter() {
                                ws.in_tile[si as usize] = false;
                            }
                            block += tw;
                        }
                    });
                }
            });
        }
    }
}

/// Stage 4 — Adaptive Tile Grouping (or the raster baseline): buffer-aware
/// group-size calibration, the grouping update with posteriori reuse, the
/// tile visit order, and the preprocess-latency roll-up that closes the
/// preprocess superstage. Owns the ATG posteriori state.
#[derive(Debug)]
pub struct GroupStage {
    pub atg: Atg,
    /// SRAM buffer line capacity, snapshotted at build (the buffer geometry
    /// is fixed) for the §3.3 group-size calibration.
    pub buffer_lines: usize,
}

impl GroupStage {
    pub fn run(&mut self, bind: &FrameBind, ctx: &mut FrameCtx) {
        if bind.config.use_atg {
            // Calibrate ATG's group-size cap to the buffer: a group's
            // combined working set should fit ~70% of the buffer lines
            // (§3.3: grouping "optimizes on-chip buffer data reuse" —
            // oversized groups thrash).
            let mut occupied_sum = 0usize;
            let mut occupied_cnt = 0usize;
            for b in &ctx.block_items {
                if !b.is_empty() {
                    occupied_sum += b.len();
                    occupied_cnt += 1;
                }
            }
            if occupied_cnt > 0 {
                let avg_unique = occupied_sum as f64 / occupied_cnt as f64;
                // Grouped blocks are grouped *because* they share splats;
                // the marginal working set per extra block is roughly half
                // its standalone unique count.
                let budget = self.buffer_lines as f64;
                self.atg.config.max_group_blocks =
                    ((budget / (0.5 * avg_unique).max(1.0)) as usize).clamp(4, 256);
            }

            let out = self.atg.update(&ctx.conn);
            ctx.energy.atg_pj += out.scan_ops as f64 * ops::E_CMP_FP16_PJ
                + out.uf_ops as f64 * ops::E_UNIONFIND_PJ;
            out.groups.tile_order_into(
                bind.tile_grid.tiles_x,
                bind.tile_grid.tiles_y,
                bind.config.atg.tile_block,
                &mut ctx.tile_order,
                &mut ctx.block_scratch,
            );
            ctx.atg_ops = out.regroup_ops();
            ctx.atg_flags = out.flags;
        } else {
            raster_order_into(bind.tile_grid.tiles_x, bind.tile_grid.tiles_y, &mut ctx.tile_order);
            ctx.atg_ops = 0;
            ctx.atg_flags = 0;
        }

        // Preprocess latency: DRAM fetch ∥ grid tests + projection + binning.
        // Paging traffic on `traffic.paging_dram` is cull-issued at this
        // point in the frame (the blend stage adds its own later): demand
        // fills serialize ahead of the fetch stream, so the DRAM term is
        // fetch + paging.
        let proj_ns = ctx.dcim.busy_ns();
        let test_ns = (ctx.cull.fetched as f64
            + bind.grid.n_cells() as f64
            + ctx.intersections as f64 / 4.0)
            / DIGITAL_FREQ_GHZ;
        ctx.latency.preprocess_ns = (ctx.traffic.preprocess_dram.busy_ns
            + ctx.traffic.paging_dram.busy_ns)
            .max(proj_ns + test_ns);
        // Sub-stage attribution of the same modeled quantities, for the
        // tracer's six-granular stage spans (`obs::trace`). `test_ns`
        // splits back into its cull and intersect terms.
        ctx.preprocess_breakdown = PreprocessBreakdown {
            cull_ns: (ctx.cull.fetched as f64 + bind.grid.n_cells() as f64) / DIGITAL_FREQ_GHZ,
            project_ns: proj_ns,
            intersect_ns: ctx.intersections as f64 / 4.0 / DIGITAL_FREQ_GHZ,
            group_ns: ctx.atg_ops as f64 / DIGITAL_FREQ_GHZ,
        };
    }
}

/// Stage 5 — depth sorting at Tile Block granularity (paper §3.2/§3.3-I):
/// each block sorts the *union* of its tiles' splats once — shared splats
/// are sorted a single time — and every tile extracts its own ordered list
/// from the block's result (a stable, order-preserving filter). Owns the
/// sort engine (AII posteriori boundaries or the conventional baseline).
///
/// **Executor fan-out:** blocks are strided across the pool's workers.
/// Every per-block write is disjoint — the block's working set, its
/// posteriori boundary slot, its stat cell, and its tiles' `sorted_bins`
/// entries (each tile belongs to exactly one block) — and the per-block
/// [`SortStats`] partials (all integer counters) reduce on the calling
/// thread in fixed block order, so the stat outputs are bit-identical to
/// the serial walk at any thread count.
#[derive(Debug)]
pub struct SortStage {
    pub engine: SortEngine,
}

impl SortStage {
    pub fn run(&mut self, bind: &FrameBind, ctx: &mut FrameCtx, pool: &WorkerPool) {
        // Engine dispatch: the AII arm exposes its per-block posteriori
        // slots for the fan-out; the conventional arm is stateless and
        // reads the live configuration (pre-refactor contract).
        let (eng_buckets, eng_hw, slots_sl) = match &mut self.engine {
            SortEngine::Aii(aii) => {
                let nb = aii.n_buckets;
                let hw = aii.hw;
                (nb, hw, Some(SharedSlice::new(aii.boundaries_mut())))
            }
            SortEngine::Conventional => (bind.config.n_buckets, bind.config.sort_hw, None),
        };

        let FrameCtx {
            bins,
            block_tiles,
            block_items,
            sorted_bins,
            block_sort_stats,
            workers,
            splats,
            sort,
            energy,
            latency,
            ..
        } = ctx;
        let n_blocks = block_tiles.len();
        let n_splats = splats.len();
        for v in sorted_bins.iter_mut() {
            v.clear();
        }
        block_sort_stats.clear();
        block_sort_stats.resize(n_blocks, SortStats::default());
        let t = workers.len().max(1);
        {
            let bins: &[Vec<u32>] = bins;
            let block_tiles: &[Vec<usize>] = block_tiles;
            let items_sl = SharedSlice::new(block_items.as_mut_slice());
            let sorted_sl = SharedSlice::new(sorted_bins.as_mut_slice());
            let stats_sl = SharedSlice::new(block_sort_stats.as_mut_slice());
            pool.scope(|scope| {
                for (w, ws) in workers.iter_mut().enumerate() {
                    scope.spawn(move || {
                        ws.in_tile.clear();
                        ws.in_tile.resize(n_splats, false);
                        let mut block = w;
                        while block < n_blocks {
                            // SAFETY: block indices are strided by worker
                            // (w, w+t, …), so no two workers touch the same
                            // block's working set, posteriori slot, or stat
                            // cell — and each tile belongs to exactly one
                            // block, so `sorted_bins` writes are disjoint
                            // too.
                            let items = unsafe { items_sl.get_mut(block) };
                            if !items.is_empty() {
                                let stats = match slots_sl {
                                    Some(sl) => AiiSort::sort_block_slot(
                                        eng_buckets,
                                        &eng_hw,
                                        unsafe { sl.get_mut(block) },
                                        items,
                                        &mut ws.buckets,
                                    ),
                                    None => conventional_bucket_bitonic_into(
                                        items,
                                        eng_buckets,
                                        &eng_hw,
                                        &mut ws.buckets,
                                    ),
                                };
                                unsafe { *stats_sl.get_mut(block) = stats };
                                // Per-tile extraction (stable,
                                // order-preserving filter of the block's
                                // sorted working set).
                                for &tile in &block_tiles[block] {
                                    let out = unsafe { sorted_sl.get_mut(tile) };
                                    for &si in &bins[tile] {
                                        ws.in_tile[si as usize] = true;
                                    }
                                    for &(_, si) in items.iter() {
                                        if ws.in_tile[si as usize] {
                                            out.push(si);
                                        }
                                    }
                                    for &si in &bins[tile] {
                                        ws.in_tile[si as usize] = false;
                                    }
                                }
                            }
                            block += t;
                        }
                    });
                }
            });
        }
        // Fixed block-order reduction (integer counters — exact).
        for s in block_sort_stats.iter() {
            sort.add(s);
        }
        energy.sort_pj += sort.comparisons as f64 * ops::E_CMP_FP16_PJ
            + sort.bucketed as f64 * ops::E_ROUTE_PJ;
        latency.sort_ns = sort.cycles as f64 / DIGITAL_FREQ_GHZ;
    }
}

/// Stage 6 — blending: §3.3-III depth-segment calibration, the SRAM/DRAM
/// reuse simulation over the chosen tile order, the optional numeric render
/// (NMC arithmetic), DCIM blend charging, early-termination calibration,
/// and the blend-latency roll-up. Owns the SRAM buffer, the hardware
/// renderer, and the live early-termination factor; miss fills issue
/// through the context's blend [`MemPort`](crate::memory::MemPort).
///
/// **Executor fan-out (three phases):**
///
/// 1. *classify* — contiguous chunks of the tile order stream every
///    `(tile, splat)` lookup, tagged with its global pair index, into
///    per-depth-segment queues (per-worker, so queue appends are private;
///    worker-order concatenation reconstructs global order);
/// 2. *walk* — one independent [`SegmentWalker`](crate::memory::SegmentWalker)
///    per depth segment replays its queue (segments strided across
///    workers), recording hits/misses and the miss list;
/// 3. *reduce* — SRAM counters merge in segment order, and DRAM miss fills
///    replay through the blend port sorted by global pair index — the
///    exact serial issue order, so every DRAM stat (sync oracle or
///    event-queue) is bit-identical to the serial walk.
///
/// The optional numeric render fans out per tile (disjoint pixels,
/// per-tile NMC partials).
#[derive(Debug)]
pub struct BlendStage {
    pub sram: SramBuffer,
    pub renderer: HwRenderer,
    /// Live early-termination factor (calibrated by rendered frames).
    pub et_factor: f64,
    /// Pooled rasterizer scratch (depth orders, NMC partials) — part of
    /// the zero-allocation contract, carried across detach/resume with
    /// the stage.
    pub render_scratch: RenderScratch,
}

impl BlendStage {
    pub fn new(sram: SramBuffer, renderer: HwRenderer) -> BlendStage {
        BlendStage {
            sram,
            renderer,
            et_factor: EARLY_TERMINATION_FACTOR,
            render_scratch: RenderScratch::default(),
        }
    }

    pub fn run(
        &mut self,
        bind: &FrameBind,
        render_image: bool,
        ctx: &mut FrameCtx,
        pool: &WorkerPool,
    ) {
        // Balanced depth-segment boundaries (§3.3-III: the buffer's N depth
        // segments are co-designed with AII-Sort's buckets — equal-count
        // intervals over this frame's visible depths).
        {
            let FrameCtx { splats, depth_scratch, depth_boundaries, .. } = ctx;
            calibrate_depth_segments(
                bind.config.n_buckets,
                splats,
                depth_scratch,
                depth_boundaries,
            );
        }

        // SRAM/DRAM reuse simulation over the chosen tile order.
        ctx.blend_port.begin_frame();
        self.sram.reset();
        let segments = self.sram.config.segments.max(1);

        // Pair-enumeration prefix over the tile order (the global request
        // indices the replay sorts by) + the modeled pair upper bound.
        let mut blend_pairs_upper = 0u64;
        {
            let FrameCtx { tile_order, sorted_bins, pair_base, .. } = ctx;
            pair_base.clear();
            let mut idx = 0u64;
            for &tile in tile_order.iter() {
                pair_base.push(idx);
                idx += sorted_bins[tile].len() as u64;
                let (x0, y0, x1, y1) = bind.tile_grid.tile_pixels(tile);
                let pixels = ((x1 - x0) * (y1 - y0)) as u64;
                blend_pairs_upper += pixels * sorted_bins[tile].len() as u64;
            }
        }

        // Phase 1 — classify lookups into per-segment streams.
        {
            let FrameCtx {
                tile_order,
                sorted_bins,
                splats,
                depth_boundaries,
                pair_base,
                workers,
                ..
            } = ctx;
            let t = workers.len();
            let n_pos = tile_order.len();
            let tile_order: &[usize] = tile_order;
            let sorted_bins: &[Vec<u32>] = sorted_bins;
            let splats: &[Splat2D] = splats;
            let boundaries: &[f32] = depth_boundaries;
            let pair_base: &[u64] = pair_base;
            pool.scope(|scope| {
                for (w, ws) in workers.iter_mut().enumerate() {
                    scope.spawn(move || {
                        ws.seg_streams.resize_with(segments, Vec::new);
                        for s in ws.seg_streams.iter_mut() {
                            s.clear();
                        }
                        let (lo, hi) = chunk_bounds(w, n_pos, t);
                        for p in lo..hi {
                            let tile = tile_order[p];
                            let mut idx = pair_base[p];
                            for &si in &sorted_bins[tile] {
                                let s = &splats[si as usize];
                                let seg = depth_segment(boundaries, s.depth);
                                ws.seg_streams[seg].push((idx, s.id));
                                idx += 1;
                            }
                        }
                    });
                }
            });
        }

        // Phase 2 — independent per-segment walks.
        {
            let FrameCtx { workers, seg_stats, seg_misses, .. } = ctx;
            seg_stats.clear();
            seg_stats.resize(segments, SramStats::default());
            seg_misses.resize_with(segments, Vec::new);
            for m in seg_misses.iter_mut() {
                m.clear();
            }
            let t = workers.len().max(1);
            let workers_ref: &[WorkerScratch] = workers;
            let mut walkers = self.sram.segment_walkers();
            let n_segs = walkers.len();
            {
                let walkers_sl = SharedSlice::new(walkers.as_mut_slice());
                let stats_sl = SharedSlice::new(seg_stats.as_mut_slice());
                let miss_sl = SharedSlice::new(seg_misses.as_mut_slice());
                pool.scope(|scope| {
                    for w in 0..t {
                        scope.spawn(move || {
                            let mut seg = w;
                            while seg < n_segs {
                                // SAFETY: segment indices are strided by
                                // worker — each walker, stat cell, and miss
                                // list is touched by exactly one worker.
                                let walker = unsafe { walkers_sl.get_mut(seg) };
                                let misses = unsafe { miss_sl.get_mut(seg) };
                                // Worker-order concatenation of the
                                // per-worker streams = ascending global
                                // pair index (contiguous chunks).
                                for ws in workers_ref {
                                    if let Some(stream) = ws.seg_streams.get(seg) {
                                        for &(idx, id) in stream {
                                            if !walker.lookup_or_note(id as u64) {
                                                misses.push((idx, id));
                                            }
                                        }
                                    }
                                }
                                unsafe { *stats_sl.get_mut(seg) = walker.stats() };
                                seg += t;
                            }
                        });
                    }
                });
            }
        }

        // Phase 3 — serial reduction: counters in segment order, DRAM miss
        // fills in global pair order (the serial walk's issue order).
        {
            let FrameCtx { seg_stats, seg_misses, miss_order, blend_port, .. } = ctx;
            self.sram.merge_stats(seg_stats);
            miss_order.clear();
            for m in seg_misses.iter() {
                miss_order.extend_from_slice(m);
            }
            miss_order.sort_unstable_by_key(|&(idx, _)| idx);
            for &(_, id) in miss_order.iter() {
                blend_port.read(bind.layout.addr[id as usize], bind.layout.bytes_per_gaussian);
            }
        }
        ctx.traffic.blend_dram = ctx.blend_port.stats();
        ctx.traffic.blend_sram = self.sram.stats();
        ctx.energy.dram_pj += ctx.traffic.blend_dram.energy_pj;
        ctx.energy.sram_pj += ctx.traffic.blend_sram.energy_pj;
        // Paging traffic the miss fills triggered on the residency layer
        // (zero when fully resident) — added on top of the cull-issued
        // paging already captured by the cull stage.
        let blend_paging = ctx.blend_port.paging_stats();
        ctx.traffic.paging_dram.add(&blend_paging);
        ctx.energy.dram_pj += blend_paging.energy_pj;

        // Numeric render (optional) gives the exact blended-pair count.
        // Reuses the bins `IntersectStage` left in the context (identical
        // to a fresh `bin_splats` pass by that stage's fan-out contract),
        // so the hot path never re-bins.
        let mut nmc = NmcAccumulator::new();
        let (image, blend_pairs) = if render_image {
            let img = self.renderer.render_splats_binned_par(
                &ctx.splats,
                &ctx.bins,
                &ctx.tile_order,
                &mut nmc,
                pool,
                &mut self.render_scratch,
            );
            let exact = nmc.stats().blend_ops;
            if blend_pairs_upper > 0 {
                // Calibrate the live factor for subsequent perf-only frames.
                self.et_factor = exact as f64 / blend_pairs_upper as f64;
            }
            (Some(img), exact)
        } else {
            (None, (blend_pairs_upper as f64 * self.et_factor) as u64)
        };
        let counts = BlendOpCounts::from_pairs(blend_pairs, ctx.splats.len() as u64);
        counts.charge(&mut ctx.dcim);
        ctx.energy.dcim_pj = ctx.dcim.stats().energy_pj;
        ctx.energy.nmc_pj = if render_image {
            nmc.stats().energy_pj
        } else {
            blend_pairs as f64 * nmc.e_blend_pj
        };

        // Blend latency: DCIM compute vs DRAM miss-fill, overlapped.
        let blend_dcim_ns = {
            // Only the blend share of DCIM work (subtract preprocess).
            let blend_ops = counts.macs + counts.lut_lookups;
            blend_ops as f64 / bind.config.dcim.macs_per_cycle() / bind.config.dcim.freq_ghz
        };
        ctx.latency.blend_ns =
            blend_dcim_ns.max(ctx.traffic.blend_dram.busy_ns + blend_paging.busy_ns);
        ctx.image = image;
        ctx.blend_pairs = blend_pairs;
    }
}

/// Recompute the buffer's depth-segment boundaries as equal-count quantiles
/// of this frame's visible depths (§3.3-III co-design with AII-Sort:
/// balanced intervals ⇒ balanced segment occupancy). Pooled: both vectors
/// keep their capacity across frames.
pub(crate) fn calibrate_depth_segments(
    n_buckets: usize,
    splats: &[Splat2D],
    depths: &mut Vec<f32>,
    boundaries: &mut Vec<f32>,
) {
    boundaries.clear();
    if n_buckets <= 1 || splats.is_empty() {
        return;
    }
    depths.clear();
    depths.extend(splats.iter().map(|s| s.depth));
    depths.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    boundaries.extend(
        (1..n_buckets).map(|i| depths[(i * depths.len() / n_buckets).min(depths.len() - 1)]),
    );
}

/// Which depth segment of the SRAM buffer a splat belongs to (§3.3-III:
/// buffer partitioned into N segments by depth). Binary search over the
/// sorted boundaries — equivalent to (and replacing) the old linear scan:
/// both return the count of boundaries ≤ `depth`.
#[inline]
pub(crate) fn depth_segment(boundaries: &[f32], depth: f32) -> usize {
    boundaries.partition_point(|&b| depth >= b)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The pre-refactor linear scan, kept as the oracle for the
    /// `partition_point` replacement.
    fn depth_segment_linear(boundaries: &[f32], depth: f32) -> usize {
        let mut seg = 0;
        while seg < boundaries.len() && depth >= boundaries[seg] {
            seg += 1;
        }
        seg
    }

    #[test]
    fn binary_depth_segment_matches_linear_scan() {
        let cases: &[&[f32]] = &[
            &[],
            &[1.0],
            &[1.0, 2.5, 7.0],
            &[1.0, 1.0, 2.0, 2.0, 9.5],
            &[0.5, 0.5, 0.5],
        ];
        for boundaries in cases {
            let mut probes = vec![f32::MIN, 0.0, f32::MAX];
            for &b in boundaries.iter() {
                probes.extend([b - 1e-3, b, b + 1e-3]);
            }
            for d in probes {
                assert_eq!(
                    depth_segment(boundaries, d),
                    depth_segment_linear(boundaries, d),
                    "boundaries {boundaries:?} depth {d}"
                );
            }
        }
    }

    #[test]
    fn calibration_produces_sorted_boundaries() {
        use crate::math::{Vec2, Vec3};
        let splat = |depth: f32| Splat2D {
            id: 0,
            mean: Vec2::new(0.0, 0.0),
            conic: [1.0, 0.0, 1.0],
            radius: 1.0,
            rx: 1.0,
            ry: 1.0,
            depth,
            alpha_base: 0.5,
            color: Vec3::ONE,
        };
        let splats: Vec<Splat2D> = [5.0, 1.0, 9.0, 3.0, 7.0, 2.0, 8.0, 4.0]
            .iter()
            .map(|&d| splat(d))
            .collect();
        let mut depths = Vec::new();
        let mut boundaries = Vec::new();
        calibrate_depth_segments(4, &splats, &mut depths, &mut boundaries);
        assert_eq!(boundaries.len(), 3);
        assert!(boundaries.windows(2).all(|w| w[0] <= w[1]));
        // Empty / single-bucket cases clear the boundaries.
        calibrate_depth_segments(1, &splats, &mut depths, &mut boundaries);
        assert!(boundaries.is_empty());
        calibrate_depth_segments(4, &[], &mut depths, &mut boundaries);
        assert!(boundaries.is_empty());
    }
}
