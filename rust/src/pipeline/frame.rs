//! Frame pipeline: orchestrates culling, projection, intersection testing,
//! ATG, AII-Sort, and DCIM blending for one frame, producing both pixels
//! (optional) and hardware statistics.

use crate::camera::Camera;
use crate::culling::conventional::ConventionalCulling;
use crate::culling::{CullOutput, DrFc, GridConfig, GridPartition};
use crate::dcim::mapping::BlendOpCounts;
use crate::dcim::nmc::NmcAccumulator;
use crate::dcim::{DcimConfig, DcimMacro};
use crate::energy::{ops, FrameEnergy, StageLatency};
use crate::memory::dram::DramModel;
use crate::memory::sram::{SramBuffer, SramConfig};
use crate::memory::TrafficLog;
use crate::render::{HwRenderer, Image};
use crate::scene::{DramLayout, Gaussian4D, Scene};
use crate::sorting::{
    conventional_bucket_bitonic, AiiSort, SortHwConfig, SortStats,
};
use crate::tiles::atg::{Atg, AtgConfig};
use crate::tiles::connection::ConnectionGraph;
use crate::tiles::intersect::{bin_splats, Splat2D, TileGrid};
use crate::tiles::raster::raster_order;

/// Per-Gaussian preprocessing MACs on the DCIM tier: temporal slice (eq. 5:
/// 6), covariance transform J·W·Σ·Wᵀ·Jᵀ (2 × 3×3×3 matmuls ≈ 54), conic
/// inversion + projection (≈ 12), SH color (42).
pub const PREPROCESS_MACS_PER_GAUSSIAN: u64 = 6 + 54 + 12 + 42;

/// Digital clock for the sorter / controller blocks (GHz).
pub const DIGITAL_FREQ_GHZ: f64 = 1.0;

/// Initial early-termination factor used to estimate blend pairs before the
/// first numeric render has calibrated it: fraction of (pixel × splat)
/// pairs actually blended before saturation/cutoffs. Every numerically
/// rendered frame re-calibrates the pipeline's live factor from the exact
/// NMC blend count, so perf-only frames after any rendered frame use a
/// measured value.
pub const EARLY_TERMINATION_FACTOR: f64 = 0.25;

/// Full pipeline configuration (defaults = the paper's chosen operating
/// point: grid 4, threshold 0.5, Tile Blocks 4, N = 8 buckets).
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub width: usize,
    pub height: usize,
    /// DR-FC grid number (Fig. 9 knob).
    pub grid_n: usize,
    pub atg: AtgConfig,
    /// AII-Sort / buffer-segment bucket count N (Fig. 11 knob).
    pub n_buckets: usize,
    /// Feature switches (ablations / baselines).
    pub use_drfc: bool,
    pub use_atg: bool,
    pub use_aii: bool,
    pub dcim: DcimConfig,
    pub sort_hw: SortHwConfig,
    /// On-chip blend-buffer capacity (bytes). Paper hardware: 256 KB.
    /// Scaled-workload benches shrink it proportionally so the
    /// working-set/capacity ratio matches the paper-scale scenes
    /// (DESIGN.md §7).
    pub sram_bytes: usize,
}

impl PipelineConfig {
    /// The paper's configuration for a given scene class.
    pub fn paper(dynamic: bool) -> PipelineConfig {
        PipelineConfig {
            width: 1280,
            height: 720,
            grid_n: 4,
            atg: AtgConfig::default(),
            n_buckets: 8,
            use_drfc: true,
            use_atg: true,
            use_aii: true,
            dcim: if dynamic { DcimConfig::paper_dynamic() } else { DcimConfig::paper_static() },
            sort_hw: SortHwConfig::default(),
            sram_bytes: 256 * 1024,
        }
    }

    /// All-baseline configuration (conventional culling, raster scan,
    /// conventional sort) — the Fig. 2(a) profiling subject.
    pub fn baseline(dynamic: bool) -> PipelineConfig {
        PipelineConfig {
            use_drfc: false,
            use_atg: false,
            use_aii: false,
            ..PipelineConfig::paper(dynamic)
        }
    }

    /// Scale the image (tests / fast benches).
    pub fn with_resolution(mut self, w: usize, h: usize) -> PipelineConfig {
        self.width = w;
        self.height = h;
        self
    }
}

/// Result of one frame.
#[derive(Debug, Clone)]
pub struct FrameResult {
    pub image: Option<Image>,
    pub traffic: TrafficLog,
    pub energy: FrameEnergy,
    pub latency: StageLatency,
    pub sort: SortStats,
    /// ATG work + flags (0 work when ATG disabled).
    pub atg_ops: u64,
    pub atg_flags: u64,
    pub n_visible: usize,
    /// (pixel × splat) pairs blended (exact when rendered, modeled otherwise).
    pub blend_pairs: u64,
    /// Splat-tile intersection pairs.
    pub intersections: u64,
}

/// The frame pipeline engine. Owns all hardware models and the posteriori
/// state (ATG groups, AII boundaries) carried between frames.
pub struct FramePipeline<'a> {
    pub config: PipelineConfig,
    pub scene: &'a Scene,
    pub grid: GridPartition,
    pub layout: DramLayout,
    pub tile_grid: TileGrid,
    dram: DramModel,
    sram: SramBuffer,
    atg: Atg,
    aii: AiiSort,
    renderer: HwRenderer,
    frame_idx: usize,
    /// Live early-termination factor (calibrated by rendered frames).
    et_factor: f64,
    /// Per-frame balanced depth-segment boundaries (§3.3-III).
    depth_boundaries: Vec<f32>,
    /// FP16-quantized copy of the scene (what the datapath reads from
    /// DRAM) — computed once at build instead of per frame (§Perf).
    quantized: Vec<Gaussian4D>,
}

impl<'a> FramePipeline<'a> {
    /// Build (includes the offline grid partition + DRAM layout).
    pub fn new(scene: &'a Scene, config: PipelineConfig) -> FramePipeline<'a> {
        let grid_cfg = if scene.dynamic {
            GridConfig::new(config.grid_n)
        } else {
            GridConfig::static_scene(config.grid_n)
        };
        let grid = GridPartition::build(scene, grid_cfg);
        let layout = DramLayout::build(scene, &grid);
        let tile_grid = TileGrid::new(config.width, config.height);
        let conn = ConnectionGraph::new(tile_grid.tiles_x, tile_grid.tiles_y, config.atg.tile_block);
        let n_blocks = conn.n_blocks();
        let sram = SramBuffer::new(SramConfig {
            capacity_bytes: config.sram_bytes,
            ..SramConfig::paper_default(
                Gaussian4D::dram_bytes(scene.dynamic),
                config.n_buckets,
            )
        });
        let quantized: Vec<Gaussian4D> =
            scene.gaussians.iter().map(|g| g.quantized_fp16()).collect();
        FramePipeline {
            atg: Atg::new(config.atg),
            aii: AiiSort::new(config.n_buckets, n_blocks, config.sort_hw),
            renderer: HwRenderer::new(config.width, config.height),
            dram: DramModel::default_lpddr5(),
            sram,
            grid,
            layout,
            tile_grid,
            config,
            scene,
            frame_idx: 0,
            et_factor: EARLY_TERMINATION_FACTOR,
            depth_boundaries: Vec::new(),
            quantized,
        }
    }

    /// Reset posteriori state and frame counter (scene cut).
    pub fn reset(&mut self) {
        self.atg.reset();
        self.aii.reset();
        self.frame_idx = 0;
    }

    /// Process one frame. `render_image = false` runs only the performance
    /// path (events + models), which is what the parameter-sweep benches use.
    pub fn render_frame(&mut self, cam: &Camera, t: f32, render_image: bool) -> FrameResult {
        let mut energy = FrameEnergy::default();
        let mut traffic = TrafficLog::new();
        let mut latency = StageLatency::default();

        // ------------------------------------------------- preprocess ----
        self.dram.reset();
        let cull = self.cull(cam, t, &mut energy);
        traffic.preprocess_dram = self.dram.stats();
        energy.dram_pj += traffic.preprocess_dram.energy_pj;
        traffic.gaussians_fetched = cull.fetched;
        traffic.gaussians_visible = cull.visible.len() as u64;

        // Projection of visible Gaussians (DCIM work).
        let mut dcim = DcimMacro::new(self.config.dcim);
        dcim.macs(cull.visible.len() as u64 * PREPROCESS_MACS_PER_GAUSSIAN);
        let splats: Vec<Splat2D> = cull
            .visible
            .iter()
            .filter_map(|&gi| {
                crate::tiles::intersect::project_gaussian(
                    &self.quantized[gi as usize],
                    gi,
                    cam,
                    t,
                )
            })
            .collect();

        // Intersection testing + connection tracking.
        let mut conn = ConnectionGraph::new(
            self.tile_grid.tiles_x,
            self.tile_grid.tiles_y,
            self.config.atg.tile_block,
        );
        let bins = bin_splats(&self.tile_grid, &splats);
        let mut intersections = 0u64;
        for s in &splats {
            if let Some((tx0, ty0, tx1, ty1)) = self.tile_grid.tile_range(s) {
                intersections += ((tx1 - tx0 + 1) * (ty1 - ty0 + 1)) as u64;
                conn.record_footprint(tx0, ty0, tx1, ty1);
            }
        }
        energy.intersect_pj += intersections as f64 * ops::E_INTERSECT_PJ;

        // Block-level unique-splat working sets (needed by the sort stage
        // and by ATG's buffer-capacity calibration below).
        let mut block_tiles: Vec<Vec<usize>> = vec![Vec::new(); conn.n_blocks()];
        for tile in 0..bins.len() {
            let (tx, ty) = self.tile_grid.tile_xy(tile);
            block_tiles[conn.block_of_tile(tx, ty)].push(tile);
        }
        let mut member = vec![false; splats.len()];
        let mut block_items: Vec<Vec<(f32, u32)>> = Vec::with_capacity(conn.n_blocks());
        for tiles in &block_tiles {
            let mut items: Vec<(f32, u32)> = Vec::new();
            for &tile in tiles {
                for &si in &bins[tile] {
                    if !member[si as usize] {
                        member[si as usize] = true;
                        items.push((splats[si as usize].depth, si));
                    }
                }
            }
            for &(_, si) in &items {
                member[si as usize] = false;
            }
            block_items.push(items);
        }

        // Calibrate ATG's group-size cap to the buffer: a group's combined
        // working set should fit ~70% of the buffer lines (§3.3: grouping
        // "optimizes on-chip buffer data reuse" — oversized groups thrash).
        if self.config.use_atg {
            let occupied: Vec<usize> = block_items
                .iter()
                .map(|b| b.len())
                .filter(|&l| l > 0)
                .collect();
            if !occupied.is_empty() {
                let avg_unique = occupied.iter().sum::<usize>() as f64 / occupied.len() as f64;
                // Grouped blocks are grouped *because* they share splats;
                // the marginal working set per extra block is roughly half
                // its standalone unique count.
                let budget = self.sram.capacity_lines() as f64;
                self.atg.config.max_group_blocks =
                    ((budget / (0.5 * avg_unique).max(1.0)) as usize).clamp(4, 256);
            }
        }

        // Balanced depth-segment boundaries (§3.3-III: the buffer's N depth
        // segments are co-designed with AII-Sort's buckets — equal-count
        // intervals over this frame's visible depths).
        self.calibrate_depth_segments(&splats);

        // ATG (grouping decision feeds the blend tile order).
        let (tile_order, atg_ops, atg_flags) = if self.config.use_atg {
            let out = self.atg.update(&conn);
            energy.atg_pj += out.scan_ops as f64 * ops::E_CMP_FP16_PJ
                + out.uf_ops as f64 * ops::E_UNIONFIND_PJ;
            (
                out.groups.tile_order(
                    self.tile_grid.tiles_x,
                    self.tile_grid.tiles_y,
                    self.config.atg.tile_block,
                ),
                out.regroup_ops(),
                out.flags,
            )
        } else {
            (raster_order(self.tile_grid.tiles_x, self.tile_grid.tiles_y), 0, 0)
        };

        // Preprocess latency: DRAM fetch ∥ grid tests + projection + binning.
        let proj_ns = dcim.busy_ns();
        let test_ns = (cull.fetched as f64 + self.grid.n_cells() as f64
            + intersections as f64 / 4.0)
            / DIGITAL_FREQ_GHZ;
        latency.preprocess_ns =
            traffic.preprocess_dram.busy_ns.max(proj_ns + test_ns);

        // ------------------------------------------------------- sort ----
        // Sorting runs at Tile Block granularity (paper §3.2/§3.3-I: the
        // bucket intervals are tracked per block): each block sorts the
        // *union* of its tiles' splats once — shared splats are sorted a
        // single time — and every tile extracts its own ordered list from
        // the block's result (a stable, order-preserving filter).
        let mut sort = SortStats::default();
        let mut sorted_bins: Vec<Vec<u32>> = vec![Vec::new(); bins.len()];
        let mut in_tile = vec![false; splats.len()];
        for (block, tiles) in block_tiles.iter().enumerate() {
            let items = &mut block_items[block];
            if items.is_empty() {
                continue;
            }
            let items: &mut Vec<(f32, u32)> = items;
            let stats = if self.config.use_aii {
                self.aii.sort_tile(block, items)
            } else {
                conventional_bucket_bitonic(items, self.config.n_buckets, &self.config.sort_hw)
            };
            sort.add(&stats);
            // Per-tile extraction (stable, order-preserving).
            for &tile in tiles {
                for &si in &bins[tile] {
                    in_tile[si as usize] = true;
                }
                for &(_, si) in items.iter() {
                    if in_tile[si as usize] {
                        sorted_bins[tile].push(si);
                    }
                }
                for &si in &bins[tile] {
                    in_tile[si as usize] = false;
                }
            }
        }
        energy.sort_pj += sort.comparisons as f64 * ops::E_CMP_FP16_PJ
            + sort.bucketed as f64 * ops::E_ROUTE_PJ;
        latency.sort_ns = sort.cycles as f64 / DIGITAL_FREQ_GHZ;

        // ------------------------------------------------------ blend ----
        // SRAM/DRAM reuse simulation over the chosen tile order.
        self.dram.reset();
        self.sram.reset();
        let mut blend_pairs_upper = 0u64;
        for &tile in &tile_order {
            let (x0, y0, x1, y1) = self.tile_grid.tile_pixels(tile);
            let pixels = ((x1 - x0) * (y1 - y0)) as u64;
            blend_pairs_upper += pixels * sorted_bins[tile].len() as u64;
            for &si in &sorted_bins[tile] {
                let s = &splats[si as usize];
                let segment = self.depth_segment(s.depth);
                if !self.sram.lookup(segment, s.id as u64) {
                    self.dram.read(
                        self.layout.addr[s.id as usize],
                        self.layout.bytes_per_gaussian,
                    );
                    self.sram.insert(segment, s.id as u64);
                }
            }
        }
        traffic.blend_dram = self.dram.stats();
        traffic.blend_sram = self.sram.stats();
        energy.dram_pj += traffic.blend_dram.energy_pj;
        energy.sram_pj += traffic.blend_sram.energy_pj;

        // Numeric render (optional) gives the exact blended-pair count.
        let mut nmc = NmcAccumulator::new();
        let (image, blend_pairs) = if render_image {
            let img = self
                .renderer
                .render_splats_ordered(&splats, &tile_order, &mut nmc);
            let exact = nmc.stats().blend_ops;
            if blend_pairs_upper > 0 {
                // Calibrate the live factor for subsequent perf-only frames.
                self.et_factor = exact as f64 / blend_pairs_upper as f64;
            }
            (Some(img), exact)
        } else {
            (None, (blend_pairs_upper as f64 * self.et_factor) as u64)
        };
        let counts = BlendOpCounts::from_pairs(blend_pairs, splats.len() as u64);
        counts.charge(&mut dcim);
        energy.dcim_pj = dcim.stats().energy_pj;
        energy.nmc_pj = if render_image {
            nmc.stats().energy_pj
        } else {
            blend_pairs as f64 * nmc.e_blend_pj
        };

        // Blend latency: DCIM compute vs DRAM miss-fill, overlapped.
        let blend_dcim_ns = {
            // Only the blend share of DCIM work (subtract preprocess).
            let blend_ops = counts.macs + counts.lut_lookups;
            blend_ops as f64 / self.config.dcim.macs_per_cycle() / self.config.dcim.freq_ghz
        };
        latency.blend_ns = blend_dcim_ns.max(traffic.blend_dram.busy_ns);

        self.frame_idx += 1;
        FrameResult {
            image,
            traffic,
            energy,
            latency,
            sort,
            atg_ops,
            atg_flags,
            n_visible: splats.len(),
            blend_pairs,
            intersections,
        }
    }

    fn cull(&mut self, cam: &Camera, t: f32, energy: &mut FrameEnergy) -> CullOutput {
        if self.config.use_drfc {
            let drfc = DrFc::new(self.scene, &self.grid, &self.layout);
            let out = drfc.cull(cam, t, &mut self.dram);
            energy.cull_pj += self.grid.n_cells() as f64 * ops::E_GRID_TEST_PJ
                + out.fetched as f64 * ops::E_FRUSTUM_PJ;
            out
        } else {
            let conv = ConventionalCulling::new(self.scene, &self.layout);
            let out = conv.cull(cam, t, &mut self.dram);
            energy.cull_pj += out.fetched as f64 * ops::E_FRUSTUM_PJ;
            out
        }
    }

    /// The live early-termination factor (initially
    /// [`EARLY_TERMINATION_FACTOR`], re-calibrated by rendered frames).
    pub fn et_factor(&self) -> f64 {
        self.et_factor
    }

    /// Recompute the buffer's depth-segment boundaries as equal-count
    /// quantiles of this frame's visible depths (§3.3-III co-design with
    /// AII-Sort: balanced intervals ⇒ balanced segment occupancy).
    fn calibrate_depth_segments(&mut self, splats: &[Splat2D]) {
        let n = self.config.n_buckets;
        if n <= 1 || splats.is_empty() {
            self.depth_boundaries.clear();
            return;
        }
        let mut depths: Vec<f32> = splats.iter().map(|s| s.depth).collect();
        depths.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        self.depth_boundaries = (1..n)
            .map(|i| depths[(i * depths.len() / n).min(depths.len() - 1)])
            .collect();
    }

    /// Which depth segment of the SRAM buffer a splat belongs to
    /// (§3.3-III: buffer partitioned into N segments by depth).
    fn depth_segment(&self, depth: f32) -> usize {
        let mut seg = 0;
        while seg < self.depth_boundaries.len() && depth >= self.depth_boundaries[seg] {
            seg += 1;
        }
        seg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Trajectory, ViewCondition};
    use crate::math::Vec3;
    use crate::scene::synth::{SceneKind, SynthParams};

    fn small_scene() -> Scene {
        SynthParams::new(SceneKind::DynamicLarge, 4000).generate()
    }

    fn template(w: usize, h: usize) -> Camera {
        let mut c = Camera::look_at(
            Vec3::new(0.0, 4.0, 20.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            w as f32 / h as f32,
            0.1,
            200.0,
        );
        c.set_resolution(w, h);
        c
    }

    #[test]
    fn frame_produces_consistent_stats() {
        let scene = small_scene();
        let cfg = PipelineConfig::paper(true).with_resolution(320, 180);
        let mut p = FramePipeline::new(&scene, cfg);
        let cam = template(320, 180);
        let r = p.render_frame(&cam, 0.3, false);
        assert!(r.n_visible > 0);
        assert!(r.traffic.gaussians_fetched >= r.traffic.gaussians_visible);
        assert!(r.energy.total_pj() > 0.0);
        assert!(r.latency.pipelined_ns() > 0.0);
        assert!(r.blend_pairs > 0);
    }

    #[test]
    fn rendered_and_perf_only_agree_on_traffic() {
        let scene = small_scene();
        let cfg = PipelineConfig::paper(true).with_resolution(160, 96);
        let cam = template(160, 96);
        let mut p1 = FramePipeline::new(&scene, cfg.clone());
        let r1 = p1.render_frame(&cam, 0.3, true);
        let mut p2 = FramePipeline::new(&scene, cfg);
        let r2 = p2.render_frame(&cam, 0.3, false);
        assert!(r1.image.is_some());
        assert!(r2.image.is_none());
        assert_eq!(r1.traffic.gaussians_fetched, r2.traffic.gaussians_fetched);
        assert_eq!(r1.traffic.blend_sram.lookups, r2.traffic.blend_sram.lookups);
        assert_eq!(r1.n_visible, r2.n_visible);
    }

    #[test]
    fn early_termination_factor_calibrates_from_rendered_frames() {
        let scene = small_scene();
        let cfg = PipelineConfig::paper(true).with_resolution(160, 96);
        let cam = template(160, 96);
        let mut p = FramePipeline::new(&scene, cfg);
        assert_eq!(p.et_factor(), EARLY_TERMINATION_FACTOR);
        let exact = p.render_frame(&cam, 0.3, true);
        let calibrated = p.et_factor();
        assert!(calibrated > 0.0 && calibrated <= 1.0, "factor {calibrated}");
        // A perf-only frame right after must model pairs near the exact
        // count of the same view (identical frame → same upper bound).
        let modeled = p.render_frame(&cam, 0.3, false);
        let ratio = modeled.blend_pairs as f64 / exact.blend_pairs.max(1) as f64;
        assert!(
            (0.8..=1.25).contains(&ratio),
            "calibrated model {} vs exact {} (ratio {ratio})",
            modeled.blend_pairs,
            exact.blend_pairs
        );
    }

    #[test]
    fn drfc_reduces_preprocess_dram_vs_baseline() {
        let scene = small_scene();
        let cam = template(320, 180);
        let mut with = FramePipeline::new(
            &scene,
            PipelineConfig::paper(true).with_resolution(320, 180),
        );
        let mut without = FramePipeline::new(
            &scene,
            PipelineConfig {
                use_drfc: false,
                ..PipelineConfig::paper(true).with_resolution(320, 180)
            },
        );
        let rw = with.render_frame(&cam, 0.2, false);
        let ro = without.render_frame(&cam, 0.2, false);
        assert!(
            rw.traffic.preprocess_dram.bytes < ro.traffic.preprocess_dram.bytes,
            "DR-FC {} vs conventional {}",
            rw.traffic.preprocess_dram.bytes,
            ro.traffic.preprocess_dram.bytes
        );
        // Both see the same visible set.
        assert_eq!(rw.n_visible, ro.n_visible);
    }

    #[test]
    fn posteriori_frames_cost_less_atg_and_sort() {
        let scene = small_scene();
        let cfg = PipelineConfig::paper(true).with_resolution(320, 180);
        let mut p = FramePipeline::new(&scene, cfg);
        let cam_t = template(320, 180);
        // A fully static viewing sequence (no head motion, frozen scene
        // time): phase 2 must reuse the grouping wholesale.
        let traj = Trajectory::new(ViewCondition::Static, 4)
            .with_scene(Vec3::ZERO, 22.0)
            .with_time_span(0.3, 0.3);
        let frames = traj.generate(&cam_t);
        let mut results = Vec::new();
        for (cam, t) in &frames {
            results.push(p.render_frame(cam, *t, false));
        }
        let first = &results[0];
        let later = &results[3];
        assert!(
            later.atg_ops < first.atg_ops,
            "posteriori ATG {} vs frame-0 {}",
            later.atg_ops,
            first.atg_ops
        );
        assert_eq!(later.atg_flags, 0, "static sequence raises no flags");
        assert_eq!(later.sort.minmax_scanned, 0, "AII skips min/max after frame 0");
    }

    #[test]
    fn static_scene_pipeline_works() {
        let scene = SynthParams::new(SceneKind::StaticLarge, 3000).generate();
        let cfg = PipelineConfig::paper(false).with_resolution(256, 144);
        let mut p = FramePipeline::new(&scene, cfg);
        let cam = template(256, 144);
        let r = p.render_frame(&cam, 0.0, true);
        assert!(r.n_visible > 0);
        let img = r.image.unwrap();
        assert!(img.mean_luma() > 0.01, "rendered something: {}", img.mean_luma());
    }
}
