//! Frame pipeline: the stage-graph engine orchestrating culling,
//! projection, intersection testing, ATG, AII-Sort, and DCIM blending for
//! one frame, producing both pixels (optional) and hardware statistics.
//!
//! [`FramePipeline::render_frame`] is a linear composition of the six stage
//! units in [`super::stages`] over a pooled [`FrameCtx`]; the offline scene
//! preparation ([`ScenePrep`]) is held behind `Arc`s so N per-viewer
//! pipelines can share it without copying (see
//! [`crate::coordinator::RenderServer`]).

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::camera::Camera;
use crate::culling::{CullReuse, CullReuseStats, GridConfig, GridPartition};
use crate::dcim::DcimConfig;
use crate::energy::{FrameEnergy, PreprocessBreakdown, StageLatency};
use crate::obs::{TraceSink, Track, Tracer};
use crate::util::json::Json;
use crate::util::timer::PhaseProfile;
use crate::memory::sram::{SramBuffer, SramConfig};
use crate::memory::{
    MemMode, MemPort, MemSimConfig, MemStage, MemorySystem, PortId, ResidencyConfig,
    ResidencyPrefetcher, ShardMap, TrafficLog,
};
use crate::render::{HwRenderer, Image, RenderBackend};
use crate::scene::{
    CompressedStore, DramLayout, Gaussian4D, Scene, TemporalStream, UpdateFrameStats,
};
use crate::sorting::{SortEngine, SortHwConfig, SortStats};
use crate::tiles::atg::{Atg, AtgConfig};
use crate::tiles::connection::ConnectionGraph;
use crate::tiles::intersect::TileGrid;

use super::ctx::{FrameBind, FrameCtx};
use super::par::{resolve_threads, WorkerPool};
use super::stages::{BlendStage, CullStage, GroupStage, IntersectStage, ProjectStage, SortStage};

/// Per-Gaussian preprocessing MACs on the DCIM tier: temporal slice (eq. 5:
/// 6), covariance transform J·W·Σ·Wᵀ·Jᵀ (2 × 3×3×3 matmuls ≈ 54), conic
/// inversion + projection (≈ 12), SH color (42).
pub const PREPROCESS_MACS_PER_GAUSSIAN: u64 = 6 + 54 + 12 + 42;

/// Digital clock for the sorter / controller blocks (GHz).
pub const DIGITAL_FREQ_GHZ: f64 = 1.0;

/// Initial early-termination factor used to estimate blend pairs before the
/// first numeric render has calibrated it: fraction of (pixel × splat)
/// pairs actually blended before saturation/cutoffs. Every numerically
/// rendered frame re-calibrates the pipeline's live factor from the exact
/// NMC blend count, so perf-only frames after any rendered frame use a
/// measured value.
pub const EARLY_TERMINATION_FACTOR: f64 = 0.25;

/// Full pipeline configuration (defaults = the paper's chosen operating
/// point: grid 4, threshold 0.5, Tile Blocks 4, N = 8 buckets).
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub width: usize,
    pub height: usize,
    /// DR-FC grid number (Fig. 9 knob).
    pub grid_n: usize,
    pub atg: AtgConfig,
    /// AII-Sort / buffer-segment bucket count N (Fig. 11 knob).
    pub n_buckets: usize,
    /// Feature switches (ablations / baselines).
    pub use_drfc: bool,
    pub use_atg: bool,
    pub use_aii: bool,
    pub dcim: DcimConfig,
    pub sort_hw: SortHwConfig,
    /// On-chip blend-buffer capacity (bytes). Paper hardware: 256 KB.
    /// Scaled-workload benches shrink it proportionally so the
    /// working-set/capacity ratio matches the paper-scale scenes
    /// (DESIGN.md §7).
    pub sram_bytes: usize,
    /// DRAM timing backend: the synchronous oracle (default — bit-identical
    /// to the frozen monolith) or the event-queue memory system with
    /// outstanding transactions, shard channel groups, and contention.
    pub mem: MemSimConfig,
    /// Dynamic-scene update streaming: bake the scene's FP16 records at
    /// each frame's scene time, XOR-delta them against frame t-1, and
    /// stream the dirty-cell writes into DRAM through a dedicated
    /// [`MemStage::Update`] port that contends with render reads. Off by
    /// default — static serving stays byte-identical.
    pub dynamic_updates: bool,
    /// Dirty-cell-aware cull reuse (the temporal extension of DR-FC):
    /// clean cell runs replay last frame's fetch instead of re-reading
    /// DRAM. Only active when `dynamic_updates` and `use_drfc` are on.
    pub cull_reuse: bool,
    /// Keep the AII sort's posteriori intervals live across scene updates
    /// (the paper's warm path). `false` cold-starts the engine whenever an
    /// update frame changed any record — the comparison baseline for the
    /// warm-vs-cold BENCH numbers.
    pub aii_retain: bool,
    /// Host threads of the intra-frame parallel executor (`pipeline::par`):
    /// `0` = auto (the `PALLAS_THREADS` environment variable, else
    /// `available_parallelism`). Every simulated stat output is
    /// bit-identical at any value — this knob only trades host wall-clock.
    pub threads: usize,
    /// Blend datapath of the numeric rasterizers (scalar per-pixel loop
    /// or the 8-wide lane kernel). Like `threads`, every output —
    /// pixels, NMC statistics, report JSON — is bit-identical at either
    /// value; the knob only trades host wall-clock. Defaults from the
    /// `PALLAS_RENDER_BACKEND` environment variable.
    pub render_backend: RenderBackend,
}

impl PipelineConfig {
    /// The paper's configuration for a given scene class.
    pub fn paper(dynamic: bool) -> PipelineConfig {
        PipelineConfig {
            width: 1280,
            height: 720,
            grid_n: 4,
            atg: AtgConfig::default(),
            n_buckets: 8,
            use_drfc: true,
            use_atg: true,
            use_aii: true,
            dcim: if dynamic { DcimConfig::paper_dynamic() } else { DcimConfig::paper_static() },
            sort_hw: SortHwConfig::default(),
            sram_bytes: 256 * 1024,
            mem: MemSimConfig {
                residency: ResidencyConfig::from_env(),
                ..MemSimConfig::default()
            },
            dynamic_updates: false,
            cull_reuse: true,
            aii_retain: true,
            threads: 0,
            render_backend: RenderBackend::from_env(),
        }
    }

    /// All-baseline configuration (conventional culling, raster scan,
    /// conventional sort) — the Fig. 2(a) profiling subject.
    pub fn baseline(dynamic: bool) -> PipelineConfig {
        PipelineConfig {
            use_drfc: false,
            use_atg: false,
            use_aii: false,
            ..PipelineConfig::paper(dynamic)
        }
    }

    /// Scale the image (tests / fast benches).
    pub fn with_resolution(mut self, w: usize, h: usize) -> PipelineConfig {
        self.width = w;
        self.height = h;
        self
    }

    /// Switch the dynamic-scene update stream (and its coherence
    /// optimizations) on or off.
    pub fn with_dynamic_updates(mut self, on: bool) -> PipelineConfig {
        self.dynamic_updates = on;
        self
    }

    /// Pin the executor thread count (`0` = auto).
    pub fn with_threads(mut self, threads: usize) -> PipelineConfig {
        self.threads = threads;
        self
    }

    /// Pin the render backend (overrides the environment default).
    pub fn with_render_backend(mut self, backend: RenderBackend) -> PipelineConfig {
        self.render_backend = backend;
        self
    }

    /// The executor thread count this configuration resolves to (see
    /// [`resolve_threads`]).
    pub fn resolved_threads(&self) -> usize {
        resolve_threads(self.threads)
    }
}

/// Host wall-clock accounting of the intra-frame executor — the BENCH
/// layer's per-stage timing source, a thin frame-count wrapper over
/// [`PhaseProfile`] (phases `"sort"`, `"blend"`, `"frame"`). Simulated-time
/// latencies live in [`StageLatency`]; this is what actually elapsed on the
/// host, so it is *not* part of any determinism contract and reports must
/// route it into the registry's nondeterministic `host` section.
#[derive(Debug, Clone, Default)]
pub struct HostStageWall {
    profile: PhaseProfile,
}

impl HostStageWall {
    fn push(&mut self, sort_s: f64, blend_s: f64, frame_s: f64) {
        self.profile.add("sort", Duration::from_secs_f64(sort_s));
        self.profile.add("blend", Duration::from_secs_f64(blend_s));
        self.profile.add("frame", Duration::from_secs_f64(frame_s));
    }

    /// Frames measured.
    pub fn frames(&self) -> u64 {
        self.profile.count("frame")
    }

    /// Cumulative host seconds inside the sort stage.
    pub fn sort_s(&self) -> f64 {
        self.profile.total("sort").as_secs_f64()
    }

    /// Cumulative host seconds inside the blend stage.
    pub fn blend_s(&self) -> f64 {
        self.profile.total("blend").as_secs_f64()
    }

    /// Cumulative host seconds across whole frames.
    pub fn frame_s(&self) -> f64 {
        self.profile.total("frame").as_secs_f64()
    }

    /// Full percentile ladder of per-frame sort-stage seconds.
    pub fn sort_ladder(&self) -> crate::obs::LatencyLadder {
        self.profile.ladder("sort")
    }

    /// Full percentile ladder of per-frame blend-stage seconds.
    pub fn blend_ladder(&self) -> crate::obs::LatencyLadder {
        self.profile.ladder("blend")
    }

    /// The underlying phase profile (phases `"sort"`, `"blend"`, `"frame"`).
    pub fn profile(&self) -> &PhaseProfile {
        &self.profile
    }
}

/// Result of one frame.
#[derive(Debug, Clone)]
pub struct FrameResult {
    pub image: Option<Image>,
    pub traffic: TrafficLog,
    pub energy: FrameEnergy,
    pub latency: StageLatency,
    /// Modeled sub-stage attribution inside `latency.preprocess_ns` (the
    /// tracer's cull/project/intersect/group spans).
    pub preprocess_breakdown: PreprocessBreakdown,
    pub sort: SortStats,
    /// ATG work + flags (0 work when ATG disabled).
    pub atg_ops: u64,
    pub atg_flags: u64,
    pub n_visible: usize,
    /// (pixel × splat) pairs blended (exact when rendered, modeled otherwise).
    pub blend_pairs: u64,
    /// Splat-tile intersection pairs.
    pub intersections: u64,
    /// Dynamic update-stream statistics (zero for static serving / when
    /// the stream is off).
    pub update: UpdateFrameStats,
    /// Dirty-cell cull-reuse statistics (zero when reuse is off).
    pub cull_reuse: CullReuseStats,
}

impl FrameResult {
    /// Emit this frame's simulated-time spans into `tracer` on `track`
    /// starting at `t0_ns`: a `frame` span covering the sequential stage
    /// walk, `preprocess`/`sort`/`blend` children laid end to end, and the
    /// cull/project/intersect/group attribution spans nested inside
    /// `preprocess`. The breakdown attributes a DRAM ∥ compute superstage,
    /// so its sequential layout is clamped to the preprocess envelope; the
    /// unclamped modeled values ride every span's args. All inputs are
    /// simulated quantities and the caller invokes this in deterministic
    /// order, so the recorded stream is bit-identical across host thread
    /// counts. Returns the frame span's end time (ns) — the track cursor
    /// for the next frame.
    pub fn trace_spans(
        &self,
        tracer: &mut Tracer,
        pid: u64,
        track: Track,
        frame_idx: usize,
        t0_ns: f64,
    ) -> f64 {
        let l = &self.latency;
        let frame_end = t0_ns + l.sequential_ns();
        tracer.span(
            pid,
            track,
            &format!("frame {frame_idx}"),
            "frame",
            t0_ns,
            l.sequential_ns(),
            vec![
                ("n_visible", Json::from(self.n_visible as u64)),
                ("blend_pairs", Json::from(self.blend_pairs)),
                ("intersections", Json::from(self.intersections)),
                ("dram_bytes", Json::from(self.traffic.total_dram_bytes())),
            ],
        );
        let pre_t0 = t0_ns;
        let pre_end = pre_t0 + l.preprocess_ns;
        tracer.span(
            pid,
            track,
            "preprocess",
            "stage",
            pre_t0,
            l.preprocess_ns,
            vec![
                ("dram_busy_ns", Json::from(self.traffic.preprocess_dram.busy_ns)),
                ("paging_busy_ns", Json::from(self.traffic.paging_dram.busy_ns)),
            ],
        );
        // The four attribution sub-spans, laid sequentially and clamped to
        // the preprocess envelope (they model the compute side of a
        // DRAM ∥ compute superstage, so their sum can exceed it).
        let b = &self.preprocess_breakdown;
        let mut sub_t = pre_t0;
        for (name, modeled_ns) in [
            ("cull", b.cull_ns),
            ("project", b.project_ns),
            ("intersect", b.intersect_ns),
            ("group", b.group_ns),
        ] {
            let dur = modeled_ns.min((pre_end - sub_t).max(0.0));
            tracer.span(
                pid,
                track,
                name,
                "stage",
                sub_t,
                dur,
                vec![("modeled_ns", Json::from(modeled_ns))],
            );
            sub_t += dur;
        }
        tracer.span(
            pid,
            track,
            "sort",
            "stage",
            pre_end,
            l.sort_ns,
            vec![("cycles", Json::from(self.sort.cycles))],
        );
        tracer.span(
            pid,
            track,
            "blend",
            "stage",
            pre_end + l.sort_ns,
            l.blend_ns,
            vec![
                ("dram_busy_ns", Json::from(self.traffic.blend_dram.busy_ns)),
                ("sram_lookups", Json::from(self.traffic.blend_sram.lookups)),
            ],
        );
        tracer.set_cursor(pid, track, frame_end);
        frame_end
    }
}

/// The offline, immutable scene preparation: grid partition, DRAM layout,
/// the FP16-quantized parameter copy, and the shard map partitioning the
/// layout's DRAM span across channel groups. Built once per scene and
/// shared (`Arc`) by every pipeline rendering it — one viewer or many.
#[derive(Debug, Clone)]
pub struct ScenePrep {
    pub grid: Arc<GridPartition>,
    pub layout: Arc<DramLayout>,
    pub quantized: Arc<Vec<Gaussian4D>>,
    /// Row-aligned partition of the layout's full span (records + pointer
    /// tables) into `config.mem.shards` channel-group shards.
    pub shard_map: Arc<ShardMap>,
    /// Delta/FP16-compressed backing store over the layout's span — built
    /// only when the streaming-residency layer is enabled
    /// (`config.mem.residency`), `None` for fully-resident configs.
    pub compressed: Option<Arc<CompressedStore>>,
}

impl ScenePrep {
    /// Build the preparation (grid partition + DRAM layout + quantized
    /// copy + shard map).
    pub fn build(scene: &Scene, config: &PipelineConfig) -> ScenePrep {
        let grid_cfg = if scene.dynamic {
            GridConfig::new(config.grid_n)
        } else {
            GridConfig::static_scene(config.grid_n)
        };
        let grid = Arc::new(GridPartition::build(scene, grid_cfg));
        let layout = Arc::new(DramLayout::build(scene, &grid));
        let quantized: Arc<Vec<Gaussian4D>> =
            Arc::new(scene.gaussians.iter().map(|g| g.quantized_fp16()).collect());
        let shard_map = Arc::new(ShardMap::build(
            layout.total_span_bytes(),
            config.mem.shards,
            config.mem.dram.row_bytes,
        ));
        let compressed = if config.mem.residency.enabled() {
            Some(Arc::new(CompressedStore::build(
                &quantized,
                scene.dynamic,
                &layout,
                config.mem.residency.pages,
                config.mem.dram.row_bytes,
            )))
        } else {
            None
        };
        ScenePrep { grid, layout, quantized, shard_map, compressed }
    }
}

/// The frame pipeline engine: the stage graph plus its pooled context.
/// Stages own all hardware models and the posteriori state (ATG groups,
/// AII boundaries, early-termination calibration) carried between frames.
pub struct FramePipeline<'a> {
    pub config: PipelineConfig,
    pub scene: &'a Scene,
    pub grid: Arc<GridPartition>,
    pub layout: Arc<DramLayout>,
    pub tile_grid: TileGrid,
    /// FP16-quantized copy of the scene (what the datapath reads from
    /// DRAM) — computed once at build instead of per frame (§Perf).
    quantized: Arc<Vec<Gaussian4D>>,
    cull_stage: CullStage,
    project_stage: ProjectStage,
    intersect_stage: IntersectStage,
    group_stage: GroupStage,
    sort_stage: SortStage,
    blend_stage: BlendStage,
    ctx: FrameCtx,
    frame_idx: usize,
    /// Event-queue memory system backing the context's ports (None in
    /// synchronous mode).
    mem_sys: Option<Arc<Mutex<MemorySystem>>>,
    /// Whether this pipeline owns `mem_sys` (private system: the pipeline
    /// drives the per-frame epoch barrier). A system attached via
    /// [`FramePipeline::with_shared_memory`] is paced by its owner — the
    /// contended `RenderServer` batch.
    owns_mem: bool,
    /// The intra-frame parallel executor (sized by
    /// `PipelineConfig::threads`; persistent across frames).
    pool: WorkerPool,
    /// Host wall-clock per-stage accounting (BENCH layer).
    host: HostStageWall,
    /// Opt-in frame tracer `(sink, pid)` for standalone pipelines
    /// ([`FramePipeline::set_tracer`]). Round-managed pipelines leave this
    /// `None` — the round engine emits their spans post-replay in policy
    /// order instead.
    tracer: Option<(TraceSink, u64)>,
}

/// Which memory backend [`FramePipeline::build`] wires the context's ports
/// to.
enum MemChoice {
    /// Follow `PipelineConfig::mem` (private sync or private event-queue).
    Config,
    /// Register ports on a shared, contended event-queue system.
    Shared(Arc<Mutex<MemorySystem>>),
    /// Record per-frame request traces (two-phase contended batches).
    Trace,
}

impl<'a> FramePipeline<'a> {
    /// Build, including the offline grid partition + DRAM layout (use
    /// [`FramePipeline::with_prep`] to share an existing preparation).
    pub fn new(scene: &'a Scene, config: PipelineConfig) -> FramePipeline<'a> {
        let prep = ScenePrep::build(scene, &config);
        FramePipeline::with_prep(scene, prep, config)
    }

    /// Build on a shared scene preparation (multi-viewer serving: N
    /// pipelines, one grid/layout/quantized copy). The memory backend
    /// follows `config.mem`: synchronous ports, or a *private* event-queue
    /// system the pipeline paces itself.
    pub fn with_prep(
        scene: &'a Scene,
        prep: ScenePrep,
        config: PipelineConfig,
    ) -> FramePipeline<'a> {
        FramePipeline::build(scene, prep, config, MemChoice::Config)
    }

    /// Build on a shared preparation *and* a shared event-queue memory
    /// system: the pipeline registers its cull/blend ports on `sys` and
    /// contends with every other pipeline attached to it. The owner of
    /// `sys` (e.g. the contended `RenderServer` batch) drives
    /// `MemorySystem::advance_epoch` at frame-round boundaries.
    pub fn with_shared_memory(
        scene: &'a Scene,
        prep: ScenePrep,
        config: PipelineConfig,
        sys: Arc<Mutex<MemorySystem>>,
    ) -> FramePipeline<'a> {
        FramePipeline::build(scene, prep, config, MemChoice::Shared(sys))
    }

    /// Build on a shared preparation with **trace-recording** memory
    /// ports: frames simulate everything except DRAM timing, and
    /// [`FramePipeline::take_frame_traces`] drains the per-frame request
    /// streams for deterministic replay into a shared system — the render
    /// half of the two-phase contended batch.
    pub fn with_trace_ports(
        scene: &'a Scene,
        prep: ScenePrep,
        config: PipelineConfig,
    ) -> FramePipeline<'a> {
        FramePipeline::build(scene, prep, config, MemChoice::Trace)
    }

    /// Build the (cull, blend, update) [`MemPort`]s for a backend choice —
    /// shared by [`FramePipeline::build`] and the session-resume
    /// constructors (a resumed session re-registers fresh ports; retained
    /// state never carries another system's port handles). The update port
    /// exists only under `config.dynamic_updates` and always registers
    /// **third** (after cull, then blend) so port registration — and with
    /// it static-scene per-port statistics — is untouched when the stream
    /// is off.
    fn make_ports(
        config: &PipelineConfig,
        prep: &ScenePrep,
        choice: MemChoice,
    ) -> (MemPort, MemPort, Option<MemPort>, Option<Arc<Mutex<MemorySystem>>>, bool) {
        let dynamic = config.dynamic_updates;
        match choice {
            MemChoice::Shared(sys) => {
                let cull = MemPort::shared(&sys, MemStage::Preprocess);
                let blend = MemPort::shared(&sys, MemStage::Blend);
                let update = dynamic.then(|| MemPort::shared(&sys, MemStage::Update));
                (cull, blend, update, Some(sys), false)
            }
            MemChoice::Trace => (
                MemPort::trace(MemStage::Preprocess),
                MemPort::trace(MemStage::Blend),
                dynamic.then(|| MemPort::trace(MemStage::Update)),
                None,
                false,
            ),
            MemChoice::Config => match config.mem.mode {
                MemMode::Sync => (
                    MemPort::sync(config.mem.dram, MemStage::Preprocess),
                    MemPort::sync(config.mem.dram, MemStage::Blend),
                    dynamic.then(|| MemPort::sync(config.mem.dram, MemStage::Update)),
                    None,
                    false,
                ),
                MemMode::EventQueue => {
                    let mut sys = MemorySystem::new(config.mem.clone(), *prep.shard_map);
                    if let Some(store) = &prep.compressed {
                        sys.attach_residency(store);
                    }
                    let sys = Arc::new(Mutex::new(sys));
                    let cull = MemPort::shared(&sys, MemStage::Preprocess);
                    let blend = MemPort::shared(&sys, MemStage::Blend);
                    let update = dynamic.then(|| MemPort::shared(&sys, MemStage::Update));
                    (cull, blend, update, Some(sys), true)
                }
            },
        }
    }

    fn build(
        scene: &'a Scene,
        prep: ScenePrep,
        config: PipelineConfig,
        choice: MemChoice,
    ) -> FramePipeline<'a> {
        let tile_grid = TileGrid::new(config.width, config.height);
        let conn =
            ConnectionGraph::new(tile_grid.tiles_x, tile_grid.tiles_y, config.atg.tile_block);
        let n_blocks = conn.n_blocks();
        let sram = SramBuffer::new(SramConfig {
            capacity_bytes: config.sram_bytes,
            ..SramConfig::paper_default(
                Gaussian4D::dram_bytes(scene.dynamic),
                config.n_buckets,
            )
        });
        let buffer_lines = sram.capacity_lines();

        let (cull_port, blend_port, update_port, mem_sys, owns_mem) =
            Self::make_ports(&config, &prep, choice);

        let threads = config.resolved_threads();
        let mut ctx = FrameCtx::new(
            conn,
            config.dcim,
            n_blocks,
            tile_grid.n_tiles(),
            cull_port,
            blend_port,
        )
        .with_workers(threads);
        // The residency prefetcher rides the pooled context so it survives
        // session detach/resume (trajectory history and the previous
        // frame's cull pages are retained per-session state).
        ctx.prefetcher = prep.compressed.as_ref().map(|store| {
            ResidencyPrefetcher::new(
                config.mem.residency.policy,
                Arc::clone(&prep.grid),
                Arc::clone(store),
            )
        });
        // Dynamic update streaming: the temporal-delta producer and (under
        // DR-FC) the dirty-cell cull-reuse residency ride the context too —
        // both are carried per-session state.
        ctx.update_port = update_port;
        if config.dynamic_updates {
            ctx.temporal = Some(TemporalStream::new(
                scene.dynamic,
                prep.quantized.len(),
                prep.layout.cell_ranges.len(),
            ));
            if config.cull_reuse && config.use_drfc {
                ctx.cull_reuse = Some(CullReuse::new(
                    prep.layout.cell_ranges.len(),
                    prep.quantized.len(),
                ));
            }
        }
        FramePipeline {
            pool: WorkerPool::new(threads),
            host: HostStageWall::default(),
            tracer: None,
            cull_stage: CullStage,
            project_stage: ProjectStage,
            intersect_stage: IntersectStage,
            group_stage: GroupStage { atg: Atg::new(config.atg), buffer_lines },
            sort_stage: SortStage {
                engine: SortEngine::new(
                    config.use_aii,
                    config.n_buckets,
                    n_blocks,
                    config.sort_hw,
                ),
            },
            blend_stage: BlendStage::new(
                sram,
                HwRenderer::new(config.width, config.height).with_backend(config.render_backend),
            ),
            ctx,
            tile_grid,
            grid: prep.grid,
            layout: prep.layout,
            quantized: prep.quantized,
            config,
            scene,
            frame_idx: 0,
            mem_sys,
            owns_mem,
        }
    }

    /// The event-queue memory system backing this pipeline's ports (None
    /// in synchronous mode).
    pub fn memory_system(&self) -> Option<&Arc<Mutex<MemorySystem>>> {
        self.mem_sys.as_ref()
    }

    /// The (cull, blend) [`PortId`]s this pipeline registered on its
    /// event-queue memory system (None in synchronous mode). Owners of a
    /// shared system use this to map per-port statistics back to viewers
    /// instead of assuming a registration order.
    pub fn mem_port_ids(&self) -> Option<(PortId, PortId)> {
        Some((self.ctx.cull_port.shared_id()?, self.ctx.blend_port.shared_id()?))
    }

    /// The [`PortId`] of the dynamic update stream on the shared
    /// event-queue system (None when the stream is off or the backend is
    /// private/trace).
    pub fn update_port_id(&self) -> Option<PortId> {
        self.ctx.update_port.as_ref().and_then(MemPort::shared_id)
    }

    /// Reset posteriori state and frame counter (scene cut).
    pub fn reset(&mut self) {
        self.group_stage.atg.reset();
        self.sort_stage.engine.reset();
        // Cold-start the temporal machinery too: the next advance re-bakes
        // the baseline (ships nothing) and nothing is fetch-resident.
        if let Some(ts) = &mut self.ctx.temporal {
            *ts = TemporalStream::new(
                self.scene.dynamic,
                self.quantized.len(),
                self.layout.cell_ranges.len(),
            );
        }
        if let Some(reuse) = &mut self.ctx.cull_reuse {
            reuse.reset();
        }
        self.frame_idx = 0;
    }

    /// Advance the dynamic update stream for scene time `t`: bake + diff
    /// every record, issue the dirty-cell delta writes through the update
    /// port, drop cull-reuse residency for everything that changed, and
    /// (under `aii_retain = false`) cold-start the AII sort whenever any
    /// record moved. No-op unless the pipeline was built with
    /// `dynamic_updates`. Runs before the cull stage; the update writes are
    /// double-buffered per cell, so the frame's own reads never wait on
    /// them — the stream contends only through the shared channels.
    fn run_update_stream(&mut self, t: f32) {
        let FrameCtx { temporal, update_port, cull_reuse, traffic, energy, update_stats, .. } =
            &mut self.ctx;
        let (Some(temporal), Some(port)) = (temporal.as_mut(), update_port.as_mut()) else {
            return;
        };
        port.begin_frame();
        let stats = temporal.advance(&self.quantized, &self.layout, t);
        for (addr, bytes) in temporal.take_writes() {
            port.read(addr, bytes);
        }
        *update_stats = stats;
        traffic.update_dram = port.stats();
        energy.dram_pj += traffic.update_dram.energy_pj;
        if let Some(reuse) = cull_reuse.as_mut() {
            reuse.invalidate(temporal.dirty_cells(), temporal.dirty_records());
        }
        if !self.config.aii_retain && stats.updated_records > 0 {
            self.sort_stage.engine.reset();
        }
    }

    /// Process one frame. `render_image = false` runs only the performance
    /// path (events + models), which is what the parameter-sweep benches use.
    ///
    /// The body is the stage graph: every stage reads/writes the pooled
    /// [`FrameCtx`] through the shared [`FrameBind`] view.
    pub fn render_frame(&mut self, cam: &Camera, t: f32, render_image: bool) -> FrameResult {
        // Private event-queue system: frame barrier (all in-flight
        // transactions retire; port clocks align to the completion
        // horizon). Shared systems are paced by their owner per round.
        if self.owns_mem {
            if let Some(sys) = &self.mem_sys {
                sys.lock().expect("memory system lock poisoned").advance_epoch();
            }
        }
        let frame_t0 = Instant::now();
        self.ctx.begin_frame();
        // Dynamic scenes: stage the frame's update writes before any render
        // read is issued (no-op for static serving).
        self.run_update_stream(t);
        let bind = FrameBind {
            scene: self.scene,
            grid: &self.grid,
            layout: &self.layout,
            quantized: self.quantized.as_slice(),
            config: &self.config,
            tile_grid: &self.tile_grid,
        };
        self.cull_stage.run(&bind, cam, t, &mut self.ctx, &self.pool);
        self.project_stage.run(&bind, cam, t, &mut self.ctx, &self.pool);
        self.intersect_stage.run(&bind, &mut self.ctx, &self.pool);
        self.group_stage.run(&bind, &mut self.ctx);
        let sort_t0 = Instant::now();
        self.sort_stage.run(&bind, &mut self.ctx, &self.pool);
        let sort_s = sort_t0.elapsed().as_secs_f64();
        let blend_t0 = Instant::now();
        self.blend_stage.run(&bind, render_image, &mut self.ctx, &self.pool);
        let blend_s = blend_t0.elapsed().as_secs_f64();
        self.host.push(sort_s, blend_s, frame_t0.elapsed().as_secs_f64());
        let fidx = self.frame_idx;
        self.frame_idx += 1;

        let result = FrameResult {
            image: self.ctx.image.take(),
            traffic: self.ctx.traffic.clone(),
            energy: self.ctx.energy,
            latency: self.ctx.latency,
            preprocess_breakdown: self.ctx.preprocess_breakdown,
            sort: self.ctx.sort,
            atg_ops: self.ctx.atg_ops,
            atg_flags: self.ctx.atg_flags,
            n_visible: self.ctx.splats.len(),
            blend_pairs: self.ctx.blend_pairs,
            intersections: self.ctx.intersections,
            update: self.ctx.update_stats,
            cull_reuse: self.ctx.reuse_stats,
        };
        // Standalone tracing: emit this frame's simulated spans on the
        // pipeline's single viewer track. Round-managed pipelines have no
        // tracer here — their owner emits post-replay in policy order.
        if let Some((sink, pid)) = &self.tracer {
            let mut tr = sink.lock().expect("tracer lock poisoned");
            let t0 = tr.cursor(*pid, Track::Viewer(0));
            result.trace_spans(&mut tr, *pid, Track::Viewer(0), fidx, t0);
        }
        result
    }

    /// Attach an opt-in frame tracer: opens a traced process section named
    /// `label` on `sink`, records every subsequent frame's simulated-time
    /// stage spans on [`Track::Viewer`]\(0\), and — when this pipeline owns
    /// a private event-queue memory system — attaches the sink to it so
    /// per-channel DRAM transaction spans land in the same section.
    pub fn set_tracer(&mut self, sink: &TraceSink, label: &str) {
        let pid = sink.lock().expect("tracer lock poisoned").begin_process(label);
        if self.owns_mem {
            if let Some(sys) = &self.mem_sys {
                sys.lock()
                    .expect("memory system lock poisoned")
                    .set_tracer(sink.clone(), pid);
            }
        }
        self.tracer = Some((sink.clone(), pid));
    }

    /// The live early-termination factor (initially
    /// [`EARLY_TERMINATION_FACTOR`], re-calibrated by rendered frames).
    pub fn et_factor(&self) -> f64 {
        self.blend_stage.et_factor
    }

    /// Drain the per-frame DRAM request traces — `(cull, blend, update)`
    /// streams of `(addr, bytes)` in issue order (the update stream is
    /// empty unless dynamic updates are on). Non-empty only for pipelines
    /// built via [`FramePipeline::with_trace_ports`]; call once after each
    /// `render_frame`.
    pub fn take_frame_traces(
        &mut self,
    ) -> (Vec<(u64, u64)>, Vec<(u64, u64)>, Vec<(u64, u64)>) {
        (
            self.ctx.cull_port.take_trace(),
            self.ctx.blend_port.take_trace(),
            self.ctx.update_port.as_mut().map(MemPort::take_trace).unwrap_or_default(),
        )
    }

    /// Drain the prefetch page list the cull port recorded this frame
    /// (trace-port pipelines only; empty otherwise). The two-phase round
    /// engine replays it into the shared system *before* the frame's cull
    /// trace, mirroring the lockstep issue order.
    pub fn take_frame_prefetch(&mut self) -> Vec<usize> {
        self.ctx.cull_port.take_prefetch()
    }

    /// Host wall-clock per-stage accounting across all frames rendered so
    /// far (see [`HostStageWall`]).
    pub fn host_wall(&self) -> &HostStageWall {
        &self.host
    }

    /// Executor threads this pipeline's pool applies per frame.
    pub fn pool_threads(&self) -> usize {
        self.pool.threads()
    }

    /// Capacities of the pooled scratch buffers (see
    /// [`FrameCtx::scratch_capacities`]) — steady-state frames must leave
    /// this unchanged (the zero-allocation contract).
    pub fn scratch_capacities(&self) -> Vec<usize> {
        let mut caps = self.ctx.scratch_capacities();
        // The rasterizer's pooled scratch (depth orders, NMC partials,
        // debug seen-bitmap) is part of the same contract.
        caps.extend(self.blend_stage.render_scratch.capacities());
        caps
    }

    /// Detach this pipeline's retained per-session state — the pooled
    /// [`FrameCtx`] (scratch warmth), the ATG grouping and AII interval
    /// posteriori, the early-termination calibration, and the frame
    /// counter — into an owned [`SessionState`] that outlives the
    /// pipeline's scene borrow. A departing viewer session detaches so a
    /// later pipeline (same scene preparation and geometry) can resume
    /// warm instead of cold-starting; the state's memory ports are
    /// *not* carried over (resume registers fresh ones).
    pub fn detach_session(self) -> SessionState {
        SessionState {
            shape: SessionShape::of(&self.config),
            ctx: self.ctx,
            group_stage: self.group_stage,
            sort_stage: self.sort_stage,
            blend_stage: self.blend_stage,
            frame_idx: self.frame_idx,
            host: self.host,
        }
    }

    /// Resume a detached session on a shared preparation with the memory
    /// backend chosen by `config.mem` (the [`FramePipeline::with_prep`]
    /// counterpart). The very next `render_frame` continues the stream
    /// bit-identically to the pipeline the state was detached from.
    ///
    /// # Panics
    ///
    /// Panics if `config`'s state-bearing shape (resolution, tile block,
    /// bucket count, SRAM capacity, sort-engine choice) differs from the
    /// configuration the state was detached under — the pooled context is
    /// tile-indexed and the retained stages bake those dimensions in.
    pub fn resume_with_prep(
        scene: &'a Scene,
        prep: ScenePrep,
        config: PipelineConfig,
        state: SessionState,
    ) -> FramePipeline<'a> {
        FramePipeline::resume(scene, prep, config, MemChoice::Config, state)
    }

    /// Resume a detached session with **trace-recording** ports (the
    /// [`FramePipeline::with_trace_ports`] counterpart) — the render half
    /// of a two-phase contended round; the owner replays the traces into
    /// its shared system. The continuation is bit-identical to a
    /// shared-port resume: retained state never carries port handles.
    pub fn resume_with_trace_ports(
        scene: &'a Scene,
        prep: ScenePrep,
        config: PipelineConfig,
        state: SessionState,
    ) -> FramePipeline<'a> {
        FramePipeline::resume(scene, prep, config, MemChoice::Trace, state)
    }

    /// Resume a detached session with its cull/blend ports registered on a
    /// shared, contended event-queue system (the
    /// [`FramePipeline::with_shared_memory`] counterpart).
    pub fn resume_with_shared_memory(
        scene: &'a Scene,
        prep: ScenePrep,
        config: PipelineConfig,
        sys: Arc<Mutex<MemorySystem>>,
        state: SessionState,
    ) -> FramePipeline<'a> {
        FramePipeline::resume(scene, prep, config, MemChoice::Shared(sys), state)
    }

    fn resume(
        scene: &'a Scene,
        prep: ScenePrep,
        config: PipelineConfig,
        choice: MemChoice,
        state: SessionState,
    ) -> FramePipeline<'a> {
        assert_eq!(
            state.shape,
            SessionShape::of(&config),
            "session state detached under a different pipeline shape"
        );
        let tile_grid = TileGrid::new(config.width, config.height);
        let (cull_port, blend_port, update_port, mem_sys, owns_mem) =
            Self::make_ports(&config, &prep, choice);
        let SessionState {
            mut ctx,
            group_stage,
            sort_stage,
            mut blend_stage,
            frame_idx,
            host,
            ..
        } = state;
        ctx.cull_port = cull_port;
        ctx.blend_port = blend_port;
        ctx.update_port = update_port;
        // Align the carried temporal machinery with the resuming
        // configuration: the delta baseline and the cull-reuse residency
        // are retained per-session state (the resume is bit-identical to
        // an uninterrupted stream), created fresh when the resuming run
        // turns the stream on, dropped when it turns it off.
        if config.dynamic_updates {
            if ctx.temporal.is_none() {
                ctx.temporal = Some(TemporalStream::new(
                    scene.dynamic,
                    prep.quantized.len(),
                    prep.layout.cell_ranges.len(),
                ));
            }
            if config.cull_reuse && config.use_drfc {
                if ctx.cull_reuse.is_none() {
                    ctx.cull_reuse = Some(CullReuse::new(
                        prep.layout.cell_ranges.len(),
                        prep.quantized.len(),
                    ));
                }
            } else {
                ctx.cull_reuse = None;
            }
        } else {
            ctx.temporal = None;
            ctx.cull_reuse = None;
        }
        // Align the carried prefetcher with the resuming configuration:
        // keep it only when residency is still enabled under the *same*
        // policy (its history is policy-shaped); otherwise rebuild fresh
        // (or drop it when residency is off).
        ctx.prefetcher = if config.mem.residency.enabled() {
            match ctx.prefetcher.take() {
                Some(p) if p.policy() == config.mem.residency.policy => Some(p),
                _ => prep.compressed.as_ref().map(|store| {
                    ResidencyPrefetcher::new(
                        config.mem.residency.policy,
                        Arc::clone(&prep.grid),
                        Arc::clone(store),
                    )
                }),
            }
        } else {
            None
        };
        // The blend datapath (scalar vs lane-batched) is host-side, not
        // state-bearing — outputs are bit-identical — so the resumed run's
        // choice wins over whatever the session was detached under.
        blend_stage.renderer.backend = config.render_backend;
        // The executor pool is host-side state, resized to this run's
        // thread count (simulated stats are thread-count invariant).
        let threads = config.resolved_threads();
        ctx.workers.resize_with(threads.max(1), Default::default);
        FramePipeline {
            pool: WorkerPool::new(threads),
            host,
            tracer: None,
            cull_stage: CullStage,
            project_stage: ProjectStage,
            intersect_stage: IntersectStage,
            group_stage,
            sort_stage,
            blend_stage,
            ctx,
            tile_grid,
            grid: prep.grid,
            layout: prep.layout,
            quantized: prep.quantized,
            config,
            scene,
            frame_idx,
            mem_sys,
            owns_mem,
        }
    }

    /// Seed the AII sort engine's per-block intervals from retained state
    /// (`SessionState::take_aii_intervals` of a departed session). Returns
    /// `false` (and leaves the engine untouched) when the engine is the
    /// conventional baseline or the block counts differ — warm-starting is
    /// an optimization, never a requirement.
    pub fn warm_start_aii(&mut self, intervals: Vec<Option<Vec<f32>>>) -> bool {
        match &mut self.sort_stage.engine {
            SortEngine::Aii(aii) if aii.n_blocks() == intervals.len() => {
                aii.warm_start(intervals);
                true
            }
            _ => false,
        }
    }

    /// Tile blocks whose AII interval slots currently hold posteriori
    /// boundaries (0 for the conventional engine).
    pub fn aii_warm_blocks(&self) -> usize {
        match &self.sort_stage.engine {
            SortEngine::Aii(aii) => aii.warm_blocks(),
            SortEngine::Conventional => 0,
        }
    }
}

/// Owned, scene-independent retained state of one viewer session's
/// pipeline: the pooled frame context (scratch capacity warmth), the
/// stage units carrying posteriori state (ATG groups, AII intervals, SRAM
/// geometry + early-termination calibration), and the frame counter.
/// Produced by [`FramePipeline::detach_session`]; consumed by the
/// `resume_*` constructors. The contained memory ports are replaced on
/// resume — sessions own their state, memory systems own their ports.
#[derive(Debug)]
pub struct SessionState {
    /// The state-bearing configuration shape the state was detached under
    /// — resume re-checks it before adopting the retained stages.
    shape: SessionShape,
    ctx: FrameCtx,
    group_stage: GroupStage,
    sort_stage: SortStage,
    blend_stage: BlendStage,
    frame_idx: usize,
    host: HostStageWall,
}

/// The configuration dimensions baked into retained session state: the
/// tile-indexed context geometry, the block/bucket structure of the sort
/// and group stages, the SRAM buffer capacity, and the sort-engine choice.
/// Resume requires an exact match; everything else in `PipelineConfig`
/// (threads, memory backend, feature switches outside sorting) is safe to
/// change across a handoff.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct SessionShape {
    width: usize,
    height: usize,
    tile_block: usize,
    n_buckets: usize,
    sram_bytes: usize,
    use_aii: bool,
}

impl SessionShape {
    fn of(config: &PipelineConfig) -> SessionShape {
        SessionShape {
            width: config.width,
            height: config.height,
            tile_block: config.atg.tile_block,
            n_buckets: config.n_buckets,
            sram_bytes: config.sram_bytes,
            use_aii: config.use_aii,
        }
    }
}

impl SessionState {
    /// Frames the detached session had rendered.
    pub fn frame_idx(&self) -> usize {
        self.frame_idx
    }

    /// Extract the AII sort engine's retained per-block intervals, leaving
    /// the state cold (None for the conventional engine). This is the
    /// donor side of [`FramePipeline::warm_start_aii`]: a scheduler hands a
    /// departed session's intervals to a joining viewer whose view is
    /// expected to be depth-coherent with the donor's.
    pub fn take_aii_intervals(&mut self) -> Option<Vec<Option<Vec<f32>>>> {
        match &mut self.sort_stage.engine {
            SortEngine::Aii(aii) => Some(aii.take_intervals()),
            SortEngine::Conventional => None,
        }
    }

    /// Tile blocks whose AII slots hold posteriori boundaries.
    pub fn aii_warm_blocks(&self) -> usize {
        match &self.sort_stage.engine {
            SortEngine::Aii(aii) => aii.warm_blocks(),
            SortEngine::Conventional => 0,
        }
    }

    /// Release the pooled per-frame scratch of a *parked* state (the
    /// context pools and the rasterizer's render scratch). Semantic
    /// carried state — temporal deltas, cull reuse, prefetcher history,
    /// AII interval posteriori, early-termination calibration — is
    /// untouched, so a trimmed state still donates warm AII intervals
    /// and still resumes bit-identically; it just re-grows its pools on
    /// the first frame after resume. A scheduler retaining thousands of
    /// departed sessions calls this so parked states hold O(semantic
    /// state), not O(peak frame working set).
    pub fn trim_scratch(&mut self) {
        self.ctx.trim_scratch();
        self.blend_stage.render_scratch.trim();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Trajectory, ViewCondition};
    use crate::math::Vec3;
    use crate::scene::synth::{SceneKind, SynthParams};

    fn small_scene() -> Scene {
        SynthParams::new(SceneKind::DynamicLarge, 4000).generate()
    }

    fn template(w: usize, h: usize) -> Camera {
        let mut c = Camera::look_at(
            Vec3::new(0.0, 4.0, 20.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            w as f32 / h as f32,
            0.1,
            200.0,
        );
        c.set_resolution(w, h);
        c
    }

    #[test]
    fn frame_produces_consistent_stats() {
        let scene = small_scene();
        let cfg = PipelineConfig::paper(true).with_resolution(320, 180);
        let mut p = FramePipeline::new(&scene, cfg);
        let cam = template(320, 180);
        let r = p.render_frame(&cam, 0.3, false);
        assert!(r.n_visible > 0);
        assert!(r.traffic.gaussians_fetched >= r.traffic.gaussians_visible);
        assert!(r.energy.total_pj() > 0.0);
        assert!(r.latency.pipelined_ns() > 0.0);
        assert!(r.blend_pairs > 0);
    }

    #[test]
    fn rendered_and_perf_only_agree_on_traffic() {
        let scene = small_scene();
        let cfg = PipelineConfig::paper(true).with_resolution(160, 96);
        let cam = template(160, 96);
        let mut p1 = FramePipeline::new(&scene, cfg.clone());
        let r1 = p1.render_frame(&cam, 0.3, true);
        let mut p2 = FramePipeline::new(&scene, cfg);
        let r2 = p2.render_frame(&cam, 0.3, false);
        assert!(r1.image.is_some());
        assert!(r2.image.is_none());
        assert_eq!(r1.traffic.gaussians_fetched, r2.traffic.gaussians_fetched);
        assert_eq!(r1.traffic.blend_sram.lookups, r2.traffic.blend_sram.lookups);
        assert_eq!(r1.n_visible, r2.n_visible);
    }

    #[test]
    fn early_termination_factor_calibrates_from_rendered_frames() {
        let scene = small_scene();
        let cfg = PipelineConfig::paper(true).with_resolution(160, 96);
        let cam = template(160, 96);
        let mut p = FramePipeline::new(&scene, cfg);
        assert_eq!(p.et_factor(), EARLY_TERMINATION_FACTOR);
        let exact = p.render_frame(&cam, 0.3, true);
        let calibrated = p.et_factor();
        assert!(calibrated > 0.0 && calibrated <= 1.0, "factor {calibrated}");
        // A perf-only frame right after must model pairs near the exact
        // count of the same view (identical frame → same upper bound).
        let modeled = p.render_frame(&cam, 0.3, false);
        let ratio = modeled.blend_pairs as f64 / exact.blend_pairs.max(1) as f64;
        assert!(
            (0.8..=1.25).contains(&ratio),
            "calibrated model {} vs exact {} (ratio {ratio})",
            modeled.blend_pairs,
            exact.blend_pairs
        );
    }

    #[test]
    fn drfc_reduces_preprocess_dram_vs_baseline() {
        let scene = small_scene();
        let cam = template(320, 180);
        let mut with = FramePipeline::new(
            &scene,
            PipelineConfig::paper(true).with_resolution(320, 180),
        );
        let mut without = FramePipeline::new(
            &scene,
            PipelineConfig {
                use_drfc: false,
                ..PipelineConfig::paper(true).with_resolution(320, 180)
            },
        );
        let rw = with.render_frame(&cam, 0.2, false);
        let ro = without.render_frame(&cam, 0.2, false);
        assert!(
            rw.traffic.preprocess_dram.bytes < ro.traffic.preprocess_dram.bytes,
            "DR-FC {} vs conventional {}",
            rw.traffic.preprocess_dram.bytes,
            ro.traffic.preprocess_dram.bytes
        );
        // Both see the same visible set.
        assert_eq!(rw.n_visible, ro.n_visible);
    }

    #[test]
    fn posteriori_frames_cost_less_atg_and_sort() {
        let scene = small_scene();
        let cfg = PipelineConfig::paper(true).with_resolution(320, 180);
        let mut p = FramePipeline::new(&scene, cfg);
        let cam_t = template(320, 180);
        // A fully static viewing sequence (no head motion, frozen scene
        // time): phase 2 must reuse the grouping wholesale.
        let traj = Trajectory::new(ViewCondition::Static, 4)
            .with_scene(Vec3::ZERO, 22.0)
            .with_time_span(0.3, 0.3);
        let frames = traj.generate(&cam_t);
        let mut results = Vec::new();
        for (cam, t) in &frames {
            results.push(p.render_frame(cam, *t, false));
        }
        let first = &results[0];
        let later = &results[3];
        assert!(
            later.atg_ops < first.atg_ops,
            "posteriori ATG {} vs frame-0 {}",
            later.atg_ops,
            first.atg_ops
        );
        assert_eq!(later.atg_flags, 0, "static sequence raises no flags");
        assert_eq!(later.sort.minmax_scanned, 0, "AII skips min/max after frame 0");
    }

    #[test]
    fn static_scene_pipeline_works() {
        let scene = SynthParams::new(SceneKind::StaticLarge, 3000).generate();
        let cfg = PipelineConfig::paper(false).with_resolution(256, 144);
        let mut p = FramePipeline::new(&scene, cfg);
        let cam = template(256, 144);
        let r = p.render_frame(&cam, 0.0, true);
        assert!(r.n_visible > 0);
        let img = r.image.unwrap();
        assert!(img.mean_luma() > 0.01, "rendered something: {}", img.mean_luma());
    }

    #[test]
    fn event_queue_backend_runs_and_models_stage_overlap() {
        let scene = small_scene();
        let mut cfg = PipelineConfig::paper(true).with_resolution(192, 108);
        cfg.mem = crate::memory::MemSimConfig::event_queue();
        let mut p = FramePipeline::new(&scene, cfg);
        assert!(p.memory_system().is_some());
        let cam = template(192, 108);
        let r1 = p.render_frame(&cam, 0.3, false);
        assert!(r1.traffic.preprocess_dram.bytes > 0);
        // The blend miss-fill shares channels with the cull fetch: the
        // overlap model records blend requests queueing behind the
        // preprocess stream.
        assert!(r1.traffic.blend_dram.wait_ns > 0.0);
        // Per-frame epoch barriers keep later frames well-formed: same
        // view ⇒ same transfer counts, no stale-horizon waits exploding.
        let r2 = p.render_frame(&cam, 0.3, false);
        assert_eq!(r1.traffic.blend_dram.bytes, r2.traffic.blend_dram.bytes);
        assert_eq!(
            r1.traffic.preprocess_dram.bursts,
            r2.traffic.preprocess_dram.bursts
        );
    }

    #[test]
    fn detached_session_resumes_bit_identically() {
        let scene = small_scene();
        let cfg = PipelineConfig::paper(true).with_resolution(192, 108);
        let prep = ScenePrep::build(&scene, &cfg);
        let cam = template(192, 108);
        // Frozen pose + scene time: frame 2's working sets depend only on
        // the carried posteriori state, making the handoff check exact.
        let times = [0.3f32, 0.3, 0.3];

        // Uninterrupted reference.
        let mut whole = FramePipeline::with_prep(&scene, prep.clone(), cfg.clone());
        let mut expect = Vec::new();
        for &t in &times {
            expect.push(whole.render_frame(&cam, t, false));
        }

        // Detach after frame 1, resume, continue: frame 2 must match the
        // uninterrupted stream bit-for-bit (posteriori state carried over).
        let mut first = FramePipeline::with_prep(&scene, prep.clone(), cfg.clone());
        first.render_frame(&cam, times[0], false);
        first.render_frame(&cam, times[1], false);
        let state = first.detach_session();
        assert_eq!(state.frame_idx(), 2);
        assert!(state.aii_warm_blocks() > 0, "posteriori intervals retained");
        let mut resumed = FramePipeline::resume_with_prep(&scene, prep.clone(), cfg.clone(), state);
        let r = resumed.render_frame(&cam, times[2], false);
        let e = &expect[2];
        assert_eq!(r.traffic, e.traffic);
        assert_eq!(r.sort, e.sort);
        assert_eq!(r.energy, e.energy);
        assert_eq!(r.n_visible, e.n_visible);
        assert_eq!(r.blend_pairs, e.blend_pairs);
        assert_eq!(r.atg_ops, e.atg_ops, "ATG posteriori must survive the handoff");
        assert_eq!(r.sort.minmax_scanned, 0, "AII stays warm across the handoff");

        // A cold pipeline at the same frame pays the min/max scan instead.
        let mut cold = FramePipeline::with_prep(&scene, prep, cfg);
        let c = cold.render_frame(&cam, times[2], false);
        assert!(c.sort.minmax_scanned > 0);
    }

    #[test]
    fn aii_warm_start_seeds_intervals_from_donor_state() {
        let scene = small_scene();
        let cfg = PipelineConfig::paper(true).with_resolution(192, 108);
        let prep = ScenePrep::build(&scene, &cfg);
        let cam = template(192, 108);

        let mut donor = FramePipeline::with_prep(&scene, prep.clone(), cfg.clone());
        donor.render_frame(&cam, 0.3, false);
        let mut state = donor.detach_session();
        let intervals = state.take_aii_intervals().expect("paper config uses AII");
        assert_eq!(state.aii_warm_blocks(), 0, "take_aii_intervals cools the donor");

        let mut joiner = FramePipeline::with_prep(&scene, prep, cfg);
        assert_eq!(joiner.aii_warm_blocks(), 0);
        assert!(joiner.warm_start_aii(intervals));
        assert!(joiner.aii_warm_blocks() > 0);
        let r = joiner.render_frame(&cam, 0.3, false);
        assert_eq!(
            r.sort.minmax_scanned, 0,
            "warm-started joiner skips the phase-1 scan on a coherent view"
        );
    }

    #[test]
    fn shared_prep_matches_private_build() {
        let scene = small_scene();
        let cfg = PipelineConfig::paper(true).with_resolution(192, 108);
        let cam = template(192, 108);
        let prep = ScenePrep::build(&scene, &cfg);
        let mut shared_a = FramePipeline::with_prep(&scene, prep.clone(), cfg.clone());
        let mut shared_b = FramePipeline::with_prep(&scene, prep, cfg.clone());
        let mut private = FramePipeline::new(&scene, cfg);
        let ra = shared_a.render_frame(&cam, 0.4, false);
        let rb = shared_b.render_frame(&cam, 0.4, false);
        let rp = private.render_frame(&cam, 0.4, false);
        assert_eq!(ra.traffic, rb.traffic);
        assert_eq!(ra.traffic, rp.traffic);
        assert_eq!(ra.sort, rp.sort);
        assert_eq!(ra.n_visible, rp.n_visible);
    }
}
