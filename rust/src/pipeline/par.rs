//! `pipeline::par` — the deterministic intra-frame parallel executor.
//!
//! [`WorkerPool`] is a **persistent, std-only scoped worker pool**: `N − 1`
//! OS threads live as long as the pool (one [`FramePipeline`] or one
//! contended server batch), and [`WorkerPool::scope`] hands out a
//! [`Scope`] whose `spawn` accepts closures borrowing the caller's stack —
//! exactly like `std::thread::scope`, but without re-spawning threads every
//! frame. The calling thread participates: after the scope closure returns
//! it drains the task queue itself, so a pool of `threads = T` applies `T`
//! cores to the region.
//!
//! # Determinism contract
//!
//! The executor never makes *statistics* depend on scheduling:
//!
//! * workers write **disjoint** slices of the pooled
//!   [`FrameCtx`](super::FrameCtx) (per-block sort outputs, per-tile blend
//!   outputs, per-segment SRAM streams) through [`SharedSlice`];
//! * every accumulator that crosses the fan-out is either an integer
//!   counter (exact under any reduction order) or is **derived** from
//!   integer counters at read time (SRAM/NMC energy), and partials are
//!   reduced on the calling thread in fixed block/tile/segment order;
//! * DRAM request *order* is preserved by collecting requests with their
//!   global sequence index and replaying them serially.
//!
//! Consequently every simulated stat output is bit-identical to the serial
//! path at any thread count — enforced by the `stage_graph_determinism`
//! thread-matrix suite and the CI `threads-matrix` job.

use std::any::Any;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Resolve a configured thread count: `0` means "auto" — the
/// `PALLAS_THREADS` environment variable if set (and a positive integer),
/// else `std::thread::available_parallelism()`.
pub fn resolve_threads(configured: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    if let Ok(s) = std::env::var("PALLAS_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(usize::from).unwrap_or(1)
}

/// The contiguous `[lo, hi)` index range of an `items`-long space assigned
/// to `worker` of `workers` total — the single chunk-partition rule every
/// chunked stage fan-out (cull cells, project gaussians, intersect splat
/// routing, blend classify) shares. Ceil-divided, so ascending worker
/// order covers the space exactly once; trailing workers may get empty
/// ranges.
pub(crate) fn chunk_bounds(worker: usize, items: usize, workers: usize) -> (usize, usize) {
    let chunk = items.div_ceil(workers.max(1)).max(1);
    ((worker * chunk).min(items), ((worker + 1) * chunk).min(items))
}

type Task = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    queue: VecDeque<Task>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

/// Completion latch of one scope: counts outstanding tasks and carries the
/// first panic payload across threads.
struct Latch {
    remaining: Mutex<usize>,
    done_cv: Condvar,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

/// A persistent scoped worker pool (see the module docs). `threads <= 1`
/// builds a serial pool: no OS threads, `spawn` runs closures inline in
/// spawn order — the degenerate case every parallel region reduces to.
pub struct WorkerPool {
    shared: Option<Arc<PoolShared>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("threads", &self.threads).finish()
    }
}

impl WorkerPool {
    /// Build a pool applying `threads` cores to each scope (the calling
    /// thread counts as one; `threads − 1` workers are spawned).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        if threads == 1 {
            return WorkerPool { shared: None, handles: Vec::new(), threads };
        }
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { queue: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
        });
        let handles = (0..threads - 1)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        WorkerPool { shared: Some(shared), handles, threads }
    }

    /// Cores this pool applies to a scope (including the caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run a parallel region: `f` spawns tasks on the given [`Scope`];
    /// `scope` returns only after every spawned task has finished. Panics
    /// inside tasks are caught, the region completes, and the first payload
    /// is re-raised here.
    pub fn scope<'env, F>(&self, f: F)
    where
        F: FnOnce(&Scope<'_, 'env>),
    {
        let latch = Arc::new(Latch {
            remaining: Mutex::new(0),
            done_cv: Condvar::new(),
            panic: Mutex::new(None),
        });
        let scope = Scope { pool: self, latch: Arc::clone(&latch), _env: PhantomData };
        // A panic in `f` must not unwind past already-spawned tasks (they
        // borrow the caller's stack): catch it, finish the region, re-raise.
        let f_result = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        // The caller helps drain the queue, then waits for stragglers still
        // running on workers.
        if let Some(shared) = &self.shared {
            loop {
                let task = {
                    let mut st = shared.state.lock().expect("worker pool lock poisoned");
                    st.queue.pop_front()
                };
                match task {
                    Some(t) => t(),
                    None => break,
                }
            }
        }
        let mut remaining = latch.remaining.lock().expect("scope latch lock poisoned");
        while *remaining > 0 {
            remaining = latch.done_cv.wait(remaining).expect("scope latch wait poisoned");
        }
        drop(remaining);
        if let Err(p) = f_result {
            resume_unwind(p);
        }
        let payload = latch.panic.lock().expect("scope panic slot poisoned").take();
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            shared.state.lock().expect("worker pool lock poisoned").shutdown = true;
            shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let task = {
            let mut st = shared.state.lock().expect("worker pool lock poisoned");
            loop {
                if let Some(t) = st.queue.pop_front() {
                    break Some(t);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.work_cv.wait(st).expect("worker pool wait poisoned");
            }
        };
        match task {
            Some(t) => t(),
            None => return,
        }
    }
}

/// The spawn handle of one [`WorkerPool::scope`] region. Closures may
/// borrow anything that outlives the `scope` call (`'env`).
pub struct Scope<'pool, 'env> {
    pool: &'pool WorkerPool,
    latch: Arc<Latch>,
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'pool, 'env> Scope<'pool, 'env> {
    /// Spawn one task. On a serial pool the closure runs inline (in spawn
    /// order); otherwise it is queued for the workers / the draining
    /// caller.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        let Some(shared) = &self.pool.shared else {
            f();
            return;
        };
        *self.latch.remaining.lock().expect("scope latch lock poisoned") += 1;
        let latch = Arc::clone(&self.latch);
        let task: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            if let Err(p) = catch_unwind(AssertUnwindSafe(f)) {
                let mut slot = latch.panic.lock().expect("scope panic slot poisoned");
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
            let mut remaining = latch.remaining.lock().expect("scope latch lock poisoned");
            *remaining -= 1;
            if *remaining == 0 {
                latch.done_cv.notify_all();
            }
        });
        // SAFETY: `scope` does not return until the latch reaches zero,
        // i.e. this task has finished running, so every `'env` borrow the
        // closure captures strictly outlives its execution. The lifetime is
        // erased only to store the task in the long-lived queue.
        let task: Task = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Box<dyn FnOnce() + Send>>(task)
        };
        let mut st = shared.state.lock().expect("worker pool lock poisoned");
        st.queue.push_back(task);
        drop(st);
        shared.work_cv.notify_one();
    }
}

/// A shared view of a mutable slice for fan-out writes to **disjoint**
/// indices. The executor's stages partition index spaces statically (by
/// block, tile, or segment), so no two workers ever touch the same element;
/// the wrapper only erases the exclusivity the borrow checker cannot see
/// across the static partition.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

impl<T> Clone for SharedSlice<'_, T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SharedSlice<'_, T> {}

// SAFETY: access discipline is the caller's obligation (disjoint indices);
// the data itself moves between threads, hence the `T: Send` bounds.
unsafe impl<T: Send> Send for SharedSlice<'_, T> {}
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> SharedSlice<'a, T> {
        SharedSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Exclusive access to element `i`.
    ///
    /// # Safety
    ///
    /// `i < len`, and no other thread may access index `i` while the
    /// returned borrow lives (the stages guarantee this by striding or
    /// chunking the index space per worker).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_pool_runs_inline_in_spawn_order() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut log: Vec<usize> = Vec::new();
        {
            let log = &mut log;
            pool.scope(|s| {
                // Serial spawns run immediately, so sequential &mut
                // captures are fine one at a time.
                s.spawn(|| log.push(1));
            });
        }
        let mut log2: Vec<usize> = Vec::new();
        {
            let log2 = &mut log2;
            pool.scope(|s| s.spawn(move || log2.extend([2, 3])));
        }
        assert_eq!(log, vec![1]);
        assert_eq!(log2, vec![2, 3]);
    }

    #[test]
    fn parallel_pool_completes_all_tasks() {
        let pool = WorkerPool::new(4);
        let counter = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..64 {
                let counter = &counter;
                s.spawn(move || {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 64);
        // The pool is persistent: a second scope reuses the same workers.
        pool.scope(|s| {
            for _ in 0..8 {
                let counter = &counter;
                s.spawn(move || {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 72);
    }

    #[test]
    fn scoped_borrows_of_disjoint_chunks() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u64; 30];
        pool.scope(|s| {
            for (i, chunk) in data.chunks_mut(10).enumerate() {
                s.spawn(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 10 + j) as u64;
                    }
                });
            }
        });
        assert_eq!(data, (0..30u64).collect::<Vec<_>>());
    }

    #[test]
    fn shared_slice_disjoint_strided_writes() {
        let pool = WorkerPool::new(4);
        let mut data = vec![0usize; 101];
        let n = data.len();
        {
            let sl = SharedSlice::new(data.as_mut_slice());
            pool.scope(|s| {
                for w in 0..4 {
                    s.spawn(move || {
                        let mut i = w;
                        while i < n {
                            // SAFETY: indices strided by worker — disjoint.
                            unsafe { *sl.get_mut(i) = i * 2 };
                            i += 4;
                        }
                    });
                }
            });
        }
        assert!(data.iter().enumerate().all(|(i, &v)| v == i * 2));
    }

    #[test]
    fn task_panic_propagates_after_region_completes() {
        let pool = WorkerPool::new(2);
        let done = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.spawn(|| panic!("boom"));
                let done = &done;
                s.spawn(move || {
                    done.fetch_add(1, Ordering::SeqCst);
                });
            });
        }));
        assert!(result.is_err(), "task panic must surface from scope()");
        assert_eq!(done.load(Ordering::SeqCst), 1, "sibling task still ran");
        // The pool survives a panicked scope.
        let again = AtomicUsize::new(0);
        pool.scope(|s| {
            let again = &again;
            s.spawn(move || {
                again.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(again.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn resolve_threads_prefers_explicit_over_env() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn chunk_bounds_partitions_exactly_once_in_order() {
        for items in [0usize, 1, 5, 17, 100, 101] {
            for workers in [1usize, 2, 3, 8, 16] {
                let mut covered = Vec::new();
                for w in 0..workers {
                    let (lo, hi) = chunk_bounds(w, items, workers);
                    assert!(lo <= hi && hi <= items);
                    covered.extend(lo..hi);
                }
                let expect: Vec<usize> = (0..items).collect();
                assert_eq!(covered, expect, "items={items} workers={workers}");
            }
        }
        // Degenerate worker count clamps to one.
        assert_eq!(chunk_bounds(0, 4, 0), (0, 4));
    }
}
