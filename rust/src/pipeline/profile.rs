//! Fig. 2(a) reproduction: latency breakdown of the (baseline) dynamic-3DGS
//! pipeline into preprocessing / sorting / rasterization, from the modeled
//! stage latencies.

use super::frame::{FramePipeline, PipelineConfig};
use crate::camera::Camera;
use crate::scene::Scene;

/// One phase's share of frame latency.
#[derive(Debug, Clone)]
pub struct PhaseShare {
    pub phase: &'static str,
    pub ns: f64,
    pub share: f64,
}

/// Run `frames` frames of the given configuration and return the averaged
/// breakdown (preprocessing / sorting / rasterization shares).
pub fn profile_breakdown(
    scene: &Scene,
    config: PipelineConfig,
    frames: &[(Camera, f32)],
) -> Vec<PhaseShare> {
    let mut pipeline = FramePipeline::new(scene, config);
    let mut pre = 0.0;
    let mut sort = 0.0;
    let mut blend = 0.0;
    for (cam, t) in frames {
        let r = pipeline.render_frame(cam, *t, false);
        pre += r.latency.preprocess_ns;
        sort += r.latency.sort_ns;
        blend += r.latency.blend_ns;
    }
    let total = (pre + sort + blend).max(1e-12);
    vec![
        PhaseShare { phase: "preprocessing", ns: pre, share: pre / total },
        PhaseShare { phase: "sorting", ns: sort, share: sort / total },
        PhaseShare { phase: "rasterization", ns: blend, share: blend / total },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Trajectory, ViewCondition};
    use crate::math::Vec3;
    use crate::scene::synth::{SceneKind, SynthParams};

    #[test]
    fn baseline_preprocessing_dominated_by_culling_fetch() {
        // The paper's Fig. 2(a): in the unoptimized dynamic pipeline,
        // preprocessing (frustum-culling DRAM sweep) is a major phase.
        let scene = SynthParams::new(SceneKind::DynamicLarge, 60_000).generate();
        let mut cam = Camera::look_at(
            Vec3::new(0.0, 4.0, 20.0),
            Vec3::ZERO,
            Vec3::new(0.0, 1.0, 0.0),
            60f32.to_radians(),
            16.0 / 9.0,
            0.1,
            200.0,
        );
        cam.set_resolution(160, 90);
        let frames = Trajectory::new(ViewCondition::Average, 3)
            .with_scene(Vec3::ZERO, 22.0)
            .generate(&cam);
        let shares = profile_breakdown(
            &scene,
            PipelineConfig::baseline(true).with_resolution(160, 90),
            &frames,
        );
        assert_eq!(shares.len(), 3);
        let total: f64 = shares.iter().map(|s| s.share).sum();
        assert!((total - 1.0).abs() < 1e-9);
        // All three phases must register (their balance shifts with scale —
        // the fig2 bench runs the paper-scale version).
        for s in &shares {
            let floor = if s.phase == "sorting" { 0.01 } else { 0.05 };
            assert!(
                s.share > floor,
                "phase must be significant in the baseline: {} = {}",
                s.phase,
                s.share
            );
        }
    }
}
